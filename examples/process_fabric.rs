//! Process-mode fabric: count a treelet with P = 4 real rank *processes*
//! exchanging packets over localhost sockets, then compare against the
//! in-process threaded fabric — the counts are bit-identical, and the
//! process-mode report carries *measured* (wall-clock) link parameters
//! instead of the simulated Hockney ones.
//!
//!     cargo build --release            # the workers need `harpsg-rank`
//!     cargo run --release --example process_fabric

use harpsg::coordinator::{
    launch, DistributedRunner, FabricKind, ModeSelect, ProcSpec, RunConfig,
};
use harpsg::graph::{rmat::generate, RmatParams};
use harpsg::template::builtin;
use std::path::PathBuf;

/// Examples build into `target/<profile>/examples/`, the worker binary
/// into `target/<profile>/` — point the launcher one directory up.
fn worker_binary() -> Option<PathBuf> {
    let exe = std::env::current_exe().ok()?;
    let bin = exe.parent()?.parent()?.join("harpsg-rank");
    bin.exists().then_some(bin)
}

fn main() {
    let mut cfg = RunConfig::default();
    cfg.n_ranks = 4;
    cfg.n_workers = 2;
    cfg.n_iterations = 10;
    cfg.seed = 7;
    cfg.mode = ModeSelect::Pipeline;
    cfg.fabric = FabricKind::Socket;

    // the graph travels as a *spec*, not as bytes: every rank process
    // regenerates the identical R-MAT graph from the seed
    let graph_spec = "rmat:256:2000:3:7";
    let mut spec = ProcSpec::new("u5-2", graph_spec, 0, cfg.clone());
    spec.rank_bin = worker_binary();
    if spec.rank_bin.is_none() {
        eprintln!("note: `harpsg-rank` not found next to the target dir;");
        eprintln!("      run `cargo build --release` first (falling back to $PATH siblings)");
    }

    println!("launching {} rank processes over localhost TCP...", cfg.n_ranks);
    let merged = launch(&spec).expect("process-mode launch");
    println!("process-mode estimate: {:.0} embeddings", merged.estimate);

    // the same job on the in-process threaded fabric
    let g = generate(&RmatParams::with_skew(256, 2_000, 3, 7));
    let t = builtin("u5-2").expect("builtin template");
    cfg.fabric = FabricKind::Threaded;
    let reference = DistributedRunner::new(&t, &g, cfg).run();
    println!("in-process estimate:   {:.0} embeddings", reference.estimate);
    assert_eq!(
        merged.estimate.to_bits(),
        reference.estimate.to_bits(),
        "the fabric must not change the count"
    );
    println!("bit-identical across fabrics: yes");

    // measured, not simulated: each rank fitted alpha + beta*bytes to its
    // own real blocking sends over the mesh
    println!("\nmeasured link (wall-clock Hockney fit per rank):");
    for l in &merged.link {
        println!(
            "  rank {}: alpha {:.3e} s, beta {:.3e} s/B ({} sends)",
            l.rank, l.alpha_s, l.beta_s_per_byte, l.samples
        );
    }
    println!(
        "\nexchange: {} decisions, wall-clock {:.2} s across {} processes",
        merged.comm_decisions.len(),
        merged.real_seconds,
        spec.cfg.n_ranks
    );
}
