//! Quickstart: estimate treelet counts on a small R-MAT graph and compare
//! against the exact brute-force count — first single-rank, then through
//! the `harpsg::api` facade (a `Session` running a validated `CountJob`).
//!
//!     cargo run --release --example quickstart

use harpsg::api::{CountJob, Session, SessionOptions};
use harpsg::colorcount::{count_embeddings, estimate, Engine};
use harpsg::coordinator::ModeSelect;
use harpsg::graph::{degree_stats, rmat::generate, RmatParams};
use harpsg::template::builtin;

fn main() {
    // a small social-network-like graph
    let g = generate(&RmatParams::with_skew(256, 2_000, 3, 7));
    let st = degree_stats(&g);
    println!(
        "graph: {} vertices, {} edges, avg deg {:.1}, max deg {}",
        st.n_vertices, st.n_edges, st.avg_degree, st.max_degree
    );

    let t = builtin("u5-2").expect("builtin template");
    println!("template: {} ({} vertices)", t.name, t.size());

    // exact count (exponential backtracking — only viable on tiny graphs)
    let truth = count_embeddings(&t, &g);
    println!("exact embeddings (brute force): {truth}");

    // single-rank color-coding estimate
    let engine = Engine::new(&t);
    let est = estimate(&engine, &g, 400, 42, 3);
    println!(
        "color-coding estimate (400 iters): {:.0} (error {:+.1}%)",
        est.value,
        100.0 * (est.value - truth) / truth
    );

    // the same estimate through the facade (8 simulated ranks, pipelined
    // Adaptive-Group exchange, neighbor-list partitioned tasks) —
    // identical counting semantics, plus the model clock and a
    // serializable report
    let session = Session::with_options(g, SessionOptions::default()).expect("session");
    let job = CountJob::builder(t)
        .ranks(8)
        .iterations(50)
        .mode(ModeSelect::AdaptiveLb)
        .build()
        .expect("valid job");
    let res = session.count(&job).expect("count");
    println!(
        "distributed estimate (8 ranks, 50 iters): {:.0} (error {:+.1}%)",
        res.estimate,
        100.0 * (res.estimate - truth) / truth
    );
    println!(
        "model clock: {:.3} ms/iter ({:.0}% compute), peak {:.1} KiB/rank",
        res.model.total * 1e3,
        100.0 * (1.0 - res.model.comm_ratio()),
        res.peak_mem() as f64 / 1024.0
    );
    println!("\nmachine-readable report (harpsg count --json prints the same):");
    println!("{}", res.to_json_string());
}
