//! Adaptive-Group communication demo: shows the mode switch, the ring
//! schedule, and the measured overlap ratio ρ for small vs large
//! templates — the mechanism behind Figs 8/9. The measured section runs
//! through one `api::Session`, so all four templates share one partition
//! and request-list build.
//!
//!     cargo run --release --example adaptive_comm_demo

use harpsg::api::{CountJob, Session};
use harpsg::comm::{CommMode, Schedule};
use harpsg::coordinator::ModeSelect;
use harpsg::graph::Dataset;
use harpsg::template::{builtin, complexity};

fn main() {
    println!("== the Fig-2 routing: 5 ranks, group size 3 ==");
    let s = Schedule::ring(5, 1);
    for (w, step) in s.plans.iter().enumerate() {
        print!("step {w}:");
        for (p, plan) in step.iter().enumerate() {
            print!("  {p}→{}", plan.send_to[0]);
        }
        println!();
    }
    println!("(every ordered pair exactly once across {} steps)\n", s.n_steps());

    println!("== adaptive switch by template intensity (threshold 4.5) ==");
    let pol = harpsg::comm::AdaptivePolicy::default();
    for name in harpsg::template::BUILTIN_NAMES {
        let tc = complexity(&builtin(name).unwrap());
        let mode = pol.choose(&tc, 10);
        println!(
            "  {:7} intensity {:6.1} -> {}",
            name,
            tc.intensity,
            match mode {
                CommMode::AllToAll => "all-to-all",
                CommMode::Pipeline { .. } => "pipelined ring",
            }
        );
    }

    println!("\n== model-driven group-size sweep (P = 10, u12-2 shape) ==");
    let binom = harpsg::combin::Binomial::new();
    let tc = complexity(&builtin("u12-2").unwrap());
    for rows in [5.0, 50.0, 500.0, 5_000.0, 50_000.0] {
        let shape = harpsg::comm::CombineShape {
            k: 12,
            size: 8,
            passive_size: 4,
            active_size: 4,
            remote_rows_per_step: rows,
            n_ranks: 10,
            wire_row_bytes: None,
        };
        let (mode, pred) = pol.choose_group(&tc, &shape, &binom);
        println!(
            "  {:>7.0} rows/peer -> {:<16} (W={}, predicted rho {:.2})",
            rows,
            match mode {
                CommMode::AllToAll => "all-to-all".to_string(),
                CommMode::Pipeline { g } => format!("ring g={g}"),
            },
            pred.n_steps,
            pred.rho,
        );
    }
    println!("(starved steps fall back to bulk; mid-range loads widen the group");
    println!(" to amortize the per-step floor; compute-rich loads keep g = 1)");

    println!("\n== measured overlap ratio ρ (pipeline forced) ==");
    let session = Session::new(Dataset::R500K3.generate(8000));
    for (name, ranks) in [("u5-2", 8), ("u10-2", 8), ("u12-2", 8), ("u12-1", 8)] {
        let job = CountJob::of_builtin(name)
            .expect("builtin")
            .ranks(ranks)
            .mode(ModeSelect::Pipeline)
            .build()
            .expect("valid job");
        let r = session.count(&job).expect("count");
        println!(
            "  {:7} P={ranks}: mean ρ = {:.3}  (comm exposed {:.0}% of total, setup {})",
            name,
            r.model.mean_rho(),
            100.0 * r.model.comm_ratio(),
            if r.setup_reused { "reused" } else { "built" }
        );
    }

    println!("\n== adaptive per-subtemplate decisions (sweep + calibration) ==");
    let job = CountJob::of_builtin("u12-2")
        .expect("builtin")
        .ranks(8)
        .mode(ModeSelect::Adaptive)
        .adaptive(true)
        .iterations(2)
        .build()
        .expect("valid job");
    let r = session.count(&job).expect("count");
    for d in &r.comm_decisions {
        println!(
            "  sub {:>2}: {:<10} g={} ({} steps)  rho pred {:.2} / meas {}",
            d.sub,
            d.mode_name(),
            d.g,
            d.n_steps,
            d.predicted_rho,
            match d.measured_rho {
                Some(m) => format!("{m:.2}"),
                None => "-".into(),
            },
        );
    }
    println!("\nhigh-intensity templates hide their transfers; small ones can't —");
    println!("which is exactly why the Adaptive mode switches them to all-to-all.");
}
