//! End-to-end driver (the EXPERIMENTS.md headline run): the full system on
//! a real small workload, proving all layers compose.
//!
//! 1. generates the Twitter analog (Table 2 scaled — DESIGN.md §1);
//! 2. counts u10-2 with the full coordinator stack via `api::Session`
//!    (Adaptive-Group pipeline + neighbor-list partitioning) vs the
//!    MPI-Fascia baseline — the paper's headline: ≥2x at u10-2, ~5x at
//!    u12-2;
//! 3. re-runs a small template through the **XLA engine**: the combine hot
//!    spot executes in the AOT-compiled JAX/Pallas artifact via PJRT, and
//!    must agree with the native engine bit-for-bit on the colorful counts;
//! 4. prints the paper-style metric block (time, comm ratio, peak memory).
//!
//!     make artifacts && cargo run --release --example e2e_twitter_analog

use harpsg::api::{CountJob, PartitionKind, Session, SessionOptions};
use harpsg::baseline::run_fascia;
use harpsg::coordinator::{EngineKind, ModeSelect};
use harpsg::graph::{degree_stats, Dataset};
use harpsg::template::builtin;

fn main() {
    let scale = 20_000; // Twitter/20000 ≈ 2.2K vertices, 100K edges
    let g = Dataset::TwitterS.generate(scale);
    let st = degree_stats(&g);
    println!("== workload: Twitter analog (scale 1/{scale}) ==");
    println!(
        "   {} vertices, {} edges, avg deg {:.1}, max deg {} (skew {:.0}x)",
        st.n_vertices, st.n_edges, st.avg_degree, st.max_degree, st.skewness
    );

    let session = Session::new(g.clone());

    // ---- headline: AdaptiveLB vs MPI-Fascia on u10-2 / u12-2 ----
    for tpl_name in ["u10-2", "u12-2"] {
        let t = builtin(tpl_name).unwrap();
        let job = CountJob::builder(t.clone())
            .ranks(16)
            .iterations(1)
            .mode(ModeSelect::AdaptiveLb)
            .build()
            .expect("valid job");
        let ours = session.count(&job).expect("count");
        let fascia = run_fascia(&t, &g, 16, scale, 42);
        println!("\n== {tpl_name} on 16 ranks ==");
        println!(
            "   AdaptiveLB : {:.4} model-s/iter, comm {:.0}%, peak {:.1} MiB/rank",
            ours.model.total,
            100.0 * ours.model.comm_ratio(),
            ours.peak_mem() as f64 / (1 << 20) as f64
        );
        println!(
            "   MPI-Fascia : {:.4} model-s/iter, comm {:.0}%, peak {:.1} MiB/rank{}",
            fascia.model.total,
            100.0 * fascia.model.comm_ratio(),
            fascia.peak_mem() as f64 / (1 << 20) as f64,
            if fascia.oom { "  [OOM at paper's 120GB/node budget]" } else { "" }
        );
        println!(
            "   speedup    : {:.2}x   peak-mem reduction: {:.2}x",
            fascia.model.total / ours.model.total,
            fascia.peak_mem() as f64 / ours.peak_mem() as f64
        );
        let agree = ours
            .colorful
            .iter()
            .zip(&fascia.colorful)
            .all(|(a, b)| (a - b).abs() <= 1e-6 * b.abs().max(1.0));
        println!("   counts agree with baseline: {agree}");
        assert!(agree, "implementations must count identically");
    }

    // ---- the three-layer path: XLA engine via PJRT artifacts ----
    println!("\n== XLA engine (AOT JAX/Pallas combine via PJRT) ==");
    let xla_session = Session::with_options(
        g,
        SessionOptions {
            seed: 42,
            partition: PartitionKind::Random,
            load_xla: true,
        },
    );
    match xla_session {
        Ok(xs) => {
            let t = builtin("u5-2").unwrap();
            let mk = |engine| {
                CountJob::builder(t.clone())
                    .ranks(4)
                    .iterations(2)
                    .engine(engine)
                    .build()
                    .expect("valid job")
            };
            let native = xs.count(&mk(EngineKind::Native)).expect("native run");
            let xla = xs.count(&mk(EngineKind::Xla)).expect("xla run");
            for (i, (n, x)) in native.colorful.iter().zip(&xla.colorful).enumerate() {
                println!("   iter {i}: native colorful {n}, xla colorful {x}");
                assert!(
                    (n - x).abs() <= 1e-4 * n.abs().max(1.0),
                    "XLA engine must match native counts"
                );
            }
            println!(
                "   u5-2 estimate (native) {:.3e} vs (xla) {:.3e} — MATCH",
                native.estimate, xla.estimate
            );
            println!(
                "   real wall-clock: native {:.2}s, xla {:.2}s (PJRT per-block dispatch)",
                native.real_seconds, xla.real_seconds
            );
        }
        Err(e) => {
            println!("   artifacts not available ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    }
    println!("\ne2e OK — all layers compose. Full numbers: EXPERIMENTS.md");
}
