//! Graphlet frequency distribution (GFD) — the §1 use case: for a family
//! of tree templates, estimate each count and report the distribution.
//! Bressan et al. (WSDM'17) use exactly this treelet kernel to push GFD to
//! larger graphs/templates.
//!
//!     cargo run --release --example graphlet_frequency -- [dataset] [scale]

use harpsg::coordinator::{DistributedRunner, ModeSelect, RunConfig};
use harpsg::graph::{degree_stats, Dataset};
use harpsg::template::{builtin, complexity};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let ds = match args.first().map(|s| s.as_str()) {
        Some("MI") => Dataset::MiamiS,
        Some("OR") => Dataset::OrkutS,
        Some("TW") => Dataset::TwitterS,
        Some("R250K8") => Dataset::R250K8,
        _ => Dataset::OrkutS,
    };
    let scale: u32 = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000);
    let g = ds.generate(scale);
    let st = degree_stats(&g);
    println!(
        "GFD on {} (1/{scale}): {} vertices, {} edges",
        ds.abbrev(),
        st.n_vertices,
        st.n_edges
    );

    let family = ["u3-1", "u5-2", "u7-2", "u10-2"];
    let mut rows = Vec::new();
    for name in family {
        let t = builtin(name).unwrap();
        let cfg = RunConfig {
            n_ranks: 8,
            n_iterations: 8,
            mode: ModeSelect::AdaptiveLb,
            ..RunConfig::default()
        };
        let r = DistributedRunner::new(&t, &g, cfg).run();
        rows.push((name, r.estimate, r.model.total));
    }
    let total: f64 = rows.iter().map(|(_, e, _)| e).sum();
    println!("\n{:>8} {:>16} {:>10} {:>12} {:>10}", "template", "estimate", "share", "model s/it", "intensity");
    for (name, est, time) in rows {
        println!(
            "{:>8} {:>16.3e} {:>9.2}% {:>12.4} {:>10.1}",
            name,
            est,
            100.0 * est / total,
            time,
            complexity(&builtin(name).unwrap()).intensity
        );
    }
}
