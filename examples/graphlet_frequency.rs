//! Graphlet frequency distribution (GFD) — the §1 use case: for a family
//! of tree templates, estimate each count and report the distribution.
//! Bressan et al. (WSDM'17) use exactly this treelet kernel to push GFD to
//! larger graphs/templates.
//!
//! This is the facade's batch showcase: `Session::count_batch` runs the
//! whole family against one shared partition/request-list build, and the
//! per-report setup accounting shows the amortization win over fresh
//! per-template setup.
//!
//!     cargo run --release --example graphlet_frequency -- [dataset] [scale]

use harpsg::api::{CountJob, JobReport, Session};
use harpsg::coordinator::ModeSelect;
use harpsg::graph::{degree_stats, Dataset};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let ds = match args.first().map(|s| s.as_str()) {
        Some("MI") => Dataset::MiamiS,
        Some("OR") => Dataset::OrkutS,
        Some("TW") => Dataset::TwitterS,
        Some("R250K8") => Dataset::R250K8,
        _ => Dataset::OrkutS,
    };
    let scale: u32 = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000);
    let g = ds.generate(scale);
    let st = degree_stats(&g);
    println!(
        "GFD on {} (1/{scale}): {} vertices, {} edges",
        ds.abbrev(),
        st.n_vertices,
        st.n_edges
    );

    let family = ["u3-1", "u5-2", "u7-2", "u10-2"];
    let session = Session::new(g);
    let jobs: Vec<_> = family
        .iter()
        .map(|name| {
            CountJob::of_builtin(name)
                .expect("builtin template")
                .ranks(8)
                .iterations(8)
                .mode(ModeSelect::AdaptiveLb)
                .build()
                .expect("valid job")
        })
        .collect();
    let reports = session.count_batch(&jobs).expect("batch");

    let total: f64 = reports.iter().map(|r| r.estimate).sum();
    println!(
        "\n{:>8} {:>16} {:>10} {:>12} {:>10} {:>10}",
        "template", "estimate", "share", "model s/it", "intensity", "setup"
    );
    for r in &reports {
        println!(
            "{:>8} {:>16.3e} {:>9.2}% {:>12.4} {:>10.1} {:>10}",
            r.template,
            r.estimate,
            100.0 * r.estimate / total,
            r.model.total,
            r.complexity.intensity,
            if r.setup_reused { "reused" } else { "built" }
        );
    }
    let built: f64 = reports
        .iter()
        .filter(|r| !r.setup_reused)
        .map(|r| r.setup_seconds)
        .sum();
    println!(
        "\nsession amortization: 1 partition/request-list build ({:.1} ms) served {} templates",
        built * 1e3,
        reports.len()
    );
    println!("\nCSV (JobReport::series_of):");
    print!("{}", JobReport::series_of(&reports).to_csv());
}
