//! Differential suite for the vectorized combine kernel
//! (`colorcount::kernel`, `--kernel`):
//!
//! 1. **kernel invariance** — estimates, colorful counts and samples are
//!    bit-identical across all three kernel modes, both exchange
//!    executors, both storage representations and rank counts {1, 2, 5,
//!    6}, against the sequential dense *scalar* baseline. DP count
//!    tables are integer-valued (every entry is an embedding count well
//!    below 2^24), so the SIMD lane-tree reassociation is exact and the
//!    contract is bit-identity, not a tolerance;
//! 2. **wide-template leg** — the same invariance on a 12-vertex
//!    template, where the aggregation width (C(12,6) = 924) gives the
//!    8-lane chunks real work, at a reduced rank matrix;
//! 3. **report contract** — `config.kernel` in the JSON report names the
//!    requested mode verbatim.
//!
//! CI's kernel-matrix feeds `HARPSG_TEST_KERNEL={scalar,simd,auto}` to
//! pin the mode set (and `HARPSG_TEST_RANKS` as everywhere else).

use harpsg::api::{CountJob, JobReport, PartitionKind, Session, SessionOptions};
use harpsg::colorcount::{KernelMode, StorageMode};
use harpsg::coordinator::{ExchangeExec, ModeSelect};
use harpsg::graph::rmat::{generate, RmatParams};

/// Kernel modes under differential test. CI's kernel-matrix sets
/// `HARPSG_TEST_KERNEL` to pin the suite to one mode; unset runs all
/// three (scalar is always re-run as the baseline regardless).
fn test_kernel_modes() -> Vec<KernelMode> {
    if let Ok(v) = std::env::var("HARPSG_TEST_KERNEL") {
        if let Some(m) = KernelMode::parse(v.trim()) {
            return vec![m];
        }
    }
    vec![KernelMode::Scalar, KernelMode::Simd, KernelMode::Auto]
}

/// Rank counts, honoring the CI matrix the same way
/// `tests/pipeline_exec.rs` does.
fn test_rank_counts() -> Vec<usize> {
    if let Ok(v) = std::env::var("HARPSG_TEST_RANKS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 1 {
                return vec![1, n];
            }
            if n == 1 {
                return vec![1];
            }
        }
    }
    vec![1, 2, 5, 6]
}

fn session(n: usize, m: u64, skew: u32, seed: u64) -> Session {
    Session::with_options(
        generate(&RmatParams::with_skew(n, m, skew, seed)),
        SessionOptions {
            seed: 7,
            partition: PartitionKind::Random,
            load_xla: false,
        },
    )
    .unwrap()
}

fn job(
    tpl: &str,
    ranks: usize,
    exec: ExchangeExec,
    storage: StorageMode,
    kernel: KernelMode,
    workers: usize,
) -> CountJob {
    CountJob::of_builtin(tpl)
        .unwrap()
        .ranks(ranks)
        .mode(ModeSelect::Pipeline)
        .exchange(exec)
        .table_storage(storage)
        .kernel(kernel)
        .iterations(1)
        .seed(7)
        .workers(workers)
        .build()
        .unwrap()
}

/// Tentpole acceptance: the full differential matrix. Every (kernel ×
/// exchange executor × storage × rank count) combination reports
/// estimates bit-identical to the sequential dense scalar baseline —
/// the kernel is an execution-strategy change, never a numerics change
/// on integer-valued tables.
#[test]
fn kernel_modes_bit_identical_to_sequential_scalar_baseline() {
    let s = session(52, 260, 3, 4242);
    let ranks = test_rank_counts();
    let kernels = test_kernel_modes();
    for tpl in ["u5-2", "u10-2"] {
        for &r in &ranks {
            let base = s
                .count(&job(
                    tpl,
                    r,
                    ExchangeExec::Sequential,
                    StorageMode::Dense,
                    KernelMode::Scalar,
                    2,
                ))
                .unwrap();
            for &kernel in &kernels {
                for exec in [ExchangeExec::Sequential, ExchangeExec::Threaded] {
                    for storage in [StorageMode::Dense, StorageMode::Sparse] {
                        let got = s.count(&job(tpl, r, exec, storage, kernel, 2)).unwrap();
                        assert_eq!(
                            base.estimate.to_bits(),
                            got.estimate.to_bits(),
                            "{tpl} P={r} {kernel:?} {exec:?} {storage:?}: {} vs scalar {}",
                            got.estimate,
                            base.estimate
                        );
                        assert_eq!(
                            base.colorful, got.colorful,
                            "{tpl} P={r} {kernel:?} {exec:?} {storage:?}"
                        );
                        assert_eq!(
                            base.samples, got.samples,
                            "{tpl} P={r} {kernel:?} {exec:?} {storage:?}"
                        );
                    }
                }
            }
        }
    }
}

/// The wide-template leg: u12-1's mid-levels carry aggregation widths in
/// the hundreds, so the SIMD path runs many full 8-lane chunks per row
/// (not just the remainder loop). Reduced matrix — threaded executor,
/// worker sweep, largest pinned rank count — to bound runtime.
#[test]
fn simd_kernel_matches_scalar_on_twelve_vertex_template() {
    let s = session(67, 360, 3, 99);
    let ranks = test_rank_counts();
    let r = *ranks.last().unwrap();
    let base = s
        .count(&job(
            "u12-1",
            r,
            ExchangeExec::Sequential,
            StorageMode::Dense,
            KernelMode::Scalar,
            1,
        ))
        .unwrap();
    for &kernel in &test_kernel_modes() {
        for workers in [1usize, 3] {
            let got = s
                .count(&job(
                    "u12-1",
                    r,
                    ExchangeExec::Threaded,
                    StorageMode::Auto,
                    kernel,
                    workers,
                ))
                .unwrap();
            assert_eq!(
                base.estimate.to_bits(),
                got.estimate.to_bits(),
                "u12-1 P={r} {kernel:?} w={workers}: {} vs scalar {}",
                got.estimate,
                base.estimate
            );
            assert_eq!(base.colorful, got.colorful, "u12-1 P={r} {kernel:?} w={workers}");
            assert_eq!(base.samples, got.samples, "u12-1 P={r} {kernel:?} w={workers}");
        }
    }
}

/// The JSON contract behind `harpsg count --json --kernel …`:
/// `config.kernel` names the requested mode verbatim (`auto` stays
/// `auto` — resolution happens per split width at run time).
#[test]
fn json_report_carries_kernel_mode() {
    let s = session(40, 200, 3, 21);
    let parse = |r: &JobReport| harpsg::util::jsonparse::parse(&r.to_json_string()).unwrap();
    for (kernel, name) in [
        (KernelMode::Scalar, "scalar"),
        (KernelMode::Simd, "simd"),
        (KernelMode::Auto, "auto"),
    ] {
        let rep = s
            .count(&job(
                "u5-2",
                2,
                ExchangeExec::Threaded,
                StorageMode::Dense,
                kernel,
                2,
            ))
            .unwrap();
        assert_eq!(rep.kernel, name);
        let parsed = parse(&rep);
        assert_eq!(
            parsed.get("config").unwrap().get("kernel").unwrap().as_str(),
            Some(name),
            "JSON config.kernel for {kernel:?}"
        );
    }
}
