//! Differential suite for frontier pruning (`--prune`):
//!
//! 1. **prune invariance** — estimates, colorful counts and samples are
//!    bit-identical across prune modes {off, on, auto}, both exchange
//!    executors, both storage representations and rank counts {1, 2, 5,
//!    6}, against the sequential dense *unpruned* baseline. Pruning only
//!    elides exact `+0.0` accumulations and products with an exact `0.0`
//!    factor, so the contract is bit-identity, not a tolerance;
//! 2. **wide-template leg** — u12-1 on a graph with isolated-edge
//!    components: a 2-vertex component cannot host any rooted colorful
//!    embedding of active size ≥ 3, so its rows are deterministically
//!    dead and `pairs_skipped` must be strictly positive at P=6;
//! 3. **socket-fabric leg** — the same invariance with every rank behind
//!    its own `SocketFabric` endpoint on a localhost TCP mesh (mirroring
//!    `tests/fabric.rs`), plus the allreduced per-subtemplate
//!    `PruneStats` replicated identically on every rank;
//! 4. **report contract** — `config.prune` in the JSON report names the
//!    requested mode verbatim and the top-level `prune[]` array carries
//!    the per-subtemplate occupancy/skip schema.
//!
//! The row-level membership property (frontier membership ⇔ row nnz > 0)
//! is covered where the bitmap lives, by
//! `colorcount::frontier::tests::prop_membership_equals_row_nnz`.
//!
//! CI's prune-matrix pins `HARPSG_TEST_RANKS` as everywhere else;
//! `HARPSG_TEST_PRUNE=1` widens the template set to the full builtin
//! zoo this suite supports.

use harpsg::api::{CountJob, JobReport, PartitionKind, Session, SessionOptions};
use harpsg::colorcount::{median_of_means, EngineContext, PruneMode, StorageMode};
use harpsg::comm::{config_digest, PeerAddr, SocketFabric, SocketListener, SocketOptions};
use harpsg::coordinator::{
    DistributedRunner, ExchangeExec, FabricKind, ModeSelect, RunConfig, RunResult,
};
use harpsg::graph::{graph_from_edges, Graph};
use harpsg::template::builtin;
use std::time::Duration;

/// Templates under differential test. `HARPSG_TEST_PRUNE=1` (the CI
/// prune-matrix full leg) runs the zoo; the default set keeps local
/// `cargo test` bounded while still covering a narrow and a wide shape.
fn test_templates() -> Vec<&'static str> {
    if std::env::var("HARPSG_TEST_PRUNE").as_deref() == Ok("1") {
        return vec!["u3-1", "u5-2", "u7-2", "u10-2", "u12-1"];
    }
    vec!["u5-2", "u10-2"]
}

/// Rank counts, honoring the CI matrix the same way `tests/kernel.rs`
/// does.
fn test_rank_counts() -> Vec<usize> {
    if let Ok(v) = std::env::var("HARPSG_TEST_RANKS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 1 {
                return vec![1, n];
            }
            if n == 1 {
                return vec![1];
            }
        }
    }
    vec![1, 2, 5, 6]
}

const PRUNE_MODES: [PruneMode; 3] = [PruneMode::Off, PruneMode::On, PruneMode::Auto];

/// A graph engineered so pruning has something deterministic to skip:
/// a connected blob on vertices 0..32 (large enough to host every
/// builtin template this suite runs), four isolated-edge components
/// (32-33 … 38-39) whose rows are dead for any active size ≥ 3, and
/// four isolated vertices (40..43) that keep every non-trivial frontier
/// occupancy strictly below 1.0.
fn prune_graph() -> Graph {
    let mut edges: Vec<(u32, u32)> = vec![(32, 33), (34, 35), (36, 37), (38, 39)];
    for v in 0..32u32 {
        for u in (v + 1)..32 {
            if (v + u) % 3 == 1 {
                edges.push((v, u));
            }
        }
    }
    graph_from_edges(44, &edges)
}

fn session() -> Session {
    Session::with_options(
        prune_graph(),
        SessionOptions {
            seed: 7,
            partition: PartitionKind::Random,
            load_xla: false,
        },
    )
    .unwrap()
}

fn job(
    tpl: &str,
    ranks: usize,
    exec: ExchangeExec,
    storage: StorageMode,
    prune: PruneMode,
    workers: usize,
) -> CountJob {
    CountJob::of_builtin(tpl)
        .unwrap()
        .ranks(ranks)
        .mode(ModeSelect::Pipeline)
        .exchange(exec)
        .table_storage(storage)
        .prune(prune)
        .iterations(1)
        .seed(7)
        .workers(workers)
        .build()
        .unwrap()
}

/// Stats sanity shared by every leg: occupancies are fractions, and a
/// run with pruning resolved *off* must tally zero skipped work.
fn check_stats(rep: &JobReport, label: &str) {
    for s in &rep.prune {
        assert!(
            (0.0..=1.0).contains(&s.frontier_occupancy),
            "{label} sub {}: occupancy {} outside [0,1]",
            s.sub,
            s.frontier_occupancy
        );
    }
    if rep.prune_mode == "off" {
        for s in &rep.prune {
            assert_eq!(
                (s.pairs_skipped, s.rows_skipped, s.wire_rows_dropped),
                (0, 0, 0),
                "{label} sub {}: pruning off must skip nothing",
                s.sub
            );
        }
    }
}

/// Tentpole acceptance: the full differential matrix. Every (prune mode
/// × exchange executor × storage × rank count) combination reports
/// estimates bit-identical to the sequential dense unpruned baseline —
/// pruning is an execution-strategy change, never a numerics change.
#[test]
fn prune_modes_bit_identical_to_unpruned_baseline() {
    let s = session();
    let ranks = test_rank_counts();
    for tpl in test_templates() {
        for &r in &ranks {
            let base = s
                .count(&job(
                    tpl,
                    r,
                    ExchangeExec::Sequential,
                    StorageMode::Dense,
                    PruneMode::Off,
                    2,
                ))
                .unwrap();
            check_stats(&base, &format!("{tpl} P={r} baseline"));
            for prune in PRUNE_MODES {
                for exec in [ExchangeExec::Sequential, ExchangeExec::Threaded] {
                    for storage in [StorageMode::Dense, StorageMode::Sparse] {
                        let got = s.count(&job(tpl, r, exec, storage, prune, 2)).unwrap();
                        let label = format!("{tpl} P={r} {prune:?} {exec:?} {storage:?}");
                        assert_eq!(
                            base.estimate.to_bits(),
                            got.estimate.to_bits(),
                            "{label}: {} vs unpruned {}",
                            got.estimate,
                            base.estimate
                        );
                        assert_eq!(base.colorful, got.colorful, "{label}");
                        assert_eq!(base.samples, got.samples, "{label}");
                        check_stats(&got, &label);
                    }
                }
            }
        }
    }
}

/// The wide-template leg at the acceptance point: u12-1's root split is
/// 6/6, so subtemplates with active size ≥ 3 exist and the isolated-edge
/// rows of `prune_graph` are provably dead in their tables — pruning
/// must skip pairs on every coloring, and the isolated vertices must
/// show up as sub-unit frontier occupancy.
#[test]
fn pruned_u12_skips_pairs_and_stays_exact() {
    let s = session();
    let r = *test_rank_counts().last().unwrap();
    let base = s
        .count(&job(
            "u12-1",
            r,
            ExchangeExec::Sequential,
            StorageMode::Dense,
            PruneMode::Off,
            1,
        ))
        .unwrap();
    for workers in [1usize, 3] {
        let got = s
            .count(&job(
                "u12-1",
                r,
                ExchangeExec::Threaded,
                StorageMode::Auto,
                PruneMode::On,
                workers,
            ))
            .unwrap();
        let label = format!("u12-1 P={r} pruned w={workers}");
        assert_eq!(
            base.estimate.to_bits(),
            got.estimate.to_bits(),
            "{label}: {} vs unpruned {}",
            got.estimate,
            base.estimate
        );
        assert_eq!(base.colorful, got.colorful, "{label}");
        check_stats(&got, &label);
        let pairs: u64 = got.prune.iter().map(|s| s.pairs_skipped).sum();
        assert!(pairs > 0, "{label}: dead isolated-edge rows must skip pairs");
        assert!(
            got.prune.iter().any(|s| s.frontier_occupancy < 1.0),
            "{label}: isolated vertices must dent some frontier"
        );
    }
}

// ---------------------------------------------------------------------
// socket-fabric leg (mirrors tests/fabric.rs)
// ---------------------------------------------------------------------

fn socket_rank_counts() -> Vec<usize> {
    if let Ok(v) = std::env::var("HARPSG_TEST_RANKS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 2 {
                return vec![2, n];
            }
            return vec![2];
        }
    }
    vec![2, 5]
}

fn socket_opts() -> SocketOptions {
    SocketOptions {
        connect_timeout: Duration::from_secs(30),
        connect_backoff: Duration::from_millis(5),
        recv_timeout: Duration::from_secs(120),
    }
}

fn base_cfg(ranks: usize, prune: PruneMode) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.n_ranks = ranks;
    cfg.n_workers = 2;
    cfg.n_iterations = 2;
    cfg.seed = 7;
    cfg.mode = ModeSelect::Pipeline;
    cfg.exchange = ExchangeExec::Threaded;
    cfg.prune = prune;
    cfg
}

/// Run `cfg` with every rank behind its own `SocketFabric` endpoint on a
/// localhost TCP mesh, one OS thread per rank (the transport is byte-
/// for-byte the one real processes use; only the address exchange is
/// in-memory).
fn socket_run(tpl: &str, g: &Graph, cfg: &RunConfig) -> Vec<RunResult> {
    let n = cfg.n_ranks;
    let listeners: Vec<SocketListener> = (0..n)
        .map(|_| SocketListener::bind(&PeerAddr::Tcp("127.0.0.1:0".into())).unwrap())
        .collect();
    let addrs: Vec<PeerAddr> = listeners.iter().map(|l| l.local_addr().clone()).collect();
    let digest = config_digest(&format!("prune-test {tpl} P={n} seed={}", cfg.seed));
    let mut out: Vec<Option<RunResult>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (r, l) in listeners.into_iter().enumerate() {
            let addrs = addrs.clone();
            let cfg = cfg.clone();
            handles.push(s.spawn(move || {
                let t = builtin(tpl).unwrap();
                let fabric =
                    SocketFabric::establish(r, l, &addrs, digest, n.max(1), socket_opts())
                        .unwrap();
                let mut runner = DistributedRunner::new(&t, g, cfg);
                let res = runner.run_on(&fabric, &[r]).unwrap();
                fabric.finish();
                (r, res)
            }));
        }
        for h in handles {
            let (r, res) = h.join().unwrap();
            out[r] = Some(res);
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Merge per-rank partials exactly like `procmode::merge` / the
/// launcher (see `tests/fabric.rs`).
fn merge_counts(tpl: &str, per_rank: &[RunResult]) -> (Vec<f64>, f64) {
    let t = builtin(tpl).unwrap();
    let ctx = EngineContext::new(&t);
    let iters = per_rank[0].colorful.len();
    let mut colorful = Vec::with_capacity(iters);
    let mut samples = Vec::with_capacity(iters);
    for it in 0..iters {
        let mut total = 0.0f64;
        for r in per_rank {
            assert_eq!(r.colorful.len(), iters, "{tpl}: ragged iteration counts");
            total += r.colorful[it];
        }
        colorful.push(total);
        samples.push(total * ctx.colorful_scale() / ctx.aut as f64);
    }
    let estimate = median_of_means(&samples, 3.min(samples.len()));
    (colorful, estimate)
}

/// Pruned runs over the socket mesh are bit-identical to the unpruned
/// threaded reference, and the allreduced `PruneStats` — occupancies
/// and skip tallies are global sums, not rank-local views — replicate
/// identically on every rank.
#[test]
fn pruned_socket_counts_match_unpruned_threaded_bitwise() {
    let g = prune_graph();
    for tpl in ["u5-2", "u12-1"] {
        for ranks in socket_rank_counts() {
            let t = builtin(tpl).unwrap();
            let unpruned =
                DistributedRunner::new(&t, &g, base_cfg(ranks, PruneMode::Off)).run();
            let pruned_ref =
                DistributedRunner::new(&t, &g, base_cfg(ranks, PruneMode::On)).run();
            let label = format!("{tpl} P={ranks} pruned/socket");
            assert_eq!(
                unpruned.estimate.to_bits(),
                pruned_ref.estimate.to_bits(),
                "{label}: threaded pruned diverged from unpruned"
            );

            let mut cfg = base_cfg(ranks, PruneMode::On);
            cfg.fabric = FabricKind::Socket;
            let per_rank = socket_run(tpl, &g, &cfg);
            let (colorful, estimate) = merge_counts(tpl, &per_rank);
            for (it, (&m, &r)) in colorful.iter().zip(&pruned_ref.colorful).enumerate() {
                assert_eq!(
                    m.to_bits(),
                    r.to_bits(),
                    "{label} it={it}: socket colorful {m} vs threaded {r}"
                );
            }
            assert_eq!(
                estimate.to_bits(),
                pruned_ref.estimate.to_bits(),
                "{label}: socket estimate {estimate} vs threaded {}",
                pruned_ref.estimate
            );
            for (r, res) in per_rank.iter().enumerate() {
                assert_eq!(
                    res.prune, pruned_ref.prune,
                    "{label}: rank {r} prune stats diverged from the threaded run"
                );
            }
            if tpl == "u12-1" {
                let pairs: u64 = pruned_ref.prune.iter().map(|s| s.pairs_skipped).sum();
                assert!(pairs > 0, "{label}: u12-1 must skip isolated-edge pairs");
            }
        }
    }
}

/// The JSON contract behind `harpsg count --json --prune …`:
/// `config.prune` names the requested mode verbatim (`auto` stays
/// `auto` — resolution happens per table at run time) and the top-level
/// `prune[]` array carries the per-subtemplate schema.
#[test]
fn json_report_carries_prune_mode_and_stats() {
    let s = session();
    let parse = |r: &JobReport| harpsg::util::jsonparse::parse(&r.to_json_string()).unwrap();
    for (mode, name) in [
        (PruneMode::On, "on"),
        (PruneMode::Off, "off"),
        (PruneMode::Auto, "auto"),
    ] {
        let rep = s
            .count(&job(
                "u5-2",
                2,
                ExchangeExec::Threaded,
                StorageMode::Dense,
                mode,
                2,
            ))
            .unwrap();
        assert_eq!(rep.prune_mode, name);
        let parsed = parse(&rep);
        assert_eq!(
            parsed.get("config").unwrap().get("prune").unwrap().as_str(),
            Some(name),
            "JSON config.prune for {mode:?}"
        );
        let arr = parsed.get("prune").unwrap().as_arr().unwrap();
        assert!(!arr.is_empty(), "prune[] must list every subtemplate");
        for entry in arr {
            for key in [
                "sub",
                "frontier_occupancy",
                "pairs_skipped",
                "rows_skipped",
                "wire_rows_dropped",
            ] {
                assert!(
                    entry.get(key).is_some(),
                    "prune[] entry missing `{key}` for {mode:?}"
                );
            }
        }
    }
}
