//! Cross-module integration tests: the invariants a downstream user
//! relies on, exercised over the real stack (graph gen → partition →
//! distributed DP → estimate; plus the AOT/PJRT path when artifacts are
//! built). All distributed runs go through the `harpsg::api` facade —
//! `Session` + `CountJob` + `JobReport` — which is exactly how the CLI
//! and the figure harness drive the system.

use harpsg::api::{CountJob, HarpsgError, PartitionKind, Progress, Session, SessionOptions};
use harpsg::colorcount::{count_embeddings, Engine};
use harpsg::coordinator::{EngineKind, ModeSelect};
use harpsg::graph::rmat::{generate, RmatParams};
use harpsg::graph::{Dataset, Graph};
use harpsg::template::{builtin, BUILTIN_NAMES};
use harpsg::util::prop;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn session_with_seed(g: Graph, seed: u64) -> Session {
    Session::with_options(
        g,
        SessionOptions {
            seed,
            partition: PartitionKind::Random,
            load_xla: false,
        },
    )
    .expect("session")
}

/// The core invariant, at integration scale: any (mode, ranks, template)
/// combination produces the same colorful counts as the single-rank
/// engine on the same iteration seeds.
#[test]
fn distributed_count_invariance_matrix() {
    let g = generate(&RmatParams::with_skew(300, 2_500, 3, 99));
    let session = session_with_seed(g.clone(), 5);
    for tpl in ["u3-1", "u5-2", "u7-2", "u10-2"] {
        let t = builtin(tpl).unwrap();
        let engine = Engine::new(&t);
        let reference: Vec<f64> = (0..2)
            .map(|it| engine.run_iteration(&g, harpsg::util::mix2(5, it)).colorful)
            .collect();
        for mode in [ModeSelect::Naive, ModeSelect::Pipeline, ModeSelect::AdaptiveLb] {
            for ranks in [2, 7] {
                let job = CountJob::builder(t.clone())
                    .ranks(ranks)
                    .mode(mode)
                    .iterations(2)
                    .seed(5)
                    .build()
                    .unwrap();
                let r = session.count(&job).unwrap();
                for (it, (a, b)) in r.colorful.iter().zip(&reference).enumerate() {
                    let rel = (a - b).abs() / b.abs().max(1.0);
                    assert!(
                        rel < 1e-3,
                        "{tpl} {mode:?} P={ranks} iter{it}: {a} vs {b}"
                    );
                }
            }
        }
    }
    // the whole matrix used exactly one plan per rank count
    assert_eq!(session.cached_plans(), 2);
}

/// Property-style sweep: random graph/template/mode/rank combinations
/// keep the invariant.
#[test]
fn prop_distributed_invariance() {
    prop::check("dist_invariance", |gen| {
        let n = gen.usize_in(20, 150);
        let m = gen.usize_in(n, 6 * n) as u64;
        let skew = gen.usize_in(1, 8) as u32;
        let g = generate(&RmatParams::with_skew(n, m, skew, gen.case_seed));
        let tpl = *gen.pick(&["u3-1", "u5-2", "u7-2"]);
        let ranks = gen.usize_in(1, 6);
        let mode = *gen.pick(&[
            ModeSelect::Naive,
            ModeSelect::Pipeline,
            ModeSelect::Adaptive,
            ModeSelect::AdaptiveLb,
        ]);
        let t = builtin(tpl).unwrap();
        let seed = gen.case_seed ^ 0xABCD;
        let single = Engine::new(&t)
            .run_iteration(&g, harpsg::util::mix2(seed, 0))
            .colorful;
        let session = session_with_seed(g, seed);
        let mut builder = CountJob::builder(t)
            .ranks(ranks)
            .mode(mode)
            .iterations(1)
            .seed(seed)
            .threads(gen.usize_in(1, 48));
        if mode == ModeSelect::AdaptiveLb {
            builder = builder.task_size(gen.usize_in(1, 100) as u32);
        }
        let job = builder.build().map_err(|e| e.to_string())?;
        let r = session.count(&job).map_err(|e| e.to_string())?;
        let rel = (r.colorful[0] - single).abs() / single.abs().max(1.0);
        if rel < 1e-3 {
            Ok(())
        } else {
            Err(format!(
                "{tpl} {mode:?} P={ranks}: {} vs {single}",
                r.colorful[0]
            ))
        }
    });
}

/// End-to-end estimator accuracy against the exact count.
#[test]
fn estimator_converges_distributed() {
    let g = generate(&RmatParams::with_skew(48, 220, 1, 3));
    let t = builtin("u5-2").unwrap();
    let truth = count_embeddings(&t, &g);
    assert!(truth > 0.0);
    let session = session_with_seed(g, 11);
    let job = CountJob::builder(t)
        .ranks(4)
        .iterations(800)
        .seed(11)
        .build()
        .unwrap();
    let r = session.count(&job).unwrap();
    let rel = (r.estimate - truth).abs() / truth;
    assert!(rel < 0.2, "estimate {} vs exact {truth} (rel {rel})", r.estimate);
}

/// All ten builtin templates run through the full stack without panicking
/// and yield finite estimates (tiny workload) — one session, one shared
/// exchange plan.
#[test]
fn all_templates_run_end_to_end() {
    let g = generate(&RmatParams::with_skew(64, 600, 3, 21));
    let session = Session::new(g);
    for tpl in BUILTIN_NAMES {
        let job = CountJob::of_builtin(tpl)
            .unwrap()
            .ranks(3)
            .iterations(1)
            .build()
            .unwrap();
        let r = session.count(&job).unwrap();
        assert!(r.estimate.is_finite(), "{tpl}");
        assert!(r.model.total > 0.0, "{tpl}");
        assert!(r.peak_mem() > 0, "{tpl}");
        assert!(!r.comm_decisions.is_empty(), "{tpl}");
    }
    assert_eq!(session.cached_plans(), 1);
}

/// THE acceptance check for the session facade: a multi-template batch
/// reuses one partition + request-list build and still produces
/// bit-identical estimates to fresh per-template sessions.
#[test]
fn session_batch_reuses_setup_bit_identically() {
    let g = generate(&RmatParams::with_skew(200, 1_600, 3, 77));
    let names = ["u3-1", "u5-2", "u7-2", "u10-2"];
    let mk_job = |name: &str| {
        CountJob::of_builtin(name)
            .unwrap()
            .ranks(4)
            .iterations(2)
            .seed(9)
            .build()
            .unwrap()
    };

    let batch_session = session_with_seed(g.clone(), 9);
    let jobs: Vec<_> = names.iter().map(|n| mk_job(n)).collect();
    let batch = batch_session.count_batch(&jobs).unwrap();

    // one plan served all four templates…
    assert_eq!(batch_session.cached_plans(), 1);
    assert!(Arc::ptr_eq(
        &batch_session.plan(4),
        &batch_session.plan(4)
    ));
    // …and every job after the first says so
    assert!(!batch[0].setup_reused);
    assert!(batch[1..].iter().all(|r| r.setup_reused));

    // bit-identical to per-template sessions with the same options
    for (name, batched) in names.iter().zip(&batch) {
        let solo_session = session_with_seed(g.clone(), 9);
        let solo = solo_session.count(&mk_job(name)).unwrap();
        assert_eq!(
            solo.estimate.to_bits(),
            batched.estimate.to_bits(),
            "{name}: batch and solo estimates must be bit-identical"
        );
        assert_eq!(solo.colorful, batched.colorful, "{name}");
        assert_eq!(solo.samples, batched.samples, "{name}");
        assert_eq!(solo.peak_mem_per_rank, batched.peak_mem_per_rank, "{name}");
    }
}

/// Counting observer: every callback fires, with internally consistent
/// totals (ring of 5 ranks with g=1 → 4 exchange steps per combine).
#[test]
fn progress_observer_streams_events() {
    #[derive(Default)]
    struct Counter {
        run_starts: AtomicUsize,
        iterations: AtomicUsize,
        sub_starts: AtomicUsize,
        sub_dones: AtomicUsize,
        steps: AtomicUsize,
        run_ends: AtomicUsize,
    }
    impl Progress for Counter {
        fn on_run_start(&self, n_iterations: usize, n_subtemplates: usize) {
            assert_eq!(n_iterations, 2);
            assert!(n_subtemplates > 0);
            self.run_starts.fetch_add(1, Ordering::SeqCst);
        }
        fn on_iteration(&self, _it: usize, n: usize) {
            assert_eq!(n, 2);
            self.iterations.fetch_add(1, Ordering::SeqCst);
        }
        fn on_subtemplate_start(&self, _sub: usize, n_steps: usize, pipelined: bool) {
            assert!(pipelined);
            assert_eq!(n_steps, 4);
            self.sub_starts.fetch_add(1, Ordering::SeqCst);
        }
        fn on_exchange_step(&self, _sub: usize, step: usize, n_steps: usize) {
            assert!(step < n_steps);
            self.steps.fetch_add(1, Ordering::SeqCst);
        }
        fn on_subtemplate_done(&self, _sub: usize) {
            self.sub_dones.fetch_add(1, Ordering::SeqCst);
        }
        fn on_run_end(&self) {
            self.run_ends.fetch_add(1, Ordering::SeqCst);
        }
    }

    let g = generate(&RmatParams::with_skew(80, 500, 3, 13));
    let session = Session::new(g);
    let job = CountJob::of_builtin("u5-2")
        .unwrap()
        .ranks(5)
        .mode(ModeSelect::Pipeline)
        .iterations(2)
        .build()
        .unwrap();
    let counter = Arc::new(Counter::default());
    let report = session.count_with_progress(&job, counter.clone()).unwrap();

    assert_eq!(counter.run_starts.load(Ordering::SeqCst), 1);
    assert_eq!(counter.run_ends.load(Ordering::SeqCst), 1);
    assert_eq!(counter.iterations.load(Ordering::SeqCst), 2);
    let subs = counter.sub_starts.load(Ordering::SeqCst);
    assert!(subs > 0);
    assert_eq!(counter.sub_dones.load(Ordering::SeqCst), subs);
    // every combine runs its full 4-step ring
    assert_eq!(counter.steps.load(Ordering::SeqCst), subs * 4);
    // the report agrees with what the observer saw
    assert_eq!(report.n_iterations, 2);
    assert!(report.comm_decisions.iter().all(|d| d.n_steps == 4));
}

/// `JobReport::to_json_string` must round-trip through the crate's own
/// JSON parser with the headline fields intact — this is the contract
/// behind `harpsg count --json`.
#[test]
fn json_report_roundtrips() {
    let g = generate(&RmatParams::with_skew(90, 700, 3, 17));
    let session = Session::new(g);
    let job = CountJob::of_builtin("u7-2")
        .unwrap()
        .ranks(4)
        .iterations(2)
        .build()
        .unwrap();
    let report = session.count(&job).unwrap();
    let parsed = harpsg::util::jsonparse::parse(&report.to_json_string()).unwrap();

    let tpl = parsed.get("template").unwrap();
    assert_eq!(tpl.get("name").unwrap().as_str(), Some("u7-2"));
    assert_eq!(tpl.get("k").unwrap().as_usize(), Some(7));
    let cfg = parsed.get("config").unwrap();
    assert_eq!(cfg.get("ranks").unwrap().as_usize(), Some(4));
    assert_eq!(cfg.get("mode").unwrap().as_str(), Some("AdaptiveLB"));
    let est = parsed.get("estimate").unwrap().as_f64().unwrap();
    assert!((est - report.estimate).abs() <= 1e-9 * report.estimate.abs().max(1.0));
    assert_eq!(parsed.get("colorful").unwrap().as_arr().unwrap().len(), 2);
    let mem = parsed.get("memory").unwrap();
    assert_eq!(
        mem.get("peak_per_rank").unwrap().as_arr().unwrap().len(),
        4
    );
    assert!(!parsed.get("comm").unwrap().as_arr().unwrap().is_empty());
}

/// Jobs that select the XLA engine on a session without the runtime are
/// rejected with the typed error, not a panic at run time.
#[test]
fn xla_without_runtime_is_a_typed_error() {
    let g = generate(&RmatParams::with_skew(40, 160, 1, 23));
    let session = Session::new(g);
    let job = CountJob::of_builtin("u3-1")
        .unwrap()
        .ranks(2)
        .engine(EngineKind::Xla)
        .build()
        .unwrap();
    assert!(matches!(
        session.count(&job),
        Err(HarpsgError::EngineUnavailable(_))
    ));
}

/// The XLA engine (PJRT artifacts) produces identical counts to the
/// native engine through the full distributed stack.
#[test]
fn xla_engine_matches_native_end_to_end() {
    let xla_session = Session::with_options(
        Dataset::MiamiS.generate(4000),
        SessionOptions {
            seed: 42,
            partition: PartitionKind::Random,
            load_xla: true,
        },
    );
    let Ok(session) = xla_session else {
        eprintln!("skipping: run `make artifacts` first (or build with --features pjrt)");
        return;
    };
    for tpl in ["u3-1", "u5-2", "u7-2"] {
        let mk = |engine| {
            CountJob::of_builtin(tpl)
                .unwrap()
                .ranks(3)
                .iterations(2)
                .engine(engine)
                .build()
                .unwrap()
        };
        let native = session.count(&mk(EngineKind::Native)).unwrap();
        let xla = session.count(&mk(EngineKind::Xla)).unwrap();
        for (a, b) in native.colorful.iter().zip(&xla.colorful) {
            let rel = (a - b).abs() / b.abs().max(1.0);
            assert!(rel < 1e-4, "{tpl}: native {a} vs xla {b}");
        }
    }
}

/// Peak memory: the pipelined exchange must beat the bulk exchange on
/// every large template (Fig 12's invariant).
#[test]
fn pipeline_memory_dominance() {
    let g = generate(&RmatParams::with_skew(400, 8_000, 3, 31));
    let session = Session::new(g);
    for tpl in ["u10-2", "u12-1", "u12-2"] {
        let run = |mode| {
            let job = CountJob::of_builtin(tpl)
                .unwrap()
                .ranks(8)
                .mode(mode)
                .iterations(1)
                .build()
                .unwrap();
            session.count(&job).unwrap().peak_mem()
        };
        let naive = run(ModeSelect::Naive);
        let pipe = run(ModeSelect::Pipeline);
        assert!(
            (pipe as f64) < naive as f64 * 0.95,
            "{tpl}: pipeline {pipe} !< naive {naive}"
        );
    }
}

/// Estimates must be deterministic given a seed (full stack, across
/// separately-opened sessions).
#[test]
fn runs_are_reproducible() {
    let g = generate(&RmatParams::with_skew(128, 900, 3, 8));
    let mk_job = || {
        CountJob::of_builtin("u7-2")
            .unwrap()
            .ranks(5)
            .iterations(3)
            .seed(77)
            .build()
            .unwrap()
    };
    let a = session_with_seed(g.clone(), 77).count(&mk_job()).unwrap();
    let b = session_with_seed(g, 77).count(&mk_job()).unwrap();
    assert_eq!(a.colorful, b.colorful);
    assert_eq!(a.estimate, b.estimate);
    assert_eq!(a.peak_mem_per_rank, b.peak_mem_per_rank);
}
