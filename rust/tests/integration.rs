//! Cross-module integration tests: the invariants a downstream user
//! relies on, exercised over the real stack (graph gen → partition →
//! distributed DP → estimate; plus the AOT/PJRT path when artifacts are
//! built).

use harpsg::colorcount::{count_embeddings, Engine};
use harpsg::coordinator::{DistributedRunner, EngineKind, ModeSelect, RunConfig};
use harpsg::graph::rmat::{generate, RmatParams};
use harpsg::graph::Dataset;
use harpsg::runtime::{XlaCombine, XlaRuntime};
use harpsg::template::{builtin, BUILTIN_NAMES};
use harpsg::util::prop;

/// The core invariant, at integration scale: any (mode, ranks, template)
/// combination produces the same colorful counts as the single-rank
/// engine on the same iteration seeds.
#[test]
fn distributed_count_invariance_matrix() {
    let g = generate(&RmatParams::with_skew(300, 2_500, 3, 99));
    for tpl in ["u3-1", "u5-2", "u7-2", "u10-2"] {
        let t = builtin(tpl).unwrap();
        let engine = Engine::new(&t);
        let reference: Vec<f64> = (0..2)
            .map(|it| engine.run_iteration(&g, harpsg::util::mix2(5, it)).colorful)
            .collect();
        for mode in [ModeSelect::Naive, ModeSelect::Pipeline, ModeSelect::AdaptiveLb] {
            for ranks in [2, 7] {
                let cfg = RunConfig {
                    n_ranks: ranks,
                    mode,
                    n_iterations: 2,
                    seed: 5,
                    ..RunConfig::default()
                };
                let r = DistributedRunner::new(&t, &g, cfg).run();
                for (it, (a, b)) in r.colorful.iter().zip(&reference).enumerate() {
                    let rel = (a - b).abs() / b.abs().max(1.0);
                    assert!(
                        rel < 1e-3,
                        "{tpl} {mode:?} P={ranks} iter{it}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

/// Property-style sweep: random graph/template/mode/rank combinations
/// keep the invariant.
#[test]
fn prop_distributed_invariance() {
    prop::check("dist_invariance", |gen| {
        let n = gen.usize_in(20, 150);
        let m = gen.usize_in(n, 6 * n) as u64;
        let skew = gen.usize_in(1, 8) as u32;
        let g = generate(&RmatParams::with_skew(n, m, skew, gen.case_seed));
        let tpl = *gen.pick(&["u3-1", "u5-2", "u7-2"]);
        let ranks = gen.usize_in(1, 6);
        let mode = *gen.pick(&[
            ModeSelect::Naive,
            ModeSelect::Pipeline,
            ModeSelect::Adaptive,
            ModeSelect::AdaptiveLb,
        ]);
        let t = builtin(tpl).unwrap();
        let seed = gen.case_seed ^ 0xABCD;
        let single = Engine::new(&t)
            .run_iteration(&g, harpsg::util::mix2(seed, 0))
            .colorful;
        let cfg = RunConfig {
            n_ranks: ranks,
            mode,
            n_iterations: 1,
            seed,
            task_size: gen.usize_in(1, 100) as u32,
            n_threads: gen.usize_in(1, 48),
            ..RunConfig::default()
        };
        let r = DistributedRunner::new(&t, &g, cfg).run();
        let rel = (r.colorful[0] - single).abs() / single.abs().max(1.0);
        if rel < 1e-3 {
            Ok(())
        } else {
            Err(format!(
                "{tpl} {mode:?} P={ranks}: {} vs {single}",
                r.colorful[0]
            ))
        }
    });
}

/// End-to-end estimator accuracy against the exact count.
#[test]
fn estimator_converges_distributed() {
    let g = generate(&RmatParams::with_skew(48, 220, 1, 3));
    let t = builtin("u5-2").unwrap();
    let truth = count_embeddings(&t, &g);
    assert!(truth > 0.0);
    let cfg = RunConfig {
        n_ranks: 4,
        n_iterations: 800,
        seed: 11,
        ..RunConfig::default()
    };
    let r = DistributedRunner::new(&t, &g, cfg).run();
    let rel = (r.estimate - truth).abs() / truth;
    assert!(rel < 0.2, "estimate {} vs exact {truth} (rel {rel})", r.estimate);
}

/// All ten builtin templates run through the full stack without panicking
/// and yield finite estimates (tiny workload).
#[test]
fn all_templates_run_end_to_end() {
    let g = generate(&RmatParams::with_skew(64, 600, 3, 21));
    for tpl in BUILTIN_NAMES {
        let t = builtin(tpl).unwrap();
        let cfg = RunConfig {
            n_ranks: 3,
            n_iterations: 1,
            ..RunConfig::default()
        };
        let r = DistributedRunner::new(&t, &g, cfg).run();
        assert!(r.estimate.is_finite(), "{tpl}");
        assert!(r.model.total > 0.0, "{tpl}");
        assert!(r.peak_mem() > 0, "{tpl}");
    }
}

/// The XLA engine (PJRT artifacts) produces identical counts to the
/// native engine through the full distributed stack.
#[test]
fn xla_engine_matches_native_end_to_end() {
    let Ok(rt) = XlaRuntime::load_default() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = std::sync::Arc::new(rt);
    let g = Dataset::MiamiS.generate(4000);
    for tpl in ["u3-1", "u5-2", "u7-2"] {
        let t = builtin(tpl).unwrap();
        let mk = |engine| RunConfig {
            n_ranks: 3,
            n_iterations: 2,
            engine,
            ..RunConfig::default()
        };
        let native = DistributedRunner::new(&t, &g, mk(EngineKind::Native)).run();
        let mut xrun = DistributedRunner::new(&t, &g, mk(EngineKind::Xla));
        xrun.xla = Some(XlaCombine::new(rt.clone()));
        let xla = xrun.run();
        for (a, b) in native.colorful.iter().zip(&xla.colorful) {
            let rel = (a - b).abs() / b.abs().max(1.0);
            assert!(rel < 1e-4, "{tpl}: native {a} vs xla {b}");
        }
    }
}

/// Peak memory: the pipelined exchange must beat the bulk exchange on
/// every large template (Fig 12's invariant).
#[test]
fn pipeline_memory_dominance() {
    let g = generate(&RmatParams::with_skew(400, 8_000, 3, 31));
    for tpl in ["u10-2", "u12-1", "u12-2"] {
        let t = builtin(tpl).unwrap();
        let run = |mode| {
            let cfg = RunConfig {
                n_ranks: 8,
                mode,
                n_iterations: 1,
                ..RunConfig::default()
            };
            DistributedRunner::new(&t, &g, cfg).run().peak_mem()
        };
        let naive = run(ModeSelect::Naive);
        let pipe = run(ModeSelect::Pipeline);
        assert!(
            (pipe as f64) < naive as f64 * 0.95,
            "{tpl}: pipeline {pipe} !< naive {naive}"
        );
    }
}

/// Estimates must be deterministic given a seed (full stack).
#[test]
fn runs_are_reproducible() {
    let g = generate(&RmatParams::with_skew(128, 900, 3, 8));
    let t = builtin("u7-2").unwrap();
    let mk = || RunConfig {
        n_ranks: 5,
        n_iterations: 3,
        seed: 77,
        ..RunConfig::default()
    };
    let a = DistributedRunner::new(&t, &g, mk()).run();
    let b = DistributedRunner::new(&t, &g, mk()).run();
    assert_eq!(a.colorful, b.colorful);
    assert_eq!(a.estimate, b.estimate);
    assert_eq!(a.peak_mem_per_rank, b.peak_mem_per_rank);
}
