//! Differential + acceptance suite for the adaptive dense/sparse
//! count-table storage (`colorcount::storage`, `--table-storage`):
//!
//! 1. **representation invariance** — estimates, colorful counts and
//!    samples are bit-identical across all three storage modes, both
//!    exchange executors and rank counts {1, 2, 5, 6}, against the
//!    sequential dense baseline;
//! 2. **memory acceptance** — on a 12-vertex template at P = 6 the
//!    `Auto` policy's accounted peak lands strictly below the dense
//!    baseline, with the delta reported in the JSON `memory` section;
//! 3. **wire contract** — sparse-aware exchange never ships more bytes
//!    than the dense encoding under `Auto`, and the JSON report carries
//!    the per-subtemplate `storage` section (density / storage /
//!    bytes_saved).
//!
//! CI's storage-matrix feeds `HARPSG_TEST_STORAGE={dense,sparse,auto}`
//! to pin the mode set (and `HARPSG_TEST_RANKS` as everywhere else).

use harpsg::api::{CountJob, JobReport, PartitionKind, Session, SessionOptions};
use harpsg::colorcount::StorageMode;
use harpsg::coordinator::{ExchangeExec, ModeSelect};
use harpsg::graph::rmat::{generate, RmatParams};

/// Storage modes under differential test. CI's storage-matrix sets
/// `HARPSG_TEST_STORAGE` to pin the suite to one mode; unset runs all
/// three (dense is always re-run as the baseline regardless).
fn test_storage_modes() -> Vec<StorageMode> {
    if let Ok(v) = std::env::var("HARPSG_TEST_STORAGE") {
        if let Some(m) = StorageMode::parse(v.trim()) {
            return vec![m];
        }
    }
    vec![StorageMode::Dense, StorageMode::Sparse, StorageMode::Auto]
}

/// Rank counts, honoring the CI matrix the same way
/// `tests/pipeline_exec.rs` does.
fn test_rank_counts() -> Vec<usize> {
    if let Ok(v) = std::env::var("HARPSG_TEST_RANKS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 1 {
                return vec![1, n];
            }
            if n == 1 {
                return vec![1];
            }
        }
    }
    vec![1, 2, 5, 6]
}

fn session(n: usize, m: u64, skew: u32, seed: u64) -> Session {
    Session::with_options(
        generate(&RmatParams::with_skew(n, m, skew, seed)),
        SessionOptions {
            seed: 7,
            partition: PartitionKind::Random,
            load_xla: false,
        },
    )
    .unwrap()
}

fn job(
    tpl: &str,
    ranks: usize,
    mode: ModeSelect,
    exec: ExchangeExec,
    storage: StorageMode,
) -> CountJob {
    CountJob::of_builtin(tpl)
        .unwrap()
        .ranks(ranks)
        .mode(mode)
        .exchange(exec)
        .table_storage(storage)
        .iterations(1)
        .seed(7)
        .workers(2)
        .build()
        .unwrap()
}

/// Satellite: the storage differential leg. Every (storage mode ×
/// exchange executor × rank count × comm mode) combination reports
/// estimates bit-identical to the sequential dense baseline — storage is
/// a representation change, never a numerics change.
#[test]
fn storage_modes_bit_identical_to_sequential_dense_baseline() {
    let s = session(52, 260, 3, 4242);
    let ranks = test_rank_counts();
    let storages = test_storage_modes();
    for tpl in ["u5-2", "u10-2"] {
        for comm in [ModeSelect::Naive, ModeSelect::Pipeline] {
            for &r in &ranks {
                let base = s
                    .count(&job(tpl, r, comm, ExchangeExec::Sequential, StorageMode::Dense))
                    .unwrap();
                assert_eq!(base.peak_mem(), base.peak_mem_dense());
                for &storage in &storages {
                    for exec in [ExchangeExec::Sequential, ExchangeExec::Threaded] {
                        let got = s.count(&job(tpl, r, comm, exec, storage)).unwrap();
                        assert_eq!(
                            base.estimate.to_bits(),
                            got.estimate.to_bits(),
                            "{tpl} {comm:?} P={r} {storage:?} {exec:?}: {} vs dense {}",
                            got.estimate,
                            base.estimate
                        );
                        assert_eq!(
                            base.colorful, got.colorful,
                            "{tpl} {comm:?} P={r} {storage:?} {exec:?}"
                        );
                        assert_eq!(
                            base.samples, got.samples,
                            "{tpl} {comm:?} P={r} {storage:?} {exec:?}"
                        );
                        // the dense-baseline ledger is storage-invariant:
                        // every mode reproduces the dense run's real peaks
                        assert_eq!(
                            got.peak_mem_dense_per_rank, base.peak_mem_per_rank,
                            "{tpl} {comm:?} P={r} {storage:?} {exec:?}: baseline ledger"
                        );
                    }
                }
            }
        }
    }
}

/// Acceptance: on a 12-vertex template at P = 6 the Auto policy's
/// accounted peak is strictly below the dense baseline, the delta is
/// reported (in the result and in JSON), and the one-hot leaves show up
/// as sparse with their measured 1/k density.
#[test]
fn auto_storage_reduces_peak_on_twelve_vertex_template() {
    let s = session(72, 400, 3, 99);
    let run = |storage| {
        s.count(&job(
            "u12-1",
            6,
            ModeSelect::Pipeline,
            ExchangeExec::Threaded,
            storage,
        ))
        .unwrap()
    };
    let dense = run(StorageMode::Dense);
    let auto = run(StorageMode::Auto);
    assert_eq!(auto.estimate.to_bits(), dense.estimate.to_bits());
    assert!(
        auto.peak_mem() < dense.peak_mem(),
        "auto peak {} must be strictly below dense {}",
        auto.peak_mem(),
        dense.peak_mem()
    );
    assert_eq!(auto.peak_mem_dense(), dense.peak_mem());
    assert_eq!(
        auto.peak_bytes_saved(),
        dense.peak_mem() - auto.peak_mem(),
        "the reported delta is exactly the baseline gap"
    );
    assert_eq!(dense.peak_bytes_saved(), 0);
    // the density probe drove real decisions: a fully-sparse sub with
    // leaf density 1/12 and genuine savings
    let leaf = auto
        .storage
        .iter()
        .find(|d| d.storage_name() == "sparse" && (d.density - 1.0 / 12.0).abs() < 1e-9)
        .expect("one-hot leaves stored sparse under auto");
    assert!(leaf.bytes_saved() > 0);
    assert!(leaf.resident_bytes < leaf.dense_bytes);
    // dense runs report every table dense with nothing saved
    assert!(dense
        .storage
        .iter()
        .all(|d| d.storage_name() == "dense" && d.bytes_saved() == 0));
}

/// Under `Auto`, the sparse-aware exchange never ships a step that
/// out-weighs the dense encoding: per rank, the largest step's received
/// bytes and the streaming recv peak are bounded by the dense run's.
#[test]
fn auto_exchange_never_exceeds_dense_wire_bytes() {
    let s = session(80, 420, 3, 55);
    let run = |storage| {
        s.count(&job(
            "u10-2",
            6,
            ModeSelect::Pipeline,
            ExchangeExec::Threaded,
            storage,
        ))
        .unwrap()
    };
    let dense = run(StorageMode::Dense);
    let auto = run(StorageMode::Auto);
    let d = dense.measured.as_ref().expect("threaded run measures");
    let a = auto.measured.as_ref().expect("threaded run measures");
    for p in 0..6 {
        assert!(
            a.max_step_recv_bytes_per_rank[p] <= d.max_step_recv_bytes_per_rank[p],
            "rank {p}: auto step bytes {} exceed dense {}",
            a.max_step_recv_bytes_per_rank[p],
            d.max_step_recv_bytes_per_rank[p]
        );
        assert!(a.recv_peak_per_rank[p] <= d.recv_peak_per_rank[p], "rank {p}");
        assert!(a.recv_peak_per_rank[p] > 0, "rank {p} received nothing");
    }
    assert!(auto.peak_mem() <= dense.peak_mem());
}

/// The JSON contract behind `harpsg count --json --table-storage …`:
/// `config.table_storage` names the mode, the `storage` array carries
/// per-sub density/storage/bytes_saved, and the `memory` section reports
/// the dense baseline and the saved delta.
#[test]
fn json_report_carries_storage_section() {
    let s = session(60, 320, 3, 21);
    let parse = |r: &JobReport| harpsg::util::jsonparse::parse(&r.to_json_string()).unwrap();

    let auto = s
        .count(&job(
            "u10-2",
            5,
            ModeSelect::Pipeline,
            ExchangeExec::Threaded,
            StorageMode::Auto,
        ))
        .unwrap();
    let parsed = parse(&auto);
    assert_eq!(
        parsed
            .get("config")
            .unwrap()
            .get("table_storage")
            .unwrap()
            .as_str(),
        Some("auto")
    );
    let storage = parsed.get("storage").unwrap().as_arr().unwrap();
    assert!(!storage.is_empty());
    let mut saw_sparse = false;
    for d in storage {
        let density = d.get("density").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&density));
        let name = d.get("storage").unwrap().as_str().unwrap();
        assert!(["dense", "sparse", "mixed"].contains(&name));
        let dense_b = d.get("dense_bytes").unwrap().as_f64().unwrap();
        let resident = d.get("resident_bytes").unwrap().as_f64().unwrap();
        let saved = d.get("bytes_saved").unwrap().as_f64().unwrap();
        assert!(
            ((dense_b - resident).max(0.0) - saved).abs() < 1e-9,
            "bytes_saved must equal max(dense - resident, 0)"
        );
        if name == "sparse" {
            saw_sparse = true;
            assert!(resident < dense_b);
        }
    }
    assert!(saw_sparse, "auto on u10-2 must store something sparse");
    let mem = parsed.get("memory").unwrap();
    let peak = mem.get("peak").unwrap().as_f64().unwrap();
    let baseline = mem.get("peak_dense_baseline").unwrap().as_f64().unwrap();
    let saved = mem.get("bytes_saved").unwrap().as_f64().unwrap();
    assert!(baseline >= peak);
    assert!((baseline - peak - saved).abs() < 1e-9);

    // dense runs: baseline == peak, nothing saved
    let dense = s
        .count(&job(
            "u10-2",
            5,
            ModeSelect::Pipeline,
            ExchangeExec::Threaded,
            StorageMode::Dense,
        ))
        .unwrap();
    let parsed = parse(&dense);
    assert_eq!(
        parsed
            .get("config")
            .unwrap()
            .get("table_storage")
            .unwrap()
            .as_str(),
        Some("dense")
    );
    let mem = parsed.get("memory").unwrap();
    assert_eq!(mem.get("bytes_saved").unwrap().as_f64(), Some(0.0));
    assert_eq!(
        mem.get("peak").unwrap().as_f64(),
        mem.get("peak_dense_baseline").unwrap().as_f64()
    );
}
