//! Differential + acceptance suite for partition-sharded graph storage
//! (`graph::shard`, `--graph-storage`):
//!
//! 1. **backend invariance** — estimates, colorful counts and samples are
//!    bit-identical between the resident CSR and the segment-file backend
//!    across builtin templates, both exchange executors and rank counts
//!    {1, 2, 5, 6} (the partition, and hence the plan, is identical by
//!    construction — only where adjacency is read from changes);
//! 2. **corrupt-segment matrix** — every byte-level corruption of the
//!    shard header or a segment file fails with its typed
//!    `GraphLoadError`, in the PR 4 fixture style;
//! 3. **out-of-core acceptance** — a synthetic R-MAT ≥ 4× larger than the
//!    configured resident-adjacency budget auto-resolves to `mmap`,
//!    counts bit-identically to the resident baseline, and every rank's
//!    graph ledger entry stays within 1.5× of its partition-proportional
//!    share; the JSON report carries `config.graph_storage` and
//!    `memory.graph_resident_per_rank`.
//!
//! CI's shard-matrix sets `HARPSG_TEST_SHARD=1` to run the full builtin
//! template sweep (and `HARPSG_TEST_RANKS` as everywhere else); unset,
//! a trimmed template subset keeps the default run fast.

use harpsg::api::{CountJob, JobReport, PartitionKind, Session, SessionOptions};
use harpsg::coordinator::{ExchangeExec, ModeSelect};
use harpsg::graph::rmat::{generate, RmatParams};
use harpsg::graph::shard::{segment_file_name, shard_to_scratch, SHARD_HEADER_FILE};
use harpsg::graph::{
    graph_from_edges, Graph, GraphLoadError, GraphStorageMode, GraphStore, Partition,
    SegmentedGraph,
};
use harpsg::template::BUILTIN_NAMES;

/// Templates under differential test: the full builtin set when CI's
/// shard-matrix exports `HARPSG_TEST_SHARD=1`, a trimmed subset (leaf,
/// small tree, medium, 12-vertex) otherwise.
fn test_templates() -> Vec<&'static str> {
    if std::env::var("HARPSG_TEST_SHARD").as_deref() == Ok("1") {
        return BUILTIN_NAMES.to_vec();
    }
    vec!["u3-1", "u5-2", "u10-2", "u12-2"]
}

/// Rank counts, honoring the CI matrix the same way the other
/// differential suites do.
fn test_rank_counts() -> Vec<usize> {
    if let Ok(v) = std::env::var("HARPSG_TEST_RANKS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 1 {
                return vec![1, n];
            }
            if n == 1 {
                return vec![1];
            }
        }
    }
    vec![1, 2, 5, 6]
}

fn session(n: usize, m: u64, skew: u32, seed: u64) -> Session {
    Session::with_options(
        generate(&RmatParams::with_skew(n, m, skew, seed)),
        SessionOptions {
            seed: 7,
            partition: PartitionKind::Random,
            load_xla: false,
        },
    )
    .unwrap()
}

fn job(tpl: &str, ranks: usize, exec: ExchangeExec, storage: GraphStorageMode) -> CountJob {
    CountJob::of_builtin(tpl)
        .unwrap()
        .ranks(ranks)
        .mode(ModeSelect::Pipeline)
        .exchange(exec)
        .graph_storage(storage)
        .iterations(1)
        .seed(7)
        .workers(2)
        .build()
        .unwrap()
}

/// Tentpole differential leg: for every template × executor × rank count,
/// the segment-file backend reproduces the resident run bit for bit —
/// sharding changes where adjacency is read from, never what is counted.
#[test]
fn mmap_storage_bit_identical_to_resident_baseline() {
    let s = session(52, 260, 3, 4242);
    let ranks = test_rank_counts();
    for tpl in test_templates() {
        for &r in &ranks {
            let base = s
                .count(&job(tpl, r, ExchangeExec::Sequential, GraphStorageMode::Resident))
                .unwrap();
            assert_eq!(base.graph_storage, "resident");
            for exec in [ExchangeExec::Sequential, ExchangeExec::Threaded] {
                let got = s.count(&job(tpl, r, exec, GraphStorageMode::Mmap)).unwrap();
                assert_eq!(got.graph_storage, "mmap", "{tpl} P={r} {exec:?}");
                assert_eq!(
                    base.estimate.to_bits(),
                    got.estimate.to_bits(),
                    "{tpl} P={r} {exec:?}: {} vs resident {}",
                    got.estimate,
                    base.estimate
                );
                assert_eq!(base.colorful, got.colorful, "{tpl} P={r} {exec:?}");
                assert_eq!(base.samples, got.samples, "{tpl} P={r} {exec:?}");
            }
        }
    }
}

/// Satellite regression: more ranks than vertices. The balanced
/// `Partition::block` fix means surplus ranks are exactly the empty
/// ones; sharding such a partition writes genuinely empty segments
/// (header + `offsets = [0]`, no adjacency), and the segment-backed
/// exchange plan is structurally identical to the resident one.
#[test]
fn more_ranks_than_vertices_shards_and_plans() {
    let g = graph_from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
    let part = Partition::block(4, 6);
    for p in 0..4 {
        assert_eq!(part.locals[p], vec![p as u32]);
    }
    for p in 4..6 {
        assert!(part.locals[p].is_empty());
    }
    let seg = shard_to_scratch(&g, &part).unwrap();
    for p in 4..6 {
        let c = seg.load_rank(p, &part.locals[p]).unwrap();
        assert_eq!(c.offsets, vec![0]);
        assert!(c.adj.is_empty());
    }
    let resident = harpsg::coordinator::ExchangePlan::build(&g, part.clone());
    let sharded = harpsg::coordinator::ExchangePlan::from_segments(&seg, part).unwrap();
    assert_eq!(resident.part.owner, sharded.part.owner);
    assert_eq!(resident.req.needs, sharded.req.needs);
    assert_eq!(resident.mean_remote_rows(), sharded.mean_remote_rows());
    assert_eq!(resident.graph_storage, "resident");
    assert_eq!(sharded.graph_storage, "mmap");
    // empty ranks keep nothing resident beyond their (empty) offsets row
    for p in 4..6 {
        assert_eq!(sharded.graph_bytes_per_rank[p], 8);
    }
}

/// Fixture graph for the corruption matrix (same shape as the PR 4
/// loader fixtures): adj rows v0:[1,4] v1:[0,2] v2:[1] v3:[4] v4:[0,3].
fn fixture() -> (Graph, Partition) {
    let g = graph_from_edges(5, &[(0, 1), (1, 2), (3, 4), (0, 4)]);
    let part = Partition::block(5, 1);
    (g, part)
}

fn fixture_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join("harpsg_shard_tests")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn mutate(path: &std::path::Path, at: usize, bytes: &[u8]) {
    let mut buf = std::fs::read(path).unwrap();
    buf[at..at + bytes.len()].copy_from_slice(bytes);
    std::fs::write(path, &buf).unwrap();
}

/// Satellite: the corrupt-segment matrix. Every structural invariant of
/// the shard header fails `SegmentedGraph::open` with its typed
/// diagnosis, never a panic.
#[test]
fn corrupt_shard_header_is_typed() {
    let (g, part) = fixture();
    let dir = fixture_dir("hdr");
    let seg = part.shard_storage(&g, &dir).unwrap();
    drop(seg);
    let hp = dir.join(SHARD_HEADER_FILE);
    let good = std::fs::read(&hp).unwrap();
    // layout: magic 8 | n 8 | n_edges 8 | n_ranks 8 | tag 8 | per-rank 16

    // a missing header is an I/O error carrying NotFound, not a panic
    let empty = fixture_dir("hdr-missing");
    match SegmentedGraph::open(&empty) {
        Err(GraphLoadError::Io { kind, .. }) => {
            assert_eq!(kind, std::io::ErrorKind::NotFound)
        }
        other => panic!("want Io(NotFound), got {other:?}"),
    }

    mutate(&hp, 0, b"NOTSHARD");
    assert!(matches!(
        SegmentedGraph::open(&dir),
        Err(GraphLoadError::BadMagic)
    ));
    std::fs::write(&hp, &good).unwrap();

    // truncated header: the per-rank table is cut short
    std::fs::write(&hp, &good[..good.len() - 8]).unwrap();
    assert!(matches!(
        SegmentedGraph::open(&dir),
        Err(GraphLoadError::Truncated { .. })
    ));
    std::fs::write(&hp, &good).unwrap();

    // an absurd rank count would imply a header longer than the file
    mutate(&hp, 24, &u64::MAX.to_le_bytes());
    assert!(matches!(
        SegmentedGraph::open(&dir),
        Err(GraphLoadError::SizeOverflow)
    ));
    std::fs::write(&hp, &good).unwrap();

    // segments must cover exactly the declared vertex count
    mutate(&hp, 40, &99u64.to_le_bytes());
    assert!(matches!(
        SegmentedGraph::open(&dir),
        Err(GraphLoadError::SegmentMismatch { .. })
    ));
    std::fs::write(&hp, &good).unwrap();

    // header edge count must match the adjacency total (2 per edge)
    mutate(&hp, 16, &99u64.to_le_bytes());
    assert!(matches!(
        SegmentedGraph::open(&dir),
        Err(GraphLoadError::EdgeCountMismatch { .. })
    ));
    std::fs::write(&hp, &good).unwrap();

    // the untouched baseline still opens
    assert!(SegmentedGraph::open(&dir).is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: the corrupt-segment matrix, segment-file half. Loading a
/// rank's slice re-runs every `load_binary` invariant segment-aware.
#[test]
fn corrupt_segment_file_is_typed() {
    let (g, part) = fixture();
    let dir = fixture_dir("seg");
    let seg = part.shard_storage(&g, &dir).unwrap();
    let sp = dir.join(segment_file_name(0));
    let good = std::fs::read(&sp).unwrap();
    // layout: magic 8 | rank 8 | n_local 8 | adj_len 8 |
    //         offsets 6·8 (48) | adj 8·4 (32) — adj starts at byte 80
    let load = |seg: &SegmentedGraph| seg.load_rank(0, &part.locals[0]);
    assert!(load(&seg).is_ok());

    mutate(&sp, 0, b"NOTASEGM");
    assert!(matches!(load(&seg), Err(GraphLoadError::BadMagic)));
    std::fs::write(&sp, &good).unwrap();

    // the segment's own header must agree with the shard header
    mutate(&sp, 8, &7u64.to_le_bytes());
    assert!(matches!(
        load(&seg),
        Err(GraphLoadError::SegmentMismatch { rank: 0, .. })
    ));
    std::fs::write(&sp, &good).unwrap();

    // truncated payload: the last adjacency entry is missing
    std::fs::write(&sp, &good[..good.len() - 4]).unwrap();
    match load(&seg) {
        Err(GraphLoadError::Truncated { expected, actual }) => {
            assert_eq!(expected as usize, good.len());
            assert_eq!(actual as usize, good.len() - 4);
        }
        other => panic!("want Truncated, got {other:?}"),
    }
    std::fs::write(&sp, &good).unwrap();

    // local offsets must start at 0…
    mutate(&sp, 32, &1u64.to_le_bytes());
    assert!(matches!(
        load(&seg),
        Err(GraphLoadError::NonMonotoneOffsets { index: 0 })
    ));
    std::fs::write(&sp, &good).unwrap();

    // …and end exactly at the declared adjacency length
    mutate(&sp, 32 + 5 * 8, &9u64.to_le_bytes());
    assert!(matches!(
        load(&seg),
        Err(GraphLoadError::SegmentMismatch { rank: 0, .. })
    ));
    std::fs::write(&sp, &good).unwrap();

    // adjacency entries must name real vertices
    mutate(&sp, 80, &99u32.to_le_bytes());
    match load(&seg) {
        Err(GraphLoadError::AdjOutOfRange {
            index,
            value,
            n_vertices,
        }) => {
            assert_eq!((index, value, n_vertices), (0, 99, 5));
        }
        other => panic!("want AdjOutOfRange, got {other:?}"),
    }
    std::fs::write(&sp, &good).unwrap();

    // self-loops, duplicates and unsorted rows are diagnosed against the
    // *global* ids the rows store (adj = [1,4, 0,2, 1, 4, 0,3])
    mutate(&sp, 80, &0u32.to_le_bytes());
    assert!(matches!(
        load(&seg),
        Err(GraphLoadError::SelfLoop { vertex: 0 })
    ));
    std::fs::write(&sp, &good).unwrap();

    mutate(&sp, 84, &1u32.to_le_bytes());
    assert!(matches!(
        load(&seg),
        Err(GraphLoadError::DuplicateNeighbor {
            vertex: 0,
            value: 1
        })
    ));
    std::fs::write(&sp, &good).unwrap();

    mutate(&sp, 88, &3u32.to_le_bytes());
    assert!(matches!(
        load(&seg),
        Err(GraphLoadError::UnsortedNeighbors { vertex: 1 })
    ));
    std::fs::write(&sp, &good).unwrap();

    // a deleted segment file surfaces as Io(NotFound) at load time
    std::fs::remove_file(&sp).unwrap();
    match load(&seg) {
        Err(GraphLoadError::Io { kind, detail }) => {
            assert_eq!(kind, std::io::ErrorKind::NotFound);
            assert!(detail.contains("seg_0.bin"));
        }
        other => panic!("want Io(NotFound), got {other:?}"),
    }
    std::fs::write(&sp, &good).unwrap();
    assert!(load(&seg).is_ok());
    drop(seg);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Scratch shards clean up after themselves: the directory written by
/// `shard_to_scratch` is gone once the `SegmentedGraph` drops.
#[test]
fn scratch_shards_are_removed_on_drop() {
    let g = generate(&RmatParams::with_skew(60, 200, 3, 9));
    let part = Partition::random(g.n_vertices(), 3, 7);
    let seg = shard_to_scratch(&g, &part).unwrap();
    let dir = seg.dir().to_path_buf();
    assert!(dir.join(SHARD_HEADER_FILE).exists());
    assert!(dir.join(segment_file_name(2)).exists());
    drop(seg);
    assert!(!dir.exists(), "scratch dir {} must be removed", dir.display());
}

/// Acceptance (the low-memory CI leg greps for `out_of_core`): a
/// synthetic R-MAT ≥ 4× the configured resident-adjacency budget
/// auto-resolves to `mmap`, counts bit-identically to the resident
/// baseline, and each rank's graph ledger entry stays within 1.5× of its
/// partition-proportional share of the CSR.
#[test]
fn out_of_core_counts_under_budget_bit_identical() {
    let n = 4096usize;
    let s = session(n, 16_384, 3, 77);
    let graph_bytes = s.graph().bytes();
    // the budget admits at most a quarter of the CSR: the graph is ≥ 4×
    // larger than what `auto` lets a rank keep resident
    let budget = graph_bytes / 4;
    assert!(graph_bytes >= 4 * budget);

    let ranks = 6usize;
    let mk = |storage: GraphStorageMode| {
        let mut b = CountJob::of_builtin("u5-2")
            .unwrap()
            .ranks(ranks)
            .mode(ModeSelect::Pipeline)
            .exchange(ExchangeExec::Threaded)
            .graph_storage(storage)
            .iterations(1)
            .seed(7)
            .workers(2);
        if storage == GraphStorageMode::Auto {
            b = b.graph_budget(budget);
        }
        b.build().unwrap()
    };
    let base = s.count(&mk(GraphStorageMode::Resident)).unwrap();
    let auto = s.count(&mk(GraphStorageMode::Auto)).unwrap();

    // auto resolved out-of-core, and nothing about the counts moved
    assert_eq!(base.graph_storage, "resident");
    assert_eq!(auto.graph_storage, "mmap");
    assert_eq!(base.estimate.to_bits(), auto.estimate.to_bits());
    assert_eq!(base.colorful, auto.colorful);
    assert_eq!(base.samples, auto.samples);

    // ledger: every rank's graph entry is within 1.5× of its
    // partition-proportional share (12 B/vertex bookkeeping + its slice
    // of the CSR), so no rank ever holds anything close to the full graph
    let plan = s.plan(ranks);
    assert_eq!(auto.graph_resident_per_rank.len(), ranks);
    for p in 0..ranks {
        let n_local = plan.part.n_local(p) as u64;
        let ideal = 12 * n_local + (graph_bytes * n_local).div_ceil(n as u64);
        let got = auto.graph_resident_per_rank[p];
        assert!(
            (got as f64) <= 1.5 * ideal as f64 + 64.0,
            "rank {p}: ledger {got} vs proportional bound {ideal}"
        );
        assert!(got < graph_bytes, "rank {p} holds the whole CSR");
        assert!(got > 0, "rank {p} charged nothing");
    }
    // the resident baseline charges the historical even share
    for p in 0..ranks {
        let want = (plan.part.n_local(p) * 12) as u64 + graph_bytes / ranks as u64;
        assert_eq!(base.graph_resident_per_rank[p], want);
    }

    // JSON contract: config.graph_storage + memory.graph_resident_per_rank
    let parse = |r: &JobReport| harpsg::util::jsonparse::parse(&r.to_json_string()).unwrap();
    let parsed = parse(&auto);
    assert_eq!(
        parsed
            .get("config")
            .unwrap()
            .get("graph_storage")
            .unwrap()
            .as_str(),
        Some("mmap")
    );
    let per_rank = parsed
        .get("memory")
        .unwrap()
        .get("graph_resident_per_rank")
        .unwrap()
        .as_arr()
        .unwrap();
    assert_eq!(per_rank.len(), ranks);
    for (p, v) in per_rank.iter().enumerate() {
        assert_eq!(
            v.as_f64().unwrap(),
            auto.graph_resident_per_rank[p] as f64,
            "rank {p}"
        );
    }
    assert_eq!(
        parse(&base)
            .get("config")
            .unwrap()
            .get("graph_storage")
            .unwrap()
            .as_str(),
        Some("resident")
    );
}

/// The `GraphStore` seam both backends implement: identical topology,
/// different residency accounting.
#[test]
fn graph_store_backends_agree_on_topology() {
    let g = generate(&RmatParams::with_skew(100, 300, 3, 13));
    let part = Partition::random(g.n_vertices(), 4, 7);
    let seg = shard_to_scratch(&g, &part).unwrap();
    assert_eq!(GraphStore::n_vertices(&g), GraphStore::n_vertices(&seg));
    assert_eq!(GraphStore::n_edges(&g), GraphStore::n_edges(&seg));
    assert_eq!(GraphStore::storage_name(&g), "resident");
    assert_eq!(GraphStore::storage_name(&seg), "mmap");
    for p in 0..4 {
        let rv = GraphStore::rank_view(&g, &part, p).unwrap();
        let sv = GraphStore::rank_view(&seg, &part, p).unwrap();
        for r in 0..part.n_local(p) {
            assert_eq!(rv.neighbors(r), sv.neighbors(r), "rank {p} row {r}");
        }
    }
}
