//! Differential + acceptance suite for model-driven Adaptive-Group
//! selection: whatever exchange shape the sweep (or a forced group size)
//! picks, the counting math must not move by a bit — and at a realistic
//! calibration the sweep must genuinely choose `g > 1` and run it on the
//! rank-parallel executor.
//!
//! 1. **builtin × ranks × executors × (mode, g) matrix** — estimates,
//!    colorful counts and samples are bit-identical to the sequential
//!    all-to-all baseline for every builtin template, rank counts
//!    {2, 5, 6}, both executors, the adaptive sweep on and off, and every
//!    feasible forced ring group size (plus the g = P-1 bulk limit);
//! 2. **acceptance** — at P = 6 the sweep selects `g > 1` for some
//!    subtemplate of u12-1, the threaded executor runs that schedule,
//!    estimates match the sequential all-to-all baseline bit-for-bit, and
//!    the report carries per-subtemplate predicted vs measured ρ;
//! 3. **calibration feedback** — multi-iteration adaptive runs recalibrate
//!    between iterations without disturbing the counts.

use harpsg::api::{CountJob, PartitionKind, Session, SessionOptions};
use harpsg::combin::Binomial;
use harpsg::comm::{AdaptivePolicy, CombineShape, CommMode};
use harpsg::coordinator::{ExchangeExec, ModeSelect};
use harpsg::graph::rmat::{generate, RmatParams};
use harpsg::template::{builtin, complexity, partition_template, BUILTIN_NAMES};
use harpsg::util::Json;

/// Rank counts under test, honoring the CI thread matrix the same way
/// `tests/pipeline_exec.rs` does: `HARPSG_TEST_RANKS=N` pins to {2, N},
/// the default is {2, 5, 6} (2 = no feasible ring, 5/6 = odd/even rings
/// with a two-wide feasible band).
fn test_rank_counts() -> Vec<usize> {
    if let Ok(v) = std::env::var("HARPSG_TEST_RANKS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 2 {
                return vec![2, n];
            }
            return vec![2];
        }
    }
    vec![2, 5, 6]
}

fn test_workers() -> usize {
    std::env::var("HARPSG_TEST_WORKERS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(2)
}

/// The CI adaptive leg: `HARPSG_TEST_ADAPTIVE=1` pins the matrix to the
/// sweep-enabled configurations only (the release leg runs them with real
/// timing skew), `=0` to the static ones; unset runs both.
fn adaptive_legs() -> Vec<bool> {
    match std::env::var("HARPSG_TEST_ADAPTIVE").ok().as_deref() {
        Some("1") => vec![true],
        Some("0") => vec![false],
        _ => vec![false, true],
    }
}

fn session(n: usize, m: u64, skew: u32, seed: u64) -> Session {
    Session::with_options(
        generate(&RmatParams::with_skew(n, m, skew, seed)),
        SessionOptions {
            seed: 7,
            partition: PartitionKind::Random,
            load_xla: false,
        },
    )
    .unwrap()
}

fn base_job(tpl: &str, ranks: usize) -> CountJob {
    CountJob::of_builtin(tpl)
        .unwrap()
        .ranks(ranks)
        .mode(ModeSelect::Naive)
        .exchange(ExchangeExec::Sequential)
        .iterations(1)
        .seed(7)
        .workers(test_workers())
        .build()
        .unwrap()
}

/// Satellite: the (mode, g) differential matrix. The exchange shape is a
/// performance decision, never a correctness one: every adaptive-sweep
/// and forced-group configuration reproduces the sequential all-to-all
/// baseline bit-for-bit on both executors.
#[test]
fn every_shape_choice_is_bit_identical() {
    let light = session(44, 170, 3, 2036);
    let heavy = session(16, 48, 2, 2037);
    let ranks = test_rank_counts();
    let workers = test_workers();
    let execs = [ExchangeExec::Sequential, ExchangeExec::Threaded];
    for tpl in BUILTIN_NAMES {
        let k = builtin(tpl).unwrap().size();
        // the k ≥ 13 templates dominate the runtime: smaller graph, and
        // only the largest configured rank count
        let (s, tpl_ranks) = if k >= 13 {
            (&heavy, vec![*ranks.iter().max().unwrap()])
        } else {
            (&light, ranks.clone())
        };
        for &r in &tpl_ranks {
            let base = s.count(&base_job(tpl, r)).unwrap();
            // the adaptive sweep, both executors, both adaptive modes
            for adaptive in adaptive_legs() {
                for exec in execs {
                    for mode in [ModeSelect::Adaptive, ModeSelect::AdaptiveLb] {
                        let mut b = CountJob::of_builtin(tpl)
                            .unwrap()
                            .ranks(r)
                            .mode(mode)
                            .adaptive(adaptive)
                            .exchange(exec)
                            .iterations(1)
                            .seed(7)
                            .workers(workers);
                        if mode == ModeSelect::AdaptiveLb {
                            b = b.task_size(50);
                        }
                        let res = s.count(&b.build().unwrap()).unwrap();
                        assert_eq!(
                            base.estimate.to_bits(),
                            res.estimate.to_bits(),
                            "{tpl} P={r} {mode:?} adaptive={adaptive} {exec:?}"
                        );
                        assert_eq!(base.colorful, res.colorful, "{tpl} P={r} {exec:?}");
                        assert_eq!(base.samples, res.samples, "{tpl} P={r} {exec:?}");
                        for d in &res.comm_decisions {
                            assert!(
                                !d.pipelined || 2 * d.g + 1 <= r,
                                "{tpl} P={r}: infeasible scheduled g={}",
                                d.g
                            );
                        }
                    }
                }
            }
            // every feasible forced ring size, plus the bulk g = P-1 limit
            let mut gs: Vec<usize> = AdaptivePolicy::feasible_groups(r).collect();
            if r >= 2 {
                gs.push(r - 1);
            }
            gs.dedup();
            for g in gs {
                for exec in execs {
                    let job = CountJob::of_builtin(tpl)
                        .unwrap()
                        .ranks(r)
                        .mode(ModeSelect::Pipeline)
                        .group_size(g)
                        .exchange(exec)
                        .iterations(1)
                        .seed(7)
                        .workers(workers)
                        .build()
                        .unwrap();
                    let res = s.count(&job).unwrap();
                    assert_eq!(
                        base.estimate.to_bits(),
                        res.estimate.to_bits(),
                        "{tpl} P={r} forced g={g} {exec:?}"
                    );
                    assert_eq!(base.colorful, res.colorful, "{tpl} P={r} g={g} {exec:?}");
                    // the forced shape really ran: every combine reports it
                    for d in &res.comm_decisions {
                        assert_eq!(d.g, g, "{tpl} P={r} {exec:?}");
                        assert_eq!(d.pipelined, g < r - 1, "{tpl} P={r} {exec:?}");
                    }
                }
            }
        }
    }
}

/// Acceptance: at P = 6 the calibrated sweep picks `g > 1` for some
/// subtemplate of a large builtin template, the rank-parallel executor
/// runs that schedule, estimates stay bit-identical to the sequential
/// all-to-all baseline, and the JSON report shows predicted vs measured ρ
/// per subtemplate.
#[test]
fn adaptive_selects_wider_group_and_stays_exact() {
    let ranks = 6usize;
    let s = session(96, 700, 3, 23);
    let tpl = builtin("u12-1").unwrap();
    let tc = complexity(&tpl);
    let dag = partition_template(&tpl);
    let binom = Binomial::new();
    let plan = s.plan(ranks);
    let rows = plan.mean_remote_rows();
    assert!(rows > 0.0, "partitioned RMAT graph must have remote edges");

    // probe the model against the session's *real* exchange plan for a
    // calibration (flop_time) whose sweep prefers g > 1 somewhere — the
    // mid-regime where one step's fold at g = 1 undershoots the per-step
    // transfer floor but a wider group crosses it
    let mut policy = AdaptivePolicy::default();
    let mut found = None;
    'search: for step in 0..200 {
        let ft = 1e-12 * 1.2f64.powi(step);
        policy.flop_time = ft;
        for sub in dag.subs.iter().filter(|s| !s.is_leaf()) {
            let shape = CombineShape {
                k: tpl.size(),
                size: sub.size,
                passive_size: sub.passive_size(&dag),
                active_size: sub.active_size(&dag),
                remote_rows_per_step: rows,
                n_ranks: ranks,
                wire_row_bytes: None,
            };
            if let (CommMode::Pipeline { g }, _) = policy.choose_group(&tc, &shape, &binom) {
                if g > 1 {
                    found = Some(ft);
                    break 'search;
                }
            }
        }
    }
    let ft = found.expect("some flop_time must prefer g > 1 at P = 6");
    policy.flop_time = ft;

    let adaptive_job = CountJob::builder(tpl.clone())
        .ranks(ranks)
        .mode(ModeSelect::Adaptive)
        .adaptive(true)
        .policy(policy)
        .exchange(ExchangeExec::Threaded)
        .iterations(1)
        .seed(7)
        .workers(test_workers())
        .build()
        .unwrap();
    let ad = s.count(&adaptive_job).unwrap();
    let base = s.count(&base_job("u12-1", ranks)).unwrap();

    // the sweep chose a wider ring for some combine, and it really ran
    let wide = ad
        .comm_decisions
        .iter()
        .find(|d| d.pipelined && d.g > 1)
        .expect("the probed calibration must select g > 1 in the run too");
    assert_eq!(wide.n_steps, (ranks - 1 + wide.g - 1) / wide.g);
    assert!(
        wide.measured_rho.is_some(),
        "threaded executor must measure the ring it ran"
    );
    assert!((0.0..=1.0).contains(&wide.predicted_rho));

    // counting is schedule-invariant: bit-identical to sequential naive
    assert_eq!(ad.colorful, base.colorful);
    assert_eq!(ad.estimate.to_bits(), base.estimate.to_bits());
    assert_eq!(ad.samples, base.samples);

    // the JSON report shows the per-subtemplate decisions
    let parsed = harpsg::util::jsonparse::parse(&ad.to_json_string()).unwrap();
    assert!(matches!(
        parsed.get("config").unwrap().get("adaptive"),
        Some(Json::Bool(true))
    ));
    let comm = parsed.get("comm").unwrap().as_arr().unwrap();
    assert!(!comm.is_empty());
    let mut saw_wide = false;
    for d in comm {
        let g = d.get("g").unwrap().as_usize().unwrap();
        let pred = d.get("rho_pred").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&pred));
        match d.get("mode").unwrap().as_str().unwrap() {
            "ring" => {
                let meas = d.get("rho_meas").unwrap().as_f64().unwrap();
                assert!((0.0..=1.0).contains(&meas));
                if g > 1 {
                    saw_wide = true;
                }
            }
            "all-to-all" => {
                assert!(matches!(d.get("rho_meas"), Some(Json::Null)));
            }
            other => panic!("unknown comm mode {other}"),
        }
    }
    assert!(saw_wide, "JSON must carry the g > 1 decision");
}

/// Calibration feedback: across iterations the measured flop time and
/// overlap reshape the decisions, but never the counts — a 4-iteration
/// adaptive run matches the static baseline bit-for-bit, and repeated
/// runs agree with each other on every count.
#[test]
fn calibration_feedback_never_moves_counts() {
    let s = session(64, 300, 3, 41);
    let mk = |adaptive: bool, exec: ExchangeExec| {
        CountJob::of_builtin("u10-2")
            .unwrap()
            .ranks(5)
            .mode(ModeSelect::Adaptive)
            .adaptive(adaptive)
            .exchange(exec)
            .iterations(4)
            .seed(7)
            .workers(test_workers())
            .build()
            .unwrap()
    };
    let reference = s.count(&mk(false, ExchangeExec::Sequential)).unwrap();
    for exec in [ExchangeExec::Sequential, ExchangeExec::Threaded] {
        for run in 0..3 {
            let r = s.count(&mk(true, exec)).unwrap();
            assert_eq!(
                reference.estimate.to_bits(),
                r.estimate.to_bits(),
                "{exec:?} run {run}"
            );
            assert_eq!(reference.colorful, r.colorful, "{exec:?} run {run}");
            assert_eq!(reference.samples, r.samples, "{exec:?} run {run}");
        }
    }
}
