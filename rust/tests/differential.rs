//! Differential suite for the real multithreaded combine executor: the
//! parallel path is proven against three independent references —
//!
//! 1. the **serial engine** (`Engine::run_iteration`), bit-for-bit at
//!    per-vertex task granularity for every builtin template;
//! 2. **itself across worker counts** (1, 2, 4, 7, plus the CI matrix
//!    value from `HARPSG_TEST_WORKERS`), bit-for-bit at every task
//!    granularity including split hubs, through both the single-rank
//!    engine and the full distributed facade;
//! 3. the **exact backtracking oracle** (`colorcount::brute`), in
//!    distribution: the parallel estimator's mean converges to the exact
//!    count on small graphs.

use harpsg::api::{CountJob, PartitionKind, Session, SessionOptions};
use harpsg::colorcount::{count_embeddings, Engine};
use harpsg::coordinator::ModeSelect;
use harpsg::graph::rmat::{generate, RmatParams};
use harpsg::template::{builtin, BUILTIN_NAMES};
use harpsg::util::{mix2, prop};

/// Worker counts under differential test. Unset, the full fixed matrix
/// {1, 2, 4, 7} runs. CI's thread-matrix job sets `HARPSG_TEST_WORKERS=N`
/// to *pin* the suite to {1, N}: each matrix leg then genuinely runs a
/// different pool shape (N=1 exercises the inline single-worker path
/// everywhere, N=4 the spawned pool) instead of repeating the default.
fn test_worker_counts() -> Vec<usize> {
    if let Ok(v) = std::env::var("HARPSG_TEST_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 1 {
                return vec![1, n];
            }
            if n == 1 {
                return vec![1];
            }
        }
    }
    vec![1, 2, 4, 7]
}

/// Satellite 1: for every builtin template on a deterministic R-MAT
/// graph, the parallel `run_iteration` is bit-identical to the serial
/// engine — colorful and estimate — for 1, 2, 4 and 7 workers (and the
/// CI matrix value), at the serial engine's per-vertex task granularity.
#[test]
fn every_builtin_parallel_matches_serial_bitwise() {
    // modest size: the k=15 templates make this the heaviest differential
    let g = generate(&RmatParams::with_skew(56, 320, 3, 2024));
    let full = test_worker_counts();
    for tpl in BUILTIN_NAMES {
        let t = builtin(tpl).unwrap();
        // the k ≥ 13 templates dominate the runtime; a trimmed matrix
        // still exercises serial-vs-parallel and 1-vs-many workers there
        // (an env-pinned set is already ≤ 2 entries — keep it as is)
        let (n_iters, workers) = if t.size() >= 13 {
            let trimmed = if full.len() > 2 { vec![1, 4] } else { full.clone() };
            (1u64, trimmed)
        } else {
            (2u64, full.clone())
        };
        let e = Engine::new(&t);
        for it in 0..n_iters {
            let seed = mix2(7, it);
            let serial = e.run_iteration(&g, seed);
            for &w in &workers {
                let (par, stats) = e.run_iteration_workers(&g, seed, w, 0);
                assert_eq!(
                    serial.colorful.to_bits(),
                    par.colorful.to_bits(),
                    "{tpl} it={it} workers={w}: colorful {} vs serial {}",
                    par.colorful,
                    serial.colorful
                );
                assert_eq!(
                    serial.estimate.to_bits(),
                    par.estimate.to_bits(),
                    "{tpl} it={it} workers={w}"
                );
                assert_eq!(stats.n_workers(), w);
            }
        }
    }
}

/// Split-hub granularities: the result legitimately differs from the
/// unchunked serial sum only in f32 rounding, but must be bit-identical
/// across every worker count (the executor's core determinism contract).
#[test]
fn split_granularities_are_worker_count_invariant() {
    // skewed graph so hubs genuinely split into many tasks
    let g = generate(&RmatParams::with_skew(120, 1400, 6, 31));
    let workers = test_worker_counts();
    for tpl in ["u5-2", "u10-2"] {
        let t = builtin(tpl).unwrap();
        let e = Engine::new(&t);
        for mts in [1u32, 3, 16] {
            let (reference, _) = e.run_iteration_workers(&g, 5, 1, mts);
            for &w in &workers {
                let (par, _) = e.run_iteration_workers(&g, 5, w, mts);
                assert_eq!(
                    reference.colorful.to_bits(),
                    par.colorful.to_bits(),
                    "{tpl} mts={mts} workers={w}"
                );
                assert_eq!(reference.estimate.to_bits(), par.estimate.to_bits());
            }
            // chunking only reorders f32 adds: the unchunked serial value
            // stays within float-rounding distance
            let serial = e.run_iteration(&g, 5);
            let rel = (reference.colorful - serial.colorful).abs()
                / serial.colorful.abs().max(1.0);
            assert!(
                rel < 1e-4,
                "{tpl} mts={mts}: chunked {} vs serial {} (rel {rel})",
                reference.colorful,
                serial.colorful
            );
        }
    }
}

/// Full-stack differential: through `Session`/`CountJob`/the distributed
/// coordinator, every communication mode reports bit-identical estimates
/// for every worker count, while the measured record reflects the pool.
#[test]
fn distributed_modes_bit_identical_across_workers() {
    let g = generate(&RmatParams::with_skew(150, 1100, 4, 99));
    let session = Session::with_options(
        g,
        SessionOptions {
            seed: 9,
            partition: PartitionKind::Random,
            load_xla: false,
        },
    )
    .unwrap();
    let workers = test_worker_counts();
    for mode in [
        ModeSelect::Naive,
        ModeSelect::Pipeline,
        ModeSelect::Adaptive,
        ModeSelect::AdaptiveLb,
    ] {
        let run = |w: usize| {
            let job = CountJob::of_builtin("u7-2")
                .unwrap()
                .ranks(4)
                .mode(mode)
                .iterations(2)
                .seed(9)
                .workers(w)
                .build()
                .unwrap();
            session.count(&job).unwrap()
        };
        let base = run(1);
        assert!(base.workers.n_pairs > 0);
        for &w in &workers {
            let r = run(w);
            assert_eq!(
                base.estimate.to_bits(),
                r.estimate.to_bits(),
                "{mode:?} workers={w}"
            );
            assert_eq!(base.colorful, r.colorful, "{mode:?} workers={w}");
            assert_eq!(base.samples, r.samples, "{mode:?} workers={w}");
            assert_eq!(r.workers.n_workers(), w);
            assert_eq!(r.n_workers, w);
            // the Alg-4 queue itself is schedule-independent
            assert_eq!(base.workers.n_tasks, r.workers.n_tasks);
            assert_eq!(base.workers.n_pairs, r.workers.n_pairs);
        }
    }
}

/// Satellite 2 (integration flavor): random graph / template / task-size /
/// worker-count draws keep the single-rank parallel engine bit-identical
/// to the serial engine at per-vertex granularity, and worker-invariant at
/// the drawn granularity.
#[test]
fn prop_parallel_engine_differential() {
    prop::check("parallel_engine_diff", |gen| {
        let n = gen.usize_in(10, 80);
        let m = gen.usize_in(n, 5 * n) as u64;
        let skew = gen.usize_in(1, 8) as u32;
        let g = generate(&RmatParams::with_skew(n, m, skew, gen.case_seed));
        let tpl = *gen.pick(&["u3-1", "u5-2", "u7-2"]);
        let t = builtin(tpl).unwrap();
        let e = Engine::new(&t);
        let seed = gen.case_seed ^ 0x7777;
        let w = gen.usize_in(1, 8);
        let serial = e.run_iteration(&g, seed);
        let (pv, _) = e.run_iteration_workers(&g, seed, w, 0);
        if serial.colorful.to_bits() != pv.colorful.to_bits() {
            return Err(format!(
                "{tpl} w={w}: per-vertex parallel {} != serial {}",
                pv.colorful, serial.colorful
            ));
        }
        let mts = gen.usize_in(1, 40) as u32;
        let (a, _) = e.run_iteration_workers(&g, seed, 1, mts);
        let (b, _) = e.run_iteration_workers(&g, seed, w, mts);
        if a.colorful.to_bits() != b.colorful.to_bits() {
            return Err(format!(
                "{tpl} mts={mts} w={w}: {} != single-worker {}",
                b.colorful, a.colorful
            ));
        }
        Ok(())
    });
}

/// Satellite 3: on small graphs (≤ 12 vertices) the parallel estimator's
/// mean over many iterations converges to the exact backtracking count,
/// for three templates — run with split tasks and multiple workers so the
/// whole parallel path is what converges.
#[test]
fn parallel_estimator_converges_to_brute_force() {
    for (tpl, iters, tol) in [
        ("u3-1", 2_000u64, 0.15),
        ("u5-2", 6_000, 0.25),
        ("u7-2", 12_000, 0.40),
    ] {
        let t = builtin(tpl).unwrap();
        // deterministically scan seeds for a 12-vertex graph where the
        // template occurs often enough for a stable cross-check
        let mut seed = 50u64;
        let (g, truth) = loop {
            let g = generate(&RmatParams::with_skew(12, 30, 1, seed));
            let truth = count_embeddings(&t, &g);
            if truth >= 10.0 {
                break (g, truth);
            }
            seed += 1;
            assert!(seed < 500, "{tpl}: no 12-vertex graph with enough copies");
        };
        let e = Engine::new(&t);
        let mut sum = 0.0f64;
        for it in 0..iters {
            let (out, _) = e.run_iteration_workers(&g, mix2(123, it), 3, 2);
            sum += out.estimate;
        }
        let mean = sum / iters as f64;
        let rel = (mean - truth).abs() / truth;
        assert!(
            rel < tol,
            "{tpl}: parallel estimator mean {mean} vs exact {truth} (rel {rel:.3})"
        );
    }
}
