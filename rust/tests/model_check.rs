//! Integration-level model checking through the public crate API.
//!
//! Compiled only with `--features model-check`. Where the in-crate model
//! suites (`util::shim::model`, `coordinator::memory`, `comm::mailbox`)
//! exercise internals, these tests drive the same invariants the way an
//! embedder would: public constructors, public accessors, and the
//! exported [`harpsg::util::shim::model`] explorer.
//!
//! Run with:
//!
//! ```text
//! cargo test --features model-check
//! ```

#![cfg(feature = "model-check")]

use std::sync::Arc;

use harpsg::comm::{Packet, ThreadedFabric};
use harpsg::coordinator::{MemClass, SharedAccountant};
use harpsg::util::shim::{self, model};

/// The explorer actually explores: two racing `fetch_add`s admit more
/// than one interleaving, and every one of them sums correctly.
#[test]
fn explorer_covers_multiple_schedules() {
    let n = model::Model::new().check(|| {
        let x = Arc::new(shim::AtomicU64::new(0));
        let a = Arc::clone(&x);
        let t = model::spawn(move || {
            a.fetch_add(1);
        });
        x.fetch_add(2);
        t.join();
        assert_eq!(x.load(), 3);
    });
    assert!(n >= 2, "expected at least two interleavings, got {n}");
}

/// The shim mutex serializes critical sections in every schedule: a
/// read-modify-write under the lock never loses an update.
#[test]
fn shim_mutex_excludes_concurrent_critical_sections() {
    model::Model::new().check(|| {
        let m = Arc::new(shim::Mutex::new(0u64));
        let a = Arc::clone(&m);
        let t = model::spawn(move || {
            let mut g = a.lock().unwrap();
            let v = *g;
            *g = v + 1;
        });
        {
            let mut g = m.lock().unwrap();
            let v = *g;
            *g = v + 1;
        }
        t.join();
        assert_eq!(*m.lock().unwrap(), 2);
    });
}

/// The public accountant invariants hold in every interleaving of two
/// alloc/free pairs: full conservation at quiescence and a peak that is
/// exact for whatever concurrency the schedule actually produced.
#[test]
fn accountant_conserves_and_peaks_exactly() {
    model::Model::new().preemption_bound(2).check(|| {
        let acc = Arc::new(SharedAccountant::new());
        let a = Arc::clone(&acc);
        let t = model::spawn(move || {
            a.alloc(MemClass::CountTable, 64);
            a.free(MemClass::CountTable, 64);
        });
        acc.alloc(MemClass::RecvBuffer, 32);
        acc.free(MemClass::RecvBuffer, 32);
        t.join();
        assert_eq!(acc.total(), 0, "bytes stranded after both frees");
        let peak = acc.peak();
        assert!((64..=96).contains(&peak), "peak {peak} outside [64, 96]");
    });
}

/// A one-step exchange between two ranks completes in every schedule,
/// delivers the payload intact, and releases all in-flight bytes.
#[test]
fn fabric_exchange_completes_in_every_interleaving() {
    model::Model::new().preemption_bound(2).check(|| {
        let fab = Arc::new(ThreadedFabric::new(2, 1));
        let f = Arc::clone(&fab);
        let t = model::spawn(move || {
            f.send(Packet::new(0, 1, 0, 0, 1, vec![7.0]));
        });
        let got = fab.recv_step(1, 0, 1);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].dense_rows(), &[7.0]);
        t.join();
        fab.assert_empty();
        assert_eq!(fab.in_flight_bytes(), 0);
    });
}
