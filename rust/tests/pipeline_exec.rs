//! Differential suite for the rank-parallel pipelined exchange executor
//! (`ExchangeExec::Threaded`): the real Fig-3 schedule — rank threads,
//! in-flight packets, streaming fold — must be a *bit-exact* drop-in for
//! the sequential reference exchange.
//!
//! 1. **builtin × mode × ranks matrix** — threaded estimates, colorful
//!    counts, samples and per-rank memory ledgers are bit-identical to
//!    the sequential executor for every builtin template, all four comm
//!    modes, and rank counts {1, 2, 5, 6};
//! 2. **repeated-run determinism** — same seed, 10 runs: identical
//!    colorful counts, catching any thread-interleaving nondeterminism;
//! 3. **measured pipeline report** — a threaded run's `JobReport` JSON
//!    carries the `pipeline_measured` section (real per-step ρ, exposed
//!    wait, per-rank receive peaks), with the streaming memory bound
//!    (peak ≤ one step's received bytes) holding in pipelined mode.

use harpsg::api::{CountJob, PartitionKind, Session, SessionOptions};
use harpsg::coordinator::{ExchangeExec, ModeSelect};
use harpsg::graph::rmat::{generate, RmatParams};
use harpsg::template::{builtin, BUILTIN_NAMES};
use harpsg::util::Json;

const MODES: [ModeSelect; 4] = [
    ModeSelect::Naive,
    ModeSelect::Pipeline,
    ModeSelect::Adaptive,
    ModeSelect::AdaptiveLb,
];

/// Rank counts under differential test. CI's matrix sets
/// `HARPSG_TEST_RANKS=N` to pin the suite to {1, N}; the default runs the
/// full fixed set {1, 2, 5, 6} (1 = degenerate no-exchange, 2 = pipeline
/// falls back to all-to-all, 5/6 = odd/even multi-step rings).
fn test_rank_counts() -> Vec<usize> {
    if let Ok(v) = std::env::var("HARPSG_TEST_RANKS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 1 {
                return vec![1, n];
            }
            if n == 1 {
                return vec![1];
            }
        }
    }
    vec![1, 2, 5, 6]
}

/// Combine-pool width, honoring the CI thread matrix the same way
/// `tests/differential.rs` does: `HARPSG_TEST_WORKERS=N` pins to N.
fn test_workers() -> usize {
    std::env::var("HARPSG_TEST_WORKERS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(4)
}

fn session(n: usize, m: u64, skew: u32, seed: u64) -> Session {
    Session::with_options(
        generate(&RmatParams::with_skew(n, m, skew, seed)),
        SessionOptions {
            seed: 7,
            partition: PartitionKind::Random,
            load_xla: false,
        },
    )
    .unwrap()
}

fn job(tpl: &str, ranks: usize, mode: ModeSelect, exec: ExchangeExec, workers: usize) -> CountJob {
    CountJob::of_builtin(tpl)
        .unwrap()
        .ranks(ranks)
        .mode(mode)
        .exchange(exec)
        .iterations(1)
        .seed(7)
        .workers(workers)
        .build()
        .unwrap()
}

/// Satellite 1: the full differential matrix. Every builtin template, all
/// four comm modes, rank counts {1, 2, 5, 6} — threaded bit-identical to
/// sequential. The k ≥ 13 templates dominate the runtime, so they run on
/// a smaller graph with the ring sizes that matter ({1, 6}); every mode
/// still crosses both executors there.
#[test]
fn every_builtin_threaded_matches_sequential_bitwise() {
    let light = session(44, 170, 3, 2026);
    let heavy = session(16, 48, 2, 2027);
    let ranks = test_rank_counts();
    let workers = test_workers();
    for tpl in BUILTIN_NAMES {
        let k = builtin(tpl).unwrap().size();
        let (s, tpl_ranks) = if k >= 13 {
            let trimmed = if ranks.len() > 2 {
                vec![1, 6]
            } else {
                ranks.clone()
            };
            (&heavy, trimmed)
        } else {
            (&light, ranks.clone())
        };
        for mode in MODES {
            for &r in &tpl_ranks {
                let seq = s
                    .count(&job(tpl, r, mode, ExchangeExec::Sequential, workers))
                    .unwrap();
                let thr = s
                    .count(&job(tpl, r, mode, ExchangeExec::Threaded, workers))
                    .unwrap();
                assert_eq!(
                    seq.estimate.to_bits(),
                    thr.estimate.to_bits(),
                    "{tpl} {mode:?} P={r}: threaded {} vs sequential {}",
                    thr.estimate,
                    seq.estimate
                );
                assert_eq!(seq.colorful, thr.colorful, "{tpl} {mode:?} P={r}");
                assert_eq!(seq.samples, thr.samples, "{tpl} {mode:?} P={r}");
                assert_eq!(
                    seq.peak_mem_per_rank, thr.peak_mem_per_rank,
                    "{tpl} {mode:?} P={r}: memory ledgers diverged"
                );
                // same Alg-4 queues and pair totals on either executor
                assert_eq!(seq.workers.n_tasks, thr.workers.n_tasks, "{tpl} {mode:?} P={r}");
                assert_eq!(seq.workers.n_pairs, thr.workers.n_pairs, "{tpl} {mode:?} P={r}");
                assert!(seq.measured.is_none(), "{tpl} {mode:?} P={r}");
                assert!(thr.measured.is_some(), "{tpl} {mode:?} P={r}");
            }
        }
    }
}

/// Satellite 2: interleaving nondeterminism cannot hide behind a single
/// lucky schedule — 10 repeated threaded runs with the same seed produce
/// identical colorful counts and estimates, bit for bit.
#[test]
fn repeated_threaded_runs_are_deterministic() {
    let s = session(60, 320, 3, 99);
    let mk = || job("u7-2", 5, ModeSelect::Pipeline, ExchangeExec::Threaded, test_workers());
    let reference = s.count(&mk()).unwrap();
    assert!(!reference.colorful.is_empty());
    for run in 1..10 {
        let r = s.count(&mk()).unwrap();
        assert_eq!(
            reference.colorful, r.colorful,
            "run {run}: colorful counts diverged across identical runs"
        );
        assert_eq!(
            reference.estimate.to_bits(),
            r.estimate.to_bits(),
            "run {run}"
        );
        assert_eq!(reference.samples, r.samples, "run {run}");
    }
}

/// Worker-count invariance survives the nested rank×worker budget: the
/// threaded executor gives every rank `ceil(workers / ranks)` combine
/// threads, and any configured width reproduces width 1 exactly.
#[test]
fn threaded_worker_counts_are_bit_identical() {
    let s = session(50, 240, 3, 31);
    for mode in [ModeSelect::Pipeline, ModeSelect::AdaptiveLb] {
        let base = s
            .count(&job("u5-2", 5, mode, ExchangeExec::Threaded, 1))
            .unwrap();
        for workers in [2, test_workers(), 7] {
            let r = s
                .count(&job("u5-2", 5, mode, ExchangeExec::Threaded, workers))
                .unwrap();
            assert_eq!(
                base.estimate.to_bits(),
                r.estimate.to_bits(),
                "{mode:?} workers={workers}"
            );
            assert_eq!(base.colorful, r.colorful, "{mode:?} workers={workers}");
            assert_eq!(r.workers.n_workers(), workers, "{mode:?}");
            assert_eq!(base.workers.n_tasks, r.workers.n_tasks, "{mode:?}");
            assert_eq!(base.workers.n_pairs, r.workers.n_pairs, "{mode:?}");
        }
    }
}

/// Acceptance: a pipelined threaded run reports a measured pipeline —
/// real per-step ρ in [0, 1], per-rank receive peaks — and the streaming
/// bound holds: every rank's measured `RecvBuffer` peak is at most one
/// exchange step's received bytes.
#[test]
fn measured_pipeline_reported_and_peak_bounded() {
    let s = session(80, 420, 3, 55);
    let report = s
        .count(&job("u10-2", 6, ModeSelect::Pipeline, ExchangeExec::Threaded, test_workers()))
        .unwrap();
    let m = report.measured.as_ref().expect("measured pipeline section");
    // ring of 6 ranks, g = 1 → 5 steps per combine
    assert_eq!(m.steps.len(), 5);
    assert!(m.n_combines > 0);
    assert!(m.comp_s > 0.0, "folds took real time");
    for step in m.mean_steps() {
        let rho = step.rho();
        assert!((0.0..=1.0).contains(&rho), "rho {rho} out of range");
    }
    assert!((0.0..=1.0).contains(&m.mean_rho()));
    assert_eq!(m.recv_peak_per_rank.len(), 6);
    for (p, (&peak, &bound)) in m
        .recv_peak_per_rank
        .iter()
        .zip(&m.max_step_recv_bytes_per_rank)
        .enumerate()
    {
        assert!(peak > 0, "rank {p} received nothing");
        assert!(
            peak <= bound,
            "rank {p}: peak {peak} exceeds one step's bytes {bound}"
        );
    }
}

/// The JSON contract behind `harpsg count --json`: threaded runs carry a
/// `pipeline_measured` object (per-step rho/comp/wait, peaks); sequential
/// runs serialize the field as `null`; the config section names the
/// executor.
#[test]
fn json_report_carries_measured_pipeline() {
    let s = session(70, 360, 3, 21);
    let thr = s
        .count(&job("u7-2", 5, ModeSelect::Pipeline, ExchangeExec::Threaded, 2))
        .unwrap();
    let parsed = harpsg::util::jsonparse::parse(&thr.to_json_string()).unwrap();
    assert_eq!(
        parsed
            .get("config")
            .unwrap()
            .get("exchange")
            .unwrap()
            .as_str(),
        Some("threaded")
    );
    let mp = parsed.get("pipeline_measured").unwrap();
    let steps = mp.get("steps").unwrap().as_arr().unwrap();
    assert_eq!(steps.len(), 4, "ring of 5 ranks → 4 steps");
    for step in steps {
        let rho = step.get("rho").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&rho));
        assert!(step.get("comp_s").unwrap().as_f64().is_some());
        assert!(step.get("wait_s").unwrap().as_f64().is_some());
    }
    assert!(mp.get("mean_rho").unwrap().as_f64().is_some());
    assert!(mp.get("exposed_wait_s").unwrap().as_f64().unwrap() >= 0.0);
    let peaks = mp.get("recv_peak_per_rank").unwrap().as_arr().unwrap();
    let bounds = mp
        .get("max_step_recv_bytes_per_rank")
        .unwrap()
        .as_arr()
        .unwrap();
    assert_eq!(peaks.len(), 5);
    for (peak, bound) in peaks.iter().zip(bounds) {
        assert!(peak.as_f64().unwrap() <= bound.as_f64().unwrap());
    }

    let seq = s
        .count(&job("u7-2", 5, ModeSelect::Pipeline, ExchangeExec::Sequential, 2))
        .unwrap();
    let parsed = harpsg::util::jsonparse::parse(&seq.to_json_string()).unwrap();
    assert_eq!(*parsed.get("pipeline_measured").unwrap(), Json::Null);
    assert_eq!(
        parsed
            .get("config")
            .unwrap()
            .get("exchange")
            .unwrap()
            .as_str(),
        Some("sequential")
    );
}
