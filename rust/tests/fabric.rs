//! Differential suite for the process-mode socket fabric: the same
//! per-subtemplate exchange schedules must produce **bit-identical**
//! counts whether the rank loop runs as threads in one address space
//! (`ThreadedFabric`, modeled clocks) or as one `SocketFabric` endpoint
//! per rank over a localhost TCP mesh (wall clocks).
//!
//! Three layers:
//!
//! 1. **in-thread socket matrix** — P OS threads, each owning exactly one
//!    rank of its own `DistributedRunner` over its own `SocketFabric`
//!    endpoint (the transport is byte-for-byte the one real processes
//!    use; only the address exchange is in-memory). Builtin templates ×
//!    both exchange executors × ranks {2, 5, 6}: merged colorful counts,
//!    the recomputed estimate, and every static-mode comm decision
//!    (shape *and* predicted ρ, which derive from the fixed
//!    `policy.flop_time` calibration seed) match the threaded run
//!    bit-for-bit;
//! 2. **launcher E2E** — `coordinator::procmode::launch` spawns real
//!    `harpsg-rank` processes (via `CARGO_BIN_EXE_harpsg-rank`) and the
//!    merged `RunResult` is bit-identical to the in-process run, with
//!    wall-clock link measurements from every rank;
//! 3. **error paths** — a bad template or a missing worker binary
//!    surfaces a typed error without hanging the launcher.
//!
//! CI's socket-matrix pins `HARPSG_TEST_RANKS=N` to {2, N} and the
//! release leg sets `HARPSG_TEST_ADAPTIVE=1`, as everywhere else.

use harpsg::colorcount::{median_of_means, EngineContext};
use harpsg::comm::{config_digest, PeerAddr, SocketFabric, SocketListener, SocketOptions};
use harpsg::coordinator::{
    launch, DistributedRunner, ExchangeExec, FabricKind, ModeSelect, ProcSpec, RunConfig,
    RunResult,
};
use harpsg::graph::rmat::{generate, RmatParams};
use harpsg::graph::Graph;
use harpsg::template::builtin;
use std::path::PathBuf;
use std::time::Duration;

/// Rank counts, honoring the CI matrix the same way
/// `tests/pipeline_exec.rs` does. 1 is excluded: a single owned rank is
/// by definition not process mode (`owned.len() == n_ranks`).
fn test_rank_counts() -> Vec<usize> {
    if let Ok(v) = std::env::var("HARPSG_TEST_RANKS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 2 {
                return vec![2, n];
            }
            return vec![2];
        }
    }
    vec![2, 5, 6]
}

/// The CI adaptive leg, as in `tests/adaptive.rs`: `=1` pins to the
/// sweep-enabled leg, `=0` to the static one, unset runs both.
fn adaptive_legs() -> Vec<bool> {
    match std::env::var("HARPSG_TEST_ADAPTIVE").ok().as_deref() {
        Some("1") => vec![true],
        Some("0") => vec![false],
        _ => vec![false, true],
    }
}

fn opts() -> SocketOptions {
    SocketOptions {
        connect_timeout: Duration::from_secs(30),
        connect_backoff: Duration::from_millis(5),
        // generous: a failed peer must surface as a typed error, but a
        // loaded CI box must not trip the bound mid-run
        recv_timeout: Duration::from_secs(120),
    }
}

fn base_cfg(ranks: usize, exec: ExchangeExec, adaptive: bool) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.n_ranks = ranks;
    cfg.n_workers = 2;
    cfg.n_iterations = 3;
    cfg.seed = 7;
    cfg.mode = if adaptive {
        ModeSelect::Adaptive
    } else {
        ModeSelect::Pipeline
    };
    cfg.adaptive_group = adaptive;
    cfg.exchange = exec;
    cfg
}

/// Run `cfg` with every rank behind its own `SocketFabric` endpoint on a
/// localhost TCP mesh, one OS thread per rank. Returns the per-rank
/// partial results in rank order.
fn socket_run(tpl: &str, g: &Graph, cfg: &RunConfig) -> Vec<RunResult> {
    let n = cfg.n_ranks;
    let listeners: Vec<SocketListener> = (0..n)
        .map(|_| SocketListener::bind(&PeerAddr::Tcp("127.0.0.1:0".into())).unwrap())
        .collect();
    let addrs: Vec<PeerAddr> = listeners.iter().map(|l| l.local_addr().clone()).collect();
    // every endpoint of one run shares the digest; a real launcher
    // derives it from the canonical config text
    let digest = config_digest(&format!("fabric-test {tpl} P={n} seed={}", cfg.seed));
    let mut out: Vec<Option<RunResult>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (r, l) in listeners.into_iter().enumerate() {
            let addrs = addrs.clone();
            let cfg = cfg.clone();
            handles.push(s.spawn(move || {
                let t = builtin(tpl).unwrap();
                let fabric =
                    SocketFabric::establish(r, l, &addrs, digest, n.max(1), opts()).unwrap();
                let mut runner = DistributedRunner::new(&t, g, cfg);
                let res = runner.run_on(&fabric, &[r]).unwrap();
                fabric.finish();
                (r, res)
            }));
        }
        for h in handles {
            let (r, res) = h.join().unwrap();
            out[r] = Some(res);
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Merge per-rank partials exactly like `procmode::merge` / the launcher:
/// colorful counts fold in ascending rank order from 0.0, then the
/// samples rescale and the estimate recomputes with the same
/// median-of-means grouping the in-process run uses.
fn merge_counts(tpl: &str, per_rank: &[RunResult]) -> (Vec<f64>, f64) {
    let t = builtin(tpl).unwrap();
    let ctx = EngineContext::new(&t);
    let iters = per_rank[0].colorful.len();
    let mut colorful = Vec::with_capacity(iters);
    let mut samples = Vec::with_capacity(iters);
    for it in 0..iters {
        let mut total = 0.0f64;
        for r in per_rank {
            assert_eq!(r.colorful.len(), iters, "{tpl}: ragged iteration counts");
            total += r.colorful[it];
        }
        colorful.push(total);
        samples.push(total * ctx.colorful_scale() / ctx.aut as f64);
    }
    let estimate = median_of_means(&samples, 3.min(samples.len()));
    (colorful, estimate)
}

/// Tentpole acceptance: builtin templates × both exchange executors ×
/// ranks {2, 5, 6}, static modes — byte-equal count estimates and
/// identical comm decisions (including predicted ρ, which derives from
/// the fixed calibration seed `policy.flop_time`, never wall clocks)
/// between the socket mesh and the in-process threaded fabric.
#[test]
fn socket_counts_and_decisions_match_threaded_bitwise() {
    let g = generate(&RmatParams::with_skew(48, 240, 3, 99));
    for tpl in ["u3-1", "u5-2", "u7-2"] {
        for exec in [ExchangeExec::Threaded, ExchangeExec::Sequential] {
            for ranks in test_rank_counts() {
                let mut cfg = base_cfg(ranks, exec, false);
                let t = builtin(tpl).unwrap();
                let reference = DistributedRunner::new(&t, &g, cfg.clone()).run();

                cfg.fabric = FabricKind::Socket;
                let per_rank = socket_run(tpl, &g, &cfg);
                let (colorful, estimate) = merge_counts(tpl, &per_rank);

                let label = format!("{tpl} P={ranks} {exec:?}");
                for (it, (&m, &r)) in colorful.iter().zip(&reference.colorful).enumerate() {
                    assert_eq!(
                        m.to_bits(),
                        r.to_bits(),
                        "{label} it={it}: socket colorful {m} vs threaded {r}"
                    );
                }
                assert_eq!(
                    estimate.to_bits(),
                    reference.estimate.to_bits(),
                    "{label}: socket estimate {estimate} vs threaded {}",
                    reference.estimate
                );
                // every rank process replicated the full decision list,
                // and it matches the threaded run exactly
                for (r, res) in per_rank.iter().enumerate() {
                    assert_eq!(
                        res.comm_decisions.len(),
                        reference.comm_decisions.len(),
                        "{label} rank {r}"
                    );
                    for (d, e) in res.comm_decisions.iter().zip(&reference.comm_decisions) {
                        assert_eq!(d.sub, e.sub, "{label} rank {r}");
                        assert_eq!(d.pipelined, e.pipelined, "{label} rank {r} sub {}", d.sub);
                        assert_eq!(d.g, e.g, "{label} rank {r} sub {}", d.sub);
                        assert_eq!(d.n_steps, e.n_steps, "{label} rank {r} sub {}", d.sub);
                        assert_eq!(
                            d.predicted_rho.to_bits(),
                            e.predicted_rho.to_bits(),
                            "{label} rank {r} sub {}",
                            d.sub
                        );
                    }
                    // static storage decisions replicate too (the
                    // calibration allreduce makes them global)
                    assert_eq!(res.storage, reference.storage, "{label} rank {r}");
                }
            }
        }
    }
}

/// The adaptive sweep over sockets: counts stay bit-identical to the
/// threaded adaptive run (the shape is a performance decision, never a
/// correctness one), every rank process reports the *same* decision list
/// (the calibration allreduce keeps the sweeps in lockstep — divergence
/// would deadlock the mesh), and every scheduled ring is feasible.
#[test]
fn adaptive_sweep_stays_exact_and_consistent_over_sockets() {
    if !adaptive_legs().contains(&true) {
        return;
    }
    let g = generate(&RmatParams::with_skew(48, 240, 3, 99));
    for exec in [ExchangeExec::Threaded, ExchangeExec::Sequential] {
        for ranks in test_rank_counts() {
            let mut cfg = base_cfg(ranks, exec, true);
            let t = builtin("u5-2").unwrap();
            let reference = DistributedRunner::new(&t, &g, cfg.clone()).run();

            cfg.fabric = FabricKind::Socket;
            let per_rank = socket_run("u5-2", &g, &cfg);
            let (colorful, estimate) = merge_counts("u5-2", &per_rank);

            let label = format!("u5-2 P={ranks} {exec:?} adaptive");
            for (it, (&m, &r)) in colorful.iter().zip(&reference.colorful).enumerate() {
                assert_eq!(m.to_bits(), r.to_bits(), "{label} it={it}");
            }
            assert_eq!(estimate.to_bits(), reference.estimate.to_bits(), "{label}");
            let first = &per_rank[0];
            for (r, res) in per_rank.iter().enumerate() {
                assert_eq!(
                    res.comm_decisions.len(),
                    first.comm_decisions.len(),
                    "{label} rank {r}"
                );
                for (d, e) in res.comm_decisions.iter().zip(&first.comm_decisions) {
                    assert_eq!(
                        (d.sub, d.pipelined, d.g, d.n_steps, d.predicted_rho.to_bits()),
                        (e.sub, e.pipelined, e.g, e.n_steps, e.predicted_rho.to_bits()),
                        "{label}: rank {r} diverged from rank 0 on sub {}",
                        d.sub
                    );
                    assert!(
                        !d.pipelined || 2 * d.g + 1 <= ranks,
                        "{label} rank {r}: infeasible scheduled g={}",
                        d.g
                    );
                }
            }
        }
    }
}

/// Launcher E2E: real `harpsg-rank` worker processes over localhost,
/// spawned and merged by `coordinator::procmode::launch`. The merged
/// result is bit-identical to the in-process run of the same config, and
/// the report carries a wall-clock link fit from every rank.
#[test]
fn launcher_spawns_processes_and_merges_bitwise() {
    let ranks = 4usize;
    let mut cfg = base_cfg(ranks, ExchangeExec::Threaded, false);
    cfg.fabric = FabricKind::Socket;
    let mut spec = ProcSpec::new("u5-2", "rmat:64:320:3:11", 0, cfg.clone());
    spec.rank_bin = Some(PathBuf::from(env!("CARGO_BIN_EXE_harpsg-rank")));
    let merged = launch(&spec).expect("process-mode launch over localhost");

    let g = generate(&RmatParams::with_skew(64, 320, 3, 11));
    let t = builtin("u5-2").unwrap();
    let reference = DistributedRunner::new(&t, &g, cfg).run();

    assert_eq!(merged.colorful.len(), reference.colorful.len());
    for (it, (&m, &r)) in merged.colorful.iter().zip(&reference.colorful).enumerate() {
        assert_eq!(
            m.to_bits(),
            r.to_bits(),
            "it={it}: launcher colorful {m} vs in-process {r}"
        );
    }
    assert_eq!(
        merged.estimate.to_bits(),
        reference.estimate.to_bits(),
        "launcher estimate {} vs in-process {}",
        merged.estimate,
        reference.estimate
    );
    assert_eq!(merged.comm_decisions.len(), reference.comm_decisions.len());
    for (d, e) in merged.comm_decisions.iter().zip(&reference.comm_decisions) {
        assert_eq!(
            (d.sub, d.pipelined, d.g, d.n_steps, d.predicted_rho.to_bits()),
            (e.sub, e.pipelined, e.g, e.n_steps, e.predicted_rho.to_bits())
        );
    }
    assert_eq!(merged.storage, reference.storage);
    // measured, not simulated: one wall-clock Hockney fit per rank,
    // each computed from that rank's real blocking sends
    assert_eq!(merged.link.len(), ranks, "one link fit per rank process");
    for (r, l) in merged.link.iter().enumerate() {
        assert_eq!(l.rank, r);
        assert!(l.samples > 0, "rank {r}: link fit without samples");
        assert!(l.alpha_s >= 0.0 && l.beta_s_per_byte >= 0.0);
    }
    // the in-process reference has no wire to measure
    assert!(reference.link.is_empty());
    assert!(merged.oom == reference.oom);
}

/// Error paths stay typed and prompt: a template the workers could never
/// resolve fails before any process spawns, and a missing worker binary
/// fails at spawn — neither hangs the launcher.
#[test]
fn launcher_errors_are_typed_not_hangs() {
    let mut cfg = base_cfg(2, ExchangeExec::Threaded, false);
    cfg.fabric = FabricKind::Socket;
    cfg.n_iterations = 1;

    let spec = ProcSpec::new("no-such-template", "rmat:16:40:2:3", 0, cfg.clone());
    assert!(launch(&spec).is_err(), "unknown template must fail fast");

    let mut spec = ProcSpec::new("u3-1", "rmat:16:40:2:3", 0, cfg);
    spec.rank_bin = Some(PathBuf::from("/nonexistent/harpsg-rank"));
    let err = launch(&spec).expect_err("missing worker binary must fail at spawn");
    let msg = format!("{err}");
    assert!(
        msg.contains("harpsg-rank") || msg.to_lowercase().contains("spawn"),
        "unhelpful spawn error: {msg}"
    );
}
