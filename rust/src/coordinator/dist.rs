//! The distributed color-coding coordinator (paper Alg 2 + Alg 3).
//!
//! `P` simulated ranks each own a random vertex partition of the input
//! graph. Every subtemplate combine runs in two phases:
//!
//! 1. **local** — aggregate+contract over locally-owned neighbor pairs;
//! 2. **exchange** — per the chosen [`CommMode`], ship active-child count
//!    rows between ranks (all-to-all in one step, or the Adaptive-Group
//!    ring in `W` steps) and fold each received slice into the output
//!    (the per-step contraction is exact because the factored combine is
//!    linear in the aggregation — `colorcount::engine`).
//!
//! All counting is *real* (bit-identical to the single-rank engine, an
//! invariant enforced by tests). Time is dual-clocked: real single-core
//! wall-clock for calibration, plus the model clock — virtual-thread
//! replay for computation (Fig 11), Hockney for transfers (Eq 8), and the
//! pipeline algebra (Eq 9–14) for interleaving — which regenerates the
//! paper's figures (DESIGN.md §1).
//!
//! The partition-derived exchange structures (request lists and the
//! local/remote neighbor-pair plans) depend only on the graph and the
//! partition — **not** on the template — so they are factored into
//! [`ExchangePlan`]. `api::Session` builds one plan per rank count and
//! shares it (via `Arc`) across every template counted on that graph,
//! amortizing the dominant setup cost of multi-template sweeps.

use super::memory::{DualAccountant, MemClass};
use super::run::{
    CommDecision, EngineKind, ExchangeExec, ModeSelect, ModelTime, PruneStats, RankLink,
    RunConfig, RunResult, StorageDecision, ThreadStats,
};
use crate::api::{HarpsgError, Progress};
use crate::colorcount::engine::{aggregate_batch, contract_touched, CombineScratch};
use crate::colorcount::parallel::{
    combine_batches_pruned, nested_budget, ExecStats, PairBatch,
};
use crate::colorcount::storage::{self, StoragePolicy, TableStorage};
use crate::colorcount::{EngineContext, Frontier, KernelMode, PruneMode};
use crate::colorcount::{init_leaf_table, median_of_means, Coloring, Count, CountTable};
use crate::combin::SplitTable;
use crate::comm::{
    AdaptivePolicy, CombineShape, CommMode, FabricResult, GroupCalibration, HockneyParams,
    LinkMeasurement, Packet, RankFabric, Schedule, ThreadedFabric,
};
use crate::graph::shard::shard_to_scratch;
use crate::graph::{Graph, GraphLoadError, GraphStore, Partition, RequestLists, SegmentedGraph};
use crate::pipeline::{naive, pipelined, MeasuredPipeline, PipelineReport, StepTiming};
use crate::sched::{make_tasks, replay, TaskCostModel};
use crate::template::{complexity, Template, TemplateComplexity};
use std::borrow::Cow;
use std::sync::Arc;
use std::time::Instant;

/// Feasibility clamp for a forced ring group size: a pipelined ring
/// needs full communication groups of m = 2g+1 ≤ P; `g = P-1` is the
/// degenerate single-step all-to-all. Everything else (including the
/// half-open band (P-1)/2 < g < P-1, which would schedule overlapping
/// groups the Fig-2 routing cannot realize) is a typed error.
pub fn validate_group_size(g: usize, n_ranks: usize) -> Result<(), HarpsgError> {
    if g == 0 {
        return Err(HarpsgError::InvalidJob("group_size must be ≥ 1".into()));
    }
    if n_ranks >= 2 && g == n_ranks - 1 {
        return Ok(()); // the all-to-all degenerate
    }
    // the one feasibility predicate (shared with the adaptive sweep)
    let max_ring = AdaptivePolicy::max_feasible_group(n_ranks);
    if g <= max_ring {
        return Ok(());
    }
    Err(HarpsgError::InvalidJob(format!(
        "group_size {g} infeasible for {n_ranks} ranks: a pipelined ring needs \
         2g+1 ≤ P (g ≤ {max_ring}), or g = P-1 = {} for all-to-all",
        n_ranks.saturating_sub(1)
    )))
}

/// One subtemplate's exchange decision for one iteration: the schedule the
/// executors run plus the model context the report carries.
#[derive(Debug, Clone)]
struct SubDecision {
    schedule: Schedule,
    pipelined: bool,
    /// ring offsets per step (P-1 for all-to-all)
    g: usize,
    /// the model's predicted mean ρ for this shape (0 for all-to-all)
    predicted_rho: f64,
}

/// Raw per-subtemplate model records in compute *units*; converted to
/// seconds once the unit cost is calibrated from the real measurements.
struct SubRecord {
    sub: usize,
    /// per-rank thread-replay makespan of the local phase, units
    local_makespan: Vec<f64>,
    /// `[step][rank]` (thread-replay makespan units, comm seconds)
    steps: Vec<Vec<(f64, f64)>>,
    pipelined: bool,
}

/// One subtemplate's storage bookkeeping for one iteration, all ranks
/// aggregated: density inputs (nnz/cells), how many ranks went sparse,
/// and resident vs dense-layout bytes. Feeds both the report's
/// [`StorageDecision`]s (final iteration) and the next iteration's
/// sparse wire-byte model.
#[derive(Debug, Clone, Copy, Default)]
struct SubStorage {
    nnz: u64,
    cells: u64,
    sparse_ranks: usize,
    n_ranks: usize,
    dense_bytes: u64,
    resident_bytes: u64,
    /// rows of the stored tables with any nonzero entry (the frontier's
    /// live count), summed over ranks
    live_rows: u64,
    /// total stored rows, summed over ranks
    total_rows: u64,
    /// frontier-pruning tallies of the combine that built this sub's
    /// tables (always 0 for leaves and with pruning off)
    pairs_skipped: u64,
    rows_skipped: u64,
    wire_rows_dropped: u64,
}

impl SubStorage {
    fn density(&self) -> f64 {
        if self.cells == 0 {
            0.0
        } else {
            self.nnz as f64 / self.cells as f64
        }
    }

    /// Fraction of stored rows that are live. Mirrors
    /// [`Frontier::occupancy`]'s empty-table convention (1.0), so the
    /// `Auto` wire model never discounts a sub it knows nothing about.
    fn occupancy(&self) -> f64 {
        if self.total_rows == 0 {
            1.0
        } else {
            self.live_rows as f64 / self.total_rows as f64
        }
    }
}

/// Store a freshly built dense table per the policy: measure its density
/// (the `CountTable::density`/`nnz` probe — the Auto policy's input and
/// the report's per-sub figure), swap the rank's *real* ledger from the
/// dense bytes the caller already charged to the chosen representation
/// (the dense-baseline ledger keeps holding the dense charge), and fold
/// the outcome into the iteration's per-sub record.
fn store_table(
    policy: &StoragePolicy,
    t: CountTable,
    mem: &mut DualAccountant,
    rec: &mut SubStorage,
) -> TableStorage {
    let dense_b = t.bytes();
    let cells = t.data.len() as u64;
    let (stored, nnz) = TableStorage::from_dense_policy(t, policy);
    let nnz = nnz as u64;
    if stored.bytes() != dense_b {
        // free→alloc: the ledger models steady-state residency of the
        // live representation, not the transient compression copy
        mem.free2(MemClass::CountTable, dense_b, 0);
        mem.alloc2(MemClass::CountTable, stored.bytes(), 0);
    }
    rec.nnz += nnz;
    rec.cells += cells;
    rec.n_ranks += 1;
    if stored.is_sparse() {
        rec.sparse_ranks += 1;
    }
    rec.dense_bytes += dense_b;
    rec.resident_bytes += stored.bytes();
    // the frontier occupancy probe: feeds the report's prune stats and
    // the next iteration's wire-byte model (one linear scan, same order
    // as the density probe above)
    let f = stored.frontier();
    rec.live_rows += f.live_rows() as u64;
    rec.total_rows += f.n_rows() as u64;
    stored
}

/// The per-table pruning gate both executors share: `None` when the
/// mode (or this table's measured occupancy, under `Auto`) says to
/// stream everything — the frontier is then never even built, keeping
/// prune-off runs at exactly the historical cost.
fn table_frontier_for(t: &TableStorage, prune: PruneMode) -> Option<Frontier> {
    if matches!(prune, PruneMode::Off) {
        return None;
    }
    let f = t.frontier();
    prune.active_for(f.occupancy()).then_some(f)
}

/// Filter an adjacency pair list by the active table's frontier: pairs
/// whose active row `u` is dead only add exact `+0.0`s, so dropping them
/// before the executor sees the list is bit-exact — and makes every
/// downstream task queue frontier-effective (degrees, LPT costs, the
/// model replay) without further plumbing. Borrows the original list
/// untouched when no frontier applies.
fn prune_pairs<'a>(
    pairs: &'a [(u32, u32)],
    frontier: Option<&Frontier>,
    skipped: &mut u64,
) -> Cow<'a, [(u32, u32)]> {
    match frontier {
        None => Cow::Borrowed(pairs),
        Some(f) => {
            let kept: Vec<(u32, u32)> = pairs
                .iter()
                .copied()
                .filter(|&(_, u)| f.contains(u as usize))
                .collect();
            *skipped += (pairs.len() - kept.len()) as u64;
            Cow::Owned(kept)
        }
    }
}

/// The single send-side serializer both exchange executors share: encode
/// the rows receiver `q` requested from rank `p`'s active table, in the
/// receiver's request-list order, in the table's own storage encoding
/// (`colorcount::storage::encode_rows` — dense tables ship the
/// historical flat rows, sparse tables ship CSR rows). With pruning
/// active on the sender's table, the masked encoder drops the
/// frontier-dead requested rows from the wire entirely
/// (`encode_rows_masked` — the receiver's positional fold re-expands
/// them to empty rows, so the fold order and results never move).
fn encode_request_rows(
    active: &TableStorage,
    plan: &ExchangePlan,
    p: usize,
    q: usize,
    pruned: bool,
) -> storage::RowsPayload {
    let want = plan.req.rows(q, p);
    let rows = want.iter().map(|&u| plan.part.local_index[u as usize] as usize);
    if pruned {
        storage::encode_rows_masked(active, rows)
    } else {
        storage::encode_rows(active, rows)
    }
}

/// Template-independent exchange setup for one (graph, partition) pair:
/// the request lists plus, per rank, the precomputed local neighbor-pair
/// list and the per-sender fold plans. Building this walks every edge of
/// the graph once — the dominant fixed cost of a distributed run — so it
/// is shared across templates via `Arc` (see `api::Session`).
pub struct ExchangePlan {
    pub part: Partition,
    pub req: RequestLists,
    /// per rank: (v_local_row, u_local_row) pairs with both endpoints local
    pub(crate) local_pairs: Vec<Vec<(u32, u32)>>,
    /// `plans[p][q]`: (v_local_row, row index in the buffer received from q)
    pub(crate) plans: Vec<Vec<Vec<(u32, u32)>>>,
    /// mean request-list length over ordered rank pairs — the exact value
    /// of the paper's Eq-5 `≈ |E|/P²` estimate, fed to the adaptive
    /// model as the expected remote rows per peer per step
    mean_remote_rows: f64,
    /// resolved storage backend the plan was built from ("resident" or
    /// "mmap") — recorded so the run charges the right ledger class
    pub graph_storage: &'static str,
    /// graph bytes each rank keeps resident under that backend, charged
    /// to the memory ledger and surfaced as `memory.graph_resident_per_rank`
    pub graph_bytes_per_rank: Vec<u64>,
}

impl ExchangePlan {
    /// Build the exchange structures for an explicit partition.
    pub fn build(g: &Graph, part: Partition) -> ExchangePlan {
        Self::build_with_store(g, part).expect("resident graph store cannot fail")
    }

    /// Build against any [`GraphStore`]: the plan build is the single
    /// consumer of adjacency in a distributed run (executors replay the
    /// precomputed pair lists; remote rows travel via request lists), so
    /// this is the one place local adjacency reads go through the store.
    /// Ranks are visited one at a time and each segment view is dropped
    /// before the next loads — peak graph memory under `mmap` is one
    /// rank's slice, never the whole CSR.
    pub fn build_with_store<S: GraphStore + ?Sized>(
        store: &S,
        part: Partition,
    ) -> Result<ExchangePlan, GraphLoadError> {
        let n_ranks = part.n_ranks;
        let mut needs: Vec<Vec<Vec<u32>>> = vec![vec![Vec::new(); n_ranks]; n_ranks];
        let mut local_pairs = vec![Vec::new(); n_ranks];
        let mut plans = vec![vec![Vec::new(); n_ranks]; n_ranks];
        let mut graph_bytes = Vec::with_capacity(n_ranks);
        let mut seen: Vec<u64> = Vec::new();
        for p in 0..n_ranks {
            let view = store.rank_view(&part, p)?;
            seen.clear();
            for r in 0..part.locals[p].len() {
                for &u in view.neighbors(r) {
                    let q = part.owner_of(u);
                    if q != p {
                        seen.push(((q as u64) << 32) | u as u64);
                    }
                }
            }
            seen.sort_unstable();
            seen.dedup();
            for &key in &seen {
                needs[p][(key >> 32) as usize].push(key as u32);
            }
            for r in 0..part.locals[p].len() {
                for &u in view.neighbors(r) {
                    let q = part.owner_of(u);
                    if q == p {
                        local_pairs[p].push((r as u32, part.local_index[u as usize]));
                    } else {
                        let row = needs[p][q].binary_search(&u).expect("request list");
                        plans[p][q].push((r as u32, row as u32));
                    }
                }
            }
            graph_bytes.push(store.rank_bytes(&part, p));
        }
        let req = RequestLists { needs };
        let mut req_rows = 0u64;
        for p in 0..n_ranks {
            for q in 0..n_ranks {
                if p != q {
                    req_rows += req.rows(p, q).len() as u64;
                }
            }
        }
        let ordered_pairs = (n_ranks * n_ranks.saturating_sub(1)).max(1);
        Ok(ExchangePlan {
            part,
            req,
            local_pairs,
            plans,
            mean_remote_rows: req_rows as f64 / ordered_pairs as f64,
            graph_storage: store.storage_name(),
            graph_bytes_per_rank: graph_bytes,
        })
    }

    /// Build from an on-disk segment set (`--graph-storage mmap`),
    /// verifying the segments were cut for exactly this partition.
    pub fn from_segments(
        seg: &SegmentedGraph,
        part: Partition,
    ) -> Result<ExchangePlan, GraphLoadError> {
        seg.verify_partition(&part)?;
        Self::build_with_store(seg, part)
    }

    /// The partition [`Self::random`] builds — the seed mixing is part of
    /// the reproducibility contract, shared by every entry point (runner,
    /// session, sharded storage) so identical seeds always cut identical
    /// partitions regardless of backend.
    pub fn random_partition(g: &Graph, n_ranks: usize, seed: u64) -> Partition {
        Partition::random(g.n_vertices(), n_ranks, seed ^ 0x9a27)
    }

    /// The paper's default: a hashed random partition (seed-mixed exactly
    /// like the historical `DistributedRunner::new` path).
    pub fn random(g: &Graph, n_ranks: usize, seed: u64) -> ExchangePlan {
        Self::build(g, Self::random_partition(g, n_ranks, seed))
    }

    /// Contiguous block partition (ablation A2).
    pub fn block(g: &Graph, n_ranks: usize) -> ExchangePlan {
        Self::build(g, Partition::block(g.n_vertices(), n_ranks))
    }

    pub fn n_ranks(&self) -> usize {
        self.part.n_ranks
    }

    /// Mean remote rows a rank requests from one peer (the exact Eq-5
    /// quantity) — the `remote_rows_per_step` input of [`CombineShape`].
    pub fn mean_remote_rows(&self) -> f64 {
        self.mean_remote_rows
    }
}

/// Build the exchange plan for `part` under the configured graph-storage
/// mode: `resident` (or `auto` under budget) walks the shared CSR;
/// `mmap` (or `auto` over budget) cuts scratch per-rank segment files,
/// builds the plan one rank-slice at a time, and removes the scratch
/// shards when the [`SegmentedGraph`] drops — after this returns, the
/// plan is self-contained and no segment is held resident.
pub fn build_plan_for(
    g: &Graph,
    cfg: &RunConfig,
    part: Partition,
) -> Result<ExchangePlan, GraphLoadError> {
    if cfg.graph_storage.resolves_to_mmap(g.bytes(), cfg.graph_budget) {
        let seg = shard_to_scratch(g, &part)?;
        ExchangePlan::from_segments(&seg, part)
    } else {
        Ok(ExchangePlan::build(g, part))
    }
}

pub struct DistributedRunner<'g> {
    pub g: &'g Graph,
    pub ctx: EngineContext,
    pub cfg: RunConfig,
    /// shared partition + request lists + neighbor-pair plans
    pub plan: Arc<ExchangePlan>,
    pub tc: TemplateComplexity,
    /// optional XLA combine backend (runtime::xla_engine), used when
    /// `cfg.engine == EngineKind::Xla`
    pub xla: Option<crate::runtime::XlaCombine>,
    /// ablation hook: force a ring group size regardless of mode
    group_override: Option<usize>,
    /// optional observer receiving per-iteration / per-subtemplate /
    /// per-exchange-step callbacks (`api::Progress`)
    progress: Option<Arc<dyn Progress>>,
}

impl<'g> DistributedRunner<'g> {
    pub fn new(t: &Template, g: &'g Graph, cfg: RunConfig) -> Self {
        let part = ExchangePlan::random_partition(g, cfg.n_ranks, cfg.seed);
        let plan = build_plan_for(g, &cfg, part).expect("graph storage sharding failed");
        Self::with_plan(t, g, cfg, Arc::new(plan))
    }

    /// Build with an explicit partition (ablation A2 uses block layout).
    pub fn with_partition(t: &Template, g: &'g Graph, cfg: RunConfig, part: Partition) -> Self {
        let plan = Arc::new(ExchangePlan::build(g, part));
        Self::with_plan(t, g, cfg, plan)
    }

    /// Build on a prebuilt (usually session-cached) exchange plan. This is
    /// the facade's amortized path: the plan is reused across templates.
    pub fn with_plan(t: &Template, g: &'g Graph, cfg: RunConfig, plan: Arc<ExchangePlan>) -> Self {
        assert_eq!(
            plan.n_ranks(),
            cfg.n_ranks,
            "exchange plan was built for a different rank count"
        );
        let ctx = EngineContext::new(t);
        let tc = complexity(t);
        DistributedRunner {
            g,
            ctx,
            cfg,
            plan,
            tc,
            xla: None,
            group_override: None,
            progress: None,
        }
    }

    /// Ablation hook: force the ring group size (offsets per step).
    /// Validates ring feasibility against the configured rank count —
    /// `2g+1 ≤ P`, or the degenerate all-to-all `g = P-1` — instead of
    /// silently scheduling an infeasible ring.
    pub fn set_group_size(&mut self, g: usize) -> Result<(), HarpsgError> {
        validate_group_size(g, self.cfg.n_ranks)?;
        self.group_override = Some(g);
        Ok(())
    }

    /// Attach a progress observer (see `api::Progress`).
    pub fn set_progress(&mut self, progress: Arc<dyn Progress>) {
        self.progress = Some(progress);
    }

    /// Ablation hook: swap to a contiguous block partition (rebuilds the
    /// request lists and update plans).
    pub fn use_block_partition(&mut self) {
        self.plan = Arc::new(ExchangePlan::block(self.g, self.cfg.n_ranks));
    }

    /// The combine shape of subtemplate `i` — the adaptive model's input.
    /// `storage_stats` carries the previous iteration's per-sub storage
    /// outcome: when the active child's table went sparse on some ranks,
    /// the model charges the measured-density sparse wire bytes for the
    /// sparse share — capped at the dense row width, because the codec's
    /// per-packet fallback guarantees the wire never exceeds the dense
    /// encoding — keeping the ρ predictions honest about what the fabric
    /// will actually ship.
    fn combine_shape(&self, i: usize, storage_stats: &[Option<SubStorage>]) -> CombineShape {
        let dag = &self.ctx.dag;
        let sub = &dag.subs[i];
        let st_opt = sub.active.and_then(|a| storage_stats[a]);
        let dense_row =
            AdaptivePolicy::row_bytes(self.ctx.k, sub.active_size(dag), &self.ctx.binom) as f64;
        let base = st_opt
            .filter(|st| st.sparse_ranks > 0 && st.cells > 0 && st.n_ranks > 0)
            .map(|st| {
                let a2 = self.ctx.binom.c(self.ctx.k, sub.active_size(dag)) as usize;
                let sparse = storage::expected_sparse_row_bytes(st.density(), a2).min(dense_row);
                let frac = st.sparse_ranks as f64 / st.n_ranks as f64;
                frac * sparse + (1.0 - frac) * dense_row
            });
        // frontier discount: when pruning is active for the active
        // child's measured occupancy, the masked encoding ships only the
        // live share of the requested rows (mask/offset overhead is a
        // few bytes per 64 rows — absorbed by the dense cap), so the
        // Hockney ρ predictions stay honest about the pruned wire
        let wire_row_bytes = match st_opt {
            Some(st)
                if st.total_rows > 0
                    && st.occupancy() < 1.0
                    && self.cfg.prune.active_for(st.occupancy()) =>
            {
                Some((base.unwrap_or(dense_row) * st.occupancy()).min(dense_row))
            }
            _ => base,
        };
        CombineShape {
            k: self.ctx.k,
            size: sub.size,
            passive_size: sub.passive_size(dag),
            active_size: sub.active_size(dag),
            remote_rows_per_step: self.plan.mean_remote_rows(),
            n_ranks: self.cfg.n_ranks,
            wire_row_bytes,
        }
    }

    /// The single CommMode → concrete-schedule translation (the forced
    /// `group_override` wins over any mode): returns the schedule, whether
    /// it pipelines, and the offsets-per-step `g` it realizes. Shared by
    /// [`Self::schedule`] and [`Self::decide_sub`] so the two can't drift.
    fn shape_of(&self, mode: CommMode) -> (Schedule, bool, usize) {
        let n_ranks = self.cfg.n_ranks;
        if let Some(g) = self.group_override {
            return (
                Schedule::ring(n_ranks, g),
                g < n_ranks.saturating_sub(1),
                g,
            );
        }
        match mode {
            CommMode::AllToAll => (
                Schedule::all_to_all(n_ranks),
                false,
                n_ranks.saturating_sub(1).max(1),
            ),
            CommMode::Pipeline { g } => (Schedule::ring(n_ranks, g), true, g),
        }
    }

    /// Decide the exchange shape of one subtemplate combine for the next
    /// iteration. Precedence: the `group_override` ablation hook, then —
    /// with `adaptive_group` on in the Adaptive/AdaptiveLB modes — the
    /// calibrated model sweep ([`AdaptivePolicy::choose_group`]), else the
    /// historical static per-template switch.
    fn decide_sub(
        &self,
        i: usize,
        cal: &GroupCalibration,
        storage_stats: &[Option<SubStorage>],
    ) -> SubDecision {
        let binom = &self.ctx.binom;
        let shape = self.combine_shape(i, storage_stats);
        let pol = self.cfg.policy.calibrated(cal);
        let adaptive = self.group_override.is_none()
            && self.cfg.adaptive_group
            && matches!(self.cfg.mode, ModeSelect::Adaptive | ModeSelect::AdaptiveLb);
        let (mode, pred) = if adaptive {
            let (mode, pred) = pol.choose_group(&self.tc, &shape, binom);
            (mode, Some(pred))
        } else {
            (self.cfg.comm_mode(self.tc.intensity), None)
        };
        let (schedule, pipelined, g) = self.shape_of(mode);
        let predicted_rho = if pipelined {
            pred.filter(|p| p.g == g)
                .map(|p| p.rho)
                .unwrap_or_else(|| pol.predict_group(&shape, g, binom).rho)
        } else {
            0.0
        };
        SubDecision {
            schedule,
            pipelined,
            g,
            predicted_rho,
        }
    }

    /// The template-level schedule under the *static* switch (or the
    /// forced override) — what every subtemplate gets when the adaptive
    /// sweep is off. Sweep-enabled runs decide per subtemplate inside
    /// `run()` (see `RunResult::comm_decisions`); this accessor
    /// deliberately reports the static shape.
    pub fn schedule(&self) -> (Schedule, bool) {
        let (schedule, pipelined, _) = self.shape_of(self.cfg.comm_mode(self.tc.intensity));
        (schedule, pipelined)
    }

    fn contract_backend(
        &self,
        out: &mut CountTable,
        passive: &CountTable,
        split: &crate::combin::SplitTable,
        scratch: &mut CombineScratch,
    ) -> u64 {
        match self.cfg.engine {
            EngineKind::Native => contract_touched(out, passive, split, scratch),
            EngineKind::Xla => match &self.xla {
                Some(x) => x.contract_touched(out, passive, split, scratch),
                None => contract_touched(out, passive, split, scratch),
            },
        }
    }

    /// Run the full estimation on the default in-process fabric; see
    /// [`RunResult`]. Infallible: the in-process mailbox cannot lose a
    /// peer, so any transport error here is a logic bug.
    pub fn run(&mut self) -> RunResult {
        let n_ranks = self.cfg.n_ranks;
        // capacity covers the deepest ring (P-1 steps) and the 1-step
        // all-to-all; ledger step slots are reserved per-exchange anyway
        let fabric = ThreadedFabric::for_run(n_ranks, n_ranks.max(1));
        let owned: Vec<usize> = (0..n_ranks).collect();
        match self.run_on(&fabric, &owned) {
            Ok(r) => r,
            Err(e) => panic!("in-process run cannot fail: {e}"),
        }
    }

    /// Run the full estimation over an explicit [`RankFabric`], computing
    /// only the ranks in `owned` locally. The in-process path owns all of
    /// them; in **process mode** each rank process passes its own single
    /// rank and a [`crate::comm::SocketFabric`] wired to its peers. The
    /// control flow — iteration loop, DAG order, per-subtemplate exchange
    /// decisions — is replicated deterministically on every participant,
    /// so the fabric only ever carries count rows plus (process mode) the
    /// per-iteration calibration allreduce that keeps every process's
    /// adaptive state bit-identical. Transport failures surface as
    /// [`HarpsgError::Transport`] instead of hanging the fold.
    pub fn run_on(
        &mut self,
        fabric: &dyn RankFabric,
        owned: &[usize],
    ) -> Result<RunResult, HarpsgError> {
        let wall = Instant::now();
        let n_ranks = self.cfg.n_ranks;
        assert_eq!(
            fabric.n_ranks(),
            n_ranks,
            "fabric sized for a different rank count"
        );
        assert!(!owned.is_empty(), "a participant must own at least one rank");
        let process_mode = owned.len() != n_ranks;
        let k = self.ctx.k;
        let n_subs = self.ctx.dag.subs.len();
        let last_use = self.ctx.dag.last_use();
        let eff_task = self.cfg.effective_task_size();
        // the parallel executor serves the native engine (and the XLA
        // stub fallback); only a *loaded* XLA runtime keeps the serial
        // scratch-based combine so its kernel sees the same buffers
        let use_exec = !(self.cfg.engine == EngineKind::Xla && self.xla.is_some());
        // the rank-parallel pipelined executor needs the combine executor
        // (per-rank nested pools); the serial-scratch XLA path falls back
        // to the sequential exchange
        let exec_threaded = use_exec && self.cfg.exchange == ExchangeExec::Threaded;
        // table storage: the serial-scratch XLA path views tables as
        // dense blocks, so a *loaded* XLA runtime forces the dense
        // policy; every other path honors the configured mode
        let storage_policy = if use_exec {
            StoragePolicy::of(self.cfg.table_storage)
        } else {
            StoragePolicy::dense()
        };
        let mut measured = ExecStats::zeros(self.cfg.n_workers);
        let mut pipe = MeasuredPipeline::new(n_ranks);

        // Exchange decisions are per subtemplate and per iteration: the
        // static modes (Alg 3 line 2) give every non-leaf sub the same
        // shape, while the adaptive sweep may pick a different g per sub
        // and recalibrate between iterations. The final iteration's
        // decisions are what the report carries.
        let non_leaf: Vec<usize> = self
            .ctx
            .dag
            .order
            .iter()
            .copied()
            .filter(|&i| !self.ctx.dag.subs[i].is_leaf())
            .collect();
        let mut cal = GroupCalibration::default();
        let mut decisions: Vec<Option<SubDecision>> = vec![None; n_subs];
        // per-sub storage outcome: `sub_storage` is this iteration's
        // record (the final iteration's survives into the report);
        // `last_storage` carries the latest known outcome per sub into
        // the next iteration's wire-byte model
        let mut sub_storage: Vec<SubStorage> = vec![SubStorage::default(); n_subs];
        let mut last_storage: Vec<Option<SubStorage>> = vec![None; n_subs];
        // per-sub measured overlap (threaded executor only): Σρ, count,
        // and the (pipelined, g) shape the measurements belong to —
        // calibration can change a sub's shape between iterations, and
        // ρ measured under a different g must not be paired with the
        // final shape's prediction
        let mut rho_meas_sum = vec![0.0f64; n_subs];
        let mut rho_meas_n = vec![0u64; n_subs];
        let mut rho_meas_shape: Vec<Option<(bool, usize)>> = vec![None; n_subs];
        // this iteration's (predicted ρ, measured ρ) feedback pairs
        let mut iter_feedback: Vec<(f64, f64)> = Vec::new();
        // this iteration's per-combine step measurements from the
        // threaded executor: (sub, predicted ρ, pipelined, per-step
        // (Σ comp_s, Σ wait_s) over the locally-owned ranks). Folded into
        // the measured-ρ accumulators at iteration end — *after* the
        // process-mode allreduce has globalized the sums, so every rank
        // process calibrates from identical values
        let mut iter_meas: Vec<(usize, f64, bool, Vec<(f64, f64)>)> = Vec::new();
        // units/seconds already folded into the calibration, so each
        // iteration feeds only its own delta (not the running mean —
        // the EWMA does the smoothing)
        let (mut fed_units, mut fed_compute) = (0.0f64, 0.0f64);
        if let Some(pr) = &self.progress {
            pr.on_run_start(self.cfg.n_iterations, n_subs);
        }

        let mut samples = Vec::with_capacity(self.cfg.n_iterations);
        let mut colorful = Vec::with_capacity(self.cfg.n_iterations);
        let mut records: Vec<SubRecord> = Vec::new();
        let mut mems: Vec<DualAccountant> =
            (0..n_ranks).map(|_| DualAccountant::new()).collect();
        // graph bytes each rank keeps resident, as the plan's storage
        // backend accounted them: an even share of the shared CSR when
        // resident, the rank's own partition-proportional slice when
        // sharded (`--graph-storage mmap`) — distinct ledger classes so
        // Fig-12 style breakdowns can tell the two apart
        let graph_class = if self.plan.graph_storage == "mmap" {
            MemClass::GraphShard
        } else {
            MemClass::Graph
        };
        for &p in owned {
            mems[p].alloc(graph_class, self.plan.graph_bytes_per_rank[p]);
        }
        let mut total_units = 0.0f64;
        let mut real_compute = 0.0f64;
        let mut hist_units: Vec<f64> = vec![0.0; self.cfg.n_threads + 1];
        let mut busy_units = 0.0f64;

        let max_agg = self
            .ctx
            .dag
            .subs
            .iter()
            .filter(|s| !s.is_leaf())
            .map(|s| self.ctx.binom.c(k, s.active_size(&self.ctx.dag)) as usize)
            .max()
            .unwrap_or(1);

        for it in 0..self.cfg.n_iterations {
            if let Some(pr) = &self.progress {
                pr.on_iteration(it, self.cfg.n_iterations);
            }
            // (re)decide every combine's exchange shape with the current
            // calibration — iteration 0 uses the configured policy, later
            // iterations fold in the measured flop time and overlap. A
            // shape change discards the ρ measured under the old shape.
            for &i in &non_leaf {
                let d = self.decide_sub(i, &cal, &last_storage);
                let shape_key = Some((d.pipelined, d.g));
                if rho_meas_shape[i] != shape_key {
                    rho_meas_shape[i] = shape_key;
                    rho_meas_sum[i] = 0.0;
                    rho_meas_n[i] = 0;
                }
                decisions[i] = Some(d);
            }
            for s in sub_storage.iter_mut() {
                *s = SubStorage::default();
            }
            let iter_seed = crate::util::mix2(self.cfg.seed, it as u64);
            let coloring = Coloring::random(self.g.n_vertices(), k, iter_seed);
            let mut tables: Vec<Vec<Option<TableStorage>>> = vec![vec![None; n_subs]; n_ranks];
            // per-vertex scratch rows only back the serial XLA path; the
            // executor keeps its own per-task partials (the `Scratch`
            // memory accounting below models either)
            let mut scratches: Vec<CombineScratch> = if use_exec {
                Vec::new()
            } else {
                (0..n_ranks)
                    .map(|p| CombineScratch::new(self.plan.part.n_local(p), max_agg))
                    .collect()
            };
            for &p in owned {
                mems[p].alloc(
                    MemClass::Scratch,
                    (self.plan.part.n_local(p) * max_agg * std::mem::size_of::<Count>()) as u64,
                );
            }

            for (order_pos, &i) in self.ctx.dag.order.clone().iter().enumerate() {
                let sub = self.ctx.dag.subs[i].clone();
                if sub.is_leaf() {
                    for &p in owned {
                        let t = init_leaf_table(&self.plan.part.locals[p], &coloring);
                        mems[p].alloc(MemClass::CountTable, t.bytes());
                        let stored =
                            store_table(&storage_policy, t, &mut mems[p], &mut sub_storage[i]);
                        tables[p][i] = Some(stored);
                    }
                    last_storage[i] = Some(sub_storage[i]);
                } else {
                    let dec = decisions[i].as_ref().expect("sub decided this iteration");
                    let (rec, step_meas) = if exec_threaded {
                        self.combine_subtemplate_threaded(
                            fabric,
                            owned,
                            i,
                            dec,
                            &storage_policy,
                            &mut sub_storage[i],
                            &mut tables,
                            &mut mems,
                            &mut total_units,
                            &mut real_compute,
                            &mut hist_units,
                            &mut busy_units,
                            eff_task,
                            it,
                            &mut measured,
                            &mut pipe,
                        )?
                    } else {
                        let rec = self.combine_subtemplate(
                            fabric,
                            owned,
                            i,
                            dec,
                            &storage_policy,
                            &mut sub_storage[i],
                            &mut tables,
                            &mut scratches,
                            &mut mems,
                            &mut total_units,
                            &mut real_compute,
                            &mut hist_units,
                            &mut busy_units,
                            eff_task,
                            it,
                            use_exec,
                            &mut measured,
                        )?;
                        (rec, Vec::new())
                    };
                    last_storage[i] = Some(sub_storage[i]);
                    if !step_meas.is_empty() {
                        iter_meas.push((i, dec.predicted_rho, dec.pipelined, step_meas));
                    }
                    records.push(rec);
                }
                // free tables whose last reader has run
                for (j, lu) in last_use.iter().enumerate() {
                    if *lu == order_pos && j != self.ctx.dag.root {
                        for &p in owned {
                            if let Some(t) = tables[p][j].take() {
                                mems[p].free2(MemClass::CountTable, t.bytes(), t.dense_bytes());
                            }
                        }
                    }
                }
            }

            // Alg 2 line 22: colorful count over the locally-owned ranks
            // (the global count in-process; the rank's partial in process
            // mode, where the launcher sums the per-process partials)
            let total: f64 = owned
                .iter()
                .map(|&p| tables[p][self.ctx.dag.root].as_ref().unwrap().total())
                .sum();
            colorful.push(total);
            samples.push(total * self.ctx.colorful_scale() / self.ctx.aut as f64);

            for &p in owned {
                if let Some(t) = tables[p][self.ctx.dag.root].take() {
                    mems[p].free2(MemClass::CountTable, t.bytes(), t.dense_bytes());
                }
                mems[p].free(
                    MemClass::Scratch,
                    (self.plan.part.n_local(p) * max_agg * std::mem::size_of::<Count>()) as u64,
                );
            }

            // the runtime feedback loop: this iteration's measured
            // seconds-per-unit (the delta, not the running mean) and its
            // predicted-vs-measured overlap pairs recalibrate the model
            // before the next iteration's decisions (adaptive sweep only —
            // the static modes never read `cal`).
            //
            // In process mode the raw measurements are first allreduced
            // over the rank processes (deterministic ascending-rank
            // summation on every participant): divergent calibrations —
            // or divergent storage statistics feeding the wire-byte
            // model — would make processes choose different schedules
            // next iteration, which deadlocks the exchange. The same
            // round carries each process's measured link fit, whose
            // average replaces the simulated Hockney α/β (the paper's
            // calibration loop fed wall-clock timings). The round runs
            // in every mode, not just the adaptive sweep, so storage
            // decisions, measured ρ, and the merged report are globally
            // identical however the work is sliced across processes.
            let mut du = total_units - fed_units;
            let mut dc = real_compute - fed_compute;
            if process_mode {
                let global = allreduce_calibration(
                    fabric,
                    owned,
                    du,
                    dc,
                    fabric.measured_link(),
                    &sub_storage,
                    &iter_meas,
                )?;
                du = global.du;
                dc = global.dc;
                for (j, st) in global.storage.iter().enumerate() {
                    if st.n_ranks > 0 {
                        sub_storage[j] = *st;
                        last_storage[j] = Some(*st);
                    }
                }
                for (meas, entry) in global.step_meas.iter().zip(iter_meas.iter_mut()) {
                    entry.3 = meas.clone();
                }
                // only the adaptive sweep feeds the measured fit back into
                // the Hockney parameters (the paper's calibration loop);
                // static modes keep the configured α/β so their decisions
                // stay bit-identical to the in-process fabric's
                if self.cfg.adaptive_group {
                    if let Some((alpha, beta)) = global.link {
                        self.cfg.net.alpha = alpha;
                        self.cfg.net.beta = beta;
                        self.cfg.policy.net.alpha = alpha;
                        self.cfg.policy.net.beta = beta;
                    }
                }
            }
            // fold this iteration's measured mean ρ per combine, over the
            // overlap-capable steps (step 0's wait can never be hidden —
            // same convention as `MeasuredPipeline::mean_rho`)
            for (j, predicted, pipelined, steps_m) in iter_meas.drain(..) {
                if steps_m.len() > 1 {
                    let mut sum = 0.0;
                    for &(comp, wait) in &steps_m[1..] {
                        let tot = comp + wait;
                        sum += if tot <= 0.0 { 1.0 } else { comp / tot };
                    }
                    let r = sum / (steps_m.len() - 1) as f64;
                    rho_meas_sum[j] += r;
                    rho_meas_n[j] += 1;
                    if pipelined {
                        iter_feedback.push((predicted, r));
                    }
                }
            }
            if self.cfg.adaptive_group {
                if du > 0.0 {
                    cal.observe_flop_time((dc / du).max(1e-12));
                }
                fed_units = total_units;
                fed_compute = real_compute;
                // one damped calibration step per iteration, not per
                // combine: geometric-mean the iteration's pairs first so
                // feedback strength doesn't scale with subtemplate count
                if !iter_feedback.is_empty() {
                    let n = iter_feedback.len() as f64;
                    let (mut lp, mut lm) = (0.0f64, 0.0f64);
                    for (pred, meas) in iter_feedback.drain(..) {
                        lp += pred.clamp(0.05, 1.0).ln();
                        lm += meas.clamp(0.05, 1.0).ln();
                    }
                    cal.observe_rho((lp / n).exp(), (lm / n).exp());
                }
            } else {
                iter_feedback.clear();
            }
        }

        // ---- calibration & model conversion ----
        // The model clock converts Eq-4 units with the *fixed* per-unit
        // cost from the policy (the paper-engine cost shape): using the
        // measured per-unit time instead would make the conversion depend
        // on which mode ran (per-step contraction makes our real engine's
        // work mode-dependent), breaking cross-mode comparability. The
        // measured value is still reported in `RunResult::flop_time`.
        let flop_time = self.cfg.policy.flop_time;
        let measured_flop_time = if total_units > 0.0 {
            (real_compute / total_units).max(1e-12)
        } else {
            flop_time
        };
        let mut model = ModelTime::default();
        for rec in &records {
            // local phase: the barrier waits for the slowest rank; the
            // difference to the mean is straggler wait, which the paper's
            // instrumentation books as communication (Eq 8-9)
            let local_max = rec.local_makespan.iter().copied().fold(0.0, f64::max) * flop_time;
            let local_mean = rec.local_makespan.iter().sum::<f64>()
                / rec.local_makespan.len().max(1) as f64
                * flop_time;
            let timings: Vec<Vec<StepTiming>> = rec
                .steps
                .iter()
                .map(|row| {
                    row.iter()
                        .map(|&(units, comm)| StepTiming {
                            comp: units * flop_time,
                            comm,
                        })
                        .collect()
                })
                .collect();
            let report: PipelineReport = if rec.pipelined {
                pipelined(&timings)
            } else {
                naive(&timings)
            };
            model.total += local_max + report.makespan;
            model.comp += local_mean + report.comp_total;
            model.comm_total += report.comm_total;
            model.comm_exposed += (local_max - local_mean) + report.comm_exposed;
            model.straggler += (local_max - local_mean) + report.straggler;
            model.rho_by_sub.push((rec.sub, report.mean_rho()));
        }
        // per-iteration averages
        let iters = self.cfg.n_iterations.max(1) as f64;
        model.total /= iters;
        model.comp /= iters;
        model.comm_total /= iters;
        model.comm_exposed /= iters;
        model.straggler /= iters;

        let estimate = median_of_means(&samples, 3.min(samples.len()));
        let peak_mem_per_rank: Vec<u64> = mems.iter().map(|m| m.real.peak).collect();
        let peak_mem_dense_per_rank: Vec<u64> = mems.iter().map(|m| m.dense.peak).collect();
        let oom = match self.cfg.mem_limit {
            Some(lim) => peak_mem_per_rank.iter().any(|&b| b > lim),
            None => false,
        };
        let total_hist: f64 = hist_units.iter().sum();
        // the report's per-subtemplate decisions: the final iteration's
        // shapes, with the run's mean measured overlap next to each
        // (a zero-iteration run never filled them — report the initial
        // decisions instead of panicking, like the historical path)
        for &i in &non_leaf {
            if decisions[i].is_none() {
                decisions[i] = Some(self.decide_sub(i, &cal, &last_storage));
            }
        }
        // the report's per-subtemplate storage outcomes: the final
        // iteration's measured densities, chosen representations and
        // resident-vs-dense byte deltas (subs that never built a table —
        // zero-iteration runs — are omitted)
        let storage_decisions: Vec<StorageDecision> = sub_storage
            .iter()
            .enumerate()
            .filter(|(_, st)| st.n_ranks > 0)
            .map(|(i, st)| StorageDecision {
                sub: i,
                density: st.density(),
                sparse_ranks: st.sparse_ranks,
                n_ranks: st.n_ranks,
                dense_bytes: st.dense_bytes,
                resident_bytes: st.resident_bytes,
            })
            .collect();
        // the report's per-subtemplate pruning outcomes: the final
        // iteration's frontier occupancy and skip tallies, globalized by
        // the same allreduce as the storage record
        let prune_stats: Vec<PruneStats> = sub_storage
            .iter()
            .enumerate()
            .filter(|(_, st)| st.n_ranks > 0)
            .map(|(i, st)| PruneStats {
                sub: i,
                frontier_occupancy: st.occupancy(),
                pairs_skipped: st.pairs_skipped,
                rows_skipped: st.rows_skipped,
                wire_rows_dropped: st.wire_rows_dropped,
            })
            .collect();
        let comm_decisions: Vec<CommDecision> = non_leaf
            .iter()
            .map(|&i| {
                let d = decisions[i].as_ref().expect("sub decided");
                CommDecision {
                    sub: i,
                    pipelined: d.pipelined,
                    g: d.g,
                    n_steps: d.schedule.n_steps(),
                    predicted_rho: d.predicted_rho,
                    // only meaningful when the *final* shape pipelines:
                    // calibration can flip a sub to all-to-all after a
                    // ring iteration already measured ρ, and the report
                    // contract keeps rho_meas null for single-step shapes
                    measured_rho: if d.pipelined && rho_meas_n[i] > 0 {
                        Some(rho_meas_sum[i] / rho_meas_n[i] as f64)
                    } else {
                        None
                    },
                }
            })
            .collect();
        if let Some(pr) = &self.progress {
            pr.on_run_end();
        }
        // the fabric's measured link fit (socket fabrics OLS-fit their
        // wall-clock send timings; the in-process mailbox reports none),
        // attributed to every locally-owned rank for the merged report
        let link: Vec<RankLink> = match fabric.measured_link() {
            Some(l) => owned
                .iter()
                .map(|&p| RankLink {
                    rank: p,
                    alpha_s: l.alpha_s,
                    beta_s_per_byte: l.beta_s_per_byte,
                    samples: l.samples,
                })
                .collect(),
            None => Vec::new(),
        };
        Ok(RunResult {
            estimate,
            samples,
            colorful,
            model,
            real_seconds: wall.elapsed().as_secs_f64(),
            peak_mem_per_rank,
            peak_mem_dense_per_rank,
            storage: storage_decisions,
            prune: prune_stats,
            flop_time: measured_flop_time,
            threads: ThreadStats {
                avg_concurrency: if total_hist > 0.0 {
                    busy_units / total_hist
                } else {
                    0.0
                },
                concurrency_histogram: hist_units.iter().map(|&u| u * flop_time).collect(),
            },
            comm_decisions,
            workers: measured,
            measured: if exec_threaded { Some(pipe) } else { None },
            oom,
            graph_storage: self.plan.graph_storage.to_string(),
            graph_resident_per_rank: self.plan.graph_bytes_per_rank.clone(),
            link,
        })
    }

    /// One non-leaf subtemplate combine across all ranks: local phase, then
    /// the scheduled exchange. Real counting runs on the parallel combine
    /// executor (`colorcount::parallel`, `cfg.n_workers` threads) unless a
    /// loaded XLA runtime keeps the serial scratch path — `use_exec` is
    /// decided once in `run()`, which also sizes `scratches` to match and
    /// forces the dense storage policy for that path; `measured`
    /// accumulates the executor's per-worker record. The finished output
    /// tables are stored per `policy` (dense or sparse, from measured
    /// density), with the outcome recorded in `store_rec`. Runs the
    /// locally-owned ranks against the given fabric — in process mode
    /// step `w` is fully posted before it drains, so the sequential fold
    /// never deadlocks against the peer processes running the same loop.
    /// Returns the model record.
    #[allow(clippy::too_many_arguments)]
    fn combine_subtemplate(
        &mut self,
        fabric: &dyn RankFabric,
        owned: &[usize],
        i: usize,
        dec: &SubDecision,
        policy: &StoragePolicy,
        store_rec: &mut SubStorage,
        tables: &mut [Vec<Option<TableStorage>>],
        scratches: &mut [CombineScratch],
        mems: &mut [DualAccountant],
        total_units: &mut f64,
        real_compute: &mut f64,
        hist_units: &mut [f64],
        busy_units: &mut f64,
        eff_task: u32,
        iteration: usize,
        use_exec: bool,
        measured: &mut ExecStats,
    ) -> FabricResult<SubRecord> {
        let n_ranks = self.cfg.n_ranks;
        let sub = self.ctx.dag.subs[i].clone();
        let split = self.ctx.splits[i].clone().expect("non-leaf split");
        let a2_sets = self.ctx.binom.c(self.ctx.k, sub.active_size(&self.ctx.dag)) as usize;
        let pass_idx = sub.passive.unwrap();
        let act_idx = sub.active.unwrap();
        let schedule = &dec.schedule;
        let is_pipelined = dec.pipelined;
        if let Some(pr) = &self.progress {
            pr.on_subtemplate_start(i, schedule.n_steps(), is_pipelined);
        }
        // Model-clock cost units follow the paper's Eq 4: each neighbor
        // pair costs C(k,|Ti|)·C(|Ti|,|Ti'|) — the Harp-DAAL/FASCIA
        // per-neighbor DP loop whose thread behaviour Fig 11 measures.
        // (Our *real* engine uses the factored combine, which is cheaper
        // and better balanced — that improvement is reported on the real
        // clock and in EXPERIMENTS.md §Perf, not silently mixed into the
        // paper-shape figures.)
        let pair_units = (split.n_sets * split.n_splits) as f64;
        let cost_model = TaskCostModel {
            unit_per_pair: pair_units,
            unit_per_task: 0.0,
            overhead: self.cfg.task_overhead_units,
        };
        // frontier layer: per-rank pruning gates over the finalized child
        // tables (the `--prune` knob). The serial-scratch XLA path
        // streams everything — its kernel owns the unpruned combine — so
        // pruning rides the executor paths only.
        let prune = if use_exec { self.cfg.prune } else { PruneMode::Off };

        // allocate outputs (zero-row placeholders for ranks other
        // processes own — they are never written or stored)
        let mut owned_mask = vec![false; n_ranks];
        for &p in owned {
            owned_mask[p] = true;
        }
        let mut act_fronts: Vec<Option<Frontier>> = vec![None; n_ranks];
        let mut pass_fronts: Vec<Option<Frontier>> = vec![None; n_ranks];
        for &p in owned {
            act_fronts[p] = table_frontier_for(tables[p][act_idx].as_ref().unwrap(), prune);
            pass_fronts[p] = table_frontier_for(tables[p][pass_idx].as_ref().unwrap(), prune);
        }
        let mut outs: Vec<CountTable> = (0..n_ranks)
            .map(|p| {
                let rows = if owned_mask[p] {
                    self.plan.part.n_local(p)
                } else {
                    0
                };
                CountTable::zeros(rows, split.n_sets)
            })
            .collect();
        for &p in owned {
            mems[p].alloc(MemClass::CountTable, outs[p].bytes());
        }

        let shuffle_seed =
            |p: usize, w: usize| model_shuffle_seed(self.cfg.seed, iteration, i, p, w, eff_task);

        // ---- local phase ----
        // NB: `pass_idx` may equal `act_idx` (deduplicated shapes, e.g. a
        // P2 splitting into leaf+leaf), so borrow immutably.
        let mut local_makespan: Vec<f64> = Vec::with_capacity(owned.len());
        for &p in owned {
            let t0 = Instant::now();
            let active = tables[p][act_idx].as_ref().unwrap();
            let passive = tables[p][pass_idx].as_ref().unwrap();
            let pairs = prune_pairs(
                &self.plan.local_pairs[p],
                act_fronts[p].as_ref(),
                &mut store_rec.pairs_skipped,
            );
            let n_pairs = if use_exec {
                let batch = [PairBatch {
                    pairs: &pairs[..],
                    rows: active.as_rows(),
                }];
                let st = combine_batches_pruned(
                    &mut outs[p],
                    passive.as_rows(),
                    &split,
                    &batch,
                    eff_task,
                    self.cfg.n_workers,
                    self.cfg.kernel,
                    pass_fronts[p].as_ref(),
                    Some(&cost_model),
                );
                let n = st.n_pairs;
                store_rec.rows_skipped += st.rows_skipped;
                measured.merge(&st);
                n
            } else {
                scratches[p].begin(a2_sets);
                let n = aggregate_batch(&mut scratches[p], active.as_rows(), pairs.iter().copied());
                let _ = self.contract_backend(
                    &mut outs[p],
                    passive.as_dense(),
                    &split,
                    &mut scratches[p],
                );
                n
            };
            let dt = t0.elapsed().as_secs_f64();
            *total_units += n_pairs as f64 * pair_units;
            *real_compute += dt;
            // thread-level replay over Alg-4 tasks (frontier-effective
            // degrees: the pruned pair list is what the queue covers)
            let mut degs = vec![0u32; self.plan.part.n_local(p)];
            for &(v, _) in pairs.iter() {
                degs[v as usize] += 1;
            }
            let tasks = make_tasks(&degs, eff_task, shuffle_seed(p, usize::MAX));
            let costs: Vec<f64> = tasks.iter().map(|t| cost_model.cost(t)).collect();
            let rep = replay(&costs, self.cfg.n_threads, self.cfg.phys_cores);
            local_makespan.push(rep.makespan);
            for (c, t) in rep.concurrency_histogram.iter().enumerate() {
                hist_units[c.min(hist_units.len() - 1)] += t;
                *busy_units += c as f64 * t;
            }
        }

        // ---- exchange phase ----
        // step `w` is fully posted for every owned rank before any rank
        // drains it, so the canonical (sender, seq) drain returns the
        // exact fold order the historical arrival-order drain produced
        fabric.begin_exchange(schedule.n_steps());
        let mut steps: Vec<Vec<(f64, f64)>> = Vec::with_capacity(schedule.n_steps());
        for (w, plans_w) in schedule.plans.iter().enumerate() {
            // send: rows the receivers requested from us, in the active
            // table's own encoding (the shared codec seam); with pruning
            // active the masked encoder drops frontier-dead rows
            for &p in owned {
                let active = tables[p][act_idx].as_ref().unwrap();
                let pruned_wire = act_fronts[p].is_some();
                for &q in &plans_w[p].send_to {
                    let payload = encode_request_rows(active, &self.plan, p, q, pruned_wire);
                    store_rec.wire_rows_dropped += payload.rows_dropped();
                    fabric.send(Packet::with_payload(p, q, w, i, a2_sets, payload))?;
                }
            }
            // receive + fold
            let mut step_row: Vec<(f64, f64)> = Vec::with_capacity(owned.len());
            for &p in owned {
                let packets = fabric.recv_step(p, w, plans_w[p].recv_from.len())?;
                let mut recv_bytes = 0u64;
                let mut recv_dense_bytes = 0u64;
                let n_msgs = packets.len();
                // view the received row blocks as tables by *moving* each
                // packet's payload — receiving never copies a row; sparse
                // payloads stay sparse straight into the fold
                let mut bufs: Vec<(usize, TableStorage)> = Vec::with_capacity(packets.len());
                for pkt in packets {
                    let bytes = pkt.bytes();
                    recv_bytes += bytes;
                    recv_dense_bytes += pkt.dense_equiv_bytes();
                    mems[p].alloc2(MemClass::RecvBuffer, bytes, pkt.dense_equiv_bytes());
                    let q = pkt.sender();
                    bufs.push((q, TableStorage::from_payload(pkt.payload, a2_sets)));
                }
                // the received buffers are this step's active rows: prune
                // each sender's fold pairs by its buffer's own frontier
                let pair_lists: Vec<Cow<[(u32, u32)]>> = bufs
                    .iter()
                    .map(|(q, buf)| {
                        prune_pairs(
                            &self.plan.plans[p][*q],
                            table_frontier_for(buf, prune).as_ref(),
                            &mut store_rec.pairs_skipped,
                        )
                    })
                    .collect();
                let mut degs = vec![0u32; self.plan.part.n_local(p)];
                for pl in &pair_lists {
                    for &(v, _) in pl.iter() {
                        degs[v as usize] += 1;
                    }
                }
                let t0 = Instant::now();
                let passive = tables[p][pass_idx].as_ref().unwrap();
                let n_pairs = if use_exec {
                    let batches: Vec<PairBatch> = bufs
                        .iter()
                        .zip(&pair_lists)
                        .map(|((_, buf), pl)| PairBatch {
                            pairs: pl.as_ref(),
                            rows: buf.as_rows(),
                        })
                        .collect();
                    let st = combine_batches_pruned(
                        &mut outs[p],
                        passive.as_rows(),
                        &split,
                        &batches,
                        eff_task,
                        self.cfg.n_workers,
                        self.cfg.kernel,
                        pass_fronts[p].as_ref(),
                        Some(&cost_model),
                    );
                    let n = st.n_pairs;
                    store_rec.rows_skipped += st.rows_skipped;
                    measured.merge(&st);
                    n
                } else {
                    scratches[p].begin(a2_sets);
                    let mut n = 0u64;
                    for ((_, buf), pl) in bufs.iter().zip(&pair_lists) {
                        n += aggregate_batch(&mut scratches[p], buf.as_rows(), pl.iter().copied());
                    }
                    let _ = self.contract_backend(
                        &mut outs[p],
                        passive.as_dense(),
                        &split,
                        &mut scratches[p],
                    );
                    n
                };
                let dt = t0.elapsed().as_secs_f64();
                *total_units += n_pairs as f64 * pair_units;
                *real_compute += dt;
                // pipelined mode frees the step slice immediately; the
                // naive bulk exchange keeps every slice until the combine
                // ends (Fig 12's contrast)
                if is_pipelined {
                    mems[p].free2(MemClass::RecvBuffer, recv_bytes, recv_dense_bytes);
                }
                let tasks = make_tasks(&degs, eff_task, shuffle_seed(p, w));
                let costs: Vec<f64> = tasks.iter().map(|t| cost_model.cost(t)).collect();
                let rep = replay(&costs, self.cfg.n_threads, self.cfg.phys_cores);
                for (c, t) in rep.concurrency_histogram.iter().enumerate() {
                    hist_units[c.min(hist_units.len() - 1)] += t;
                    *busy_units += c as f64 * t;
                }
                let comm = self.cfg.net.step(n_msgs, recv_bytes).max(self.cfg.net.step(
                    plans_w[p].send_to.len(),
                    fabric.ledger().sent_bytes(p, w),
                ));
                step_row.push((rep.makespan, comm));
            }
            steps.push(step_row);
            if let Some(pr) = &self.progress {
                pr.on_exchange_step(i, w, schedule.n_steps());
            }
        }
        fabric.assert_empty();
        // bulk mode: release all receive buffers now
        if !is_pipelined {
            for &p in owned {
                mems[p].release_all(MemClass::RecvBuffer);
            }
        }

        for (p, o) in outs.into_iter().enumerate() {
            if owned_mask[p] {
                let stored = store_table(policy, o, &mut mems[p], store_rec);
                tables[p][i] = Some(stored);
            }
        }
        if let Some(pr) = &self.progress {
            pr.on_subtemplate_done(i);
        }

        Ok(SubRecord {
            sub: i,
            local_makespan,
            steps,
            pipelined: is_pipelined,
        })
    }

    /// One non-leaf combine on the **rank-parallel pipelined executor**:
    /// every simulated rank runs on its own scoped thread against the
    /// thread-safe [`ThreadedFabric`], executing the paper's Fig-3
    /// schedule for real — at step `w` a rank first posts its sends, then
    /// folds step `w-1`'s received rows while `w`'s packets arrive from
    /// the other rank threads. Received payloads are moved (never cloned)
    /// into the fold and released the moment the step's combine finishes,
    /// so a rank's `RecvBuffer` high-water mark is genuinely one step's
    /// slice.
    ///
    /// Estimates are bit-identical to [`Self::combine_subtemplate`]: the
    /// fabric delivers each step's packets in canonical (sender, seq)
    /// order — the exact fold order of the sequential loop — and the
    /// combine executor is worker-count-invariant, so neither the thread
    /// interleaving nor the per-rank [`nested_budget`] pool width can
    /// move a bit (`tests/pipeline_exec.rs` enforces this).
    ///
    /// Returns the model record plus the per-step measured
    /// `(Σ comp_s, Σ wait_s)` over the locally-owned ranks — the caller
    /// folds those into the measured-ρ accumulators at iteration end
    /// (after the process-mode allreduce globalizes them); the *measured*
    /// overlap (real per-step ρ, blocked wait, per-rank receive peaks)
    /// also accumulates into `pipe`.
    #[allow(clippy::too_many_arguments)]
    fn combine_subtemplate_threaded(
        &mut self,
        fabric: &dyn RankFabric,
        owned: &[usize],
        i: usize,
        dec: &SubDecision,
        policy: &StoragePolicy,
        store_rec: &mut SubStorage,
        tables: &mut [Vec<Option<TableStorage>>],
        mems: &mut [DualAccountant],
        total_units: &mut f64,
        real_compute: &mut f64,
        hist_units: &mut [f64],
        busy_units: &mut f64,
        eff_task: u32,
        iteration: usize,
        measured: &mut ExecStats,
        pipe: &mut MeasuredPipeline,
    ) -> FabricResult<(SubRecord, Vec<(f64, f64)>)> {
        let n_ranks = self.cfg.n_ranks;
        let sub = self.ctx.dag.subs[i].clone();
        let split = self.ctx.splits[i].clone().expect("non-leaf split");
        let a2_sets = self.ctx.binom.c(self.ctx.k, sub.active_size(&self.ctx.dag)) as usize;
        let pass_idx = sub.passive.unwrap();
        let act_idx = sub.active.unwrap();
        let schedule = &dec.schedule;
        let is_pipelined = dec.pipelined;
        let n_steps = schedule.n_steps();
        if let Some(pr) = &self.progress {
            pr.on_subtemplate_start(i, n_steps, is_pipelined);
        }
        let cost_model = TaskCostModel {
            unit_per_pair: (split.n_sets * split.n_splits) as f64,
            unit_per_task: 0.0,
            overhead: self.cfg.task_overhead_units,
        };

        let mut owned_mask = vec![false; n_ranks];
        for &p in owned {
            owned_mask[p] = true;
        }
        let mut outs: Vec<CountTable> = (0..n_ranks)
            .map(|p| {
                let rows = if owned_mask[p] {
                    self.plan.part.n_local(p)
                } else {
                    0
                };
                CountTable::zeros(rows, split.n_sets)
            })
            .collect();
        for &p in owned {
            mems[p].alloc(MemClass::CountTable, outs[p].bytes());
        }

        fabric.begin_exchange(n_steps);
        // the worker pool splits across the rank threads *this process*
        // runs (all of them in-process; one in process mode)
        let nested = nested_budget(self.cfg.n_workers, owned.len());
        let notify = StepNotifier::new(self.progress.clone(), i, n_steps, owned.len());
        let env = RankEnv {
            sub: i,
            iteration,
            eff_task,
            a2_sets,
            act_idx,
            pass_idx,
            nested,
            kernel: self.cfg.kernel,
            prune: self.cfg.prune,
            n_threads: self.cfg.n_threads,
            phys_cores: self.cfg.phys_cores,
            seed: self.cfg.seed,
            net: self.cfg.net,
            cost_model,
            plan: &self.plan,
            schedule,
            split: &split,
            fabric,
            notify: &notify,
        };

        let logs: Vec<(usize, FabricResult<RankLog>)> = std::thread::scope(|s| {
            let handles: Vec<_> = outs
                .iter_mut()
                .zip(mems.iter_mut())
                .zip(tables.iter())
                .enumerate()
                .filter(|(p, _)| owned_mask[*p])
                .map(|(p, ((out, mem), rank_tables))| {
                    let env = &env;
                    (
                        p,
                        s.spawn(move || rank_exchange_worker(env, p, rank_tables, out, mem)),
                    )
                })
                .collect();
            handles
                .into_iter()
                .map(|(p, h)| (p, h.join().expect("rank exchange worker panicked")))
                .collect()
        });
        let mut rank_logs: Vec<(usize, RankLog)> = Vec::with_capacity(logs.len());
        for (p, lg) in logs {
            rank_logs.push((p, lg?));
        }
        fabric.assert_empty();
        pipe.observe_in_flight_peak(fabric.ledger().in_flight_peak());

        // deterministic reduction in owned-rank order (0..P in-process)
        // regardless of which thread finished first
        let mut local_makespan: Vec<f64> = Vec::with_capacity(rank_logs.len());
        let mut steps: Vec<Vec<(f64, f64)>> = vec![Vec::with_capacity(rank_logs.len()); n_steps];
        let mut step_comp = vec![0.0f64; n_steps];
        let mut step_wait = vec![0.0f64; n_steps];
        for (idx, (p, lg)) in rank_logs.into_iter().enumerate() {
            local_makespan.push(lg.local_makespan);
            for (w, st) in lg.steps.iter().enumerate() {
                steps[w].push((st.makespan_units, st.comm_s));
                step_comp[w] += st.comp_s;
                step_wait[w] += st.wait_s;
            }
            *total_units += lg.units;
            *real_compute += lg.real_compute;
            for (c, t) in lg.hist.iter().enumerate() {
                hist_units[c.min(hist_units.len() - 1)] += t;
            }
            *busy_units += lg.busy_units;
            store_rec.pairs_skipped += lg.pairs_skipped;
            store_rec.rows_skipped += lg.stats.rows_skipped;
            store_rec.wire_rows_dropped += lg.wire_rows_dropped;
            // each owned rank's nested lanes land at their own offset so
            // genuinely concurrent threads stay distinct in the record
            measured.absorb_at(&lg.stats, idx * nested);
            pipe.observe_rank(p, lg.recv_peak, lg.max_step_recv_bytes);
        }
        for w in 0..n_steps {
            pipe.add_step(
                w,
                step_comp[w] / owned.len() as f64,
                step_wait[w] / owned.len() as f64,
            );
        }
        pipe.finish_combine();

        for (p, o) in outs.into_iter().enumerate() {
            if owned_mask[p] {
                let stored = store_table(policy, o, &mut mems[p], store_rec);
                tables[p][i] = Some(stored);
            }
        }
        // per-step notifications already streamed live via `StepNotifier`
        if let Some(pr) = &self.progress {
            pr.on_subtemplate_done(i);
        }

        let step_meas: Vec<(f64, f64)> =
            (0..n_steps).map(|w| (step_comp[w], step_wait[w])).collect();
        Ok((
            SubRecord {
                sub: i,
                local_makespan,
                steps,
                pipelined: is_pipelined,
            },
            step_meas,
        ))
    }
}

/// Everything a rank worker thread reads (immutably) during one threaded
/// combine; one instance is shared by all rank threads of the combine.
struct RankEnv<'a> {
    /// subtemplate index
    sub: usize,
    iteration: usize,
    eff_task: u32,
    a2_sets: usize,
    act_idx: usize,
    pass_idx: usize,
    /// per-rank nested combine-pool width ([`nested_budget`])
    nested: usize,
    /// combine-kernel choice (the `--kernel` knob)
    kernel: KernelMode,
    /// frontier-pruning mode (the `--prune` knob)
    prune: PruneMode,
    n_threads: usize,
    phys_cores: usize,
    seed: u64,
    net: HockneyParams,
    cost_model: TaskCostModel,
    plan: &'a ExchangePlan,
    schedule: &'a Schedule,
    split: &'a SplitTable,
    fabric: &'a dyn RankFabric,
    notify: &'a StepNotifier,
}

/// One rank's model + measured record for one exchange step.
struct RankStepLog {
    /// thread-replay makespan of the step's fold, compute units
    makespan_units: f64,
    /// Hockney-modeled transfer seconds (same formula as the sequential
    /// executor: max of the receive and send sides)
    comm_s: f64,
    /// measured wall seconds folding the step's rows
    comp_s: f64,
    /// measured wall seconds blocked waiting for the step's packets
    wait_s: f64,
}

/// Everything one rank worker brings home from one threaded combine.
struct RankLog {
    local_makespan: f64,
    steps: Vec<RankStepLog>,
    units: f64,
    real_compute: f64,
    hist: Vec<f64>,
    busy_units: f64,
    stats: ExecStats,
    /// `(v, u)` pairs dropped because `u`'s active row was frontier-dead
    pairs_skipped: u64,
    /// rows elided from this rank's outgoing wire payloads by the masked
    /// encoding
    wire_rows_dropped: u64,
    /// high-water mark of this rank's `RecvBuffer` bytes
    recv_peak: u64,
    /// largest single step's received bytes (the streaming bound)
    max_step_recv_bytes: u64,
}

/// The Alg-4 shuffle seed for the *model* task queue of one (iteration,
/// subtemplate, rank, step) cell — the single definition both executors
/// share, so their modeled queues match bit-for-bit. `None` disables
/// shuffling at per-vertex granularity. NB: the local phase passes
/// `usize::MAX` as its step slot, whose all-ones bits OR over the other
/// fields — every local phase therefore shares one seed,
/// `mix2(seed, u64::MAX)`. That collapse is historical behavior, kept
/// bit-compatible with the original sequential executor.
fn model_shuffle_seed(
    seed: u64,
    iteration: usize,
    sub: usize,
    rank: usize,
    step: usize,
    eff_task: u32,
) -> Option<u64> {
    if eff_task > 0 {
        Some(crate::util::mix2(
            seed,
            (iteration as u64) << 32 | (sub as u64) << 16 | (rank as u64) << 8 | step as u64,
        ))
    } else {
        None
    }
}

/// Per-step completion barrier for live progress streaming from the
/// rank-parallel executor: the *last* rank to finish folding step `w`
/// fires `on_exchange_step`/`on_exchange_measured` with the rank-averaged
/// measurements, so observers see each step as it completes on every
/// rank — the same contract the sequential executor honors — instead of
/// a burst after the whole combine. (Steps complete in order on every
/// rank; only a descheduled firing thread can briefly reorder two
/// adjacent notifications.)
struct StepNotifier {
    progress: Option<Arc<dyn Progress>>,
    sub: usize,
    n_steps: usize,
    n_ranks: usize,
    /// per step: (Σ comp_s, Σ wait_s, ranks done)
    acc: Vec<std::sync::Mutex<(f64, f64, usize)>>,
}

impl StepNotifier {
    fn new(
        progress: Option<Arc<dyn Progress>>,
        sub: usize,
        n_steps: usize,
        n_ranks: usize,
    ) -> Self {
        StepNotifier {
            progress,
            sub,
            n_steps,
            n_ranks,
            acc: (0..n_steps)
                .map(|_| std::sync::Mutex::new((0.0, 0.0, 0)))
                .collect(),
        }
    }

    /// Record one rank's measurements for step `w`; fires the progress
    /// callbacks when this was the last rank to complete the step.
    fn record(&self, w: usize, comp_s: f64, wait_s: f64) {
        let done = {
            let mut g = self.acc[w].lock().unwrap();
            g.0 += comp_s;
            g.1 += wait_s;
            g.2 += 1;
            if g.2 == self.n_ranks {
                Some((g.0 / self.n_ranks as f64, g.1 / self.n_ranks as f64))
            } else {
                None
            }
        };
        if let Some((comp, wait)) = done {
            if let Some(pr) = &self.progress {
                pr.on_exchange_step(self.sub, w, self.n_steps);
                pr.on_exchange_measured(self.sub, w, comp, wait);
            }
        }
    }
}

/// The body of one rank's worker thread: local combine, then the Fig-3
/// pipelined loop — post step `w`'s sends, fold step `w-1` while `w` is
/// in flight. See [`DistributedRunner::combine_subtemplate_threaded`] for
/// the determinism argument.
fn rank_exchange_worker(
    env: &RankEnv<'_>,
    p: usize,
    rank_tables: &[Option<TableStorage>],
    out: &mut CountTable,
    mem: &mut DualAccountant,
) -> FabricResult<RankLog> {
    let n_steps = env.schedule.n_steps();
    let n_local = env.plan.part.n_local(p);
    let active = rank_tables[env.act_idx].as_ref().unwrap();
    let passive = rank_tables[env.pass_idx].as_ref().unwrap();
    let shuffle_seed =
        |w: usize| model_shuffle_seed(env.seed, env.iteration, env.sub, p, w, env.eff_task);

    let mut stats = ExecStats::zeros(env.nested);
    let mut units = 0.0f64;
    let mut real_compute = 0.0f64;
    let mut hist = vec![0.0f64; env.n_threads + 1];
    let mut busy_units = 0.0f64;
    let mut steps: Vec<RankStepLog> = Vec::with_capacity(n_steps);
    let mut recv_peak = 0u64;
    let mut max_step_recv_bytes = 0u64;
    // frontiers of this rank's finalized child tables — shared by the
    // local phase, every fold step, and the outgoing wire encoding
    let act_front = table_frontier_for(active, env.prune);
    let pass_front = table_frontier_for(passive, env.prune);
    let mut pairs_skipped = 0u64;
    let mut wire_rows_dropped = 0u64;

    // ---- local phase ----
    let t0 = Instant::now();
    let pairs = prune_pairs(&env.plan.local_pairs[p], act_front.as_ref(), &mut pairs_skipped);
    let batch = [PairBatch {
        pairs: &pairs[..],
        rows: active.as_rows(),
    }];
    let st = combine_batches_pruned(
        out,
        passive.as_rows(),
        env.split,
        &batch,
        env.eff_task,
        env.nested,
        env.kernel,
        pass_front.as_ref(),
        Some(&env.cost_model),
    );
    real_compute += t0.elapsed().as_secs_f64();
    units += st.n_pairs as f64 * env.cost_model.unit_per_pair;
    stats.merge(&st);
    // frontier-effective degrees: the model queue sees the work that
    // actually ran, identically in both executors
    let mut degs = vec![0u32; n_local];
    for &(v, _) in pairs.iter() {
        degs[v as usize] += 1;
    }
    let tasks = make_tasks(&degs, env.eff_task, shuffle_seed(usize::MAX));
    let costs: Vec<f64> = tasks.iter().map(|t| env.cost_model.cost(t)).collect();
    let rep = replay(&costs, env.n_threads, env.phys_cores);
    let local_makespan = rep.makespan;
    for (c, t) in rep.concurrency_histogram.iter().enumerate() {
        hist[c.min(env.n_threads)] += t;
        busy_units += c as f64 * t;
    }

    // ---- exchange: fold one step while the next is in flight ----
    let mut fold_step = |w: usize| -> FabricResult<()> {
        let wait0 = Instant::now();
        let packets = env
            .fabric
            .recv_step(p, w, env.schedule.plans[w][p].recv_from.len())?;
        let wait_s = wait0.elapsed().as_secs_f64();
        let n_msgs = packets.len();
        let mut recv_bytes = 0u64;
        let mut recv_dense_bytes = 0u64;
        let mut bufs: Vec<(usize, TableStorage)> = Vec::with_capacity(n_msgs);
        for pkt in packets {
            let bytes = pkt.bytes();
            recv_bytes += bytes;
            recv_dense_bytes += pkt.dense_equiv_bytes();
            mem.alloc2(MemClass::RecvBuffer, bytes, pkt.dense_equiv_bytes());
            let q = pkt.sender();
            // streaming fold input: the payload is *moved* out of the
            // packet — receiving never copies a row, and sparse payloads
            // feed the fold without densifying
            bufs.push((q, TableStorage::from_payload(pkt.payload, env.a2_sets)));
        }
        recv_peak = recv_peak.max(mem.current(MemClass::RecvBuffer));
        max_step_recv_bytes = max_step_recv_bytes.max(recv_bytes);
        // prune each sender's fold pairs by its received buffer's own
        // frontier — deterministic in the data, so both executors drop
        // the same pairs
        let pair_lists: Vec<Cow<[(u32, u32)]>> = bufs
            .iter()
            .map(|(q, buf)| {
                prune_pairs(
                    &env.plan.plans[p][*q],
                    table_frontier_for(buf, env.prune).as_ref(),
                    &mut pairs_skipped,
                )
            })
            .collect();
        let mut degs = vec![0u32; n_local];
        for pl in &pair_lists {
            for &(v, _) in pl.iter() {
                degs[v as usize] += 1;
            }
        }
        let tc0 = Instant::now();
        let batches: Vec<PairBatch> = bufs
            .iter()
            .zip(&pair_lists)
            .map(|((_, buf), pl)| PairBatch {
                pairs: pl.as_ref(),
                rows: buf.as_rows(),
            })
            .collect();
        let st = combine_batches_pruned(
            out,
            passive.as_rows(),
            env.split,
            &batches,
            env.eff_task,
            env.nested,
            env.kernel,
            pass_front.as_ref(),
            Some(&env.cost_model),
        );
        let comp_s = tc0.elapsed().as_secs_f64();
        drop(batches);
        drop(pair_lists);
        drop(bufs);
        // the step's slice is released the moment its fold completes —
        // the real memory bound, not bookkeeping
        mem.free2(MemClass::RecvBuffer, recv_bytes, recv_dense_bytes);
        stats.merge(&st);
        units += st.n_pairs as f64 * env.cost_model.unit_per_pair;
        real_compute += comp_s;
        let tasks = make_tasks(&degs, env.eff_task, shuffle_seed(w));
        let costs: Vec<f64> = tasks.iter().map(|t| env.cost_model.cost(t)).collect();
        let rep = replay(&costs, env.n_threads, env.phys_cores);
        for (c, t) in rep.concurrency_histogram.iter().enumerate() {
            hist[c.min(env.n_threads)] += t;
            busy_units += c as f64 * t;
        }
        let comm = env.net.step(n_msgs, recv_bytes).max(env.net.step(
            env.schedule.plans[w][p].send_to.len(),
            env.fabric.ledger().sent_bytes(p, w),
        ));
        steps.push(RankStepLog {
            makespan_units: rep.makespan,
            comm_s: comm,
            comp_s,
            wait_s,
        });
        // live progress: the last rank to finish the step fires the
        // observer callbacks with the rank-averaged measurements
        env.notify.record(w, comp_s, wait_s);
        Ok(())
    };

    for w in 0..n_steps {
        // post step w's sends, non-blocking, in the active table's own
        // encoding (the shared codec seam — same serializer as the
        // sequential executor); with pruning active the masked encoder
        // drops frontier-dead rows
        for &q in &env.schedule.plans[w][p].send_to {
            let payload = encode_request_rows(active, env.plan, p, q, act_front.is_some());
            wire_rows_dropped += payload.rows_dropped();
            env.fabric
                .send(Packet::with_payload(p, q, w, env.sub, env.a2_sets, payload))?;
        }
        // ... then fold the previous step while w's packets fly
        if w > 0 {
            fold_step(w - 1)?;
        }
    }
    if n_steps > 0 {
        fold_step(n_steps - 1)?;
    }
    drop(fold_step);

    Ok(RankLog {
        local_makespan,
        steps,
        units,
        real_compute,
        hist,
        busy_units,
        stats,
        pairs_skipped,
        wire_rows_dropped,
        recv_peak,
        max_step_recv_bytes,
    })
}

/// The globalized per-iteration calibration inputs a process-mode
/// allreduce returns: every field is the deterministic ascending-rank
/// sum (or, for the link, the participant average) of the per-process
/// locals, bit-identical on every rank process.
struct GlobalCalibration {
    du: f64,
    dc: f64,
    /// averaged measured (α seconds, β seconds/byte) over the
    /// participants that had a link fit; `None` when none did
    link: Option<(f64, f64)>,
    /// per-sub global storage outcome (all ranks, not just local ones)
    storage: Vec<SubStorage>,
    /// per threaded combine of the iteration, per step: global
    /// (Σ comp_s, Σ wait_s) over all ranks
    step_meas: Vec<Vec<(f64, f64)>>,
}

/// Flatten this process's per-iteration measurements, allreduce them,
/// and unflatten the global sums. The payload layout is a pure function
/// of replicated state (`n_subs`, the iteration's combine decisions), so
/// every process encodes and decodes identically.
fn allreduce_calibration(
    fabric: &dyn RankFabric,
    owned: &[usize],
    du: f64,
    dc: f64,
    link: Option<LinkMeasurement>,
    sub_storage: &[SubStorage],
    iter_meas: &[(usize, f64, bool, Vec<(f64, f64)>)],
) -> FabricResult<GlobalCalibration> {
    let mut local = vec![du, dc];
    match link {
        Some(l) => local.extend([1.0, l.alpha_s, l.beta_s_per_byte]),
        None => local.extend([0.0, 0.0, 0.0]),
    }
    for st in sub_storage {
        local.extend([
            st.nnz as f64,
            st.cells as f64,
            st.sparse_ranks as f64,
            st.n_ranks as f64,
            st.dense_bytes as f64,
            st.resident_bytes as f64,
            st.live_rows as f64,
            st.total_rows as f64,
            st.pairs_skipped as f64,
            st.rows_skipped as f64,
            st.wire_rows_dropped as f64,
        ]);
    }
    for (_, _, _, steps) in iter_meas {
        for &(comp, wait) in steps {
            local.extend([comp, wait]);
        }
    }
    let sum = allreduce_f64(fabric, owned, &local)?;
    let n_link = sum[2];
    let link = if n_link > 0.0 {
        Some((sum[3] / n_link, sum[4] / n_link))
    } else {
        None
    };
    let mut at = 5;
    let mut storage = Vec::with_capacity(sub_storage.len());
    for _ in 0..sub_storage.len() {
        storage.push(SubStorage {
            nnz: sum[at] as u64,
            cells: sum[at + 1] as u64,
            sparse_ranks: sum[at + 2] as usize,
            n_ranks: sum[at + 3] as usize,
            dense_bytes: sum[at + 4] as u64,
            resident_bytes: sum[at + 5] as u64,
            live_rows: sum[at + 6] as u64,
            total_rows: sum[at + 7] as u64,
            pairs_skipped: sum[at + 8] as u64,
            rows_skipped: sum[at + 9] as u64,
            wire_rows_dropped: sum[at + 10] as u64,
        });
        at += 11;
    }
    let mut step_meas = Vec::with_capacity(iter_meas.len());
    for (_, _, _, steps) in iter_meas {
        let mut m = Vec::with_capacity(steps.len());
        for _ in 0..steps.len() {
            m.push((sum[at], sum[at + 1]));
            at += 2;
        }
        step_meas.push(m);
    }
    Ok(GlobalCalibration {
        du: sum[0],
        dc: sum[1],
        link,
        storage,
        step_meas,
    })
}

/// One elementwise-sum allreduce over the fabric: the first owned rank
/// carries this process's vector (any further owned ranks contribute
/// zeros so nothing double-counts), every rank broadcasts to every peer
/// in one step, and every participant folds the per-rank contributions
/// in ascending rank order — deterministic f64 sums, bit-identical on
/// every process, with no coordinator.
fn allreduce_f64(
    fabric: &dyn RankFabric,
    owned: &[usize],
    local: &[f64],
) -> FabricResult<Vec<f64>> {
    let n_ranks = fabric.n_ranks();
    if n_ranks <= 1 || local.is_empty() {
        return Ok(local.to_vec());
    }
    fabric.begin_exchange(1);
    let zeros = vec![0.0f64; local.len()];
    for (idx, &p) in owned.iter().enumerate() {
        let mine = if idx == 0 { local } else { &zeros[..] };
        let rows = encode_f64_rows(mine);
        for q in 0..n_ranks {
            if q != p {
                fabric.send(Packet::new(p, q, 0, 0, 1, rows.clone()))?;
            }
        }
    }
    let mut result: Option<Vec<f64>> = None;
    for (idx, &p) in owned.iter().enumerate() {
        let packets = fabric.recv_step(p, 0, n_ranks - 1)?;
        if idx == 0 {
            let mut by_sender: Vec<Vec<f64>> = vec![Vec::new(); n_ranks];
            by_sender[p] = local.to_vec();
            for pkt in &packets {
                let vals = decode_f64_rows(pkt.dense_rows());
                assert_eq!(
                    vals.len(),
                    local.len(),
                    "allreduce payload length diverged across ranks"
                );
                by_sender[pkt.sender()] = vals;
            }
            let mut sum = vec![0.0f64; local.len()];
            for vals in &by_sender {
                for (s, v) in sum.iter_mut().zip(vals) {
                    *s += *v;
                }
            }
            result = Some(sum);
        }
    }
    fabric.assert_empty();
    Ok(result.expect("at least one owned rank"))
}

/// Encode f64s losslessly into the fabric's f32 row payload: each value
/// ships as its two raw bit-halves (`f32::from_bits` round-trips bit
/// patterns exactly; nothing ever does arithmetic on these rows).
fn encode_f64_rows(vals: &[f64]) -> Vec<Count> {
    let mut rows = Vec::with_capacity(vals.len() * 2);
    for v in vals {
        let b = v.to_bits();
        rows.push(f32::from_bits((b >> 32) as u32));
        rows.push(f32::from_bits(b as u32));
    }
    rows
}

/// Inverse of [`encode_f64_rows`].
fn decode_f64_rows(rows: &[Count]) -> Vec<f64> {
    rows.chunks_exact(2)
        .map(|c| f64::from_bits(((c[0].to_bits() as u64) << 32) | c[1].to_bits() as u64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{generate, RmatParams};
    use crate::template::builtin;

    fn small_graph(seed: u64) -> Graph {
        generate(&RmatParams::with_skew(64, 300, 3, seed))
    }

    fn run_mode(t: &str, g: &Graph, mode: ModeSelect, ranks: usize) -> RunResult {
        let tpl = builtin(t).unwrap();
        let mut cfg = RunConfig::default();
        cfg.n_ranks = ranks;
        cfg.mode = mode;
        cfg.n_iterations = 2;
        let mut r = DistributedRunner::new(&tpl, g, cfg);
        r.run()
    }

    #[test]
    fn distributed_equals_single_rank() {
        // THE invariant: colorful counts are identical for every rank
        // count and every communication mode (same coloring seed).
        let g = small_graph(11);
        let tpl = builtin("u5-2").unwrap();
        let engine = crate::colorcount::Engine::new(&tpl);
        let reference: Vec<f64> = (0..2)
            .map(|it| {
                engine
                    .run_iteration(&g, crate::util::mix2(42, it as u64))
                    .colorful
            })
            .collect();
        for mode in [
            ModeSelect::Naive,
            ModeSelect::Pipeline,
            ModeSelect::Adaptive,
            ModeSelect::AdaptiveLb,
        ] {
            for ranks in [1, 2, 5] {
                let res = run_mode("u5-2", &g, mode, ranks);
                for (a, b) in res.colorful.iter().zip(&reference) {
                    let rel = (a - b).abs() / b.abs().max(1.0);
                    assert!(
                        rel < 1e-3,
                        "{mode:?} P={ranks}: colorful {a} vs single-rank {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn plan_is_shared_not_rebuilt() {
        // the facade contract: one ExchangePlan can serve many templates
        let g = small_graph(29);
        let plan = Arc::new(ExchangePlan::random(&g, 4, 42));
        for tpl in ["u3-1", "u5-2", "u7-2"] {
            let t = builtin(tpl).unwrap();
            let cfg = RunConfig {
                n_ranks: 4,
                n_iterations: 1,
                ..RunConfig::default()
            };
            let mut shared = DistributedRunner::with_plan(&t, &g, cfg.clone(), plan.clone());
            let mut fresh = DistributedRunner::new(&t, &g, cfg);
            // the runner must hold the caller's allocation, not a copy
            assert!(Arc::ptr_eq(&plan, &shared.plan), "{tpl}");
            let a = shared.run();
            let b = fresh.run();
            assert_eq!(a.colorful, b.colorful, "{tpl}");
            assert_eq!(a.estimate, b.estimate, "{tpl}");
        }
    }

    #[test]
    #[should_panic(expected = "different rank count")]
    fn plan_rank_mismatch_panics() {
        let g = small_graph(31);
        let plan = Arc::new(ExchangePlan::random(&g, 4, 42));
        let t = builtin("u3-1").unwrap();
        let cfg = RunConfig {
            n_ranks: 5,
            ..RunConfig::default()
        };
        let _ = DistributedRunner::with_plan(&t, &g, cfg, plan);
    }

    #[test]
    fn pipeline_reduces_peak_memory() {
        let g = small_graph(13);
        let naive = run_mode("u10-2", &g, ModeSelect::Naive, 6);
        let pipe = run_mode("u10-2", &g, ModeSelect::Pipeline, 6);
        assert!(
            pipe.peak_mem() < naive.peak_mem(),
            "pipeline {} must beat naive {}",
            pipe.peak_mem(),
            naive.peak_mem()
        );
    }

    /// Satellite (behavior, not bookkeeping): on the streaming executor a
    /// rank's measured `RecvBuffer` high-water mark is bounded by the
    /// largest *single step's* received bytes — computed here
    /// independently from the exchange plan and schedule, not from the
    /// executor's own report.
    #[test]
    fn streaming_recv_peak_bounded_by_one_step() {
        let g = small_graph(13);
        let tpl = builtin("u10-2").unwrap();
        let mut cfg = RunConfig::default();
        cfg.n_ranks = 6;
        cfg.mode = ModeSelect::Pipeline;
        cfg.n_iterations = 2;
        let mut r = DistributedRunner::new(&tpl, &g, cfg);

        // the plan-derived bound: per rank, the largest step slice any
        // non-leaf subtemplate can receive (packet header + the requested
        // rows at that sub's active width and the engine element size)
        let (schedule, pipelined) = r.schedule();
        assert!(pipelined);
        let n_ranks = r.cfg.n_ranks;
        let mut bound = vec![0u64; n_ranks];
        for sub in r.ctx.dag.subs.iter().filter(|s| !s.is_leaf()) {
            let a2 = r.ctx.binom.c(r.ctx.k, sub.active_size(&r.ctx.dag));
            for plans_w in &schedule.plans {
                for (p, b) in bound.iter_mut().enumerate() {
                    let step_bytes: u64 = plans_w[p]
                        .recv_from
                        .iter()
                        .map(|&q| {
                            Packet::HEADER_BYTES
                                + r.plan.req.rows(p, q).len() as u64
                                    * a2
                                    * std::mem::size_of::<Count>() as u64
                        })
                        .sum();
                    *b = (*b).max(step_bytes);
                }
            }
        }

        let res = r.run();
        let m = res.measured.as_ref().expect("threaded run reports measured");
        assert_eq!(m.recv_peak_per_rank.len(), n_ranks);
        for p in 0..n_ranks {
            assert!(
                m.recv_peak_per_rank[p] <= m.max_step_recv_bytes_per_rank[p],
                "rank {p}: peak {} exceeds its own largest step {}",
                m.recv_peak_per_rank[p],
                m.max_step_recv_bytes_per_rank[p]
            );
            assert!(
                m.recv_peak_per_rank[p] <= bound[p],
                "rank {p}: measured peak {} exceeds plan-derived step bound {}",
                m.recv_peak_per_rank[p],
                bound[p]
            );
            assert!(m.recv_peak_per_rank[p] > 0, "rank {p} received nothing");
        }
        // a multi-step run really did fold step w-1 while w was in
        // flight: the record covers every combine and every step
        assert_eq!(m.steps.len(), schedule.n_steps());
        assert!(m.n_combines > 0);
    }

    /// The threaded executor is a drop-in: bit-identical estimates and an
    /// identical memory ledger vs. the sequential reference, in every
    /// mode (the full matrix lives in `tests/pipeline_exec.rs`).
    #[test]
    fn threaded_equals_sequential_executor() {
        let g = small_graph(47);
        let tpl = builtin("u7-2").unwrap();
        for mode in [ModeSelect::Naive, ModeSelect::Pipeline, ModeSelect::AdaptiveLb] {
            let run_with = |exchange: ExchangeExec| {
                let mut cfg = RunConfig::default();
                cfg.n_ranks = 5;
                cfg.mode = mode;
                cfg.n_iterations = 2;
                cfg.n_workers = 2;
                cfg.exchange = exchange;
                DistributedRunner::new(&tpl, &g, cfg).run()
            };
            let seq = run_with(ExchangeExec::Sequential);
            let thr = run_with(ExchangeExec::Threaded);
            assert_eq!(seq.colorful, thr.colorful, "{mode:?}");
            assert_eq!(seq.estimate.to_bits(), thr.estimate.to_bits(), "{mode:?}");
            assert_eq!(seq.samples, thr.samples, "{mode:?}");
            assert_eq!(seq.peak_mem_per_rank, thr.peak_mem_per_rank, "{mode:?}");
            // the work totals agree too: same task queues either way
            assert_eq!(seq.workers.n_tasks, thr.workers.n_tasks, "{mode:?}");
            assert_eq!(seq.workers.n_pairs, thr.workers.n_pairs, "{mode:?}");
            assert!(seq.measured.is_none());
            assert!(thr.measured.is_some());
            // the *model* clock is executor-independent: both paths feed
            // the Eq 9–14 algebra the same replayed makespans and
            // Hockney byte counts, so every modeled figure is bit-equal
            // (guards the duplicated step bookkeeping in the two
            // executors against one-sided edits)
            assert_eq!(
                seq.model.total.to_bits(),
                thr.model.total.to_bits(),
                "{mode:?}: modeled makespan diverged between executors"
            );
            assert_eq!(seq.model.comp.to_bits(), thr.model.comp.to_bits(), "{mode:?}");
            assert_eq!(
                seq.model.comm_total.to_bits(),
                thr.model.comm_total.to_bits(),
                "{mode:?}"
            );
            assert_eq!(
                seq.model.comm_exposed.to_bits(),
                thr.model.comm_exposed.to_bits(),
                "{mode:?}"
            );
            assert_eq!(seq.model.rho_by_sub, thr.model.rho_by_sub, "{mode:?}");
        }
    }

    #[test]
    fn adaptive_picks_alltoall_for_small_templates() {
        let g = small_graph(17);
        let tpl = builtin("u3-1").unwrap();
        let mut cfg = RunConfig::default();
        cfg.n_ranks = 5;
        cfg.mode = ModeSelect::Adaptive;
        let r = DistributedRunner::new(&tpl, &g, cfg);
        let (s, pipelined) = r.schedule();
        assert!(!pipelined);
        assert_eq!(s.n_steps(), 1);
    }

    #[test]
    fn adaptive_picks_ring_for_large_templates() {
        let g = small_graph(17);
        let tpl = builtin("u12-2").unwrap();
        let mut cfg = RunConfig::default();
        cfg.n_ranks = 5;
        cfg.mode = ModeSelect::Adaptive;
        let r = DistributedRunner::new(&tpl, &g, cfg);
        let (s, pipelined) = r.schedule();
        assert!(pipelined);
        assert_eq!(s.n_steps(), 4);
    }

    #[test]
    fn comm_decisions_match_schedule() {
        let g = small_graph(37);
        let res = run_mode("u10-2", &g, ModeSelect::Pipeline, 6);
        assert!(!res.comm_decisions.is_empty());
        for d in &res.comm_decisions {
            assert!(d.pipelined);
            assert_eq!(d.g, 1);
            assert_eq!(d.group_size(), Some(3));
            assert_eq!(d.n_steps, 5); // ring of 6 ranks, g = 1
            // default executor is threaded: a 5-step ring has an overlap
            // window, so the measured ρ must be recorded and sane
            let m = d.measured_rho.expect("threaded pipelined combine");
            assert!((0.0..=1.0).contains(&m), "rho {m}");
            assert!((0.0..=1.0).contains(&d.predicted_rho));
        }
        let res = run_mode("u10-2", &g, ModeSelect::Naive, 6);
        for d in &res.comm_decisions {
            assert!(!d.pipelined);
            assert_eq!(d.g, 5); // all-to-all exchanges with all P-1 peers
            assert_eq!(d.n_steps, 1);
            assert_eq!(d.predicted_rho, 0.0);
            assert!(d.measured_rho.is_none(), "single step has no overlap window");
        }
    }

    /// Satellite regression (P = 2 and P = 3): every boundary a group size
    /// crosses on its way into a ring schedule clamps to 2g+1 ≤ P (with
    /// the g = P-1 all-to-all degenerate) and reports a typed error.
    #[test]
    fn group_size_clamped_at_small_rank_counts() {
        // P = 2: no pipelined ring exists; only the all-to-all degenerate
        assert!(validate_group_size(1, 2).is_ok());
        assert!(matches!(
            validate_group_size(2, 2),
            Err(HarpsgError::InvalidJob(_))
        ));
        // P = 3: g = 1 (groups of 3) and g = 2 (all-to-all) only
        assert!(validate_group_size(1, 3).is_ok());
        assert!(validate_group_size(2, 3).is_ok());
        assert!(matches!(
            validate_group_size(3, 3),
            Err(HarpsgError::InvalidJob(_))
        ));
        // the half-open band (P-1)/2 < g < P-1 is infeasible
        assert!(validate_group_size(3, 8).is_ok());
        for bad in [4usize, 5, 6] {
            assert!(validate_group_size(bad, 8).is_err(), "g={bad} P=8");
        }
        assert!(validate_group_size(7, 8).is_ok());
        assert!(validate_group_size(0, 8).is_err());

        // the runner-level ablation hook rejects instead of scheduling
        let g = small_graph(59);
        let tpl = builtin("u5-2").unwrap();
        let mut cfg = RunConfig::default();
        cfg.n_ranks = 3;
        cfg.n_iterations = 1;
        let mut r = DistributedRunner::new(&tpl, &g, cfg);
        assert!(r.set_group_size(1).is_ok());
        assert!(matches!(
            r.set_group_size(3),
            Err(HarpsgError::InvalidJob(_))
        ));
        let mut cfg2 = RunConfig::default();
        cfg2.n_ranks = 2;
        cfg2.n_iterations = 1;
        let mut r2 = DistributedRunner::new(&tpl, &g, cfg2);
        assert!(r2.set_group_size(2).is_err());
        assert!(r2.set_group_size(1).is_ok(), "g = P-1 all-to-all stays legal");
        let res = r2.run();
        assert!(res.comm_decisions.iter().all(|d| !d.pipelined));
    }

    /// Satellite: the adaptive model's byte accounting *is* the fabric's.
    /// Per (rank, step), the modeled row width (engine element size ×
    /// active sets) plus the per-packet header reproduce exactly what a
    /// `ThreadedFabric` measures when the executor's packets for a real
    /// exchange plan flow through it — for g = 1, a wider ring, and the
    /// all-to-all schedule.
    #[test]
    fn modeled_step_bytes_match_threaded_fabric() {
        let g = small_graph(53);
        let tpl = builtin("u10-2").unwrap();
        let ctx = EngineContext::new(&tpl);
        let n_ranks = 5usize;
        let plan = ExchangePlan::random(&g, n_ranks, 42);
        for ring_g in [1usize, 2, 4] {
            let sched = Schedule::ring(n_ranks, ring_g);
            for (i, sub) in ctx.dag.subs.iter().enumerate() {
                if sub.is_leaf() {
                    continue;
                }
                let a = sub.active_size(&ctx.dag);
                let a2_sets = ctx.binom.c(ctx.k, a) as usize;
                let row_bytes = AdaptivePolicy::row_bytes(ctx.k, a, &ctx.binom);
                let fab = ThreadedFabric::new(n_ranks, sched.n_steps());
                for (w, plans_w) in sched.plans.iter().enumerate() {
                    for p in 0..n_ranks {
                        for &q in &plans_w[p].send_to {
                            let want = plan.req.rows(q, p);
                            let rows = vec![0.0; want.len() * a2_sets];
                            fab.send(Packet::new(p, q, w, i, a2_sets, rows));
                        }
                    }
                }
                for (w, plans_w) in sched.plans.iter().enumerate() {
                    for p in 0..n_ranks {
                        let modeled: u64 = plans_w[p]
                            .send_to
                            .iter()
                            .map(|&q| {
                                plan.req.rows(q, p).len() as u64 * row_bytes
                                    + Packet::HEADER_BYTES
                            })
                            .sum();
                        assert_eq!(
                            fab.sent_bytes(p, w),
                            modeled,
                            "g={ring_g} sub {i} rank {p} step {w}"
                        );
                        let _ = fab.recv_step(p, w, plans_w[p].recv_from.len());
                        // …and the receive side agrees with the same model
                        let modeled_recv: u64 = plans_w[p]
                            .recv_from
                            .iter()
                            .map(|&q| {
                                plan.req.rows(p, q).len() as u64 * row_bytes
                                    + Packet::HEADER_BYTES
                            })
                            .sum();
                        assert_eq!(
                            fab.recv_bytes(p, w),
                            modeled_recv,
                            "recv g={ring_g} sub {i} rank {p} step {w}"
                        );
                    }
                }
                fab.assert_empty();
            }
        }
    }

    /// Satellite: byte-exactness survives the sparse encoding. For a real
    /// exchange plan and a genuinely sparse active table, the wire bytes
    /// modeled from the codec's sizing rule — per packet, the header plus
    /// per-row offsets plus 8 bytes per non-zero entry of the requested
    /// rows — reproduce exactly what a `ThreadedFabric` measures on both
    /// the send and receive side, and undercut the dense encoding.
    #[test]
    fn sparse_encoded_step_bytes_match_threaded_fabric() {
        let g = small_graph(67);
        let n_ranks = 5usize;
        let plan = ExchangePlan::random(&g, n_ranks, 42);
        let a2_sets = 10usize;
        // a low-density table over every vertex (row = local index per
        // rank is irrelevant here — encode_request_rows indexes by local
        // row, so build one table per rank)
        let tables: Vec<TableStorage> = (0..n_ranks)
            .map(|p| {
                let n = plan.part.n_local(p);
                let mut t = CountTable::zeros(n, a2_sets);
                for r in 0..n {
                    // ~20% density, deterministic pattern
                    t.row_mut(r)[(r * 7) % a2_sets] = 1.0 + r as f32;
                    if r % 2 == 0 {
                        t.row_mut(r)[(r * 3 + 1) % a2_sets] = 0.5;
                    }
                }
                let (stored, _) = TableStorage::from_dense_policy(
                    t,
                    &StoragePolicy::of(storage::StorageMode::Sparse),
                );
                assert!(stored.is_sparse());
                stored
            })
            .collect();
        for ring_g in [1usize, 2, 4] {
            let sched = Schedule::ring(n_ranks, ring_g);
            let fab = ThreadedFabric::new(n_ranks, sched.n_steps());
            for (w, plans_w) in sched.plans.iter().enumerate() {
                for p in 0..n_ranks {
                    for &q in &plans_w[p].send_to {
                        let payload = encode_request_rows(&tables[p], &plan, p, q, false);
                        fab.send(Packet::with_payload(p, q, w, 0, a2_sets, payload));
                    }
                }
            }
            // the codec-level sizing rule, computed independently from
            // the sparse rows themselves: CSR bytes when smaller than
            // the dense encoding of the same subset, dense otherwise
            let packet_bytes = |sender: usize, receiver: usize| -> u64 {
                let want = plan.req.rows(receiver, sender);
                let nnz: u64 = want
                    .iter()
                    .map(|&u| {
                        let r = plan.part.local_index[u as usize] as usize;
                        match &tables[sender] {
                            TableStorage::Sparse(t) => t.row_entries(r).len() as u64,
                            TableStorage::Dense(_) => unreachable!(),
                        }
                    })
                    .sum();
                let sparse = (want.len() as u64 + 1) * 4 + nnz * 8;
                let dense = want.len() as u64 * a2_sets as u64 * 4;
                Packet::HEADER_BYTES + sparse.min(dense)
            };
            for (w, plans_w) in sched.plans.iter().enumerate() {
                for p in 0..n_ranks {
                    let modeled: u64 = plans_w[p].send_to.iter().map(|&q| packet_bytes(p, q)).sum();
                    assert_eq!(fab.sent_bytes(p, w), modeled, "g={ring_g} rank {p} step {w}");
                    let dense_modeled: u64 = plans_w[p]
                        .send_to
                        .iter()
                        .map(|&q| {
                            plan.req.rows(q, p).len() as u64
                                * AdaptivePolicy::row_bytes(5, 2, &crate::combin::Binomial::new())
                                + Packet::HEADER_BYTES
                        })
                        .sum();
                    // C(5,2) = 10 = a2_sets: the dense encoding of the
                    // same rows is strictly heavier at ~20% density
                    if !plans_w[p].send_to.is_empty()
                        && plans_w[p].send_to.iter().any(|&q| !plan.req.rows(q, p).is_empty())
                    {
                        assert!(
                            modeled < dense_modeled,
                            "g={ring_g} rank {p} step {w}: \
                             sparse {modeled} !< dense {dense_modeled}"
                        );
                    }
                    let _ = fab.recv_step(p, w, plans_w[p].recv_from.len());
                    let modeled_recv: u64 =
                        plans_w[p].recv_from.iter().map(|&q| packet_bytes(q, p)).sum();
                    assert_eq!(
                        fab.recv_bytes(p, w),
                        modeled_recv,
                        "recv g={ring_g} rank {p} step {w}"
                    );
                }
            }
            fab.assert_empty();
        }
    }

    /// Acceptance core: estimates are bit-identical across the three
    /// storage modes and both exchange executors, the auto policy's
    /// accounted peak on a 12-vertex template at P = 6 lands strictly
    /// below the dense baseline, and the dense-baseline ledger of a
    /// sparse run reproduces the dense run's real ledger exactly (the
    /// full matrix lives in `tests/storage.rs`).
    #[test]
    fn storage_modes_bit_identical_and_auto_peak_drops() {
        let g = small_graph(71);
        let tpl = builtin("u12-1").unwrap();
        let run_with = |storage: crate::colorcount::StorageMode, exchange: ExchangeExec| {
            let mut cfg = RunConfig::default();
            cfg.n_ranks = 6;
            cfg.mode = ModeSelect::Pipeline;
            cfg.n_iterations = 1;
            cfg.table_storage = storage;
            cfg.exchange = exchange;
            DistributedRunner::new(&tpl, &g, cfg).run()
        };
        use crate::colorcount::StorageMode as SM;
        let dense = run_with(SM::Dense, ExchangeExec::Sequential);
        // dense mode: the two ledgers coincide
        assert_eq!(dense.peak_mem_per_rank, dense.peak_mem_dense_per_rank);
        assert_eq!(dense.peak_bytes_saved(), 0);
        for exchange in [ExchangeExec::Sequential, ExchangeExec::Threaded] {
            for storage in [SM::Dense, SM::Sparse, SM::Auto] {
                let r = run_with(storage, exchange);
                assert_eq!(r.colorful, dense.colorful, "{storage:?} {exchange:?}");
                assert_eq!(
                    r.estimate.to_bits(),
                    dense.estimate.to_bits(),
                    "{storage:?} {exchange:?}"
                );
                assert_eq!(r.samples, dense.samples, "{storage:?} {exchange:?}");
                // the dense-baseline ledger is executor- and mode-
                // invariant: it always reproduces the dense run's peaks
                assert_eq!(
                    r.peak_mem_dense_per_rank, dense.peak_mem_per_rank,
                    "{storage:?} {exchange:?}: dense baseline diverged"
                );
            }
        }
        let auto = run_with(SM::Auto, ExchangeExec::Threaded);
        assert!(
            auto.peak_mem() < dense.peak_mem(),
            "auto {} must beat dense {}",
            auto.peak_mem(),
            dense.peak_mem()
        );
        assert_eq!(auto.peak_bytes_saved(), dense.peak_mem() - auto.peak_mem());
        // the one-hot leaf tables must have been stored sparse with the
        // measured 1/k density
        let leaf = auto
            .storage
            .iter()
            .find(|d| {
                d.sparse_ranks == d.n_ranks && (d.density - 1.0 / 12.0).abs() < 1e-9
            })
            .expect("a one-hot leaf stored sparse");
        assert!(leaf.bytes_saved() > 0);
        assert_eq!(leaf.storage_name(), "sparse");
        // dense mode reports every table dense
        assert!(dense.storage.iter().all(|d| d.storage_name() == "dense"));
    }

    /// Kernel-knob acceptance core: DP tables are integer-valued, so the
    /// SIMD lane-tree reorder is exact and estimates are bit-identical
    /// across all three kernel modes, both exchange executors and worker
    /// counts (the full template × rank matrix lives in
    /// `tests/kernel.rs`).
    #[test]
    fn kernel_modes_bit_identical_across_executors() {
        let g = small_graph(67);
        let tpl = builtin("u12-1").unwrap();
        let run_with = |kernel: KernelMode, exchange: ExchangeExec, workers: usize| {
            let mut cfg = RunConfig::default();
            cfg.n_ranks = 5;
            cfg.mode = ModeSelect::AdaptiveLb;
            cfg.n_iterations = 1;
            cfg.n_workers = workers;
            cfg.kernel = kernel;
            cfg.exchange = exchange;
            DistributedRunner::new(&tpl, &g, cfg).run()
        };
        let baseline = run_with(KernelMode::Scalar, ExchangeExec::Sequential, 1);
        for exchange in [ExchangeExec::Sequential, ExchangeExec::Threaded] {
            for kernel in [KernelMode::Scalar, KernelMode::Simd, KernelMode::Auto] {
                for workers in [1, 3] {
                    let r = run_with(kernel, exchange, workers);
                    assert_eq!(
                        r.estimate.to_bits(),
                        baseline.estimate.to_bits(),
                        "{kernel:?} {exchange:?} workers={workers}"
                    );
                    assert_eq!(r.colorful, baseline.colorful);
                    assert_eq!(r.samples, baseline.samples);
                }
            }
        }
    }

    /// Adaptive sweep end-to-end: decisions stay feasible, the counting
    /// math is schedule-invariant (bit-identical estimates vs the static
    /// path), and multi-iteration runs recalibrate without disturbance.
    #[test]
    fn adaptive_sweep_matches_static_estimates() {
        let g = small_graph(61);
        let tpl = builtin("u10-2").unwrap();
        let mk = |adaptive: bool, exchange: ExchangeExec| {
            let mut cfg = RunConfig::default();
            cfg.n_ranks = 6;
            cfg.mode = ModeSelect::Adaptive;
            cfg.n_iterations = 3;
            cfg.adaptive_group = adaptive;
            cfg.exchange = exchange;
            DistributedRunner::new(&tpl, &g, cfg).run()
        };
        let base = mk(false, ExchangeExec::Sequential);
        for exchange in [ExchangeExec::Sequential, ExchangeExec::Threaded] {
            let r = mk(true, exchange);
            assert_eq!(r.colorful, base.colorful, "{exchange:?}");
            assert_eq!(r.estimate.to_bits(), base.estimate.to_bits(), "{exchange:?}");
            assert!(!r.comm_decisions.is_empty());
            for d in &r.comm_decisions {
                if d.pipelined {
                    assert!(
                        d.g <= AdaptivePolicy::max_feasible_group(6),
                        "infeasible g {}",
                        d.g
                    );
                    assert!((0.0..=1.0).contains(&d.predicted_rho));
                } else {
                    assert_eq!(d.n_steps, 1);
                }
            }
        }
    }

    #[test]
    fn worker_counts_are_bit_identical() {
        // the acceptance invariant: any worker count reproduces the
        // single-worker run exactly, in every communication mode
        let g = small_graph(41);
        let tpl = builtin("u5-2").unwrap();
        for mode in [
            ModeSelect::Naive,
            ModeSelect::Pipeline,
            ModeSelect::AdaptiveLb,
        ] {
            let run_with = |workers: usize| {
                let mut cfg = RunConfig::default();
                cfg.n_ranks = 3;
                cfg.mode = mode;
                cfg.n_iterations = 2;
                cfg.n_workers = workers;
                DistributedRunner::new(&tpl, &g, cfg).run()
            };
            let base = run_with(1);
            assert_eq!(base.workers.n_workers(), 1);
            assert!(base.workers.n_pairs > 0);
            assert!(base.workers.busy_seconds[0] > 0.0);
            for workers in [2, 4] {
                let r = run_with(workers);
                assert_eq!(r.colorful, base.colorful, "{mode:?} workers={workers}");
                assert_eq!(
                    r.estimate.to_bits(),
                    base.estimate.to_bits(),
                    "{mode:?} workers={workers}"
                );
                // the task queue and its consumption totals are
                // schedule-independent too
                assert_eq!(r.workers.n_workers(), workers);
                assert_eq!(r.workers.n_pairs, base.workers.n_pairs);
                assert_eq!(r.workers.n_tasks, base.workers.n_tasks);
                assert_eq!(r.workers.units, base.workers.units);
            }
        }
    }

    #[test]
    fn oom_flag_respects_limit() {
        let g = small_graph(19);
        let tpl = builtin("u10-2").unwrap();
        let mut cfg = RunConfig::default();
        cfg.n_ranks = 4;
        cfg.mode = ModeSelect::Naive;
        cfg.mem_limit = Some(1); // 1 byte: everything OOMs
        let mut r = DistributedRunner::new(&tpl, &g, cfg.clone());
        assert!(r.run().oom);
        cfg.mem_limit = None;
        let mut r = DistributedRunner::new(&tpl, &g, cfg);
        assert!(!r.run().oom);
    }

    #[test]
    fn model_time_positive_and_decomposes() {
        let g = small_graph(23);
        let res = run_mode("u7-2", &g, ModeSelect::Pipeline, 4);
        assert!(res.model.total > 0.0);
        assert!(res.model.comp > 0.0);
        assert!(res.model.comm_total > 0.0);
        assert!(res.model.comm_exposed <= res.model.comm_total + 1e-12);
        assert!(res.flop_time > 0.0 && res.flop_time < 1e-3);
    }

    /// Satellite: the pruned exchange encoder drops frontier-dead rows
    /// behind the presence mask, and the bytes a `ThreadedFabric`
    /// measures reproduce the codec's three-way sizing rule (dense /
    /// positional CSR / masked CSR, masked only when strictly smaller) —
    /// computed here independently from the tables. The prune-off
    /// encoder on the same dense tables ships the full slab, so the
    /// pruned wire is also checked to never cost a byte over it.
    #[test]
    fn pruned_exchange_masks_dead_rows_on_the_wire() {
        let g = small_graph(73);
        let n_ranks = 5usize;
        let plan = ExchangePlan::random(&g, n_ranks, 42);
        let a2_sets = 10usize;
        // dense tables where only every fourth local row is live (one
        // entry each): most requested positions are frontier-dead
        let tables: Vec<TableStorage> = (0..n_ranks)
            .map(|p| {
                let n = plan.part.n_local(p);
                let mut t = CountTable::zeros(n, a2_sets);
                for r in (0..n).step_by(4) {
                    t.row_mut(r)[(r * 7) % a2_sets] = 1.0 + r as f32;
                }
                TableStorage::Dense(t)
            })
            .collect();
        // the codec sizing rule for one packet's body, from first
        // principles: live rows carry exactly one entry here
        let packet_body = |sender: usize, receiver: usize| -> u64 {
            let want = plan.req.rows(receiver, sender);
            let n = want.len() as u64;
            let live = want
                .iter()
                .filter(|&&u| plan.part.local_index[u as usize] % 4 == 0)
                .count() as u64;
            let sparse = (n + 1) * 4 + live * 8;
            let dense = n * a2_sets as u64 * 4;
            let masked = 4 + n.div_ceil(64) * 8 + (live + 1) * 4 + live * 8;
            if masked < sparse.min(dense) {
                masked
            } else {
                sparse.min(dense)
            }
        };
        for ring_g in [1usize, 2] {
            let sched = Schedule::ring(n_ranks, ring_g);
            let fab = ThreadedFabric::new(n_ranks, sched.n_steps());
            let mut dropped = 0u64;
            for (w, plans_w) in sched.plans.iter().enumerate() {
                for p in 0..n_ranks {
                    for &q in &plans_w[p].send_to {
                        let payload = encode_request_rows(&tables[p], &plan, p, q, true);
                        dropped += payload.rows_dropped();
                        fab.send(Packet::with_payload(p, q, w, 0, a2_sets, payload));
                    }
                }
            }
            assert!(dropped > 0, "g={ring_g}: no dead row left the wire");
            for (w, plans_w) in sched.plans.iter().enumerate() {
                for p in 0..n_ranks {
                    let modeled: u64 = plans_w[p]
                        .send_to
                        .iter()
                        .map(|&q| Packet::HEADER_BYTES + packet_body(p, q))
                        .sum();
                    assert_eq!(fab.sent_bytes(p, w), modeled, "g={ring_g} rank {p} step {w}");
                    // pruning never costs bytes: the prune-off encoder
                    // ships these dense tables as full slabs
                    let unpruned: u64 = plans_w[p]
                        .send_to
                        .iter()
                        .map(|&q| {
                            let payload = encode_request_rows(&tables[p], &plan, p, q, false);
                            Packet::HEADER_BYTES + payload.wire_bytes()
                        })
                        .sum();
                    assert!(
                        modeled <= unpruned,
                        "g={ring_g} rank {p} step {w}: pruned {modeled} > unpruned {unpruned}"
                    );
                    let _ = fab.recv_step(p, w, plans_w[p].recv_from.len());
                    let modeled_recv: u64 = plans_w[p]
                        .recv_from
                        .iter()
                        .map(|&q| Packet::HEADER_BYTES + packet_body(q, p))
                        .sum();
                    assert_eq!(
                        fab.recv_bytes(p, w),
                        modeled_recv,
                        "recv g={ring_g} rank {p} step {w}"
                    );
                }
            }
            fab.assert_empty();
        }
    }

    /// Tentpole acceptance core: on a graph engineered with 2-vertex and
    /// 0-degree components — which cannot host any rooted embedding of
    /// size ≥ 3, so u12-1's size-6 root split is guaranteed dead rows —
    /// every prune mode on both exchange executors at P = 6 reproduces
    /// the unpruned sequential run bit for bit, the pruned run provably
    /// skips work, and its modeled wire bytes never exceed the unpruned
    /// model's (the full template × mode × fabric matrix lives in
    /// `tests/prune.rs`).
    #[test]
    fn prune_modes_bit_identical_and_skip_work() {
        // a dense bipartite blob on 0..32, four isolated edges, four
        // isolated vertices
        let mut edges = vec![(32u32, 33u32), (34, 35), (36, 37), (38, 39)];
        for v in 0..32u32 {
            for u in (v + 1)..32 {
                if (v + u) % 2 == 1 {
                    edges.push((v, u));
                }
            }
        }
        let g = crate::graph::graph_from_edges(44, &edges);
        let tpl = builtin("u12-1").unwrap();
        let run_with = |prune: PruneMode, exchange: ExchangeExec| {
            let mut cfg = RunConfig::default();
            cfg.n_ranks = 6;
            cfg.mode = ModeSelect::Pipeline;
            cfg.n_iterations = 2;
            cfg.n_workers = 2;
            cfg.exchange = exchange;
            cfg.prune = prune;
            DistributedRunner::new(&tpl, &g, cfg).run()
        };
        let base = run_with(PruneMode::Off, ExchangeExec::Sequential);
        // prune off records occupancies but never skips anything
        assert!(base
            .prune
            .iter()
            .all(|s| s.pairs_skipped == 0 && s.rows_skipped == 0 && s.wire_rows_dropped == 0));
        for exchange in [ExchangeExec::Sequential, ExchangeExec::Threaded] {
            for prune in [PruneMode::Off, PruneMode::On, PruneMode::Auto] {
                let r = run_with(prune, exchange);
                assert_eq!(r.colorful, base.colorful, "{prune:?} {exchange:?}");
                assert_eq!(
                    r.estimate.to_bits(),
                    base.estimate.to_bits(),
                    "{prune:?} {exchange:?}"
                );
                assert_eq!(r.samples, base.samples, "{prune:?} {exchange:?}");
                assert_eq!(
                    r.peak_mem_per_rank, base.peak_mem_per_rank,
                    "{prune:?} {exchange:?}"
                );
                for s in &r.prune {
                    assert!(
                        (0.0..=1.0).contains(&s.frontier_occupancy),
                        "{prune:?} {exchange:?} sub {}: occupancy {}",
                        s.sub,
                        s.frontier_occupancy
                    );
                }
            }
        }
        let on_seq = run_with(PruneMode::On, ExchangeExec::Sequential);
        let on_thr = run_with(PruneMode::On, ExchangeExec::Threaded);
        // the skip bookkeeping is executor-invariant, like the counts
        assert_eq!(on_seq.prune, on_thr.prune);
        let pairs: u64 = on_seq.prune.iter().map(|s| s.pairs_skipped).sum();
        assert!(pairs > 0, "isolated edges must prune pairs: {:?}", on_seq.prune);
        assert!(
            on_seq.prune.iter().any(|s| s.frontier_occupancy < 1.0),
            "dead components must dent some sub's occupancy: {:?}",
            on_seq.prune
        );
        // the occupancy-discounted wire model never charges more than
        // the unpruned model
        assert!(
            on_seq.model.comm_total <= base.model.comm_total + 1e-9,
            "pruned modeled comm {} > unpruned {}",
            on_seq.model.comm_total,
            base.model.comm_total
        );
    }
}
