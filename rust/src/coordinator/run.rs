//! Run configuration and results for the distributed coordinator — the
//! four implementations of Table 1 (Naive / Pipeline / Adaptive /
//! AdaptiveLB) are configurations of one runner.

use crate::colorcount::{ExecStats, KernelMode, PruneMode, StorageMode};
use crate::comm::{AdaptivePolicy, CommMode, HockneyParams};
use crate::graph::GraphStorageMode;
use crate::pipeline::MeasuredPipeline;

/// Paper Table 1: the four experiment code versions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModeSelect {
    /// all-to-all, no adaptive switch, per-vertex tasks
    Naive,
    /// always the ring pipeline, per-vertex tasks
    Pipeline,
    /// adaptive all-to-all/pipeline switch, per-vertex tasks
    Adaptive,
    /// adaptive switch + neighbor-list partitioning
    AdaptiveLb,
}

impl ModeSelect {
    pub fn name(&self) -> &'static str {
        match self {
            ModeSelect::Naive => "Naive",
            ModeSelect::Pipeline => "Pipeline",
            ModeSelect::Adaptive => "Adaptive",
            ModeSelect::AdaptiveLb => "AdaptiveLB",
        }
    }

    /// The CLI/config spelling of this mode.
    pub fn flag(&self) -> &'static str {
        match self {
            ModeSelect::Naive => "naive",
            ModeSelect::Pipeline => "pipeline",
            ModeSelect::Adaptive => "adaptive",
            ModeSelect::AdaptiveLb => "adaptive-lb",
        }
    }

    /// Parse the CLI/config spelling; `None` for unknown names (callers
    /// map this to `api::HarpsgError::UnknownMode`).
    pub fn parse(name: &str) -> Option<ModeSelect> {
        match name {
            "naive" => Some(ModeSelect::Naive),
            "pipeline" => Some(ModeSelect::Pipeline),
            "adaptive" => Some(ModeSelect::Adaptive),
            "adaptive-lb" | "adaptivelb" => Some(ModeSelect::AdaptiveLb),
            _ => None,
        }
    }
}

/// Which combine backend executes the DP hot loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// the native Rust combine (`colorcount::engine`)
    Native,
    /// the AOT-compiled JAX/Pallas kernel via PJRT (`runtime::xla_engine`)
    Xla,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Xla => "xla",
        }
    }

    /// Parse the CLI/config spelling; `None` for unknown names.
    pub fn parse(name: &str) -> Option<EngineKind> {
        match name {
            "native" => Some(EngineKind::Native),
            "xla" => Some(EngineKind::Xla),
            _ => None,
        }
    }
}

/// Which executor drives the per-subtemplate exchange.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeExec {
    /// the historical reference path: every step runs to completion over
    /// all ranks in one loop on the calling thread
    Sequential,
    /// the rank-parallel pipelined executor: one worker thread per rank,
    /// step `w`'s packets in flight while step `w-1`'s rows fold — the
    /// paper's Fig-3 schedule executed, not just modeled. Bit-identical
    /// estimates to `Sequential` (enforced by `tests/pipeline_exec.rs`).
    Threaded,
}

impl ExchangeExec {
    pub fn name(&self) -> &'static str {
        match self {
            ExchangeExec::Sequential => "sequential",
            ExchangeExec::Threaded => "threaded",
        }
    }

    /// Parse the CLI/config spelling; `None` for unknown names.
    pub fn parse(name: &str) -> Option<ExchangeExec> {
        match name {
            "sequential" => Some(ExchangeExec::Sequential),
            "threaded" => Some(ExchangeExec::Threaded),
            _ => None,
        }
    }
}

/// Which transport carries the exchange packets between ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricKind {
    /// simulated ranks inside one process (threads over the in-memory
    /// mailbox) — the default, and the only kind `Session::count` runs
    Threaded,
    /// rank *processes* framing packets over TCP/Unix sockets; driven by
    /// the `harpsg-rank` launcher (`coordinator::procmode`), which feeds
    /// the Hockney calibration wall-clock link measurements instead of
    /// simulated ones
    Socket,
}

impl FabricKind {
    pub fn name(&self) -> &'static str {
        match self {
            FabricKind::Threaded => "threaded",
            FabricKind::Socket => "socket",
        }
    }

    /// Parse the CLI/config spelling; `None` for unknown names.
    pub fn parse(name: &str) -> Option<FabricKind> {
        match name {
            "threaded" => Some(FabricKind::Threaded),
            "socket" => Some(FabricKind::Socket),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct RunConfig {
    pub n_ranks: usize,
    /// virtual threads per rank for the thread-level replay
    pub n_threads: usize,
    /// real combine-executor threads (the `--workers` knob). Unlike
    /// `n_threads` (a *model* of the paper's 48-thread nodes), this spawns
    /// actual OS threads for every combine; counts are bit-identical for
    /// any value (see `colorcount::parallel`).
    pub n_workers: usize,
    /// Alg-4 max task size; 0 = per-vertex granularity
    pub task_size: u32,
    pub mode: ModeSelect,
    pub n_iterations: usize,
    pub seed: u64,
    pub policy: AdaptivePolicy,
    pub net: HockneyParams,
    /// per-rank memory budget (models the 120 GB/node limit); None = ∞
    pub mem_limit: Option<u64>,
    pub engine: EngineKind,
    /// physical cores per node for the hyper-threading model
    pub phys_cores: usize,
    /// per-task scheduling overhead in compute units (Alg-4 granularity
    /// trade-off, Fig 11 bottom-right)
    pub task_overhead_units: f64,
    /// exchange executor: rank-parallel pipelined (default) or the
    /// sequential reference path. A loaded XLA runtime forces the
    /// sequential path (its kernel owns the serial scratch buffers).
    pub exchange: ExchangeExec,
    /// model-driven per-subtemplate group-size selection (the `--adaptive`
    /// knob): in the Adaptive/AdaptiveLB modes, sweep every feasible ring
    /// group size `g ∈ 1..=(P-1)/2` through the Hockney + compute model
    /// per subtemplate and feed measured flop time / overlap back into
    /// the policy between iterations. Off (the default) keeps the
    /// historical static switch (intensity threshold, fixed g = 1).
    pub adaptive_group: bool,
    /// count-table representation (the `--table-storage` knob): `Dense`
    /// (the historical layout, default), `Sparse` (force per-row
    /// `(set_rank, count)` storage and wire encoding), or `Auto` (pick
    /// per table from the measured density — `colorcount::storage`).
    /// Estimates are bit-identical for every choice; only resident
    /// bytes, wire bytes and speed change. A *loaded* XLA runtime forces
    /// dense (its kernel views tables as dense blocks).
    pub table_storage: StorageMode,
    /// combine kernel (the `--kernel` knob): `Scalar` (the historical
    /// per-element loops, default — and the differential baseline),
    /// `Simd` (chunked-lane SpMM + fused eMA over adjacency row-blocks,
    /// `colorcount::kernel`), or `Auto` (pick per combine from the
    /// aggregation width — identical on every rank and worker, so a run
    /// never mixes choices for one combine). Bit-identical to scalar on
    /// integer-valued tables (every DP table below 2^24); fractional data
    /// follows the documented lane-tree tolerance policy. Results never
    /// depend on the worker count either way. A *loaded* XLA runtime
    /// bypasses the native executor entirely, so the knob is inert there.
    pub kernel: KernelMode,
    /// graph storage backend (the `--graph-storage` knob): `Resident`
    /// (the historical shared CSR, default), `Mmap` (cut the graph into
    /// per-rank segment files and build the plan one slice at a time —
    /// each rank owns only its vertex partition's adjacency), or `Auto`
    /// (mmap exactly when the full CSR exceeds `graph_budget`).
    /// Estimates are bit-identical for every choice; only the graph
    /// entry of the memory ledger changes (`graph::shard`).
    pub graph_storage: GraphStorageMode,
    /// resident-adjacency budget in bytes that `GraphStorageMode::Auto`
    /// resolves against (the `--graph-budget-mb` knob); `None` uses
    /// [`GraphStorageMode::DEFAULT_BUDGET`]
    pub graph_budget: Option<u64>,
    /// rank transport (the `--fabric` knob): `Threaded` (simulated ranks
    /// in one process, default) or `Socket` (rank processes over
    /// TCP/Unix sockets — requires the `harpsg-rank` launcher; the
    /// in-process `Session::count` path rejects it with a typed error).
    /// Estimates are bit-identical for every choice.
    pub fabric: FabricKind,
    /// frontier pruning (the `--prune` knob): `Off` (the historical
    /// behaviour, default), `On` (every combine consults the finalized
    /// tables' nonzero-row frontiers to skip dead aggregation pairs,
    /// dead contractions and dead wire rows), or `Auto` (prune per table
    /// when its measured frontier occupancy is low enough to pay —
    /// `colorcount::frontier`). Estimates are bit-identical for every
    /// choice: every elided float op is an exact `+0.0` add or a product
    /// with an exact `0.0` factor. Only work, wire bytes and speed
    /// change; [`RunResult::prune`] reports what was skipped.
    pub prune: PruneMode,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            n_ranks: 4,
            n_threads: 48,
            n_workers: 1,
            task_size: 50,
            mode: ModeSelect::AdaptiveLb,
            n_iterations: 1,
            seed: 42,
            policy: AdaptivePolicy::default(),
            net: HockneyParams::default(),
            mem_limit: None,
            engine: EngineKind::Native,
            phys_cores: crate::sched::PHYSICAL_CORES,
            task_overhead_units: 10_000.0,
            exchange: ExchangeExec::Threaded,
            adaptive_group: false,
            table_storage: StorageMode::Dense,
            kernel: KernelMode::Scalar,
            graph_storage: GraphStorageMode::Resident,
            graph_budget: None,
            fabric: FabricKind::Threaded,
            prune: PruneMode::Off,
        }
    }
}

impl RunConfig {
    /// Task size actually used: LB modes use `task_size`, others run at
    /// per-vertex granularity (Table 1 "Neighbor list partitioning: Off").
    pub fn effective_task_size(&self) -> u32 {
        match self.mode {
            ModeSelect::AdaptiveLb => self.task_size,
            _ => 0,
        }
    }

    /// The communication mode for a template of the given complexity.
    pub fn comm_mode(&self, intensity: f64) -> CommMode {
        use crate::template::TemplateComplexity;
        let tc = TemplateComplexity {
            name: String::new(),
            k: 0,
            memory: 0,
            computation: 0,
            intensity,
        };
        match self.mode {
            ModeSelect::Naive => CommMode::AllToAll,
            ModeSelect::Pipeline => {
                // same feasibility predicate as the sweep: a pipelined
                // ring needs 2g+1 ≤ P
                if AdaptivePolicy::max_feasible_group(self.n_ranks) >= 1 {
                    CommMode::Pipeline { g: 1 }
                } else {
                    CommMode::AllToAll
                }
            }
            ModeSelect::Adaptive | ModeSelect::AdaptiveLb => self.policy.choose(&tc, self.n_ranks),
        }
    }
}

/// Modeled (cluster-clock) timing of one run, per iteration.
#[derive(Debug, Clone, Default)]
pub struct ModelTime {
    /// end-to-end modeled seconds per iteration
    pub total: f64,
    /// computation portion (thread-level makespans, incl. local combine)
    pub comp: f64,
    /// exposed (non-overlapped) communication
    pub comm_exposed: f64,
    /// total transfer time had nothing overlapped
    pub comm_total: f64,
    /// straggler wait (Eq 9) accumulated over steps
    pub straggler: f64,
    /// mean overlap ratio ρ per subtemplate (exchange subtemplates only)
    pub rho_by_sub: Vec<(usize, f64)>,
}

impl ModelTime {
    /// communication share of total (the ratio charts of Figs 7/10/14)
    pub fn comm_ratio(&self) -> f64 {
        if self.total <= 0.0 {
            0.0
        } else {
            self.comm_exposed / self.total
        }
    }

    pub fn mean_rho(&self) -> f64 {
        if self.rho_by_sub.is_empty() {
            return 0.0;
        }
        self.rho_by_sub.iter().map(|(_, r)| r).sum::<f64>() / self.rho_by_sub.len() as f64
    }
}

/// Aggregated thread-level stats (Fig 11's VTune histograms). These are
/// *modeled* (virtual-replay) figures; the *measured* per-worker record
/// of the real combine executor lives in [`RunResult::workers`].
#[derive(Debug, Clone, Default)]
pub struct ThreadStats {
    /// time-weighted average concurrency
    pub avg_concurrency: f64,
    /// histogram[c] = modeled seconds with exactly c busy threads
    pub concurrency_histogram: Vec<f64>,
}

/// The exchange shape chosen for one subtemplate combine. The static
/// modes decide once per template (Alg 3), so every non-leaf subtemplate
/// shares one decision; with `adaptive_group` on, the model-driven sweep
/// decides per subtemplate (and recalibrates between iterations — the
/// recorded decision is the final iteration's). `api::JobReport` shows
/// the schedule and the predicted vs measured overlap next to each
/// combine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommDecision {
    /// index of the subtemplate in the partition DAG
    pub sub: usize,
    /// true = Adaptive-Group ring, false = bulk all-to-all
    pub pipelined: bool,
    /// ring offsets per step (communication groups of 2g+1 ranks);
    /// `P - 1` for the single-step all-to-all
    pub g: usize,
    /// exchange steps `W` (1 for all-to-all)
    pub n_steps: usize,
    /// the model's predicted mean overlap ratio ρ (Eq 14) for the chosen
    /// shape (0 for all-to-all — nothing overlaps in one bulk step)
    pub predicted_rho: f64,
    /// measured mean per-step ρ = comp/(comp+wait) over this sub's
    /// combines, from the rank-parallel executor; `None` when the
    /// sequential executor ran or the schedule had no overlap window
    pub measured_rho: Option<f64>,
}

impl CommDecision {
    pub fn mode_name(&self) -> &'static str {
        if self.pipelined {
            "ring"
        } else {
            "all-to-all"
        }
    }

    /// The paper's ring group size m = 2g+1; `None` for all-to-all, whose
    /// single step spans all ranks (print `mode_name` instead).
    pub fn group_size(&self) -> Option<usize> {
        self.pipelined.then_some(2 * self.g + 1)
    }
}

/// Per-subtemplate storage outcome of the run's final iteration, all
/// ranks aggregated: the measured density of the built tables (the
/// un-dead-coded `CountTable::density` probe), how many ranks stored the
/// table sparse, and the resident vs dense-layout bytes. Surfaced in the
/// report's JSON `storage` section and the CLI's human output.
#[derive(Debug, Clone, PartialEq)]
pub struct StorageDecision {
    /// index of the subtemplate in the partition DAG
    pub sub: usize,
    /// fraction of non-zero entries across all ranks' tables
    pub density: f64,
    /// ranks that stored this sub's table sparse (decisions are
    /// per-rank, data-driven; `Auto` can legitimately mix)
    pub sparse_ranks: usize,
    pub n_ranks: usize,
    /// bytes the unconditional dense layout would hold, summed over ranks
    pub dense_bytes: u64,
    /// bytes actually resident, summed over ranks
    pub resident_bytes: u64,
}

impl StorageDecision {
    /// Resident savings against the dense layout (0 when the sparse
    /// representation did not pay off).
    pub fn bytes_saved(&self) -> u64 {
        self.dense_bytes.saturating_sub(self.resident_bytes)
    }

    /// "dense", "sparse", or "mixed" (per-rank decisions disagreed).
    pub fn storage_name(&self) -> &'static str {
        if self.sparse_ranks == 0 {
            "dense"
        } else if self.sparse_ranks == self.n_ranks {
            "sparse"
        } else {
            "mixed"
        }
    }
}

/// Per-subtemplate frontier-pruning outcome of the run's final
/// iteration, all ranks aggregated: the measured nonzero-row occupancy
/// of the sub's stored tables and the work the frontier layer elided in
/// the combine that built them — adjacency pairs whose active row was
/// dead, output rows whose passive row was dead, and requested wire
/// rows the masked encoding dropped. Zeros (with the occupancy still
/// measured) when pruning is off. Surfaced in the report's JSON `prune`
/// section and the CLI's human output.
#[derive(Debug, Clone, PartialEq)]
pub struct PruneStats {
    /// index of the subtemplate in the partition DAG
    pub sub: usize,
    /// fraction of this sub's stored rows with any nonzero entry,
    /// across all ranks (1.0 when the tables held no rows)
    pub frontier_occupancy: f64,
    /// aggregation pairs skipped because the active row was dead
    pub pairs_skipped: u64,
    /// output rows whose contraction was skipped because the passive
    /// row was dead
    pub rows_skipped: u64,
    /// requested rows dropped from the wire by the masked encoding
    pub wire_rows_dropped: u64,
}

/// One rank's wall-clock link parameters, least-squares fitted from its
/// real blocking sends (socket fabric only — the in-process fabrics have
/// no wire to measure). The measured counterpart of the simulated Hockney
/// `(α, β)` in [`RunConfig::net`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RankLink {
    pub rank: usize,
    /// fitted per-message latency, seconds
    pub alpha_s: f64,
    /// fitted per-byte transfer time, seconds/byte
    pub beta_s_per_byte: f64,
    /// sends the fit was computed from
    pub samples: usize,
}

#[derive(Debug, Clone)]
pub struct RunResult {
    /// the subgraph-count estimate (median of means over iterations)
    pub estimate: f64,
    /// per-iteration estimates
    pub samples: Vec<f64>,
    /// per-iteration raw colorful counts (for exactness cross-checks)
    pub colorful: Vec<f64>,
    pub model: ModelTime,
    /// real single-core wall-clock of the whole run, seconds
    pub real_seconds: f64,
    /// per-rank peak memory, bytes (resident bytes of the live table
    /// representations — the Eq 7/12 ledger)
    pub peak_mem_per_rank: Vec<u64>,
    /// what the per-rank peaks would have been under the unconditional
    /// dense layout (the `DualAccountant` baseline ledger); equal to
    /// `peak_mem_per_rank` in dense mode
    pub peak_mem_dense_per_rank: Vec<u64>,
    /// final-iteration storage outcome per subtemplate
    pub storage: Vec<StorageDecision>,
    /// final-iteration frontier-pruning outcome per subtemplate (the
    /// `--prune` knob; occupancies are measured even with pruning off)
    pub prune: Vec<PruneStats>,
    /// calibrated seconds per compute unit
    pub flop_time: f64,
    pub threads: ThreadStats,
    /// measured per-worker execution record of the real combine executor,
    /// summed over every combine of the run (empty-ish when the XLA
    /// backend bypassed the executor)
    pub workers: ExecStats,
    /// the exchange schedule chosen for each non-leaf subtemplate
    pub comm_decisions: Vec<CommDecision>,
    /// measured overlap/memory record of the rank-parallel pipelined
    /// executor — real per-step ρ, exposed wait, per-rank `RecvBuffer`
    /// peaks — next to the *modeled* figures in [`RunResult::model`].
    /// `None` when the sequential executor ran (config, or XLA fallback).
    pub measured: Option<MeasuredPipeline>,
    /// modeled per-rank memory exceeded `mem_limit`
    pub oom: bool,
    /// resolved graph-storage backend the run used ("resident" or "mmap"
    /// — `auto` resolves before the plan builds, so it never appears here)
    pub graph_storage: String,
    /// graph bytes each rank kept resident, as charged to the memory
    /// ledger: an even CSR share when resident, the rank's own
    /// partition-proportional segment slice when sharded
    pub graph_resident_per_rank: Vec<u64>,
    /// measured per-rank link parameters (socket fabric only; empty when
    /// an in-process fabric carried the exchange)
    pub link: Vec<RankLink>,
}

impl RunResult {
    pub fn peak_mem(&self) -> u64 {
        self.peak_mem_per_rank.iter().copied().max().unwrap_or(0)
    }

    /// Largest per-rank peak under the dense-baseline ledger.
    pub fn peak_mem_dense(&self) -> u64 {
        self.peak_mem_dense_per_rank
            .iter()
            .copied()
            .max()
            .unwrap_or(0)
    }

    /// Peak-memory delta against the dense baseline (the Fig-12-style
    /// savings the sparse storage buys; 0 in dense mode).
    pub fn peak_bytes_saved(&self) -> u64 {
        self.peak_mem_dense().saturating_sub(self.peak_mem())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_task_size_by_mode() {
        let mut c = RunConfig::default();
        c.task_size = 50;
        c.mode = ModeSelect::Naive;
        assert_eq!(c.effective_task_size(), 0);
        c.mode = ModeSelect::AdaptiveLb;
        assert_eq!(c.effective_task_size(), 50);
    }

    #[test]
    fn comm_mode_by_select() {
        let mut c = RunConfig::default();
        c.n_ranks = 8;
        c.mode = ModeSelect::Naive;
        assert_eq!(c.comm_mode(100.0), CommMode::AllToAll);
        c.mode = ModeSelect::Pipeline;
        assert_eq!(c.comm_mode(0.1), CommMode::Pipeline { g: 1 });
        c.mode = ModeSelect::Adaptive;
        assert_eq!(c.comm_mode(0.1), CommMode::AllToAll);
        assert!(matches!(c.comm_mode(100.0), CommMode::Pipeline { .. }));
    }

    #[test]
    fn exchange_exec_parse_roundtrip() {
        for e in [ExchangeExec::Sequential, ExchangeExec::Threaded] {
            assert_eq!(ExchangeExec::parse(e.name()), Some(e));
        }
        assert_eq!(ExchangeExec::parse("warp"), None);
        assert_eq!(RunConfig::default().exchange, ExchangeExec::Threaded);
    }

    #[test]
    fn fabric_kind_parse_roundtrip() {
        for k in [FabricKind::Threaded, FabricKind::Socket] {
            assert_eq!(FabricKind::parse(k.name()), Some(k));
        }
        assert_eq!(FabricKind::parse("carrier-pigeon"), None);
        assert_eq!(RunConfig::default().fabric, FabricKind::Threaded);
    }

    #[test]
    fn comm_ratio_math() {
        let m = ModelTime {
            total: 10.0,
            comm_exposed: 4.0,
            ..Default::default()
        };
        assert!((m.comm_ratio() - 0.4).abs() < 1e-12);
    }
}
