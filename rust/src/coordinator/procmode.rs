//! Process-mode rank orchestration: the launcher that spawns one OS
//! process per rank (the `harpsg-rank` worker binary), wires them into a
//! socket mesh, and merges their per-rank [`RunResult`]s — plus the
//! worker-side entry point those processes run.
//!
//! The protocol is deliberately line-oriented and replayable by hand:
//!
//! 1. launcher → worker (stdin): the canonical config block, one
//!    `key value` line each, terminated by `end-config`. Every worker
//!    receives byte-identical text; its FNV digest is the handshake
//!    config digest, so a worker launched with a different config is
//!    rejected at connect time with a typed error.
//! 2. worker → launcher (stdout): `HARPSG-RANK-ADDR <addr>` once its
//!    listener is bound (TCP port 0 resolves here, so peers race
//!    nothing).
//! 3. launcher → worker: `addrs <a0> <a1> …` — every rank's resolved
//!    address, rank-indexed.
//! 4. worker: establishes the [`SocketFabric`] mesh, runs
//!    [`DistributedRunner::run_on`] with its single owned rank, and
//!    emits its results between `HARPSG-RANK-BEGIN`/`HARPSG-RANK-END`
//!    as `key value…` lines (f64s travel as raw bit patterns in hex, so
//!    the merge is lossless).
//!
//! The merge reconstructs the in-process fold exactly: per-iteration
//! colorful partials sum in ascending rank order (the same 0-seeded f64
//! fold `run_on` does over owned ranks), so the merged estimate is
//! bit-identical to a threaded-fabric run of the same config. Modeled
//! timing (`model`, `threads`, `flop_time`) is rank 0's view — each
//! process models only its own rank; the decision-relevant inputs were
//! allreduced during the run, so rank 0's decisions and storage records
//! speak for every rank.

use super::dist::DistributedRunner;
use super::run::{
    CommDecision, EngineKind, ExchangeExec, FabricKind, ModeSelect, ModelTime, PruneStats,
    RankLink, RunConfig, RunResult, StorageDecision, ThreadStats,
};
use crate::api::HarpsgError;
use crate::colorcount::parallel::ExecStats;
use crate::colorcount::{median_of_means, EngineContext, KernelMode, PruneMode};
use crate::colorcount::storage::StorageMode;
use crate::comm::{config_digest, PeerAddr, SocketFabric, SocketOptions};
use crate::comm::socket::SocketListener;
use crate::graph::rmat::{generate, RmatParams};
use crate::graph::shard::GraphStorageMode;
use crate::graph::{loader, Dataset, Graph};
use crate::template::{builtin, Template, BUILTIN_NAMES};
use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};

/// Marker a worker prints (stdout) once its listener is bound.
pub const ADDR_TAG: &str = "HARPSG-RANK-ADDR";
/// Marker opening a worker's result block.
pub const BEGIN_TAG: &str = "HARPSG-RANK-BEGIN";
/// Marker closing a worker's result block.
pub const END_TAG: &str = "HARPSG-RANK-END";
/// Terminates the config block on a worker's stdin.
const CFG_END: &str = "end-config";
/// Prefixes the rank-indexed address list on a worker's stdin.
const ADDRS_KEY: &str = "addrs";
/// Env var overriding where the launcher finds the worker binary
/// (defaults to a `harpsg-rank` sibling of the current executable).
pub const RANK_BIN_ENV: &str = "HARPSG_RANK_BIN";

/// Everything a process-mode run needs beyond the [`RunConfig`]: the
/// template and graph are passed as *specs* (not objects) because every
/// worker process re-resolves them deterministically from the same text.
#[derive(Debug, Clone)]
pub struct ProcSpec {
    /// builtin template name (`u3-1`, …) or a template file path
    pub template: String,
    /// graph spec: `rmat:<nv>:<ne>:<skew>:<seed>`, a dataset
    /// abbreviation (`MI`, `OR`, …, `R500K3`), or an edge-list path
    pub dataset: String,
    /// downscale divisor for dataset abbreviations (ignored otherwise)
    pub scale: u32,
    /// `tcp` (localhost, ephemeral ports) or `unix:<dir>` (one socket
    /// file per rank under `<dir>`)
    pub listen: String,
    /// explicit worker binary; `None` falls back to [`RANK_BIN_ENV`]
    /// then to the `harpsg-rank` sibling of the current executable
    pub rank_bin: Option<PathBuf>,
    pub cfg: RunConfig,
}

impl ProcSpec {
    pub fn new(template: &str, dataset: &str, scale: u32, cfg: RunConfig) -> ProcSpec {
        ProcSpec {
            template: template.to_string(),
            dataset: dataset.to_string(),
            scale,
            listen: "tcp".to_string(),
            rank_bin: None,
            cfg,
        }
    }
}

fn bits(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn parse_bits(s: &str) -> Result<f64, HarpsgError> {
    u64::from_str_radix(s, 16)
        .map(f64::from_bits)
        .map_err(|e| HarpsgError::Parse(format!("bad f64 bit pattern `{s}`: {e}")))
}

fn parse_num<T: std::str::FromStr>(key: &str, s: &str) -> Result<T, HarpsgError>
where
    T::Err: std::fmt::Display,
{
    s.parse()
        .map_err(|e| HarpsgError::Parse(format!("bad value for `{key}`: `{s}`: {e}")))
}

fn parse_opt_u64(key: &str, s: &str) -> Result<Option<u64>, HarpsgError> {
    if s == "none" {
        Ok(None)
    } else {
        parse_num(key, s).map(Some)
    }
}

fn opt_u64(v: Option<u64>) -> String {
    match v {
        Some(b) => b.to_string(),
        None => "none".to_string(),
    }
}

/// The canonical config block: one `key value` line per field, in fixed
/// order, f64s as bit patterns. Identical `ProcSpec`s produce identical
/// text — the launcher sends this to every worker, and its
/// [`config_digest`] is what the socket handshake verifies, so a worker
/// holding as much as one different bit refuses to join the mesh.
pub fn canonical_config(spec: &ProcSpec) -> String {
    let c = &spec.cfg;
    let mut s = String::new();
    let mut kv = |k: &str, v: String| {
        s.push_str(k);
        s.push(' ');
        s.push_str(&v);
        s.push('\n');
    };
    kv("template", spec.template.clone());
    kv("dataset", spec.dataset.clone());
    kv("scale", spec.scale.to_string());
    kv("listen", spec.listen.clone());
    kv("n-ranks", c.n_ranks.to_string());
    kv("n-threads", c.n_threads.to_string());
    kv("n-workers", c.n_workers.to_string());
    kv("task-size", c.task_size.to_string());
    kv("mode", c.mode.flag().to_string());
    kv("n-iterations", c.n_iterations.to_string());
    kv("seed", c.seed.to_string());
    kv("mem-limit", opt_u64(c.mem_limit));
    kv("engine", c.engine.name().to_string());
    kv("phys-cores", c.phys_cores.to_string());
    kv("task-overhead-units", bits(c.task_overhead_units));
    kv("exchange", c.exchange.name().to_string());
    kv("adaptive-group", (c.adaptive_group as u8).to_string());
    kv("table-storage", c.table_storage.name().to_string());
    kv("kernel", c.kernel.name().to_string());
    kv("graph-storage", c.graph_storage.name().to_string());
    kv("graph-budget", opt_u64(c.graph_budget));
    kv("fabric", c.fabric.name().to_string());
    kv("prune", c.prune.name().to_string());
    kv("policy-intensity-threshold", bits(c.policy.intensity_threshold));
    kv("policy-min-ranks", c.policy.min_ranks.to_string());
    kv("policy-flop-time", bits(c.policy.flop_time));
    kv("policy-net-alpha", bits(c.policy.net.alpha));
    kv("policy-net-beta", bits(c.policy.net.beta));
    kv("policy-net-step-overhead", bits(c.policy.net.step_overhead));
    kv("net-alpha", bits(c.net.alpha));
    kv("net-beta", bits(c.net.beta));
    kv("net-step-overhead", bits(c.net.step_overhead));
    s
}

/// Inverse of [`canonical_config`]. Strict: unknown keys are typed
/// errors, so a launcher/worker version skew fails loudly instead of
/// silently dropping a knob (the digest would catch it anyway, but this
/// error names the key).
pub fn parse_config(text: &str) -> Result<ProcSpec, HarpsgError> {
    let mut spec = ProcSpec::new("", "", 0, RunConfig::default());
    let c = &mut spec.cfg;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once(' ')
            .ok_or_else(|| HarpsgError::Parse(format!("config line without value: `{line}`")))?;
        let bad = |what: &str| HarpsgError::Parse(format!("unknown {what} `{v}`"));
        match k {
            "template" => spec.template = v.to_string(),
            "dataset" => spec.dataset = v.to_string(),
            "scale" => spec.scale = parse_num(k, v)?,
            "listen" => spec.listen = v.to_string(),
            "n-ranks" => c.n_ranks = parse_num(k, v)?,
            "n-threads" => c.n_threads = parse_num(k, v)?,
            "n-workers" => c.n_workers = parse_num(k, v)?,
            "task-size" => c.task_size = parse_num(k, v)?,
            "mode" => c.mode = ModeSelect::parse(v).ok_or_else(|| bad("mode"))?,
            "n-iterations" => c.n_iterations = parse_num(k, v)?,
            "seed" => c.seed = parse_num(k, v)?,
            "mem-limit" => c.mem_limit = parse_opt_u64(k, v)?,
            "engine" => c.engine = EngineKind::parse(v).ok_or_else(|| bad("engine"))?,
            "phys-cores" => c.phys_cores = parse_num(k, v)?,
            "task-overhead-units" => c.task_overhead_units = parse_bits(v)?,
            "exchange" => c.exchange = ExchangeExec::parse(v).ok_or_else(|| bad("exchange"))?,
            "adaptive-group" => c.adaptive_group = v == "1",
            "table-storage" => {
                c.table_storage = StorageMode::parse(v).ok_or_else(|| bad("table storage"))?
            }
            "kernel" => c.kernel = KernelMode::parse(v).ok_or_else(|| bad("kernel"))?,
            "graph-storage" => {
                c.graph_storage = GraphStorageMode::parse(v).ok_or_else(|| bad("graph storage"))?
            }
            "graph-budget" => c.graph_budget = parse_opt_u64(k, v)?,
            "fabric" => c.fabric = FabricKind::parse(v).ok_or_else(|| bad("fabric"))?,
            "prune" => c.prune = PruneMode::parse(v).ok_or_else(|| bad("prune"))?,
            "policy-intensity-threshold" => c.policy.intensity_threshold = parse_bits(v)?,
            "policy-min-ranks" => c.policy.min_ranks = parse_num(k, v)?,
            "policy-flop-time" => c.policy.flop_time = parse_bits(v)?,
            "policy-net-alpha" => c.policy.net.alpha = parse_bits(v)?,
            "policy-net-beta" => c.policy.net.beta = parse_bits(v)?,
            "policy-net-step-overhead" => c.policy.net.step_overhead = parse_bits(v)?,
            "net-alpha" => c.net.alpha = parse_bits(v)?,
            "net-beta" => c.net.beta = parse_bits(v)?,
            "net-step-overhead" => c.net.step_overhead = parse_bits(v)?,
            _ => return Err(HarpsgError::Parse(format!("unknown config key `{k}`"))),
        }
    }
    if spec.template.is_empty() || spec.dataset.is_empty() {
        return Err(HarpsgError::MissingValue(
            "process-mode config needs `template` and `dataset`".into(),
        ));
    }
    Ok(spec)
}

/// Resolve a template spec: builtin name, else template file path.
pub fn resolve_template(spec: &str) -> Result<Template, HarpsgError> {
    if BUILTIN_NAMES.contains(&spec) {
        return builtin(spec).map_err(|e| HarpsgError::Template(format!("{e:#}")));
    }
    let text = std::fs::read_to_string(spec)
        .map_err(|e| HarpsgError::Io(format!("read template file {spec}: {e}")))?;
    Template::parse(spec, &text).map_err(|e| HarpsgError::Template(format!("{e:#}")))
}

/// Resolve a graph spec. Every form is deterministic, so the launcher
/// and all worker processes materialize byte-identical graphs:
/// `rmat:<nv>:<ne>:<skew>:<seed>` generates directly, a dataset
/// abbreviation generates its paper analog at `scale`, anything else
/// loads as an edge-list file.
pub fn resolve_graph(spec: &str, scale: u32) -> Result<Graph, HarpsgError> {
    if let Some(rest) = spec.strip_prefix("rmat:") {
        let parts: Vec<&str> = rest.split(':').collect();
        if parts.len() != 4 {
            return Err(HarpsgError::Parse(format!(
                "bad rmat spec `{spec}` (want rmat:<nv>:<ne>:<skew>:<seed>)"
            )));
        }
        let nv: usize = parse_num("rmat nv", parts[0])?;
        let ne: u64 = parse_num("rmat ne", parts[1])?;
        let skew: u32 = parse_num("rmat skew", parts[2])?;
        let seed: u64 = parse_num("rmat seed", parts[3])?;
        return Ok(generate(&RmatParams::with_skew(nv, ne, skew, seed)));
    }
    let ds = match spec {
        "MI" => Some(Dataset::MiamiS),
        "OR" => Some(Dataset::OrkutS),
        "NY" => Some(Dataset::NycS),
        "TW" => Some(Dataset::TwitterS),
        "SK" => Some(Dataset::SkS),
        "FR" => Some(Dataset::FriendsterS),
        "R250K1" => Some(Dataset::R250K1),
        "R250K3" => Some(Dataset::R250K3),
        "R250K8" => Some(Dataset::R250K8),
        "R500K3" => Some(Dataset::R500K3),
        _ => None,
    };
    match ds {
        Some(d) => Ok(d.generate(scale)),
        None => loader::load_edge_list(std::path::Path::new(spec))
            .map_err(|e| HarpsgError::Io(format!("load graph {spec}: {e:#}"))),
    }
}

/// The listen address rank `r` binds: localhost with an ephemeral port
/// for `tcp` (the resolved port is advertised after bind), a per-rank
/// socket file under the directory for `unix:<dir>`.
fn bind_spec(listen: &str, rank: usize) -> Result<PeerAddr, HarpsgError> {
    if listen == "tcp" {
        Ok(PeerAddr::Tcp("127.0.0.1:0".to_string()))
    } else if let Some(dir) = listen.strip_prefix("unix:") {
        Ok(PeerAddr::Unix(PathBuf::from(dir).join(format!("rank{rank}.sock"))))
    } else {
        Err(HarpsgError::Parse(format!(
            "bad listen spec `{listen}` (want `tcp` or `unix:<dir>`)"
        )))
    }
}

/// One worker's reported results, straight off its stdout block.
struct RankOutput {
    colorful: Vec<f64>,
    real_seconds: f64,
    peak_mem: u64,
    peak_mem_dense: u64,
    graph_resident: u64,
    oom: bool,
    flop_time: f64,
    graph_storage: String,
    model: ModelTime,
    avg_concurrency: f64,
    hist: Vec<f64>,
    decisions: Vec<CommDecision>,
    storage: Vec<StorageDecision>,
    prune: Vec<PruneStats>,
    link: Vec<RankLink>,
}

impl Default for RankOutput {
    fn default() -> Self {
        RankOutput {
            colorful: Vec::new(),
            real_seconds: 0.0,
            peak_mem: 0,
            peak_mem_dense: 0,
            graph_resident: 0,
            oom: false,
            flop_time: 0.0,
            graph_storage: String::new(),
            model: ModelTime::default(),
            avg_concurrency: 0.0,
            hist: Vec::new(),
            decisions: Vec::new(),
            storage: Vec::new(),
            prune: Vec::new(),
            link: Vec::new(),
        }
    }
}

/// Emit one rank's [`RunResult`] as the result block (the worker side of
/// the protocol). `rank` selects the per-rank entries this process owns.
fn emit_result(out: &mut impl Write, rank: usize, r: &RunResult) -> std::io::Result<()> {
    writeln!(out, "{BEGIN_TAG}")?;
    let joined = |vals: &[f64]| {
        vals.iter().map(|&v| bits(v)).collect::<Vec<_>>().join(" ")
    };
    if !r.colorful.is_empty() {
        writeln!(out, "colorful {}", joined(&r.colorful))?;
    }
    writeln!(out, "real-seconds {}", bits(r.real_seconds))?;
    writeln!(out, "peak-mem {}", r.peak_mem_per_rank.get(rank).copied().unwrap_or(0))?;
    writeln!(
        out,
        "peak-mem-dense {}",
        r.peak_mem_dense_per_rank.get(rank).copied().unwrap_or(0)
    )?;
    writeln!(
        out,
        "graph-resident {}",
        r.graph_resident_per_rank.get(rank).copied().unwrap_or(0)
    )?;
    writeln!(out, "oom {}", r.oom as u8)?;
    writeln!(out, "flop-time {}", bits(r.flop_time))?;
    writeln!(out, "graph-storage {}", r.graph_storage)?;
    writeln!(
        out,
        "model {} {} {} {} {}",
        bits(r.model.total),
        bits(r.model.comp),
        bits(r.model.comm_exposed),
        bits(r.model.comm_total),
        bits(r.model.straggler)
    )?;
    for &(sub, rho) in &r.model.rho_by_sub {
        writeln!(out, "rho {sub} {}", bits(rho))?;
    }
    writeln!(out, "avg-concurrency {}", bits(r.threads.avg_concurrency))?;
    if !r.threads.concurrency_histogram.is_empty() {
        writeln!(out, "hist {}", joined(&r.threads.concurrency_histogram))?;
    }
    for d in &r.comm_decisions {
        let meas = match d.measured_rho {
            Some(m) => bits(m),
            None => "none".to_string(),
        };
        writeln!(
            out,
            "decision {} {} {} {} {} {meas}",
            d.sub,
            d.pipelined as u8,
            d.g,
            d.n_steps,
            bits(d.predicted_rho)
        )?;
    }
    for s in &r.storage {
        writeln!(
            out,
            "storage {} {} {} {} {} {}",
            s.sub,
            bits(s.density),
            s.sparse_ranks,
            s.n_ranks,
            s.dense_bytes,
            s.resident_bytes
        )?;
    }
    for s in &r.prune {
        writeln!(
            out,
            "prune {} {} {} {} {}",
            s.sub,
            bits(s.frontier_occupancy),
            s.pairs_skipped,
            s.rows_skipped,
            s.wire_rows_dropped
        )?;
    }
    for l in &r.link {
        writeln!(
            out,
            "link {} {} {} {}",
            l.rank,
            bits(l.alpha_s),
            bits(l.beta_s_per_byte),
            l.samples
        )?;
    }
    writeln!(out, "{END_TAG}")?;
    out.flush()
}

/// Parse the result block of worker `rank` from its stdout lines
/// (everything between [`BEGIN_TAG`] and [`END_TAG`]).
fn parse_result(rank: usize, lines: &mut impl Iterator<Item = std::io::Result<String>>) -> Result<RankOutput, HarpsgError> {
    let io_err = |e: std::io::Error| HarpsgError::Transport(format!("rank {rank} stdout: {e}"));
    let mut seen_begin = false;
    let mut o = RankOutput::default();
    loop {
        let line = match lines.next() {
            Some(l) => l.map_err(io_err)?,
            None => {
                return Err(HarpsgError::Transport(format!(
                    "rank {rank} exited before its result block completed"
                )))
            }
        };
        let line = line.trim().to_string();
        if !seen_begin {
            // tolerate stray diagnostics before the block opens
            if line == BEGIN_TAG {
                seen_begin = true;
            }
            continue;
        }
        if line == END_TAG {
            return Ok(o);
        }
        let (k, v) = line
            .split_once(' ')
            .ok_or_else(|| HarpsgError::Parse(format!("rank {rank}: bad result line `{line}`")))?;
        let fields: Vec<&str> = v.split_whitespace().collect();
        let want = |n: usize| -> Result<(), HarpsgError> {
            if fields.len() == n {
                Ok(())
            } else {
                Err(HarpsgError::Parse(format!(
                    "rank {rank}: `{k}` wants {n} fields, got {}",
                    fields.len()
                )))
            }
        };
        match k {
            "colorful" => {
                o.colorful = fields.iter().map(|&f| parse_bits(f)).collect::<Result<_, _>>()?
            }
            "real-seconds" => o.real_seconds = parse_bits(v)?,
            "peak-mem" => o.peak_mem = parse_num(k, v)?,
            "peak-mem-dense" => o.peak_mem_dense = parse_num(k, v)?,
            "graph-resident" => o.graph_resident = parse_num(k, v)?,
            "oom" => o.oom = v == "1",
            "flop-time" => o.flop_time = parse_bits(v)?,
            "graph-storage" => o.graph_storage = v.to_string(),
            "model" => {
                want(5)?;
                o.model.total = parse_bits(fields[0])?;
                o.model.comp = parse_bits(fields[1])?;
                o.model.comm_exposed = parse_bits(fields[2])?;
                o.model.comm_total = parse_bits(fields[3])?;
                o.model.straggler = parse_bits(fields[4])?;
            }
            "rho" => {
                want(2)?;
                o.model
                    .rho_by_sub
                    .push((parse_num("rho sub", fields[0])?, parse_bits(fields[1])?));
            }
            "avg-concurrency" => o.avg_concurrency = parse_bits(v)?,
            "hist" => {
                o.hist = fields.iter().map(|&f| parse_bits(f)).collect::<Result<_, _>>()?
            }
            "decision" => {
                want(6)?;
                o.decisions.push(CommDecision {
                    sub: parse_num("decision sub", fields[0])?,
                    pipelined: fields[1] == "1",
                    g: parse_num("decision g", fields[2])?,
                    n_steps: parse_num("decision n_steps", fields[3])?,
                    predicted_rho: parse_bits(fields[4])?,
                    measured_rho: if fields[5] == "none" {
                        None
                    } else {
                        Some(parse_bits(fields[5])?)
                    },
                });
            }
            "storage" => {
                want(6)?;
                o.storage.push(StorageDecision {
                    sub: parse_num("storage sub", fields[0])?,
                    density: parse_bits(fields[1])?,
                    sparse_ranks: parse_num("storage sparse_ranks", fields[2])?,
                    n_ranks: parse_num("storage n_ranks", fields[3])?,
                    dense_bytes: parse_num("storage dense_bytes", fields[4])?,
                    resident_bytes: parse_num("storage resident_bytes", fields[5])?,
                });
            }
            "prune" => {
                want(5)?;
                o.prune.push(PruneStats {
                    sub: parse_num("prune sub", fields[0])?,
                    frontier_occupancy: parse_bits(fields[1])?,
                    pairs_skipped: parse_num("prune pairs_skipped", fields[2])?,
                    rows_skipped: parse_num("prune rows_skipped", fields[3])?,
                    wire_rows_dropped: parse_num("prune wire_rows_dropped", fields[4])?,
                });
            }
            "link" => {
                want(4)?;
                o.link.push(RankLink {
                    rank: parse_num("link rank", fields[0])?,
                    alpha_s: parse_bits(fields[1])?,
                    beta_s_per_byte: parse_bits(fields[2])?,
                    samples: parse_num("link samples", fields[3])?,
                });
            }
            _ => {
                return Err(HarpsgError::Parse(format!(
                    "rank {rank}: unknown result key `{k}`"
                )))
            }
        }
    }
}

/// The worker-process entry point behind the `harpsg-rank` binary:
/// `harpsg-rank --rank <r>` with the config block on stdin. Everything
/// the binary does funnels through here so the protocol stays inside
/// `coordinator/` (the binary itself never names a transport type).
pub fn rank_main(args: &[String]) -> Result<(), HarpsgError> {
    let mut rank: Option<usize> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--rank" => {
                let v = it
                    .next()
                    .ok_or_else(|| HarpsgError::MissingValue("--rank".into()))?;
                rank = Some(parse_num("--rank", v)?);
            }
            other => return Err(HarpsgError::UnknownFlag(other.to_string())),
        }
    }
    let rank = rank.ok_or_else(|| HarpsgError::MissingValue("--rank".into()))?;

    let stdin = std::io::stdin();
    let mut lines = stdin.lock().lines();
    let mut cfg_text = String::new();
    loop {
        let line = lines
            .next()
            .ok_or_else(|| {
                HarpsgError::Transport(format!("rank {rank}: stdin closed before `{CFG_END}`"))
            })?
            .map_err(|e| HarpsgError::Transport(format!("rank {rank} stdin: {e}")))?;
        if line.trim() == CFG_END {
            break;
        }
        cfg_text.push_str(&line);
        cfg_text.push('\n');
    }
    let digest = config_digest(&cfg_text);
    let spec = parse_config(&cfg_text)?;
    let cfg = spec.cfg.clone();
    if rank >= cfg.n_ranks {
        return Err(HarpsgError::InvalidJob(format!(
            "--rank {rank} out of range for {} ranks",
            cfg.n_ranks
        )));
    }
    if cfg.engine == EngineKind::Xla {
        return Err(HarpsgError::InvalidJob(
            "the socket fabric requires the native engine".into(),
        ));
    }
    let t = resolve_template(&spec.template)?;
    let g = resolve_graph(&spec.dataset, spec.scale)?;

    let listener = SocketListener::bind(&bind_spec(&spec.listen, rank)?)
        .map_err(|e| HarpsgError::Io(format!("rank {rank} bind: {e}")))?;
    {
        let mut out = std::io::stdout().lock();
        writeln!(out, "{ADDR_TAG} {}", listener.local_addr())
            .and_then(|_| out.flush())
            .map_err(|e| HarpsgError::Transport(format!("rank {rank} stdout: {e}")))?;
    }

    let addr_line = lines
        .next()
        .ok_or_else(|| {
            HarpsgError::Transport(format!("rank {rank}: stdin closed before `{ADDRS_KEY}`"))
        })?
        .map_err(|e| HarpsgError::Transport(format!("rank {rank} stdin: {e}")))?;
    let rest = addr_line
        .trim()
        .strip_prefix(ADDRS_KEY)
        .ok_or_else(|| {
            HarpsgError::Parse(format!("rank {rank}: expected `{ADDRS_KEY} …`, got `{addr_line}`"))
        })?;
    let peers: Vec<PeerAddr> = rest.split_whitespace().map(PeerAddr::parse).collect();
    if peers.len() != cfg.n_ranks {
        return Err(HarpsgError::Parse(format!(
            "rank {rank}: got {} peer addresses for {} ranks",
            peers.len(),
            cfg.n_ranks
        )));
    }

    let fabric = SocketFabric::establish(
        rank,
        listener,
        &peers,
        digest,
        cfg.n_ranks.max(1),
        SocketOptions::default(),
    )?;
    let mut runner = DistributedRunner::new(&t, &g, cfg);
    let result = runner.run_on(&fabric, &[rank])?;
    {
        let mut out = std::io::stdout().lock();
        emit_result(&mut out, rank, &result)
            .map_err(|e| HarpsgError::Transport(format!("rank {rank} stdout: {e}")))?;
    }
    fabric.finish();
    Ok(())
}

/// Where the launcher finds the worker binary.
fn rank_binary(spec: &ProcSpec) -> Result<PathBuf, HarpsgError> {
    if let Some(p) = &spec.rank_bin {
        return Ok(p.clone());
    }
    if let Ok(p) = std::env::var(RANK_BIN_ENV) {
        return Ok(PathBuf::from(p));
    }
    let me = std::env::current_exe()
        .map_err(|e| HarpsgError::Io(format!("current_exe: {e}")))?;
    Ok(me.with_file_name("harpsg-rank"))
}

fn kill_all(children: &mut [(usize, Child)]) {
    for (_, c) in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// Spawn one `harpsg-rank` process per rank, run the distributed count
/// over the socket mesh, and merge the per-rank results into one
/// [`RunResult`] (see the module docs for the merge contract). Any
/// worker failure — bad exit, protocol violation, transport error —
/// kills the remaining workers and surfaces as a typed error.
pub fn launch(spec: &ProcSpec) -> Result<RunResult, HarpsgError> {
    let n_ranks = spec.cfg.n_ranks;
    if n_ranks == 0 {
        return Err(HarpsgError::InvalidJob("n_ranks must be ≥ 1".into()));
    }
    if spec.cfg.engine == EngineKind::Xla {
        return Err(HarpsgError::InvalidJob(
            "the socket fabric requires the native engine".into(),
        ));
    }
    // resolve the template up front: the merge rescales the summed
    // colorful counts exactly like `run_on` does per process, and a bad
    // spec should fail before any process spawns
    let t = resolve_template(&spec.template)?;
    let ctx = EngineContext::new(&t);
    let bin = rank_binary(spec)?;
    let config = canonical_config(spec);

    let mut children: Vec<(usize, Child)> = Vec::with_capacity(n_ranks);
    for r in 0..n_ranks {
        let spawned = Command::new(&bin)
            .arg("--rank")
            .arg(r.to_string())
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn();
        match spawned {
            Ok(child) => children.push((r, child)),
            Err(e) => {
                kill_all(&mut children);
                return Err(HarpsgError::Io(format!(
                    "spawn {} for rank {r}: {e}",
                    bin.display()
                )));
            }
        }
    }

    let run = |children: &mut Vec<(usize, Child)>| -> Result<RunResult, HarpsgError> {
        // phase 1: config out, bound addresses back
        let mut readers = Vec::with_capacity(n_ranks);
        for (r, child) in children.iter_mut() {
            let r = *r;
            let mut stdin = child.stdin.take().expect("piped stdin");
            stdin
                .write_all(config.as_bytes())
                .and_then(|_| stdin.write_all(format!("{CFG_END}\n").as_bytes()))
                .map_err(|e| HarpsgError::Transport(format!("rank {r} stdin: {e}")))?;
            let stdout = BufReader::new(child.stdout.take().expect("piped stdout"));
            // keep stdin open: the address list goes out in phase 2
            readers.push((r, stdin, stdout.lines()));
        }
        let mut addrs = Vec::with_capacity(n_ranks);
        for (r, _, lines) in readers.iter_mut() {
            let r = *r;
            loop {
                let line = lines
                    .next()
                    .ok_or_else(|| {
                        HarpsgError::Transport(format!(
                            "rank {r} exited before advertising its address"
                        ))
                    })?
                    .map_err(|e| HarpsgError::Transport(format!("rank {r} stdout: {e}")))?;
                if let Some(addr) = line.trim().strip_prefix(ADDR_TAG) {
                    addrs.push(addr.trim().to_string());
                    break;
                }
            }
        }
        // phase 2: the full rank-indexed address list to every worker
        let addr_line = format!("{ADDRS_KEY} {}\n", addrs.join(" "));
        for (r, stdin, _) in readers.iter_mut() {
            stdin
                .write_all(addr_line.as_bytes())
                .map_err(|e| HarpsgError::Transport(format!("rank {} stdin: {e}", r)))?;
        }
        // phase 3: collect every worker's result block
        let mut outs = Vec::with_capacity(n_ranks);
        for (r, _, lines) in readers.iter_mut() {
            outs.push(parse_result(*r, lines)?);
        }
        drop(readers);
        for (r, child) in children.iter_mut() {
            let status = child
                .wait()
                .map_err(|e| HarpsgError::Transport(format!("rank {r} wait: {e}")))?;
            if !status.success() {
                return Err(HarpsgError::Transport(format!(
                    "rank {r} exited with {status}"
                )));
            }
        }
        Ok(merge(spec, &ctx, outs))
    };
    match run(&mut children) {
        Ok(r) => Ok(r),
        Err(e) => {
            kill_all(&mut children);
            Err(e)
        }
    }
}

/// Fold the per-rank outputs into one [`RunResult`]. Counts merge
/// exactly: ascending-rank f64 summation of the per-iteration colorful
/// partials reproduces `run_on`'s in-process fold bit for bit, and the
/// estimate is recomputed from the merged samples with the same
/// median-of-means call. Decision/storage records come from rank 0 —
/// the in-run allreduce made them identical on every rank.
fn merge(spec: &ProcSpec, ctx: &EngineContext, outs: Vec<RankOutput>) -> RunResult {
    let iters = outs.first().map(|o| o.colorful.len()).unwrap_or(0);
    let mut colorful = vec![0.0f64; iters];
    for o in &outs {
        for (acc, &v) in colorful.iter_mut().zip(&o.colorful) {
            *acc += v;
        }
    }
    let scale = ctx.colorful_scale();
    let aut = ctx.aut as f64;
    let samples: Vec<f64> = colorful.iter().map(|&c| c * scale / aut).collect();
    let estimate = if samples.is_empty() {
        0.0
    } else {
        median_of_means(&samples, 3.min(samples.len()))
    };
    let first = &outs[0];
    RunResult {
        estimate,
        samples,
        colorful,
        model: first.model.clone(),
        real_seconds: outs.iter().map(|o| o.real_seconds).fold(0.0, f64::max),
        peak_mem_per_rank: outs.iter().map(|o| o.peak_mem).collect(),
        peak_mem_dense_per_rank: outs.iter().map(|o| o.peak_mem_dense).collect(),
        storage: first.storage.clone(),
        prune: first.prune.clone(),
        flop_time: first.flop_time,
        threads: ThreadStats {
            avg_concurrency: first.avg_concurrency,
            concurrency_histogram: first.hist.clone(),
        },
        comm_decisions: first.decisions.clone(),
        workers: ExecStats::zeros(spec.cfg.n_workers),
        measured: None,
        oom: outs.iter().any(|o| o.oom),
        graph_storage: first.graph_storage.clone(),
        graph_resident_per_rank: outs.iter().map(|o| o.graph_resident).collect(),
        link: outs.iter().flat_map(|o| o.link.iter().copied()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ProcSpec {
        let mut cfg = RunConfig::default();
        cfg.n_ranks = 3;
        cfg.seed = 99;
        cfg.net.alpha = 1.25e-6;
        ProcSpec::new("u5-2", "rmat:64:300:3:7", 0, cfg)
    }

    #[test]
    fn config_roundtrips_bit_exact() {
        let s = spec();
        let text = canonical_config(&s);
        let back = parse_config(&text).unwrap();
        assert_eq!(back.template, "u5-2");
        assert_eq!(back.dataset, "rmat:64:300:3:7");
        assert_eq!(back.cfg.n_ranks, 3);
        assert_eq!(back.cfg.seed, 99);
        assert_eq!(back.cfg.net.alpha.to_bits(), 1.25e-6f64.to_bits());
        // canonical text is a fixed point — same digest on every process
        assert_eq!(canonical_config(&back), text);
    }

    #[test]
    fn config_rejects_unknown_keys() {
        let e = parse_config("template u3-1\ndataset MI\nwarp-drive 9\n").unwrap_err();
        assert!(matches!(e, HarpsgError::Parse(_)), "{e}");
        assert!(e.to_string().contains("warp-drive"));
    }

    #[test]
    fn graph_specs_resolve_deterministically() {
        let a = resolve_graph("rmat:64:300:3:7", 0).unwrap();
        let b = resolve_graph("rmat:64:300:3:7", 0).unwrap();
        assert_eq!(a.n_vertices(), b.n_vertices());
        assert_eq!(a.n_edges, b.n_edges);
        assert!(resolve_graph("rmat:64:300", 0).is_err());
        let mi = resolve_graph("MI", 2000).unwrap();
        assert!(mi.n_vertices() > 0);
    }

    #[test]
    fn result_block_roundtrips_bit_exact() {
        let r = RunResult {
            estimate: 12.5,
            samples: vec![12.5],
            colorful: vec![3.75],
            model: ModelTime {
                total: 1.0,
                comp: 0.5,
                comm_exposed: 0.25,
                comm_total: 0.75,
                straggler: 0.125,
                rho_by_sub: vec![(2, 0.875)],
            },
            real_seconds: 0.5,
            peak_mem_per_rank: vec![0, 4096, 0],
            peak_mem_dense_per_rank: vec![0, 8192, 0],
            storage: vec![StorageDecision {
                sub: 2,
                density: 0.5,
                sparse_ranks: 1,
                n_ranks: 3,
                dense_bytes: 100,
                resident_bytes: 60,
            }],
            prune: vec![PruneStats {
                sub: 2,
                frontier_occupancy: 0.375,
                pairs_skipped: 40,
                rows_skipped: 9,
                wire_rows_dropped: 13,
            }],
            flop_time: 1e-9,
            threads: ThreadStats {
                avg_concurrency: 2.5,
                concurrency_histogram: vec![0.0, 1.0],
            },
            comm_decisions: vec![CommDecision {
                sub: 2,
                pipelined: true,
                g: 1,
                n_steps: 2,
                predicted_rho: 0.625,
                measured_rho: None,
            }],
            workers: ExecStats::zeros(1),
            measured: None,
            oom: false,
            graph_storage: "resident".to_string(),
            graph_resident_per_rank: vec![0, 128, 0],
            link: vec![RankLink {
                rank: 1,
                alpha_s: 2e-5,
                beta_s_per_byte: 3e-9,
                samples: 17,
            }],
        };
        let mut buf = Vec::new();
        emit_result(&mut buf, 1, &r).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let mut lines = text
            .lines()
            .map(|l| -> std::io::Result<String> { Ok(l.to_string()) });
        let o = parse_result(1, &mut lines).unwrap();
        assert_eq!(o.colorful, vec![3.75]);
        assert_eq!(o.peak_mem, 4096);
        assert_eq!(o.peak_mem_dense, 8192);
        assert_eq!(o.graph_resident, 128);
        assert_eq!(o.model.rho_by_sub, vec![(2, 0.875)]);
        assert_eq!(o.decisions, r.comm_decisions);
        assert_eq!(o.link, vec![r.link[0]]);
        assert_eq!(o.storage.len(), 1);
        assert_eq!(o.storage[0].resident_bytes, 60);
        assert_eq!(o.prune, r.prune);
        assert_eq!(
            o.prune[0].frontier_occupancy.to_bits(),
            0.375f64.to_bits()
        );
    }

    #[test]
    fn merge_sums_partials_in_rank_order() {
        let s = spec();
        let t = resolve_template("u3-1").unwrap();
        let ctx = EngineContext::new(&t);
        let mk = |c: Vec<f64>, peak: u64| RankOutput {
            colorful: c,
            peak_mem: peak,
            peak_mem_dense: peak,
            real_seconds: peak as f64,
            ..RankOutput::default()
        };
        let merged = merge(
            &s,
            &ctx,
            vec![mk(vec![1.0, 2.0], 10), mk(vec![3.0, 4.0], 30), mk(vec![5.0, 6.0], 20)],
        );
        assert_eq!(merged.colorful, vec![9.0, 12.0]);
        assert_eq!(merged.peak_mem_per_rank, vec![10, 30, 20]);
        assert_eq!(merged.peak_mem(), 30);
        assert_eq!(merged.real_seconds, 30.0);
        let scale = ctx.colorful_scale();
        let aut = ctx.aut as f64;
        assert_eq!(merged.samples[0].to_bits(), (9.0 * scale / aut).to_bits());
    }

    #[test]
    fn bind_specs_cover_both_transports() {
        assert_eq!(
            bind_spec("tcp", 3).unwrap(),
            PeerAddr::Tcp("127.0.0.1:0".into())
        );
        assert_eq!(
            bind_spec("unix:/tmp/x", 2).unwrap(),
            PeerAddr::Unix(PathBuf::from("/tmp/x/rank2.sock"))
        );
        assert!(bind_spec("carrier-pigeon", 0).is_err());
    }
}
