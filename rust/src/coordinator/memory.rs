//! Per-rank peak-memory accounting (Eq 7 / Eq 12, Fig 12).
//!
//! Tracks the bytes of every live intermediate buffer by category — count
//! tables `C(v,Ti)`, received remote rows `C(u,Ti)`, and the aggregation
//! scratch — and records the high-water mark. The Naive (all-to-all) mode
//! holds *all* remote rows of a combine at once; the pipelined mode holds
//! one step's slice at a time: the 2–5× peak reduction of Fig 12 falls
//! straight out of this ledger.

use crate::util::shim::AtomicU64;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemClass {
    CountTable,
    RecvBuffer,
    Scratch,
    /// the fully resident shared CSR (an even n_ranks⁻¹ share per rank)
    Graph,
    /// a rank's own partition-proportional adjacency slice under
    /// `--graph-storage mmap` — the out-of-core bound the ledger verifies
    GraphShard,
}

const N_CLASSES: usize = 5;

fn class_idx(c: MemClass) -> usize {
    match c {
        MemClass::CountTable => 0,
        MemClass::RecvBuffer => 1,
        MemClass::Scratch => 2,
        MemClass::Graph => 3,
        MemClass::GraphShard => 4,
    }
}

#[derive(Debug, Clone, Default)]
pub struct MemoryAccountant {
    current: [u64; N_CLASSES],
    pub peak: u64,
    /// breakdown of the peak moment
    pub peak_by_class: [u64; N_CLASSES],
}

impl MemoryAccountant {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&mut self, class: MemClass, bytes: u64) {
        self.current[class_idx(class)] += bytes;
        let total = self.total();
        if total > self.peak {
            self.peak = total;
            self.peak_by_class = self.current;
        }
    }

    pub fn free(&mut self, class: MemClass, bytes: u64) {
        let c = &mut self.current[class_idx(class)];
        debug_assert!(*c >= bytes, "freeing {bytes} from {c} in {class:?}");
        *c = c.saturating_sub(bytes);
    }

    pub fn total(&self) -> u64 {
        self.current.iter().sum()
    }

    pub fn current(&self, class: MemClass) -> u64 {
        self.current[class_idx(class)]
    }
}

/// Two ledgers in lockstep: the **real** one charges the resident bytes
/// of whichever table/packet representation is actually live (dense rows
/// or sparse `(set_rank, count)` entries — `colorcount::storage`), while
/// the **dense** one charges what the unconditional dense layout would
/// have held at the same program points. Their peaks are the run's
/// `peak_mem_per_rank` and `peak_mem_dense_per_rank`; the difference is
/// the report's `bytes_saved` — the Eq 7/12 accounting measured against
/// its own dense baseline without running the job twice.
///
/// Classes whose bytes are representation-independent (graph CSR,
/// aggregation scratch) are charged identically through [`Self::alloc`];
/// count tables and receive buffers go through [`Self::alloc2`] with
/// both byte counts.
#[derive(Debug, Clone, Default)]
pub struct DualAccountant {
    pub real: MemoryAccountant,
    pub dense: MemoryAccountant,
}

impl DualAccountant {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge both ledgers the same bytes (representation-independent
    /// allocations).
    pub fn alloc(&mut self, class: MemClass, bytes: u64) {
        self.alloc2(class, bytes, bytes);
    }

    pub fn free(&mut self, class: MemClass, bytes: u64) {
        self.free2(class, bytes, bytes);
    }

    /// Charge the live representation's bytes to the real ledger and the
    /// dense layout's bytes to the baseline ledger.
    pub fn alloc2(&mut self, class: MemClass, real_bytes: u64, dense_bytes: u64) {
        self.real.alloc(class, real_bytes);
        self.dense.alloc(class, dense_bytes);
    }

    pub fn free2(&mut self, class: MemClass, real_bytes: u64, dense_bytes: u64) {
        self.real.free(class, real_bytes);
        self.dense.free(class, dense_bytes);
    }

    /// Release everything both ledgers hold in `class` (the bulk-mode
    /// end-of-exchange drain, where the two sides hold different totals).
    pub fn release_all(&mut self, class: MemClass) {
        let r = self.real.current(class);
        let d = self.dense.current(class);
        self.free2(class, r, d);
    }

    /// The real ledger's current bytes in `class`.
    pub fn current(&self, class: MemClass) -> u64 {
        self.real.current(class)
    }
}

/// Thread-safe ledger for buffers that several threads allocate and free
/// concurrently — in the rank-parallel exchange executor, packet payloads
/// are charged by sender threads and released by receiver threads, so the
/// single-owner [`MemoryAccountant`] cannot account them. Lock-free:
/// per-class current bytes, a dedicated running total, and a monotone
/// high-water mark, all through [`crate::util::shim`] atomics (so the
/// `model-check` build can exhaustively explore this ledger's
/// interleavings).
///
/// The recorded peak is **exact** even under contention and across
/// classes: every `alloc`/`free` also updates the single `total` counter,
/// and `alloc` derives its high-water observation from that counter's
/// `fetch_add` return value — the combined ledger at the operation's own
/// linearization point. (A sum over per-class loads — how this used to
/// work — is not a consistent snapshot: it can pair one class's old
/// level with another's new one, over- or under-stating the true
/// concurrent maximum; the `model-check` regression tests exhibit both
/// failure modes on 2-thread schedules.) `free` saturates at zero, so a
/// racing over-release can never underflow either counter.
#[derive(Debug, Default)]
pub struct SharedAccountant {
    current: [AtomicU64; N_CLASSES],
    /// exact running sum over all classes; alloc/free keep it in lockstep
    /// with `current` so one RMW yields a consistent combined snapshot
    total: AtomicU64,
    peak: AtomicU64,
}

/// Atomically subtract up to `bytes` from `c`, clamping at zero. Returns
/// the amount actually removed (less than `bytes` only on over-release).
fn saturating_sub(c: &AtomicU64, bytes: u64) -> u64 {
    let mut cur = c.load();
    loop {
        let next = cur.saturating_sub(bytes);
        match c.compare_exchange_weak(cur, next) {
            Ok(_) => return cur - next,
            Err(observed) => cur = observed,
        }
    }
}

impl SharedAccountant {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&self, class: MemClass, bytes: u64) {
        // the running total's fetch_add return value IS the combined
        // ledger at this allocation's linearization point — no re-read
        // of other classes, hence no torn snapshot. Ordering (total
        // before class, the mirror of `free`) keeps `total >= sum of
        // classes` in every interleaving, so a racing free can never
        // strand bytes in the total.
        let after = self.total.fetch_add(bytes) + bytes;
        self.peak.fetch_max(after);
        self.current[class_idx(class)].fetch_add(bytes);
    }

    pub fn free(&self, class: MemClass, bytes: u64) {
        let removed = saturating_sub(&self.current[class_idx(class)], bytes);
        // deduct only what the class ledger really held, so an
        // over-release cannot drag the total below the other classes
        saturating_sub(&self.total, removed);
    }

    pub fn total(&self) -> u64 {
        self.total.load()
    }

    pub fn current(&self, class: MemClass) -> u64 {
        self.current[class_idx(class)].load()
    }

    pub fn peak(&self) -> u64 {
        self.peak.load()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peak() {
        let mut m = MemoryAccountant::new();
        m.alloc(MemClass::CountTable, 100);
        m.alloc(MemClass::RecvBuffer, 50);
        assert_eq!(m.peak, 150);
        m.free(MemClass::RecvBuffer, 50);
        m.alloc(MemClass::RecvBuffer, 20);
        assert_eq!(m.peak, 150, "peak is sticky");
        assert_eq!(m.total(), 120);
    }

    #[test]
    fn peak_breakdown() {
        let mut m = MemoryAccountant::new();
        m.alloc(MemClass::Graph, 10);
        m.alloc(MemClass::CountTable, 200);
        m.alloc(MemClass::Scratch, 5);
        assert_eq!(m.peak_by_class[class_idx(MemClass::CountTable)], 200);
        assert_eq!(m.peak_by_class[class_idx(MemClass::Graph)], 10);
    }

    #[test]
    fn shared_accountant_tracks_peak_and_saturates() {
        let m = SharedAccountant::new();
        m.alloc(MemClass::RecvBuffer, 100);
        m.alloc(MemClass::CountTable, 50);
        assert_eq!(m.total(), 150);
        assert_eq!(m.peak(), 150);
        m.free(MemClass::RecvBuffer, 100);
        assert_eq!(m.current(MemClass::RecvBuffer), 0);
        assert_eq!(m.peak(), 150, "peak is sticky");
        // saturating free: an over-release clamps at zero, never wraps
        m.free(MemClass::CountTable, 10_000);
        assert_eq!(m.current(MemClass::CountTable), 0);
        assert_eq!(m.total(), 0);
    }

    #[test]
    fn shared_accountant_concurrent_alloc_free() {
        // 8 threads × 200 balanced alloc/free rounds: the total never
        // underflows, the final ledger is exactly zero, and the recorded
        // peak is sane — at least one thread's live slice, at most the
        // sum of everything ever allocated.
        const THREADS: usize = 8;
        const ROUNDS: usize = 200;
        const BYTES: u64 = 64;
        let m = SharedAccountant::new();
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for _ in 0..ROUNDS {
                        m.alloc(MemClass::RecvBuffer, BYTES);
                        assert!(m.peak() >= m.current(MemClass::RecvBuffer));
                        m.free(MemClass::RecvBuffer, BYTES);
                    }
                });
            }
        });
        assert_eq!(m.total(), 0, "balanced alloc/free must return to zero");
        assert!(m.peak() >= BYTES);
        assert!(m.peak() <= (THREADS * ROUNDS) as u64 * BYTES);
    }

    #[test]
    fn dual_ledger_tracks_real_and_dense_baselines() {
        let mut m = DualAccountant::new();
        m.alloc(MemClass::Graph, 100); // representation-independent
        m.alloc2(MemClass::CountTable, 30, 400); // sparse table, dense worth 400
        assert_eq!(m.real.peak, 130);
        assert_eq!(m.dense.peak, 500);
        assert_eq!(m.current(MemClass::CountTable), 30);
        m.alloc2(MemClass::RecvBuffer, 8, 64);
        m.alloc2(MemClass::RecvBuffer, 16, 64);
        assert_eq!(m.real.peak, 154);
        assert_eq!(m.dense.peak, 628);
        m.release_all(MemClass::RecvBuffer);
        assert_eq!(m.real.current(MemClass::RecvBuffer), 0);
        assert_eq!(m.dense.current(MemClass::RecvBuffer), 0);
        m.free2(MemClass::CountTable, 30, 400);
        assert_eq!(m.real.total(), 100);
        assert_eq!(m.dense.total(), 100);
        // peaks stay sticky and ordered: real never exceeds dense when
        // every alloc2 charged real ≤ dense
        assert!(m.real.peak <= m.dense.peak);
    }

    #[test]
    fn peak_is_exact_not_just_bounded() {
        // two classes alive at once: the combined peak must be their sum
        // (the pre-fix scheme only guaranteed a bounded window here)
        let m = SharedAccountant::new();
        m.alloc(MemClass::CountTable, 70);
        m.alloc(MemClass::RecvBuffer, 30);
        m.free(MemClass::CountTable, 70);
        m.alloc(MemClass::Scratch, 10);
        assert_eq!(m.peak(), 100);
        assert_eq!(m.total(), 40);
    }

    #[test]
    fn pipeline_vs_bulk_shape() {
        // holding one 10-unit slice at a time peaks lower than nine at once
        let mut bulk = MemoryAccountant::new();
        bulk.alloc(MemClass::CountTable, 100);
        for _ in 0..9 {
            bulk.alloc(MemClass::RecvBuffer, 10);
        }
        let mut pipe = MemoryAccountant::new();
        pipe.alloc(MemClass::CountTable, 100);
        for _ in 0..9 {
            pipe.alloc(MemClass::RecvBuffer, 10);
            pipe.free(MemClass::RecvBuffer, 10);
        }
        assert_eq!(bulk.peak, 190);
        assert_eq!(pipe.peak, 110);
    }
}

/// Exhaustive small-config schedules of the shared ledger under the
/// bounded-interleaving model checker, including regression witnesses
/// that the pre-fix peak scheme (high-water from a sum of per-class
/// loads) both over- and under-counts on schedules the exact
/// running-total scheme handles correctly.
#[cfg(all(test, feature = "model-check"))]
mod model_tests {
    use super::*;
    use crate::util::shim::model;
    use std::sync::Arc;

    /// The historical `SharedAccountant` peak scheme, reconstructed for
    /// the regression demos: the allocated class's level is pinned by
    /// the fetch_add return value, but the *other* class is re-read — a
    /// torn snapshot under concurrency. Two classes suffice.
    #[derive(Default)]
    struct LegacyPeak {
        current: [AtomicU64; 2],
        peak: AtomicU64,
    }

    impl LegacyPeak {
        fn alloc(&self, idx: usize, bytes: u64) {
            let mut observed = self.current[idx].fetch_add(bytes) + bytes;
            observed += self.current[1 - idx].load();
            self.peak.fetch_max(observed);
        }

        fn free(&self, idx: usize, bytes: u64) {
            saturating_sub(&self.current[idx], bytes);
        }
    }

    #[test]
    fn model_conservation_and_exact_peak_invariants() {
        // T1 and T2 each run a balanced alloc/free in distinct classes.
        // Every schedule must conserve (final total zero) and record a
        // peak inside [max single class, sum of both]; across the
        // exploration both extremes must actually be witnessed.
        let hi = Arc::new(AtomicU64::new(0));
        let lo = Arc::new(AtomicU64::new(0));
        let (hi2, lo2) = (Arc::clone(&hi), Arc::clone(&lo));
        let n = model::Model::new().preemption_bound(2).check(move || {
            let m = Arc::new(SharedAccountant::new());
            let m1 = Arc::clone(&m);
            let t1 = model::spawn(move || {
                m1.alloc(MemClass::CountTable, 64);
                m1.free(MemClass::CountTable, 64);
            });
            let m2 = Arc::clone(&m);
            let t2 = model::spawn(move || {
                m2.alloc(MemClass::RecvBuffer, 32);
                m2.free(MemClass::RecvBuffer, 32);
            });
            t1.join();
            t2.join();
            assert_eq!(m.total(), 0, "balanced alloc/free must conserve");
            assert_eq!(m.current(MemClass::CountTable), 0);
            assert_eq!(m.current(MemClass::RecvBuffer), 0);
            let p = m.peak();
            assert!(p >= 64, "peak {p} below the largest single class");
            assert!(p <= 96, "peak {p} above everything ever allocated");
            if p == 96 {
                hi2.fetch_add(1);
            }
            if p == 64 {
                lo2.fetch_add(1);
            }
        });
        assert!(hi.load() > 0, "no schedule overlapped both classes ({n} runs)");
        assert!(lo.load() > 0, "no schedule serialized the classes ({n} runs)");
    }

    #[test]
    fn model_legacy_peak_undercounts_exact_catches_it() {
        // T1: alloc(A) then free(A). T2: alloc(B). In schedules where A
        // and B are simultaneously live the true combined peak is 200 —
        // the exact scheme records it, while the legacy torn snapshot
        // can miss it on both threads (T1 reads B before T2's add, T2
        // reads A after T1's free) and report only 100.
        let undercount = Arc::new(AtomicU64::new(0));
        let seen = Arc::clone(&undercount);
        let n = model::Model::new().preemption_bound(2).check(move || {
            let exact = Arc::new(SharedAccountant::new());
            let legacy = Arc::new(LegacyPeak::default());
            let (e1, l1) = (Arc::clone(&exact), Arc::clone(&legacy));
            let t1 = model::spawn(move || {
                e1.alloc(MemClass::CountTable, 100);
                l1.alloc(0, 100);
                e1.free(MemClass::CountTable, 100);
                l1.free(0, 100);
            });
            let (e2, l2) = (Arc::clone(&exact), Arc::clone(&legacy));
            let t2 = model::spawn(move || {
                e2.alloc(MemClass::RecvBuffer, 100);
                l2.alloc(1, 100);
            });
            t1.join();
            t2.join();
            assert_eq!(exact.total(), 100, "only T2's allocation is live");
            let (ep, lp) = (exact.peak(), legacy.peak.load());
            assert!(ep == 100 || ep == 200, "exact peak {ep}");
            // (no ordering between ep and lp holds in general: other
            // schedules of this same program make the legacy scheme
            // OVERcount instead — see the companion test)
            if lp < ep {
                seen.fetch_add(1);
            }
        });
        assert!(
            undercount.load() > 0,
            "exploration never witnessed the legacy undercount ({n} schedules)"
        );
    }

    #[test]
    fn model_legacy_peak_overcounts_exact_does_not() {
        // T1: alloc(A). T2: free(A) then alloc(B) — the cross-thread
        // release mirrors the fabric (sender charges, receiver frees).
        // The legacy scheme can pair T1's pinned A level with B's level
        // read *after* the free, reporting a 200-byte moment that never
        // existed; the exact running total can only ever see 100.
        let overcount = Arc::new(AtomicU64::new(0));
        let seen = Arc::clone(&overcount);
        let n = model::Model::new().preemption_bound(2).check(move || {
            let exact = Arc::new(SharedAccountant::new());
            let legacy = Arc::new(LegacyPeak::default());
            let (e1, l1) = (Arc::clone(&exact), Arc::clone(&legacy));
            let t1 = model::spawn(move || {
                e1.alloc(MemClass::CountTable, 100);
                l1.alloc(0, 100);
            });
            let (e2, l2) = (Arc::clone(&exact), Arc::clone(&legacy));
            let t2 = model::spawn(move || {
                e2.free(MemClass::CountTable, 100);
                l2.free(0, 100);
                e2.alloc(MemClass::RecvBuffer, 100);
                l2.alloc(1, 100);
            });
            t1.join();
            t2.join();
            let ep = exact.peak();
            let lp = legacy.peak.load();
            // at most one 100-byte buffer was ever live... unless the
            // free lost the race and removed nothing — then both are
            // legitimately live and 200 is the true peak. The legacy
            // overcount is the schedule where the peaks disagree.
            let live = exact.total();
            assert!(ep <= live.max(100) + 100, "exact peak {ep} unbounded");
            if lp > ep {
                seen.fetch_add(1);
                assert_eq!(lp, 200, "legacy overcount should report 200, got {lp}");
            }
            // conservation: the ledger always equals its class sum
            assert_eq!(
                live,
                exact.current(MemClass::CountTable) + exact.current(MemClass::RecvBuffer)
            );
        });
        assert!(
            overcount.load() > 0,
            "exploration never witnessed the legacy overcount ({n} schedules)"
        );
    }
}
