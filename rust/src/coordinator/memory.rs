//! Per-rank peak-memory accounting (Eq 7 / Eq 12, Fig 12).
//!
//! Tracks the bytes of every live intermediate buffer by category — count
//! tables `C(v,Ti)`, received remote rows `C(u,Ti)`, and the aggregation
//! scratch — and records the high-water mark. The Naive (all-to-all) mode
//! holds *all* remote rows of a combine at once; the pipelined mode holds
//! one step's slice at a time: the 2–5× peak reduction of Fig 12 falls
//! straight out of this ledger.

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemClass {
    CountTable,
    RecvBuffer,
    Scratch,
    Graph,
}

const N_CLASSES: usize = 4;

fn class_idx(c: MemClass) -> usize {
    match c {
        MemClass::CountTable => 0,
        MemClass::RecvBuffer => 1,
        MemClass::Scratch => 2,
        MemClass::Graph => 3,
    }
}

#[derive(Debug, Clone, Default)]
pub struct MemoryAccountant {
    current: [u64; N_CLASSES],
    pub peak: u64,
    /// breakdown of the peak moment
    pub peak_by_class: [u64; N_CLASSES],
}

impl MemoryAccountant {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&mut self, class: MemClass, bytes: u64) {
        self.current[class_idx(class)] += bytes;
        let total = self.total();
        if total > self.peak {
            self.peak = total;
            self.peak_by_class = self.current;
        }
    }

    pub fn free(&mut self, class: MemClass, bytes: u64) {
        let c = &mut self.current[class_idx(class)];
        debug_assert!(*c >= bytes, "freeing {bytes} from {c} in {class:?}");
        *c = c.saturating_sub(bytes);
    }

    pub fn total(&self) -> u64 {
        self.current.iter().sum()
    }

    pub fn current(&self, class: MemClass) -> u64 {
        self.current[class_idx(class)]
    }
}

/// Two ledgers in lockstep: the **real** one charges the resident bytes
/// of whichever table/packet representation is actually live (dense rows
/// or sparse `(set_rank, count)` entries — `colorcount::storage`), while
/// the **dense** one charges what the unconditional dense layout would
/// have held at the same program points. Their peaks are the run's
/// `peak_mem_per_rank` and `peak_mem_dense_per_rank`; the difference is
/// the report's `bytes_saved` — the Eq 7/12 accounting measured against
/// its own dense baseline without running the job twice.
///
/// Classes whose bytes are representation-independent (graph CSR,
/// aggregation scratch) are charged identically through [`Self::alloc`];
/// count tables and receive buffers go through [`Self::alloc2`] with
/// both byte counts.
#[derive(Debug, Clone, Default)]
pub struct DualAccountant {
    pub real: MemoryAccountant,
    pub dense: MemoryAccountant,
}

impl DualAccountant {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge both ledgers the same bytes (representation-independent
    /// allocations).
    pub fn alloc(&mut self, class: MemClass, bytes: u64) {
        self.alloc2(class, bytes, bytes);
    }

    pub fn free(&mut self, class: MemClass, bytes: u64) {
        self.free2(class, bytes, bytes);
    }

    /// Charge the live representation's bytes to the real ledger and the
    /// dense layout's bytes to the baseline ledger.
    pub fn alloc2(&mut self, class: MemClass, real_bytes: u64, dense_bytes: u64) {
        self.real.alloc(class, real_bytes);
        self.dense.alloc(class, dense_bytes);
    }

    pub fn free2(&mut self, class: MemClass, real_bytes: u64, dense_bytes: u64) {
        self.real.free(class, real_bytes);
        self.dense.free(class, dense_bytes);
    }

    /// Release everything both ledgers hold in `class` (the bulk-mode
    /// end-of-exchange drain, where the two sides hold different totals).
    pub fn release_all(&mut self, class: MemClass) {
        let r = self.real.current(class);
        let d = self.dense.current(class);
        self.free2(class, r, d);
    }

    /// The real ledger's current bytes in `class`.
    pub fn current(&self, class: MemClass) -> u64 {
        self.real.current(class)
    }
}

/// Thread-safe ledger for buffers that several threads allocate and free
/// concurrently — in the rank-parallel exchange executor, packet payloads
/// are charged by sender threads and released by receiver threads, so the
/// single-owner [`MemoryAccountant`] cannot account them. Lock-free:
/// per-class current bytes plus a monotone high-water mark.
///
/// The allocated class's contribution to the peak is exact even under
/// contention: `alloc` derives its observation from the `fetch_add`
/// return value, so the class's true high-water mark is always captured
/// (a ledger used for a single class — like the fabric's in-flight
/// tracking — therefore records an exact peak). Other classes are added
/// from racy loads, so a *multi*-class peak can only land between the
/// max per-class peak and the true combined one. `free` saturates at
/// zero, so a racing release can never underflow the ledger.
#[derive(Debug, Default)]
pub struct SharedAccountant {
    current: [AtomicU64; N_CLASSES],
    peak: AtomicU64,
}

impl SharedAccountant {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&self, class: MemClass, bytes: u64) {
        let idx = class_idx(class);
        // the fetch_add return value pins this class's exact level at the
        // moment of allocation — a later free by another thread cannot
        // erase the observation (a racy re-read of `current` could)
        let mut observed = self.current[idx].fetch_add(bytes, Ordering::Relaxed) + bytes;
        for (j, c) in self.current.iter().enumerate() {
            if j != idx {
                observed += c.load(Ordering::Relaxed);
            }
        }
        self.peak.fetch_max(observed, Ordering::Relaxed);
    }

    pub fn free(&self, class: MemClass, bytes: u64) {
        let c = &self.current[class_idx(class)];
        let mut cur = c.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(bytes);
            match c.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(observed) => cur = observed,
            }
        }
    }

    pub fn total(&self) -> u64 {
        self.current.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn current(&self, class: MemClass) -> u64 {
        self.current[class_idx(class)].load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peak() {
        let mut m = MemoryAccountant::new();
        m.alloc(MemClass::CountTable, 100);
        m.alloc(MemClass::RecvBuffer, 50);
        assert_eq!(m.peak, 150);
        m.free(MemClass::RecvBuffer, 50);
        m.alloc(MemClass::RecvBuffer, 20);
        assert_eq!(m.peak, 150, "peak is sticky");
        assert_eq!(m.total(), 120);
    }

    #[test]
    fn peak_breakdown() {
        let mut m = MemoryAccountant::new();
        m.alloc(MemClass::Graph, 10);
        m.alloc(MemClass::CountTable, 200);
        m.alloc(MemClass::Scratch, 5);
        assert_eq!(m.peak_by_class[class_idx(MemClass::CountTable)], 200);
        assert_eq!(m.peak_by_class[class_idx(MemClass::Graph)], 10);
    }

    #[test]
    fn shared_accountant_tracks_peak_and_saturates() {
        let m = SharedAccountant::new();
        m.alloc(MemClass::RecvBuffer, 100);
        m.alloc(MemClass::CountTable, 50);
        assert_eq!(m.total(), 150);
        assert_eq!(m.peak(), 150);
        m.free(MemClass::RecvBuffer, 100);
        assert_eq!(m.current(MemClass::RecvBuffer), 0);
        assert_eq!(m.peak(), 150, "peak is sticky");
        // saturating free: an over-release clamps at zero, never wraps
        m.free(MemClass::CountTable, 10_000);
        assert_eq!(m.current(MemClass::CountTable), 0);
        assert_eq!(m.total(), 0);
    }

    #[test]
    fn shared_accountant_concurrent_alloc_free() {
        // 8 threads × 200 balanced alloc/free rounds: the total never
        // underflows, the final ledger is exactly zero, and the recorded
        // peak is sane — at least one thread's live slice, at most the
        // sum of everything ever allocated.
        const THREADS: usize = 8;
        const ROUNDS: usize = 200;
        const BYTES: u64 = 64;
        let m = SharedAccountant::new();
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for _ in 0..ROUNDS {
                        m.alloc(MemClass::RecvBuffer, BYTES);
                        assert!(m.peak() >= m.current(MemClass::RecvBuffer));
                        m.free(MemClass::RecvBuffer, BYTES);
                    }
                });
            }
        });
        assert_eq!(m.total(), 0, "balanced alloc/free must return to zero");
        assert!(m.peak() >= BYTES);
        assert!(m.peak() <= (THREADS * ROUNDS) as u64 * BYTES);
    }

    #[test]
    fn dual_ledger_tracks_real_and_dense_baselines() {
        let mut m = DualAccountant::new();
        m.alloc(MemClass::Graph, 100); // representation-independent
        m.alloc2(MemClass::CountTable, 30, 400); // sparse table, dense worth 400
        assert_eq!(m.real.peak, 130);
        assert_eq!(m.dense.peak, 500);
        assert_eq!(m.current(MemClass::CountTable), 30);
        m.alloc2(MemClass::RecvBuffer, 8, 64);
        m.alloc2(MemClass::RecvBuffer, 16, 64);
        assert_eq!(m.real.peak, 154);
        assert_eq!(m.dense.peak, 628);
        m.release_all(MemClass::RecvBuffer);
        assert_eq!(m.real.current(MemClass::RecvBuffer), 0);
        assert_eq!(m.dense.current(MemClass::RecvBuffer), 0);
        m.free2(MemClass::CountTable, 30, 400);
        assert_eq!(m.real.total(), 100);
        assert_eq!(m.dense.total(), 100);
        // peaks stay sticky and ordered: real never exceeds dense when
        // every alloc2 charged real ≤ dense
        assert!(m.real.peak <= m.dense.peak);
    }

    #[test]
    fn pipeline_vs_bulk_shape() {
        // holding one 10-unit slice at a time peaks lower than nine at once
        let mut bulk = MemoryAccountant::new();
        bulk.alloc(MemClass::CountTable, 100);
        for _ in 0..9 {
            bulk.alloc(MemClass::RecvBuffer, 10);
        }
        let mut pipe = MemoryAccountant::new();
        pipe.alloc(MemClass::CountTable, 100);
        for _ in 0..9 {
            pipe.alloc(MemClass::RecvBuffer, 10);
            pipe.free(MemClass::RecvBuffer, 10);
        }
        assert_eq!(bulk.peak, 190);
        assert_eq!(pipe.peak, 110);
    }
}
