//! Per-rank peak-memory accounting (Eq 7 / Eq 12, Fig 12).
//!
//! Tracks the bytes of every live intermediate buffer by category — count
//! tables `C(v,Ti)`, received remote rows `C(u,Ti)`, and the aggregation
//! scratch — and records the high-water mark. The Naive (all-to-all) mode
//! holds *all* remote rows of a combine at once; the pipelined mode holds
//! one step's slice at a time: the 2–5× peak reduction of Fig 12 falls
//! straight out of this ledger.

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemClass {
    CountTable,
    RecvBuffer,
    Scratch,
    Graph,
}

const N_CLASSES: usize = 4;

fn class_idx(c: MemClass) -> usize {
    match c {
        MemClass::CountTable => 0,
        MemClass::RecvBuffer => 1,
        MemClass::Scratch => 2,
        MemClass::Graph => 3,
    }
}

#[derive(Debug, Clone, Default)]
pub struct MemoryAccountant {
    current: [u64; N_CLASSES],
    pub peak: u64,
    /// breakdown of the peak moment
    pub peak_by_class: [u64; N_CLASSES],
}

impl MemoryAccountant {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&mut self, class: MemClass, bytes: u64) {
        self.current[class_idx(class)] += bytes;
        let total = self.total();
        if total > self.peak {
            self.peak = total;
            self.peak_by_class = self.current;
        }
    }

    pub fn free(&mut self, class: MemClass, bytes: u64) {
        let c = &mut self.current[class_idx(class)];
        debug_assert!(*c >= bytes, "freeing {bytes} from {c} in {class:?}");
        *c = c.saturating_sub(bytes);
    }

    pub fn total(&self) -> u64 {
        self.current.iter().sum()
    }

    pub fn current(&self, class: MemClass) -> u64 {
        self.current[class_idx(class)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peak() {
        let mut m = MemoryAccountant::new();
        m.alloc(MemClass::CountTable, 100);
        m.alloc(MemClass::RecvBuffer, 50);
        assert_eq!(m.peak, 150);
        m.free(MemClass::RecvBuffer, 50);
        m.alloc(MemClass::RecvBuffer, 20);
        assert_eq!(m.peak, 150, "peak is sticky");
        assert_eq!(m.total(), 120);
    }

    #[test]
    fn peak_breakdown() {
        let mut m = MemoryAccountant::new();
        m.alloc(MemClass::Graph, 10);
        m.alloc(MemClass::CountTable, 200);
        m.alloc(MemClass::Scratch, 5);
        assert_eq!(m.peak_by_class[class_idx(MemClass::CountTable)], 200);
        assert_eq!(m.peak_by_class[class_idx(MemClass::Graph)], 10);
    }

    #[test]
    fn pipeline_vs_bulk_shape() {
        // holding one 10-unit slice at a time peaks lower than nine at once
        let mut bulk = MemoryAccountant::new();
        bulk.alloc(MemClass::CountTable, 100);
        for _ in 0..9 {
            bulk.alloc(MemClass::RecvBuffer, 10);
        }
        let mut pipe = MemoryAccountant::new();
        pipe.alloc(MemClass::CountTable, 100);
        for _ in 0..9 {
            pipe.alloc(MemClass::RecvBuffer, 10);
            pipe.free(MemClass::RecvBuffer, 10);
        }
        assert_eq!(bulk.peak, 190);
        assert_eq!(pipe.peak, 110);
    }
}
