//! The L3 coordinator: the paper's system contribution. `dist` drives the
//! distributed color-coding of Alg 2/3 over simulated ranks, `memory`
//! accounts peak intermediate bytes (Eq 7/12), `run` holds the Table-1
//! mode matrix and results.

pub mod dist;
pub mod memory;
pub mod run;

pub use dist::{build_plan_for, validate_group_size, DistributedRunner, ExchangePlan};
pub use memory::{DualAccountant, MemClass, MemoryAccountant, SharedAccountant};
pub use run::{
    CommDecision, EngineKind, ExchangeExec, ModeSelect, ModelTime, RunConfig, RunResult,
    StorageDecision, ThreadStats,
};
