//! The L3 coordinator: the paper's system contribution. `dist` drives the
//! distributed color-coding of Alg 2/3 over simulated ranks (or, through
//! [`dist::DistributedRunner::run_on`], over any [`crate::comm::RankFabric`]),
//! `memory` accounts peak intermediate bytes (Eq 7/12), `run` holds the
//! Table-1 mode matrix and results, and `procmode` is the process-mode
//! orchestration: the rank-process launcher and the `harpsg-rank` worker
//! entry point that run the same schedules over a socket mesh.

pub mod dist;
pub mod memory;
pub mod procmode;
pub mod run;

pub use dist::{build_plan_for, validate_group_size, DistributedRunner, ExchangePlan};
pub use memory::{DualAccountant, MemClass, MemoryAccountant, SharedAccountant};
pub use procmode::{launch, rank_main, ProcSpec};
pub use run::{
    CommDecision, EngineKind, ExchangeExec, FabricKind, ModeSelect, ModelTime, PruneStats,
    RankLink, RunConfig, RunResult, StorageDecision, ThreadStats,
};
