//! Neighbor-list partitioning (paper Alg 4): build the fine-grained task
//! queue that bounds per-thread work, plus the task-cost model used by
//! the virtual-thread replay.
//!
//! A task is a `(vertex, neighbor-sublist)` slice of the CSR adjacency,
//! at most `max_task_size` neighbors long. With `max_task_size = 0`
//! ("per-vertex granularity", the Naive/FASCIA behaviour) each vertex is
//! one task regardless of its degree — a hub vertex then pins a whole
//! thread, which is exactly the imbalance Fig 11 measures.

use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Task {
    /// local row of the owning vertex
    pub vertex: u32,
    /// offset into the vertex's neighbor list
    pub start: u32,
    /// number of neighbors in this task
    pub len: u32,
}

/// Largest per-vertex workload `make_tasks` will split. Entries above
/// this are saturated (documented edge case): the split loop's `u32`
/// offset arithmetic (`pos + l`) and every downstream
/// `start + len + slack` computation then stay strictly below
/// `u32::MAX`, with 2¹⁶ of headroom for callers that add fixed slack to
/// task ends. Real CSR degrees are bounded by the graph's edge count and
/// sit far below this; only synthetic/corrupt inputs can hit it.
pub const MAX_TASK_SPAN: u32 = u32::MAX - (1 << 16);

/// Build the task queue for a set of per-vertex workloads (Alg 4).
/// `degrees[r]` is the number of adjacency pairs vertex-row `r` must
/// process in this combine step. `max_task_size == 0` disables splitting.
///
/// Entries above [`MAX_TASK_SPAN`] are saturated to it (the tasks then
/// cover `[0, MAX_TASK_SPAN)` of that vertex's list) rather than fed into
/// the `u32` split arithmetic — see the const's docs.
pub fn make_tasks(degrees: &[u32], max_task_size: u32, shuffle_seed: Option<u64>) -> Vec<Task> {
    let mut q = Vec::new();
    for (r, &raw) in degrees.iter().enumerate() {
        let n = raw.min(MAX_TASK_SPAN);
        if n == 0 {
            continue;
        }
        if max_task_size == 0 || n <= max_task_size {
            q.push(Task {
                vertex: r as u32,
                start: 0,
                len: n,
            });
        } else {
            let mut pos = 0u32;
            let mut rem = n;
            while rem > 0 {
                let l = rem.min(max_task_size);
                q.push(Task {
                    vertex: r as u32,
                    start: pos,
                    len: l,
                });
                pos += l;
                rem -= l;
            }
        }
    }
    // Alg 4 line 16: shuffle to mitigate same-vertex atomic conflicts
    if let Some(seed) = shuffle_seed {
        Rng::stream(seed, 0x5348_5546).shuffle(&mut q);
    }
    q
}

/// Cost model for one task, in abstract "units" (converted to seconds by
/// the calibrated flop time): `len` adjacency pairs each costing
/// `unit_per_pair` (the agg row add, ∝ C(k,|Ti''|)), plus the task's
/// share of the per-vertex contraction (∝ C(k,|Ti|)·C(|Ti|,|Ti'|)) and a
/// fixed scheduling overhead.
#[derive(Debug, Clone, Copy)]
pub struct TaskCostModel {
    /// units per adjacency pair (≈ C(k, |Ti''|))
    pub unit_per_pair: f64,
    /// units per task for contraction share + atomics
    pub unit_per_task: f64,
    /// fixed per-task scheduling/synchronization overhead units
    pub overhead: f64,
}

impl TaskCostModel {
    #[inline]
    pub fn cost(&self, t: &Task) -> f64 {
        self.overhead + self.unit_per_task + self.unit_per_pair * t.len as f64
    }

    pub fn total(&self, tasks: &[Task]) -> f64 {
        tasks.iter().map(|t| self.cost(t)).sum()
    }
}

/// Longest-processing-time-first execution order: a permutation of task
/// indices, descending by modeled cost, ties kept in canonical queue
/// order. The queue itself stays canonical — partial slots and the merge
/// fold are indexed by task position — so consuming tasks *through* this
/// permutation changes only the claim schedule, never a result bit,
/// while the classic LPT bound keeps the makespan within 4/3 of optimal
/// for any worker count.
pub fn lpt_order(tasks: &[Task], model: &TaskCostModel) -> Vec<u32> {
    let mut order: Vec<u32> = (0..tasks.len() as u32).collect();
    order.sort_by(|&a, &b| {
        let ca = model.cost(&tasks[a as usize]);
        let cb = model.cost(&tasks[b as usize]);
        cb.partial_cmp(&ca)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn no_split_when_small() {
        let q = make_tasks(&[3, 0, 5], 10, None);
        assert_eq!(
            q,
            vec![
                Task { vertex: 0, start: 0, len: 3 },
                Task { vertex: 2, start: 0, len: 5 }
            ]
        );
    }

    #[test]
    fn splits_hub_vertex() {
        let q = make_tasks(&[12], 5, None);
        assert_eq!(q.len(), 3);
        assert_eq!(q[0], Task { vertex: 0, start: 0, len: 5 });
        assert_eq!(q[1], Task { vertex: 0, start: 5, len: 5 });
        assert_eq!(q[2], Task { vertex: 0, start: 10, len: 2 });
    }

    #[test]
    fn zero_disables_splitting() {
        let q = make_tasks(&[1000, 2], 0, None);
        assert_eq!(q.len(), 2);
        assert_eq!(q[0].len, 1000);
    }

    #[test]
    fn shuffle_preserves_multiset() {
        let degs: Vec<u32> = (0..50).map(|i| (i * 7) % 23 + 1).collect();
        let a = make_tasks(&degs, 6, None);
        let mut b = make_tasks(&degs, 6, Some(9));
        assert_ne!(a, b, "shuffle must change order");
        b.sort_by_key(|t| (t.vertex, t.start));
        let mut a2 = a.clone();
        a2.sort_by_key(|t| (t.vertex, t.start));
        assert_eq!(a2, b);
    }

    #[test]
    fn prop_tasks_cover_exactly() {
        prop::check("task_cover", |g| {
            let n = g.usize_in(1, 60);
            let degs: Vec<u32> = (0..n).map(|_| g.usize_in(0, 200) as u32).collect();
            let s = g.usize_in(1, 50) as u32;
            let q = make_tasks(&degs, s, Some(g.case_seed));
            // per-vertex: intervals tile [0, deg)
            let mut seen: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n];
            for t in &q {
                if t.len == 0 || t.len > s {
                    return Err(format!("bad task len {}", t.len));
                }
                seen[t.vertex as usize].push((t.start, t.len));
            }
            for (v, iv) in seen.iter_mut().enumerate() {
                iv.sort();
                let mut pos = 0u32;
                for &(st, l) in iv.iter() {
                    if st != pos {
                        return Err(format!("gap at vertex {v}"));
                    }
                    pos += l;
                }
                if pos != degs[v] {
                    return Err(format!("vertex {v} covered {pos}/{}", degs[v]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn saturates_overflowing_degrees() {
        // regression: a pathological degree near u32::MAX must neither
        // wrap the split arithmetic nor blow up the queue — it is
        // saturated to MAX_TASK_SPAN (a large task size keeps the queue
        // small enough to materialize here)
        let s = 1u32 << 30;
        let q = make_tasks(&[u32::MAX, 7], s, None);
        let mut covered = 0u64;
        for t in &q {
            if t.vertex == 0 {
                assert!(t.len <= s);
                assert!(t.start as u64 + t.len as u64 <= MAX_TASK_SPAN as u64);
                covered += t.len as u64;
            }
        }
        assert_eq!(covered, MAX_TASK_SPAN as u64);
        // sane entries are untouched
        assert!(q.iter().any(|t| t.vertex == 1 && t.start == 0 && t.len == 7));
        // boundary value passes through un-saturated
        let q = make_tasks(&[MAX_TASK_SPAN], s, None);
        assert_eq!(q.iter().map(|t| t.len as u64).sum::<u64>(), MAX_TASK_SPAN as u64);
    }

    /// Satellite regression: a pathological hub + many-smalls workload,
    /// consumed in LPT order by a deterministic least-loaded greedy
    /// assignment (the claim loop's idealized schedule), balances within
    /// 1.2× of the mean load for every worker count — with the cost model
    /// actually driving the order, not sitting as dead code.
    #[test]
    fn lpt_order_balances_hub_plus_smalls() {
        let mut degs = vec![10_000u32];
        degs.resize(201, 10);
        let tasks = make_tasks(&degs, 100, None);
        let m = TaskCostModel {
            unit_per_pair: 1.0,
            unit_per_task: 0.0,
            overhead: 0.5,
        };
        let order = lpt_order(&tasks, &m);
        // a permutation, descending in modeled cost
        let mut seen = vec![false; tasks.len()];
        for &i in &order {
            assert!(!seen[i as usize], "index {i} repeated");
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        for w in order.windows(2) {
            assert!(
                m.cost(&tasks[w[0] as usize]) >= m.cost(&tasks[w[1] as usize]),
                "order not descending at {w:?}"
            );
        }
        for workers in [2usize, 4, 8] {
            let mut load = vec![0.0f64; workers];
            for &i in &order {
                let w = (0..workers)
                    .min_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap())
                    .unwrap();
                load[w] += m.cost(&tasks[i as usize]);
            }
            let max = load.iter().fold(0.0f64, |a, &b| a.max(b));
            let mean = load.iter().sum::<f64>() / workers as f64;
            assert!(
                max <= 1.2 * mean,
                "workers={workers}: max load {max} vs mean {mean}"
            );
        }
        // without splitting no order can balance: the hub is one task
        // bigger than everything else combined — the imbalance Fig 11
        // measures, and exactly what LPT-over-split-tasks removes
        let unsplit = make_tasks(&degs, 0, None);
        let worst = unsplit.iter().map(|t| m.cost(t)).fold(0.0, f64::max);
        assert!(worst > m.total(&unsplit) / 2.0);
    }

    #[test]
    fn lpt_order_is_stable_on_ties() {
        let tasks = make_tasks(&[5, 5, 5, 5], 10, None);
        let m = TaskCostModel {
            unit_per_pair: 1.0,
            unit_per_task: 0.0,
            overhead: 0.0,
        };
        // equal costs: canonical order preserved exactly
        assert_eq!(lpt_order(&tasks, &m), vec![0, 1, 2, 3]);
    }

    #[test]
    fn cost_model_bounds_hub_tasks() {
        let m = TaskCostModel {
            unit_per_pair: 2.0,
            unit_per_task: 1.0,
            overhead: 0.5,
        };
        let naive = make_tasks(&[10_000, 10], 0, None);
        let lb = make_tasks(&[10_000, 10], 50, None);
        let max_naive = naive.iter().map(|t| m.cost(t)).fold(0.0, f64::max);
        let max_lb = lb.iter().map(|t| m.cost(t)).fold(0.0, f64::max);
        assert!(max_naive > 100.0 * max_lb / 2.0);
        // totals stay comparable (overhead grows only mildly)
        assert!(m.total(&lb) < m.total(&naive) * 1.5);
    }
}
