//! Thread-level scheduling substrate: the Alg-4 neighbor-list partitioning
//! task factory (`tasks`) and the deterministic virtual-thread replay that
//! stands in for the paper's OpenMP pool + VTune concurrency measurements
//! (`vtime`).

pub mod tasks;
pub mod vtime;

pub use tasks::{lpt_order, make_tasks, Task, TaskCostModel, MAX_TASK_SPAN};
pub use vtime::{replay, ThreadReplay, PHYSICAL_CORES};
