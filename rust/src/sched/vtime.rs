//! Virtual-thread list scheduler: deterministically replays a task queue
//! on `T` virtual workers to obtain the thread-level makespan, concurrency
//! histogram and per-thread utilization that the paper measured with
//! VTune (Fig 11). See DESIGN.md §1 for why this substitutes for real
//! multithreading on the single-core container: load imbalance is a
//! property of the (real) task-size distribution, which list scheduling
//! reproduces.
//!
//! Hyper-threading model: with `T` threads on `phys` physical cores,
//! per-thread speed is `min(1, phys/T)` — total throughput saturates at
//! the physical core count. A monolithic hub task then *slows down* when
//! T exceeds `phys` (it runs on a slower logical thread), which is exactly
//! the Naive-implementation degradation beyond 24 threads the paper
//! observes, while bounded tasks (AdaptiveLB) stay flat.

/// Paper testbed: 2 × 12-core Xeon E5-2670v3.
pub const PHYSICAL_CORES: usize = 24;

#[derive(Debug, Clone)]
pub struct ThreadReplay {
    /// wall-clock units until the last task finishes
    pub makespan: f64,
    /// Σ busy time / (T · makespan): utilization in [0,1]
    pub utilization: f64,
    /// average number of concurrently busy threads
    pub avg_concurrency: f64,
    /// histogram[c] = time spent with exactly c busy threads (c ≤ T)
    pub concurrency_histogram: Vec<f64>,
    pub n_threads: usize,
}

/// List-schedule `costs` (in work units at speed 1) on `n_threads` virtual
/// threads with the hyper-threading speed model.
pub fn replay(costs: &[f64], n_threads: usize, phys_cores: usize) -> ThreadReplay {
    assert!(n_threads >= 1);
    let speed = (phys_cores as f64 / n_threads as f64).min(1.0);
    // earliest-free-thread assignment via a simple linear scan (T ≤ 64)
    let mut free_at = vec![0.0f64; n_threads];
    let mut intervals: Vec<(f64, f64)> = Vec::with_capacity(costs.len());
    for &c in costs {
        let (t, _) = free_at
            .iter()
            .enumerate()
            .map(|(i, &f)| (i, f))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)))
            .unwrap();
        let start = free_at[t];
        let dur = c / speed;
        free_at[t] = start + dur;
        intervals.push((start, start + dur));
    }
    let makespan = free_at.iter().copied().fold(0.0, f64::max);
    let busy: f64 = intervals.iter().map(|(s, e)| e - s).sum();

    // concurrency histogram by sweeping interval endpoints
    let mut events: Vec<(f64, i32)> = Vec::with_capacity(intervals.len() * 2);
    for &(s, e) in &intervals {
        events.push((s, 1));
        events.push((e, -1));
    }
    events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(b.1.cmp(&a.1)));
    let mut histogram = vec![0.0f64; n_threads + 1];
    let mut cur = 0i32;
    let mut last_t = 0.0f64;
    for (t, d) in events {
        if t > last_t {
            histogram[cur.max(0) as usize] += t - last_t;
            last_t = t;
        }
        cur += d;
    }
    let avg_concurrency = if makespan > 0.0 { busy / makespan } else { 0.0 };
    ThreadReplay {
        makespan,
        utilization: if makespan > 0.0 {
            busy / (n_threads as f64 * makespan)
        } else {
            0.0
        },
        avg_concurrency,
        concurrency_histogram: histogram,
        n_threads,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_balance() {
        let costs = vec![1.0; 8];
        let r = replay(&costs, 4, 24);
        assert!((r.makespan - 2.0).abs() < 1e-12);
        assert!((r.utilization - 1.0).abs() < 1e-12);
        assert!((r.avg_concurrency - 4.0).abs() < 1e-12);
    }

    #[test]
    fn hub_task_dominates() {
        // one task of 100, many of 1: makespan pinned by the hub
        let mut costs = vec![1.0; 50];
        costs.insert(0, 100.0);
        let r = replay(&costs, 8, 24);
        assert!((r.makespan - 100.0).abs() < 1e-9);
        assert!(r.utilization < 0.25);
    }

    #[test]
    fn hyperthreading_slows_monolithic_tasks() {
        // beyond the physical cores, a single hub task takes longer —
        // the paper's Naive degradation (Fig 11)
        let mut costs = vec![1.0; 100];
        costs.insert(0, 500.0);
        let at24 = replay(&costs, 24, 24).makespan;
        let at48 = replay(&costs, 48, 24).makespan;
        assert!(
            at48 > 1.8 * at24,
            "hub at 48 threads {at48} vs 24 threads {at24}"
        );
        // balanced tasks are unaffected (total throughput saturates)
        let flat: Vec<f64> = vec![1.0; 4800];
        let f24 = replay(&flat, 24, 24).makespan;
        let f48 = replay(&flat, 48, 24).makespan;
        assert!((f48 - f24).abs() / f24 < 0.05);
    }

    #[test]
    fn histogram_sums_to_makespan() {
        let costs = vec![3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let r = replay(&costs, 3, 24);
        let sum: f64 = r.concurrency_histogram.iter().sum();
        assert!((sum - r.makespan).abs() < 1e-9);
    }

    #[test]
    fn more_threads_help_balanced_load() {
        let costs: Vec<f64> = (0..240).map(|i| 1.0 + (i % 3) as f64).collect();
        let m6 = replay(&costs, 6, 24).makespan;
        let m12 = replay(&costs, 12, 24).makespan;
        let m24 = replay(&costs, 24, 24).makespan;
        assert!(m12 < m6 * 0.6);
        assert!(m24 < m12 * 0.7);
    }

    #[test]
    fn empty_queue() {
        let r = replay(&[], 4, 24);
        assert_eq!(r.makespan, 0.0);
        assert_eq!(r.avg_concurrency, 0.0);
    }
}
