//! Data packets and the bit-packed meta ID (paper Fig. 4).
//!
//! Each packet carries a 32-bit meta ID packing `(sender, receiver,
//! queue offset)`; the routing algorithm decodes it to deliver the packet
//! and to reassemble multi-packet payloads in order — the mechanism that
//! lets Harp reconfigure routing on-the-fly instead of baking the
//! collective into the program structure.

use crate::colorcount::storage::RowsPayload;
use crate::colorcount::Count;

/// sender: 10 bits (≤1024 ranks), receiver: 10 bits, offset: 12 bits.
pub const SENDER_BITS: u32 = 10;
pub const RECEIVER_BITS: u32 = 10;
pub const OFFSET_BITS: u32 = 12;

pub const MAX_RANKS: usize = 1 << SENDER_BITS;
pub const MAX_OFFSET: usize = 1 << OFFSET_BITS;

/// Pack `(sender, receiver, offset)` into a meta ID.
#[inline]
pub fn encode_meta(sender: usize, receiver: usize, offset: usize) -> u32 {
    debug_assert!(sender < MAX_RANKS && receiver < MAX_RANKS && offset < MAX_OFFSET);
    ((sender as u32) << (RECEIVER_BITS + OFFSET_BITS))
        | ((receiver as u32) << OFFSET_BITS)
        | offset as u32
}

/// Unpack a meta ID.
#[inline]
pub fn decode_meta(meta: u32) -> (usize, usize, usize) {
    let sender = (meta >> (RECEIVER_BITS + OFFSET_BITS)) as usize;
    let receiver = ((meta >> OFFSET_BITS) & ((1 << RECEIVER_BITS) - 1)) as usize;
    let offset = (meta & ((1 << OFFSET_BITS) - 1)) as usize;
    (sender, receiver, offset)
}

/// A count-row packet: count-table rows for the vertices the receiver
/// requested (in the receiver's request-list order), carried in whichever
/// encoding the sender's table storage uses — flat dense rows at the
/// engine's [`Count`] element width, or CSR `(set_rank, count)` sparse
/// rows ([`RowsPayload`], `colorcount::storage`).
#[derive(Debug, Clone)]
pub struct Packet {
    pub meta: u32,
    /// which subtemplate's counts these are
    pub subtemplate: u32,
    /// row width (number of color sets)
    pub n_sets: u32,
    pub payload: RowsPayload,
}

impl Packet {
    /// Wire bytes of the packet envelope: the 4-byte meta ID plus the
    /// 8-byte (subtemplate, n_sets) header. The encoding tag rides in the
    /// header's spare bits.
    pub const HEADER_BYTES: u64 = 12;

    /// A dense-row packet (the historical constructor — byte-identical
    /// wire size to the original flat layout).
    pub fn new(
        sender: usize,
        receiver: usize,
        offset: usize,
        subtemplate: usize,
        n_sets: usize,
        rows: Vec<Count>,
    ) -> Self {
        Self::with_payload(
            sender,
            receiver,
            offset,
            subtemplate,
            n_sets,
            RowsPayload::Dense(rows),
        )
    }

    /// A packet around an already-encoded payload (what the exchange
    /// executors build via `colorcount::storage::encode_rows`).
    pub fn with_payload(
        sender: usize,
        receiver: usize,
        offset: usize,
        subtemplate: usize,
        n_sets: usize,
        payload: RowsPayload,
    ) -> Self {
        Packet {
            meta: encode_meta(sender, receiver, offset),
            subtemplate: subtemplate as u32,
            n_sets: n_sets as u32,
            payload,
        }
    }

    #[inline]
    pub fn sender(&self) -> usize {
        decode_meta(self.meta).0
    }

    #[inline]
    pub fn receiver(&self) -> usize {
        decode_meta(self.meta).1
    }

    #[inline]
    pub fn offset(&self) -> usize {
        decode_meta(self.meta).2
    }

    /// Packet size on the wire: header plus the *encoded* payload bytes
    /// ([`RowsPayload::wire_bytes`] — the one sizing rule the adaptive
    /// model, the fabric accounting and the recv-buffer ledger share, so
    /// modeled step bytes and measured accounting agree exactly).
    pub fn bytes(&self) -> u64 {
        Self::HEADER_BYTES + self.payload.wire_bytes()
    }

    /// Rows this packet carries.
    pub fn n_rows(&self) -> usize {
        self.payload.n_rows(self.n_sets.max(1) as usize)
    }

    /// What the same rows would cost under the dense encoding — the
    /// baseline for the `bytes_saved` accounting of the report and the
    /// dense-ledger side of `coordinator::memory::DualAccountant`.
    pub fn dense_equiv_bytes(&self) -> u64 {
        Self::HEADER_BYTES
            + (self.n_rows() * self.n_sets as usize * std::mem::size_of::<Count>()) as u64
    }

    /// The dense payload's rows (test convenience; panics on an encoded
    /// payload).
    pub fn dense_rows(&self) -> &[Count] {
        match &self.payload {
            RowsPayload::Dense(rows) => rows,
            RowsPayload::Sparse { .. } | RowsPayload::Masked { .. } => {
                panic!("packet carries an encoded payload")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_extremes() {
        for (s, r, o) in [
            (0, 0, 0),
            (MAX_RANKS - 1, 0, 5),
            (0, MAX_RANKS - 1, MAX_OFFSET - 1),
            (511, 513, 2049),
        ] {
            assert_eq!(decode_meta(encode_meta(s, r, o)), (s, r, o));
        }
    }

    #[test]
    fn prop_roundtrip() {
        prop::check("meta_roundtrip", |g| {
            let s = g.usize_in(0, MAX_RANKS - 1);
            let r = g.usize_in(0, MAX_RANKS - 1);
            let o = g.usize_in(0, MAX_OFFSET - 1);
            if decode_meta(encode_meta(s, r, o)) == (s, r, o) {
                Ok(())
            } else {
                Err(format!("({s},{r},{o})"))
            }
        });
    }

    #[test]
    fn packet_accessors_and_bytes() {
        let p = Packet::new(3, 7, 11, 2, 4, vec![1.0; 8]);
        assert_eq!(p.sender(), 3);
        assert_eq!(p.receiver(), 7);
        assert_eq!(p.offset(), 11);
        assert_eq!(p.bytes(), 4 + 8 + 32);
        assert_eq!(p.n_rows(), 2);
        // dense packets are their own dense equivalent
        assert_eq!(p.dense_equiv_bytes(), p.bytes());
        assert_eq!(p.dense_rows(), &[1.0; 8]);
    }

    #[test]
    fn sparse_packet_bytes_follow_the_codec() {
        // 3 rows × 4 sets with 2 non-zeros: wire = header + offsets + entries
        let payload = RowsPayload::Sparse {
            offsets: vec![0, 1, 1, 2],
            entries: vec![(0, 1.0), (3, 2.0)],
        };
        let wire = payload.wire_bytes();
        let p = Packet::with_payload(0, 1, 0, 2, 4, payload);
        assert_eq!(wire, 4 * 4 + 2 * 8);
        assert_eq!(p.bytes(), Packet::HEADER_BYTES + wire);
        assert_eq!(p.n_rows(), 3);
        // the dense encoding of the same rows would cost 3·4·4 payload bytes
        assert_eq!(p.dense_equiv_bytes(), Packet::HEADER_BYTES + 48);
        assert!(p.bytes() < p.dense_equiv_bytes());
    }

    #[test]
    fn masked_packet_bytes_follow_the_codec() {
        // 70 requested rows, one live: wire = n_rows + 2 mask words +
        // 2 offsets + 1 entry; the dense equivalent still charges all 70
        let payload = RowsPayload::Masked {
            n_rows: 70,
            mask: vec![1u64 << 9, 0],
            offsets: vec![0, 1],
            entries: vec![(2, 5.0)],
        };
        let wire = payload.wire_bytes();
        assert_eq!(wire, 4 + 2 * 8 + 2 * 4 + 8);
        let p = Packet::with_payload(0, 1, 0, 2, 4, payload);
        assert_eq!(p.bytes(), Packet::HEADER_BYTES + wire);
        assert_eq!(p.n_rows(), 70);
        assert_eq!(p.payload.rows_dropped(), 69);
        assert_eq!(p.dense_equiv_bytes(), Packet::HEADER_BYTES + 70 * 4 * 4);
        assert!(p.bytes() < p.dense_equiv_bytes());
    }
}
