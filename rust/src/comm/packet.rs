//! Data packets and the bit-packed meta ID (paper Fig. 4).
//!
//! Each packet carries a 32-bit meta ID packing `(sender, receiver,
//! queue offset)`; the routing algorithm decodes it to deliver the packet
//! and to reassemble multi-packet payloads in order — the mechanism that
//! lets Harp reconfigure routing on-the-fly instead of baking the
//! collective into the program structure.

use crate::colorcount::Count;

/// sender: 10 bits (≤1024 ranks), receiver: 10 bits, offset: 12 bits.
pub const SENDER_BITS: u32 = 10;
pub const RECEIVER_BITS: u32 = 10;
pub const OFFSET_BITS: u32 = 12;

pub const MAX_RANKS: usize = 1 << SENDER_BITS;
pub const MAX_OFFSET: usize = 1 << OFFSET_BITS;

/// Pack `(sender, receiver, offset)` into a meta ID.
#[inline]
pub fn encode_meta(sender: usize, receiver: usize, offset: usize) -> u32 {
    debug_assert!(sender < MAX_RANKS && receiver < MAX_RANKS && offset < MAX_OFFSET);
    ((sender as u32) << (RECEIVER_BITS + OFFSET_BITS))
        | ((receiver as u32) << OFFSET_BITS)
        | offset as u32
}

/// Unpack a meta ID.
#[inline]
pub fn decode_meta(meta: u32) -> (usize, usize, usize) {
    let sender = (meta >> (RECEIVER_BITS + OFFSET_BITS)) as usize;
    let receiver = ((meta >> OFFSET_BITS) & ((1 << RECEIVER_BITS) - 1)) as usize;
    let offset = (meta & ((1 << OFFSET_BITS) - 1)) as usize;
    (sender, receiver, offset)
}

/// A count-row packet: `rows` are count-table rows (at the engine's
/// [`Count`] element width) for the vertices the receiver requested (in
/// the receiver's request-list order), flattened.
#[derive(Debug, Clone)]
pub struct Packet {
    pub meta: u32,
    /// which subtemplate's counts these are
    pub subtemplate: u32,
    /// row width (number of color sets)
    pub n_sets: u32,
    pub rows: Vec<Count>,
}

impl Packet {
    /// Wire bytes of the packet envelope: the 4-byte meta ID plus the
    /// 8-byte (subtemplate, n_sets) header.
    pub const HEADER_BYTES: u64 = 12;

    pub fn new(
        sender: usize,
        receiver: usize,
        offset: usize,
        subtemplate: usize,
        n_sets: usize,
        rows: Vec<Count>,
    ) -> Self {
        Packet {
            meta: encode_meta(sender, receiver, offset),
            subtemplate: subtemplate as u32,
            n_sets: n_sets as u32,
            rows,
        }
    }

    #[inline]
    pub fn sender(&self) -> usize {
        decode_meta(self.meta).0
    }

    #[inline]
    pub fn receiver(&self) -> usize {
        decode_meta(self.meta).1
    }

    #[inline]
    pub fn offset(&self) -> usize {
        decode_meta(self.meta).2
    }

    /// Payload size on the wire (meta + header + rows at the engine's
    /// element width). The adaptive model charges the same per-packet
    /// header and per-entry width, so modeled step bytes and the fabric's
    /// measured accounting agree exactly.
    pub fn bytes(&self) -> u64 {
        Self::HEADER_BYTES + (self.rows.len() * std::mem::size_of::<Count>()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn roundtrip_extremes() {
        for (s, r, o) in [
            (0, 0, 0),
            (MAX_RANKS - 1, 0, 5),
            (0, MAX_RANKS - 1, MAX_OFFSET - 1),
            (511, 513, 2049),
        ] {
            assert_eq!(decode_meta(encode_meta(s, r, o)), (s, r, o));
        }
    }

    #[test]
    fn prop_roundtrip() {
        prop::check("meta_roundtrip", |g| {
            let s = g.usize_in(0, MAX_RANKS - 1);
            let r = g.usize_in(0, MAX_RANKS - 1);
            let o = g.usize_in(0, MAX_OFFSET - 1);
            if decode_meta(encode_meta(s, r, o)) == (s, r, o) {
                Ok(())
            } else {
                Err(format!("({s},{r},{o})"))
            }
        });
    }

    #[test]
    fn packet_accessors_and_bytes() {
        let p = Packet::new(3, 7, 11, 2, 4, vec![1.0; 8]);
        assert_eq!(p.sender(), 3);
        assert_eq!(p.receiver(), 7);
        assert_eq!(p.offset(), 11);
        assert_eq!(p.bytes(), 4 + 8 + 32);
    }
}
