//! The Hockney communication cost model (Eq 8): `t(n) = α + β·n` for a
//! message of `n` bytes. The paper analyses its communication complexity
//! with exactly this model; we apply it to the *actual byte counts* the
//! simulated ranks exchange, which is what makes the modeled figures
//! faithful (DESIGN.md §1).

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HockneyParams {
    /// per-message latency, seconds
    pub alpha: f64,
    /// transfer time per byte, seconds (1/bandwidth)
    pub beta: f64,
    /// fixed per-collective-step software overhead, seconds: barrier
    /// synchronization plus the (de)serialization/packing the Harp
    /// mappers pay per exchange step. This floor — not the wire latency —
    /// is what starves small templates of overlap as P grows (Fig 8/9);
    /// 50 µs reproduces the paper's separation at the harness downscale.
    pub step_overhead: f64,
}

impl HockneyParams {
    /// FDR InfiniBand-like defaults (the paper's testbed interconnect):
    /// ~2 µs latency, ~6 GB/s effective point-to-point bandwidth.
    pub fn infiniband() -> Self {
        HockneyParams {
            alpha: 2.0e-6,
            beta: 1.0 / 6.0e9,
            step_overhead: 5.0e-5,
        }
    }

    /// 10 GbE-like parameters (ablation: slower network moves the
    /// adaptive switch point).
    pub fn tengige() -> Self {
        HockneyParams {
            alpha: 20.0e-6,
            beta: 1.0 / 1.1e9,
            step_overhead: 8.0e-5,
        }
    }

    /// Time to move one message of `bytes`.
    #[inline]
    pub fn msg(&self, bytes: u64) -> f64 {
        self.alpha + self.beta * bytes as f64
    }

    /// Time for one rank's step in a collective where it exchanges
    /// `n_msgs` messages totalling `bytes` (serialized through one NIC —
    /// the conservative single-port model), plus the per-step software
    /// overhead.
    #[inline]
    pub fn step(&self, n_msgs: usize, bytes: u64) -> f64 {
        self.step_overhead + self.alpha * n_msgs as f64 + self.beta * bytes as f64
    }
}

impl Default for HockneyParams {
    fn default() -> Self {
        Self::infiniband()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_in_bytes() {
        let h = HockneyParams {
            alpha: 1e-6,
            beta: 1e-9,
            step_overhead: 0.0,
        };
        assert!((h.msg(0) - 1e-6).abs() < 1e-18);
        assert!((h.msg(1000) - (1e-6 + 1e-6)).abs() < 1e-15);
        let big = h.msg(2_000_000);
        assert!((big - (1e-6 + 2e-3)).abs() < 1e-12);
    }

    #[test]
    fn step_accounts_per_message_latency() {
        let h = HockneyParams {
            alpha: 1e-6,
            beta: 0.0,
            step_overhead: 0.0,
        };
        assert!((h.step(24, 12345) - 24e-6).abs() < 1e-15);
    }

    #[test]
    fn presets_ordered() {
        // InfiniBand beats 10GbE on both latency and bandwidth
        let ib = HockneyParams::infiniband();
        let ge = HockneyParams::tengige();
        assert!(ib.alpha < ge.alpha);
        assert!(ib.beta < ge.beta);
    }
}
