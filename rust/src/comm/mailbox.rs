//! The simulated-rank message substrate: per-rank inboxes that move real
//! packet payloads between ranks, with byte accounting per (step, rank).
//!
//! This replaces the paper's 25-node InfiniBand fabric (repro band 0 —
//! DESIGN.md §1). Data movement is real (actual count rows are copied
//! between rank-owned buffers and drive the receiver's DP update);
//! *timing* is modeled by the Hockney parameters over the measured bytes.

use super::packet::Packet;

/// Mailbox fabric for `n_ranks` simulated ranks.
#[derive(Debug)]
pub struct Fabric {
    pub n_ranks: usize,
    inboxes: Vec<Vec<Packet>>,
    /// bytes sent by each rank since the last `reset_accounting`
    sent_bytes: Vec<u64>,
    /// messages sent by each rank
    sent_msgs: Vec<usize>,
}

impl Fabric {
    pub fn new(n_ranks: usize) -> Self {
        Fabric {
            n_ranks,
            inboxes: (0..n_ranks).map(|_| Vec::new()).collect(),
            sent_bytes: vec![0; n_ranks],
            sent_msgs: vec![0; n_ranks],
        }
    }

    /// Send a packet: lands in the receiver's inbox immediately (delivery
    /// order = send order, deterministic).
    pub fn send(&mut self, p: Packet) {
        let to = p.receiver();
        assert!(to < self.n_ranks, "receiver {to} out of range");
        let from = p.sender();
        self.sent_bytes[from] += p.bytes();
        self.sent_msgs[from] += 1;
        self.inboxes[to].push(p);
    }

    /// Drain rank `p`'s inbox (all packets received this step).
    pub fn drain(&mut self, p: usize) -> Vec<Packet> {
        std::mem::take(&mut self.inboxes[p])
    }

    /// Peek how many packets are waiting.
    pub fn pending(&self, p: usize) -> usize {
        self.inboxes[p].len()
    }

    pub fn sent_bytes(&self, p: usize) -> u64 {
        self.sent_bytes[p]
    }

    pub fn sent_msgs(&self, p: usize) -> usize {
        self.sent_msgs[p]
    }

    /// Reset the per-step accounting (call at each step boundary).
    pub fn reset_accounting(&mut self) {
        self.sent_bytes.fill(0);
        self.sent_msgs.fill(0);
    }

    /// Assert no packets are stranded (end-of-exchange invariant).
    pub fn assert_empty(&self) {
        for (p, ib) in self.inboxes.iter().enumerate() {
            assert!(ib.is_empty(), "rank {p} has {} stranded packets", ib.len());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_and_drain() {
        let mut f = Fabric::new(3);
        f.send(Packet::new(0, 2, 0, 1, 2, vec![1.0, 2.0]));
        f.send(Packet::new(1, 2, 0, 1, 2, vec![3.0, 4.0]));
        assert_eq!(f.pending(2), 2);
        let got = f.drain(2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].sender(), 0);
        assert_eq!(got[1].sender(), 1);
        assert_eq!(f.pending(2), 0);
    }

    #[test]
    fn byte_accounting() {
        let mut f = Fabric::new(2);
        let p = Packet::new(0, 1, 0, 0, 4, vec![0.0; 4]);
        let b = p.bytes();
        f.send(p);
        assert_eq!(f.sent_bytes(0), b);
        assert_eq!(f.sent_msgs(0), 1);
        f.reset_accounting();
        assert_eq!(f.sent_bytes(0), 0);
    }

    #[test]
    #[should_panic(expected = "stranded")]
    fn stranded_packets_detected() {
        let mut f = Fabric::new(2);
        f.send(Packet::new(0, 1, 0, 0, 1, vec![1.0]));
        f.assert_empty();
    }
}
