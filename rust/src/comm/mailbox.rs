//! The simulated-rank message substrate: per-rank inboxes that move real
//! packet payloads between ranks, with byte accounting per (step, rank).
//!
//! This replaces the paper's 25-node InfiniBand fabric (repro band 0 —
//! DESIGN.md §1). Data movement is real (actual count rows are copied
//! between rank-owned buffers and drive the receiver's DP update);
//! *timing* is modeled by the Hockney parameters over the measured bytes.
//!
//! Two fabrics share the packet format:
//!
//! * [`Fabric`] — the original single-threaded mailbox, used by the
//!   sequential exchange executor (one step at a time, all ranks in one
//!   loop).
//! * [`ThreadedFabric`] — the thread-safe variant behind the rank-parallel
//!   pipelined executor: every rank runs on its own thread, `send` is
//!   callable from any of them, and [`ThreadedFabric::recv_step`] blocks
//!   until a step's full packet set has arrived, returning it in the
//!   canonical `(step, sender, seq)` order so delivery is deterministic
//!   regardless of thread interleaving.

use super::fabric::{FabricError, FabricResult, RankFabric, StepLedger};
use super::packet::Packet;
use crate::util::shim::{Condvar, Mutex};
use std::time::Duration;

/// Mailbox fabric for `n_ranks` simulated ranks.
#[derive(Debug)]
pub struct Fabric {
    pub n_ranks: usize,
    inboxes: Vec<Vec<Packet>>,
    /// bytes sent by each rank since the last `reset_accounting`
    sent_bytes: Vec<u64>,
    /// messages sent by each rank
    sent_msgs: Vec<usize>,
}

impl Fabric {
    pub fn new(n_ranks: usize) -> Self {
        Fabric {
            n_ranks,
            inboxes: (0..n_ranks).map(|_| Vec::new()).collect(),
            sent_bytes: vec![0; n_ranks],
            sent_msgs: vec![0; n_ranks],
        }
    }

    /// Send a packet: lands in the receiver's inbox immediately (delivery
    /// order = send order, deterministic).
    pub fn send(&mut self, p: Packet) {
        let to = p.receiver();
        assert!(to < self.n_ranks, "receiver {to} out of range");
        let from = p.sender();
        self.sent_bytes[from] += p.bytes();
        self.sent_msgs[from] += 1;
        self.inboxes[to].push(p);
    }

    /// Drain rank `p`'s inbox (all packets received this step).
    pub fn drain(&mut self, p: usize) -> Vec<Packet> {
        std::mem::take(&mut self.inboxes[p])
    }

    /// Peek how many packets are waiting.
    pub fn pending(&self, p: usize) -> usize {
        self.inboxes[p].len()
    }

    pub fn sent_bytes(&self, p: usize) -> u64 {
        self.sent_bytes[p]
    }

    pub fn sent_msgs(&self, p: usize) -> usize {
        self.sent_msgs[p]
    }

    /// Reset the per-step accounting (call at each step boundary).
    pub fn reset_accounting(&mut self) {
        self.sent_bytes.fill(0);
        self.sent_msgs.fill(0);
    }

    /// Assert no packets are stranded (end-of-exchange invariant).
    pub fn assert_empty(&self) {
        for (p, ib) in self.inboxes.iter().enumerate() {
            assert!(ib.is_empty(), "rank {p} has {} stranded packets", ib.len());
        }
    }
}

/// A queued packet plus the metadata that fixes its canonical position.
#[derive(Debug)]
struct Queued {
    sender: usize,
    step: usize,
    /// per-(sender, step) sequence number, assigned at send time
    seq: u64,
    pkt: Packet,
}

/// How long a receiver may block waiting for a step's packets before the
/// fabric declares the exchange wedged. This is a deadlock backstop, not
/// a workload limit: a healthy wait is bounded by the slowest peer's
/// previous fold step, so the window must comfortably exceed any single
/// step's compute (debug builds on large graphs included).
const RECV_TIMEOUT: Duration = Duration::from_secs(600);

/// Thread-safe mailbox fabric for the rank-parallel exchange executor.
///
/// Senders may run on any thread; every send is stamped with a
/// per-(sender, step) sequence number, and [`Self::recv_step`] hands the
/// receiver its packets sorted by `(sender, seq)` — so the fold order a
/// receiver sees is exactly the order the sequential executor produces
/// (ascending sender rank, send order within a sender), independent of
/// which thread ran first.
///
/// Byte/message accounting is per `(rank, step)` — the threaded analogue
/// of calling `reset_accounting` at each step boundary — and the payload
/// bytes parked in inboxes are charged to a [`SharedAccountant`] under
/// `MemClass::RecvBuffer` from send until receive, exposing the true
/// in-flight high-water mark of the pipeline.
#[derive(Debug)]
pub struct ThreadedFabric {
    pub n_ranks: usize,
    inboxes: Vec<Mutex<Vec<Queued>>>,
    arrivals: Vec<Condvar>,
    /// the shared per-(rank, step) accounting: bytes/messages sent, bytes
    /// drained, send sequence numbers, the one-shot drain tracker and the
    /// in-flight high-water accountant — the same [`StepLedger`] every
    /// [`RankFabric`] implementation carries, so modeled-vs-measured byte
    /// tests run against any of them
    ledger: StepLedger,
}

impl ThreadedFabric {
    /// A fabric for a single exchange of exactly `n_steps` steps (the
    /// historical constructor — tests and one-shot callers).
    pub fn new(n_ranks: usize, n_steps: usize) -> Self {
        Self::for_run(n_ranks, n_steps)
    }

    /// A fabric reused across a whole run's combines: sized for exchanges
    /// of up to `max_steps` steps, reset per combine via
    /// [`RankFabric::begin_exchange`]. The per-step send path then does
    /// two `fetch_add`s on the preallocated ledger grids — no per-combine
    /// reallocation of the accounting state.
    pub fn for_run(n_ranks: usize, max_steps: usize) -> Self {
        ThreadedFabric {
            n_ranks,
            inboxes: (0..n_ranks).map(|_| Mutex::new(Vec::new())).collect(),
            arrivals: (0..n_ranks).map(|_| Condvar::new()).collect(),
            ledger: StepLedger::new(n_ranks, max_steps),
        }
    }

    /// Steps of the exchange currently in progress.
    pub fn n_steps(&self) -> usize {
        self.ledger.n_steps()
    }

    /// Send a packet; the packet's `offset` field is its exchange step.
    /// Callable from any thread.
    pub fn send(&self, p: Packet) {
        let to = p.receiver();
        let from = p.sender();
        let step = p.offset();
        let bytes = p.bytes();
        // range asserts live in the ledger; one call accounts the send
        // and stamps the canonical (sender, step) sequence number
        let seq = self.ledger.note_send(from, to, step, bytes);
        self.ledger.park(bytes);
        {
            let mut ib = self.inboxes[to].lock().unwrap();
            ib.push(Queued {
                sender: from,
                step,
                seq,
                pkt: p,
            });
        }
        self.arrivals[to].notify_all();
    }

    /// The fallible core of [`Self::recv_step`]: waits up to `timeout`
    /// for the step's packet set, returning a typed [`FabricError`] on
    /// expiry instead of panicking. A double drain stays a panic — that
    /// is an executor bug, not a transport condition.
    fn try_recv_step(
        &self,
        p: usize,
        step: usize,
        n_expected: usize,
        timeout: Duration,
    ) -> FabricResult<Vec<Packet>> {
        self.ledger.mark_drained(p, step);
        let mut ib = self.inboxes[p].lock().unwrap();
        while ib.iter().filter(|q| q.step == step).count() < n_expected {
            let (guard, timed) = self.arrivals[p].wait_timeout(ib, timeout).unwrap();
            ib = guard;
            if timed.timed_out() && ib.iter().filter(|q| q.step == step).count() < n_expected {
                let got = ib.iter().filter(|q| q.step == step).count();
                return Err(FabricError::timeout(
                    p,
                    step,
                    format!("{got} of {n_expected} packet(s) arrived before the window closed"),
                ));
            }
        }
        let mut got = Vec::with_capacity(n_expected);
        let mut rest = Vec::with_capacity(ib.len().saturating_sub(n_expected));
        for q in ib.drain(..) {
            if q.step == step {
                got.push(q);
            } else {
                rest.push(q);
            }
        }
        *ib = rest;
        drop(ib);
        got.sort_by_key(|q| (q.sender, q.seq));
        let bytes: u64 = got.iter().map(|q| q.pkt.bytes()).sum();
        self.ledger.note_recv(p, step, bytes);
        self.ledger.unpark(bytes);
        Ok(got.into_iter().map(|q| q.pkt).collect())
    }

    /// Block until at least `n_expected` packets for `step` sit in rank
    /// `p`'s inbox, then take every packet of that step, sorted by
    /// `(sender, seq)`. Packets of other steps stay queued. Panics if the
    /// wait exceeds [`RECV_TIMEOUT`] (a wedged exchange, not slow I/O) or
    /// if the same (rank, step) is drained twice (an executor bug: the
    /// second caller would block forever or steal late packets).
    pub fn recv_step(&self, p: usize, step: usize, n_expected: usize) -> Vec<Packet> {
        match self.try_recv_step(p, step, n_expected, RECV_TIMEOUT) {
            Ok(pkts) => pkts,
            Err(e) => panic!(
                "rank {p} timed out waiting for {n_expected} packet(s) of step {step} ({e})"
            ),
        }
    }

    /// Packets currently waiting for rank `p` (any step).
    pub fn pending(&self, p: usize) -> usize {
        self.inboxes[p].lock().unwrap().len()
    }

    /// Bytes rank `p` sent at `step`.
    pub fn sent_bytes(&self, p: usize, step: usize) -> u64 {
        self.ledger.sent_bytes(p, step)
    }

    /// Messages rank `p` sent at `step`.
    pub fn sent_msgs(&self, p: usize, step: usize) -> u64 {
        self.ledger.sent_msgs(p, step)
    }

    /// Bytes rank `p` received (drained) at `step`.
    pub fn recv_bytes(&self, p: usize, step: usize) -> u64 {
        self.ledger.recv_bytes(p, step)
    }

    /// Total bytes rank `p` sent across all steps (matches the sequential
    /// fabric's accounting summed over its per-step resets).
    pub fn total_sent_bytes(&self, p: usize) -> u64 {
        self.ledger.total_sent_bytes(p)
    }

    /// Total messages rank `p` sent across all steps.
    pub fn total_sent_msgs(&self, p: usize) -> u64 {
        self.ledger.total_sent_msgs(p)
    }

    /// Payload bytes currently in flight (sent, not yet received).
    pub fn in_flight_bytes(&self) -> u64 {
        self.ledger.in_flight_bytes()
    }

    /// High-water mark of in-flight payload bytes over the fabric's life.
    pub fn in_flight_peak(&self) -> u64 {
        self.ledger.in_flight_peak()
    }

    /// Assert no packets are stranded (end-of-exchange invariant).
    pub fn assert_empty(&self) {
        for (p, ib) in self.inboxes.iter().enumerate() {
            let n = ib.lock().unwrap().len();
            assert!(n == 0, "rank {p} has {n} stranded packets");
        }
    }
}

impl RankFabric for ThreadedFabric {
    fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    fn begin_exchange(&self, n_steps: usize) {
        // a clean previous combine drained everything; starting the next
        // one over stranded packets would corrupt the canonical order
        self.assert_empty();
        self.ledger.begin_exchange(n_steps);
        for ib in &self.inboxes {
            // hot-path allocation audit: pre-reserve one slot per peer so
            // steady-state sends never grow the inbox under its lock
            ib.lock().unwrap().reserve(self.n_ranks);
        }
    }

    fn send(&self, p: Packet) -> FabricResult<()> {
        ThreadedFabric::send(self, p);
        Ok(())
    }

    fn recv_step(&self, p: usize, step: usize, n_expected: usize) -> FabricResult<Vec<Packet>> {
        self.try_recv_step(p, step, n_expected, RECV_TIMEOUT)
    }

    fn ledger(&self) -> &StepLedger {
        &self.ledger
    }

    fn pending(&self, p: usize) -> usize {
        ThreadedFabric::pending(self, p)
    }

    fn assert_empty(&self) {
        ThreadedFabric::assert_empty(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_and_drain() {
        let mut f = Fabric::new(3);
        f.send(Packet::new(0, 2, 0, 1, 2, vec![1.0, 2.0]));
        f.send(Packet::new(1, 2, 0, 1, 2, vec![3.0, 4.0]));
        assert_eq!(f.pending(2), 2);
        let got = f.drain(2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].sender(), 0);
        assert_eq!(got[1].sender(), 1);
        assert_eq!(f.pending(2), 0);
    }

    #[test]
    fn byte_accounting() {
        let mut f = Fabric::new(2);
        let p = Packet::new(0, 1, 0, 0, 4, vec![0.0; 4]);
        let b = p.bytes();
        f.send(p);
        assert_eq!(f.sent_bytes(0), b);
        assert_eq!(f.sent_msgs(0), 1);
        f.reset_accounting();
        assert_eq!(f.sent_bytes(0), 0);
    }

    #[test]
    #[should_panic(expected = "stranded")]
    fn stranded_packets_detected() {
        let mut f = Fabric::new(2);
        f.send(Packet::new(0, 1, 0, 0, 1, vec![1.0]));
        f.assert_empty();
    }

    /// One send per sender rank, per step, per receiver: `(sender, step,
    /// k)` encoded in the payload so order and content are checkable.
    fn payload(sender: usize, step: usize, k: usize) -> Vec<f32> {
        vec![sender as f32, step as f32, k as f32]
    }

    /// Satellite: random send schedules executed by genuinely concurrent
    /// sender threads always drain in canonical `(step, sender, seq)`
    /// order, and the threaded fabric's byte/message accounting matches
    /// the sequential fabric fed the identical schedule.
    #[test]
    fn prop_threaded_fabric_canonical_and_accounted() {
        crate::util::prop::check("threaded_fabric", |gen| {
            let n_ranks = gen.usize_in(2, 6);
            let n_steps = gen.usize_in(1, 4);
            // per-sender ordered send streams (the order a rank thread
            // would issue them in)
            let mut by_sender: Vec<Vec<(usize, usize)>> = Vec::new();
            for _ in 0..n_ranks {
                let n_sends = gen.usize_in(0, 12);
                by_sender.push(
                    (0..n_sends)
                        .map(|_| {
                            (
                                gen.usize_in(0, n_ranks - 1),
                                gen.usize_in(0, n_steps - 1),
                            )
                        })
                        .collect(),
                );
            }

            let fab = ThreadedFabric::new(n_ranks, n_steps);
            std::thread::scope(|s| {
                for (from, sends) in by_sender.iter().enumerate() {
                    let fab = &fab;
                    s.spawn(move || {
                        for (k, &(to, step)) in sends.iter().enumerate() {
                            fab.send(Packet::new(from, to, step, 0, 3, payload(from, step, k)));
                        }
                    });
                }
            });

            // sequential reference for the accounting comparison
            let mut seq_fab = Fabric::new(n_ranks);
            for (from, sends) in by_sender.iter().enumerate() {
                for (k, &(to, step)) in sends.iter().enumerate() {
                    seq_fab.send(Packet::new(from, to, step, 0, 3, payload(from, step, k)));
                }
            }
            for p in 0..n_ranks {
                if fab.total_sent_bytes(p) != seq_fab.sent_bytes(p) {
                    return Err(format!(
                        "rank {p}: threaded {} bytes != sequential {}",
                        fab.total_sent_bytes(p),
                        seq_fab.sent_bytes(p)
                    ));
                }
                if fab.total_sent_msgs(p) != seq_fab.sent_msgs(p) as u64 {
                    return Err(format!("rank {p}: message counts differ"));
                }
            }

            // canonical drain: for each (receiver, step), the packets come
            // out sorted by sender, and within a sender in send order
            for p in 0..n_ranks {
                for w in 0..n_steps {
                    let mut expect: Vec<Vec<f32>> = Vec::new();
                    for (from, sends) in by_sender.iter().enumerate() {
                        for (k, &(to, step)) in sends.iter().enumerate() {
                            if to == p && step == w {
                                expect.push(payload(from, w, k));
                            }
                        }
                    }
                    let got = fab.recv_step(p, w, expect.len());
                    if got.len() != expect.len() {
                        return Err(format!(
                            "rank {p} step {w}: {} packets != expected {}",
                            got.len(),
                            expect.len()
                        ));
                    }
                    for (pkt, want) in got.iter().zip(&expect) {
                        if pkt.dense_rows() != want.as_slice() {
                            return Err(format!(
                                "rank {p} step {w}: non-canonical order {:?} vs {want:?}",
                                pkt.dense_rows()
                            ));
                        }
                    }
                }
            }
            fab.assert_empty();
            if fab.in_flight_bytes() != 0 {
                return Err("in-flight bytes nonzero after full drain".into());
            }
            Ok(())
        });
    }

    #[test]
    fn threaded_recv_leaves_other_steps_queued() {
        let fab = ThreadedFabric::new(2, 2);
        fab.send(Packet::new(0, 1, 1, 0, 1, vec![1.0])); // step 1 first
        fab.send(Packet::new(0, 1, 0, 0, 1, vec![2.0]));
        let step0 = fab.recv_step(1, 0, 1);
        assert_eq!(step0.len(), 1);
        assert_eq!(step0[0].dense_rows(), &[2.0]);
        assert_eq!(fab.pending(1), 1, "step-1 packet stays queued");
        let step1 = fab.recv_step(1, 1, 1);
        assert_eq!(step1[0].dense_rows(), &[1.0]);
        fab.assert_empty();
    }

    #[test]
    fn threaded_in_flight_high_water() {
        let fab = ThreadedFabric::new(2, 1);
        let a = Packet::new(0, 1, 0, 0, 2, vec![0.0; 2]);
        let b = Packet::new(0, 1, 0, 0, 4, vec![0.0; 4]);
        let total = a.bytes() + b.bytes();
        fab.send(a);
        fab.send(b);
        assert_eq!(fab.in_flight_bytes(), total);
        let got = fab.recv_step(1, 0, 2);
        assert_eq!(got.len(), 2);
        assert_eq!(fab.in_flight_bytes(), 0);
        assert_eq!(fab.in_flight_peak(), total);
    }

    #[test]
    #[should_panic(expected = "stranded")]
    fn threaded_stranded_packets_detected() {
        let fab = ThreadedFabric::new(2, 1);
        fab.send(Packet::new(0, 1, 0, 0, 1, vec![1.0]));
        fab.assert_empty();
    }

    #[test]
    fn threaded_recv_blocks_until_late_sender() {
        // receiver starts waiting before the second sender has sent:
        // recv_step must block, then deliver in canonical sender order
        let fab = ThreadedFabric::new(3, 1);
        fab.send(Packet::new(1, 2, 0, 0, 1, vec![1.0]));
        let senders: Vec<Packet> = std::thread::scope(|s| {
            let h = s.spawn(|| fab.recv_step(2, 0, 2));
            s.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(20));
                fab.send(Packet::new(0, 2, 0, 0, 1, vec![0.0]));
            });
            h.join().unwrap()
        });
        assert_eq!(senders.len(), 2);
        assert_eq!(senders[0].sender(), 0, "sorted by sender, not arrival");
        assert_eq!(senders[1].sender(), 1);
    }

    #[test]
    #[should_panic(expected = "double drain")]
    fn threaded_double_drain_detected() {
        // recv_step is a one-shot collective per (rank, step): a second
        // drain is an executor bug and must fail loudly, not block or
        // return an empty set
        let fab = ThreadedFabric::new(2, 1);
        fab.send(Packet::new(0, 1, 0, 0, 1, vec![1.0]));
        let got = fab.recv_step(1, 0, 1);
        assert_eq!(got.len(), 1);
        let _ = fab.recv_step(1, 0, 0);
    }

    #[test]
    fn threaded_distinct_steps_are_independent_drains() {
        // the double-drain tracker is keyed per (rank, step): draining
        // every step of a rank once is the normal pipelined pattern
        let fab = ThreadedFabric::new(2, 2);
        fab.send(Packet::new(0, 1, 0, 0, 1, vec![1.0]));
        fab.send(Packet::new(0, 1, 1, 0, 1, vec![2.0]));
        assert_eq!(fab.recv_step(1, 0, 1).len(), 1);
        assert_eq!(fab.recv_step(1, 1, 1).len(), 1);
        fab.assert_empty();
    }

    #[test]
    #[should_panic(expected = "stranded")]
    fn teardown_detects_partially_drained_exchange() {
        // a partial drain (step 0 taken, step 1 left queued) must be
        // caught by the end-of-exchange teardown check
        let fab = ThreadedFabric::new(2, 2);
        fab.send(Packet::new(0, 1, 0, 0, 1, vec![1.0]));
        fab.send(Packet::new(0, 1, 1, 0, 1, vec![2.0]));
        let _ = fab.recv_step(1, 0, 1);
        fab.assert_empty();
    }

    #[test]
    fn reversed_arrival_still_folds_canonically() {
        // physical arrival order fully inverted (later steps first,
        // higher sender ranks first): every drain still comes out in
        // canonical (sender, seq) order with byte accounting intact
        let fab = ThreadedFabric::new(3, 2);
        fab.send(Packet::new(1, 2, 1, 0, 3, payload(1, 1, 0)));
        fab.send(Packet::new(1, 2, 0, 0, 3, payload(1, 0, 0)));
        fab.send(Packet::new(0, 2, 1, 0, 3, payload(0, 1, 0)));
        fab.send(Packet::new(0, 2, 1, 0, 3, payload(0, 1, 1)));
        fab.send(Packet::new(0, 2, 0, 0, 3, payload(0, 0, 0)));
        let s0 = fab.recv_step(2, 0, 2);
        let got0: Vec<usize> = s0.iter().map(|p| p.sender()).collect();
        assert_eq!(got0, [0, 1]);
        assert_eq!(s0[0].dense_rows(), payload(0, 0, 0).as_slice());
        let s1 = fab.recv_step(2, 1, 3);
        let got1: Vec<usize> = s1.iter().map(|p| p.sender()).collect();
        assert_eq!(got1, [0, 0, 1], "senders ascending, seq within sender");
        assert_eq!(s1[0].dense_rows(), payload(0, 1, 0).as_slice());
        assert_eq!(s1[1].dense_rows(), payload(0, 1, 1).as_slice());
        fab.assert_empty();
        assert_eq!(fab.in_flight_bytes(), 0);
    }
}

/// Exhaustive small-config schedules of the threaded fabric protocol
/// under the bounded-interleaving model checker: canonical drain order,
/// conservation of in-flight bytes, and deadlock reporting.
#[cfg(all(test, feature = "model-check"))]
mod model_tests {
    use super::*;
    use crate::util::shim::model;
    use std::sync::Arc;

    #[test]
    fn model_two_senders_always_drain_canonically() {
        // 2 senders × 1 receiver, one step: whatever order the sends
        // land in, the receiver sees (sender, seq) canonical order and
        // the ledger conserves
        let pkt_bytes = Packet::new(0, 2, 0, 0, 1, vec![0.0]).bytes();
        model::Model::new().preemption_bound(2).check(move || {
            let fab = Arc::new(ThreadedFabric::new(3, 1));
            let f0 = Arc::clone(&fab);
            let s0 = model::spawn(move || {
                f0.send(Packet::new(0, 2, 0, 0, 1, vec![10.0]));
                f0.send(Packet::new(0, 2, 0, 0, 1, vec![11.0]));
            });
            let f1 = Arc::clone(&fab);
            let s1 = model::spawn(move || {
                f1.send(Packet::new(1, 2, 0, 0, 1, vec![20.0]));
            });
            let fr = Arc::clone(&fab);
            let r = model::spawn(move || {
                let got = fr.recv_step(2, 0, 3);
                let vals: Vec<f32> = got.iter().map(|p| p.dense_rows()[0]).collect();
                assert_eq!(vals, [10.0, 11.0, 20.0], "canonical (sender, seq) order");
            });
            s0.join();
            s1.join();
            r.join();
            fab.assert_empty();
            assert_eq!(fab.in_flight_bytes(), 0, "all charged bytes released");
            assert!(fab.in_flight_peak() >= pkt_bytes, "peak below one packet");
            assert!(fab.in_flight_peak() <= 3 * pkt_bytes);
            assert_eq!(fab.recv_bytes(2, 0), 3 * pkt_bytes);
        });
    }

    #[test]
    fn model_two_rank_two_step_pipeline() {
        // the Fig-3 overlap shape on 2 ranks × 2 steps: each rank posts
        // both steps' sends up front (so a step-1 packet can arrive
        // before step 0 is drained), then drains its steps in order.
        // Every schedule must complete with canonical per-step payloads.
        model::Model::new().preemption_bound(2).check(|| {
            let fab = Arc::new(ThreadedFabric::new(2, 2));
            let run = |fab: Arc<ThreadedFabric>, r: usize| {
                let q = 1 - r;
                fab.send(Packet::new(r, q, 0, 0, 1, vec![(10 * r) as f32]));
                fab.send(Packet::new(r, q, 1, 0, 1, vec![(10 * r + 1) as f32]));
                let s0 = fab.recv_step(r, 0, 1);
                assert_eq!(s0[0].dense_rows(), &[(10 * q) as f32]);
                let s1 = fab.recv_step(r, 1, 1);
                assert_eq!(s1[0].dense_rows(), &[(10 * q + 1) as f32]);
            };
            let f0 = Arc::clone(&fab);
            let t0 = model::spawn(move || run(f0, 0));
            let f1 = Arc::clone(&fab);
            let t1 = model::spawn(move || run(f1, 1));
            t0.join();
            t1.join();
            fab.assert_empty();
            assert_eq!(fab.in_flight_bytes(), 0);
        });
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn model_missing_packet_reported_as_deadlock() {
        // the receiver expects two packets but only one is ever sent: in
        // the model build the condvar wait cannot time out, so the
        // checker must diagnose the blocked receiver as a deadlock (with
        // its BlockedCondvar state in the report)
        model::Model::new().check(|| {
            let fab = Arc::new(ThreadedFabric::new(3, 1));
            let fs = Arc::clone(&fab);
            let s = model::spawn(move || {
                fs.send(Packet::new(0, 2, 0, 0, 1, vec![1.0]));
            });
            let fr = Arc::clone(&fab);
            let r = model::spawn(move || {
                let _ = fr.recv_step(2, 0, 2);
            });
            s.join();
            r.join();
        });
    }
}
