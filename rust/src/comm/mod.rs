//! Communication substrate: the Hockney cost model (Eq 8), bit-packed
//! packet meta IDs (Fig 4), the simulated-rank mailbox fabric, exchange
//! schedules (all-to-all and the Adaptive-Group ring of Fig 2), and the
//! adaptive mode switch (Alg 3).

pub mod adaptive;
pub mod fabric;
pub mod frame;
pub mod group;
pub mod hockney;
pub mod mailbox;
pub mod packet;
pub mod socket;

pub use adaptive::{AdaptivePolicy, CombineShape, CommMode, GroupCalibration, GroupPrediction};
pub use fabric::{FabricError, FabricResult, LinkMeasurement, RankFabric, StepLedger};
pub use frame::{config_digest, Frame, FrameError, Handshake, WIRE_VERSION};
pub use group::{Schedule, StepPlan};
pub use hockney::HockneyParams;
pub use mailbox::{Fabric, ThreadedFabric};
pub use packet::{decode_meta, encode_meta, Packet};
pub use socket::{PeerAddr, SocketFabric, SocketListener, SocketOptions};
