//! The adaptive communication decision (Alg 3 line 2, generalized): pick
//! the exchange shape of every subtemplate combine from the Eq 8 / Eq 14
//! Hockney + compute model instead of a hard-wired switch.
//!
//! Two layers:
//!
//! * [`AdaptivePolicy::choose`] — the paper's coarse per-template gate
//!   ("if |Ti| is large", §3.2.2): pipeline compute-heavy templates, stay
//!   on all-to-all otherwise. Kept as the fast path and as the first
//!   filter of the sweep below.
//! * [`AdaptivePolicy::choose_group`] — the model-driven sweep: for one
//!   subtemplate combine ([`CombineShape`]) evaluate every feasible ring
//!   group size `g ∈ 1..=(P-1)/2` through the per-step compute (Eq 4) and
//!   transfer (Eq 8) models, predict the overlap ratio ρ (Eq 14) and the
//!   pipelined makespan (Eq 9–13, including the short last step when
//!   `g ∤ P-1`), pick the `g` maximizing predicted ρ, and fall back to
//!   bulk all-to-all when no candidate's predicted makespan beats it.
//!
//! The model self-calibrates at runtime through [`GroupCalibration`]: the
//! coordinator feeds back the measured per-unit compute cost and the
//! measured per-step ρ of previous iterations, which rescale the compute
//! and transfer models for the next iteration's decisions.

use crate::colorcount::Count;
use crate::combin::Binomial;
use crate::comm::group::Schedule;
use crate::comm::hockney::HockneyParams;
use crate::comm::packet::Packet;
use crate::template::TemplateComplexity;

/// Which exchange schedule to use for a combine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommMode {
    AllToAll,
    /// ring with `g` offsets per step (group size 2g+1)
    Pipeline { g: usize },
}

/// Tunables for the switch. Defaults reproduce the paper's behaviour:
/// u10-2 (intensity 5.3) and larger pipeline; u3-1/u5-2/u7-2 (≤ 3.5)
/// stay on all-to-all (Fig 9).
#[derive(Debug, Clone, Copy)]
pub struct AdaptivePolicy {
    /// minimum Table-3 computation intensity to pipeline
    pub intensity_threshold: f64,
    /// below this rank count pipelining is pointless
    pub min_ranks: usize,
    /// per-combine-unit compute cost in seconds (calibrated by the
    /// coordinator from real measurements)
    pub flop_time: f64,
    pub net: HockneyParams,
}

impl Default for AdaptivePolicy {
    fn default() -> Self {
        AdaptivePolicy {
            intensity_threshold: 4.5,
            min_ranks: 3,
            flop_time: 0.5e-9,
            net: HockneyParams::default(),
        }
    }
}

/// Inputs describing one subtemplate combine on one rank.
#[derive(Debug, Clone, Copy)]
pub struct CombineShape {
    pub k: usize,
    /// |Ti|
    pub size: usize,
    /// |Ti'|
    pub passive_size: usize,
    /// |Ti''|
    pub active_size: usize,
    /// expected remote neighbor rows received *per peer* per step,
    /// ≈ |E|/P² (Eq 5); the coordinator passes the exact request-list
    /// mean instead of the asymptotic estimate
    pub remote_rows_per_step: f64,
    pub n_ranks: usize,
    /// expected *encoded* wire bytes per shipped row, when the active
    /// table is stored (and therefore shipped) sparse — the coordinator
    /// derives it from the previous iteration's measured density
    /// (`colorcount::storage::expected_sparse_row_bytes`). `None` keeps
    /// the dense charge `size_of::<Count>() · C(k, |Ti''|)`, so dense
    /// runs predict byte-for-byte what they always did.
    pub wire_row_bytes: Option<f64>,
}

/// One candidate exchange shape, evaluated through the model: the ring
/// with `g` offsets per step (or single-step all-to-all when
/// `n_steps == 1`), its predicted first-step compute/transfer seconds,
/// overlap ratio ρ and end-to-end exchange makespan.
#[derive(Debug, Clone, Copy)]
pub struct GroupPrediction {
    /// offsets per step (the paper's group size is 2g+1)
    pub g: usize,
    /// W = ceil((P-1)/g)
    pub n_steps: usize,
    /// modeled fold seconds for a full step's received rows (Eq 4)
    pub step_comp: f64,
    /// modeled transfer seconds for a full step (Eq 8)
    pub step_comm: f64,
    /// predicted mean overlap ratio ρ over the non-cold-start steps
    /// (Eq 14); 0 for a single-step exchange (nothing to overlap)
    pub rho: f64,
    /// predicted exchange makespan (Eq 9–13): cold-start transfer, then
    /// each stage overlaps the previous step's fold with the next
    /// transfer, plus the final exposed fold
    pub makespan: f64,
}

/// Runtime feedback folded into the policy between iterations: the
/// coordinator's measured per-unit compute cost and the mismatch between
/// predicted and measured per-step overlap. Both are EWMA-smoothed and
/// clamped so one noisy iteration cannot capsize the decisions.
#[derive(Debug, Clone, Copy)]
pub struct GroupCalibration {
    /// measured seconds per compute unit (None until the first feedback)
    pub flop_time: Option<f64>,
    /// multiplicative correction on the modeled transfer times: > 1 when
    /// measured overlap keeps falling short of the prediction (transfers
    /// effectively cost more than the Hockney parameters claim)
    pub comm_scale: f64,
    /// ρ observations folded in
    pub n_rho: u64,
}

impl Default for GroupCalibration {
    fn default() -> Self {
        GroupCalibration {
            flop_time: None,
            comm_scale: 1.0,
            n_rho: 0,
        }
    }
}

impl GroupCalibration {
    /// Fold in one iteration's measured seconds-per-unit (EWMA).
    pub fn observe_flop_time(&mut self, measured: f64) {
        let m = measured.max(1e-12);
        self.flop_time = Some(match self.flop_time {
            None => m,
            Some(prev) => 0.5 * prev + 0.5 * m,
        });
    }

    /// Fold in one (predicted ρ, measured ρ) observation — the
    /// coordinator feeds one per iteration, geometric-meaned over that
    /// iteration's combines. Measured overlap below the prediction means
    /// the model undercosts transfers: scale them up, and vice versa. The
    /// per-observation step is damped (square root) and the total
    /// correction clamped to [1/4, 4], so one noisy iteration cannot
    /// capsize the decisions.
    pub fn observe_rho(&mut self, predicted: f64, measured: f64) {
        let p = predicted.clamp(0.05, 1.0);
        let m = measured.clamp(0.05, 1.0);
        let step = (p / m).sqrt().clamp(0.5, 2.0);
        self.comm_scale = (self.comm_scale * step).clamp(0.25, 4.0);
        self.n_rho += 1;
    }
}

impl AdaptivePolicy {
    /// Largest ring group size feasible at `n_ranks`: the pipelined ring
    /// needs full communication groups of m = 2g+1 ≤ P, i.e. g ≤ (P-1)/2.
    /// 0 means no pipelined ring exists (P < 3).
    pub fn max_feasible_group(n_ranks: usize) -> usize {
        n_ranks.saturating_sub(1) / 2
    }

    /// The feasible ring group sizes at `n_ranks` (empty below P = 3).
    pub fn feasible_groups(n_ranks: usize) -> std::ops::RangeInclusive<usize> {
        1..=Self::max_feasible_group(n_ranks)
    }

    /// Wire bytes of one count row at the engine's actual element width
    /// (the fabric moves `Count` rows, so the model must charge
    /// `size_of::<Count>()` per entry — not a hard-coded width).
    pub fn row_bytes(k: usize, active_size: usize, binom: &Binomial) -> u64 {
        binom.c(k, active_size) * std::mem::size_of::<Count>() as u64
    }

    /// A policy with the runtime feedback applied: measured flop time
    /// replaces the configured one, and the transfer model is rescaled by
    /// the observed overlap mismatch.
    pub fn calibrated(&self, cal: &GroupCalibration) -> AdaptivePolicy {
        let mut p = *self;
        if let Some(ft) = cal.flop_time {
            p.flop_time = ft;
        }
        p.net.alpha *= cal.comm_scale;
        p.net.beta *= cal.comm_scale;
        p.net.step_overhead *= cal.comm_scale;
        p
    }

    /// The coarse per-template mode switch (Alg 3 line 2). `Pipeline`
    /// requires a feasible ring (2g+1 ≤ P), so P < 3 never pipelines
    /// regardless of `min_ranks`.
    pub fn choose(&self, tc: &TemplateComplexity, n_ranks: usize) -> CommMode {
        if n_ranks >= self.min_ranks
            && Self::max_feasible_group(n_ranks) >= 1
            && tc.intensity >= self.intensity_threshold
        {
            CommMode::Pipeline { g: 1 }
        } else {
            CommMode::AllToAll
        }
    }

    /// Modeled fold time for a step that receives from `offsets` peers
    /// (Eq 4 scaled by `flop_time`).
    pub fn step_compute_g(&self, s: &CombineShape, offsets: usize, binom: &Binomial) -> f64 {
        let units = binom.c(s.k, s.size) as f64 * binom.c(s.size, s.passive_size) as f64;
        self.flop_time * units * offsets as f64 * s.remote_rows_per_step.max(0.0)
    }

    /// Modeled transfer time for a step that exchanges with `offsets`
    /// peers (Eq 8): per-step software overhead, per-message latency, and
    /// the payload at its *encoded* width — the engine's dense element
    /// width by default, or the shape's expected sparse row bytes when
    /// the active table ships sparse — plus the per-packet header the
    /// fabric actually accounts.
    pub fn step_comm_g(&self, s: &CombineShape, offsets: usize, binom: &Binomial) -> f64 {
        let row_bytes = s
            .wire_row_bytes
            .unwrap_or_else(|| Self::row_bytes(s.k, s.active_size, binom) as f64);
        let rows = offsets as f64 * s.remote_rows_per_step.max(0.0);
        let bytes = rows * row_bytes + (offsets as u64 * Packet::HEADER_BYTES) as f64;
        self.net.step(offsets, bytes.round() as u64)
    }

    /// Back-compat g = 1 helpers (the shape the paper's Fig 8 analysis
    /// uses).
    pub fn step_compute(&self, s: &CombineShape, binom: &Binomial) -> f64 {
        self.step_compute_g(s, 1, binom)
    }

    pub fn step_comm(&self, s: &CombineShape, binom: &Binomial) -> f64 {
        self.step_comm_g(s, 1, binom)
    }

    /// The predicted overlap ratio ρ (Eq 14) of the g = 1 ring: as the
    /// rank count grows, per-step compute shrinks ∝ 1/P² against the α
    /// latency floor, which is exactly why small templates stop
    /// overlapping (paper Fig 8).
    pub fn overlap(&self, s: &CombineShape, binom: &Binomial) -> f64 {
        let comm = self.step_comm(s, binom);
        if comm <= 0.0 {
            return 1.0;
        }
        (self.step_compute(s, binom) / comm).min(1.0)
    }

    /// Evaluate the ring with `g` offsets per step through the pipeline
    /// algebra, honoring the short last step when `g ∤ P-1`. The per-step
    /// chunking comes from [`Schedule::ring_step_sizes`] — the same
    /// definition the executed schedule is built from.
    pub fn predict_group(&self, s: &CombineShape, g: usize, binom: &Binomial) -> GroupPrediction {
        let g = g.max(1);
        let sizes = Schedule::ring_step_sizes(s.n_ranks, g);
        let n_steps = sizes.len();
        if n_steps == 0 {
            return GroupPrediction {
                g,
                n_steps: 0,
                step_comp: 0.0,
                step_comm: 0.0,
                rho: 0.0,
                makespan: 0.0,
            };
        }
        let comp: Vec<f64> = sizes
            .iter()
            .map(|&m| self.step_compute_g(s, m, binom))
            .collect();
        let comm: Vec<f64> = sizes
            .iter()
            .map(|&m| self.step_comm_g(s, m, binom))
            .collect();
        // Eq 9–13: cold-start transfer; stage w overlaps fold(w-1) with
        // transfer(w); the last step's fold is fully exposed.
        let mut makespan = comm[0];
        let mut rho_sum = 0.0;
        for w in 1..n_steps {
            makespan += comm[w].max(comp[w - 1]);
            rho_sum += if comm[w] <= 0.0 {
                1.0
            } else {
                (comp[w - 1] / comm[w]).min(1.0)
            };
        }
        makespan += comp[n_steps - 1];
        let rho = if n_steps > 1 {
            rho_sum / (n_steps - 1) as f64
        } else {
            0.0
        };
        GroupPrediction {
            g,
            n_steps,
            step_comp: comp[0],
            step_comm: comm[0],
            rho,
            makespan,
        }
    }

    /// Evaluate the single-step bulk all-to-all (the naive schedule):
    /// every transfer exposed, then the full fold.
    pub fn predict_all_to_all(&self, s: &CombineShape, binom: &Binomial) -> GroupPrediction {
        let peers = s.n_ranks.saturating_sub(1).max(1);
        let comp = self.step_compute_g(s, peers, binom);
        let comm = self.step_comm_g(s, peers, binom);
        GroupPrediction {
            g: peers,
            n_steps: 1,
            step_comp: comp,
            step_comm: comm,
            rho: 0.0,
            makespan: comm + comp,
        }
    }

    /// The model-driven sweep: the intensity gate first (paper Alg 3
    /// line 2), then every feasible `g ∈ 1..=(P-1)/2` through
    /// [`Self::predict_group`]. Among the candidates whose predicted
    /// makespan beats the single-step bulk exchange, the argmax-ρ one
    /// wins (ties broken by smaller predicted makespan, then smaller
    /// `g` — the paper's default); all-to-all when no candidate beats it.
    pub fn choose_group(
        &self,
        tc: &TemplateComplexity,
        s: &CombineShape,
        binom: &Binomial,
    ) -> (CommMode, GroupPrediction) {
        const RHO_EPS: f64 = 1e-9;
        let all = self.predict_all_to_all(s, binom);
        if s.n_ranks < self.min_ranks || tc.intensity < self.intensity_threshold {
            return (CommMode::AllToAll, all);
        }
        let mut best: Option<GroupPrediction> = None;
        for g in Self::feasible_groups(s.n_ranks) {
            let p = self.predict_group(s, g, binom);
            if p.makespan >= all.makespan {
                continue; // cannot beat the bulk exchange
            }
            let replace = match &best {
                None => true,
                Some(b) => {
                    p.rho > b.rho + RHO_EPS
                        || ((p.rho - b.rho).abs() <= RHO_EPS && p.makespan < b.makespan)
                }
            };
            if replace {
                best = Some(p);
            }
        }
        match best {
            Some(b) => (CommMode::Pipeline { g: b.g }, b),
            None => (CommMode::AllToAll, all),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::template::{builtin, complexity};

    #[test]
    fn paper_mode_assignments() {
        let pol = AdaptivePolicy::default();
        for (name, want_pipeline) in [
            ("u3-1", false),
            ("u5-2", false),
            ("u7-2", false),
            ("u10-2", true),
            ("u12-1", true),
            ("u12-2", true),
            ("u15-1", true),
        ] {
            let tc = complexity(&builtin(name).unwrap());
            let mode = pol.choose(&tc, 10);
            assert_eq!(
                matches!(mode, CommMode::Pipeline { .. }),
                want_pipeline,
                "{name}: got {mode:?} (intensity {})",
                tc.intensity
            );
        }
    }

    #[test]
    fn two_ranks_never_pipeline() {
        let pol = AdaptivePolicy::default();
        let tc = complexity(&builtin("u12-2").unwrap());
        assert_eq!(pol.choose(&tc, 2), CommMode::AllToAll);
        // …even when min_ranks is mistuned: no ring of groups 2g+1 ≤ 2
        // exists, so the gate must clamp on feasibility (the historical
        // bug returned Pipeline{g: 1} here)
        let mut loose = pol;
        loose.min_ranks = 1;
        assert_eq!(loose.choose(&tc, 2), CommMode::AllToAll);
        assert_eq!(AdaptivePolicy::max_feasible_group(2), 0);
        assert!(AdaptivePolicy::feasible_groups(2).next().is_none());
    }

    #[test]
    fn three_ranks_feasibility_clamp() {
        // P = 3: exactly one feasible ring group size (g = 1, m = 3)
        assert_eq!(AdaptivePolicy::max_feasible_group(3), 1);
        let feas: Vec<usize> = AdaptivePolicy::feasible_groups(3).collect();
        assert_eq!(feas, vec![1]);
        let pol = AdaptivePolicy::default();
        let tc = complexity(&builtin("u12-2").unwrap());
        let b = crate::combin::Binomial::new();
        let s = shape(12, 8, 4, 500.0, 3);
        let (mode, pred) = pol.choose_group(&tc, &s, &b);
        if let CommMode::Pipeline { g } = mode {
            assert_eq!(g, 1, "only g = 1 is feasible at P = 3");
            assert_eq!(pred.n_steps, 2);
        }
    }

    fn shape(k: usize, size: usize, pass: usize, rows: f64, ranks: usize) -> CombineShape {
        CombineShape {
            k,
            size,
            passive_size: pass,
            active_size: size - pass,
            remote_rows_per_step: rows,
            n_ranks: ranks,
            wire_row_bytes: None,
        }
    }

    #[test]
    fn overlap_decays_with_rank_count() {
        // same graph, more ranks -> fewer rows per step -> α floor wins.
        // (Use a small-template shape with a fast effective flop time, as
        // measured for streaming |Ti''|=1 updates, so the latency floor is
        // actually reachable — the regime of Fig 8's small-template drop.)
        let b = Binomial::new();
        let mut pol = AdaptivePolicy::default();
        pol.flop_time = 0.3e-9;
        let edges = 4.0e6;
        let rho_small_p = pol.overlap(&shape(3, 2, 1, edges / 16.0, 4), &b);
        let rho_large_p = pol.overlap(&shape(3, 2, 1, edges / 4096.0, 64), &b);
        assert!(rho_large_p < rho_small_p);
        assert!(rho_large_p < 0.5, "α floor must dominate at P=64");
    }

    #[test]
    fn overlap_monotone_in_intensity() {
        let b = Binomial::new();
        let pol = AdaptivePolicy::default();
        let lo = pol.overlap(&shape(5, 3, 1, 1_000.0, 8), &b);
        let hi = pol.overlap(&shape(12, 10, 5, 1_000.0, 8), &b);
        assert!(hi >= lo, "bigger combine units must not lower overlap");
    }

    #[test]
    fn slower_network_discourages_pipeline() {
        let b = Binomial::new();
        let mut pol = AdaptivePolicy::default();
        let s = shape(7, 5, 2, 3_000.0, 8);
        let fast = pol.overlap(&s, &b);
        pol.net = HockneyParams::tengige();
        let slow = pol.overlap(&s, &b);
        assert!(slow <= fast);
    }

    #[test]
    fn row_bytes_track_engine_element_width() {
        let b = Binomial::new();
        // the fabric ships Count rows: the model must charge exactly that
        let expect = b.c(12, 4) * std::mem::size_of::<Count>() as u64;
        assert_eq!(AdaptivePolicy::row_bytes(12, 4, &b), expect);
        // and the per-step bytes the model charges match a real packet
        // carrying the same rows (header included)
        let n_sets = b.c(12, 4) as usize;
        let rows_per_peer = 7usize;
        let pkt = Packet::new(0, 1, 0, 0, n_sets, vec![0.0; rows_per_peer * n_sets]);
        assert_eq!(
            pkt.bytes(),
            rows_per_peer as u64 * AdaptivePolicy::row_bytes(12, 4, &b) + Packet::HEADER_BYTES
        );
    }

    /// Sparse-encoded exchanges charge the measured-density wire model:
    /// cheaper transfers than dense for the same shape, raising predicted
    /// ρ — the model stays honest about what the fabric will ship.
    #[test]
    fn sparse_wire_bytes_move_the_model() {
        let b = Binomial::new();
        let pol = AdaptivePolicy::default();
        let mut s = shape(10, 6, 3, 2_000.0, 8);
        let dense_comm = pol.step_comm_g(&s, 1, &b);
        let n_sets = b.c(10, 3) as usize;
        let density = 0.1;
        s.wire_row_bytes = Some(crate::colorcount::storage::expected_sparse_row_bytes(
            density, n_sets,
        ));
        let sparse_comm = pol.step_comm_g(&s, 1, &b);
        assert!(
            sparse_comm < dense_comm,
            "sparse {sparse_comm} must undercut dense {dense_comm}"
        );
        assert!(pol.overlap(&s, &b) >= {
            let mut d = s;
            d.wire_row_bytes = None;
            pol.overlap(&d, &b)
        });
        // near-full density the sparse encoding is *more* expensive
        // (8 bytes/entry vs 4) and the model must say so
        s.wire_row_bytes = Some(crate::colorcount::storage::expected_sparse_row_bytes(
            1.0, n_sets,
        ));
        assert!(pol.step_comm_g(&s, 1, &b) > dense_comm);
    }

    #[test]
    fn step_counts_match_ring_schedule() {
        // the model predicts against the exact chunking the executed
        // schedule realizes (shared by construction; pinned here anyway)
        for p in 1..20usize {
            for g in 1..20usize {
                let sizes = Schedule::ring_step_sizes(p, g);
                let sched = Schedule::ring(p, g);
                assert_eq!(sizes.len(), sched.n_steps(), "P={p} g={g}");
                for (w, os) in sched.offsets.iter().enumerate() {
                    assert_eq!(sizes[w], os.len(), "P={p} g={g} step {w}");
                }
            }
        }
    }

    /// The mid-regime where the sweep genuinely prefers g = 2: per-step
    /// compute at g = 1 sits below the transfer floor (ρ < 1) but doubling
    /// the group crosses it, and the predicted pipelined makespan still
    /// beats bulk all-to-all. Worked constants: P = 6, IB overhead 50 µs,
    /// x₁ ≈ 40 µs.
    #[test]
    fn sweep_picks_wider_group_in_mid_regime() {
        let b = Binomial::new();
        let mut pol = AdaptivePolicy::default();
        let s = shape(12, 8, 4, 1.0, 6);
        // units = C(12,8)·C(8,4) = 495·70 = 34650; aim x₁ = 40 µs
        pol.flop_time = 40.0e-6 / 34650.0;
        let tc = complexity(&builtin("u12-1").unwrap());
        assert!(tc.intensity >= pol.intensity_threshold);
        let (mode, pred) = pol.choose_group(&tc, &s, &b);
        assert_eq!(mode, CommMode::Pipeline { g: 2 }, "prediction: {pred:?}");
        assert_eq!(pred.n_steps, 3); // ceil(5/2)
        let rho1 = pol.predict_group(&s, 1, &b).rho;
        assert!(pred.rho > rho1, "g=2 must out-overlap g=1 here");
        assert!(pred.makespan < pol.predict_all_to_all(&s, &b).makespan);
    }

    /// Compute-rich shapes tie at ρ = 1 for every g; the tie-break keeps
    /// the paper's g = 1 default (finest pipelining, smallest slices).
    #[test]
    fn compute_bound_keeps_paper_default_group() {
        let b = Binomial::new();
        let mut pol = AdaptivePolicy::default();
        pol.flop_time = 1.0e-6; // grossly compute-bound
        let s = shape(12, 8, 4, 100.0, 8);
        let tc = complexity(&builtin("u12-2").unwrap());
        let (mode, pred) = pol.choose_group(&tc, &s, &b);
        assert_eq!(mode, CommMode::Pipeline { g: 1 });
        assert!((pred.rho - 1.0).abs() < 1e-9);
    }

    /// Nothing to hide (no compute): the extra per-step overheads make
    /// every ring worse than one bulk exchange — the fallback must fire.
    #[test]
    fn comm_only_falls_back_to_all_to_all() {
        let b = Binomial::new();
        let mut pol = AdaptivePolicy::default();
        pol.flop_time = 1.0e-15;
        let s = shape(12, 8, 4, 50.0, 8);
        let tc = complexity(&builtin("u12-2").unwrap());
        let (mode, pred) = pol.choose_group(&tc, &s, &b);
        assert_eq!(mode, CommMode::AllToAll);
        assert_eq!(pred.n_steps, 1);
        assert_eq!(pred.rho, 0.0);
    }

    /// Satellite: the chosen `g` is the argmax of modeled ρ over the
    /// feasible candidates 1..=(P-1)/2 whose predicted makespan beats the
    /// bulk exchange, for random shapes and policies; the all-to-all
    /// fallback fires exactly when no candidate beats it.
    #[test]
    fn prop_choice_is_rho_argmax_over_feasible_range() {
        let b = Binomial::new();
        let tc_hi = TemplateComplexity {
            name: "synthetic".into(),
            k: 12,
            memory: 1,
            computation: 100,
            intensity: 100.0, // always past the gate: exercise the sweep
        };
        crate::util::prop::check("rho_argmax", |gen| {
            let ranks = gen.usize_in(2, 24);
            let size = gen.usize_in(2, 10);
            let pass = gen.usize_in(1, size - 1);
            let s = CombineShape {
                k: 12,
                size,
                passive_size: pass,
                active_size: size - pass,
                remote_rows_per_step: gen.f64_in(0.0, 5_000.0),
                n_ranks: ranks,
                wire_row_bytes: None,
            };
            let mut pol = AdaptivePolicy::default();
            pol.flop_time = 10f64.powf(gen.f64_in(-12.0, -5.0));
            if gen.bool() {
                pol.net = HockneyParams::tengige();
            }
            let (mode, pred) = pol.choose_group(&tc_hi, &s, &b);
            let all = pol.predict_all_to_all(&s, &b);
            // the contenders: feasible rings predicted to beat bulk
            let contenders: Vec<GroupPrediction> = AdaptivePolicy::feasible_groups(ranks)
                .map(|g| pol.predict_group(&s, g, &b))
                .filter(|p| p.makespan < all.makespan)
                .collect();
            let best_rho = contenders.iter().map(|p| p.rho).fold(0.0f64, f64::max);
            match mode {
                CommMode::Pipeline { g } => {
                    if g > AdaptivePolicy::max_feasible_group(ranks) {
                        return Err(format!("infeasible g={g} at P={ranks}"));
                    }
                    if pred.makespan >= all.makespan {
                        return Err(format!(
                            "pipelined makespan {} does not beat all-to-all {}",
                            pred.makespan, all.makespan
                        ));
                    }
                    if pred.rho + 1e-9 < best_rho {
                        return Err(format!(
                            "chose g={g} with rho {} < contender max {}",
                            pred.rho, best_rho
                        ));
                    }
                }
                CommMode::AllToAll => {
                    if !contenders.is_empty() {
                        return Err(format!(
                            "fell back to all-to-all although {} candidate(s) \
                             beat it at P={ranks}",
                            contenders.len()
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn calibration_feedback_moves_the_model_the_right_way() {
        let b = Binomial::new();
        let pol = AdaptivePolicy::default();
        let s = shape(10, 6, 3, 200.0, 8);

        // measured overlap short of the prediction → transfers are
        // undercosted → comm_scale rises → predicted ρ drops
        let mut cal = GroupCalibration::default();
        cal.observe_rho(0.9, 0.3);
        assert!(cal.comm_scale > 1.0);
        let before = pol.predict_group(&s, 1, &b).rho;
        let after = pol.calibrated(&cal).predict_group(&s, 1, &b).rho;
        assert!(after <= before, "rho {after} must not rise past {before}");

        // the other direction: better-than-predicted overlap cheapens the
        // modeled transfers
        let mut cal2 = GroupCalibration::default();
        cal2.observe_rho(0.3, 0.9);
        assert!(cal2.comm_scale < 1.0);

        // clamps hold under hostile streaks
        for _ in 0..100 {
            cal.observe_rho(1.0, 0.05);
            cal2.observe_rho(0.05, 1.0);
        }
        assert!(cal.comm_scale <= 4.0 + 1e-12);
        assert!(cal2.comm_scale >= 0.25 - 1e-12);

        // flop-time feedback: EWMA lands between old and new observations
        let mut cal3 = GroupCalibration::default();
        cal3.observe_flop_time(2.0e-9);
        assert_eq!(cal3.flop_time, Some(2.0e-9));
        cal3.observe_flop_time(4.0e-9);
        let ft = cal3.flop_time.unwrap();
        assert!(ft > 2.0e-9 && ft < 4.0e-9);
        assert_eq!(pol.calibrated(&cal3).flop_time, ft);
    }
}
