//! The adaptive mode switch (Alg 3 line 2): use the pipelined ring for
//! compute-heavy templates, fall back to all-to-all when there is not
//! enough computation to hide the per-step transfers.
//!
//! The implementation follows the paper: the decision is made per template
//! from its Table-3 computation intensity (the paper's "if |Ti| is large"
//! with the §3.2.2 justification). The Hockney-based per-step model is
//! also exposed here — the figure harness uses it to *predict* the overlap
//! ratio ρ (Eq 14) that the pipeline ledger later measures.

use crate::combin::Binomial;
use crate::comm::hockney::HockneyParams;
use crate::template::TemplateComplexity;

/// Which exchange schedule to use for a template's combines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommMode {
    AllToAll,
    /// ring with `g` offsets per step (group size 2g+1)
    Pipeline { g: usize },
}

/// Tunables for the switch. Defaults reproduce the paper's behaviour:
/// u10-2 (intensity 5.3) and larger pipeline; u3-1/u5-2/u7-2 (≤ 3.5)
/// stay on all-to-all (Fig 9).
#[derive(Debug, Clone, Copy)]
pub struct AdaptivePolicy {
    /// minimum Table-3 computation intensity to pipeline
    pub intensity_threshold: f64,
    /// below this rank count pipelining is pointless
    pub min_ranks: usize,
    /// per-combine-unit compute cost in seconds (calibrated by the
    /// coordinator from real measurements)
    pub flop_time: f64,
    pub net: HockneyParams,
}

impl Default for AdaptivePolicy {
    fn default() -> Self {
        AdaptivePolicy {
            intensity_threshold: 4.5,
            min_ranks: 3,
            flop_time: 0.5e-9,
            net: HockneyParams::default(),
        }
    }
}

/// Inputs describing one subtemplate combine on one rank (model helper).
#[derive(Debug, Clone, Copy)]
pub struct CombineShape {
    pub k: usize,
    /// |Ti|
    pub size: usize,
    /// |Ti'|
    pub passive_size: usize,
    /// |Ti''|
    pub active_size: usize,
    /// expected remote neighbor rows per step, ≈ |E|/P² (Eq 5)
    pub remote_rows_per_step: f64,
    pub n_ranks: usize,
}

impl AdaptivePolicy {
    /// The mode switch (Alg 3 line 2).
    pub fn choose(&self, tc: &TemplateComplexity, n_ranks: usize) -> CommMode {
        if n_ranks >= self.min_ranks && tc.intensity >= self.intensity_threshold {
            CommMode::Pipeline { g: 1 }
        } else {
            CommMode::AllToAll
        }
    }

    /// Modeled per-step computation time (Eq 4 scaled by `flop_time`).
    pub fn step_compute(&self, s: &CombineShape, binom: &Binomial) -> f64 {
        let units = binom.c(s.k, s.size) as f64 * binom.c(s.size, s.passive_size) as f64;
        self.flop_time * units * s.remote_rows_per_step.max(0.0)
    }

    /// Modeled per-step communication time (Eq 8, incl. the per-step
    /// software overhead).
    pub fn step_comm(&self, s: &CombineShape, binom: &Binomial) -> f64 {
        let row_bytes = binom.c(s.k, s.active_size) * 4;
        self.net
            .step(1, (s.remote_rows_per_step.max(0.0) * row_bytes as f64) as u64)
    }

    /// The predicted overlap ratio ρ (Eq 14) under pipelining: as the rank
    /// count grows, per-step compute shrinks ∝ 1/P² against the α latency
    /// floor, which is exactly why small templates stop overlapping
    /// (paper Fig 8).
    pub fn overlap(&self, s: &CombineShape, binom: &Binomial) -> f64 {
        let comm = self.step_comm(s, binom);
        if comm <= 0.0 {
            return 1.0;
        }
        (self.step_compute(s, binom) / comm).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::template::{builtin, complexity};

    #[test]
    fn paper_mode_assignments() {
        let pol = AdaptivePolicy::default();
        for (name, want_pipeline) in [
            ("u3-1", false),
            ("u5-2", false),
            ("u7-2", false),
            ("u10-2", true),
            ("u12-1", true),
            ("u12-2", true),
            ("u15-1", true),
        ] {
            let tc = complexity(&builtin(name).unwrap());
            let mode = pol.choose(&tc, 10);
            assert_eq!(
                matches!(mode, CommMode::Pipeline { .. }),
                want_pipeline,
                "{name}: got {mode:?} (intensity {})",
                tc.intensity
            );
        }
    }

    #[test]
    fn two_ranks_never_pipeline() {
        let pol = AdaptivePolicy::default();
        let tc = complexity(&builtin("u12-2").unwrap());
        assert_eq!(pol.choose(&tc, 2), CommMode::AllToAll);
    }

    fn shape(k: usize, size: usize, pass: usize, rows: f64, ranks: usize) -> CombineShape {
        CombineShape {
            k,
            size,
            passive_size: pass,
            active_size: size - pass,
            remote_rows_per_step: rows,
            n_ranks: ranks,
        }
    }

    #[test]
    fn overlap_decays_with_rank_count() {
        // same graph, more ranks -> fewer rows per step -> α floor wins.
        // (Use a small-template shape with a fast effective flop time, as
        // measured for streaming |Ti''|=1 updates, so the latency floor is
        // actually reachable — the regime of Fig 8's small-template drop.)
        let b = Binomial::new();
        let mut pol = AdaptivePolicy::default();
        pol.flop_time = 0.3e-9;
        let edges = 4.0e6;
        let rho_small_p = pol.overlap(&shape(3, 2, 1, edges / 16.0, 4), &b);
        let rho_large_p = pol.overlap(&shape(3, 2, 1, edges / 4096.0, 64), &b);
        assert!(rho_large_p < rho_small_p);
        assert!(rho_large_p < 0.5, "α floor must dominate at P=64");
    }

    #[test]
    fn overlap_monotone_in_intensity() {
        let b = Binomial::new();
        let pol = AdaptivePolicy::default();
        let lo = pol.overlap(&shape(5, 3, 1, 1_000.0, 8), &b);
        let hi = pol.overlap(&shape(12, 10, 5, 1_000.0, 8), &b);
        assert!(hi >= lo, "bigger combine units must not lower overlap");
    }

    #[test]
    fn slower_network_discourages_pipeline() {
        let b = Binomial::new();
        let mut pol = AdaptivePolicy::default();
        let s = shape(7, 5, 2, 3_000.0, 8);
        let fast = pol.overlap(&s, &b);
        pol.net = HockneyParams::tengige();
        let slow = pol.overlap(&s, &b);
        assert!(slow <= fast);
    }
}
