//! The on-wire frame codec of the socket fabric.
//!
//! A frame is a fixed 12-byte little-endian header followed by a typed
//! body:
//!
//! ```text
//! header:    magic u32 | version u16 | kind u8 | enc u8 | body_len u32
//! handshake: config_digest u64 | rank u32 | n_ranks u32
//! packet:    epoch u32 | meta u32 | subtemplate u32 | n_sets u32 | rows
//!   rows (enc 0, dense):  f32 × (body_len − 16)/4
//!   rows (enc 1, sparse): n_offsets u32 | n_entries u32
//!                         | offsets u32 × n_offsets
//!                         | (set_rank u32, count f32) × n_entries
//!   rows (enc 2, masked): n_rows u32 | n_mask u32 | n_offsets u32
//!                         | n_entries u32 | mask u64 × n_mask
//!                         | offsets u32 × n_offsets
//!                         | (set_rank u32, count f32) × n_entries
//! bye:       (empty)
//! ```
//!
//! The row payload reuses `encode_rows`' wire layout exactly — the dense
//! and CSR encodings whose byte counts the adaptive model, the fabric
//! ledger and `Packet::bytes()` already share — so shipping a packet
//! over a socket costs the bytes the model says it does, plus the fixed
//! framing overhead (`FRAME_HEADER_BYTES` + the epoch word).
//!
//! Every decode failure is a typed [`FrameError`]; a stale binary, a
//! truncated stream or stray bytes on the port surface as `BadVersion`,
//! `Truncated` or `BadMagic` instead of garbage rows.

use super::packet::Packet;
use crate::colorcount::storage::RowsPayload;
use std::fmt;

/// `HSGF` in little-endian byte order.
pub const MAGIC: u32 = u32::from_le_bytes(*b"HSGF");

/// Bumped whenever the header or a body layout changes; peers with a
/// different version are rejected at handshake (and on every frame).
/// Version 2 added the masked row encoding (enc 2).
pub const WIRE_VERSION: u16 = 2;

/// Fixed header size preceding every body.
pub const FRAME_HEADER_BYTES: usize = 12;

/// Upper bound on `body_len`: anything larger is a corrupt or hostile
/// length prefix, rejected before any allocation happens.
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

/// Frame kinds on the wire.
pub const KIND_HANDSHAKE: u8 = 0;
pub const KIND_PACKET: u8 = 1;
pub const KIND_BYE: u8 = 2;

const ENC_DENSE: u8 = 0;
const ENC_SPARSE: u8 = 1;
const ENC_MASKED: u8 = 2;

const HANDSHAKE_BODY_BYTES: usize = 16;
const PACKET_PREFIX_BYTES: usize = 16;

/// Every way a frame can fail to decode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// fewer bytes than the header/body announced
    Truncated { need: usize, got: usize },
    /// the stream does not start with [`MAGIC`]
    BadMagic(u32),
    /// a peer speaking a different wire version
    BadVersion { got: u16, want: u16 },
    /// an unknown frame kind byte
    BadKind(u8),
    /// an unknown payload-encoding byte
    BadEnc(u8),
    /// a length prefix beyond [`MAX_FRAME_BYTES`]
    Oversized { len: u32, max: u32 },
    /// internally inconsistent body (counts don't match the length)
    BadPayload(String),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Truncated { need, got } => {
                write!(f, "truncated frame: need {need} bytes, got {got}")
            }
            FrameError::BadMagic(m) => write!(f, "bad magic {m:#010x} (want {MAGIC:#010x})"),
            FrameError::BadVersion { got, want } => {
                write!(f, "wire version {got} (want {want}); stale peer binary?")
            }
            FrameError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            FrameError::BadEnc(e) => write!(f, "unknown payload encoding {e}"),
            FrameError::Oversized { len, max } => {
                write!(f, "frame body of {len} bytes exceeds the {max}-byte bound")
            }
            FrameError::BadPayload(m) => write!(f, "bad frame payload: {m}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// A decoded frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    pub kind: u8,
    pub enc: u8,
    pub body_len: u32,
}

/// A decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// the connection opener: who is calling, and for which run
    Handshake(Handshake),
    /// one exchange packet, tagged with its combine epoch
    Packet { epoch: u32, pkt: Packet },
    /// orderly goodbye — distinguishes a clean close from a peer dying
    /// mid-exchange
    Bye,
}

/// The first frame on every connection. `config_digest` fingerprints the
/// run (template, dataset, seed, rank count, schedule-relevant config) so
/// a peer from a different run — or a stale binary with a different wire
/// version — is rejected before any packet is decoded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Handshake {
    pub config_digest: u64,
    pub rank: u32,
    pub n_ranks: u32,
}

/// FNV-1a over a canonical config string — the run fingerprint carried in
/// every handshake (the same construction as the graph shards'
/// `partition_tag`).
pub fn config_digest(canonical: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in canonical.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn put_header(out: &mut Vec<u8>, kind: u8, enc: u8, body_len: usize) {
    debug_assert!(body_len as u64 <= MAX_FRAME_BYTES as u64);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    out.push(kind);
    out.push(enc);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
}

/// Encode a handshake frame.
pub fn encode_handshake(h: &Handshake) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + HANDSHAKE_BODY_BYTES);
    put_header(&mut out, KIND_HANDSHAKE, ENC_DENSE, HANDSHAKE_BODY_BYTES);
    out.extend_from_slice(&h.config_digest.to_le_bytes());
    out.extend_from_slice(&h.rank.to_le_bytes());
    out.extend_from_slice(&h.n_ranks.to_le_bytes());
    out
}

/// Encode an orderly-goodbye frame.
pub fn encode_bye() -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES);
    put_header(&mut out, KIND_BYE, ENC_DENSE, 0);
    out
}

/// Encode one exchange packet, stamped with its combine `epoch`.
pub fn encode_packet_frame(pkt: &Packet, epoch: u32) -> Vec<u8> {
    let (enc, rows_len) = match &pkt.payload {
        RowsPayload::Dense(rows) => (ENC_DENSE, rows.len() * 4),
        RowsPayload::Sparse { offsets, entries } => {
            (ENC_SPARSE, 8 + offsets.len() * 4 + entries.len() * 8)
        }
        RowsPayload::Masked {
            mask,
            offsets,
            entries,
            ..
        } => (
            ENC_MASKED,
            16 + mask.len() * 8 + offsets.len() * 4 + entries.len() * 8,
        ),
    };
    let body_len = PACKET_PREFIX_BYTES + rows_len;
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + body_len);
    put_header(&mut out, KIND_PACKET, enc, body_len);
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&pkt.meta.to_le_bytes());
    out.extend_from_slice(&pkt.subtemplate.to_le_bytes());
    out.extend_from_slice(&pkt.n_sets.to_le_bytes());
    match &pkt.payload {
        RowsPayload::Dense(rows) => {
            for x in rows {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        RowsPayload::Sparse { offsets, entries } => {
            out.extend_from_slice(&(offsets.len() as u32).to_le_bytes());
            out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
            for o in offsets {
                out.extend_from_slice(&o.to_le_bytes());
            }
            for &(rank, x) in entries {
                out.extend_from_slice(&rank.to_le_bytes());
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        RowsPayload::Masked {
            n_rows,
            mask,
            offsets,
            entries,
        } => {
            out.extend_from_slice(&n_rows.to_le_bytes());
            out.extend_from_slice(&(mask.len() as u32).to_le_bytes());
            out.extend_from_slice(&(offsets.len() as u32).to_le_bytes());
            out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
            for w in mask {
                out.extend_from_slice(&w.to_le_bytes());
            }
            for o in offsets {
                out.extend_from_slice(&o.to_le_bytes());
            }
            for &(rank, x) in entries {
                out.extend_from_slice(&rank.to_le_bytes());
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    out
}

fn get_u32(buf: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(buf[at..at + 4].try_into().unwrap())
}

fn get_u64(buf: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(buf[at..at + 8].try_into().unwrap())
}

fn get_f32(buf: &[u8], at: usize) -> f32 {
    f32::from_le_bytes(buf[at..at + 4].try_into().unwrap())
}

/// Decode the fixed 12-byte header. The caller then reads `body_len`
/// more bytes and hands them to [`decode_body`].
pub fn decode_header(buf: &[u8]) -> Result<FrameHeader, FrameError> {
    if buf.len() < FRAME_HEADER_BYTES {
        return Err(FrameError::Truncated {
            need: FRAME_HEADER_BYTES,
            got: buf.len(),
        });
    }
    let magic = get_u32(buf, 0);
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let version = u16::from_le_bytes(buf[4..6].try_into().unwrap());
    if version != WIRE_VERSION {
        return Err(FrameError::BadVersion {
            got: version,
            want: WIRE_VERSION,
        });
    }
    let kind = buf[6];
    if kind > KIND_BYE {
        return Err(FrameError::BadKind(kind));
    }
    let enc = buf[7];
    if enc > ENC_MASKED {
        return Err(FrameError::BadEnc(enc));
    }
    let body_len = get_u32(buf, 8);
    if body_len > MAX_FRAME_BYTES {
        return Err(FrameError::Oversized {
            len: body_len,
            max: MAX_FRAME_BYTES,
        });
    }
    Ok(FrameHeader {
        kind,
        enc,
        body_len,
    })
}

/// Decode a frame body against its header.
pub fn decode_body(h: FrameHeader, body: &[u8]) -> Result<Frame, FrameError> {
    if body.len() != h.body_len as usize {
        return Err(FrameError::Truncated {
            need: h.body_len as usize,
            got: body.len(),
        });
    }
    match h.kind {
        KIND_BYE => {
            if !body.is_empty() {
                return Err(FrameError::BadPayload(format!(
                    "bye frame carries {} bytes",
                    body.len()
                )));
            }
            Ok(Frame::Bye)
        }
        KIND_HANDSHAKE => {
            if body.len() != HANDSHAKE_BODY_BYTES {
                return Err(FrameError::BadPayload(format!(
                    "handshake body of {} bytes (want {HANDSHAKE_BODY_BYTES})",
                    body.len()
                )));
            }
            Ok(Frame::Handshake(Handshake {
                config_digest: u64::from_le_bytes(body[0..8].try_into().unwrap()),
                rank: get_u32(body, 8),
                n_ranks: get_u32(body, 12),
            }))
        }
        KIND_PACKET => {
            if body.len() < PACKET_PREFIX_BYTES {
                return Err(FrameError::Truncated {
                    need: PACKET_PREFIX_BYTES,
                    got: body.len(),
                });
            }
            let epoch = get_u32(body, 0);
            let meta = get_u32(body, 4);
            let subtemplate = get_u32(body, 8);
            let n_sets = get_u32(body, 12);
            let rows = &body[PACKET_PREFIX_BYTES..];
            let payload = match h.enc {
                ENC_DENSE => {
                    if rows.len() % 4 != 0 {
                        return Err(FrameError::BadPayload(format!(
                            "dense rows of {} bytes not a multiple of 4",
                            rows.len()
                        )));
                    }
                    let data = (0..rows.len() / 4).map(|i| get_f32(rows, i * 4)).collect();
                    RowsPayload::Dense(data)
                }
                ENC_SPARSE => {
                    if rows.len() < 8 {
                        return Err(FrameError::Truncated {
                            need: 8,
                            got: rows.len(),
                        });
                    }
                    let n_offsets = get_u32(rows, 0) as usize;
                    let n_entries = get_u32(rows, 4) as usize;
                    let want = n_offsets
                        .checked_mul(4)
                        .and_then(|a| n_entries.checked_mul(8).map(|b| (a, b)))
                        .and_then(|(a, b)| a.checked_add(b))
                        .and_then(|ab| ab.checked_add(8))
                        .ok_or_else(too_big)?;
                    if rows.len() != want {
                        return Err(FrameError::BadPayload(format!(
                            "sparse rows: {} bytes for {n_offsets} offsets + {n_entries} entries \
                             (want {want})",
                            rows.len()
                        )));
                    }
                    let offsets: Vec<u32> =
                        (0..n_offsets).map(|i| get_u32(rows, 8 + i * 4)).collect();
                    let base = 8 + n_offsets * 4;
                    let entries: Vec<(u32, f32)> = (0..n_entries)
                        .map(|i| (get_u32(rows, base + i * 8), get_f32(rows, base + i * 8 + 4)))
                        .collect();
                    RowsPayload::Sparse { offsets, entries }
                }
                _ => {
                    // ENC_MASKED — decode_header bounds enc at it
                    if rows.len() < 16 {
                        return Err(FrameError::Truncated {
                            need: 16,
                            got: rows.len(),
                        });
                    }
                    let n_rows = get_u32(rows, 0);
                    let n_mask = get_u32(rows, 4) as usize;
                    let n_offsets = get_u32(rows, 8) as usize;
                    let n_entries = get_u32(rows, 12) as usize;
                    if n_mask != (n_rows as usize).div_ceil(64) {
                        return Err(FrameError::BadPayload(format!(
                            "masked rows: {n_mask} mask words for {n_rows} rows"
                        )));
                    }
                    let want = n_mask
                        .checked_mul(8)
                        .and_then(|m| n_offsets.checked_mul(4).map(|a| (m, a)))
                        .and_then(|(m, a)| n_entries.checked_mul(8).map(|b| (m, a, b)))
                        .and_then(|(m, a, b)| m.checked_add(a)?.checked_add(b))
                        .and_then(|mab| mab.checked_add(16))
                        .ok_or_else(too_big)?;
                    if rows.len() != want {
                        return Err(FrameError::BadPayload(format!(
                            "masked rows: {} bytes for {n_mask} mask words + {n_offsets} \
                             offsets + {n_entries} entries (want {want})",
                            rows.len()
                        )));
                    }
                    let mask: Vec<u64> = (0..n_mask).map(|i| get_u64(rows, 16 + i * 8)).collect();
                    let obase = 16 + n_mask * 8;
                    let offsets: Vec<u32> =
                        (0..n_offsets).map(|i| get_u32(rows, obase + i * 4)).collect();
                    let ebase = obase + n_offsets * 4;
                    let entries: Vec<(u32, f32)> = (0..n_entries)
                        .map(|i| (get_u32(rows, ebase + i * 8), get_f32(rows, ebase + i * 8 + 4)))
                        .collect();
                    RowsPayload::Masked {
                        n_rows,
                        mask,
                        offsets,
                        entries,
                    }
                }
            };
            Ok(Frame::Packet {
                epoch,
                pkt: Packet {
                    meta,
                    subtemplate,
                    n_sets,
                    payload,
                },
            })
        }
        _ => Err(FrameError::BadKind(h.kind)),
    }
}

fn too_big() -> FrameError {
    FrameError::BadPayload("row counts overflow the body length".into())
}

/// Decode one whole frame from a buffer; returns the frame and the bytes
/// consumed. Test/fixture convenience over the streaming
/// `decode_header` + `decode_body` pair the reader threads use.
pub fn decode_frame(buf: &[u8]) -> Result<(Frame, usize), FrameError> {
    let h = decode_header(buf)?;
    let end = FRAME_HEADER_BYTES + h.body_len as usize;
    if buf.len() < end {
        return Err(FrameError::Truncated {
            need: end,
            got: buf.len(),
        });
    }
    let frame = decode_body(h, &buf[FRAME_HEADER_BYTES..end])?;
    Ok((frame, end))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn roundtrip(pkt: &Packet, epoch: u32) -> Packet {
        let buf = encode_packet_frame(pkt, epoch);
        let (frame, used) = decode_frame(&buf).expect("roundtrip decode");
        assert_eq!(used, buf.len(), "whole buffer consumed");
        match frame {
            Frame::Packet { epoch: e, pkt } => {
                assert_eq!(e, epoch);
                pkt
            }
            other => panic!("expected packet frame, got {other:?}"),
        }
    }

    #[test]
    fn handshake_roundtrip_and_digest() {
        let h = Handshake {
            config_digest: config_digest("template=u5;ranks=4;seed=42"),
            rank: 3,
            n_ranks: 4,
        };
        let buf = encode_handshake(&h);
        let (frame, used) = decode_frame(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(frame, Frame::Handshake(h));
        // the digest is a pure function and separates configs
        assert_eq!(
            config_digest("template=u5;ranks=4;seed=42"),
            h.config_digest
        );
        assert_ne!(
            config_digest("template=u5;ranks=5;seed=42"),
            h.config_digest
        );
    }

    #[test]
    fn bye_roundtrip() {
        let buf = encode_bye();
        assert_eq!(buf.len(), FRAME_HEADER_BYTES);
        assert_eq!(decode_frame(&buf).unwrap().0, Frame::Bye);
    }

    /// Satellite: property roundtrip over random dense/sparse payloads —
    /// meta, subtemplate, width, epoch and every row bit must survive the
    /// wire.
    #[test]
    fn prop_packet_frame_roundtrip() {
        prop::check("frame_roundtrip", |gen| {
            let sender = gen.usize_in(0, 9);
            let receiver = gen.usize_in(0, 9);
            let step = gen.usize_in(0, 7);
            let sub = gen.usize_in(0, 30);
            let n_sets = gen.usize_in(1, 9);
            let n_rows = gen.usize_in(0, 12);
            let epoch = gen.usize_in(0, 1 << 20) as u32;
            let payload = match gen.usize_in(0, 2) {
                0 => RowsPayload::Dense(
                    (0..n_rows * n_sets)
                        .map(|i| (i as f32) * 0.37 - 2.0)
                        .collect(),
                ),
                1 => {
                    let mut offsets = vec![0u32];
                    let mut entries = Vec::new();
                    for r in 0..n_rows {
                        for s in 0..n_sets {
                            if gen.usize_in(0, 2) == 0 {
                                entries.push((s as u32, (r * n_sets + s) as f32 * 0.25));
                            }
                        }
                        offsets.push(entries.len() as u32);
                    }
                    RowsPayload::Sparse { offsets, entries }
                }
                _ => {
                    // canonical masked form: live rows non-empty, bits
                    // past n_rows clear, one offset per live row
                    let mut mask = vec![0u64; n_rows.div_ceil(64)];
                    let mut offsets = vec![0u32];
                    let mut entries = Vec::new();
                    for r in 0..n_rows {
                        if gen.usize_in(0, 2) == 0 {
                            for s in 0..gen.usize_in(1, n_sets) {
                                entries.push((s as u32, (r * n_sets + s) as f32 * 0.5));
                            }
                            mask[r / 64] |= 1u64 << (r % 64);
                            offsets.push(entries.len() as u32);
                        }
                    }
                    RowsPayload::Masked {
                        n_rows: n_rows as u32,
                        mask,
                        offsets,
                        entries,
                    }
                }
            };
            let pkt = Packet::with_payload(sender, receiver, step, sub, n_sets, payload);
            let back = roundtrip(&pkt, epoch);
            if back.meta != pkt.meta || back.subtemplate != pkt.subtemplate {
                return Err("meta/subtemplate changed".into());
            }
            if back.n_sets != pkt.n_sets {
                return Err("n_sets changed".into());
            }
            match (&back.payload, &pkt.payload) {
                (RowsPayload::Dense(a), RowsPayload::Dense(b)) => {
                    if a.len() != b.len()
                        || a.iter().zip(b).any(|(x, y)| x.to_bits() != y.to_bits())
                    {
                        return Err("dense rows moved a bit".into());
                    }
                }
                (
                    RowsPayload::Sparse {
                        offsets: ao,
                        entries: ae,
                    },
                    RowsPayload::Sparse {
                        offsets: bo,
                        entries: be,
                    },
                ) => {
                    if ao != bo {
                        return Err("sparse offsets changed".into());
                    }
                    if ae.len() != be.len()
                        || ae
                            .iter()
                            .zip(be)
                            .any(|((r1, x), (r2, y))| r1 != r2 || x.to_bits() != y.to_bits())
                    {
                        return Err("sparse entries changed".into());
                    }
                }
                (
                    RowsPayload::Masked {
                        n_rows: an,
                        mask: am,
                        offsets: ao,
                        entries: ae,
                    },
                    RowsPayload::Masked {
                        n_rows: bn,
                        mask: bm,
                        offsets: bo,
                        entries: be,
                    },
                ) => {
                    if an != bn || am != bm || ao != bo {
                        return Err("masked structure changed".into());
                    }
                    if ae.len() != be.len()
                        || ae
                            .iter()
                            .zip(be)
                            .any(|((r1, x), (r2, y))| r1 != r2 || x.to_bits() != y.to_bits())
                    {
                        return Err("masked entries changed".into());
                    }
                }
                _ => return Err("payload encoding flipped".into()),
            }
            Ok(())
        });
    }

    /// Satellite: the corrupt-byte mutation matrix — truncation at every
    /// boundary, bad magic, wrong version, unknown kind/enc, an
    /// oversized length prefix and inconsistent sparse counts all map to
    /// their typed errors (mirroring the `GraphLoadError` fixtures).
    #[test]
    fn corrupt_frame_mutation_matrix() {
        let pkt = Packet::with_payload(
            1,
            2,
            3,
            4,
            3,
            RowsPayload::Sparse {
                offsets: vec![0, 1, 2],
                entries: vec![(0, 1.5), (2, -2.5)],
            },
        );
        let good = encode_packet_frame(&pkt, 7);
        assert!(decode_frame(&good).is_ok());

        // truncated header
        for cut in 0..FRAME_HEADER_BYTES {
            match decode_frame(&good[..cut]) {
                Err(FrameError::Truncated { got, .. }) => assert_eq!(got, cut),
                other => panic!("cut at {cut}: {other:?}"),
            }
        }
        // truncated body (every prefix that includes the full header)
        for cut in FRAME_HEADER_BYTES..good.len() {
            match decode_frame(&good[..cut]) {
                Err(FrameError::Truncated { need, got }) => {
                    assert_eq!(need, good.len());
                    assert_eq!(got, cut);
                }
                other => panic!("cut at {cut}: {other:?}"),
            }
        }
        // bad magic (every corruption of the first four bytes)
        for i in 0..4 {
            let mut bad = good.clone();
            bad[i] ^= 0xff;
            assert!(
                matches!(decode_frame(&bad), Err(FrameError::BadMagic(_))),
                "byte {i}"
            );
        }
        // wrong wire version
        let mut bad = good.clone();
        bad[4] = 0x7f;
        match decode_frame(&bad) {
            Err(FrameError::BadVersion { got, want }) => {
                assert_eq!(got, 0x7f);
                assert_eq!(want, WIRE_VERSION);
            }
            other => panic!("{other:?}"),
        }
        // unknown kind / encoding
        let mut bad = good.clone();
        bad[6] = 9;
        assert_eq!(decode_frame(&bad), Err(FrameError::BadKind(9)));
        let mut bad = good.clone();
        bad[7] = 5;
        assert_eq!(decode_frame(&bad), Err(FrameError::BadEnc(5)));
        // oversized length prefix: rejected before any body read
        let mut bad = good.clone();
        bad[8..12].copy_from_slice(&(MAX_FRAME_BYTES + 1).to_le_bytes());
        match decode_frame(&bad) {
            Err(FrameError::Oversized { len, max }) => {
                assert_eq!(len, MAX_FRAME_BYTES + 1);
                assert_eq!(max, MAX_FRAME_BYTES);
            }
            other => panic!("{other:?}"),
        }
        // sparse counts inconsistent with the body length
        let mut bad = good.clone();
        let off_at = FRAME_HEADER_BYTES + PACKET_PREFIX_BYTES;
        bad[off_at..off_at + 4].copy_from_slice(&100u32.to_le_bytes());
        assert!(
            matches!(decode_frame(&bad), Err(FrameError::BadPayload(_))),
            "{:?}",
            decode_frame(&bad)
        );
        // sparse counts engineered to overflow usize
        let mut bad = good.clone();
        bad[off_at..off_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        bad[off_at + 4..off_at + 8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode_frame(&bad), Err(FrameError::BadPayload(_))));

        // masked body with a mask/row-count mismatch, then overflowing
        // counts — both rejected before any buffer is built
        let masked = encode_packet_frame(
            &Packet::with_payload(
                1,
                2,
                0,
                4,
                3,
                RowsPayload::Masked {
                    n_rows: 5,
                    mask: vec![0b00100],
                    offsets: vec![0, 1],
                    entries: vec![(1, 2.5)],
                },
            ),
            7,
        );
        assert!(decode_frame(&masked).is_ok());
        let m_at = FRAME_HEADER_BYTES + PACKET_PREFIX_BYTES;
        let mut bad = masked.clone();
        bad[m_at + 4..m_at + 8].copy_from_slice(&7u32.to_le_bytes());
        assert!(matches!(decode_frame(&bad), Err(FrameError::BadPayload(_))));
        let mut bad = masked.clone();
        bad[m_at..m_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        bad[m_at + 4..m_at + 8].copy_from_slice(&u32::MAX.to_le_bytes());
        bad[m_at + 8..m_at + 12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode_frame(&bad), Err(FrameError::BadPayload(_))));

        // dense body whose row bytes aren't a multiple of the f32 width
        let dense = encode_packet_frame(&Packet::new(0, 1, 0, 0, 2, vec![1.0, 2.0]), 1);
        let mut bad = dense.clone();
        bad.pop();
        let new_len = (bad.len() - FRAME_HEADER_BYTES) as u32;
        bad[8..12].copy_from_slice(&new_len.to_le_bytes());
        assert!(matches!(decode_frame(&bad), Err(FrameError::BadPayload(_))));

        // bye with a non-empty body
        let mut bad = encode_bye();
        bad[8..12].copy_from_slice(&1u32.to_le_bytes());
        bad.push(0);
        assert!(matches!(decode_frame(&bad), Err(FrameError::BadPayload(_))));

        // handshake with a short body
        let hs = encode_handshake(&Handshake {
            config_digest: 1,
            rank: 0,
            n_ranks: 2,
        });
        let mut bad = hs.clone();
        bad.truncate(bad.len() - 4);
        bad[8..12].copy_from_slice(&12u32.to_le_bytes());
        assert!(matches!(decode_frame(&bad), Err(FrameError::BadPayload(_))));
    }

    #[test]
    fn errors_display_actionably() {
        let e = FrameError::BadVersion { got: 2, want: 1 };
        assert!(e.to_string().contains("stale peer"));
        let e = FrameError::Oversized {
            len: MAX_FRAME_BYTES + 1,
            max: MAX_FRAME_BYTES,
        };
        assert!(e.to_string().contains("exceeds"));
        let e = FrameError::Truncated { need: 12, got: 3 };
        assert!(e.to_string().contains("12"));
        assert!(e.to_string().contains('3'));
    }
}
