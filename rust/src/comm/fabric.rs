//! The fabric seam: the [`RankFabric`] trait both exchange executors and
//! the distributed driver are parameterized over, plus the pieces every
//! implementation shares — the per-`(rank, step)` byte/message ledger
//! ([`StepLedger`]), the typed transport error ([`FabricError`]), and the
//! measured link parameters ([`LinkMeasurement`]) a real transport fits
//! from wall-clock send timings.
//!
//! Two implementations exist: the in-process
//! [`ThreadedFabric`](super::ThreadedFabric) (the default — rank threads
//! in one address space, modeled clocks) and the
//! [`SocketFabric`](super::SocketFabric) (rank *processes* framing
//! packets over TCP or Unix-domain sockets, wall clocks). Both drain in
//! the canonical `(step, sender, seq)` order, so the fold a receiver
//! performs is bit-identical whichever transport carried the rows.

use super::packet::Packet;
use crate::coordinator::memory::{MemClass, SharedAccountant};
use crate::util::shim::AtomicU64;
use std::fmt;
use std::io;

/// A typed transport failure: which local rank observed it, at which
/// exchange step, about which peer, and the underlying I/O class. This is
/// what a disconnected or timed-out peer surfaces instead of a hung fold.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FabricError {
    /// the local rank that observed the failure
    pub rank: usize,
    /// the exchange step being sent/drained, when one was in progress
    pub step: Option<usize>,
    /// the peer rank involved, when known
    pub peer: Option<usize>,
    /// the I/O failure class (`TimedOut`, `ConnectionReset`, …)
    pub kind: io::ErrorKind,
    /// human-readable context (addresses, byte counts, digests)
    pub detail: String,
}

impl FabricError {
    pub fn new(rank: usize, kind: io::ErrorKind, detail: impl Into<String>) -> Self {
        FabricError {
            rank,
            step: None,
            peer: None,
            kind,
            detail: detail.into(),
        }
    }

    pub fn at_step(mut self, step: usize) -> Self {
        self.step = Some(step);
        self
    }

    pub fn with_peer(mut self, peer: usize) -> Self {
        self.peer = Some(peer);
        self
    }

    /// A receive that outwaited the configured window.
    pub fn timeout(rank: usize, step: usize, detail: impl Into<String>) -> Self {
        FabricError::new(rank, io::ErrorKind::TimedOut, detail).at_step(step)
    }
}

impl fmt::Display for FabricError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rank {}", self.rank)?;
        if let Some(s) = self.step {
            write!(f, " step {s}")?;
        }
        if let Some(p) = self.peer {
            write!(f, " peer {p}")?;
        }
        write!(f, ": {:?}: {}", self.kind, self.detail)
    }
}

impl std::error::Error for FabricError {}

pub type FabricResult<T> = Result<T, FabricError>;

/// Measured point-to-point link parameters, least-squares fitted from
/// `(bytes, seconds)` samples of real blocking sends — the wall-clock
/// counterpart of the Hockney `(α, β)` the model otherwise simulates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkMeasurement {
    /// fitted per-message latency, seconds
    pub alpha_s: f64,
    /// fitted per-byte transfer time, seconds/byte
    pub beta_s_per_byte: f64,
    /// sends the fit was computed from
    pub samples: usize,
}

impl LinkMeasurement {
    /// Ordinary least squares of `secs = α + β·bytes` over the samples.
    /// Degenerate inputs (fewer than two samples, or all sends the same
    /// size) pin β at 0 and report the mean latency as α. Fitted values
    /// are clamped at 0 — noise can drive either coefficient negative.
    pub fn fit(samples: &[(u64, f64)]) -> Option<LinkMeasurement> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len() as f64;
        let mean_x = samples.iter().map(|&(b, _)| b as f64).sum::<f64>() / n;
        let mean_y = samples.iter().map(|&(_, s)| s).sum::<f64>() / n;
        let mut sxx = 0.0;
        let mut sxy = 0.0;
        for &(b, s) in samples {
            let dx = b as f64 - mean_x;
            sxx += dx * dx;
            sxy += dx * (s - mean_y);
        }
        let (alpha, beta) = if sxx > 0.0 && samples.len() >= 2 {
            let beta = sxy / sxx;
            (mean_y - beta * mean_x, beta)
        } else {
            (mean_y, 0.0)
        };
        Some(LinkMeasurement {
            alpha_s: alpha.max(0.0),
            beta_s_per_byte: beta.max(0.0),
            samples: samples.len(),
        })
    }

    /// Predicted seconds for one message of `bytes` under the fit.
    pub fn step(&self, bytes: u64) -> f64 {
        self.alpha_s + self.beta_s_per_byte * bytes as f64
    }
}

/// The per-`(rank, step)` accounting every [`RankFabric`] shares: bytes
/// and messages sent, bytes drained, per-(sender, step) send sequence
/// numbers, the one-shot drain tracker, and the in-flight payload
/// high-water accountant. Extracting it means the modeled-vs-measured
/// byte tests (`modeled_step_bytes_match_threaded_fabric` and friends)
/// read the same counters whichever transport ran, and the hot send path
/// is two `fetch_add`s on preallocated grids — no per-packet allocation
/// or cloned accounting state.
#[derive(Debug)]
pub struct StepLedger {
    n_ranks: usize,
    max_steps: usize,
    /// steps of the exchange currently in progress
    n_steps: AtomicU64,
    /// `[rank][step]` bytes sent
    sent_bytes: Vec<Vec<AtomicU64>>,
    /// `[rank][step]` messages sent
    sent_msgs: Vec<Vec<AtomicU64>>,
    /// `[rank][step]` bytes received (drained)
    recv_bytes: Vec<Vec<AtomicU64>>,
    /// `[sender][step]` next send sequence number
    seqs: Vec<Vec<AtomicU64>>,
    /// `[rank][step]` drain count — a drain is a one-shot collective
    drained: Vec<Vec<AtomicU64>>,
    /// payload bytes currently parked in inboxes (sent/arrived, not yet
    /// drained); the peak is the pipeline's in-flight high-water mark
    in_flight: SharedAccountant,
}

impl StepLedger {
    pub fn new(n_ranks: usize, max_steps: usize) -> Self {
        fn counters(n_ranks: usize, n_steps: usize) -> Vec<Vec<AtomicU64>> {
            (0..n_ranks)
                .map(|_| (0..n_steps).map(|_| AtomicU64::new(0)).collect())
                .collect()
        }
        StepLedger {
            n_ranks,
            max_steps,
            n_steps: AtomicU64::new(max_steps as u64),
            sent_bytes: counters(n_ranks, max_steps),
            sent_msgs: counters(n_ranks, max_steps),
            recv_bytes: counters(n_ranks, max_steps),
            seqs: counters(n_ranks, max_steps),
            drained: counters(n_ranks, max_steps),
            in_flight: SharedAccountant::new(),
        }
    }

    pub fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    /// Steps of the exchange currently in progress.
    pub fn n_steps(&self) -> usize {
        self.n_steps.load() as usize
    }

    /// Start a new exchange of `n_steps` steps: zero the per-step grids
    /// and the seq/drain trackers. The in-flight accountant is *not*
    /// reset — its high-water mark spans the fabric's whole life, and a
    /// clean previous exchange left its current count at zero anyway.
    pub fn begin_exchange(&self, n_steps: usize) {
        assert!(
            n_steps <= self.max_steps,
            "exchange of {n_steps} steps exceeds the ledger's {} step capacity",
            self.max_steps
        );
        self.n_steps.store(n_steps as u64);
        for grid in [
            &self.sent_bytes,
            &self.sent_msgs,
            &self.recv_bytes,
            &self.seqs,
            &self.drained,
        ] {
            for row in grid.iter() {
                for c in row.iter() {
                    c.store(0);
                }
            }
        }
    }

    /// Account one send; returns the packet's per-(sender, step) sequence
    /// number. Panics on out-of-range ranks/steps (an executor bug).
    pub fn note_send(&self, from: usize, to: usize, step: usize, bytes: u64) -> u64 {
        assert!(to < self.n_ranks, "receiver {to} out of range");
        assert!(from < self.n_ranks, "sender {from} out of range");
        assert!(
            step < self.n_steps(),
            "step {step} out of range ({})",
            self.n_steps()
        );
        self.sent_bytes[from][step].fetch_add(bytes);
        self.sent_msgs[from][step].fetch_add(1);
        self.seqs[from][step].fetch_add(1)
    }

    /// Account one drained step's bytes on the receive side.
    pub fn note_recv(&self, p: usize, step: usize, bytes: u64) {
        self.recv_bytes[p][step].fetch_add(bytes);
    }

    /// Mark `(p, step)` drained; panics on a double drain — the second
    /// caller would block forever or steal late packets.
    pub fn mark_drained(&self, p: usize, step: usize) {
        assert!(p < self.n_ranks, "receiver {p} out of range");
        assert!(
            step < self.n_steps(),
            "step {step} out of range ({})",
            self.n_steps()
        );
        let drains = self.drained[p][step].fetch_add(1);
        assert!(drains == 0, "rank {p}: double drain of step {step}");
    }

    /// Charge arrived-but-not-drained payload bytes.
    pub fn park(&self, bytes: u64) {
        self.in_flight.alloc(MemClass::RecvBuffer, bytes);
    }

    /// Release drained payload bytes.
    pub fn unpark(&self, bytes: u64) {
        self.in_flight.free(MemClass::RecvBuffer, bytes);
    }

    /// Bytes rank `p` sent at `step`.
    pub fn sent_bytes(&self, p: usize, step: usize) -> u64 {
        self.sent_bytes[p][step].load()
    }

    /// Messages rank `p` sent at `step`.
    pub fn sent_msgs(&self, p: usize, step: usize) -> u64 {
        self.sent_msgs[p][step].load()
    }

    /// Bytes rank `p` received (drained) at `step`.
    pub fn recv_bytes(&self, p: usize, step: usize) -> u64 {
        self.recv_bytes[p][step].load()
    }

    /// Total bytes rank `p` sent across the current exchange's steps.
    pub fn total_sent_bytes(&self, p: usize) -> u64 {
        (0..self.n_steps()).map(|w| self.sent_bytes(p, w)).sum()
    }

    /// Total messages rank `p` sent across the current exchange's steps.
    pub fn total_sent_msgs(&self, p: usize) -> u64 {
        (0..self.n_steps()).map(|w| self.sent_msgs(p, w)).sum()
    }

    /// Payload bytes currently in flight (sent, not yet drained).
    pub fn in_flight_bytes(&self) -> u64 {
        self.in_flight.current(MemClass::RecvBuffer)
    }

    /// High-water mark of in-flight payload bytes over the ledger's life.
    pub fn in_flight_peak(&self) -> u64 {
        self.in_flight.peak()
    }
}

/// The transport seam of the exchange: send packets between ranks and
/// drain them per step in the canonical `(sender, seq)` order, with the
/// shared [`StepLedger`] accounting. The executors and the distributed
/// driver only speak this trait; whether the peer ranks are threads in
/// this process or processes across a socket is an implementation detail.
///
/// Contract:
/// * [`begin_exchange`](Self::begin_exchange) opens a combine of
///   `n_steps` steps; every rank participating in the run calls it in the
///   same order (the control flow is deterministic and replicated).
/// * [`send`](Self::send) is callable from any rank thread; the packet's
///   `offset` field is its exchange step.
/// * [`recv_step`](Self::recv_step) blocks until the step's full packet
///   set arrived, then returns it sorted by `(sender, seq)` — the one
///   delivery order every transport must reproduce, because the fold
///   order determines the f32 sums bit-for-bit.
/// * Timeouts and peer failures surface as [`FabricError`]; a double
///   drain stays a panic (an executor bug, not a transport condition).
pub trait RankFabric: Sync {
    /// Ranks on the fabric.
    fn n_ranks(&self) -> usize;

    /// Start a new exchange of `n_steps` steps (resets the per-step
    /// ledger and sequence/drain trackers).
    fn begin_exchange(&self, n_steps: usize);

    /// Send a packet; its `offset` field is the exchange step.
    fn send(&self, p: Packet) -> FabricResult<()>;

    /// Block until `n_expected` packets of `step` arrived for rank `p`,
    /// then return them sorted by `(sender, seq)`.
    fn recv_step(&self, p: usize, step: usize, n_expected: usize) -> FabricResult<Vec<Packet>>;

    /// The shared per-(rank, step) accounting.
    fn ledger(&self) -> &StepLedger;

    /// Packets currently waiting for rank `p` (any step of the current
    /// exchange).
    fn pending(&self, p: usize) -> usize;

    /// Assert no packets of the current exchange are stranded.
    fn assert_empty(&self);

    /// Wall-clock link parameters fitted from real sends, when the
    /// transport has any (`None` for in-process fabrics).
    fn measured_link(&self) -> Option<LinkMeasurement> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fabric_error_display_carries_context() {
        let e = FabricError::timeout(3, 2, "1 of 2 packets").with_peer(1);
        let s = e.to_string();
        assert!(s.contains("rank 3"), "{s}");
        assert!(s.contains("step 2"), "{s}");
        assert!(s.contains("peer 1"), "{s}");
        assert!(s.contains("TimedOut"), "{s}");
        assert_eq!(e.kind, io::ErrorKind::TimedOut);
    }

    #[test]
    fn link_fit_recovers_alpha_beta() {
        // exact line: secs = 1e-4 + 2e-9 * bytes
        let samples: Vec<(u64, f64)> = [1_000u64, 10_000, 100_000, 500_000]
            .iter()
            .map(|&b| (b, 1e-4 + 2e-9 * b as f64))
            .collect();
        let m = LinkMeasurement::fit(&samples).unwrap();
        assert!((m.alpha_s - 1e-4).abs() < 1e-10, "alpha {}", m.alpha_s);
        assert!(
            (m.beta_s_per_byte - 2e-9).abs() < 1e-14,
            "beta {}",
            m.beta_s_per_byte
        );
        assert_eq!(m.samples, 4);
        assert!((m.step(1_000_000) - (1e-4 + 2e-3)).abs() < 1e-9);
    }

    #[test]
    fn link_fit_degenerate_cases() {
        assert!(LinkMeasurement::fit(&[]).is_none());
        // one sample: mean latency, zero beta
        let m = LinkMeasurement::fit(&[(4096, 3e-4)]).unwrap();
        assert_eq!(m.beta_s_per_byte, 0.0);
        assert!((m.alpha_s - 3e-4).abs() < 1e-12);
        // all sends the same size: no slope information
        let m = LinkMeasurement::fit(&[(100, 1e-4), (100, 3e-4)]).unwrap();
        assert_eq!(m.beta_s_per_byte, 0.0);
        assert!((m.alpha_s - 2e-4).abs() < 1e-12);
        // noise can fit a negative slope; it must clamp at zero
        let m = LinkMeasurement::fit(&[(100, 5e-4), (100_000, 1e-4)]).unwrap();
        assert_eq!(m.beta_s_per_byte, 0.0);
    }

    #[test]
    fn ledger_accounts_and_resets_per_exchange() {
        let l = StepLedger::new(3, 2);
        assert_eq!(l.note_send(0, 1, 0, 100), 0);
        assert_eq!(l.note_send(0, 2, 0, 50), 1, "seq advances per (sender, step)");
        assert_eq!(l.note_send(1, 0, 1, 10), 0);
        assert_eq!(l.sent_bytes(0, 0), 150);
        assert_eq!(l.sent_msgs(0, 0), 2);
        assert_eq!(l.total_sent_bytes(0), 150);
        l.note_recv(1, 0, 100);
        assert_eq!(l.recv_bytes(1, 0), 100);
        l.park(100);
        assert_eq!(l.in_flight_bytes(), 100);
        l.unpark(100);
        assert_eq!(l.in_flight_bytes(), 0);
        assert_eq!(l.in_flight_peak(), 100);
        l.mark_drained(1, 0);
        // a new exchange zeros counters and the drain tracker, keeps peak
        l.begin_exchange(1);
        assert_eq!(l.n_steps(), 1);
        assert_eq!(l.sent_bytes(0, 0), 0);
        assert_eq!(l.total_sent_msgs(0), 0);
        l.mark_drained(1, 0); // would panic had the tracker survived
        assert_eq!(l.in_flight_peak(), 100);
    }

    #[test]
    #[should_panic(expected = "double drain")]
    fn ledger_detects_double_drain() {
        let l = StepLedger::new(2, 1);
        l.mark_drained(0, 0);
        l.mark_drained(0, 0);
    }

    #[test]
    #[should_panic(expected = "step capacity")]
    fn ledger_rejects_oversized_exchange() {
        let l = StepLedger::new(2, 2);
        l.begin_exchange(3);
    }
}
