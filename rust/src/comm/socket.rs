//! The process-mode transport: rank processes framing [`Packet`]s over
//! TCP or Unix-domain sockets.
//!
//! Topology is a full mesh of directed connections: every rank binds one
//! listener, then opens one outgoing stream to each peer (with bounded
//! retry/backoff while the mesh comes up) and accepts one incoming stream
//! from each. The first frame on every stream is a [`Handshake`] carrying
//! the wire version and the run's config digest, so a peer from another
//! run, with a different rank count, or built at a different wire version
//! is rejected with a typed error before any packet is decoded.
//!
//! One reader thread per incoming stream decodes frames into the local
//! inbox. Because a TCP/UDS stream is FIFO and each directed pair has
//! exactly one stream, arrival order per `(sender, epoch, step)` *is* the
//! sender's send order — the reader assigns the canonical sequence
//! numbers on arrival, and [`SocketFabric::recv_step`] returns each
//! step's packets sorted by `(sender, seq)` exactly like the in-process
//! fabric. Packets are additionally tagged with an exchange *epoch*
//! (bumped at every [`RankFabric::begin_exchange`]): a fast sender may
//! race ahead into the next combine while this rank still drains the
//! current one, and step numbers repeat per combine, so the epoch is what
//! keeps early packets queued instead of folded into the wrong exchange.
//!
//! Every blocking send is wall-clocked; the `(bytes, seconds)` samples
//! fit the measured link parameters ([`LinkMeasurement`]) the report
//! carries in place of the simulated Hockney terms.

use super::fabric::{FabricError, FabricResult, LinkMeasurement, RankFabric, StepLedger};
use super::frame::{
    decode_body, decode_header, encode_bye, encode_handshake, encode_packet_frame, Frame,
    FrameHeader, Handshake, FRAME_HEADER_BYTES,
};
use super::packet::Packet;
use crate::util::shim::{AtomicU64, Condvar, Mutex};
use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One rank's endpoint address: a TCP `host:port` or a UDS path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PeerAddr {
    Tcp(String),
    Unix(PathBuf),
}

impl PeerAddr {
    /// Parse an address spec: anything containing `/` is a socket path,
    /// everything else a TCP `host:port`.
    pub fn parse(spec: &str) -> PeerAddr {
        if spec.contains('/') {
            PeerAddr::Unix(PathBuf::from(spec))
        } else {
            PeerAddr::Tcp(spec.to_string())
        }
    }
}

impl std::fmt::Display for PeerAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PeerAddr::Tcp(a) => write!(f, "{a}"),
            PeerAddr::Unix(p) => write!(f, "{}", p.display()),
        }
    }
}

/// Transport knobs: every wait the fabric performs is bounded.
#[derive(Debug, Clone, Copy)]
pub struct SocketOptions {
    /// total window for establishing the whole mesh (per peer connect,
    /// handshake reads, and the accept loop)
    pub connect_timeout: Duration,
    /// initial retry backoff while a peer's listener comes up (doubles up
    /// to a 500 ms cap)
    pub connect_backoff: Duration,
    /// how long a `recv_step` may block before the fold surfaces a typed
    /// timeout instead of hanging
    pub recv_timeout: Duration,
}

impl Default for SocketOptions {
    fn default() -> Self {
        SocketOptions {
            connect_timeout: Duration::from_secs(20),
            connect_backoff: Duration::from_millis(20),
            recv_timeout: Duration::from_secs(600),
        }
    }
}

/// How often blocked reads wake to check the shutdown flag.
const READ_POLL: Duration = Duration::from_millis(100);

enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    fn connect(addr: &PeerAddr) -> io::Result<Stream> {
        match addr {
            PeerAddr::Tcp(a) => {
                let s = TcpStream::connect(a)?;
                s.set_nodelay(true)?;
                Ok(Stream::Tcp(s))
            }
            PeerAddr::Unix(p) => Ok(Stream::Unix(UnixStream::connect(p)?)),
        }
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(d),
            Stream::Unix(s) => s.set_read_timeout(d),
        }
    }

    fn set_write_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_write_timeout(d),
            Stream::Unix(s) => s.set_write_timeout(d),
        }
    }

    fn shutdown_both(&self) {
        let _ = match self {
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            Stream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Unix(s) => s.flush(),
        }
    }
}

enum ListenerInner {
    Tcp(TcpListener),
    Unix(UnixListener),
}

/// A bound rank listener. Bind *before* advertising the address (the
/// launcher protocol prints the resolved address only after `bind`
/// returns, so every peer's connect races nothing).
pub struct SocketListener {
    inner: ListenerInner,
    addr: PeerAddr,
    /// UDS path to unlink on drop
    cleanup: Option<PathBuf>,
}

impl SocketListener {
    /// Bind `spec`. A TCP spec may use port 0; the resolved address (with
    /// the real port) is what [`Self::local_addr`] reports. A stale UDS
    /// path is unlinked first.
    pub fn bind(spec: &PeerAddr) -> io::Result<SocketListener> {
        match spec {
            PeerAddr::Tcp(a) => {
                let l = TcpListener::bind(a)?;
                let addr = PeerAddr::Tcp(l.local_addr()?.to_string());
                Ok(SocketListener {
                    inner: ListenerInner::Tcp(l),
                    addr,
                    cleanup: None,
                })
            }
            PeerAddr::Unix(p) => {
                if p.exists() {
                    std::fs::remove_file(p)?;
                }
                let l = UnixListener::bind(p)?;
                Ok(SocketListener {
                    inner: ListenerInner::Unix(l),
                    addr: PeerAddr::Unix(p.clone()),
                    cleanup: Some(p.clone()),
                })
            }
        }
    }

    /// The resolved address peers should connect to.
    pub fn local_addr(&self) -> &PeerAddr {
        &self.addr
    }

    fn set_nonblocking(&self, v: bool) -> io::Result<()> {
        match &self.inner {
            ListenerInner::Tcp(l) => l.set_nonblocking(v),
            ListenerInner::Unix(l) => l.set_nonblocking(v),
        }
    }

    fn accept(&self) -> io::Result<Stream> {
        // accepted streams must block (with read timeouts) even though
        // the listener polls nonblocking; inheritance is platform-defined
        match &self.inner {
            ListenerInner::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                Ok(Stream::Tcp(s))
            }
            ListenerInner::Unix(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(Stream::Unix(s))
            }
        }
    }
}

impl Drop for SocketListener {
    fn drop(&mut self) {
        if let Some(p) = &self.cleanup {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// A packet parked in the inbox with its canonical-order key.
#[derive(Debug)]
struct NetQueued {
    sender: usize,
    epoch: u64,
    step: usize,
    /// arrival-order sequence per `(sender, epoch, step)` — valid as the
    /// canonical seq because each directed pair has one FIFO stream
    seq: u64,
    pkt: Packet,
}

/// State shared with the reader threads.
struct Shared {
    rank: usize,
    n_ranks: usize,
    ledger: StepLedger,
    inbox: Mutex<Vec<NetQueued>>,
    arrival: Condvar,
    /// first transport failure observed by any reader; fails every
    /// subsequent `recv_step` instead of letting the fold hang
    fail: Mutex<Option<FabricError>>,
    /// nonzero once teardown started: readers treat EOF/timeouts as a
    /// clean exit instead of a peer failure
    shutdown: AtomicU64,
}

impl Shared {
    fn set_fail(&self, e: FabricError) {
        let mut f = self.fail.lock().unwrap();
        if f.is_none() {
            *f = Some(e);
        }
        drop(f);
        self.arrival.notify_all();
    }

    fn push(&self, sender: usize, epoch: u64, step: usize, seq: u64, pkt: Packet) {
        self.ledger.park(pkt.bytes());
        let mut ib = self.inbox.lock().unwrap();
        ib.push(NetQueued {
            sender,
            epoch,
            step,
            seq,
            pkt,
        });
        drop(ib);
        self.arrival.notify_all();
    }
}

/// What a bounded read produced.
enum ReadOutcome {
    /// buffer filled
    Full,
    /// clean EOF at a frame boundary
    Eof,
    /// the shutdown flag went up while blocked
    Shutdown,
}

/// Read exactly `buf.len()` bytes, waking every [`READ_POLL`] to check
/// the shutdown flag (and `deadline`, when one bounds the wait). EOF
/// mid-buffer is an error; EOF before the first byte is a clean boundary.
fn read_full(
    s: &mut Stream,
    buf: &mut [u8],
    shared: &Shared,
    deadline: Option<Instant>,
) -> io::Result<ReadOutcome> {
    let mut at = 0;
    while at < buf.len() {
        match s.read(&mut buf[at..]) {
            Ok(0) => {
                if at == 0 {
                    return Ok(ReadOutcome::Eof);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("stream closed {at} bytes into a {}-byte read", buf.len()),
                ));
            }
            Ok(n) => at += n,
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                if shared.shutdown.load() != 0 {
                    return Ok(ReadOutcome::Shutdown);
                }
                if deadline.is_some_and(|d| Instant::now() > d) {
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        format!("read deadline passed {at} bytes into a {}-byte read", buf.len()),
                    ));
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(ReadOutcome::Full)
}

/// Decode frames off one incoming stream until Bye, shutdown, or error.
fn reader_loop(shared: &Shared, mut stream: Stream, sender: usize) {
    let my = shared.rank;
    let fail = |kind: io::ErrorKind, detail: String| {
        shared.set_fail(FabricError::new(my, kind, detail).with_peer(sender));
    };
    // canonical sequence numbers, assigned in arrival order per
    // (epoch, step) — this thread is the only writer for this sender
    let mut seqs: HashMap<(u64, usize), u64> = HashMap::new();
    let mut header = [0u8; FRAME_HEADER_BYTES];
    loop {
        match read_full(&mut stream, &mut header, shared, None) {
            Ok(ReadOutcome::Shutdown) => return,
            Ok(ReadOutcome::Eof) => {
                if shared.shutdown.load() == 0 {
                    fail(
                        io::ErrorKind::UnexpectedEof,
                        format!("peer {sender} closed the stream without a bye frame"),
                    );
                }
                return;
            }
            Ok(ReadOutcome::Full) => {}
            Err(e) => {
                if shared.shutdown.load() == 0 {
                    fail(e.kind(), format!("reading frame header from peer {sender}: {e}"));
                }
                return;
            }
        }
        let h: FrameHeader = match decode_header(&header) {
            Ok(h) => h,
            Err(e) => {
                fail(
                    io::ErrorKind::InvalidData,
                    format!("frame from peer {sender}: {e}"),
                );
                return;
            }
        };
        let mut body = vec![0u8; h.body_len as usize];
        match read_full(&mut stream, &mut body, shared, None) {
            Ok(ReadOutcome::Full) => {}
            Ok(ReadOutcome::Shutdown) => return,
            Ok(ReadOutcome::Eof) => {
                if shared.shutdown.load() == 0 {
                    fail(
                        io::ErrorKind::UnexpectedEof,
                        format!("peer {sender} closed the stream mid-frame"),
                    );
                }
                return;
            }
            Err(e) => {
                if shared.shutdown.load() == 0 {
                    fail(e.kind(), format!("reading frame body from peer {sender}: {e}"));
                }
                return;
            }
        }
        match decode_body(h, &body) {
            Ok(Frame::Packet { epoch, pkt }) => {
                if pkt.sender() != sender || pkt.receiver() != my {
                    fail(
                        io::ErrorKind::InvalidData,
                        format!(
                            "peer {sender} sent a packet routed {}→{}",
                            pkt.sender(),
                            pkt.receiver()
                        ),
                    );
                    return;
                }
                let step = pkt.offset();
                let seq = {
                    let c = seqs.entry((epoch as u64, step)).or_insert(0);
                    let s = *c;
                    *c += 1;
                    s
                };
                shared.push(sender, epoch as u64, step, seq, pkt);
            }
            Ok(Frame::Bye) => return,
            Ok(Frame::Handshake(_)) => {
                fail(
                    io::ErrorKind::InvalidData,
                    format!("peer {sender} re-sent a handshake mid-stream"),
                );
                return;
            }
            Err(e) => {
                fail(
                    io::ErrorKind::InvalidData,
                    format!("frame body from peer {sender}: {e}"),
                );
                return;
            }
        }
    }
}

/// The socket-backed [`RankFabric`]. One instance per rank *process*;
/// `send` frames packets onto the peer streams and `recv_step` drains
/// this rank's inbox in canonical order. See the module docs for the
/// topology and epoch semantics.
pub struct SocketFabric {
    rank: usize,
    n_ranks: usize,
    shared: Arc<Shared>,
    /// write streams, indexed by peer rank (`None` at `self.rank`)
    outs: Vec<Option<Mutex<Stream>>>,
    /// current exchange epoch (bumped by `begin_exchange`)
    epoch: AtomicU64,
    /// canonical seqs for loopback sends, keyed like the readers'
    self_seqs: Mutex<HashMap<(u64, usize), u64>>,
    /// wall-clock `(frame bytes, seconds)` per blocking send
    link: Mutex<Vec<(u64, f64)>>,
    opts: SocketOptions,
    readers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// nonzero once `finish` ran (bye frames sent)
    done: AtomicU64,
}

impl SocketFabric {
    /// Build the mesh: connect out to every peer (bounded retry while
    /// their listeners come up), send our handshake on each outgoing
    /// stream, then accept and validate one inbound handshake per peer.
    /// `max_steps` sizes the shared ledger (the widest schedule any
    /// combine can use — `n_ranks` covers every ring).
    pub fn establish(
        rank: usize,
        listener: SocketListener,
        peers: &[PeerAddr],
        digest: u64,
        max_steps: usize,
        opts: SocketOptions,
    ) -> FabricResult<SocketFabric> {
        let n_ranks = peers.len();
        assert!(rank < n_ranks, "rank {rank} out of range ({n_ranks})");
        let err = |kind, detail: String| FabricError::new(rank, kind, detail);

        let shared = Arc::new(Shared {
            rank,
            n_ranks,
            ledger: StepLedger::new(n_ranks, max_steps),
            inbox: Mutex::new(Vec::new()),
            arrival: Condvar::new(),
            fail: Mutex::new(None),
            shutdown: AtomicU64::new(0),
        });

        // outgoing half: one stream per peer, handshake first
        let hello = encode_handshake(&Handshake {
            config_digest: digest,
            rank: rank as u32,
            n_ranks: n_ranks as u32,
        });
        let deadline = Instant::now() + opts.connect_timeout;
        let mut outs: Vec<Option<Mutex<Stream>>> = Vec::with_capacity(n_ranks);
        for (q, addr) in peers.iter().enumerate() {
            if q == rank {
                outs.push(None);
                continue;
            }
            let mut backoff = opts.connect_backoff;
            let mut stream = loop {
                match Stream::connect(addr) {
                    Ok(s) => break s,
                    Err(e) => {
                        if Instant::now() + backoff > deadline {
                            return Err(err(
                                e.kind(),
                                format!("connecting to rank {q} at {addr}: {e}"),
                            )
                            .with_peer(q));
                        }
                        std::thread::sleep(backoff);
                        backoff = (backoff * 2).min(Duration::from_millis(500));
                    }
                }
            };
            stream
                .set_write_timeout(Some(opts.recv_timeout))
                .map_err(|e| err(e.kind(), format!("peer {q}: set write timeout: {e}")))?;
            stream
                .write_all(&hello)
                .map_err(|e| err(e.kind(), format!("handshake to rank {q}: {e}")).with_peer(q))?;
            outs.push(Some(Mutex::new(stream)));
        }

        // incoming half: accept one stream per peer, validate its
        // handshake, and hand it to a reader thread
        listener
            .set_nonblocking(true)
            .map_err(|e| err(e.kind(), format!("accept setup: {e}")))?;
        let mut readers = Vec::with_capacity(n_ranks.saturating_sub(1));
        let mut seen = vec![false; n_ranks];
        seen[rank] = true;
        for _ in 0..n_ranks.saturating_sub(1) {
            let mut stream = loop {
                match listener.accept() {
                    Ok(s) => break s,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        if Instant::now() > deadline {
                            let missing: Vec<usize> = (0..n_ranks).filter(|&q| !seen[q]).collect();
                            return Err(err(
                                io::ErrorKind::TimedOut,
                                format!("rank(s) {missing:?} never connected"),
                            ));
                        }
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => return Err(err(e.kind(), format!("accept: {e}"))),
                }
            };
            stream
                .set_read_timeout(Some(READ_POLL))
                .map_err(|e| err(e.kind(), format!("set read timeout: {e}")))?;
            // read the handshake frame (header + body) with the deadline
            let hs = read_handshake(&mut stream, &shared, deadline)
                .map_err(|e| FabricError { rank, ..e })?;
            if hs.config_digest != digest {
                return Err(err(
                    io::ErrorKind::InvalidData,
                    format!(
                        "peer rank {} joined with config digest {:#018x}, ours is {:#018x} \
                         (different run or stale binary)",
                        hs.rank, hs.config_digest, digest
                    ),
                )
                .with_peer(hs.rank as usize));
            }
            if hs.n_ranks as usize != n_ranks {
                return Err(err(
                    io::ErrorKind::InvalidData,
                    format!("peer expects {} ranks, this run has {n_ranks}", hs.n_ranks),
                ));
            }
            let q = hs.rank as usize;
            if q >= n_ranks || seen[q] {
                return Err(err(
                    io::ErrorKind::InvalidData,
                    format!("unexpected or duplicate peer rank {q}"),
                ));
            }
            seen[q] = true;
            let sh = Arc::clone(&shared);
            readers.push(std::thread::spawn(move || reader_loop(&sh, stream, q)));
        }

        Ok(SocketFabric {
            rank,
            n_ranks,
            shared,
            outs,
            epoch: AtomicU64::new(0),
            self_seqs: Mutex::new(HashMap::new()),
            link: Mutex::new(Vec::new()),
            opts,
            readers: Mutex::new(readers),
            done: AtomicU64::new(0),
        })
    }

    /// The rank this process owns.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Orderly teardown: tell every peer goodbye so their readers exit
    /// cleanly, then let ours drain. Idempotent; also run by `Drop`.
    pub fn finish(&self) {
        if self.done.fetch_add(1) != 0 {
            return;
        }
        let bye = encode_bye();
        for out in self.outs.iter().flatten() {
            let mut s = out.lock().unwrap();
            let _ = s.write_all(&bye);
        }
        self.shared.shutdown.store(1);
        let mut readers = self.readers.lock().unwrap();
        for h in readers.drain(..) {
            let _ = h.join();
        }
        // close write halves so a peer stuck mid-read unblocks
        for out in self.outs.iter().flatten() {
            out.lock().unwrap().shutdown_both();
        }
    }

    fn current_epoch(&self) -> u64 {
        self.epoch.load()
    }

    fn first_failure(&self) -> Option<FabricError> {
        self.shared.fail.lock().unwrap().clone()
    }
}

/// Read and decode the mandatory first (handshake) frame off a fresh
/// inbound stream.
fn read_handshake(
    stream: &mut Stream,
    shared: &Shared,
    deadline: Instant,
) -> FabricResult<Handshake> {
    let err = |kind, detail: String| FabricError::new(shared.rank, kind, detail);
    let mut header = [0u8; FRAME_HEADER_BYTES];
    let mut fill = |buf: &mut [u8]| -> FabricResult<()> {
        match read_full(stream, buf, shared, Some(deadline)) {
            Ok(ReadOutcome::Full) => Ok(()),
            Ok(ReadOutcome::Eof) | Ok(ReadOutcome::Shutdown) => Err(err(
                io::ErrorKind::UnexpectedEof,
                "peer closed before completing the handshake".into(),
            )),
            Err(e) if e.kind() == io::ErrorKind::TimedOut => Err(err(
                io::ErrorKind::TimedOut,
                "handshake never arrived".into(),
            )),
            Err(e) => Err(err(e.kind(), format!("reading handshake: {e}"))),
        }
    };
    fill(&mut header)?;
    let h = decode_header(&header)
        .map_err(|e| err(io::ErrorKind::InvalidData, format!("handshake header: {e}")))?;
    let mut body = vec![0u8; h.body_len as usize];
    fill(&mut body)?;
    match decode_body(h, &body) {
        Ok(Frame::Handshake(hs)) => Ok(hs),
        Ok(other) => Err(err(
            io::ErrorKind::InvalidData,
            format!("expected a handshake frame, got {other:?}"),
        )),
        Err(e) => Err(err(io::ErrorKind::InvalidData, format!("handshake: {e}"))),
    }
}

impl RankFabric for SocketFabric {
    fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    fn begin_exchange(&self, n_steps: usize) {
        // every rank process executes the same deterministic sequence of
        // combines, so bumping the local epoch keeps all ranks' epochs in
        // lockstep without any coordination traffic
        self.epoch.fetch_add(1);
        self.shared.ledger.begin_exchange(n_steps);
    }

    fn send(&self, p: Packet) -> FabricResult<()> {
        let to = p.receiver();
        let step = p.offset();
        let bytes = p.bytes();
        let epoch = self.current_epoch();
        assert_eq!(
            p.sender(),
            self.rank,
            "socket fabric only sends for its own rank"
        );
        self.shared.ledger.note_send(self.rank, to, step, bytes);
        if to == self.rank {
            // loopback: straight into the inbox, canonical seq assigned
            // here because no reader thread sees this packet
            let seq = {
                let mut m = self.self_seqs.lock().unwrap();
                let c = m.entry((epoch, step)).or_insert(0);
                let s = *c;
                *c += 1;
                s
            };
            self.shared.push(self.rank, epoch, step, seq, p);
            return Ok(());
        }
        if let Some(e) = self.first_failure() {
            return Err(e.at_step(step));
        }
        let frame = encode_packet_frame(&p, epoch as u32);
        let out = self.outs[to].as_ref().expect("peer stream");
        let mut s = out.lock().unwrap();
        let t0 = Instant::now();
        s.write_all(&frame).map_err(|e| {
            FabricError::new(self.rank, e.kind(), format!("sending to rank {to}: {e}"))
                .at_step(step)
                .with_peer(to)
        })?;
        let secs = t0.elapsed().as_secs_f64();
        drop(s);
        self.link.lock().unwrap().push((frame.len() as u64, secs));
        Ok(())
    }

    fn recv_step(&self, p: usize, step: usize, n_expected: usize) -> FabricResult<Vec<Packet>> {
        assert_eq!(p, self.rank, "socket fabric owns a single rank");
        let epoch = self.current_epoch();
        self.shared.ledger.mark_drained(p, step);
        let deadline = Instant::now() + self.opts.recv_timeout;
        let matches =
            |q: &NetQueued| q.epoch == epoch && q.step == step;
        let mut ib = self.shared.inbox.lock().unwrap();
        while ib.iter().filter(|q| matches(q)).count() < n_expected {
            if let Some(e) = self.first_failure() {
                return Err(e.at_step(step));
            }
            let now = Instant::now();
            if now >= deadline {
                let got = ib.iter().filter(|q| matches(q)).count();
                return Err(FabricError::timeout(
                    p,
                    step,
                    format!("{got} of {n_expected} packet(s) arrived before the window closed"),
                ));
            }
            let (guard, _) = self
                .shared
                .arrival
                .wait_timeout(ib, deadline - now)
                .unwrap();
            ib = guard;
        }
        let mut got = Vec::with_capacity(n_expected);
        let mut rest = Vec::with_capacity(ib.len().saturating_sub(n_expected));
        for q in ib.drain(..) {
            if matches(&q) {
                got.push(q);
            } else {
                rest.push(q);
            }
        }
        *ib = rest;
        drop(ib);
        got.sort_by_key(|q| (q.sender, q.seq));
        let bytes: u64 = got.iter().map(|q| q.pkt.bytes()).sum();
        self.shared.ledger.note_recv(p, step, bytes);
        self.shared.ledger.unpark(bytes);
        Ok(got.into_iter().map(|q| q.pkt).collect())
    }

    fn ledger(&self) -> &StepLedger {
        &self.shared.ledger
    }

    fn pending(&self, p: usize) -> usize {
        assert_eq!(p, self.rank, "socket fabric owns a single rank");
        let epoch = self.current_epoch();
        self.shared
            .inbox
            .lock()
            .unwrap()
            .iter()
            .filter(|q| q.epoch == epoch)
            .count()
    }

    fn assert_empty(&self) {
        // packets of a *future* epoch are legitimate (a fast peer already
        // sending the next combine); only current-or-older ones strand
        let epoch = self.current_epoch();
        let n = self
            .shared
            .inbox
            .lock()
            .unwrap()
            .iter()
            .filter(|q| q.epoch <= epoch)
            .count();
        assert!(n == 0, "rank {} has {n} stranded packets", self.rank);
    }

    fn measured_link(&self) -> Option<LinkMeasurement> {
        LinkMeasurement::fit(&self.link.lock().unwrap())
    }
}

impl Drop for SocketFabric {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::frame::config_digest;

    fn establish_mesh(
        n: usize,
        digest: u64,
        opts: SocketOptions,
    ) -> Vec<FabricResult<SocketFabric>> {
        let listeners: Vec<SocketListener> = (0..n)
            .map(|_| SocketListener::bind(&PeerAddr::Tcp("127.0.0.1:0".into())).unwrap())
            .collect();
        let addrs: Vec<PeerAddr> = listeners.iter().map(|l| l.local_addr().clone()).collect();
        let mut out: Vec<Option<FabricResult<SocketFabric>>> = (0..n).map(|_| None).collect();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (r, l) in listeners.into_iter().enumerate() {
                let addrs = addrs.clone();
                handles.push(s.spawn(move || {
                    (r, SocketFabric::establish(r, l, &addrs, digest, n, opts))
                }));
            }
            for h in handles {
                let (r, f) = h.join().unwrap();
                out[r] = Some(f);
            }
        });
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    fn quick_opts() -> SocketOptions {
        SocketOptions {
            connect_timeout: Duration::from_secs(10),
            connect_backoff: Duration::from_millis(5),
            recv_timeout: Duration::from_secs(10),
        }
    }

    #[test]
    fn mesh_exchange_is_canonical_and_accounted() {
        let digest = config_digest("socket-mesh-test");
        let fabrics: Vec<SocketFabric> = establish_mesh(3, digest, quick_opts())
            .into_iter()
            .map(|f| f.unwrap())
            .collect();
        // one 2-step exchange: every rank sends one packet per peer per
        // step (including a loopback), payload tagged (sender, step)
        std::thread::scope(|s| {
            for (r, fab) in fabrics.iter().enumerate() {
                s.spawn(move || {
                    fab.begin_exchange(2);
                    for w in 0..2 {
                        for q in 0..3 {
                            RankFabric::send(
                                fab,
                                Packet::new(r, q, w, 0, 2, vec![r as f32, w as f32]),
                            )
                            .unwrap();
                        }
                    }
                    for w in 0..2 {
                        let got = fab.recv_step(r, w, 3).unwrap();
                        let senders: Vec<usize> = got.iter().map(|p| p.sender()).collect();
                        assert_eq!(senders, [0, 1, 2], "canonical order at rank {r}");
                        for p in &got {
                            assert_eq!(p.dense_rows(), &[p.sender() as f32, w as f32]);
                        }
                    }
                    fab.assert_empty();
                });
            }
        });
        // ledger: each rank sent 3 packets per step, received 3 per step
        let bytes = Packet::new(0, 1, 0, 0, 2, vec![0.0; 2]).bytes();
        for (r, fab) in fabrics.iter().enumerate() {
            for w in 0..2 {
                assert_eq!(fab.ledger().sent_msgs(r, w), 3);
                assert_eq!(fab.ledger().sent_bytes(r, w), 3 * bytes);
                assert_eq!(fab.ledger().recv_bytes(r, w), 3 * bytes);
            }
            assert_eq!(fab.ledger().in_flight_bytes(), 0);
            assert!(fab.ledger().in_flight_peak() >= bytes);
            // real sends were clocked (2 peers × 2 steps = 4 samples)
            let link = fab.measured_link().expect("link fit");
            assert_eq!(link.samples, 4);
        }
        for f in &fabrics {
            f.finish();
        }
    }

    #[test]
    fn epochs_keep_racing_combines_apart() {
        let digest = config_digest("socket-epoch-test");
        let mut fabrics = establish_mesh(2, digest, quick_opts());
        let f1 = fabrics.pop().unwrap().unwrap();
        let f0 = fabrics.pop().unwrap().unwrap();
        std::thread::scope(|s| {
            // rank 0 races ahead: sends its packets for two successive
            // 1-step combines before rank 1 drains the first
            s.spawn(|| {
                f0.begin_exchange(1);
                RankFabric::send(&f0, Packet::new(0, 1, 0, 0, 1, vec![1.0])).unwrap();
                let got = f0.recv_step(0, 0, 1).unwrap();
                assert_eq!(got[0].dense_rows(), &[10.0]);
                f0.begin_exchange(1);
                RankFabric::send(&f0, Packet::new(0, 1, 0, 0, 1, vec![2.0])).unwrap();
                let got = f0.recv_step(0, 0, 1).unwrap();
                assert_eq!(got[0].dense_rows(), &[20.0]);
            });
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(50));
                f1.begin_exchange(1);
                RankFabric::send(&f1, Packet::new(1, 0, 0, 0, 1, vec![10.0])).unwrap();
                // even if 0's second-combine packet already arrived, the
                // epoch tag keeps it out of this drain
                let got = f1.recv_step(1, 0, 1).unwrap();
                assert_eq!(got[0].dense_rows(), &[1.0], "first combine's packet");
                f1.begin_exchange(1);
                RankFabric::send(&f1, Packet::new(1, 0, 0, 0, 1, vec![20.0])).unwrap();
                let got = f1.recv_step(1, 0, 1).unwrap();
                assert_eq!(got[0].dense_rows(), &[2.0]);
            });
        });
        f0.finish();
        f1.finish();
    }

    #[test]
    fn digest_mismatch_is_rejected_typed() {
        // two ranks established with different config digests: at least
        // one side must fail handshake validation with InvalidData
        let listeners: Vec<SocketListener> = (0..2)
            .map(|_| SocketListener::bind(&PeerAddr::Tcp("127.0.0.1:0".into())).unwrap())
            .collect();
        let addrs: Vec<PeerAddr> = listeners.iter().map(|l| l.local_addr().clone()).collect();
        let opts = SocketOptions {
            connect_timeout: Duration::from_secs(5),
            ..quick_opts()
        };
        let mut results = Vec::new();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (r, l) in listeners.into_iter().enumerate() {
                let addrs = addrs.clone();
                let digest = config_digest(if r == 0 { "run-a" } else { "run-b" });
                handles.push(
                    s.spawn(move || SocketFabric::establish(r, l, &addrs, digest, 2, opts)),
                );
            }
            for h in handles {
                results.push(h.join().unwrap());
            }
        });
        let failures: Vec<&FabricError> =
            results.iter().filter_map(|r| r.as_ref().err()).collect();
        assert!(!failures.is_empty(), "mismatched digests must be rejected");
        for e in failures {
            assert_eq!(e.kind, io::ErrorKind::InvalidData, "{e}");
            assert!(e.detail.contains("digest"), "{e}");
        }
    }

    #[test]
    fn missing_peer_times_out_typed() {
        // a recv_step whose peer never sends surfaces a typed timeout
        // instead of hanging the fold
        let opts = SocketOptions {
            recv_timeout: Duration::from_millis(200),
            ..quick_opts()
        };
        let fabrics: Vec<SocketFabric> =
            establish_mesh(2, config_digest("timeout-test"), opts)
                .into_iter()
                .map(|f| f.unwrap())
                .collect();
        let f0 = &fabrics[0];
        f0.begin_exchange(1);
        fabrics[1].begin_exchange(1);
        let err = f0.recv_step(0, 0, 1).unwrap_err();
        assert_eq!(err.kind, io::ErrorKind::TimedOut, "{err}");
        assert_eq!(err.rank, 0);
        assert_eq!(err.step, Some(0));
        for f in &fabrics {
            f.finish();
        }
    }

    #[test]
    fn peer_death_mid_step_surfaces_disconnect() {
        let opts = SocketOptions {
            recv_timeout: Duration::from_secs(30),
            ..quick_opts()
        };
        let mut fabrics = establish_mesh(2, config_digest("disconnect-test"), opts);
        let f1 = fabrics.pop().unwrap().unwrap();
        let f0 = fabrics.pop().unwrap().unwrap();
        f0.begin_exchange(1);
        // rank 1 dies without a bye: drop hard by shutting its sockets
        // (finish() would send the orderly bye, which is the clean path)
        for out in f1.outs.iter().flatten() {
            out.lock().unwrap().shutdown_both();
        }
        let err = f0.recv_step(0, 0, 1).unwrap_err();
        assert_eq!(err.kind, io::ErrorKind::UnexpectedEof, "{err}");
        assert_eq!(err.peer, Some(1));
        assert!(err.detail.contains("without a bye"), "{err}");
        drop(f1);
        f0.finish();
    }

    #[test]
    fn unix_domain_mesh_works() {
        let dir = std::env::temp_dir().join(format!("harpsg-uds-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let listeners: Vec<SocketListener> = (0..2)
            .map(|r| {
                SocketListener::bind(&PeerAddr::Unix(dir.join(format!("rank-{r}.sock")))).unwrap()
            })
            .collect();
        let addrs: Vec<PeerAddr> = listeners.iter().map(|l| l.local_addr().clone()).collect();
        let digest = config_digest("uds-test");
        let mut fabrics: Vec<Option<SocketFabric>> = vec![None, None];
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (r, l) in listeners.into_iter().enumerate() {
                let addrs = addrs.clone();
                handles.push(s.spawn(move || {
                    (
                        r,
                        SocketFabric::establish(r, l, &addrs, digest, 2, quick_opts()).unwrap(),
                    )
                }));
            }
            for h in handles {
                let (r, f) = h.join().unwrap();
                fabrics[r] = Some(f);
            }
        });
        let f0 = fabrics[0].take().unwrap();
        let f1 = fabrics[1].take().unwrap();
        std::thread::scope(|s| {
            s.spawn(|| {
                f0.begin_exchange(1);
                RankFabric::send(&f0, Packet::new(0, 1, 0, 0, 1, vec![5.0])).unwrap();
                assert_eq!(f0.recv_step(0, 0, 1).unwrap()[0].dense_rows(), &[6.0]);
            });
            s.spawn(|| {
                f1.begin_exchange(1);
                RankFabric::send(&f1, Packet::new(1, 0, 0, 0, 1, vec![6.0])).unwrap();
                assert_eq!(f1.recv_step(1, 0, 1).unwrap()[0].dense_rows(), &[5.0]);
            });
        });
        f0.finish();
        f1.finish();
        drop(f0);
        drop(f1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
