//! Exchange schedules: the Adaptive-Group ring routing (paper Fig. 2 /
//! Alg 3) and the degenerate all-to-all schedule.
//!
//! A schedule decouples a complete exchange among `P` ranks into `W`
//! steps; at step `w`, rank `p` sends to the peers at offsets
//! `o ∈ O_w` (i.e. to `(p+o) mod P`) and receives from `(p-o) mod P`.
//! With `g` offsets per step the communication group containing `p` has
//! size `m = 2g+1`; the paper's Fig.-2 example is `g=1` (groups of 3,
//! `W = P-1` steps), and `g = P-1` degenerates to single-step all-to-all.

/// One rank's sends/receives for one step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StepPlan {
    pub send_to: Vec<usize>,
    pub recv_from: Vec<usize>,
}

/// A complete exchange schedule. `plans[w][p]` is rank `p`'s plan at step
/// `w`; every ordered pair (p→q, p≠q) appears exactly once across steps.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub n_ranks: usize,
    /// offsets covered at each step
    pub offsets: Vec<Vec<usize>>,
    pub plans: Vec<Vec<StepPlan>>,
}

impl Schedule {
    /// Offsets-per-step chunk sizes of the ring: `g` per step, with the
    /// remainder `(P-1) mod g` forming the short last step. This is the
    /// single definition of the ring's chunking — [`Self::ring`] builds
    /// its schedule from it and the adaptive model predicts against it,
    /// so predictions and executed schedules agree by construction.
    pub fn ring_step_sizes(n_ranks: usize, g: usize) -> Vec<usize> {
        let g = g.max(1);
        let mut sizes = Vec::new();
        let mut o = 1usize;
        while o < n_ranks {
            let hi = (o + g).min(n_ranks);
            sizes.push(hi - o);
            o = hi;
        }
        sizes
    }

    /// Ring-ordered schedule with `g ≥ 1` offsets per step.
    pub fn ring(n_ranks: usize, g: usize) -> Self {
        assert!(n_ranks >= 1);
        let mut offsets = Vec::new();
        let mut o = 1usize;
        for m in Self::ring_step_sizes(n_ranks, g) {
            offsets.push((o..o + m).collect::<Vec<_>>());
            o += m;
        }
        let plans = offsets
            .iter()
            .map(|os| {
                (0..n_ranks)
                    .map(|p| StepPlan {
                        send_to: os.iter().map(|&o| (p + o) % n_ranks).collect(),
                        recv_from: os.iter().map(|&o| (p + n_ranks - o) % n_ranks).collect(),
                    })
                    .collect()
            })
            .collect();
        Schedule {
            n_ranks,
            offsets,
            plans,
        }
    }

    /// Single-step all-to-all.
    pub fn all_to_all(n_ranks: usize) -> Self {
        Self::ring(n_ranks, n_ranks.saturating_sub(1).max(1))
    }

    pub fn n_steps(&self) -> usize {
        self.plans.len()
    }

    /// Communication-group size at each step (the paper's `m`).
    pub fn group_size(&self) -> usize {
        2 * self.offsets.first().map(|o| o.len()).unwrap_or(0) + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn check_complete(s: &Schedule) -> Result<(), String> {
        let p_count = s.n_ranks;
        let mut sent = vec![vec![0usize; p_count]; p_count];
        for (w, step) in s.plans.iter().enumerate() {
            for (p, plan) in step.iter().enumerate() {
                for &q in &plan.send_to {
                    if q == p {
                        return Err(format!("self-send p={p} step {w}"));
                    }
                    sent[p][q] += 1;
                }
                // symmetry: p receives from r at step w iff r sends to p
                for &r in &plan.recv_from {
                    if !s.plans[w][r].send_to.contains(&p) {
                        return Err(format!("asymmetric: {p} expects from {r} at {w}"));
                    }
                }
            }
        }
        for p in 0..p_count {
            for q in 0..p_count {
                let want = usize::from(p != q);
                if sent[p][q] != want {
                    return Err(format!("pair {p}->{q} covered {} times", sent[p][q]));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn fig2_example_five_ranks() {
        // paper Fig 2: P=5, group size 3 (g=1) -> 4 steps, each rank
        // talks to exactly 2 peers per step
        let s = Schedule::ring(5, 1);
        assert_eq!(s.n_steps(), 4);
        assert_eq!(s.group_size(), 3);
        for step in &s.plans {
            for plan in step {
                assert_eq!(plan.send_to.len(), 1);
                assert_eq!(plan.recv_from.len(), 1);
            }
        }
        check_complete(&s).unwrap();
    }

    #[test]
    fn all_to_all_single_step() {
        let s = Schedule::all_to_all(6);
        assert_eq!(s.n_steps(), 1);
        assert_eq!(s.plans[0][2].send_to.len(), 5);
        check_complete(&s).unwrap();
    }

    #[test]
    fn ring_step_counts() {
        // W = ceil((P-1)/g)
        assert_eq!(Schedule::ring(10, 1).n_steps(), 9);
        assert_eq!(Schedule::ring(10, 3).n_steps(), 3);
        assert_eq!(Schedule::ring(10, 4).n_steps(), 3);
        assert_eq!(Schedule::ring(10, 9).n_steps(), 1);
        assert_eq!(Schedule::ring(1, 1).n_steps(), 0);
    }

    #[test]
    fn prop_ring_complete_no_dupes() {
        prop::check("ring_complete", |gen| {
            let p = gen.usize_in(1, 24);
            let g = gen.usize_in(1, 24);
            check_complete(&Schedule::ring(p, g))
        });
    }
}
