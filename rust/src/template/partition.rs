//! Recursive template partitioning (Alg 1 line 8, Fig 1a).
//!
//! A subtemplate `Ti` rooted at ρ with children `c1..cm` (ordered by
//! descending subtree size — deterministic) is split by cutting the edge
//! to its *last* child: the **active child** `Ti''` is the subtree rooted
//! at `cm`, the **passive child** `Ti'` is `Ti` minus that subtree (root
//! stays ρ). Recursion bottoms out at single vertices. Isomorphic rooted
//! subtemplates are deduplicated by their AHU canonical string, so the DP
//! computes (and stores) each distinct shape once — this is what makes the
//! count-table inventory (and hence Fig 12's peak memory) minimal.

use super::Template;
use std::collections::HashMap;

/// A node in the partition DAG.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SubTemplate {
    /// number of vertices (= number of active colors `a`)
    pub size: usize,
    /// index of the passive child `Ti'` (None for leaves)
    pub passive: Option<usize>,
    /// index of the active child `Ti''` (None for leaves)
    pub active: Option<usize>,
    /// AHU canonical encoding of the rooted shape
    pub canon: String,
}

impl SubTemplate {
    pub fn is_leaf(&self) -> bool {
        self.passive.is_none()
    }

    /// |Ti'| — size of the passive child.
    pub fn passive_size(&self, dag: &PartitionDag) -> usize {
        self.passive.map(|i| dag.subs[i].size).unwrap_or(0)
    }

    /// |Ti''| — size of the active child.
    pub fn active_size(&self, dag: &PartitionDag) -> usize {
        self.active.map(|i| dag.subs[i].size).unwrap_or(0)
    }
}

/// The deduplicated partition DAG of a template.
#[derive(Debug, Clone)]
pub struct PartitionDag {
    pub subs: Vec<SubTemplate>,
    /// index of the full template
    pub root: usize,
    /// topological compute order: children strictly before parents
    pub order: Vec<usize>,
}

/// Rooted-tree working representation used during partitioning.
#[derive(Debug, Clone)]
struct RNode {
    children: Vec<RNode>,
}

impl RNode {
    fn size(&self) -> usize {
        1 + self.children.iter().map(RNode::size).sum::<usize>()
    }

    fn canon(&self) -> String {
        let mut cs: Vec<String> = self.children.iter().map(RNode::canon).collect();
        cs.sort();
        format!("({})", cs.concat())
    }
}

/// Build the rooted representation of `t` rooted at vertex 0, with children
/// ordered by descending subtree size (ties by vertex id).
fn build_rooted(t: &Template) -> RNode {
    fn rec(t: &Template, v: u32, parent: u32) -> RNode {
        let mut children: Vec<(usize, u32, RNode)> = t.adj[v as usize]
            .iter()
            .filter(|&&u| u != parent)
            .map(|&u| {
                let node = rec(t, u, v);
                (node.size(), u, node)
            })
            .collect();
        children.sort_by_key(|(s, u, _)| (std::cmp::Reverse(*s), *u));
        RNode {
            children: children.into_iter().map(|(_, _, n)| n).collect(),
        }
    }
    rec(t, 0, u32::MAX)
}

/// Partition a template into its deduplicated subtemplate DAG.
pub fn partition_template(t: &Template) -> PartitionDag {
    let rooted = build_rooted(t);
    let mut subs: Vec<SubTemplate> = Vec::new();
    let mut index: HashMap<String, usize> = HashMap::new();
    let mut order: Vec<usize> = Vec::new();

    fn go(
        node: &RNode,
        subs: &mut Vec<SubTemplate>,
        index: &mut HashMap<String, usize>,
        order: &mut Vec<usize>,
    ) -> usize {
        let canon = node.canon();
        if let Some(&i) = index.get(&canon) {
            return i;
        }
        let (passive, active) = if node.children.is_empty() {
            (None, None)
        } else {
            let active_node = node.children.last().unwrap();
            let a = go(active_node, subs, index, order);
            let passive_node = RNode {
                children: node.children[..node.children.len() - 1].to_vec(),
            };
            let p = go(&passive_node, subs, index, order);
            (Some(p), Some(a))
        };
        let i = subs.len();
        subs.push(SubTemplate {
            size: node.size(),
            passive,
            active,
            canon,
        });
        index.insert(subs[i].canon.clone(), i);
        order.push(i);
        i
    }

    let root = go(&rooted, &mut subs, &mut index, &mut order);
    PartitionDag { subs, root, order }
}

impl PartitionDag {
    /// For each subtemplate, the index of the last step in `order` that
    /// reads it — used by the engine to free count tables early (the
    /// intermediate-data reduction the paper's pipeline design leans on).
    pub fn last_use(&self) -> Vec<usize> {
        let mut last = vec![0usize; self.subs.len()];
        for (step, &i) in self.order.iter().enumerate() {
            last[i] = last[i].max(step);
            if let Some(p) = self.subs[i].passive {
                last[p] = last[p].max(step);
            }
            if let Some(a) = self.subs[i].active {
                last[a] = last[a].max(step);
            }
        }
        // the root's table is read when forming the final estimate
        last[self.root] = self.order.len();
        last
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::template::builtin;

    #[test]
    fn path3_partition() {
        let t = builtin("u3-1").unwrap();
        let dag = partition_template(&t);
        // shapes: leaf, path2, path3 (rooted at middle -> star2? rooted at
        // vertex 0 which is an end of the path)
        let root = &dag.subs[dag.root];
        assert_eq!(root.size, 3);
        assert!(!root.is_leaf());
        // sizes of children sum to parent
        for s in &dag.subs {
            if !s.is_leaf() {
                assert_eq!(s.passive_size(&dag) + s.active_size(&dag), s.size);
            }
        }
    }

    #[test]
    fn order_is_topological() {
        for name in crate::template::BUILTIN_NAMES {
            let t = builtin(name).unwrap();
            let dag = partition_template(&t);
            let pos: std::collections::HashMap<usize, usize> =
                dag.order.iter().enumerate().map(|(p, &i)| (i, p)).collect();
            for &i in &dag.order {
                if let (Some(p), Some(a)) = (dag.subs[i].passive, dag.subs[i].active) {
                    assert!(pos[&p] < pos[&i], "{name}: passive after parent");
                    assert!(pos[&a] < pos[&i], "{name}: active after parent");
                }
            }
            assert_eq!(dag.subs[dag.root].size, t.size());
        }
    }

    #[test]
    fn dedup_shares_shapes() {
        // a perfect binary tree has massive sharing: its partition touches
        // far fewer distinct shapes than the 2·15-1 raw splits.
        let t = crate::template::Template::from_edges(
            "pb15",
            15,
            &[
                (0, 1),
                (0, 2),
                (1, 3),
                (1, 4),
                (2, 5),
                (2, 6),
                (3, 7),
                (3, 8),
                (4, 9),
                (4, 10),
                (5, 11),
                (5, 12),
                (6, 13),
                (6, 14),
            ],
        )
        .unwrap();
        let dag = partition_template(&t);
        assert!(
            dag.subs.len() <= 12,
            "perfect binary tree should dedup to ≤12 shapes, got {}",
            dag.subs.len()
        );
        // exactly one leaf shape
        assert_eq!(dag.subs.iter().filter(|s| s.is_leaf()).count(), 1);
    }

    #[test]
    fn last_use_allows_freeing() {
        let t = builtin("u12-2").unwrap();
        let dag = partition_template(&t);
        let last = dag.last_use();
        // the leaf is used by some later step, and the root lives to the end
        let leaf = dag.subs.iter().position(|s| s.is_leaf()).unwrap();
        assert!(last[leaf] > 0);
        assert_eq!(last[dag.root], dag.order.len());
    }
}
