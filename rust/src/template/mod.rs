//! Treelet templates: representation, the builtin library matching the
//! paper's Figure 5 (u3-1 … u15-2), a text parser, recursive partitioning
//! into subtemplates (Alg 1 line 8), automorphism counting (the DP
//! over-count divisor), and the Table-3 complexity model.
//!
//! Note on shapes: the chapter shows Fig 5 only as an image. The builtin
//! shapes here are chosen to match the published vertex counts and the
//! Table-3 *computation-intensity relationships* (e.g. u12-2 has ~2× the
//! intensity of the equally-sized u12-1 because its partition splits are
//! balanced). This substitution is documented in DESIGN.md §1.

pub mod automorphism;
pub mod complexity;
pub mod partition;

pub use automorphism::automorphism_count;
pub use complexity::{complexity, TemplateComplexity};
pub use partition::{partition_template, PartitionDag, SubTemplate};

use anyhow::{bail, Context, Result};

/// A tree template on `size()` vertices. Vertex 0 is the root by
/// convention (the DP is root-invariant up to the automorphism divisor).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Template {
    pub name: String,
    /// adjacency lists (tree, undirected)
    pub adj: Vec<Vec<u32>>,
}

impl Template {
    /// Build from an undirected edge list; validates tree-ness.
    pub fn from_edges(name: &str, n: usize, edges: &[(u32, u32)]) -> Result<Template> {
        if n == 0 {
            bail!("template {name}: empty");
        }
        if edges.len() != n - 1 {
            bail!(
                "template {name}: {} edges for {} vertices — not a tree",
                edges.len(),
                n
            );
        }
        let mut adj = vec![Vec::new(); n];
        for &(u, v) in edges {
            if u as usize >= n || v as usize >= n || u == v {
                bail!("template {name}: bad edge ({u},{v})");
            }
            adj[u as usize].push(v);
            adj[v as usize].push(u);
        }
        let t = Template {
            name: name.to_string(),
            adj,
        };
        if !t.is_connected() {
            bail!("template {name}: disconnected");
        }
        Ok(t)
    }

    pub fn size(&self) -> usize {
        self.adj.len()
    }

    fn is_connected(&self) -> bool {
        let n = self.size();
        let mut seen = vec![false; n];
        let mut stack = vec![0u32];
        seen[0] = true;
        let mut count = 0;
        while let Some(v) = stack.pop() {
            count += 1;
            for &u in &self.adj[v as usize] {
                if !seen[u as usize] {
                    seen[u as usize] = true;
                    stack.push(u);
                }
            }
        }
        count == n
    }

    /// Children of `v` when the tree is rooted at 0 (parent excluded),
    /// ordered by descending subtree size then vertex id — a deterministic
    /// ordering that the partition relies on.
    pub fn rooted_children(&self) -> Vec<Vec<u32>> {
        let n = self.size();
        let mut parent = vec![u32::MAX; n];
        let mut order = Vec::with_capacity(n);
        let mut stack = vec![0u32];
        let mut seen = vec![false; n];
        seen[0] = true;
        while let Some(v) = stack.pop() {
            order.push(v);
            for &u in &self.adj[v as usize] {
                if !seen[u as usize] {
                    seen[u as usize] = true;
                    parent[u as usize] = v;
                    stack.push(u);
                }
            }
        }
        let mut sub_size = vec![1u32; n];
        for &v in order.iter().rev() {
            if parent[v as usize] != u32::MAX {
                sub_size[parent[v as usize] as usize] += sub_size[v as usize];
            }
        }
        let mut children = vec![Vec::new(); n];
        for v in 1..n as u32 {
            children[parent[v as usize] as usize].push(v);
        }
        for c in &mut children {
            c.sort_by_key(|&v| (std::cmp::Reverse(sub_size[v as usize]), v));
        }
        children
    }

    /// Parse the text format: first line `n`, then `n-1` lines `u v`.
    /// `#` comments allowed.
    pub fn parse(name: &str, text: &str) -> Result<Template> {
        let mut lines = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        let n: usize = lines
            .next()
            .context("empty template file")?
            .parse()
            .context("first line must be the vertex count")?;
        let mut edges = Vec::new();
        for l in lines {
            let mut it = l.split_whitespace();
            let u: u32 = it.next().context("missing u")?.parse()?;
            let v: u32 = it.next().context("missing v")?.parse()?;
            edges.push((u, v));
        }
        Template::from_edges(name, n, &edges)
    }
}

/// The builtin template library (paper Fig. 5). Names match the paper.
pub fn builtin(name: &str) -> Result<Template> {
    let (n, edges): (usize, Vec<(u32, u32)>) = match name {
        // path on 3 vertices
        "u3-1" => (3, vec![(0, 1), (1, 2)]),
        // "chair": root-child chain with a fork
        "u5-2" => (5, vec![(0, 1), (1, 2), (1, 3), (3, 4)]),
        // balanced binary tree of depth 2
        "u7-2" => (7, vec![(0, 1), (0, 2), (1, 3), (1, 4), (2, 5), (2, 6)]),
        // two connected hub stars (4 leaves each)
        "u10-2" => (
            10,
            vec![
                (0, 1),
                (0, 2),
                (0, 3),
                (0, 4),
                (0, 5),
                (1, 6),
                (1, 7),
                (1, 8),
                (1, 9),
            ],
        ),
        // u12-1: hub-heavy, unbalanced splits -> low computation intensity
        "u12-1" => (
            12,
            vec![
                (0, 1),
                (0, 2),
                (0, 3),
                (0, 4),
                (0, 5),
                (0, 6),
                (1, 7),
                (7, 8),
                (8, 9),
                (9, 10),
                (10, 11),
            ],
        ),
        // u12-2: balanced binary -> ~2x the intensity of u12-1 (Table 3)
        "u12-2" => (
            12,
            vec![
                (0, 1),
                (0, 2),
                (1, 3),
                (1, 4),
                (2, 5),
                (2, 6),
                (3, 7),
                (3, 8),
                (4, 9),
                (4, 10),
                (5, 11),
            ],
        ),
        // u13: three 2-deep limbs + chains — Table-3 fit:
        // mem 4655 / comp 88244 / intensity 19.0 (paper: 4823/109603/22)
        "u13" => (
            13,
            vec![
                (0, 1),
                (0, 2),
                (0, 3),
                (1, 4),
                (1, 5),
                (2, 6),
                (2, 7),
                (3, 8),
                (3, 9),
                (4, 10),
                (5, 11),
                (6, 12),
            ],
        ),
        // u14: four 3-limbs + tail — Table-3 fit:
        // mem 7190 / comp 244972 / intensity 34.1 (paper: 7371/242515/32)
        "u14" => (
            14,
            vec![
                (0, 1),
                (0, 2),
                (0, 3),
                (0, 4),
                (1, 5),
                (1, 6),
                (2, 7),
                (2, 8),
                (3, 9),
                (3, 10),
                (4, 11),
                (4, 12),
                (5, 13),
            ],
        ),
        // u15-1: limbs 4,4,3,(2-chain) — highest computation complexity:
        // mem 10844 / comp 754600 / intensity 69.6 (paper: 12383/753375/60)
        "u15-1" => (
            15,
            vec![
                (0, 1),
                (0, 2),
                (0, 3),
                (0, 4),
                (1, 5),
                (1, 6),
                (1, 7),
                (2, 8),
                (2, 9),
                (2, 10),
                (3, 11),
                (3, 12),
                (4, 13),
                (13, 14),
            ],
        ),
        // u15-2: deep mixed binary — memory-heavier, lower intensity:
        // mem 17071 / comp 516245 / intensity 30.2 (paper: 15773/617820/39)
        "u15-2" => (
            15,
            vec![
                (0, 1),
                (0, 2),
                (0, 3),
                (1, 4),
                (1, 5),
                (2, 6),
                (2, 7),
                (3, 8),
                (3, 9),
                (4, 10),
                (4, 11),
                (5, 12),
                (5, 13),
                (6, 14),
            ],
        ),
        _ => bail!("unknown builtin template `{name}`"),
    };
    Template::from_edges(name, n, &edges)
}

/// All builtin names in the paper's size order.
pub const BUILTIN_NAMES: [&str; 10] = [
    "u3-1", "u5-2", "u7-2", "u10-2", "u12-1", "u12-2", "u13", "u14", "u15-1", "u15-2",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_are_trees_of_right_size() {
        for (name, want) in [
            ("u3-1", 3),
            ("u5-2", 5),
            ("u7-2", 7),
            ("u10-2", 10),
            ("u12-1", 12),
            ("u12-2", 12),
            ("u13", 13),
            ("u14", 14),
            ("u15-1", 15),
            ("u15-2", 15),
        ] {
            let t = builtin(name).unwrap();
            assert_eq!(t.size(), want, "{name}");
        }
    }

    #[test]
    fn rejects_non_trees() {
        assert!(Template::from_edges("cycle", 3, &[(0, 1), (1, 2), (2, 0)]).is_err());
        assert!(Template::from_edges("forest", 4, &[(0, 1), (2, 3), (1, 2), (0, 3)]).is_err());
        assert!(Template::from_edges("disc", 4, &[(0, 1), (0, 1), (2, 3)]).is_err());
    }

    #[test]
    fn parse_roundtrip() {
        let t = Template::parse("p", "# a path\n4\n0 1\n1 2\n2 3\n").unwrap();
        assert_eq!(t.size(), 4);
        assert_eq!(t.adj[1], vec![0, 2]);
    }

    #[test]
    fn rooted_children_sizes_ordered() {
        let t = builtin("u12-1").unwrap();
        let ch = t.rooted_children();
        // root 0 has 6 children; first child must head the biggest subtree
        assert_eq!(ch[0].len(), 6);
        assert_eq!(ch[0][0], 1); // vertex 1 heads the 6-vertex limb
    }

    #[test]
    fn unknown_builtin_errors() {
        assert!(builtin("u99").is_err());
    }
}
