//! The Table-3 complexity model: per-template memory complexity
//! `Σ_i C(k,|Ti|)`, computation complexity `Σ_i C(k,|Ti|)·C(|Ti|,|Ti'|)`,
//! and computation intensity (their ratio). These quantities drive the
//! Adaptive-Group mode switch and the pipeline overlap predictions
//! (§3.2.2), and `benches/table3.rs` regenerates the paper's Table 3 from
//! them.

use super::partition::{partition_template, PartitionDag};
use super::Template;
use crate::combin::Binomial;

#[derive(Debug, Clone, PartialEq)]
pub struct TemplateComplexity {
    pub name: String,
    pub k: usize,
    /// Σ over distinct non-leaf subtemplates of C(k,|Ti|): the per-vertex
    /// count-table footprint in "slots" (paper Table 3 col 2)
    pub memory: u64,
    /// Σ over distinct non-leaf subtemplates of C(k,|Ti|)·C(|Ti|,|Ti''|)
    /// (paper Table 3 col 3)
    pub computation: u64,
    /// computation / memory (paper Table 3 col 4)
    pub intensity: f64,
}

/// Compute Table-3 complexities from a partition DAG.
pub fn complexity_of_dag(name: &str, k: usize, dag: &PartitionDag, binom: &Binomial) -> TemplateComplexity {
    let mut memory = 0u64;
    let mut computation = 0u64;
    for s in &dag.subs {
        if s.is_leaf() {
            continue;
        }
        let sets = binom.c(k, s.size);
        memory += sets;
        computation += sets * binom.c(s.size, s.active_size(dag));
    }
    TemplateComplexity {
        name: name.to_string(),
        k,
        memory,
        computation,
        intensity: computation as f64 / memory.max(1) as f64,
    }
}

/// Convenience: partition + complexity in one call (k = template size).
pub fn complexity(t: &Template) -> TemplateComplexity {
    let dag = partition_template(t);
    let binom = Binomial::new();
    complexity_of_dag(&t.name, t.size(), &dag, &binom)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::template::builtin;

    fn c(name: &str) -> TemplateComplexity {
        complexity(&builtin(name).unwrap())
    }

    #[test]
    fn intensity_grows_with_template_size() {
        // Table 3's headline trend: intensity rises from ~2 (u3-1) to
        // tens (u15-x)
        let names = ["u3-1", "u5-2", "u7-2", "u10-2", "u12-2", "u13", "u14"];
        let mut prev = 0.0;
        for n in names {
            let x = c(n);
            assert!(
                x.intensity >= prev,
                "{n}: intensity {} dropped below {prev}",
                x.intensity
            );
            prev = x.intensity;
        }
        assert!(c("u3-1").intensity >= 1.5 && c("u3-1").intensity <= 3.0);
        assert!(c("u15-1").intensity > 20.0, "u15-1 must be compute-heavy");
    }

    #[test]
    fn u12_2_twice_the_intensity_of_u12_1() {
        // the paper's key same-size contrast: 12 vs 6
        let i1 = c("u12-1").intensity;
        let i2 = c("u12-2").intensity;
        assert!(
            i2 > 1.6 * i1,
            "u12-2 intensity {i2} should be ~2x u12-1's {i1}"
        );
    }

    #[test]
    fn u15_1_more_intense_than_u15_2() {
        assert!(c("u15-1").intensity > c("u15-2").intensity);
    }

    #[test]
    fn memory_complexity_monotone_enough() {
        // memory complexity grows strongly with k (Table 3 col 2)
        assert!(c("u5-2").memory > c("u3-1").memory);
        assert!(c("u12-2").memory > c("u7-2").memory);
        assert!(c("u15-2").memory > c("u12-2").memory);
    }
}
