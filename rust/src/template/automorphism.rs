//! Automorphism counting for tree templates.
//!
//! The color-coding DP counts colorful *injective homomorphisms* of the
//! rooted template summed over all root images; each non-induced subgraph
//! embedding is hit exactly `aut(T)` times, so the final estimate divides
//! by the automorphism count of the (unrooted) template. We compute it by
//! rooting at the tree's centroid(s) and multiplying factorials of
//! identical-child multiplicities (AHU), handling the bicentroid case.

use super::Template;

/// Number of automorphisms of the rooted tree at `v` (children unordered),
/// together with its AHU canonical string.
fn rooted_aut(t: &Template, v: u32, parent: u32) -> (u64, String) {
    let mut children: Vec<(String, u64)> = t.adj[v as usize]
        .iter()
        .filter(|&&u| u != parent)
        .map(|&u| {
            let (a, c) = rooted_aut(t, u, v);
            (c, a)
        })
        .collect();
    children.sort();
    let mut aut = 1u64;
    let mut i = 0;
    while i < children.len() {
        let mut j = i;
        while j < children.len() && children[j].0 == children[i].0 {
            j += 1;
        }
        let m = (j - i) as u64;
        // m! for interchangeable identical subtrees, times each child's own
        aut *= factorial(m);
        for item in &children[i..j] {
            aut *= item.1;
        }
        i = j;
    }
    let canon = format!(
        "({})",
        children.iter().map(|(c, _)| c.as_str()).collect::<String>()
    );
    (aut, canon)
}

fn factorial(n: u64) -> u64 {
    (1..=n).product::<u64>().max(1)
}

/// Centroid(s) of the tree: one or two vertices minimizing the max
/// component size after removal.
fn centroids(t: &Template) -> Vec<u32> {
    let n = t.size();
    if n == 1 {
        return vec![0];
    }
    // iterative subtree sizes rooted at 0
    let children = t.rooted_children();
    let mut order = Vec::with_capacity(n);
    let mut stack = vec![0u32];
    while let Some(v) = stack.pop() {
        order.push(v);
        for &c in &children[v as usize] {
            stack.push(c);
        }
    }
    let mut size = vec![1usize; n];
    for &v in order.iter().rev() {
        for &c in &children[v as usize] {
            size[v as usize] += size[c as usize];
        }
    }
    let mut best = usize::MAX;
    let mut out = Vec::new();
    for v in 0..n as u32 {
        let mut worst = n - size[v as usize]; // component through the parent
        for &c in &children[v as usize] {
            worst = worst.max(size[c as usize]);
        }
        if worst < best {
            best = worst;
            out = vec![v];
        } else if worst == best {
            out.push(v);
        }
    }
    out
}

/// Number of automorphisms of the unrooted tree `t`.
pub fn automorphism_count(t: &Template) -> u64 {
    let cs = centroids(t);
    match cs.as_slice() {
        [c] => rooted_aut(t, *c, u32::MAX).0,
        [c1, c2] => {
            let (a1, s1) = rooted_aut(t, *c1, *c2);
            let (a2, s2) = rooted_aut(t, *c2, *c1);
            // the centroid edge can flip iff the two halves are isomorphic
            a1 * a2 * if s1 == s2 { 2 } else { 1 }
        }
        _ => unreachable!("a tree has 1 or 2 centroids"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::template::{builtin, Template};

    /// Brute-force count of adjacency-preserving vertex permutations.
    fn brute_aut(t: &Template) -> u64 {
        let n = t.size();
        let mut adj = vec![vec![false; n]; n];
        for v in 0..n {
            for &u in &t.adj[v] {
                adj[v][u as usize] = true;
            }
        }
        let mut perm: Vec<usize> = (0..n).collect();
        let mut count = 0u64;
        // Heap's algorithm over all permutations (n <= 8 in tests)
        fn heap(
            k: usize,
            perm: &mut Vec<usize>,
            adj: &Vec<Vec<bool>>,
            count: &mut u64,
        ) {
            if k == 1 {
                let n = perm.len();
                let ok = (0..n).all(|i| (0..n).all(|j| adj[i][j] == adj[perm[i]][perm[j]]));
                if ok {
                    *count += 1;
                }
                return;
            }
            for i in 0..k {
                heap(k - 1, perm, adj, count);
                if k % 2 == 0 {
                    perm.swap(i, k - 1);
                } else {
                    perm.swap(0, k - 1);
                }
            }
        }
        heap(n, &mut perm, &adj, &mut count);
        count
    }

    #[test]
    fn known_small_trees() {
        // path3: swap the two ends -> 2
        assert_eq!(automorphism_count(&builtin("u3-1").unwrap()), 2);
        // star on 5 vertices: 4! = 24
        let star = Template::from_edges("s5", 5, &[(0, 1), (0, 2), (0, 3), (0, 4)]).unwrap();
        assert_eq!(automorphism_count(&star), 24);
        // path4 (bicentroid, symmetric halves): 2
        let p4 = Template::from_edges("p4", 4, &[(0, 1), (1, 2), (2, 3)]).unwrap();
        assert_eq!(automorphism_count(&p4), 2);
        // single edge: 2
        let p2 = Template::from_edges("p2", 2, &[(0, 1)]).unwrap();
        assert_eq!(automorphism_count(&p2), 2);
        // single vertex: 1
        let p1 = Template::from_edges("p1", 1, &[]).unwrap();
        assert_eq!(automorphism_count(&p1), 1);
    }

    #[test]
    fn matches_brute_force_on_all_small_trees() {
        // every tree shape on 2..=7 vertices via random Prüfer-ish sampling
        // plus the small builtins
        for name in ["u3-1", "u5-2", "u7-2"] {
            let t = builtin(name).unwrap();
            assert_eq!(
                automorphism_count(&t),
                brute_aut(&t),
                "mismatch for {name}"
            );
        }
        // asymmetric chair with tail
        let t = Template::from_edges("y", 6, &[(0, 1), (1, 2), (1, 3), (3, 4), (4, 5)]).unwrap();
        assert_eq!(automorphism_count(&t), brute_aut(&t));
        // double star (bicentroid, symmetric): aut = 2 * (2!)^2 = 8
        let t = Template::from_edges("dbl", 6, &[(0, 1), (0, 2), (0, 3), (3, 4), (3, 5)]).unwrap();
        assert_eq!(automorphism_count(&t), brute_aut(&t));
        assert_eq!(automorphism_count(&t), 8);
    }

    #[test]
    fn big_builtins_nonzero() {
        for name in crate::template::BUILTIN_NAMES {
            let t = builtin(name).unwrap();
            assert!(automorphism_count(&t) >= 1, "{name}");
        }
        // perfect binary tree on 15: each of the 7 internal nodes can swap
        // its two identical children -> 2^7 = 128
        let pb15 = Template::from_edges(
            "pb15",
            15,
            &[
                (0, 1),
                (0, 2),
                (1, 3),
                (1, 4),
                (2, 5),
                (2, 6),
                (3, 7),
                (3, 8),
                (4, 9),
                (4, 10),
                (5, 11),
                (5, 12),
                (6, 13),
                (6, 14),
            ],
        )
        .unwrap();
        assert_eq!(automorphism_count(&pb15), 128);
        // u15-1 (two identical 3-star limbs, a 2-star limb, a chain limb):
        // 2! · (3!)² · 2! = 144
        assert_eq!(automorphism_count(&builtin("u15-1").unwrap()), 144);
    }
}
