//! The color-coding DP engine.
//!
//! The combine step (Eq 1) is implemented in its factored form
//!
//! ```text
//! out[v,s] = Σ_j  passive[v, t0[s,j]] · agg[v, t1[s,j]],
//! agg[v,·] = Σ_{u ∈ N(v)} active[u,·]
//! ```
//!
//! where the neighbor aggregation distributes over the split sum. This is
//! both the performance core of the Rust engine and the exact computation
//! that the L1 Pallas kernel / L2 JAX graph implement (SpMM + gathered
//! contraction) — see DESIGN.md §2.
//!
//! Crucially, the contraction is *linear in agg*: accumulating
//! `Σ_j passive·agg_w` per communication step `w` over partial neighbor
//! sets sums to the full result. The distributed coordinator leans on this
//! to interleave per-step computation with communication (Alg 3).

use super::frontier::{Frontier, PruneMode};
use super::kernel::KernelMode;
use super::parallel::{combine_batches_pruned, combine_batches_with, ExecStats, PairBatch};
use super::storage::RowsRef;
use super::table::{init_leaf_table, Coloring, Count, CountTable};
use crate::combin::{Binomial, CheckedSplit, SplitTable};
use crate::graph::Graph;
use crate::template::{automorphism_count, partition_template, PartitionDag, Template};

/// Immutable per-template compute context shared by every engine flavor
/// (single-rank, distributed ranks, XLA-backed).
#[derive(Debug)]
pub struct EngineContext {
    pub k: usize,
    pub binom: Binomial,
    pub dag: PartitionDag,
    /// split table per subtemplate index (None for leaves)
    pub splits: Vec<Option<SplitTable>>,
    pub aut: u64,
    pub template_name: String,
}

impl EngineContext {
    pub fn new(t: &Template) -> Self {
        let k = t.size();
        let binom = Binomial::new();
        let dag = partition_template(t);
        let splits = dag
            .subs
            .iter()
            .map(|s| {
                if s.is_leaf() {
                    None
                } else {
                    Some(SplitTable::new(k, s.size, s.passive_size(&dag), &binom))
                }
            })
            .collect();
        EngineContext {
            k,
            binom,
            dag,
            splits,
            aut: automorphism_count(t),
            template_name: t.name.clone(),
        }
    }

    /// Columns of the count table for subtemplate `i`: C(k, |Ti|).
    pub fn n_sets(&self, i: usize) -> usize {
        self.binom.c(self.k, self.dag.subs[i].size) as usize
    }

    /// The scale factor k^k / k! of Alg 1 line 12 (as f64; k ≤ 16).
    pub fn colorful_scale(&self) -> f64 {
        let k = self.k as f64;
        let mut s = 1.0f64;
        for i in 1..=self.k {
            s *= k / i as f64;
        }
        s
    }
}

/// Scratch space for one combine: a per-vertex aggregation buffer reused
/// across steps, plus the touched-row set for sparse clearing.
pub struct CombineScratch {
    agg: Vec<Count>,
    touched: Vec<u32>,
    touched_flag: Vec<bool>,
    n_agg_sets: usize,
}

impl CombineScratch {
    pub fn new(n_rows: usize, max_agg_sets: usize) -> Self {
        CombineScratch {
            agg: vec![0.0; n_rows * max_agg_sets],
            touched: Vec::new(),
            touched_flag: vec![false; n_rows],
            n_agg_sets: 0,
        }
    }

    pub fn begin(&mut self, n_agg_sets: usize) {
        self.n_agg_sets = n_agg_sets;
        debug_assert!(self.touched.is_empty());
    }

    #[inline]
    fn agg_row_mut(&mut self, r: usize) -> &mut [Count] {
        let lo = r * self.n_agg_sets;
        &mut self.agg[lo..lo + self.n_agg_sets]
    }

    /// Bytes of the aggregation buffer (peak-memory accounting).
    pub fn bytes(&self) -> u64 {
        (self.agg.len() * std::mem::size_of::<Count>()) as u64
    }

    /// Number of rows touched since `begin`.
    pub fn touched_len(&self) -> usize {
        self.touched.len()
    }

    /// The touched row indices (unordered).
    pub fn touched_rows(&self) -> &[u32] {
        &self.touched
    }

    /// Current aggregation-row width.
    pub fn n_agg_sets(&self) -> usize {
        self.n_agg_sets
    }

    /// Read an aggregation row.
    pub fn agg_row(&self, r: usize) -> &[Count] {
        let lo = r * self.n_agg_sets;
        &self.agg[lo..lo + self.n_agg_sets]
    }

    /// Clear the touched set (external combine backends call this after
    /// consuming the aggregation rows; `contract_touched` does it itself).
    pub fn finish(&mut self) {
        for &v in &self.touched {
            self.touched_flag[v as usize] = false;
        }
        self.touched.clear();
    }
}

/// Accumulate one batch of active-child rows into the aggregation buffer:
/// `agg[v,·] += active_row(u)` for every (v, u) adjacency pair in `pairs`.
///
/// `pairs` yields `(local_row_of_v, row_index_of_u_in_rows)`; `rows` is the
/// active-child row source the u-rows live in (a local table or a received
/// step buffer, dense or sparse — see `super::storage`; sparse sources add
/// only their stored entries, which is bit-identical). Returns the number
/// of pairs processed.
pub fn aggregate_batch(
    scratch: &mut CombineScratch,
    rows: RowsRef<'_>,
    pairs: impl Iterator<Item = (u32, u32)>,
) -> u64 {
    let n_sets = rows.n_sets();
    debug_assert_eq!(n_sets, scratch.n_agg_sets);
    let mut n = 0u64;
    for (v, u) in pairs {
        let v = v as usize;
        if !scratch.touched_flag[v] {
            scratch.touched_flag[v] = true;
            scratch.touched.push(v as u32);
            scratch.agg_row_mut(v).fill(0.0);
        }
        rows.add_row_into(u as usize, scratch.agg_row_mut(v));
        n += 1;
    }
    n
}

/// Contract one vertex row through the split table:
/// `orow[s] += Σ_j prow[idx1[s,j]] · arow[idx2[s,j]]`. This is the inner
/// scalar kernel shared by the serial [`contract_touched`] and the
/// parallel executor ([`super::parallel`]) so both paths run
/// bit-identical arithmetic — and the differential baseline the SIMD
/// kernel ([`super::kernel`]) is measured against. Returns the
/// (set, split) units processed for this row.
///
/// The unchecked gathers are justified by the [`CheckedSplit`] operand:
/// its construction validated every `idx1`/`idx2` entry against the
/// passive/aggregation widths, and the row-length equalities are
/// asserted here (three compares per row, amortized over the
/// `n_sets · n_splits` element ops).
#[inline]
pub(crate) fn contract_row(
    orow: &mut [Count],
    prow: &[Count],
    arow: &[Count],
    cs: &CheckedSplit<'_>,
) -> u64 {
    let split = cs.split();
    let n_splits = split.n_splits;
    let n_sets = split.n_sets;
    assert_eq!(prow.len(), cs.n_passive(), "passive row width");
    assert_eq!(arow.len(), cs.n_agg(), "aggregation row width");
    assert_eq!(orow.len(), n_sets, "output row width");
    let idx1 = &split.idx1[..n_sets * n_splits];
    let idx2 = &split.idx2[..n_sets * n_splits];
    let mut flat = 0usize;
    for o in orow.iter_mut() {
        // two accumulators break the FMA dependency chain over the
        // (short, 2–70 long) split run — measured win in §Perf
        let mut acc0 = 0.0f32;
        let mut acc1 = 0.0f32;
        let mut j = 0;
        // SAFETY: flat+j < n_sets*n_splits by loop structure; the
        // gathered prow/arow indices are in range because `cs` validated
        // every split-table entry against exactly the row widths
        // asserted above (CheckedSplit::new).
        unsafe {
            while j + 2 <= n_splits {
                let p0 = *prow.get_unchecked(*idx1.get_unchecked(flat + j) as usize);
                let a0 = *arow.get_unchecked(*idx2.get_unchecked(flat + j) as usize);
                let p1 = *prow.get_unchecked(*idx1.get_unchecked(flat + j + 1) as usize);
                let a1 = *arow.get_unchecked(*idx2.get_unchecked(flat + j + 1) as usize);
                acc0 += p0 * a0;
                acc1 += p1 * a1;
                j += 2;
            }
            if j < n_splits {
                let p = *prow.get_unchecked(*idx1.get_unchecked(flat + j) as usize);
                let a = *arow.get_unchecked(*idx2.get_unchecked(flat + j) as usize);
                acc0 += p * a;
            }
        }
        flat += n_splits;
        *o += acc0 + acc1;
    }
    (n_sets * n_splits) as u64
}

/// Contract the touched aggregation rows into `out` through the split
/// table: `out[v,s] += Σ_j passive[v,t0[s,j]] · agg[v,t1[s,j]]`, then
/// clear the touched set (ready for the next step). Returns the number of
/// (vertex, set, split) units processed — the Eq-4 computation measure.
pub fn contract_touched(
    out: &mut CountTable,
    passive: &CountTable,
    split: &SplitTable,
    scratch: &mut CombineScratch,
) -> u64 {
    contract_touched_pruned(out, passive, split, scratch, None).0
}

/// [`contract_touched`] with the frontier layer: touched vertices whose
/// passive row sits outside `frontier` (i.e. is all-zero) are skipped —
/// every contraction term would be `0.0 · x` with `x` a finite
/// non-negative count, an exact `+0.0` add, so the output bits cannot
/// change (see `super::frontier`). Returns (units, rows skipped).
pub fn contract_touched_pruned(
    out: &mut CountTable,
    passive: &CountTable,
    split: &SplitTable,
    scratch: &mut CombineScratch,
    frontier: Option<&Frontier>,
) -> (u64, u64) {
    let mut units = 0u64;
    let mut skipped = 0u64;
    // one checked construction per combine: validates every idx1/idx2
    // entry against the operand widths, so the per-element gathers in
    // `contract_row` run unchecked (bounds checks on these 10⁷+
    // L1-resident gathers are the measured hot-path cost,
    // EXPERIMENTS.md §Perf)
    let cs = CheckedSplit::new(split, passive.n_sets, scratch.n_agg_sets);
    for ti in 0..scratch.touched.len() {
        let v = scratch.touched[ti] as usize;
        if let Some(f) = frontier {
            if !f.contains(v) {
                skipped += 1;
                continue;
            }
        }
        let prow = passive.row(v);
        let lo = v * scratch.n_agg_sets;
        let arow = &scratch.agg[lo..lo + scratch.n_agg_sets];
        let orow = out.row_mut(v);
        units += contract_row(orow, prow, arow, &cs);
    }
    scratch.finish();
    (units, skipped)
}

/// Single-rank reference engine: computes the colorful count of one
/// coloring iteration over the whole graph.
pub struct Engine {
    pub ctx: EngineContext,
}

/// What the frontier layer elided during one iteration (summed over the
/// DAG's combines): adjacency pairs dropped because the active row was
/// outside its table's frontier, and contractions skipped because the
/// passive row was. Both elisions are bit-exact — see `super::frontier`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PruneTally {
    pub pairs_skipped: u64,
    pub rows_skipped: u64,
}

impl PruneTally {
    pub fn add(&mut self, other: PruneTally) {
        self.pairs_skipped += other.pairs_skipped;
        self.rows_skipped += other.rows_skipped;
    }
}

/// Result of one coloring iteration.
#[derive(Debug, Clone, Copy)]
pub struct IterationOutput {
    /// Σ_v C(v, T(ρ), S) — raw colorful count (before scaling)
    pub colorful: f64,
    /// the unbiased estimate contribution: colorful · k^k/k! / aut
    pub estimate: f64,
}

impl Engine {
    pub fn new(t: &Template) -> Self {
        Engine {
            ctx: EngineContext::new(t),
        }
    }

    /// One DAG walk shared by every engine flavor: leaf init, one
    /// `combine(out, active, passive, split)` call per non-leaf
    /// subtemplate, last-use table freeing, and the root total. The
    /// combine closure is the only thing that differs between the serial
    /// and parallel paths, so their surrounding plumbing cannot diverge.
    fn run_iteration_with(
        &self,
        g: &Graph,
        iter_seed: u64,
        mut combine: impl FnMut(&mut CountTable, &CountTable, &CountTable, &SplitTable),
    ) -> IterationOutput {
        let n = g.n_vertices();
        let vertices: Vec<u32> = (0..n as u32).collect();
        let coloring = Coloring::random(n, self.ctx.k, iter_seed);
        let mut tables: Vec<Option<CountTable>> = vec![None; self.ctx.dag.subs.len()];
        let last_use = self.ctx.dag.last_use();

        for (step, &i) in self.ctx.dag.order.iter().enumerate() {
            let sub = &self.ctx.dag.subs[i];
            if sub.is_leaf() {
                tables[i] = Some(init_leaf_table(&vertices, &coloring));
            } else {
                let split = self.ctx.splits[i].as_ref().unwrap();
                let mut out = CountTable::zeros(n, split.n_sets);
                {
                    let active = tables[sub.active.unwrap()].as_ref().unwrap();
                    let passive = tables[sub.passive.unwrap()].as_ref().unwrap();
                    combine(&mut out, active, passive, split);
                }
                tables[i] = Some(out);
            }
            // free tables whose last reader has run (intermediate-data
            // reduction; the distributed engine additionally slices)
            for (j, lu) in last_use.iter().enumerate() {
                if *lu == step && j != self.ctx.dag.root {
                    tables[j] = None;
                }
            }
        }

        let colorful = tables[self.ctx.dag.root].as_ref().unwrap().total();
        IterationOutput {
            colorful,
            estimate: colorful * self.ctx.colorful_scale() / self.ctx.aut as f64,
        }
    }

    /// Run the DP bottom-up for one coloring and return the counts.
    pub fn run_iteration(&self, g: &Graph, iter_seed: u64) -> IterationOutput {
        let n = g.n_vertices();
        let max_agg = self
            .ctx
            .dag
            .subs
            .iter()
            .filter(|s| !s.is_leaf())
            .map(|s| self.ctx.binom.c(self.ctx.k, s.active_size(&self.ctx.dag)) as usize)
            .max()
            .unwrap_or(1);
        let mut scratch = CombineScratch::new(n, max_agg);
        self.run_iteration_with(g, iter_seed, |out, active, passive, split| {
            scratch.begin(active.n_sets);
            let pairs = (0..n as u32).flat_map(|v| g.neighbors(v).iter().map(move |&u| (v, u)));
            aggregate_batch(&mut scratch, RowsRef::dense(active), pairs);
            contract_touched(out, passive, split, &mut scratch);
        })
    }

    /// [`Engine::run_iteration`] with the frontier layer: per combine,
    /// adjacency pairs whose active row is outside the active table's
    /// frontier are dropped before aggregation, and touched vertices with
    /// an all-zero passive row skip their contraction. `prune` arbitrates
    /// per table from the frontier occupancy (`Off` elides nothing and is
    /// the exact baseline; `On` always prunes; `Auto` prunes sparse
    /// frontiers only). The counts are **bit-identical** to the unpruned
    /// run for every mode — every elided float op is an exact `+0.0` add.
    pub fn run_iteration_pruned(
        &self,
        g: &Graph,
        iter_seed: u64,
        prune: PruneMode,
    ) -> (IterationOutput, PruneTally) {
        let n = g.n_vertices();
        let max_agg = self
            .ctx
            .dag
            .subs
            .iter()
            .filter(|s| !s.is_leaf())
            .map(|s| self.ctx.binom.c(self.ctx.k, s.active_size(&self.ctx.dag)) as usize)
            .max()
            .unwrap_or(1);
        let mut scratch = CombineScratch::new(n, max_agg);
        let mut tally = PruneTally::default();
        let out = self.run_iteration_with(g, iter_seed, |out, active, passive, split| {
            scratch.begin(active.n_sets);
            let af = active.frontier();
            let active_on = prune.active_for(af.occupancy());
            let mut skipped = 0u64;
            let pairs = (0..n as u32)
                .flat_map(|v| g.neighbors(v).iter().map(move |&u| (v, u)))
                .filter(|&(_, u)| {
                    if !active_on || af.contains(u as usize) {
                        true
                    } else {
                        skipped += 1;
                        false
                    }
                });
            aggregate_batch(&mut scratch, RowsRef::dense(active), pairs);
            tally.pairs_skipped += skipped;
            let pf = passive.frontier();
            let pfr = prune.active_for(pf.occupancy()).then_some(&pf);
            let (_, rows) = contract_touched_pruned(out, passive, split, &mut scratch, pfr);
            tally.rows_skipped += rows;
        });
        (out, tally)
    }

    /// Run one coloring iteration on the real multithreaded combine
    /// executor: every non-leaf combine consumes the Alg-4 task queue
    /// (built at `max_task_size` granularity; `0` = per-vertex tasks)
    /// with `n_workers` OS threads.
    ///
    /// Determinism contract (see [`super::parallel`]): the returned counts
    /// depend on `max_task_size` but **not** on `n_workers`, and with
    /// `max_task_size == 0` they are bit-identical to
    /// [`Engine::run_iteration`]. The second return value is the measured
    /// per-worker execution record of the whole iteration.
    pub fn run_iteration_workers(
        &self,
        g: &Graph,
        iter_seed: u64,
        n_workers: usize,
        max_task_size: u32,
    ) -> (IterationOutput, ExecStats) {
        self.run_iteration_workers_kernel(g, iter_seed, n_workers, max_task_size, KernelMode::Scalar)
    }

    /// [`Engine::run_iteration_workers`] with an explicit combine-kernel
    /// choice (the `--kernel` knob): `Scalar` is the historical executor,
    /// `Simd` runs the fused row-block SpMM/eMA kernel
    /// ([`super::kernel`]), `Auto` resolves per combine from the shape.
    /// The SIMD path ignores `max_task_size` (it shards by adjacency
    /// row-blocks, never splitting a vertex) and is bit-identical for
    /// every worker count.
    pub fn run_iteration_workers_kernel(
        &self,
        g: &Graph,
        iter_seed: u64,
        n_workers: usize,
        max_task_size: u32,
        kernel: KernelMode,
    ) -> (IterationOutput, ExecStats) {
        // the flat (v, u) adjacency pair list every combine consumes,
        // grouped by v in CSR order — the same pair order the serial
        // engine's iterator produces
        let pairs: Vec<(u32, u32)> = (0..g.n_vertices() as u32)
            .flat_map(|v| g.neighbors(v).iter().map(move |&u| (v, u)))
            .collect();
        let mut stats = ExecStats::zeros(n_workers);
        let out = self.run_iteration_with(g, iter_seed, |out, active, passive, split| {
            let batch = [PairBatch {
                pairs: &pairs,
                rows: RowsRef::dense(active),
            }];
            let st = combine_batches_with(
                out,
                RowsRef::dense(passive),
                split,
                &batch,
                max_task_size,
                n_workers,
                kernel,
            );
            stats.merge(&st);
        });
        (out, stats)
    }

    /// [`Engine::run_iteration_workers_kernel`] with the frontier layer
    /// (the single-rank analogue of the distributed pruned combine): per
    /// combine, the pair list is filtered by the active table's frontier
    /// before the task queue is built — so the Alg-4 tasks are sized by
    /// *frontier-effective* degrees — and the passive frontier rides into
    /// [`combine_batches_pruned`]. `cost_model`, when given, consumes the
    /// task queue in LPT order. Counts are bit-identical to the unpruned
    /// run for every mode, worker count and kernel.
    #[allow(clippy::too_many_arguments)]
    pub fn run_iteration_workers_pruned(
        &self,
        g: &Graph,
        iter_seed: u64,
        n_workers: usize,
        max_task_size: u32,
        kernel: KernelMode,
        prune: PruneMode,
        cost_model: Option<&crate::sched::TaskCostModel>,
    ) -> (IterationOutput, ExecStats, PruneTally) {
        let pairs: Vec<(u32, u32)> = (0..g.n_vertices() as u32)
            .flat_map(|v| g.neighbors(v).iter().map(move |&u| (v, u)))
            .collect();
        let mut stats = ExecStats::zeros(n_workers);
        let mut tally = PruneTally::default();
        let out = self.run_iteration_with(g, iter_seed, |out, active, passive, split| {
            let af = active.frontier();
            let kept: Vec<(u32, u32)>;
            let plist: &[(u32, u32)] = if prune.active_for(af.occupancy()) {
                kept = pairs
                    .iter()
                    .copied()
                    .filter(|&(_, u)| af.contains(u as usize))
                    .collect();
                tally.pairs_skipped += (pairs.len() - kept.len()) as u64;
                &kept
            } else {
                &pairs
            };
            let pf = passive.frontier();
            let pfr = prune.active_for(pf.occupancy()).then_some(&pf);
            let batch = [PairBatch {
                pairs: plist,
                rows: RowsRef::dense(active),
            }];
            let st = combine_batches_pruned(
                out,
                RowsRef::dense(passive),
                split,
                &batch,
                max_task_size,
                n_workers,
                kernel,
                pfr,
                cost_model,
            );
            tally.rows_skipped += st.rows_skipped;
            stats.merge(&st);
        });
        (out, stats, tally)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::graph_from_edges;
    use crate::template::builtin;

    #[test]
    fn triangle_path3_colorful_math() {
        // On a triangle with an all-distinct coloring, Σ_v C(v,P3,S) = 6
        // injective homs. Find a seed giving 3 distinct colors.
        let g = graph_from_edges(3, &[(0, 1), (1, 2), (2, 0)]);
        let t = builtin("u3-1").unwrap();
        let e = Engine::new(&t);
        let mut seed = 0u64;
        loop {
            let c = Coloring::random(3, 3, seed);
            let mut set = [false; 3];
            for &x in &c.colors {
                set[x as usize] = true;
            }
            if set.iter().all(|&b| b) {
                break;
            }
            seed += 1;
        }
        let out = e.run_iteration(&g, seed);
        assert_eq!(out.colorful, 6.0);
        // estimate = 6 * 27/6 / 2 = 13.5
        assert!((out.estimate - 13.5).abs() < 1e-9);
    }

    #[test]
    fn non_colorful_iteration_gives_zero() {
        let g = graph_from_edges(3, &[(0, 1), (1, 2), (2, 0)]);
        let t = builtin("u3-1").unwrap();
        let e = Engine::new(&t);
        // find a seed where at least two path-adjacent vertices share color
        let mut seed = 0u64;
        loop {
            let c = Coloring::random(3, 3, seed);
            if c.colors[0] == c.colors[1] && c.colors[1] == c.colors[2] {
                break;
            }
            seed += 1;
        }
        let out = e.run_iteration(&g, seed);
        assert_eq!(out.colorful, 0.0);
    }

    #[test]
    fn colorful_scale_value() {
        let t = builtin("u3-1").unwrap();
        let e = Engine::new(&t);
        assert!((e.ctx.colorful_scale() - 27.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn aggregate_then_contract_matches_naive() {
        // randomized check of the factored combine vs the direct
        // per-(u, split) double loop
        use crate::util::prop;
        prop::check("combine_factored", |gen| {
            let k = gen.usize_in(3, 6);
            let a = gen.usize_in(2, k);
            let a1 = gen.usize_in(1, a - 1);
            let binom = Binomial::new();
            let split = SplitTable::new(k, a, a1, &binom);
            let n = gen.usize_in(2, 8);
            let c1 = binom.c(k, a1) as usize;
            let c2 = binom.c(k, a - a1) as usize;
            let mut passive = CountTable::zeros(n, c1);
            let mut active = CountTable::zeros(n, c2);
            for x in passive.data.iter_mut() {
                *x = gen.usize_in(0, 3) as f32;
            }
            for x in active.data.iter_mut() {
                *x = gen.usize_in(0, 3) as f32;
            }
            // random adjacency pairs
            let n_pairs = gen.usize_in(0, 20);
            let pairs: Vec<(u32, u32)> = (0..n_pairs)
                .map(|_| (gen.usize_in(0, n - 1) as u32, gen.usize_in(0, n - 1) as u32))
                .collect();
            // factored path
            let mut out = CountTable::zeros(n, split.n_sets);
            let mut scratch = CombineScratch::new(n, c2);
            scratch.begin(c2);
            aggregate_batch(&mut scratch, RowsRef::dense(&active), pairs.iter().copied());
            contract_touched(&mut out, &passive, &split, &mut scratch);
            // naive path
            let mut naive = CountTable::zeros(n, split.n_sets);
            for &(v, u) in &pairs {
                for s in 0..split.n_sets {
                    let (r1, r2) = split.row(s);
                    let mut acc = 0.0f32;
                    for j in 0..split.n_splits {
                        acc += passive.row(v as usize)[r1[j] as usize]
                            * active.row(u as usize)[r2[j] as usize];
                    }
                    naive.row_mut(v as usize)[s] += acc;
                }
            }
            for (x, y) in out.data.iter().zip(&naive.data) {
                if (x - y).abs() > 1e-3 {
                    return Err(format!("mismatch {x} vs {y}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_iteration_bit_identical_to_serial() {
        // the executor at per-vertex granularity must reproduce the
        // serial engine exactly, for any worker count
        let g = crate::graph::rmat::generate(&crate::graph::rmat::RmatParams::with_skew(
            48, 200, 3, 7,
        ));
        for tpl in ["u3-1", "u5-2"] {
            let t = builtin(tpl).unwrap();
            let e = Engine::new(&t);
            let serial = e.run_iteration(&g, 11);
            for workers in [1, 2, 4] {
                let (par, stats) = e.run_iteration_workers(&g, 11, workers, 0);
                assert_eq!(
                    serial.colorful.to_bits(),
                    par.colorful.to_bits(),
                    "{tpl} workers={workers}"
                );
                assert_eq!(
                    serial.estimate.to_bits(),
                    par.estimate.to_bits(),
                    "{tpl} workers={workers}"
                );
                assert_eq!(stats.n_workers(), workers);
                assert!(stats.n_pairs > 0);
            }
        }
    }

    /// Differential leg of the frontier layer at the engine level: a
    /// connected blob plus an isolated edge. The 2-vertex component can
    /// host no rooted embedding of size ≥ 3, so whichever side of the
    /// u5-2 root combine has size ≥ 3 is guaranteed all-zero rows there —
    /// pruning must elide *something*, and must elide it bit-exactly.
    #[test]
    fn pruned_iterations_are_bit_identical_to_baseline() {
        use crate::colorcount::frontier::PruneMode;
        let mut edges = vec![(8u32, 9u32)];
        for v in 0..8u32 {
            for u in (v + 1)..8 {
                if (v + u) % 2 == 1 {
                    edges.push((v, u));
                }
            }
        }
        let g = graph_from_edges(10, &edges);
        let t = builtin("u5-2").unwrap();
        let e = Engine::new(&t);
        let mut elided = 0u64;
        for seed in [3u64, 11, 19] {
            let base = e.run_iteration(&g, seed);
            let mut on_tally = PruneTally::default();
            for prune in [PruneMode::Off, PruneMode::On, PruneMode::Auto] {
                let (out, tally) = e.run_iteration_pruned(&g, seed, prune);
                assert_eq!(
                    out.colorful.to_bits(),
                    base.colorful.to_bits(),
                    "{prune:?} seed={seed}"
                );
                assert_eq!(out.estimate.to_bits(), base.estimate.to_bits());
                match prune {
                    PruneMode::Off => {
                        assert_eq!(tally, PruneTally::default(), "off must elide nothing")
                    }
                    PruneMode::On => {
                        elided += tally.pairs_skipped + tally.rows_skipped;
                        on_tally = tally;
                    }
                    PruneMode::Auto => {}
                }
            }
            // executor path: every kernel, worker count and the LPT
            // scheduler reproduce the serial baseline bit for bit (counts
            // are integer-valued, so even the SIMD lane tree is exact),
            // and the elision tallies agree with the serial pruned run
            let model = crate::sched::TaskCostModel {
                unit_per_pair: 1.0,
                unit_per_task: 1.0,
                overhead: 0.1,
            };
            for workers in [1, 4] {
                for kernel in [KernelMode::Scalar, KernelMode::Simd] {
                    let (out, st, tally) = e.run_iteration_workers_pruned(
                        &g,
                        seed,
                        workers,
                        0,
                        kernel,
                        PruneMode::On,
                        Some(&model),
                    );
                    assert_eq!(
                        out.colorful.to_bits(),
                        base.colorful.to_bits(),
                        "{kernel:?} workers={workers} seed={seed}"
                    );
                    assert_eq!(tally, on_tally, "{kernel:?} workers={workers}");
                    assert_eq!(st.rows_skipped, tally.rows_skipped);
                }
            }
        }
        assert!(elided > 0, "the isolated edge must force at least one elision");
    }

    #[test]
    fn batch_split_linearity() {
        // combining pairs in two batches must equal one batch
        let binom = Binomial::new();
        let split = SplitTable::new(4, 3, 1, &binom);
        let c1 = 4;
        let c2 = binom.c(4, 2) as usize;
        let n = 4;
        let mut passive = CountTable::zeros(n, c1);
        let mut active = CountTable::zeros(n, c2);
        for (i, x) in passive.data.iter_mut().enumerate() {
            *x = (i % 3) as f32;
        }
        for (i, x) in active.data.iter_mut().enumerate() {
            *x = ((i * 7) % 5) as f32;
        }
        let pairs = [(0u32, 1u32), (0, 2), (1, 3), (2, 0), (0, 3)];
        let run = |chunks: &[&[(u32, u32)]]| {
            let mut out = CountTable::zeros(n, split.n_sets);
            let mut scratch = CombineScratch::new(n, c2);
            for ch in chunks {
                scratch.begin(c2);
                aggregate_batch(&mut scratch, RowsRef::dense(&active), ch.iter().copied());
                contract_touched(&mut out, &passive, &split, &mut scratch);
            }
            out
        };
        let one = run(&[&pairs]);
        let two = run(&[&pairs[..2], &pairs[2..]]);
        for (x, y) in one.data.iter().zip(&two.data) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }
}
