//! The real shared-memory parallel combine executor over the Alg-4 task
//! queue (paper Fig 11 / Alg 4, executed rather than replayed).
//!
//! [`combine_batches`] consumes the neighbor-pair lists of one combine as
//! a queue of [`crate::sched::Task`]s (built by [`crate::sched::make_tasks`]
//! at `max_task_size` granularity) on a `std::thread::scope` worker pool —
//! no new dependencies, no work survives the call.
//!
//! # Determinism contract
//!
//! The result is **bit-identical for every worker count**, because the
//! floating-point evaluation order is fixed by the *task decomposition*,
//! never by the thread schedule:
//!
//! 1. **Aggregate** — each task's partial aggregation row
//!    `p = Σ active[u]` is accumulated pair-by-pair from zero into a slot
//!    keyed by the task's canonical index. Which worker computes a slot is
//!    scheduling-dependent; the slot's value is not.
//! 2. **Merge + contract** — per vertex, the task partials are folded
//!    left-to-right in canonical `(vertex, batch, start)` order, and the
//!    merged row is contracted through the split table with the exact
//!    kernel the serial engine uses ([`super::engine`]'s `contract_row`).
//!    Vertices are claimed dynamically but write disjoint output rows.
//!
//! Relation to the serial path: with per-vertex tasks
//! (`max_task_size == 0`) every vertex is a single chunk, so the executor
//! is bit-identical to the serial `aggregate_batch` + `contract_touched`
//! pipeline. When a hub's neighbor list *is* split, the chunked left fold
//! legitimately rounds f32 sums differently from the serial running sum
//! (≈1e-7 relative) — but identically for 1, 2, 4, … workers, which is
//! the invariant the differential suite enforces. On integer-valued
//! tables (all DP tables before any f32 rounding occurs) even split
//! vertices are exact, hence bit-identical to serial too.
//!
//! The frontier layer ([`combine_batches_pruned`]) adds two knobs on top
//! without touching the contract: a passive-table frontier that skips
//! contractions whose every term is an exact zero, and a task cost model
//! that claims the queue in LPT order — both provably result-invariant
//! (see the function docs).

use super::engine::contract_row;
use super::frontier::Frontier;
use super::kernel::{contract_row_simd, KernelMode, ResolvedKernel};
use super::storage::{RowScratch, RowsRef};
use super::table::{Count, CountTable};
use crate::combin::{CheckedSplit, SplitTable};
use crate::sched::{lpt_order, make_tasks, Task, TaskCostModel};
use crate::util::shim::AtomicUsize;
use std::time::Instant;

/// One neighbor-pair batch of a combine: `pairs` are `(v_row, u_row)`
/// entries with each vertex's pairs stored contiguously (CSR order), and
/// `rows` is the active-child row source the `u_row` indices point into
/// (a local table, or one received step buffer of the exchange — dense or
/// sparse, see `super::storage`; sparse iteration skips a row's zero
/// entries, which is bit-identical because every aggregation slot sums
/// independently).
pub struct PairBatch<'a> {
    pub pairs: &'a [(u32, u32)],
    pub rows: RowsRef<'a>,
}

/// Measured execution record of one (or, after [`ExecStats::merge`],
/// many) parallel combines: totals plus per-worker busy time and work
/// counters. This is the *real* counterpart of the modeled
/// [`crate::coordinator::ThreadStats`] — wall-clock seconds from
/// `Instant`, not virtual-replay units.
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    /// tasks consumed from the Alg-4 queue
    pub n_tasks: u64,
    /// adjacency pairs aggregated
    pub n_pairs: u64,
    /// (vertex, set, split) contraction units (the Eq-4 measure)
    pub units: u64,
    /// output rows whose contraction was skipped because the passive row
    /// sat outside the frontier (exact zero — see `super::frontier`);
    /// always 0 when pruning is off
    pub rows_skipped: u64,
    /// measured seconds each worker spent in the combine phases
    pub busy_seconds: Vec<f64>,
    /// tasks each worker claimed
    pub worker_tasks: Vec<u64>,
    /// pairs each worker aggregated
    pub worker_pairs: Vec<u64>,
}

impl ExecStats {
    pub fn zeros(n_workers: usize) -> ExecStats {
        ExecStats {
            n_tasks: 0,
            n_pairs: 0,
            units: 0,
            rows_skipped: 0,
            busy_seconds: vec![0.0; n_workers],
            worker_tasks: vec![0; n_workers],
            worker_pairs: vec![0; n_workers],
        }
    }

    /// The worker-pool size this record was measured with.
    pub fn n_workers(&self) -> usize {
        self.busy_seconds.len()
    }

    /// Workers that executed at least one task (the Fig-11 "busy thread"
    /// notion, measured instead of modeled).
    pub fn busy_workers(&self) -> usize {
        self.worker_tasks.iter().filter(|&&t| t > 0).count()
    }

    /// Max/mean busy-time ratio across the pool (1.0 = perfectly
    /// balanced; the measured analogue of the Fig-11 imbalance).
    pub fn imbalance(&self) -> f64 {
        let n = self.busy_seconds.len();
        if n == 0 {
            return 1.0;
        }
        let max = self.busy_seconds.iter().copied().fold(0.0, f64::max);
        let mean = self.busy_seconds.iter().sum::<f64>() / n as f64;
        if mean <= 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Accumulate another combine's record (same worker-pool size).
    pub fn merge(&mut self, other: &ExecStats) {
        assert_eq!(
            self.busy_seconds.len(),
            other.busy_seconds.len(),
            "cannot merge stats from different worker-pool sizes"
        );
        self.absorb(other);
    }

    /// Accumulate a record measured on a pool **no wider** than this one,
    /// folding worker `w` of `other` into worker `w` here.
    pub fn absorb(&mut self, other: &ExecStats) {
        self.absorb_at(other, 0);
    }

    /// Accumulate a narrower record with its lanes shifted by
    /// `lane_offset` (wrapping at this record's width). This is how the
    /// rank-parallel executor rolls its per-rank nested pools (width
    /// [`nested_budget`]) into the run-level record: rank `p`'s pool
    /// lands at offset `p * nested`, so the distinct threads that were
    /// genuinely busy in parallel stay distinct in the per-worker
    /// breakdown instead of all collapsing onto slot 0.
    pub fn absorb_at(&mut self, other: &ExecStats, lane_offset: usize) {
        let n = self.busy_seconds.len();
        assert!(
            other.busy_seconds.len() <= n,
            "cannot absorb stats from a wider pool ({} > {n})",
            other.busy_seconds.len(),
        );
        self.n_tasks += other.n_tasks;
        self.n_pairs += other.n_pairs;
        self.units += other.units;
        self.rows_skipped += other.rows_skipped;
        for w in 0..other.busy_seconds.len() {
            let slot = (lane_offset + w) % n;
            self.busy_seconds[slot] += other.busy_seconds[w];
            self.worker_tasks[slot] += other.worker_tasks[w];
            self.worker_pairs[slot] += other.worker_pairs[w];
        }
    }
}

/// Per-lane worker budget for nested parallelism: when `n_lanes` rank
/// threads each drive their own combine pool out of a run-wide budget of
/// `total_workers`, give each lane `ceil(total / lanes)` (≥ 1) workers.
/// Oversubscribing by at most `lanes - 1` threads beats idling lanes, and
/// the split can never change results — the executor is bit-identical for
/// every worker count.
pub fn nested_budget(total_workers: usize, n_lanes: usize) -> usize {
    total_workers.max(1).div_ceil(n_lanes.max(1))
}

/// One schedulable unit: `len` pairs at absolute offset `off` of batch
/// `batch`'s pair list, all owned by `vertex`. Canonical index = position
/// in the plan's task vector (sorted by vertex, then batch, then start).
struct ExecTask {
    vertex: u32,
    batch: u32,
    off: usize,
    /// offset within the vertex's neighbor list (the Alg-4 task start —
    /// kept so the cost-model mirror reconstructs the scheduler's view)
    start: u32,
    len: u32,
}

/// Raw-pointer handle that lets scoped workers write disjoint windows of
/// a shared buffer. Every use below pairs it with a claim scheme (atomic
/// task/group counters) that makes the written windows disjoint; debug
/// builds additionally verify disjointness with a [`ClaimTracker`].
#[derive(Clone, Copy)]
struct SendPtr(*mut Count);

// SAFETY: moving the raw pointer between threads is sound because every
// dereference goes through a window claimed exactly once from an atomic
// counter (see the `from_raw_parts_mut` sites), so no two threads ever
// write overlapping memory through it.
unsafe impl Send for SendPtr {}

// SAFETY: shared references to SendPtr only copy the pointer value; all
// writes through it are to pairwise-disjoint claimed windows (same claim
// scheme as the Send impl), so concurrent use cannot race.
unsafe impl Sync for SendPtr {}

/// Debug-build ledger of the windows workers have claimed through a
/// [`SendPtr`]: asserts no window key is ever claimed twice (the
/// disjointness every unsafe slice reconstruction relies on), and that a
/// phase ends with every expected window claimed exactly once.
#[cfg(debug_assertions)]
struct ClaimTracker {
    claimed: crate::util::shim::Mutex<std::collections::HashSet<usize>>,
}

#[cfg(debug_assertions)]
impl ClaimTracker {
    fn new() -> Self {
        ClaimTracker {
            claimed: crate::util::shim::Mutex::new(std::collections::HashSet::new()),
        }
    }

    fn claim(&self, key: usize) {
        assert!(
            self.claimed.lock().unwrap().insert(key),
            "SendPtr window {key} claimed twice — disjointness violated"
        );
    }

    fn assert_complete(&self, expected: usize) {
        let n = self.claimed.lock().unwrap().len();
        assert_eq!(n, expected, "unclaimed SendPtr windows at end of phase");
    }
}

/// Run `worker` on `n_workers` scoped threads (inline when 1) and collect
/// each worker's result in worker-index order.
fn run_workers<R, F>(n_workers: usize, worker: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if n_workers == 1 {
        return vec![worker(0)];
    }
    let worker = &worker;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_workers)
            .map(|w| s.spawn(move || worker(w)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("combine worker panicked"))
            .collect()
    })
}

/// Build the canonical task plan: per-batch Alg-4 queues (unshuffled, so
/// the canonical order is reproducible) flattened and stably sorted by
/// vertex, plus the per-vertex group ranges `[lo, hi)` into that order.
fn build_plan(
    n_rows: usize,
    batches: &[PairBatch<'_>],
    max_task_size: u32,
) -> (Vec<ExecTask>, Vec<(usize, usize)>) {
    let mut tasks: Vec<ExecTask> = Vec::new();
    let mut degs = vec![0u32; n_rows];
    let mut first = vec![usize::MAX; n_rows];
    for (bi, b) in batches.iter().enumerate() {
        degs.fill(0);
        first.fill(usize::MAX);
        for (i, &(v, _)) in b.pairs.iter().enumerate() {
            let v = v as usize;
            assert!(v < n_rows, "pair vertex row {v} out of range ({n_rows})");
            if first[v] == usize::MAX {
                first[v] = i;
            } else {
                // hard assert: a non-contiguous list would silently route
                // pairs to the wrong vertex (task windows are offsets into
                // the vertex's run), so fail loudly in release builds too
                assert_eq!(
                    first[v] + degs[v] as usize,
                    i,
                    "batch pairs must be grouped contiguously by vertex"
                );
            }
            degs[v] += 1;
        }
        for t in make_tasks(&degs, max_task_size, None) {
            tasks.push(ExecTask {
                vertex: t.vertex,
                batch: bi as u32,
                off: first[t.vertex as usize] + t.start as usize,
                start: t.start,
                len: t.len,
            });
        }
    }
    // canonical order: (vertex, batch, start). `make_tasks` already emits
    // (vertex, start)-sorted queues per batch, so a *stable* sort on the
    // vertex key alone finishes the job.
    tasks.sort_by_key(|t| t.vertex);
    let mut groups: Vec<(usize, usize)> = Vec::new();
    let mut lo = 0usize;
    for i in 1..=tasks.len() {
        if i == tasks.len() || tasks[i].vertex != tasks[lo].vertex {
            groups.push((lo, i));
            lo = i;
        }
    }
    (tasks, groups)
}

/// Left-fold the task partials of one group (tasks `lo..hi`) into `dst`
/// in canonical order. THE determinism-critical merge: every consumer of
/// a multi-task vertex must fold through this one function so the
/// float-add sequence cannot diverge between paths.
fn fold_group(partials: &[Count], lo: usize, hi: usize, n_agg: usize, dst: &mut [Count]) {
    dst.copy_from_slice(&partials[lo * n_agg..(lo + 1) * n_agg]);
    for t in lo + 1..hi {
        for (a, &x) in dst.iter_mut().zip(&partials[t * n_agg..(t + 1) * n_agg]) {
            *a += x;
        }
    }
}

/// Fold the per-worker phase-1 records into the combine's stats.
fn absorb_phase1(stats: &mut ExecStats, p1: Vec<(f64, u64, u64)>) {
    for (w, (busy, t, p)) in p1.into_iter().enumerate() {
        stats.busy_seconds[w] += busy;
        stats.worker_tasks[w] += t;
        stats.worker_pairs[w] += p;
        stats.n_tasks += t;
        stats.n_pairs += p;
    }
}

/// Phase 1: claim tasks off the shared queue and accumulate each task's
/// partial aggregation row into its canonical slot of `partials`.
///
/// When `order` is given (a permutation of task indices, usually
/// [`lpt_order`] of the canonical queue), claim slot `j` resolves to task
/// `order[j]` — costliest tasks start first, which is the whole LPT
/// makespan argument — while the partial slot, and hence every result
/// bit, is still keyed by the task's canonical index.
/// Returns per-worker (busy seconds, tasks, pairs).
fn aggregate_phase(
    tasks: &[ExecTask],
    batches: &[PairBatch<'_>],
    n_agg: usize,
    partials: &mut [Count],
    n_workers: usize,
    order: Option<&[u32]>,
) -> Vec<(f64, u64, u64)> {
    debug_assert_eq!(partials.len(), tasks.len() * n_agg);
    if let Some(o) = order {
        assert_eq!(o.len(), tasks.len(), "claim order must cover every task");
    }
    let next = AtomicUsize::new(0);
    let ptr = SendPtr(partials.as_mut_ptr());
    #[cfg(debug_assertions)]
    let claims = ClaimTracker::new();
    let worker = |_w: usize| -> (f64, u64, u64) {
        let t0 = Instant::now();
        let mut my_tasks = 0u64;
        let mut my_pairs = 0u64;
        loop {
            let j = next.fetch_add(1);
            if j >= tasks.len() {
                break;
            }
            let i = match order {
                Some(o) => o[j] as usize,
                None => j,
            };
            #[cfg(debug_assertions)]
            claims.claim(i);
            let t = &tasks[i];
            let b = &batches[t.batch as usize];
            // SAFETY: slot `i` is an `n_agg`-wide window written only by
            // the worker that claimed index `i` from the atomic counter;
            // windows of distinct indices are disjoint.
            let slot =
                unsafe { std::slice::from_raw_parts_mut(ptr.0.add(i * n_agg), n_agg) };
            for &(_, u) in &b.pairs[t.off..t.off + t.len as usize] {
                b.rows.add_row_into(u as usize, slot);
            }
            my_tasks += 1;
            my_pairs += t.len as u64;
        }
        (t0.elapsed().as_secs_f64(), my_tasks, my_pairs)
    };
    let recs = run_workers(n_workers, worker);
    #[cfg(debug_assertions)]
    claims.assert_complete(tasks.len());
    recs
}

/// Phase 2: claim per-vertex groups, fold each group's task partials in
/// canonical order, and contract the merged row into `out`. A sparse
/// passive table is materialized one row at a time through a per-worker
/// [`RowScratch`] (touched-entry clearing, not a full-width `fill`) —
/// the materialized row equals the dense original exactly, so the
/// contraction arithmetic is representation-independent.
///
/// When `frontier` is given (the *passive* table's nonzero-row frontier),
/// groups whose vertex has an all-zero passive row are skipped: every
/// contraction term would be `0.0 * x` with `x` a finite non-negative
/// count, i.e. an exact `+0.0` add, so the output bits cannot change.
/// Returns per-worker (busy seconds, contraction units, rows skipped).
#[allow(clippy::too_many_arguments)]
fn contract_phase(
    tasks: &[ExecTask],
    groups: &[(usize, usize)],
    partials: &[Count],
    out: &mut CountTable,
    passive: RowsRef<'_>,
    cs: &CheckedSplit<'_>,
    n_agg: usize,
    n_workers: usize,
    frontier: Option<&Frontier>,
) -> Vec<(f64, u64, u64)> {
    let next = AtomicUsize::new(0);
    let n_sets = out.n_sets;
    let optr = SendPtr(out.data.as_mut_ptr());
    #[cfg(debug_assertions)]
    let claims = ClaimTracker::new();
    let worker = |_w: usize| -> (f64, u64, u64) {
        let t0 = Instant::now();
        let mut units = 0u64;
        let mut skipped = 0u64;
        let mut fold: Vec<Count> = vec![0.0; n_agg];
        let mut prow_scratch = RowScratch::new(cs.n_passive());
        loop {
            let gi = next.fetch_add(1);
            if gi >= groups.len() {
                break;
            }
            #[cfg(debug_assertions)]
            claims.claim(gi);
            let (lo, hi) = groups[gi];
            let v = tasks[lo].vertex as usize;
            if let Some(f) = frontier {
                if !f.contains(v) {
                    skipped += 1;
                    continue;
                }
            }
            let arow: &[Count] = if hi - lo == 1 {
                &partials[lo * n_agg..(lo + 1) * n_agg]
            } else {
                // deterministic merge in canonical (vertex, batch, start)
                // order — the same float-add sequence for every worker
                // count
                fold_group(partials, lo, hi, n_agg, &mut fold);
                &fold
            };
            let prow = prow_scratch.row(passive, v);
            // SAFETY: each group owns a distinct vertex `v`, claimed once
            // from the atomic counter, so output rows are written
            // disjointly; `v < out.n_rows` because `build_plan` asserted
            // every pair's vertex row against `n_rows`.
            let orow =
                unsafe { std::slice::from_raw_parts_mut(optr.0.add(v * n_sets), n_sets) };
            units += contract_row(orow, prow, arow, cs);
        }
        (t0.elapsed().as_secs_f64(), units, skipped)
    };
    let recs = run_workers(n_workers, worker);
    #[cfg(debug_assertions)]
    claims.assert_complete(groups.len());
    recs
}

/// Vertex rows per claimable block of the fused SIMD executor: big enough
/// to amortize the claim, small enough that stragglers rebalance (a
/// 4096-row step buffer still yields 64 claimable blocks).
const SIMD_BLOCK: usize = 64;

/// Per-batch CSR index of one combine's pair lists: `run[v] = (first,
/// deg)` — vertex `v`'s pairs sit at `pairs[first..first + deg]`. Same
/// contiguity contract (hard-asserted) as [`build_plan`].
fn index_batches(n_rows: usize, batches: &[PairBatch<'_>]) -> Vec<Vec<(usize, u32)>> {
    let mut runs = Vec::with_capacity(batches.len());
    for b in batches {
        let mut run: Vec<(usize, u32)> = vec![(usize::MAX, 0); n_rows];
        for (i, &(v, _)) in b.pairs.iter().enumerate() {
            let v = v as usize;
            assert!(v < n_rows, "pair vertex row {v} out of range ({n_rows})");
            let (first, deg) = &mut run[v];
            if *first == usize::MAX {
                *first = i;
            } else {
                // hard assert: a non-contiguous list would silently route
                // pairs to the wrong vertex (same contract as build_plan)
                assert_eq!(
                    *first + *deg as usize,
                    i,
                    "batch pairs must be grouped contiguously by vertex"
                );
            }
            *deg += 1;
        }
        runs.push(run);
    }
    runs
}

/// The fused SpMM + eMA executor ([`super::kernel`]): workers claim
/// [`SIMD_BLOCK`]-row blocks of the output, and for each vertex aggregate
/// its full neighbor run (all batches, canonical order) into a per-worker
/// row buffer — the SpMM stage, chunked-lane adds — then immediately
/// contract it through the split table with the lane-tree eMA kernel.
///
/// One worker owns a vertex end to end, so there is no cross-task merge
/// and no `partials` round-trip; the aggregation float order is the
/// canonical (vertex, batch, pair) order for **every** worker and block
/// count, hence bit-identical to the serial `aggregate_batch`. Only the
/// eMA lane tree reorders sums relative to the scalar `contract_row`
/// (see the kernel module's tolerance policy). `max_task_size` does not
/// apply: the shards are row blocks, never splitting a vertex.
#[allow(clippy::too_many_arguments)]
fn combine_rowblocks_simd(
    out: &mut CountTable,
    passive: RowsRef<'_>,
    cs: &CheckedSplit<'_>,
    batches: &[PairBatch<'_>],
    n_agg: usize,
    n_workers: usize,
    stats: &mut ExecStats,
    frontier: Option<&Frontier>,
) {
    let n_rows = out.n_rows;
    let runs = index_batches(n_rows, batches);
    let n_blocks = n_rows.div_ceil(SIMD_BLOCK);
    let pool = n_workers.clamp(1, n_blocks);
    let next = AtomicUsize::new(0);
    let n_sets = out.n_sets;
    let optr = SendPtr(out.data.as_mut_ptr());
    #[cfg(debug_assertions)]
    let claims = ClaimTracker::new();
    let runs = &runs;
    let worker = |_w: usize| -> (f64, u64, u64, u64, u64) {
        let t0 = Instant::now();
        let mut my_blocks = 0u64;
        let mut my_pairs = 0u64;
        let mut my_units = 0u64;
        let mut my_skipped = 0u64;
        let mut agg: Vec<Count> = vec![0.0; n_agg];
        let mut prow_scratch = RowScratch::new(cs.n_passive());
        loop {
            let bi = next.fetch_add(1);
            if bi >= n_blocks {
                break;
            }
            #[cfg(debug_assertions)]
            claims.claim(bi);
            let lo = bi * SIMD_BLOCK;
            let hi = (lo + SIMD_BLOCK).min(n_rows);
            for v in lo..hi {
                if let Some(f) = frontier {
                    if !f.contains(v) {
                        // fused ownership means the whole vertex — its
                        // aggregation too — can be skipped, not just the
                        // contraction; only count it if it had any pairs
                        // (an untouched vertex is not pruned work)
                        if runs.iter().any(|run| run[v].1 > 0) {
                            my_skipped += 1;
                        }
                        continue;
                    }
                }
                let mut touched = false;
                for (b, run) in batches.iter().zip(runs) {
                    let (first, deg) = run[v];
                    if deg == 0 {
                        continue;
                    }
                    if !touched {
                        agg.fill(0.0);
                        touched = true;
                    }
                    for &(_, u) in &b.pairs[first..first + deg as usize] {
                        b.rows.add_row_into_chunked(u as usize, &mut agg);
                    }
                    my_pairs += deg as u64;
                }
                if !touched {
                    continue;
                }
                let prow = prow_scratch.row(passive, v);
                // SAFETY: each block covers a distinct `[lo, hi)` row
                // range claimed once from the atomic counter, so output
                // rows are written disjointly; `v < n_rows == out.n_rows`
                // by the block clamp above.
                let orow =
                    unsafe { std::slice::from_raw_parts_mut(optr.0.add(v * n_sets), n_sets) };
                my_units += contract_row_simd(orow, prow, &agg, cs);
            }
            my_blocks += 1;
        }
        (t0.elapsed().as_secs_f64(), my_blocks, my_pairs, my_units, my_skipped)
    };
    let recs = run_workers(pool, worker);
    #[cfg(debug_assertions)]
    claims.assert_complete(n_blocks);
    for (w, (busy, blocks, pairs, units, skipped)) in recs.into_iter().enumerate() {
        stats.busy_seconds[w] += busy;
        stats.worker_tasks[w] += blocks;
        stats.worker_pairs[w] += pairs;
        stats.n_tasks += blocks;
        stats.n_pairs += pairs;
        stats.units += units;
        stats.rows_skipped += skipped;
    }
}

/// Execute one combine (the factored Eq-1 aggregate + contract) over the
/// given pair batches on `n_workers` real threads, adding into `out`,
/// with the scalar kernel — the historical executor and the differential
/// baseline the SIMD path is tested against.
/// See the module docs for the determinism contract. Returns the measured
/// execution record (vector fields have length `n_workers`).
pub fn combine_batches(
    out: &mut CountTable,
    passive: RowsRef<'_>,
    split: &SplitTable,
    batches: &[PairBatch<'_>],
    max_task_size: u32,
    n_workers: usize,
) -> ExecStats {
    combine_batches_with(
        out,
        passive,
        split,
        batches,
        max_task_size,
        n_workers,
        KernelMode::Scalar,
    )
}

/// [`combine_batches`] with an explicit combine-kernel choice (the
/// `--kernel` knob): `Scalar` runs the two-phase task executor, `Simd`
/// runs the fused row-block SpMM/eMA executor
/// ([`combine_rowblocks_simd`]), `Auto` resolves per combine from the
/// aggregation width. The split table is validated against the operand
/// widths once here ([`CheckedSplit`]) — both contraction kernels gather
/// through it unchecked.
#[allow(clippy::too_many_arguments)]
pub fn combine_batches_with(
    out: &mut CountTable,
    passive: RowsRef<'_>,
    split: &SplitTable,
    batches: &[PairBatch<'_>],
    max_task_size: u32,
    n_workers: usize,
    kernel: KernelMode,
) -> ExecStats {
    combine_batches_pruned(
        out,
        passive,
        split,
        batches,
        max_task_size,
        n_workers,
        kernel,
        None,
        None,
    )
}

/// [`combine_batches_with`] plus the frontier layer and the cost-model
/// scheduler — the full-knob executor entry the coordinator drives.
///
/// `passive_frontier`, when given, must be the nonzero-row frontier of
/// `passive` (same row count as `out`): vertices outside it skip their
/// contraction (scalar path) or their whole fused aggregate+contract
/// (SIMD path), counted in [`ExecStats::rows_skipped`]. Both skips are
/// bit-exact because every elided float op is an exact `+0.0` add — see
/// [`super::frontier`]'s module docs for the argument.
///
/// `cost_model`, when given, consumes the scalar task queue in
/// [`lpt_order`] instead of canonical order. The permutation touches only
/// the claim schedule — partial slots and the merge fold stay keyed by
/// canonical task index, so results are bit-identical with or without it.
/// The fused SIMD path ignores it: its shards are uniform row blocks.
#[allow(clippy::too_many_arguments)]
pub fn combine_batches_pruned(
    out: &mut CountTable,
    passive: RowsRef<'_>,
    split: &SplitTable,
    batches: &[PairBatch<'_>],
    max_task_size: u32,
    n_workers: usize,
    kernel: KernelMode,
    passive_frontier: Option<&Frontier>,
    cost_model: Option<&TaskCostModel>,
) -> ExecStats {
    assert!(n_workers >= 1, "combine executor needs at least one worker");
    if let Some(f) = passive_frontier {
        assert_eq!(
            f.n_rows(),
            out.n_rows,
            "passive frontier must cover the output rows"
        );
    }
    let mut stats = ExecStats::zeros(n_workers);
    let n_agg = match batches.first() {
        Some(b) => b.rows.n_sets(),
        None => return stats,
    };
    for b in batches {
        assert_eq!(
            b.rows.n_sets(),
            n_agg,
            "all batches of one combine must share the active-table width"
        );
    }
    assert_eq!(
        out.n_sets, split.n_sets,
        "output width must match the split table"
    );
    let cs = CheckedSplit::new(split, passive.n_sets(), n_agg);
    if batches.iter().all(|b| b.pairs.is_empty()) {
        return stats;
    }

    match kernel.resolve(n_agg) {
        ResolvedKernel::Simd => {
            combine_rowblocks_simd(
                out,
                passive,
                &cs,
                batches,
                n_agg,
                n_workers,
                &mut stats,
                passive_frontier,
            );
        }
        ResolvedKernel::Scalar => {
            let (tasks, groups) = build_plan(out.n_rows, batches, max_task_size);
            // spawning more threads than tasks is pure overhead; clamping
            // the pool never changes the result (determinism is
            // schedule-free) and the stats vectors keep their configured
            // `n_workers` length (tasks is non-empty here: some batch had
            // pairs)
            let pool = n_workers.clamp(1, tasks.len());
            let order = cost_model.map(|m| {
                // mirror the exec tasks back into the scheduler's shape so
                // the one LPT implementation ranks them
                let mirror: Vec<Task> = tasks
                    .iter()
                    .map(|t| Task {
                        vertex: t.vertex,
                        start: t.start,
                        len: t.len,
                    })
                    .collect();
                lpt_order(&mirror, m)
            });
            let mut partials: Vec<Count> = vec![0.0; tasks.len() * n_agg];
            let p1 = aggregate_phase(
                &tasks,
                batches,
                n_agg,
                &mut partials,
                pool,
                order.as_deref(),
            );
            let p2 = contract_phase(
                &tasks,
                &groups,
                &partials,
                out,
                passive,
                &cs,
                n_agg,
                pool,
                passive_frontier,
            );
            absorb_phase1(&mut stats, p1);
            for (w, (busy, units, skipped)) in p2.into_iter().enumerate() {
                stats.busy_seconds[w] += busy;
                stats.units += units;
                stats.rows_skipped += skipped;
            }
        }
    }
    stats
}

/// Verification hook (property tests, benches): run only the aggregation
/// phase + deterministic merge and return the dense merged aggregation
/// table — row `v` equals what the canonical fold leaves for vertex `v`,
/// zero for vertices with no pairs — plus the phase-1 execution record.
pub fn aggregate_merged(
    n_rows: usize,
    batches: &[PairBatch<'_>],
    max_task_size: u32,
    n_workers: usize,
) -> (CountTable, ExecStats) {
    assert!(n_workers >= 1, "combine executor needs at least one worker");
    let n_agg = batches.first().map_or(0, |b| b.rows.n_sets());
    for b in batches {
        assert_eq!(b.rows.n_sets(), n_agg);
    }
    let mut merged = CountTable::zeros(n_rows, n_agg);
    let mut stats = ExecStats::zeros(n_workers);
    if n_agg == 0 || batches.iter().all(|b| b.pairs.is_empty()) {
        return (merged, stats);
    }
    let (tasks, groups) = build_plan(n_rows, batches, max_task_size);
    let pool = n_workers.clamp(1, tasks.len());
    let mut partials: Vec<Count> = vec![0.0; tasks.len() * n_agg];
    let p1 = aggregate_phase(&tasks, batches, n_agg, &mut partials, pool, None);
    absorb_phase1(&mut stats, p1);
    for &(lo, hi) in &groups {
        let v = tasks[lo].vertex as usize;
        fold_group(&partials, lo, hi, n_agg, merged.row_mut(v));
    }
    (merged, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::colorcount::engine::{aggregate_batch, contract_touched, CombineScratch};
    use crate::colorcount::storage::SparseTable;
    use crate::combin::Binomial;
    use crate::util::prop;

    fn mk_tables(n: usize, c1: usize, c2: usize) -> (CountTable, CountTable) {
        let mut passive = CountTable::zeros(n, c1);
        let mut active = CountTable::zeros(n, c2);
        for (i, x) in passive.data.iter_mut().enumerate() {
            // fractional values so rounding differences cannot hide
            *x = ((i * 7) % 5) as f32 + 0.125;
        }
        for (i, x) in active.data.iter_mut().enumerate() {
            *x = ((i * 3) % 4) as f32 + 0.375;
        }
        (passive, active)
    }

    fn ring_pairs(n: usize, deg: usize) -> Vec<(u32, u32)> {
        (0..n as u32)
            .flat_map(|v| (1..=deg as u32).map(move |d| (v, (v + d) % n as u32)))
            .collect()
    }

    #[test]
    fn matches_serial_combine_per_vertex_tasks() {
        // per-vertex granularity: bit-identical to aggregate_batch +
        // contract_touched for any worker count
        let binom = Binomial::new();
        let split = SplitTable::new(5, 3, 1, &binom);
        let c1 = 5;
        let c2 = binom.c(5, 2) as usize;
        let n = 37;
        let (passive, active) = mk_tables(n, c1, c2);
        let pairs = ring_pairs(n, 6);

        let mut serial = CountTable::zeros(n, split.n_sets);
        let mut scratch = CombineScratch::new(n, c2);
        scratch.begin(c2);
        aggregate_batch(&mut scratch, RowsRef::dense(&active), pairs.iter().copied());
        contract_touched(&mut serial, &passive, &split, &mut scratch);

        for workers in [1, 2, 4, 7] {
            let mut par = CountTable::zeros(n, split.n_sets);
            let batch = [PairBatch {
                pairs: &pairs,
                rows: RowsRef::dense(&active),
            }];
            let st = combine_batches(
                &mut par,
                RowsRef::dense(&passive),
                &split,
                &batch,
                0,
                workers,
            );
            assert_eq!(st.n_pairs, pairs.len() as u64);
            for (a, b) in par.data.iter().zip(&serial.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={workers}");
            }
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "claimed twice")]
    fn claim_tracker_rejects_overlap() {
        let t = ClaimTracker::new();
        t.claim(3);
        t.claim(3);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "unclaimed SendPtr windows")]
    fn claim_tracker_rejects_incomplete_phase() {
        let t = ClaimTracker::new();
        t.claim(0);
        t.assert_complete(2);
    }

    /// Representation independence: sparse active and/or passive sources
    /// reproduce the dense combine bit for bit, for any worker count —
    /// the executor-level leg of the storage invariant.
    #[test]
    fn sparse_sources_are_bit_identical_to_dense() {
        let binom = Binomial::new();
        let split = SplitTable::new(5, 3, 1, &binom);
        let c1 = 5;
        let c2 = binom.c(5, 2) as usize;
        let n = 29;
        let (mut passive, mut active) = mk_tables(n, c1, c2);
        // punch holes so the sparse layouts genuinely skip entries
        for (i, x) in passive.data.iter_mut().enumerate() {
            if i % 3 != 0 {
                *x = 0.0;
            }
        }
        for (i, x) in active.data.iter_mut().enumerate() {
            if i % 4 != 1 {
                *x = 0.0;
            }
        }
        let sp_passive = SparseTable::from_dense(&passive);
        let sp_active = SparseTable::from_dense(&active);
        let pairs = ring_pairs(n, 5);
        let run = |p: RowsRef<'_>, a: RowsRef<'_>, workers: usize| {
            let mut out = CountTable::zeros(n, split.n_sets);
            let batch = [PairBatch {
                pairs: &pairs,
                rows: a,
            }];
            combine_batches(&mut out, p, &split, &batch, 3, workers);
            out
        };
        let reference = run(RowsRef::dense(&passive), RowsRef::dense(&active), 1);
        for workers in [1, 4] {
            for (p, a) in [
                (RowsRef::sparse(&sp_passive), RowsRef::dense(&active)),
                (RowsRef::dense(&passive), RowsRef::sparse(&sp_active)),
                (RowsRef::sparse(&sp_passive), RowsRef::sparse(&sp_active)),
            ] {
                let out = run(p, a, workers);
                for (x, y) in out.data.iter().zip(&reference.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "workers={workers}");
                }
            }
        }
        // the serial aggregation kernel agrees too
        let mut dense_scr = CombineScratch::new(n, c2);
        dense_scr.begin(c2);
        aggregate_batch(&mut dense_scr, RowsRef::dense(&active), pairs.iter().copied());
        let mut sparse_scr = CombineScratch::new(n, c2);
        sparse_scr.begin(c2);
        aggregate_batch(
            &mut sparse_scr,
            RowsRef::sparse(&sp_active),
            pairs.iter().copied(),
        );
        for v in 0..n {
            assert_eq!(dense_scr.agg_row(v), sparse_scr.agg_row(v), "vertex {v}");
        }
        dense_scr.finish();
        sparse_scr.finish();
    }

    #[test]
    fn split_tasks_are_worker_count_invariant() {
        // hub splitting changes the float fold vs serial, but the result
        // must be bit-identical across worker counts
        let binom = Binomial::new();
        let split = SplitTable::new(6, 4, 2, &binom);
        let c1 = binom.c(6, 2) as usize;
        let c2 = binom.c(6, 2) as usize;
        let n = 24;
        let (passive, active) = mk_tables(n, c1, c2);
        // one hub with a long list plus a ring
        let mut pairs: Vec<(u32, u32)> = (0..200u32).map(|i| (0, i % n as u32)).collect();
        pairs.extend((1..n as u32).map(|v| (v, (v + 1) % n as u32)));
        for mts in [1u32, 3, 16] {
            let run = |workers: usize| {
                let mut out = CountTable::zeros(n, split.n_sets);
                let batch = [PairBatch {
                    pairs: &pairs,
                    rows: RowsRef::dense(&active),
                }];
                combine_batches(
                    &mut out,
                    RowsRef::dense(&passive),
                    &split,
                    &batch,
                    mts,
                    workers,
                );
                out
            };
            let reference = run(1);
            for workers in [2, 3, 4, 7] {
                let out = run(workers);
                for (a, b) in out.data.iter().zip(&reference.data) {
                    assert_eq!(a.to_bits(), b.to_bits(), "mts={mts} workers={workers}");
                }
            }
        }
    }

    #[test]
    fn multi_batch_fold_is_deterministic() {
        // two batches (the exchange-fold shape): same invariance
        let binom = Binomial::new();
        let split = SplitTable::new(4, 3, 1, &binom);
        let c1 = 4;
        let c2 = binom.c(4, 2) as usize;
        let n = 16;
        let (passive, active_a) = mk_tables(n, c1, c2);
        let (_, active_b) = mk_tables(n + 3, c1, c2);
        let pairs_a = ring_pairs(n, 3);
        let pairs_b: Vec<(u32, u32)> = (0..n as u32)
            .map(|v| (v, (v * 5 + 1) % (n as u32 + 3)))
            .collect();
        let run = |workers: usize| {
            let mut out = CountTable::zeros(n, split.n_sets);
            let batches = [
                PairBatch {
                    pairs: &pairs_a,
                    rows: RowsRef::dense(&active_a),
                },
                PairBatch {
                    pairs: &pairs_b,
                    rows: RowsRef::dense(&active_b),
                },
            ];
            let st = combine_batches(
                &mut out,
                RowsRef::dense(&passive),
                &split,
                &batches,
                2,
                workers,
            );
            (out, st)
        };
        let (reference, st1) = run(1);
        assert_eq!(st1.n_pairs, (pairs_a.len() + pairs_b.len()) as u64);
        assert_eq!(st1.busy_workers(), 1);
        for workers in [2, 5] {
            let (out, st) = run(workers);
            assert_eq!(st.n_pairs, st1.n_pairs);
            assert_eq!(st.n_tasks, st1.n_tasks);
            for (a, b) in out.data.iter().zip(&reference.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={workers}");
            }
        }
    }

    /// SIMD leg of the executor invariants: on integer-valued tables the
    /// fused row-block kernel is bit-identical to the scalar executor
    /// (lane-tree reorder of exact sums), for every worker count, dense
    /// and sparse sources, single- and multi-batch.
    #[test]
    fn simd_executor_matches_scalar_bitwise_on_integer_tables() {
        let binom = Binomial::new();
        let split = SplitTable::new(6, 4, 2, &binom);
        let c1 = binom.c(6, 2) as usize;
        let c2 = binom.c(6, 2) as usize; // 15 ≥ LANE → Auto picks Simd
        let n = 150; // > SIMD_BLOCK so blocks genuinely shard
        let mut passive = CountTable::zeros(n, c1);
        let mut active = CountTable::zeros(n, c2);
        for (i, x) in passive.data.iter_mut().enumerate() {
            *x = ((i * 7) % 6) as f32; // integer-valued: sums are exact
        }
        for (i, x) in active.data.iter_mut().enumerate() {
            *x = ((i * 3) % 5) as f32;
        }
        let sp_active = SparseTable::from_dense(&active);
        let pairs = ring_pairs(n, 6);
        let run = |rows: RowsRef<'_>, workers: usize, kernel: KernelMode| {
            let mut out = CountTable::zeros(n, split.n_sets);
            let batch = [PairBatch { pairs: &pairs, rows }];
            let st = combine_batches_with(
                &mut out,
                RowsRef::dense(&passive),
                &split,
                &batch,
                4,
                workers,
                kernel,
            );
            (out, st)
        };
        let (reference, _) = run(RowsRef::dense(&active), 1, KernelMode::Scalar);
        for workers in [1, 2, 4, 7] {
            for kernel in [KernelMode::Simd, KernelMode::Auto] {
                for rows in [RowsRef::dense(&active), RowsRef::sparse(&sp_active)] {
                    let (out, st) = run(rows, workers, kernel);
                    assert_eq!(st.n_pairs, pairs.len() as u64);
                    assert_eq!(st.n_tasks, (n as u64).div_ceil(SIMD_BLOCK as u64));
                    assert_eq!(
                        st.units,
                        (n * split.n_sets * split.n_splits) as u64,
                        "every vertex contracts the full split table"
                    );
                    for (a, b) in out.data.iter().zip(&reference.data) {
                        assert_eq!(a.to_bits(), b.to_bits(), "workers={workers}");
                    }
                }
            }
        }
    }

    /// Narrow aggregation widths fall back to scalar under `Auto` (no
    /// lane win below one chunk) — and the forced `Simd` remainder path
    /// still matches within the documented policy on fractional data.
    #[test]
    fn simd_executor_fractional_data_within_policy() {
        let binom = Binomial::new();
        let split = SplitTable::new(5, 3, 1, &binom);
        let c1 = 5;
        let c2 = binom.c(5, 2) as usize;
        let n = 70;
        let (passive, active) = mk_tables(n, c1, c2);
        let pairs = ring_pairs(n, 5);
        let run = |workers: usize, kernel: KernelMode| {
            let mut out = CountTable::zeros(n, split.n_sets);
            let batch = [PairBatch {
                pairs: &pairs,
                rows: RowsRef::dense(&active),
            }];
            combine_batches_with(
                &mut out,
                RowsRef::dense(&passive),
                &split,
                &batch,
                0,
                workers,
                kernel,
            );
            out
        };
        let scalar = run(1, KernelMode::Scalar);
        // worker-count invariance of the SIMD path itself is bitwise
        let simd1 = run(1, KernelMode::Simd);
        for workers in [2, 5] {
            let out = run(workers, KernelMode::Simd);
            for (a, b) in out.data.iter().zip(&simd1.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={workers}");
            }
        }
        // vs scalar: within the documented ≤1e-4 relative policy
        for (a, b) in simd1.data.iter().zip(&scalar.data) {
            let denom = b.abs().max(1.0);
            assert!(
                (a - b).abs() / denom <= 1e-4,
                "simd {a} vs scalar {b} outside tolerance"
            );
        }
    }

    #[test]
    fn nested_budget_splits_the_pool() {
        assert_eq!(nested_budget(4, 4), 1);
        assert_eq!(nested_budget(4, 2), 2);
        assert_eq!(nested_budget(5, 2), 3); // ceil
        assert_eq!(nested_budget(1, 6), 1); // never zero
        assert_eq!(nested_budget(0, 3), 1);
        assert_eq!(nested_budget(8, 0), 8); // degenerate lane count
    }

    #[test]
    fn absorb_narrower_pool_into_wider() {
        let mut wide = ExecStats::zeros(4);
        let mut narrow = ExecStats::zeros(2);
        narrow.n_tasks = 3;
        narrow.n_pairs = 10;
        narrow.units = 7;
        narrow.busy_seconds = vec![0.5, 0.25];
        narrow.worker_tasks = vec![2, 1];
        narrow.worker_pairs = vec![6, 4];
        wide.absorb(&narrow);
        wide.absorb(&narrow);
        assert_eq!(wide.n_tasks, 6);
        assert_eq!(wide.n_pairs, 20);
        assert_eq!(wide.units, 14);
        assert_eq!(wide.worker_tasks, vec![4, 2, 0, 0]);
        assert_eq!(wide.busy_seconds[0], 1.0);
        assert_eq!(wide.n_workers(), 4, "width stays the configured pool");
    }

    #[test]
    fn absorb_at_spreads_lanes_across_the_record() {
        // two 2-wide rank pools at offsets 0 and 2 of a 4-wide record:
        // each rank's workers stay distinct slots
        let mut run = ExecStats::zeros(4);
        let mut lane = ExecStats::zeros(2);
        lane.worker_tasks = vec![5, 3];
        lane.busy_seconds = vec![1.0, 0.5];
        lane.n_tasks = 8;
        run.absorb_at(&lane, 0);
        run.absorb_at(&lane, 2);
        assert_eq!(run.worker_tasks, vec![5, 3, 5, 3]);
        assert_eq!(run.busy_seconds, vec![1.0, 0.5, 1.0, 0.5]);
        assert_eq!(run.n_tasks, 16);
        assert_eq!(run.busy_workers(), 4);
        // offsets wrap at the record width
        let mut narrow = ExecStats::zeros(2);
        narrow.absorb_at(&lane, 3);
        assert_eq!(narrow.worker_tasks, vec![3, 5]);
    }

    #[test]
    #[should_panic(expected = "wider pool")]
    fn absorb_rejects_wider_source() {
        let mut narrow = ExecStats::zeros(2);
        let wide = ExecStats::zeros(3);
        narrow.absorb(&wide);
    }

    #[test]
    fn empty_and_zero_width_inputs() {
        let binom = Binomial::new();
        let split = SplitTable::new(4, 3, 1, &binom);
        let c2 = binom.c(4, 2) as usize;
        let (passive, active) = mk_tables(4, 4, c2);
        let mut out = CountTable::zeros(4, split.n_sets);
        // no batches at all
        let st = combine_batches(&mut out, RowsRef::dense(&passive), &split, &[], 0, 3);
        assert_eq!(st.n_tasks, 0);
        // batches with no pairs
        let batch = [PairBatch {
            pairs: &[],
            rows: RowsRef::dense(&active),
        }];
        let st = combine_batches(&mut out, RowsRef::dense(&passive), &split, &batch, 0, 3);
        assert_eq!(st.n_pairs, 0);
        assert!(out.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn stats_account_every_task_and_pair() {
        let binom = Binomial::new();
        let split = SplitTable::new(5, 3, 1, &binom);
        let c2 = binom.c(5, 2) as usize;
        let n = 20;
        let (passive, active) = mk_tables(n, 5, c2);
        let pairs = ring_pairs(n, 7);
        let mut out = CountTable::zeros(n, split.n_sets);
        let batch = [PairBatch {
            pairs: &pairs,
            rows: RowsRef::dense(&active),
        }];
        let st = combine_batches(&mut out, RowsRef::dense(&passive), &split, &batch, 3, 4);
        assert_eq!(st.n_workers(), 4);
        assert_eq!(st.n_pairs, pairs.len() as u64);
        // 7 pairs per vertex at size-3 tasks → 3 tasks per vertex
        assert_eq!(st.n_tasks, (n * 3) as u64);
        assert_eq!(st.worker_tasks.iter().sum::<u64>(), st.n_tasks);
        assert_eq!(st.worker_pairs.iter().sum::<u64>(), st.n_pairs);
        assert_eq!(st.units, (n * split.n_sets * split.n_splits) as u64);
        assert!(st.imbalance() >= 1.0 - 1e-9);
    }

    /// Frontier leg of the executor invariants: pruning on a passive
    /// table with all-zero rows is bit-identical to the unpruned combine
    /// (every elided op was an exact `+0.0`), skips exactly the touched
    /// dead vertices, and holds for both kernels and any worker count.
    #[test]
    fn pruned_combine_is_bit_identical_and_counts_skips() {
        let binom = Binomial::new();
        let split = SplitTable::new(6, 4, 2, &binom);
        let c1 = binom.c(6, 2) as usize;
        let c2 = binom.c(6, 2) as usize; // 15 ≥ LANE → Simd genuinely vectorizes
        let n = 150;
        let mut passive = CountTable::zeros(n, c1);
        let mut active = CountTable::zeros(n, c2);
        for (i, x) in passive.data.iter_mut().enumerate() {
            *x = ((i * 7) % 6) as f32; // integer-valued: SIMD sums exact
        }
        for (i, x) in active.data.iter_mut().enumerate() {
            *x = ((i * 3) % 5) as f32;
        }
        // kill every third passive row so the frontier has real holes
        let mut dead = 0u64;
        for v in 0..n {
            if v % 3 == 0 {
                passive.row_mut(v).fill(0.0);
                dead += 1;
            }
        }
        let frontier = passive.frontier();
        assert_eq!(frontier.live_rows(), n - dead as usize);
        let pairs = ring_pairs(n, 6); // every vertex touched
        let run = |kernel: KernelMode, workers: usize, f: Option<&Frontier>| {
            let mut out = CountTable::zeros(n, split.n_sets);
            let batch = [PairBatch {
                pairs: &pairs,
                rows: RowsRef::dense(&active),
            }];
            let st = combine_batches_pruned(
                &mut out,
                RowsRef::dense(&passive),
                &split,
                &batch,
                4,
                workers,
                kernel,
                f,
                None,
            );
            (out, st)
        };
        for kernel in [KernelMode::Scalar, KernelMode::Simd] {
            let (reference, st0) = run(kernel, 1, None);
            assert_eq!(st0.rows_skipped, 0, "no frontier, nothing skipped");
            for workers in [1, 3, 7] {
                let (out, st) = run(kernel, workers, Some(&frontier));
                assert_eq!(st.rows_skipped, dead, "kernel {kernel:?}");
                for (a, b) in out.data.iter().zip(&reference.data) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{kernel:?} workers={workers}");
                }
                // skipped contractions do not execute: fewer units, and
                // the fused path also drops the dead vertices' pairs
                assert!(st.units < st0.units);
            }
        }
    }

    #[test]
    #[should_panic(expected = "passive frontier must cover the output rows")]
    fn pruned_combine_rejects_mismatched_frontier() {
        let binom = Binomial::new();
        let split = SplitTable::new(4, 3, 1, &binom);
        let c2 = binom.c(4, 2) as usize;
        let (passive, active) = mk_tables(8, 4, c2);
        let small = CountTable::zeros(3, 4); // frontier over the wrong row count
        let frontier = small.frontier();
        let mut out = CountTable::zeros(8, split.n_sets);
        let pairs = ring_pairs(8, 2);
        let batch = [PairBatch {
            pairs: &pairs,
            rows: RowsRef::dense(&active),
        }];
        combine_batches_pruned(
            &mut out,
            RowsRef::dense(&passive),
            &split,
            &batch,
            0,
            2,
            KernelMode::Scalar,
            Some(&frontier),
            None,
        );
    }

    /// LPT consumption changes only the claim schedule: with the cost
    /// model wired in, results and work totals are bit-identical to the
    /// canonical-order claim for every worker count — including on a
    /// hub-split queue where the permutation genuinely reorders claims.
    #[test]
    fn lpt_claims_are_bit_identical_to_canonical() {
        let binom = Binomial::new();
        let split = SplitTable::new(5, 3, 1, &binom);
        let c2 = binom.c(5, 2) as usize;
        let n = 31;
        let (passive, active) = mk_tables(n, 5, c2);
        // hub + ring: the hub splits into many tasks the LPT order fronts
        let mut pairs: Vec<(u32, u32)> = (0..300u32).map(|i| (0, i % n as u32)).collect();
        pairs.extend(ring_pairs(n, 3).into_iter().filter(|&(v, _)| v != 0));
        let model = TaskCostModel {
            unit_per_pair: 1.0,
            unit_per_task: 0.5,
            overhead: 0.25,
        };
        let run = |workers: usize, m: Option<&TaskCostModel>| {
            let mut out = CountTable::zeros(n, split.n_sets);
            let batch = [PairBatch {
                pairs: &pairs,
                rows: RowsRef::dense(&active),
            }];
            let st = combine_batches_pruned(
                &mut out,
                RowsRef::dense(&passive),
                &split,
                &batch,
                8,
                workers,
                KernelMode::Scalar,
                None,
                m,
            );
            (out, st)
        };
        let (reference, st0) = run(1, None);
        for workers in [1, 2, 5] {
            let (out, st) = run(workers, Some(&model));
            assert_eq!(st.n_tasks, st0.n_tasks);
            assert_eq!(st.n_pairs, st0.n_pairs);
            assert_eq!(st.units, st0.units);
            for (a, b) in out.data.iter().zip(&reference.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={workers}");
            }
        }
    }

    #[test]
    fn prop_merged_aggregation_matches_serial() {
        // random degree sequences, task sizes and worker counts: the
        // merged per-worker accumulators equal the serial aggregate_batch
        // rows exactly on integer-valued data (exact f32 sums), and every
        // task/pair is processed exactly once
        prop::check("parallel_aggregate", |gen| {
            let n = gen.usize_in(1, 40);
            let n_agg = gen.usize_in(1, 10);
            let n_src = gen.usize_in(1, 30);
            let mut rows = CountTable::zeros(n_src, n_agg);
            for x in rows.data.iter_mut() {
                *x = gen.usize_in(0, 5) as f32;
            }
            let degs: Vec<u32> = (0..n).map(|_| gen.usize_in(0, 25) as u32).collect();
            let mut pairs: Vec<(u32, u32)> = Vec::new();
            for (v, &d) in degs.iter().enumerate() {
                for _ in 0..d {
                    pairs.push((v as u32, gen.usize_in(0, n_src - 1) as u32));
                }
            }
            let mts = gen.usize_in(0, 30) as u32;
            let workers = gen.usize_in(1, 9);
            let batch = [PairBatch {
                pairs: &pairs,
                rows: RowsRef::dense(&rows),
            }];
            let (merged, st) = aggregate_merged(n, &batch, mts, workers);
            // coverage accounting: no task skipped or double-claimed
            let expect_tasks = make_tasks(&degs, mts, None).len() as u64;
            if st.n_tasks != expect_tasks {
                return Err(format!("{} tasks != expected {expect_tasks}", st.n_tasks));
            }
            if st.n_pairs != pairs.len() as u64 {
                return Err(format!("{} pairs != {}", st.n_pairs, pairs.len()));
            }
            if st.worker_tasks.iter().sum::<u64>() != st.n_tasks
                || st.worker_pairs.iter().sum::<u64>() != st.n_pairs
            {
                return Err("per-worker counters do not sum to totals".into());
            }
            // exactness vs the serial path
            let mut scratch = CombineScratch::new(n, n_agg);
            scratch.begin(n_agg);
            aggregate_batch(&mut scratch, RowsRef::dense(&rows), pairs.iter().copied());
            for (v, &d) in degs.iter().enumerate() {
                let got = merged.row(v);
                if d == 0 {
                    if got.iter().any(|&x| x != 0.0) {
                        return Err(format!("vertex {v} has no pairs but nonzero row"));
                    }
                } else {
                    let want = scratch.agg_row(v);
                    if got != want {
                        return Err(format!("vertex {v}: {got:?} != serial {want:?}"));
                    }
                }
            }
            scratch.finish();
            Ok(())
        });
    }
}
