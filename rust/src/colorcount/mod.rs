//! The color-coding counting substrate: count tables and colorings
//! (`table`), the DP engine with the factored combine (`engine`), the
//! real multithreaded combine executor over the Alg-4 task queue
//! (`parallel`), the vectorized SpMM/eMA combine kernel and the
//! `--kernel` knob behind it (`kernel`), the adaptive dense/sparse table
//! representations and the shared wire codec (`storage`), the (ε,δ)
//! estimation loop (`estimate`), and the exact backtracking oracle used
//! by tests and examples (`brute`).

pub mod brute;
pub mod engine;
pub mod estimate;
pub mod frontier;
pub mod kernel;
pub mod parallel;
pub mod storage;
pub mod table;

pub use brute::count_embeddings;
pub use engine::{
    aggregate_batch, contract_touched, contract_touched_pruned, CombineScratch, Engine,
    EngineContext, PruneTally,
};
pub use estimate::{estimate, iteration_bound, median_of_means, Estimate};
pub use frontier::{Frontier, PruneMode};
pub use kernel::{KernelMode, ResolvedKernel, LANE};
pub use parallel::{
    aggregate_merged, combine_batches, combine_batches_pruned, combine_batches_with,
    nested_budget, ExecStats, PairBatch,
};
pub use storage::{
    encode_rows, encode_rows_masked, RowScratch, RowsPayload, RowsRef, SparseTable, StorageMode,
    StoragePolicy, TableStorage,
};
pub use table::{init_leaf_table, Coloring, Count, CountTable};
