//! Count tables: the DP state `C(v, Ti, S)` for one subtemplate, stored
//! row-major as `[n_rows × n_sets]` f32 (FASCIA likewise uses 32-bit
//! floats; totals are accumulated in f64). Rows are *local* vertex indices
//! — the same layout serves the single-rank engine, the distributed ranks
//! and the XLA-backed engine (which views a table as a dense block).

pub type Count = f32;

#[derive(Debug, Clone)]
pub struct CountTable {
    pub n_rows: usize,
    pub n_sets: usize,
    pub data: Vec<Count>,
}

impl CountTable {
    pub fn zeros(n_rows: usize, n_sets: usize) -> Self {
        CountTable {
            n_rows,
            n_sets,
            data: vec![0.0; n_rows * n_sets],
        }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[Count] {
        let lo = r * self.n_sets;
        &self.data[lo..lo + self.n_sets]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [Count] {
        let lo = r * self.n_sets;
        &mut self.data[lo..lo + self.n_sets]
    }

    /// Sum of every entry (f64 accumulation).
    pub fn total(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    /// Resident bytes (for the peak-memory accountant, Eq 7/12).
    pub fn bytes(&self) -> u64 {
        (self.data.len() * std::mem::size_of::<Count>()) as u64
    }

    /// Bytes the dense layout holds for an `n_rows × n_sets` table — the
    /// baseline `super::storage` measures its savings against.
    pub fn dense_bytes_for(n_rows: usize, n_sets: usize) -> u64 {
        (n_rows * n_sets * std::mem::size_of::<Count>()) as u64
    }

    /// Number of non-zero entries.
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    /// Fraction of non-zero entries — count tables are sparse for small
    /// subtemplates. This probe drives the `Auto` storage policy
    /// (`super::storage`) and the per-subtemplate `density` field of the
    /// job report.
    pub fn density(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.nnz() as f64 / self.data.len() as f64
    }
}

/// A per-iteration random coloring. Colors are derived statelessly from
/// `(seed, global_vertex_id)` so any rank computes the same color for the
/// same vertex — the root of the distributed == single-rank invariant.
#[derive(Debug, Clone)]
pub struct Coloring {
    pub k: usize,
    pub colors: Vec<u8>,
}

impl Coloring {
    pub fn random(n_vertices: usize, k: usize, iter_seed: u64) -> Self {
        let colors = (0..n_vertices)
            .map(|v| (crate::util::mix2(iter_seed, v as u64) % k as u64) as u8)
            .collect();
        Coloring { k, colors }
    }

    #[inline]
    pub fn color(&self, v: u32) -> u8 {
        self.colors[v as usize]
    }
}

/// Initialize the leaf subtemplate table for the given (local) vertices:
/// row r has a single 1 at the rank of `{col(vertices[r])}` — with the
/// colex indexer over singletons that rank is simply the color itself.
pub fn init_leaf_table(vertices: &[u32], coloring: &Coloring) -> CountTable {
    let mut t = CountTable::zeros(vertices.len(), coloring.k);
    for (r, &v) in vertices.iter().enumerate() {
        let c = coloring.color(v) as usize;
        t.row_mut(r)[c] = 1.0;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_rows() {
        let mut t = CountTable::zeros(3, 4);
        t.row_mut(1)[2] = 5.0;
        assert_eq!(t.row(1), &[0.0, 0.0, 5.0, 0.0]);
        assert_eq!(t.total(), 5.0);
        assert_eq!(t.bytes(), 48);
    }

    #[test]
    fn coloring_deterministic_and_in_range() {
        let c1 = Coloring::random(100, 5, 7);
        let c2 = Coloring::random(100, 5, 7);
        assert_eq!(c1.colors, c2.colors);
        assert!(c1.colors.iter().all(|&c| (c as usize) < 5));
        let c3 = Coloring::random(100, 5, 8);
        assert_ne!(c1.colors, c3.colors);
    }

    #[test]
    fn coloring_partition_independent() {
        // color of vertex 42 must not depend on how many vertices exist
        let small = Coloring::random(50, 7, 3);
        let big = Coloring::random(500, 7, 3);
        assert_eq!(small.color(42), big.color(42));
    }

    #[test]
    fn leaf_table_one_hot() {
        let col = Coloring::random(10, 4, 1);
        let verts: Vec<u32> = vec![3, 7, 9];
        let t = init_leaf_table(&verts, &col);
        assert_eq!(t.n_rows, 3);
        assert_eq!(t.n_sets, 4);
        for (r, &v) in verts.iter().enumerate() {
            let row = t.row(r);
            assert_eq!(row.iter().sum::<Count>(), 1.0);
            assert_eq!(row[col.color(v) as usize], 1.0);
        }
    }

    #[test]
    fn density() {
        let col = Coloring::random(4, 4, 1);
        let t = init_leaf_table(&[0, 1, 2, 3], &col);
        assert!((t.density() - 0.25).abs() < 1e-9);
    }
}
