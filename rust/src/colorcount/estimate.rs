//! The (ε, δ)-approximation loop: `Niter` independent colorings, grouped
//! averages, and the median-of-means output (Alg 1 lines 3 & 14).

use super::engine::Engine;
use crate::graph::Graph;

#[derive(Debug, Clone)]
pub struct Estimate {
    /// per-iteration unbiased contributions
    pub samples: Vec<f64>,
    /// the median-of-means estimate
    pub value: f64,
    /// plain mean (useful for diagnostics)
    pub mean: f64,
}

/// `Niter = O(e^k · ln(1/δ) / ε²)` — the paper's iteration bound. Returned
/// as a u64 but typically capped by the caller: the constant-free bound is
/// astronomically conservative for the small graphs in tests.
pub fn iteration_bound(k: usize, epsilon: f64, delta: f64) -> u64 {
    let ek = std::f64::consts::E.powi(k as i32);
    (ek * (1.0 / delta).ln() / (epsilon * epsilon)).ceil() as u64
}

/// Median of `t` group means over the samples (Alg 1 line 14).
pub fn median_of_means(samples: &[f64], n_groups: usize) -> f64 {
    assert!(!samples.is_empty());
    let t = n_groups.clamp(1, samples.len());
    let per = samples.len() / t;
    let mut means: Vec<f64> = (0..t)
        .map(|j| {
            let lo = j * per;
            let hi = if j == t - 1 { samples.len() } else { lo + per };
            samples[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
        })
        .collect();
    means.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if t % 2 == 1 {
        means[t / 2]
    } else {
        0.5 * (means[t / 2 - 1] + means[t / 2])
    }
}

/// Run `n_iters` single-rank color-coding iterations and combine.
pub fn estimate(engine: &Engine, g: &Graph, n_iters: usize, seed: u64, n_groups: usize) -> Estimate {
    let samples: Vec<f64> = (0..n_iters)
        .map(|it| {
            engine
                .run_iteration(g, crate::util::mix2(seed, it as u64))
                .estimate
        })
        .collect();
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Estimate {
        value: median_of_means(&samples, n_groups),
        mean,
        samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::colorcount::brute::count_embeddings;
    use crate::graph::{graph_from_edges, rmat::generate, RmatParams};
    use crate::template::builtin;

    #[test]
    fn median_of_means_basics() {
        assert_eq!(median_of_means(&[1.0, 2.0, 3.0], 3), 2.0);
        assert_eq!(median_of_means(&[1.0, 100.0], 1), 50.5);
        // outlier-robust: one wild sample does not dominate
        let s = [10.0, 10.0, 10.0, 10.0, 10.0, 1e6];
        assert!(median_of_means(&s, 3) < 100.0);
    }

    #[test]
    fn iteration_bound_grows() {
        assert!(iteration_bound(5, 0.1, 0.1) > iteration_bound(3, 0.1, 0.1));
        assert!(iteration_bound(3, 0.05, 0.1) > iteration_bound(3, 0.1, 0.1));
    }

    #[test]
    fn converges_to_brute_force_path3() {
        // small dense-ish graph, u3-1: estimator must land near the truth
        let g = generate(&RmatParams::with_skew(32, 140, 1, 9));
        let t = builtin("u3-1").unwrap();
        let truth = count_embeddings(&t, &g);
        assert!(truth > 0.0);
        let e = Engine::new(&t);
        let est = estimate(&e, &g, 600, 42, 3);
        let rel = (est.value - truth).abs() / truth;
        assert!(
            rel < 0.15,
            "estimate {} vs truth {} (rel {rel})",
            est.value,
            truth
        );
    }

    #[test]
    fn converges_to_brute_force_u5_2() {
        let g = generate(&RmatParams::with_skew(24, 90, 1, 5));
        let t = builtin("u5-2").unwrap();
        let truth = count_embeddings(&t, &g);
        assert!(truth > 0.0, "workload must contain u5-2");
        let e = Engine::new(&t);
        let est = estimate(&e, &g, 1500, 7, 3);
        let rel = (est.value - truth).abs() / truth;
        assert!(
            rel < 0.2,
            "estimate {} vs truth {} (rel {rel})",
            est.value,
            truth
        );
    }

    #[test]
    fn exact_when_template_absent() {
        // a star graph contains no P5-chair (needs a path of length 3)
        let g = graph_from_edges(6, &[(0, 1), (0, 2), (0, 3), (0, 4), (0, 5)]);
        let t = builtin("u5-2").unwrap();
        let truth = count_embeddings(&t, &g);
        let e = Engine::new(&t);
        let est = estimate(&e, &g, 50, 3, 3);
        assert_eq!(truth, est.value, "both must be 0? truth={truth}");
    }
}
