//! Exact non-induced embedding counting by backtracking — the test oracle.
//!
//! Counts injective homomorphisms of the tree template into the graph by
//! mapping template vertices in BFS order (each vertex's parent is mapped
//! first, so candidates are exactly the unused neighbors of the parent's
//! image), then divides by `aut(T)` to count subgraph copies. Exponential
//! in general; used only on tiny graphs in tests and examples.

use crate::graph::Graph;
use crate::template::{automorphism_count, Template};

/// Number of injective homomorphisms from `t` into `g`.
pub fn injective_homomorphisms(t: &Template, g: &Graph) -> u64 {
    let n_t = t.size();
    if n_t > g.n_vertices() {
        return 0;
    }
    // BFS order of the template from vertex 0, recording parents
    let mut order = Vec::with_capacity(n_t);
    let mut parent = vec![u32::MAX; n_t];
    let mut seen = vec![false; n_t];
    let mut queue = std::collections::VecDeque::from([0u32]);
    seen[0] = true;
    while let Some(v) = queue.pop_front() {
        order.push(v);
        for &u in &t.adj[v as usize] {
            if !seen[u as usize] {
                seen[u as usize] = true;
                parent[u as usize] = v;
                queue.push_back(u);
            }
        }
    }

    let mut image = vec![u32::MAX; n_t];
    let mut used = vec![false; g.n_vertices()];
    let mut count = 0u64;

    fn rec(
        depth: usize,
        order: &[u32],
        parent: &[u32],
        image: &mut [u32],
        used: &mut [bool],
        g: &Graph,
        count: &mut u64,
    ) {
        if depth == order.len() {
            *count += 1;
            return;
        }
        let tv = order[depth] as usize;
        if depth == 0 {
            for gv in 0..g.n_vertices() as u32 {
                image[tv] = gv;
                used[gv as usize] = true;
                rec(depth + 1, order, parent, image, used, g, count);
                used[gv as usize] = false;
            }
        } else {
            let p_img = image[parent[tv] as usize];
            for &gv in g.neighbors(p_img) {
                if !used[gv as usize] {
                    image[tv] = gv;
                    used[gv as usize] = true;
                    rec(depth + 1, order, parent, image, used, g, count);
                    used[gv as usize] = false;
                }
            }
        }
    }

    rec(0, &order, &parent, &mut image, &mut used, g, &mut count);
    count
}

/// Exact count of non-induced embeddings (subgraph copies isomorphic to
/// `t`): injective homomorphisms divided by automorphisms.
pub fn count_embeddings(t: &Template, g: &Graph) -> f64 {
    let homs = injective_homomorphisms(t, g);
    let aut = automorphism_count(t);
    homs as f64 / aut as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::graph_from_edges;
    use crate::template::builtin;

    #[test]
    fn path3_in_triangle() {
        let g = graph_from_edges(3, &[(0, 1), (1, 2), (2, 0)]);
        let t = builtin("u3-1").unwrap();
        assert_eq!(injective_homomorphisms(&t, &g), 6);
        assert_eq!(count_embeddings(&t, &g), 3.0);
    }

    #[test]
    fn path3_in_star() {
        // star K1,3: P3 embeddings = pairs of leaves through center = C(3,2)=3
        let g = graph_from_edges(4, &[(0, 1), (0, 2), (0, 3)]);
        let t = builtin("u3-1").unwrap();
        assert_eq!(count_embeddings(&t, &g), 3.0);
    }

    #[test]
    fn path3_in_k4() {
        // K4: middle vertex 4 ways × C(3,2) pairs = 12
        let g = graph_from_edges(
            4,
            &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)],
        );
        let t = builtin("u3-1").unwrap();
        assert_eq!(count_embeddings(&t, &g), 12.0);
    }

    #[test]
    fn template_bigger_than_graph() {
        let g = graph_from_edges(3, &[(0, 1), (1, 2)]);
        let t = builtin("u5-2").unwrap();
        assert_eq!(count_embeddings(&t, &g), 0.0);
    }

    #[test]
    fn star5_in_k6() {
        // embeddings of K1,4 in K6: 6 centers × C(5,4) leaf sets = 30
        let mut edges = Vec::new();
        for i in 0..6u32 {
            for j in i + 1..6 {
                edges.push((i, j));
            }
        }
        let g = graph_from_edges(6, &edges);
        let star =
            crate::template::Template::from_edges("s5", 5, &[(0, 1), (0, 2), (0, 3), (0, 4)])
                .unwrap();
        assert_eq!(count_embeddings(&star, &g), 30.0);
    }
}
