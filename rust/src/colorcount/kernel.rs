//! Vectorized SpMM / eMA combine kernels (the SubGraph2Vec decomposition).
//!
//! The factored combine of Eq 1 is two linear-algebra kernels over the
//! active child's color-set columns, viewed as a dense row-major block:
//!
//! ```text
//!   SpMM:  agg[v, ·]  = Σ_{u ∈ N(v)} active[u, ·]      (A · X, A = adjacency)
//!   eMA :  out[v, s] += Σ_j passive[v, t0[s,j]] · agg[v, t1[s,j]]
//! ```
//!
//! This module holds the vectorized forms of both stages, written with
//! explicit chunked-`f32` lanes ([`LANE`]-wide `[f32; 8]` chunks, plain
//! stable Rust — the optimizer maps a fixed-width independent-lane loop
//! straight onto the target's vector registers) plus the `--kernel` knob
//! ([`KernelMode`]) that selects between them and the scalar baseline.
//! The row-block executor that shards the adjacency's CSR view over
//! workers lives in [`super::parallel`]; the per-row arithmetic is here so
//! the serial engine, the parallel executor and the benches share one
//! implementation.
//!
//! # Determinism and tolerance policy
//!
//! * **SpMM stage** ([`add_rows_chunked`]): element-wise `dst[j] += src[j]`
//!   in chunks. Every aggregation slot accumulates independently and in
//!   the same source order as the scalar loop, so this stage is
//!   **bit-identical** to the scalar baseline, always.
//! * **eMA stage** ([`contract_row_simd`]): the per-set gather dot product
//!   runs on [`LANE`] independent accumulators — term `j` lands in lane
//!   `j % LANE` — folded by the fixed reduction tree
//!   `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`. The chunk width and tree
//!   are constants, so the summation order is a pure function of
//!   `n_splits`: reproducible bit-for-bit across runs, worker counts and
//!   block sizes. It *differs* from the scalar kernel's two-accumulator
//!   order, which matters only once f32 rounding occurs: on
//!   integer-valued tables (every DP table, as long as counts stay below
//!   2^24) both orders are exact, hence bit-identical. On general
//!   fractional data the reordering moves each output by at most a few
//!   ULPs (both orders carry the standard `n_splits · ε` bound for sums
//!   of non-negative terms), which is the documented tolerance the
//!   differential suite (`tests/kernel.rs`) pins: bit-identity on
//!   integer tables, ≤ 1e-4 relative on fractional ones.

use super::table::Count;
use crate::combin::CheckedSplit;

/// Chunk width of the explicit f32 lanes. Eight `f32`s fill one AVX2
/// register (and two NEON ones); the fixed width is also what pins the
/// eMA reduction-tree order.
pub const LANE: usize = 8;

/// The `--kernel` knob: which combine kernel the executors run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// the scalar differential baseline (historical per-element loops)
    Scalar,
    /// chunked-lane SpMM + eMA over row-blocks
    Simd,
    /// pick per combine from the shape ([`KernelMode::resolve`])
    Auto,
}

impl KernelMode {
    pub fn name(&self) -> &'static str {
        match self {
            KernelMode::Scalar => "scalar",
            KernelMode::Simd => "simd",
            KernelMode::Auto => "auto",
        }
    }

    /// Parse the CLI/config spelling; `None` for unknown names.
    pub fn parse(name: &str) -> Option<KernelMode> {
        match name {
            "scalar" => Some(KernelMode::Scalar),
            "simd" => Some(KernelMode::Simd),
            "auto" => Some(KernelMode::Auto),
            _ => None,
        }
    }

    /// Resolve `Auto` for one combine from its aggregation-row width:
    /// the chunked kernels win once a row spans at least one full lane
    /// chunk; narrower rows (tiny subtemplates) stay on the scalar path
    /// where the chunk remainder handling is pure overhead. The input is
    /// a pure function of the template shape — identical on every rank
    /// and worker, so the choice can never diverge across a run.
    pub fn resolve(&self, n_agg: usize) -> ResolvedKernel {
        match self {
            KernelMode::Scalar => ResolvedKernel::Scalar,
            KernelMode::Simd => ResolvedKernel::Simd,
            KernelMode::Auto => {
                if n_agg >= LANE {
                    ResolvedKernel::Simd
                } else {
                    ResolvedKernel::Scalar
                }
            }
        }
    }
}

/// A concrete kernel choice for one combine (no `Auto` left).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResolvedKernel {
    Scalar,
    Simd,
}

/// The SpMM inner step: `dst[j] += src[j]` over explicit [`LANE`]-wide
/// chunks with a scalar remainder. Each slot accumulates independently in
/// the same order as the scalar loop, so this is bit-identical to it —
/// the chunking only tells the optimizer the lanes don't alias.
#[inline]
pub fn add_rows_chunked(dst: &mut [Count], src: &[Count]) {
    assert_eq!(dst.len(), src.len(), "row widths must match");
    let mut d = dst.chunks_exact_mut(LANE);
    let mut s = src.chunks_exact(LANE);
    for (dc, sc) in d.by_ref().zip(s.by_ref()) {
        // fixed-size array views: the length is a compile-time constant,
        // so the loop below compiles to one vector add per chunk
        let dc: &mut [Count; LANE] = dc.try_into().unwrap();
        let sc: &[Count; LANE] = sc.try_into().unwrap();
        for l in 0..LANE {
            dc[l] += sc[l];
        }
    }
    for (a, &x) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *a += x;
    }
}

/// Fold [`LANE`] lane accumulators through the fixed reduction tree —
/// THE one place the eMA summation order is defined.
#[inline]
fn reduce_lanes(acc: [f32; LANE]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// The vectorized eMA stage: contract one vertex row through the split
/// table, `orow[s] += Σ_j prow[idx1[s,j]] · arow[idx2[s,j]]`, with the
/// per-set dot product spread over [`LANE`] accumulators (term `j` in
/// lane `j % LANE`) and folded by [`reduce_lanes`]. Same contraction as
/// the scalar `contract_row`, reordered as documented in the module docs.
/// Returns the (set, split) units processed.
pub(crate) fn contract_row_simd(
    orow: &mut [Count],
    prow: &[Count],
    arow: &[Count],
    cs: &CheckedSplit<'_>,
) -> u64 {
    let split = cs.split();
    let n_splits = split.n_splits;
    let n_sets = split.n_sets;
    // the checked-construction contract: `cs` validated every idx1/idx2
    // against these widths, so the row-length equalities below are the
    // only remaining obligations of the unchecked gathers
    assert_eq!(prow.len(), cs.n_passive(), "passive row width");
    assert_eq!(arow.len(), cs.n_agg(), "aggregation row width");
    assert_eq!(orow.len(), n_sets, "output row width");
    let idx1 = &split.idx1[..n_sets * n_splits];
    let idx2 = &split.idx2[..n_sets * n_splits];
    let mut flat = 0usize;
    for o in orow.iter_mut() {
        let mut acc = [0.0f32; LANE];
        let mut j = 0;
        // SAFETY: flat+j+l < n_sets*n_splits by the loop bounds, so the
        // idx reads are in range of the slices above; the gathered
        // prow/arow indices are < prow.len()/arow.len() because `cs`
        // validated every table entry against exactly these widths at
        // construction (CheckedSplit::new), asserted again per row above.
        unsafe {
            while j + LANE <= n_splits {
                for (l, a) in acc.iter_mut().enumerate() {
                    let p = *prow.get_unchecked(*idx1.get_unchecked(flat + j + l) as usize);
                    let x = *arow.get_unchecked(*idx2.get_unchecked(flat + j + l) as usize);
                    *a += p * x;
                }
                j += LANE;
            }
            // remainder terms land in lanes 0..(n_splits % LANE), keeping
            // lane l = Σ of terms j ≡ l (mod LANE) exactly
            let mut l = 0;
            while j < n_splits {
                let p = *prow.get_unchecked(*idx1.get_unchecked(flat + j) as usize);
                let x = *arow.get_unchecked(*idx2.get_unchecked(flat + j) as usize);
                acc[l] += p * x;
                l += 1;
                j += 1;
            }
        }
        flat += n_splits;
        *o += reduce_lanes(acc);
    }
    (n_sets * n_splits) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::combin::{Binomial, SplitTable};
    use crate::util::prop;

    #[test]
    fn kernel_mode_parse_roundtrip() {
        for m in [KernelMode::Scalar, KernelMode::Simd, KernelMode::Auto] {
            assert_eq!(KernelMode::parse(m.name()), Some(m));
        }
        assert_eq!(KernelMode::parse("avx"), None);
    }

    #[test]
    fn auto_resolves_by_lane_width() {
        assert_eq!(KernelMode::Auto.resolve(LANE), ResolvedKernel::Simd);
        assert_eq!(KernelMode::Auto.resolve(LANE - 1), ResolvedKernel::Scalar);
        assert_eq!(KernelMode::Scalar.resolve(1000), ResolvedKernel::Scalar);
        assert_eq!(KernelMode::Simd.resolve(1), ResolvedKernel::Simd);
    }

    #[test]
    fn chunked_add_bit_identical_to_scalar() {
        prop::check("add_rows_chunked", |gen| {
            let n = gen.usize_in(0, 40);
            let mut dst: Vec<f32> = (0..n).map(|i| (i as f32) * 0.37 + 0.1).collect();
            let src: Vec<f32> = (0..n).map(|i| (i as f32) * 1.13 - 3.0).collect();
            let mut want = dst.clone();
            for (a, &x) in want.iter_mut().zip(&src) {
                *a += x;
            }
            add_rows_chunked(&mut dst, &src);
            for (a, b) in dst.iter().zip(&want) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("chunked add moved a bit: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    /// The documented reduction-tree order, pinned: lane l holds the sum
    /// of terms j ≡ l (mod LANE), folded ((0+1)+(2+3))+((4+5)+(6+7)).
    /// A reference implementation of exactly that order must match the
    /// kernel bit-for-bit on arbitrary fractional inputs.
    #[test]
    fn prop_reduction_tree_order_is_pinned() {
        prop::check("simd_tree_order", |gen| {
            let binom = Binomial::new();
            let k = gen.usize_in(4, 8);
            let a = gen.usize_in(2, k);
            let a1 = gen.usize_in(1, a - 1);
            let split = SplitTable::new(k, a, a1, &binom);
            let c1 = binom.c(k, a1) as usize;
            let c2 = binom.c(k, a - a1) as usize;
            let prow: Vec<f32> = (0..c1).map(|i| (i as f32) * 0.311 + 0.77).collect();
            let arow: Vec<f32> = (0..c2).map(|i| (i as f32) * 0.177 + 0.35).collect();
            let cs = crate::combin::CheckedSplit::new(&split, c1, c2);
            let mut got = vec![0.0f32; split.n_sets];
            contract_row_simd(&mut got, &prow, &arow, &cs);
            // reference: the documented order, written naively
            for s in 0..split.n_sets {
                let (r1, r2) = split.row(s);
                let mut lanes = [0.0f32; LANE];
                for j in 0..split.n_splits {
                    lanes[j % LANE] += prow[r1[j] as usize] * arow[r2[j] as usize];
                }
                let want = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
                    + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
                if got[s].to_bits() != want.to_bits() {
                    return Err(format!(
                        "set {s}: kernel {} != documented order {want}",
                        got[s]
                    ));
                }
            }
            Ok(())
        });
    }

    /// The ULP policy: on integer-valued rows the reordered sum is exact,
    /// hence bit-identical to the scalar kernel; on fractional rows it
    /// stays within the documented relative tolerance.
    #[test]
    fn simd_contract_matches_scalar_within_policy() {
        let binom = Binomial::new();
        let split = SplitTable::new(6, 4, 2, &binom);
        let c1 = binom.c(6, 2) as usize;
        let c2 = binom.c(6, 2) as usize;
        let cs = crate::combin::CheckedSplit::new(&split, c1, c2);

        // integer-valued: bit identity
        let prow: Vec<f32> = (0..c1).map(|i| ((i * 3) % 7) as f32).collect();
        let arow: Vec<f32> = (0..c2).map(|i| ((i * 5) % 4) as f32).collect();
        let mut simd = vec![0.0f32; split.n_sets];
        let mut scalar = vec![0.0f32; split.n_sets];
        contract_row_simd(&mut simd, &prow, &arow, &cs);
        crate::colorcount::engine::contract_row(&mut scalar, &prow, &arow, &cs);
        for (a, b) in simd.iter().zip(&scalar) {
            assert_eq!(a.to_bits(), b.to_bits(), "integer rows must be exact");
        }

        // fractional: ≤ 1e-4 relative (far looser than the ~n_splits·ε
        // bound both orders carry; the slack keeps the test robust)
        let prow: Vec<f32> = (0..c1).map(|i| (i as f32) * 0.123 + 0.531).collect();
        let arow: Vec<f32> = (0..c2).map(|i| (i as f32) * 0.731 + 0.25).collect();
        let mut simd = vec![0.0f32; split.n_sets];
        let mut scalar = vec![0.0f32; split.n_sets];
        contract_row_simd(&mut simd, &prow, &arow, &cs);
        crate::colorcount::engine::contract_row(&mut scalar, &prow, &arow, &cs);
        for (a, b) in simd.iter().zip(&scalar) {
            let rel = (a - b).abs() / b.abs().max(1e-12);
            assert!(rel <= 1e-4, "fractional rows out of tolerance: {a} vs {b}");
        }
    }

    #[test]
    #[should_panic(expected = "aggregation row width")]
    fn contract_row_simd_rejects_missized_agg_row() {
        let binom = Binomial::new();
        let split = SplitTable::new(5, 3, 1, &binom);
        let cs = crate::combin::CheckedSplit::new(&split, 5, 10);
        let mut orow = vec![0.0f32; split.n_sets];
        let prow = vec![0.0f32; 5];
        let arow = vec![0.0f32; 9]; // one short of the validated width
        contract_row_simd(&mut orow, &prow, &arow, &cs);
    }
}
