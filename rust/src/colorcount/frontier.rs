//! Per-table nonzero-row frontiers: which local rows of a finalized
//! subtemplate table carry any nonzero count.
//!
//! On deep subtemplates most vertices hold all-zero count rows (a row is
//! live only when some colorful embedding roots there), yet the combine
//! streams every adjacency pair and contracts every vertex regardless.
//! The frontier makes the dead set explicit — a dense bitmap plus a
//! popcount-backed iterator — so the aggregation, contraction and
//! exchange layers can skip structurally-zero work:
//!
//! * **aggregation**: a pair `(v, u)` whose active row `u` is dead only
//!   adds `+0.0` to every slot of `agg[v,·]`;
//! * **contraction**: a dead passive row zeroes every product term of
//!   `out[v,s] = Σ_j passive[v,t0]·agg[v,t1]`;
//! * **exchange**: a dead requested row ships `n_sets` zero bytes that
//!   fold into nothing on the receiver.
//!
//! Skipping all three is **bit-exact** because counts are non-negative
//! and never `-0.0` or NaN: omitting `+= 0.0` terms from an independent
//! running sum cannot move a bit, and a product with an exact `0.0`
//! factor is an exact `0.0` (same invariant the sparse storage layer
//! leans on — see `super::storage` module docs).
//!
//! Frontier bitmaps are constructed **only here**: the rest of the tree
//! reads them through the blessed accessors [`CountTable::frontier`] /
//! [`TableStorage::frontier`] (inherent impls below), so membership is
//! always derived from the table that was actually stored — the
//! analysis gate (`analysis::RULE_FRONTIER`) enforces the confinement
//! textually.

use super::storage::TableStorage;
use super::table::CountTable;

/// The `--prune` knob: whether the combine consults frontiers at all.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PruneMode {
    /// consult the frontier in every combine phase
    On,
    /// the historical behaviour: stream every pair/row (default)
    #[default]
    Off,
    /// prune per table, only when the measured frontier occupancy is low
    /// enough for the bitmap probes to pay for themselves
    Auto,
}

/// `Auto` cutoff: prune when fewer than this fraction of rows are live.
/// Near-full frontiers make every probe a taken branch for no skipped
/// work; below ~3/4 the dead-row savings dominate the probe cost.
pub const AUTO_OCCUPANCY_CUTOFF: f64 = 0.75;

impl PruneMode {
    pub fn name(&self) -> &'static str {
        match self {
            PruneMode::On => "on",
            PruneMode::Off => "off",
            PruneMode::Auto => "auto",
        }
    }

    /// Parse the CLI/config spelling; `None` for unknown names.
    pub fn parse(name: &str) -> Option<PruneMode> {
        match name {
            "on" => Some(PruneMode::On),
            "off" => Some(PruneMode::Off),
            "auto" => Some(PruneMode::Auto),
            _ => None,
        }
    }

    /// Should a combine whose active/passive table measured the given
    /// frontier occupancy prune through the bitmap? Deterministic in the
    /// data — every rank answers identically for the same table, which
    /// keeps pruning decisions globally consistent without negotiation.
    pub fn active_for(&self, occupancy: f64) -> bool {
        match self {
            PruneMode::On => true,
            PruneMode::Off => false,
            PruneMode::Auto => occupancy < AUTO_OCCUPANCY_CUTOFF,
        }
    }
}

/// The nonzero-row set of one finalized count table: a dense bitmap
/// (one bit per local row) with the live count cached. Fields are
/// private — construction happens only through the blessed accessors in
/// this module, so a `Frontier` always reflects a real table's rows.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frontier {
    n_rows: usize,
    /// `ceil(n_rows / 64)` presence words, row `r` at bit `r % 64` of
    /// word `r / 64`; bits at or past `n_rows` are always clear
    words: Vec<u64>,
    /// popcount of `words` (number of live rows)
    live: usize,
}

impl Frontier {
    /// The all-live frontier: every row present. What prune-off phases
    /// and leaf tables (every row one-hot) see.
    pub fn full(n_rows: usize) -> Frontier {
        let mut words = vec![u64::MAX; n_rows.div_ceil(64)];
        if n_rows % 64 != 0 {
            if let Some(last) = words.last_mut() {
                *last = (1u64 << (n_rows % 64)) - 1;
            }
        }
        Frontier {
            n_rows,
            words,
            live: n_rows,
        }
    }

    /// Build from a per-row liveness probe (internal: the accessors
    /// below supply the probe from the table representation).
    fn of_rows(n_rows: usize, mut row_live: impl FnMut(usize) -> bool) -> Frontier {
        let mut words = vec![0u64; n_rows.div_ceil(64)];
        let mut live = 0usize;
        for r in 0..n_rows {
            if row_live(r) {
                words[r / 64] |= 1u64 << (r % 64);
                live += 1;
            }
        }
        Frontier {
            n_rows,
            words,
            live,
        }
    }

    /// Is row `r` live (has any nonzero entry)? Out-of-range rows are
    /// dead.
    #[inline]
    pub fn contains(&self, r: usize) -> bool {
        r < self.n_rows && (self.words[r / 64] >> (r % 64)) & 1 == 1
    }

    /// Number of live rows.
    #[inline]
    pub fn live_rows(&self) -> usize {
        self.live
    }

    /// Number of rows the frontier covers (live or dead).
    #[inline]
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Fraction of rows live. An empty table reports 1.0 — there is
    /// nothing to skip, so `Auto` must not bother pruning it.
    pub fn occupancy(&self) -> f64 {
        if self.n_rows == 0 {
            1.0
        } else {
            self.live as f64 / self.n_rows as f64
        }
    }

    /// Iterate the live row indices in ascending order (word-at-a-time
    /// with `trailing_zeros`, clearing the lowest set bit per step).
    pub fn iter(&self) -> FrontierIter<'_> {
        FrontierIter {
            words: &self.words,
            word_idx: 0,
            current: self.words.first().copied().unwrap_or(0),
        }
    }
}

/// Ascending live-row iterator over a [`Frontier`]'s bitmap.
pub struct FrontierIter<'a> {
    words: &'a [u64],
    word_idx: usize,
    current: u64,
}

impl Iterator for FrontierIter<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        while self.current == 0 {
            self.word_idx += 1;
            if self.word_idx >= self.words.len() {
                return None;
            }
            self.current = self.words[self.word_idx];
        }
        let bit = self.current.trailing_zeros() as usize;
        self.current &= self.current - 1; // clear the lowest set bit
        Some(self.word_idx * 64 + bit)
    }
}

impl CountTable {
    /// The nonzero-row frontier of this table: row `r` is live iff
    /// `row(r)` has any nonzero entry (exactly `nnz(row) > 0`).
    pub fn frontier(&self) -> Frontier {
        Frontier::of_rows(self.n_rows, |r| self.row(r).iter().any(|&x| x != 0.0))
    }
}

impl TableStorage {
    /// The nonzero-row frontier of the stored table — identical for
    /// either representation of the same rows (a sparse row is live iff
    /// it has entries; compression preserves nnz exactly).
    pub fn frontier(&self) -> Frontier {
        match self {
            TableStorage::Dense(t) => t.frontier(),
            TableStorage::Sparse(t) => {
                Frontier::of_rows(t.n_rows, |r| !t.row_entries(r).is_empty())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::colorcount::storage::SparseTable;
    use crate::util::prop;

    fn random_table(gen: &mut prop::Gen) -> CountTable {
        let n_rows = gen.usize_in(0, 40);
        let n_sets = gen.usize_in(1, 9);
        let mut t = CountTable::zeros(n_rows, n_sets);
        for r in 0..n_rows {
            match gen.usize_in(0, 3) {
                0 => {} // all-zero row
                1 => {
                    for x in t.row_mut(r) {
                        *x = 1.0 + (r as f32) * 0.5;
                    }
                }
                _ => {
                    for s in 0..n_sets {
                        if gen.usize_in(0, 2) == 0 {
                            t.row_mut(r)[s] = (1 + s + r) as f32;
                        }
                    }
                }
            }
        }
        t
    }

    /// Tentpole invariant: frontier membership exactly equals row-nnz > 0,
    /// for both representations of the same table, and the iterator
    /// enumerates exactly the live set in ascending order.
    #[test]
    fn prop_membership_equals_row_nnz() {
        prop::check("frontier_membership", |gen| {
            let t = random_table(gen);
            let dense = t.frontier();
            let sp = TableStorage::Sparse(SparseTable::from_dense(&t));
            let sparse = sp.frontier();
            if dense != sparse {
                return Err("representations disagree on the frontier".into());
            }
            let mut live = 0usize;
            for r in 0..t.n_rows {
                let nnz = t.row(r).iter().filter(|&&x| x != 0.0).count();
                if dense.contains(r) != (nnz > 0) {
                    return Err(format!("row {r}: contains != nnz>0 ({nnz})"));
                }
                live += (nnz > 0) as usize;
            }
            if dense.live_rows() != live {
                return Err(format!("live_rows {} != {live}", dense.live_rows()));
            }
            let iterated: Vec<usize> = dense.iter().collect();
            let expect: Vec<usize> = (0..t.n_rows).filter(|&r| dense.contains(r)).collect();
            if iterated != expect {
                return Err(format!("iter {iterated:?} != contains-set {expect:?}"));
            }
            if dense.contains(t.n_rows) || dense.contains(t.n_rows + 63) {
                return Err("out-of-range rows must read dead".into());
            }
            Ok(())
        });
    }

    #[test]
    fn full_frontier_has_every_row() {
        for n in [0usize, 1, 63, 64, 65, 130] {
            let f = Frontier::full(n);
            assert_eq!(f.live_rows(), n);
            assert_eq!(f.n_rows(), n);
            assert_eq!(f.iter().count(), n);
            assert!((0..n).all(|r| f.contains(r)));
            assert!(!f.contains(n));
            assert_eq!(f.occupancy(), 1.0);
        }
    }

    #[test]
    fn occupancy_and_empty_table() {
        let mut t = CountTable::zeros(4, 3);
        t.row_mut(1)[2] = 5.0;
        let f = t.frontier();
        assert_eq!(f.live_rows(), 1);
        assert!((f.occupancy() - 0.25).abs() < 1e-12);
        // empty table: occupancy 1.0 so Auto never prunes it
        assert_eq!(CountTable::zeros(0, 3).frontier().occupancy(), 1.0);
    }

    #[test]
    fn prune_mode_parse_roundtrip() {
        for m in [PruneMode::On, PruneMode::Off, PruneMode::Auto] {
            assert_eq!(PruneMode::parse(m.name()), Some(m));
        }
        assert_eq!(PruneMode::parse("yes"), None);
        assert_eq!(PruneMode::default(), PruneMode::Off);
    }

    #[test]
    fn auto_prunes_only_sparse_frontiers() {
        assert!(PruneMode::On.active_for(1.0));
        assert!(!PruneMode::Off.active_for(0.0));
        assert!(PruneMode::Auto.active_for(0.2));
        assert!(!PruneMode::Auto.active_for(1.0));
        assert!(!PruneMode::Auto.active_for(AUTO_OCCUPANCY_CUTOFF));
    }
}
