//! Adaptive dense/sparse storage for count tables.
//!
//! Tree-template count tables are overwhelmingly sparse for small and
//! mid-size subtemplates (a leaf table is one-hot: density exactly 1/k),
//! yet the DP kernels want dense rows for their gathered contraction.
//! This module is the seam between the two worlds:
//!
//! * [`SparseTable`] — a CSR-style `(set_rank, count)` per-row layout;
//! * [`TableStorage`] — a count table *at rest*, in whichever
//!   representation the [`StoragePolicy`] picked from the measured
//!   density ([`CountTable::density`]);
//! * [`RowsRef`] — a borrowed row source feeding the aggregation kernels
//!   (`agg[v,·] += row(u)`), dense or sparse. Skipping a row's zero
//!   entries is **bit-exact**: every aggregation slot accumulates
//!   independently, and omitting `+= 0.0` terms from a non-negative
//!   running sum cannot move a bit (counts are never `-0.0` or NaN);
//! * [`RowsPayload`] + [`encode_rows`] — the one wire codec both exchange
//!   executors share. A packet's byte size ([`RowsPayload::wire_bytes`])
//!   *is* the resident size of the decoded table, so the fabric's
//!   accounting, the `MemoryAccountant` ledger and the Hockney model all
//!   speak the same byte counts.
//!
//! Representation never changes numerics: compressing and re-reading a
//! table reproduces the dense rows exactly (round-trip property tests
//! below), so estimates are bit-identical across every storage mode —
//! the invariant `tests/storage.rs` enforces end to end.

use super::table::{Count, CountTable};

/// Auto-policy default: store a table sparse when fewer than this
/// fraction of its entries are non-zero (and the sparse layout is
/// actually smaller — an entry costs 8 bytes against 4 dense, so the
/// break-even sits near density 1/2; 0.35 leaves margin for the per-row
/// offset overhead and the scatter/gather cost of sparse iteration).
pub const DEFAULT_SPARSE_THRESHOLD: f64 = 0.35;

/// Bytes of one sparse entry on the wire and in memory: a `u32` set rank
/// plus an `f32` count.
pub const SPARSE_ENTRY_BYTES: u64 = 8;

/// Bytes of one per-row offset (`u32`).
pub const SPARSE_OFFSET_BYTES: u64 = 4;

/// The `--table-storage` knob: which representation count tables use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorageMode {
    /// today's unconditional dense `Vec<f32>` layout
    Dense,
    /// force the per-row `(set_rank, count)` layout everywhere it fits
    Sparse,
    /// pick per table from the measured density ([`CountTable::density`])
    Auto,
}

impl StorageMode {
    pub fn name(&self) -> &'static str {
        match self {
            StorageMode::Dense => "dense",
            StorageMode::Sparse => "sparse",
            StorageMode::Auto => "auto",
        }
    }

    /// Parse the CLI/config spelling; `None` for unknown names.
    pub fn parse(name: &str) -> Option<StorageMode> {
        match name {
            "dense" => Some(StorageMode::Dense),
            "sparse" => Some(StorageMode::Sparse),
            "auto" => Some(StorageMode::Auto),
            _ => None,
        }
    }
}

/// The per-table storage decision rule. One policy instance drives a
/// whole run; decisions are taken per freshly built table from its
/// measured non-zero count, so they are deterministic given the data.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StoragePolicy {
    pub mode: StorageMode,
    /// `Auto` density cutoff (see [`DEFAULT_SPARSE_THRESHOLD`])
    pub sparse_threshold: f64,
}

impl StoragePolicy {
    /// The historical behaviour: everything dense.
    pub fn dense() -> StoragePolicy {
        Self::of(StorageMode::Dense)
    }

    pub fn of(mode: StorageMode) -> StoragePolicy {
        StoragePolicy {
            mode,
            sparse_threshold: DEFAULT_SPARSE_THRESHOLD,
        }
    }

    /// Should a freshly built `n_rows × n_sets` table with `nnz` non-zero
    /// entries be stored sparse? `Sparse` forces it wherever the `u32`
    /// entry indexing fits; `Auto` additionally requires the measured
    /// density to undercut the threshold *and* the sparse layout to be
    /// genuinely smaller in bytes.
    pub fn wants_sparse(&self, n_rows: usize, n_sets: usize, nnz: usize) -> bool {
        if nnz > u32::MAX as usize {
            return false; // offsets are u32: fall back to dense
        }
        match self.mode {
            StorageMode::Dense => false,
            StorageMode::Sparse => true,
            StorageMode::Auto => {
                let cells = n_rows * n_sets;
                if cells == 0 {
                    return false;
                }
                let density = nnz as f64 / cells as f64;
                density < self.sparse_threshold
                    && SparseTable::bytes_for(n_rows, nnz)
                        < CountTable::dense_bytes_for(n_rows, n_sets)
            }
        }
    }
}

/// Expected wire/resident bytes of one sparse-encoded row at the given
/// density — the Hockney model's per-row charge under sparse encoding
/// (entries plus this row's offset share). The executors' per-step comm
/// uses the fabric's *measured* bytes; this expectation only feeds the
/// `CommDecision` ρ predictions, calibrated from the previous iteration's
/// measured density.
pub fn expected_sparse_row_bytes(density: f64, n_sets: usize) -> f64 {
    density.clamp(0.0, 1.0) * n_sets as f64 * SPARSE_ENTRY_BYTES as f64
        + SPARSE_OFFSET_BYTES as f64
}

/// CSR-style sparse count table: per row, the `(set_rank, count)` pairs
/// of its non-zero entries, set ranks strictly ascending. Semantically
/// identical to the dense table it was built from — `to_dense` is an
/// exact inverse of `from_dense` (bitwise, including `total()`).
#[derive(Debug, Clone, PartialEq)]
pub struct SparseTable {
    pub n_rows: usize,
    pub n_sets: usize,
    /// `n_rows + 1` monotone offsets into `entries`
    pub offsets: Vec<u32>,
    /// `(set_rank, count)` pairs, row-major, ranks ascending within a row
    pub entries: Vec<(u32, Count)>,
}

impl SparseTable {
    /// Compress a dense table (entries must fit `u32` indexing — the
    /// policy's `wants_sparse` guarantees it).
    pub fn from_dense(t: &CountTable) -> SparseTable {
        Self::from_dense_counted(t, t.nnz())
    }

    /// [`Self::from_dense`] with the non-zero count already known (the
    /// policy path measures it once and passes it down, so storing a
    /// table costs one counting sweep plus the compression pass). `nnz`
    /// must equal `t.nnz()`; it only sizes the buffer and guards the
    /// `u32` indexing.
    pub fn from_dense_counted(t: &CountTable, nnz: usize) -> SparseTable {
        debug_assert_eq!(nnz, t.nnz());
        assert!(nnz <= u32::MAX as usize, "sparse table exceeds u32 indexing");
        let mut offsets = Vec::with_capacity(t.n_rows + 1);
        let mut entries = Vec::with_capacity(nnz);
        offsets.push(0u32);
        for r in 0..t.n_rows {
            for (s, &x) in t.row(r).iter().enumerate() {
                if x != 0.0 {
                    entries.push((s as u32, x));
                }
            }
            offsets.push(entries.len() as u32);
        }
        SparseTable {
            n_rows: t.n_rows,
            n_sets: t.n_sets,
            offsets,
            entries,
        }
    }

    /// Exact dense reconstruction (round-trip inverse of `from_dense`).
    pub fn to_dense(&self) -> CountTable {
        let mut t = CountTable::zeros(self.n_rows, self.n_sets);
        for r in 0..self.n_rows {
            let row = t.row_mut(r);
            for &(s, x) in self.row_entries(r) {
                row[s as usize] = x;
            }
        }
        t
    }

    #[inline]
    pub fn row_entries(&self, r: usize) -> &[(u32, Count)] {
        let lo = self.offsets[r] as usize;
        let hi = self.offsets[r + 1] as usize;
        &self.entries[lo..hi]
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Resident bytes of this layout — equal, by construction, to the
    /// wire bytes of the same rows under sparse encoding.
    pub fn bytes(&self) -> u64 {
        Self::bytes_for(self.n_rows, self.entries.len())
    }

    /// Layout bytes of an `n_rows`-row sparse table with `nnz` entries.
    pub fn bytes_for(n_rows: usize, nnz: usize) -> u64 {
        (n_rows as u64 + 1) * SPARSE_OFFSET_BYTES + nnz as u64 * SPARSE_ENTRY_BYTES
    }

    /// Sum of every entry (f64 accumulation, row-major entry order —
    /// bit-identical to the dense `total()`, which only adds `+0.0`
    /// terms where this skips them).
    pub fn total(&self) -> f64 {
        self.entries.iter().map(|&(_, x)| x as f64).sum()
    }

    /// Fraction of non-zero entries.
    pub fn density(&self) -> f64 {
        let cells = self.n_rows * self.n_sets;
        if cells == 0 {
            0.0
        } else {
            self.entries.len() as f64 / cells as f64
        }
    }
}

/// A count table at rest, in whichever representation the policy picked.
/// This is what the coordinator's per-subtemplate slots hold; the DP
/// kernels read it through [`RowsRef`] / a materialized passive row.
#[derive(Debug, Clone)]
pub enum TableStorage {
    Dense(CountTable),
    Sparse(SparseTable),
}

impl TableStorage {
    /// Store a freshly built dense table per the policy, measuring its
    /// non-zero count on the way (the [`CountTable::density`] probe —
    /// this is the decision input *and* the per-subtemplate figure the
    /// report surfaces). The count is taken once and threaded through
    /// the whole decision + compression, so storing a table costs one
    /// counting sweep regardless of the outcome. Returns the storage
    /// plus the measured `nnz`.
    pub fn from_dense_policy(t: CountTable, policy: &StoragePolicy) -> (TableStorage, usize) {
        let nnz = t.nnz();
        if policy.wants_sparse(t.n_rows, t.n_sets, nnz) {
            (
                TableStorage::Sparse(SparseTable::from_dense_counted(&t, nnz)),
                nnz,
            )
        } else {
            (TableStorage::Dense(t), nnz)
        }
    }

    /// Decode a received payload into a table (moves the payload's
    /// buffers — receiving never copies a row). Validates the sparse
    /// structure (monotone offsets, strictly ascending in-range ranks):
    /// the aggregation kernels scatter through these indices unchecked.
    pub fn from_payload(payload: RowsPayload, n_sets: usize) -> TableStorage {
        match payload {
            RowsPayload::Dense(data) => {
                let n_sets = n_sets.max(1);
                debug_assert_eq!(data.len() % n_sets, 0);
                TableStorage::Dense(CountTable {
                    n_rows: data.len() / n_sets,
                    n_sets,
                    data,
                })
            }
            RowsPayload::Sparse { offsets, entries } => {
                assert!(
                    !offsets.is_empty() && offsets[0] == 0,
                    "sparse payload: offsets must start at 0"
                );
                assert_eq!(
                    *offsets.last().unwrap() as usize,
                    entries.len(),
                    "sparse payload: last offset must equal the entry count"
                );
                for w in offsets.windows(2) {
                    assert!(w[0] <= w[1], "sparse payload: offsets must be monotone");
                    let (lo, hi) = (w[0] as usize, w[1] as usize);
                    let mut prev: Option<u32> = None;
                    for &(rank, _) in &entries[lo..hi] {
                        assert!(
                            (rank as usize) < n_sets,
                            "sparse payload: set rank {rank} out of range ({n_sets})"
                        );
                        if let Some(p) = prev {
                            assert!(p < rank, "sparse payload: set ranks must ascend within a row");
                        }
                        prev = Some(rank);
                    }
                }
                TableStorage::Sparse(SparseTable {
                    n_rows: offsets.len() - 1,
                    n_sets,
                    offsets,
                    entries,
                })
            }
            RowsPayload::Masked {
                n_rows,
                mask,
                offsets,
                entries,
            } => {
                let n_rows = n_rows as usize;
                assert_eq!(
                    mask.len(),
                    n_rows.div_ceil(64),
                    "masked payload: mask word count"
                );
                if n_rows % 64 != 0 {
                    if let Some(&last) = mask.last() {
                        assert_eq!(
                            last >> (n_rows % 64),
                            0,
                            "masked payload: bits past n_rows must be clear"
                        );
                    }
                }
                let live: usize = mask.iter().map(|w| w.count_ones() as usize).sum();
                assert_eq!(
                    offsets.len(),
                    live + 1,
                    "masked payload: one offset per live row"
                );
                assert_eq!(offsets[0], 0, "masked payload: offsets must start at 0");
                assert!(
                    offsets.windows(2).all(|w| w[0] < w[1]),
                    "masked payload: live rows must be non-empty"
                );
                // Expand back to the full positional CSR (dead rows
                // empty), then run the sparse structural validation on
                // the result — receivers index rows positionally, so the
                // expansion is what restores `plans[p][q]` addressing.
                let mut full = Vec::with_capacity(n_rows + 1);
                full.push(0u32);
                let mut next_live = 1usize;
                for r in 0..n_rows {
                    if (mask[r / 64] >> (r % 64)) & 1 == 1 {
                        full.push(offsets[next_live]);
                        next_live += 1;
                    } else {
                        full.push(*full.last().unwrap());
                    }
                }
                TableStorage::from_payload(
                    RowsPayload::Sparse {
                        offsets: full,
                        entries,
                    },
                    n_sets,
                )
            }
        }
    }

    pub fn n_rows(&self) -> usize {
        match self {
            TableStorage::Dense(t) => t.n_rows,
            TableStorage::Sparse(t) => t.n_rows,
        }
    }

    pub fn n_sets(&self) -> usize {
        match self {
            TableStorage::Dense(t) => t.n_sets,
            TableStorage::Sparse(t) => t.n_sets,
        }
    }

    /// Sum of every entry — bit-identical across representations.
    pub fn total(&self) -> f64 {
        match self {
            TableStorage::Dense(t) => t.total(),
            TableStorage::Sparse(t) => t.total(),
        }
    }

    /// Resident bytes of the live representation (what the memory
    /// accountant charges).
    pub fn bytes(&self) -> u64 {
        match self {
            TableStorage::Dense(t) => t.bytes(),
            TableStorage::Sparse(t) => t.bytes(),
        }
    }

    /// What the unconditional dense layout would hold for this table —
    /// the baseline the report's `bytes_saved` delta is measured against.
    pub fn dense_bytes(&self) -> u64 {
        CountTable::dense_bytes_for(self.n_rows(), self.n_sets())
    }

    pub fn density(&self) -> f64 {
        match self {
            TableStorage::Dense(t) => t.density(),
            TableStorage::Sparse(t) => t.density(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, TableStorage::Sparse(_))
    }

    pub fn as_rows(&self) -> RowsRef<'_> {
        match self {
            TableStorage::Dense(t) => RowsRef::dense(t),
            TableStorage::Sparse(t) => RowsRef::sparse(t),
        }
    }

    /// The dense table behind this storage. Only the serial-scratch XLA
    /// combine path calls this, and that path forces a dense policy —
    /// a sparse table here is a coordinator bug.
    pub fn as_dense(&self) -> &CountTable {
        match self {
            TableStorage::Dense(t) => t,
            TableStorage::Sparse(_) => {
                panic!("dense table required (XLA serial path runs a dense-only policy)")
            }
        }
    }
}

/// A borrowed row source for the aggregation kernels: rows of the active
/// child's table (local, or one received step buffer), dense or sparse.
///
/// Construction is **checked**: [`RowsRef::dense`] validates the table's
/// shape coherence (`data.len() == n_rows * n_sets`) and
/// [`RowsRef::sparse`] validates the CSR structure (offset vector length
/// and monotonicity, entry count, set ranks `< n_sets`) — once per
/// borrow, in release builds too. The representation is private, so
/// every `RowsRef` in the program went through these checks; that
/// invariant (not a caller comment) is what justifies the per-element
/// unchecked accesses in the hot kernels below.
#[derive(Clone, Copy)]
pub struct RowsRef<'a>(RowsRepr<'a>);

#[derive(Clone, Copy)]
enum RowsRepr<'a> {
    Dense(&'a CountTable),
    Sparse(&'a SparseTable),
}

impl<'a> RowsRef<'a> {
    /// Borrow a dense table as a row source.
    ///
    /// # Panics
    /// When the table's buffer does not hold exactly
    /// `n_rows * n_sets` entries.
    #[inline]
    pub fn dense(t: &'a CountTable) -> RowsRef<'a> {
        assert_eq!(
            t.data.len(),
            t.n_rows * t.n_sets,
            "malformed dense table: {} entries for {} x {}",
            t.data.len(),
            t.n_rows,
            t.n_sets
        );
        RowsRef(RowsRepr::Dense(t))
    }

    /// Borrow a sparse table as a row source. O(n_rows + nnz) structure
    /// validation — once per borrow, amortized over every row the
    /// aggregation kernels then scatter unchecked.
    ///
    /// # Panics
    /// When the offsets are not a monotone `n_rows + 1` vector ending at
    /// the entry count, or any stored set rank is `>= n_sets`.
    pub fn sparse(t: &'a SparseTable) -> RowsRef<'a> {
        assert_eq!(
            t.offsets.len(),
            t.n_rows + 1,
            "malformed sparse table: {} offsets for {} rows",
            t.offsets.len(),
            t.n_rows
        );
        assert_eq!(
            *t.offsets.last().unwrap() as usize,
            t.entries.len(),
            "malformed sparse table: last offset must equal the entry count"
        );
        assert!(
            t.offsets.windows(2).all(|w| w[0] <= w[1]),
            "malformed sparse table: offsets must be monotone"
        );
        assert!(
            t.entries.iter().all(|&(rank, _)| (rank as usize) < t.n_sets),
            "malformed sparse table: set rank out of range ({})",
            t.n_sets
        );
        RowsRef(RowsRepr::Sparse(t))
    }

    #[inline]
    pub fn n_sets(&self) -> usize {
        match self.0 {
            RowsRepr::Dense(t) => t.n_sets,
            RowsRepr::Sparse(t) => t.n_sets,
        }
    }

    #[inline]
    pub fn n_rows(&self) -> usize {
        match self.0 {
            RowsRepr::Dense(t) => t.n_rows,
            RowsRepr::Sparse(t) => t.n_rows,
        }
    }

    /// `dst[j] += row(u)[j]` — THE aggregation kernel every executor
    /// funnels through. The sparse arm adds only the stored entries;
    /// omitting a slot's `+= 0.0` terms is bit-exact (module docs).
    ///
    /// The row index is checked here (one compare per row, amortized over
    /// the `n_sets` element ops); everything else the unchecked accesses
    /// rely on was validated at construction of this `RowsRef`.
    #[inline]
    pub fn add_row_into(&self, u: usize, dst: &mut [Count]) {
        match self.0 {
            RowsRepr::Dense(t) => {
                let n = t.n_sets;
                assert!(u < t.n_rows, "row {u} out of range ({})", t.n_rows);
                assert_eq!(dst.len(), n, "destination width");
                // SAFETY: `u < n_rows` asserted above and
                // `data.len() == n_rows * n_sets` was validated at
                // construction (RowsRef::dense), so the window is in
                // bounds.
                unsafe {
                    let urow = t.data.get_unchecked(u * n..(u + 1) * n);
                    for (a, &x) in dst.iter_mut().zip(urow) {
                        *a += x;
                    }
                }
            }
            RowsRepr::Sparse(t) => {
                assert_eq!(dst.len(), t.n_sets, "destination width");
                for &(rank, x) in t.row_entries(u) {
                    // SAFETY: stored set ranks were validated `< n_sets`
                    // at construction of this RowsRef (RowsRef::sparse)
                    // and `dst.len() == n_sets` is asserted above.
                    unsafe {
                        *dst.get_unchecked_mut(rank as usize) += x;
                    }
                }
            }
        }
    }

    /// The SpMM-stage variant of [`Self::add_row_into`]: dense rows go
    /// through the chunked-lane add ([`super::kernel::add_rows_chunked`],
    /// bit-identical to the scalar loop — every slot accumulates
    /// independently in the same order), sparse rows keep the scalar
    /// scatter (a short entry list gains nothing from lanes).
    #[inline]
    pub fn add_row_into_chunked(&self, u: usize, dst: &mut [Count]) {
        match self.0 {
            RowsRepr::Dense(t) => {
                assert!(u < t.n_rows, "row {u} out of range ({})", t.n_rows);
                super::kernel::add_rows_chunked(dst, t.row(u));
            }
            RowsRepr::Sparse(_) => self.add_row_into(u, dst),
        }
    }

    /// Materialize row `u` as a dense slice, reusing `buf` for the
    /// sparse scatter — the passive-row reader of the contraction phase.
    /// The materialized row equals the dense original exactly.
    /// (Per-row `fill(0.0)`; the executors use [`RowScratch`], which
    /// clears at touched-entry granularity instead.)
    #[inline]
    pub fn row_in<'s>(&'s self, u: usize, buf: &'s mut [Count]) -> &'s [Count] {
        match self.0 {
            RowsRepr::Dense(t) => t.row(u),
            RowsRepr::Sparse(t) => {
                debug_assert_eq!(buf.len(), t.n_sets);
                buf.fill(0.0);
                for &(rank, x) in t.row_entries(u) {
                    buf[rank as usize] = x;
                }
                buf
            }
        }
    }
}

/// Reusable passive-row materialization scratch for the contraction
/// phase. Where [`RowsRef::row_in`] pays a full-width `fill(0.0)` per
/// materialized row, this clears **only the entries the previous sparse
/// row wrote** (touched-entry granularity) — O(prev_nnz + nnz) per row
/// instead of O(n_sets). Dense sources return the table row directly and
/// never touch the buffer, so stale sparse entries survive a dense
/// interleaving and are still cleared before the next sparse scatter.
pub struct RowScratch {
    buf: Vec<Count>,
    written: Vec<u32>,
}

impl RowScratch {
    pub fn new(n_sets: usize) -> RowScratch {
        RowScratch {
            buf: vec![0.0; n_sets],
            written: Vec::new(),
        }
    }

    /// Materialize row `u` of `rows` as a dense slice. Equals the dense
    /// original exactly, whatever was materialized before.
    #[inline]
    pub fn row<'s>(&'s mut self, rows: RowsRef<'s>, u: usize) -> &'s [Count] {
        match rows.0 {
            RowsRepr::Dense(t) => t.row(u),
            RowsRepr::Sparse(t) => {
                assert_eq!(self.buf.len(), t.n_sets, "scratch width");
                for &w in &self.written {
                    self.buf[w as usize] = 0.0;
                }
                self.written.clear();
                for &(rank, x) in t.row_entries(u) {
                    self.buf[rank as usize] = x;
                    self.written.push(rank);
                }
                &self.buf
            }
        }
    }
}

/// Bytes of one presence-bitmap word in the masked encoding (`u64`).
pub const MASK_WORD_BYTES: u64 = 8;

/// The wire form of a packet's count rows — what the exchange ships.
/// `wire_bytes` is the one sizing rule shared by `Packet::bytes()`, the
/// fabric's accounting, the recv-buffer ledger and the model tests.
#[derive(Debug, Clone, PartialEq)]
pub enum RowsPayload {
    /// flat `n_rows × n_sets` rows (today's layout)
    Dense(Vec<Count>),
    /// CSR rows: `n_rows + 1` offsets plus `(set_rank, count)` entries
    Sparse {
        offsets: Vec<u32>,
        entries: Vec<(u32, Count)>,
    },
    /// CSR rows for the **live rows only**, behind a presence bitmap
    /// over all `n_rows` requested positions — all-zero rows cost one
    /// mask bit instead of an offset. Positions are preserved: the
    /// receiver expands the mask back to a full positional table (dead
    /// rows empty), so the positional fold indexing both executors use
    /// is untouched by the dropped rows.
    Masked {
        /// requested row count (live and dead)
        n_rows: u32,
        /// `ceil(n_rows / 64)` presence words, row `i` at bit `i % 64`
        /// of word `i / 64`; bits at or past `n_rows` are clear
        mask: Vec<u64>,
        /// `live + 1` offsets into `entries`, live rows in mask order
        offsets: Vec<u32>,
        /// `(set_rank, count)` pairs of the live rows
        entries: Vec<(u32, Count)>,
    },
}

impl RowsPayload {
    /// Payload bytes on the wire (header excluded).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            RowsPayload::Dense(data) => (data.len() * std::mem::size_of::<Count>()) as u64,
            RowsPayload::Sparse { offsets, entries } => {
                offsets.len() as u64 * SPARSE_OFFSET_BYTES
                    + entries.len() as u64 * SPARSE_ENTRY_BYTES
            }
            RowsPayload::Masked {
                mask,
                offsets,
                entries,
                ..
            } => {
                4 + mask.len() as u64 * MASK_WORD_BYTES
                    + offsets.len() as u64 * SPARSE_OFFSET_BYTES
                    + entries.len() as u64 * SPARSE_ENTRY_BYTES
            }
        }
    }

    /// Rows carried, given the row width.
    pub fn n_rows(&self, n_sets: usize) -> usize {
        match self {
            RowsPayload::Dense(data) => data.len() / n_sets.max(1),
            RowsPayload::Sparse { offsets, .. } => offsets.len().saturating_sub(1),
            RowsPayload::Masked { n_rows, .. } => *n_rows as usize,
        }
    }

    /// All-zero rows this encoding dropped from the wire (0 for the
    /// dense/sparse forms, which ship every requested position).
    pub fn rows_dropped(&self) -> u64 {
        match self {
            RowsPayload::Masked { n_rows, offsets, .. } => {
                *n_rows as u64 - (offsets.len() as u64 - 1)
            }
            _ => 0,
        }
    }
}

/// Wire bytes of the masked encoding of `n_rows` positions with `live`
/// live rows carrying `nnz` entries in total.
pub fn masked_bytes_for(n_rows: usize, live: usize, nnz: usize) -> u64 {
    4 + n_rows.div_ceil(64) as u64 * MASK_WORD_BYTES
        + (live as u64 + 1) * SPARSE_OFFSET_BYTES
        + nnz as u64 * SPARSE_ENTRY_BYTES
}

/// Compress a full positional CSR into the masked wire form: dead rows
/// become clear mask bits, live rows keep their entries in order.
fn mask_csr(n_rows: usize, offsets: Vec<u32>, entries: Vec<(u32, Count)>) -> RowsPayload {
    debug_assert!(n_rows <= u32::MAX as usize);
    let mut mask = vec![0u64; n_rows.div_ceil(64)];
    let mut live_offsets = Vec::new();
    live_offsets.push(0u32);
    for i in 0..n_rows {
        if offsets[i] != offsets[i + 1] {
            mask[i / 64] |= 1u64 << (i % 64);
            live_offsets.push(offsets[i + 1]);
        }
    }
    RowsPayload::Masked {
        n_rows: n_rows as u32,
        mask,
        offsets: live_offsets,
        entries,
    }
}

/// Pick the smallest wire form for CSR-gathered rows: flat dense rows,
/// the positional CSR, or — **strictly** smaller only — the masked form
/// that drops all-zero rows behind a presence bitmap. Ties keep the
/// historical dense/sparse choice, so byte accounting that predates the
/// masked encoding is unmoved wherever masking cannot win.
fn smallest_payload(
    n_sets: usize,
    n_picks: usize,
    offsets: Vec<u32>,
    entries: Vec<(u32, Count)>,
) -> RowsPayload {
    let sparse_bytes =
        offsets.len() as u64 * SPARSE_OFFSET_BYTES + entries.len() as u64 * SPARSE_ENTRY_BYTES;
    let dense_bytes = CountTable::dense_bytes_for(n_picks, n_sets);
    let live = (0..n_picks).filter(|&i| offsets[i] != offsets[i + 1]).count();
    if n_picks <= u32::MAX as usize
        && masked_bytes_for(n_picks, live, entries.len()) < sparse_bytes.min(dense_bytes)
    {
        return mask_csr(n_picks, offsets, entries);
    }
    if sparse_bytes < dense_bytes {
        RowsPayload::Sparse { offsets, entries }
    } else {
        let mut data: Vec<Count> = vec![0.0; n_picks * n_sets];
        for i in 0..n_picks {
            let dst = &mut data[i * n_sets..(i + 1) * n_sets];
            for &(rank, x) in &entries[offsets[i] as usize..offsets[i + 1] as usize] {
                dst[rank as usize] = x;
            }
        }
        RowsPayload::Dense(data)
    }
}

/// Gather the requested rows of a sparse table as a positional CSR.
fn gather_sparse(t: &SparseTable, picks: &[usize]) -> (Vec<u32>, Vec<(u32, Count)>) {
    let mut offsets = Vec::with_capacity(picks.len() + 1);
    let mut entries = Vec::new();
    offsets.push(0u32);
    for &r in picks {
        entries.extend_from_slice(t.row_entries(r));
        offsets.push(entries.len() as u32);
    }
    (offsets, entries)
}

/// Encode the given rows of a table for the wire, in iteration order —
/// the single send-side serializer both exchange executors share. Dense
/// tables ship flat rows (byte-identical to the historical serializer).
/// Sparse tables ship their CSR rows *when that is the smaller encoding
/// for the requested subset*, fall back to flat rows otherwise (a
/// request list can be denser than its table's average), and drop
/// all-zero rows behind the masked form when that is strictly smaller
/// than both — so a packet's wire bytes never exceed the dense encoding
/// of the same rows, and never pay offsets for dead rows.
pub fn encode_rows(table: &TableStorage, rows: impl Iterator<Item = usize>) -> RowsPayload {
    match table {
        TableStorage::Dense(t) => {
            let (lo, _) = rows.size_hint();
            let mut data = Vec::with_capacity(lo * t.n_sets);
            for r in rows {
                data.extend_from_slice(t.row(r));
            }
            RowsPayload::Dense(data)
        }
        TableStorage::Sparse(t) => {
            let picks: Vec<usize> = rows.collect();
            let (offsets, entries) = gather_sparse(t, &picks);
            smallest_payload(t.n_sets, picks.len(), offsets, entries)
        }
    }
}

/// [`encode_rows`] with the masked candidate considered for **both**
/// storage representations — the frontier-pruned exchange path. Dense
/// tables pay one nonzero scan over the requested rows to build the
/// CSR candidates; prune-off runs keep the scan-free [`encode_rows`].
pub fn encode_rows_masked(table: &TableStorage, rows: impl Iterator<Item = usize>) -> RowsPayload {
    match table {
        TableStorage::Dense(t) => {
            let picks: Vec<usize> = rows.collect();
            let mut offsets = Vec::with_capacity(picks.len() + 1);
            let mut entries = Vec::new();
            offsets.push(0u32);
            for &r in &picks {
                for (s, &x) in t.row(r).iter().enumerate() {
                    if x != 0.0 {
                        entries.push((s as u32, x));
                    }
                }
                offsets.push(entries.len() as u32);
            }
            smallest_payload(t.n_sets, picks.len(), offsets, entries)
        }
        TableStorage::Sparse(_) => encode_rows(table, rows),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn random_table(gen: &mut prop::Gen) -> CountTable {
        let n_rows = gen.usize_in(0, 12);
        let n_sets = gen.usize_in(1, 9);
        let mut t = CountTable::zeros(n_rows, n_sets);
        // mix of all-zero rows, fully-dense rows and scattered fills
        for r in 0..n_rows {
            match gen.usize_in(0, 3) {
                0 => {} // all-zero row
                1 => {
                    for x in t.row_mut(r) {
                        *x = 1.0 + (r as f32) * 0.125; // fully dense row
                    }
                }
                _ => {
                    for s in 0..n_sets {
                        if gen.usize_in(0, 2) == 0 {
                            t.row_mut(r)[s] = (1 + s + r) as f32 * 0.375;
                        }
                    }
                }
            }
        }
        t
    }

    /// Satellite: sparse↔dense round-trip on random tables, including
    /// all-zero and fully-dense rows — bitwise rows, equal totals/bytes
    /// math, and the payload codec reproducing any row subset exactly.
    #[test]
    fn prop_sparse_dense_roundtrip() {
        prop::check("storage_roundtrip", |gen| {
            let t = random_table(gen);
            let sp = SparseTable::from_dense(&t);
            if sp.nnz() != t.nnz() {
                return Err(format!("nnz {} != dense {}", sp.nnz(), t.nnz()));
            }
            let back = sp.to_dense();
            if back.n_rows != t.n_rows || back.n_sets != t.n_sets {
                return Err("shape changed through round-trip".into());
            }
            for (a, b) in back.data.iter().zip(&t.data) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("round-trip moved a bit: {a} vs {b}"));
                }
            }
            if sp.total().to_bits() != t.total().to_bits() {
                return Err(format!("total {} != dense {}", sp.total(), t.total()));
            }
            if sp.bytes() != SparseTable::bytes_for(t.n_rows, t.nnz()) {
                return Err("bytes_for disagrees with bytes".into());
            }
            if (sp.density() - t.density()).abs() > 1e-12 {
                return Err("density diverged".into());
            }

            // codec round-trip over a random row subset, both encodings
            let n_pick = if t.n_rows == 0 { 0 } else { gen.usize_in(0, t.n_rows) };
            let picks: Vec<usize> = (0..n_pick).map(|_| gen.usize_in(0, t.n_rows - 1)).collect();
            let dense_store = TableStorage::Dense(t.clone());
            let sparse_store = TableStorage::Sparse(sp);
            for store in [&dense_store, &sparse_store] {
                let payload = encode_rows(store, picks.iter().copied());
                if payload.n_rows(t.n_sets) != picks.len() {
                    return Err("payload row count wrong".into());
                }
                let decoded = TableStorage::from_payload(payload, t.n_sets);
                for (i, &r) in picks.iter().enumerate() {
                    let mut want = vec![0.0; t.n_sets];
                    let mut got = vec![0.0; t.n_sets];
                    dense_store.as_rows().add_row_into(r, &mut want);
                    decoded.as_rows().add_row_into(i, &mut got);
                    for (a, b) in got.iter().zip(&want) {
                        if a.to_bits() != b.to_bits() {
                            return Err(format!("row {r} decoded {a} != {b}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn wire_bytes_match_resident_bytes() {
        let mut t = CountTable::zeros(4, 6);
        t.row_mut(0)[1] = 2.0;
        t.row_mut(2)[5] = 3.0;
        t.row_mut(2)[0] = 1.0;
        let sp = SparseTable::from_dense(&t);
        let payload = encode_rows(&TableStorage::Sparse(sp.clone()), 0..4);
        // encoding the whole table is exactly the resident layout
        assert_eq!(payload.wire_bytes(), sp.bytes());
        assert_eq!(sp.bytes(), 5 * 4 + 3 * 8);
        let dense_payload = encode_rows(&TableStorage::Dense(t.clone()), 0..4);
        assert_eq!(dense_payload.wire_bytes(), t.bytes());
        // the decoded storages account the same bytes they arrived as
        assert_eq!(
            TableStorage::from_payload(payload, 6).bytes(),
            5 * 4 + 3 * 8
        );
        assert_eq!(TableStorage::from_payload(dense_payload, 6).bytes(), t.bytes());
    }

    #[test]
    fn auto_policy_thresholds() {
        let pol = StoragePolicy::of(StorageMode::Auto);
        // one-hot leaf shape: k=12 → density 1/12, clearly sparse
        assert!(pol.wants_sparse(100, 12, 100));
        // dense table: never
        assert!(!pol.wants_sparse(100, 12, 1200));
        // density under the threshold but bytes not smaller (tiny rows):
        // n_sets=1 → sparse costs 8·nnz + offsets vs 4·rows dense
        assert!(!pol.wants_sparse(10, 1, 3));
        // forced modes ignore density
        assert!(StoragePolicy::of(StorageMode::Sparse).wants_sparse(10, 4, 40));
        assert!(!StoragePolicy::dense().wants_sparse(10, 4, 0));
        // empty table stays dense
        assert!(!pol.wants_sparse(0, 0, 0));
    }

    #[test]
    fn expected_sparse_row_bytes_tracks_codec() {
        // a row at measured density d costs ~ 8·d·n_sets + its offset
        let n_sets = 20usize;
        let mut t = CountTable::zeros(1, n_sets);
        for s in 0..5 {
            t.row_mut(0)[s] = 1.0;
        }
        let sp = SparseTable::from_dense(&t);
        let payload = encode_rows(&TableStorage::Sparse(sp), std::iter::once(0));
        let expect = expected_sparse_row_bytes(0.25, n_sets);
        // one row: wire = offsets(2·4) + entries(5·8); the model charges
        // one offset per row — off by the single base offset
        assert_eq!(payload.wire_bytes(), 48);
        assert!((expect - 44.0).abs() < 1e-9);
    }

    #[test]
    fn encode_rows_falls_back_to_dense_when_smaller() {
        // a sparse-stored table whose requested subset is fully dense:
        // the codec must ship flat rows, keeping wire ≤ dense always
        let mut t = CountTable::zeros(3, 2);
        for r in 0..3 {
            t.row_mut(r)[0] = 1.0;
            t.row_mut(r)[1] = 2.0;
        }
        let sp = TableStorage::Sparse(SparseTable::from_dense(&t));
        let payload = encode_rows(&sp, 0..3);
        assert!(matches!(payload, RowsPayload::Dense(_)));
        assert_eq!(payload.wire_bytes(), 24); // 3 rows × 2 sets × 4 B
        // the fallback reproduces the rows exactly
        match &payload {
            RowsPayload::Dense(data) => assert_eq!(data.as_slice(), t.data.as_slice()),
            RowsPayload::Sparse { .. } => unreachable!(),
        }
        // an empty request list costs 0 payload bytes, not an offset
        let empty = encode_rows(&sp, std::iter::empty());
        assert!(matches!(empty, RowsPayload::Dense(_)));
        assert_eq!(empty.wire_bytes(), 0);
        // a genuinely sparse subset stays sparse on the wire
        let mut holey = CountTable::zeros(4, 6);
        holey.row_mut(1)[3] = 5.0;
        let sp = TableStorage::Sparse(SparseTable::from_dense(&holey));
        let payload = encode_rows(&sp, 0..4);
        assert!(matches!(payload, RowsPayload::Sparse { .. }));
        assert_eq!(payload.wire_bytes(), 5 * 4 + 8);
    }

    #[test]
    fn masked_encoding_drops_dead_rows() {
        // 8 requested rows, exactly one live: sparse pays 9 offsets
        // (36 B) + 8 B; masked pays 4 + 8 (one mask word) + 2 offsets
        // (8 B) + 8 B = 28 B — strictly smaller, so the codec must mask.
        let mut t = CountTable::zeros(8, 6);
        t.row_mut(3)[2] = 7.0;
        let sp = TableStorage::Sparse(SparseTable::from_dense(&t));
        let payload = encode_rows(&sp, 0..8);
        assert!(matches!(payload, RowsPayload::Masked { .. }));
        assert_eq!(payload.wire_bytes(), masked_bytes_for(8, 1, 1));
        assert_eq!(payload.wire_bytes(), 28);
        assert_eq!(payload.n_rows(6), 8);
        assert_eq!(payload.rows_dropped(), 7);
        // positions survive the round-trip: dead rows decode empty, the
        // live row keeps its index
        let decoded = TableStorage::from_payload(payload, 6);
        assert_eq!(decoded.n_rows(), 8);
        for r in 0..8 {
            let mut got = vec![0.0; 6];
            decoded.as_rows().add_row_into(r, &mut got);
            assert_eq!(got.as_slice(), t.row(r), "row {r}");
        }
        // the masked form is what the pruned path also picks for a
        // dense-stored table of the same rows
        let masked = encode_rows_masked(&TableStorage::Dense(t.clone()), 0..8);
        assert_eq!(masked.wire_bytes(), 28);
        assert_eq!(masked.rows_dropped(), 7);
        // ...while the historical dense arm still ships flat rows
        let flat = encode_rows(&TableStorage::Dense(t), 0..8);
        assert!(matches!(flat, RowsPayload::Dense(_)));
        assert_eq!(flat.rows_dropped(), 0);
    }

    /// The pruned encoder round-trips any subset of any table bit-exactly
    /// and never exceeds the dense wire bytes of the same rows.
    #[test]
    fn prop_masked_codec_roundtrip() {
        prop::check("masked_codec", |gen| {
            let t = random_table(gen);
            let stores = [
                TableStorage::Dense(t.clone()),
                TableStorage::Sparse(SparseTable::from_dense(&t)),
            ];
            let n_pick = if t.n_rows == 0 { 0 } else { gen.usize_in(0, 2 * t.n_rows) };
            let picks: Vec<usize> = (0..n_pick)
                .map(|_| gen.usize_in(0, t.n_rows.saturating_sub(1)))
                .collect();
            if t.n_rows == 0 && !picks.is_empty() {
                return Ok(());
            }
            for store in &stores {
                let payload = encode_rows_masked(store, picks.iter().copied());
                if payload.n_rows(t.n_sets) != picks.len() {
                    return Err("masked payload row count wrong".into());
                }
                if payload.wire_bytes() > CountTable::dense_bytes_for(picks.len(), t.n_sets) {
                    return Err("masked encoding exceeded dense bytes".into());
                }
                let dead = picks
                    .iter()
                    .filter(|&&r| t.row(r).iter().all(|&x| x == 0.0))
                    .count() as u64;
                if matches!(payload, RowsPayload::Masked { .. }) && payload.rows_dropped() != dead {
                    return Err(format!(
                        "rows_dropped {} != dead picks {dead}",
                        payload.rows_dropped()
                    ));
                }
                let decoded = TableStorage::from_payload(payload, t.n_sets);
                for (i, &r) in picks.iter().enumerate() {
                    let mut want = vec![0.0; t.n_sets];
                    let mut got = vec![0.0; t.n_sets];
                    stores[0].as_rows().add_row_into(r, &mut want);
                    decoded.as_rows().add_row_into(i, &mut got);
                    for (a, b) in got.iter().zip(&want) {
                        if a.to_bits() != b.to_bits() {
                            return Err(format!("row {r} decoded {a} != {b}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "mask word count")]
    fn from_payload_rejects_short_mask() {
        let payload = RowsPayload::Masked {
            n_rows: 100,
            mask: vec![1u64],
            offsets: vec![0, 1],
            entries: vec![(0, 1.0)],
        };
        let _ = TableStorage::from_payload(payload, 4);
    }

    #[test]
    #[should_panic(expected = "bits past n_rows")]
    fn from_payload_rejects_ghost_mask_bits() {
        let payload = RowsPayload::Masked {
            n_rows: 3,
            mask: vec![1u64 << 5],
            offsets: vec![0, 1],
            entries: vec![(0, 1.0)],
        };
        let _ = TableStorage::from_payload(payload, 4);
    }

    #[test]
    #[should_panic(expected = "live rows must be non-empty")]
    fn from_payload_rejects_empty_live_row() {
        let payload = RowsPayload::Masked {
            n_rows: 2,
            mask: vec![0b11u64],
            offsets: vec![0, 0, 1],
            entries: vec![(0, 1.0)],
        };
        let _ = TableStorage::from_payload(payload, 4);
    }

    #[test]
    fn storage_mode_parse_roundtrip() {
        for m in [StorageMode::Dense, StorageMode::Sparse, StorageMode::Auto] {
            assert_eq!(StorageMode::parse(m.name()), Some(m));
        }
        assert_eq!(StorageMode::parse("csr"), None);
    }

    #[test]
    #[should_panic(expected = "ascend")]
    fn from_payload_rejects_unsorted_rows() {
        let payload = RowsPayload::Sparse {
            offsets: vec![0, 2],
            entries: vec![(3, 1.0), (1, 2.0)],
        };
        let _ = TableStorage::from_payload(payload, 4);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn from_payload_rejects_oversized_rank() {
        let payload = RowsPayload::Sparse {
            offsets: vec![0, 1],
            entries: vec![(9, 1.0)],
        };
        let _ = TableStorage::from_payload(payload, 4);
    }

    #[test]
    fn row_in_materializes_sparse_rows() {
        let mut t = CountTable::zeros(3, 5);
        t.row_mut(1)[0] = 4.0;
        t.row_mut(1)[4] = 0.5;
        let sp = SparseTable::from_dense(&t);
        let rows = RowsRef::sparse(&sp);
        let mut buf = vec![7.0; 5]; // stale garbage must be cleared
        assert_eq!(rows.row_in(1, &mut buf), t.row(1));
        let mut buf2 = vec![1.0; 5];
        assert_eq!(rows.row_in(0, &mut buf2), t.row(0));
    }
}
