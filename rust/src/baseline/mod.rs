//! The MPI-Fascia-like baseline (Slota & Madduri's FASCIA, the paper's
//! comparison target in Figs 13–15), reconstructed from its published
//! behaviour:
//!
//! * one bulk **MPI_Alltoall** count exchange per subtemplate (no
//!   pipelining, no adaptivity) — all remote rows resident at once;
//! * **per-vertex** OpenMP task granularity (no neighbor-list
//!   partitioning), so hub vertices pin threads;
//! * the full receive buffer must fit in memory — with 120 GB/node it
//!   cannot run templates beyond u12-2 on Twitter (Fig 13), which we model
//!   with a scaled per-rank memory cap.
//!
//! Implementation-wise this is a configuration of the same
//! `DistributedRunner` (identical counting semantics — FASCIA computes the
//! same DP), so every performance difference in the benches comes from the
//! communication/scheduling model, not from accidental implementation
//! drift.

use crate::coordinator::{DistributedRunner, ModeSelect, RunConfig, RunResult};
use crate::graph::Graph;
use crate::template::Template;

/// The paper's per-node memory budget (120 GB) minus what the OS, the
/// MPI runtime and FASCIA's own graph/task structures consume (~17%),
/// scaled to the analog dataset scale factor so the OOM wall lands at the
/// same template size (beyond u12-2 on Twitter — Fig 13).
pub fn scaled_mem_limit(scale: u32) -> u64 {
    (100u64 << 30) / scale.max(1) as u64
}

/// Build the FASCIA-equivalent run configuration.
pub fn fascia_config(n_ranks: usize, scale: u32, seed: u64) -> RunConfig {
    RunConfig {
        n_ranks,
        mode: ModeSelect::Naive,
        task_size: 0,
        mem_limit: Some(scaled_mem_limit(scale)),
        seed,
        ..RunConfig::default()
    }
}

/// Run the baseline on a template/graph pair.
pub fn run_fascia(t: &Template, g: &Graph, n_ranks: usize, scale: u32, seed: u64) -> RunResult {
    let mut r = DistributedRunner::new(t, g, fascia_config(n_ranks, scale, seed));
    r.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{generate, RmatParams};
    use crate::template::builtin;

    #[test]
    fn fascia_counts_match_ours() {
        // the baseline must agree on the *answer* — only performance differs
        let g = generate(&RmatParams::with_skew(64, 280, 3, 3));
        let t = builtin("u5-2").unwrap();
        let base = run_fascia(&t, &g, 4, 1000, 42);
        let mut cfg = RunConfig::default();
        cfg.n_ranks = 4;
        cfg.seed = 42;
        let ours = DistributedRunner::new(&t, &g, cfg).run();
        for (a, b) in base.colorful.iter().zip(&ours.colorful) {
            assert!((a - b).abs() / b.abs().max(1.0) < 1e-3);
        }
    }

    #[test]
    fn mem_limit_scales() {
        assert_eq!(scaled_mem_limit(1), 100u64 << 30);
        assert_eq!(scaled_mem_limit(500), (100u64 << 30) / 500);
    }

    #[test]
    fn config_is_naive_per_vertex() {
        let c = fascia_config(8, 500, 1);
        assert_eq!(c.mode, ModeSelect::Naive);
        assert_eq!(c.effective_task_size(), 0);
        assert!(c.mem_limit.is_some());
    }
}
