//! `harpsg` — the CLI launcher for the coordinator. A thin shell over
//! `harpsg::api`: it parses flags strictly (unknown or duplicated flags
//! are errors, routed through the typed `HarpsgError`), opens a
//! `Session`, builds a validated `CountJob`, and prints the `JobReport`
//! as either the human block or JSON (`--json`).
//!
//! Subcommands:
//!   count     --template <name|path> --dataset <abbrev|path> [options] [--json] [--progress]
//!   run       --config <file.toml> [--json] [--progress]
//!   templates                      (print the Table-3 complexity table)
//!   artifacts                      (check the AOT artifact manifest)
//!
//! Examples:
//!   harpsg count --template u10-2 --dataset R500K3 --scale 2000 \
//!       --ranks 8 --workers 4 --mode adaptive-lb --iters 2 --json
//!   harpsg count --template u12-1 --dataset R500K3 --ranks 8 --adaptive
//!   harpsg count --template u12-1 --dataset R500K3 --ranks 6 --table-storage auto
//!   harpsg count --template u15-1 --dataset R500K3 --workers 4 --kernel simd
//!   harpsg count --template u7-2 --dataset MI --exchange sequential
//!   harpsg count --template u10-2 --dataset R500K3 --graph-storage auto \
//!       --graph-budget-mb 256
//!   harpsg count --template u5-2 --dataset R250K3 --ranks 4 --fabric socket
//!   harpsg run --config configs/quickstart.toml

use anyhow::{Context, Result};
use harpsg::api::{
    CountJob, HarpsgError, JobReport, PartitionKind, Session, SessionOptions, StderrProgress,
};
use harpsg::colorcount::{KernelMode, PruneMode, StorageMode};
use harpsg::config::RunSpec;
use harpsg::coordinator::{
    launch, EngineKind, ExchangeExec, FabricKind, ModeSelect, ProcSpec, RunConfig,
};
use harpsg::graph::{degree_stats, loader, Dataset, Graph, GraphStorageMode};
use harpsg::runtime::XlaRuntime;
use harpsg::template::{builtin, Template, BUILTIN_NAMES};
use harpsg::util::{human_bytes, human_secs};
use std::collections::HashMap;
use std::sync::Arc;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("count") => cmd_count(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("templates") => cmd_templates(),
        Some("artifacts") => cmd_artifacts(),
        _ => {
            eprintln!(
                "usage: harpsg <count|run|templates|artifacts> [options]\n\
                 see README.md for details"
            );
            std::process::exit(2);
        }
    }
}

/// Strict flag parser: every argument must be a known value flag (followed
/// by its value) or a known boolean flag, and none may repeat. Anything
/// else is a typed error — the old parser silently dropped unknown flags.
fn parse_flags(
    args: &[String],
    value_flags: &[&str],
    bool_flags: &[&str],
) -> Result<HashMap<String, String>, HarpsgError> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let flag = args[i].as_str();
        if bool_flags.contains(&flag) {
            if out.insert(flag.to_string(), String::new()).is_some() {
                return Err(HarpsgError::DuplicateFlag(flag.to_string()));
            }
            i += 1;
        } else if value_flags.contains(&flag) {
            let value = args
                .get(i + 1)
                .ok_or_else(|| HarpsgError::MissingValue(format!("flag `{flag}` needs a value")))?;
            if out.insert(flag.to_string(), value.clone()).is_some() {
                return Err(HarpsgError::DuplicateFlag(flag.to_string()));
            }
            i += 2;
        } else {
            return Err(HarpsgError::UnknownFlag(flag.to_string()));
        }
    }
    Ok(out)
}

fn parse_number<T: std::str::FromStr>(flags: &HashMap<String, String>, flag: &str) -> Result<Option<T>, HarpsgError> {
    match flags.get(flag) {
        None => Ok(None),
        Some(v) => v.parse::<T>().map(Some).map_err(|_| {
            HarpsgError::Parse(format!("`{flag}`: expected a number, got `{v}`"))
        }),
    }
}

fn require<'f>(flags: &'f HashMap<String, String>, flag: &str) -> Result<&'f str, HarpsgError> {
    flags
        .get(flag)
        .map(|s| s.as_str())
        .ok_or_else(|| HarpsgError::MissingValue(format!("flag `{flag}` is required")))
}

fn load_template(spec: &str) -> Result<Template> {
    if BUILTIN_NAMES.contains(&spec) {
        builtin(spec)
    } else {
        let text = std::fs::read_to_string(spec)
            .map_err(|e| HarpsgError::Io(format!("read template file {spec}: {e}")))?;
        Template::parse(spec, &text)
    }
}

fn load_dataset(spec: &str, scale: u32) -> Result<Graph> {
    let ds = match spec {
        "MI" => Some(Dataset::MiamiS),
        "OR" => Some(Dataset::OrkutS),
        "NY" => Some(Dataset::NycS),
        "TW" => Some(Dataset::TwitterS),
        "SK" => Some(Dataset::SkS),
        "FR" => Some(Dataset::FriendsterS),
        "R250K1" => Some(Dataset::R250K1),
        "R250K3" => Some(Dataset::R250K3),
        "R250K8" => Some(Dataset::R250K8),
        "R500K3" => Some(Dataset::R500K3),
        _ => None,
    };
    match ds {
        Some(d) => Ok(d.generate(scale)),
        None => loader::load_edge_list(std::path::Path::new(spec)),
    }
}

/// Run one job through the facade and print the report.
/// `explicit_task_size` carries an explicitly passed `--task-size` into
/// the builder so its mode/task-size consistency validation applies —
/// wholesale `config()` alone cannot tell "set" from "default".
fn execute(
    t: Template,
    g: Graph,
    cfg: RunConfig,
    explicit_task_size: Option<u32>,
    json: bool,
    progress: bool,
) -> Result<()> {
    let session = Session::with_options(
        g,
        SessionOptions {
            seed: cfg.seed,
            partition: PartitionKind::Random,
            load_xla: cfg.engine == EngineKind::Xla,
        },
    )
    .context("open session (XLA engines need `make artifacts`)")?;
    let mut builder = CountJob::builder(t).config(cfg);
    if let Some(ts) = explicit_task_size {
        builder = builder.task_size(ts);
    }
    let job = builder.build()?;
    let report = if progress {
        session.count_with_progress(&job, Arc::new(StderrProgress))?
    } else {
        session.count(&job)?
    };
    if json {
        println!("{}", report.to_json_string());
    } else {
        print_human(session.graph(), &report);
    }
    Ok(())
}

/// Launch `cfg.n_ranks` worker processes over the socket fabric and print
/// the merged report. The original template/dataset *spec strings* travel
/// to the workers (each rank re-resolves them deterministically); the
/// local graph load exists only to fill the report's graph statistics.
fn execute_socket(
    template_spec: &str,
    dataset_spec: &str,
    scale: u32,
    cfg: RunConfig,
    explicit_task_size: Option<u32>,
    listen: Option<&str>,
    json: bool,
) -> Result<()> {
    // run the same validation gauntlet as the in-process path
    let t = load_template(template_spec)?;
    let mut builder = CountJob::builder(t).config(cfg);
    if let Some(ts) = explicit_task_size {
        builder = builder.task_size(ts);
    }
    let job = builder.build()?;
    let t0 = std::time::Instant::now();
    let g = load_dataset(dataset_spec, scale)?;
    let mut spec = ProcSpec::new(template_spec, dataset_spec, scale, job.config().clone());
    if let Some(l) = listen {
        spec.listen = l.to_string();
    }
    let setup_seconds = t0.elapsed().as_secs_f64();
    let result = launch(&spec).context("launch rank processes")?;
    let report = JobReport::from_run(&job, &g, result, false, setup_seconds);
    if json {
        println!("{}", report.to_json_string());
    } else {
        print_human(&g, &report);
    }
    Ok(())
}

fn print_human(g: &Graph, r: &JobReport) {
    let st = degree_stats(g);
    println!(
        "graph: {} vertices, {} edges, avg deg {:.1}, max deg {}",
        st.n_vertices, st.n_edges, st.avg_degree, st.max_degree
    );
    println!(
        "template: {} (k={}, intensity {:.1}) — {} mode on {} ranks ({} engine)",
        r.template, r.k, r.complexity.intensity, r.mode, r.n_ranks, r.engine
    );
    if r.adaptive {
        // the sweep decides per subtemplate: show each combine's shape
        // and its predicted vs measured overlap
        println!("exchange (adaptive per subtemplate):");
        for d in &r.comm_decisions {
            let meas = match d.measured_rho {
                Some(m) => format!("{m:.2}"),
                None => "-".to_string(),
            };
            println!(
                "  sub {:>2}: {:<10} g={} ({} step{})  rho pred {:.2} / meas {}",
                d.sub,
                d.mode_name(),
                d.g,
                d.n_steps,
                if d.n_steps == 1 { "" } else { "s" },
                d.predicted_rho,
                meas
            );
        }
    } else if let Some(d) = r.comm_decisions.first() {
        println!(
            "exchange: {} in {} step(s) per subtemplate",
            d.mode_name(),
            d.n_steps
        );
    }
    println!();
    println!("estimate:        {:.6e} embeddings", r.estimate);
    println!(
        "model time/iter: {} ({:.0}% compute, mean rho {:.2})",
        human_secs(r.model.total),
        100.0 * (1.0 - r.model.comm_ratio()),
        r.model.mean_rho()
    );
    if let Some(m) = &r.measured {
        println!(
            "pipeline (real): mean rho {:.2}, exposed wait {}, recv peak {} per rank",
            m.mean_rho(),
            human_secs(m.exposed_wait_s),
            human_bytes(m.recv_peak())
        );
    }
    if !r.link.is_empty() {
        // process mode only: the Hockney fit of each rank's wall-clock
        // send timings over the socket mesh
        println!("measured link ({} fabric):", r.fabric);
        for l in &r.link {
            println!(
                "  rank {:>2}: alpha {:.3e} s, beta {:.3e} s/B ({} send{})",
                l.rank,
                l.alpha_s,
                l.beta_s_per_byte,
                l.samples,
                if l.samples == 1 { "" } else { "s" }
            );
        }
    }
    println!(
        "workers:         {} configured, {} measured busy, imbalance {:.2}",
        r.n_workers,
        r.workers.busy_workers(),
        r.workers.imbalance()
    );
    println!("peak memory:     {} per rank", human_bytes(r.peak_mem()));
    if r.kernel != "scalar" {
        println!("kernel:          {} combine kernel", r.kernel);
    }
    if r.prune_mode != "off" {
        println!("prune:           {} frontier pruning", r.prune_mode);
        for s in r
            .prune
            .iter()
            .filter(|s| s.pairs_skipped > 0 || s.rows_skipped > 0 || s.wire_rows_dropped > 0)
        {
            println!(
                "  sub {:>2}: occupancy {:.3}, {} pairs + {} rows skipped, {} wire rows dropped",
                s.sub,
                s.frontier_occupancy,
                s.pairs_skipped,
                s.rows_skipped,
                s.wire_rows_dropped
            );
        }
    }
    if r.graph_storage != "resident" {
        let max_slice = r.graph_resident_per_rank.iter().copied().max().unwrap_or(0);
        println!(
            "graph storage:   {} (largest per-rank slice {})",
            r.graph_storage,
            human_bytes(max_slice)
        );
    }
    if r.table_storage != "dense" {
        println!(
            "table storage:   {} (dense baseline {}, saved {} at peak)",
            r.table_storage,
            human_bytes(r.peak_mem_dense()),
            human_bytes(r.peak_bytes_saved())
        );
        for d in r.storage.iter().filter(|d| d.storage_name() != "dense") {
            println!(
                "  sub {:>2}: {:<6} density {:.3}, {} -> {} ({} saved)",
                d.sub,
                d.storage_name(),
                d.density,
                human_bytes(d.dense_bytes),
                human_bytes(d.resident_bytes),
                human_bytes(d.bytes_saved())
            );
        }
    }
    println!(
        "setup:           {} ({})",
        human_secs(r.setup_seconds),
        if r.setup_reused { "reused" } else { "built" }
    );
    println!("real wall-clock: {}", human_secs(r.real_seconds));
    if r.oom {
        println!("WARNING: modeled per-rank memory exceeds the configured limit (OOM)");
    }
}

fn cmd_count(args: &[String]) -> Result<()> {
    let flags = parse_flags(
        args,
        &[
            "--template",
            "--dataset",
            "--scale",
            "--ranks",
            "--threads",
            "--workers",
            "--iters",
            "--seed",
            "--task-size",
            "--mode",
            "--engine",
            "--exchange",
            "--fabric",
            "--listen",
            "--table-storage",
            "--kernel",
            "--prune",
            "--graph-storage",
            "--graph-budget-mb",
            "--mem-limit-mb",
        ],
        &["--json", "--progress", "--adaptive"],
    )?;
    let template = require(&flags, "--template")?.to_string();
    let dataset = require(&flags, "--dataset")?.to_string();
    let scale: u32 = parse_number(&flags, "--scale")?.unwrap_or(2000);
    let mut cfg = RunConfig::default();
    if let Some(v) = parse_number::<usize>(&flags, "--ranks")? {
        cfg.n_ranks = v;
    }
    if let Some(v) = parse_number::<usize>(&flags, "--threads")? {
        cfg.n_threads = v;
    }
    if let Some(v) = parse_number::<usize>(&flags, "--workers")? {
        cfg.n_workers = v;
    }
    if let Some(v) = parse_number::<usize>(&flags, "--iters")? {
        cfg.n_iterations = v;
    }
    if let Some(v) = parse_number::<u64>(&flags, "--seed")? {
        cfg.seed = v;
    }
    let explicit_task_size = parse_number::<u32>(&flags, "--task-size")?;
    if let Some(v) = parse_number::<u64>(&flags, "--mem-limit-mb")? {
        cfg.mem_limit = Some(v << 20);
    }
    if let Some(m) = flags.get("--mode") {
        cfg.mode = ModeSelect::parse(m).ok_or_else(|| HarpsgError::UnknownMode(m.clone()))?;
    }
    if let Some(e) = flags.get("--engine") {
        cfg.engine = EngineKind::parse(e).ok_or_else(|| HarpsgError::UnknownEngine(e.clone()))?;
    }
    if let Some(x) = flags.get("--exchange") {
        cfg.exchange = ExchangeExec::parse(x).ok_or_else(|| {
            HarpsgError::Parse(format!(
                "`--exchange`: unknown executor `{x}` (threaded|sequential)"
            ))
        })?;
    }
    if let Some(f) = flags.get("--fabric") {
        cfg.fabric = FabricKind::parse(f).ok_or_else(|| {
            HarpsgError::Parse(format!(
                "`--fabric`: unknown fabric `{f}` (threaded|socket)"
            ))
        })?;
    }
    let listen = flags.get("--listen").map(|s| s.as_str());
    if listen.is_some() && cfg.fabric != FabricKind::Socket {
        return Err(HarpsgError::InvalidJob(
            "`--listen` only applies to `--fabric socket`".into(),
        )
        .into());
    }
    if let Some(s) = flags.get("--table-storage") {
        cfg.table_storage = StorageMode::parse(s).ok_or_else(|| {
            HarpsgError::Parse(format!(
                "`--table-storage`: unknown storage `{s}` (dense|sparse|auto)"
            ))
        })?;
    }
    if let Some(kn) = flags.get("--kernel") {
        cfg.kernel = KernelMode::parse(kn).ok_or_else(|| {
            HarpsgError::Parse(format!(
                "`--kernel`: unknown kernel `{kn}` (scalar|simd|auto)"
            ))
        })?;
    }
    if let Some(pm) = flags.get("--prune") {
        cfg.prune = PruneMode::parse(pm).ok_or_else(|| {
            HarpsgError::Parse(format!("`--prune`: unknown mode `{pm}` (on|off|auto)"))
        })?;
    }
    if let Some(gs) = flags.get("--graph-storage") {
        cfg.graph_storage = GraphStorageMode::parse(gs).ok_or_else(|| {
            HarpsgError::Parse(format!(
                "`--graph-storage`: unknown storage `{gs}` (resident|mmap|auto)"
            ))
        })?;
    }
    if let Some(v) = parse_number::<u64>(&flags, "--graph-budget-mb")? {
        cfg.graph_budget = Some(v << 20);
    }
    // mode/adaptive consistency is validated by the CountJob builder
    cfg.adaptive_group = flags.contains_key("--adaptive");
    if cfg.fabric == FabricKind::Socket {
        // rank *processes* over the socket mesh; per-step progress is
        // not streamed back, so `--progress` is meaningless here
        if flags.contains_key("--progress") {
            return Err(HarpsgError::InvalidJob(
                "`--progress` is not available with `--fabric socket`".into(),
            )
            .into());
        }
        return execute_socket(
            &template,
            &dataset,
            scale,
            cfg,
            explicit_task_size,
            listen,
            flags.contains_key("--json"),
        );
    }
    let t = load_template(&template)?;
    let g = load_dataset(&dataset, scale)?;
    execute(
        t,
        g,
        cfg,
        explicit_task_size,
        flags.contains_key("--json"),
        flags.contains_key("--progress"),
    )
}

fn cmd_run(args: &[String]) -> Result<()> {
    let flags = parse_flags(args, &["--config"], &["--json", "--progress"])?;
    let path = require(&flags, "--config")?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| HarpsgError::Io(format!("read config {path}: {e}")))?;
    // RunSpec::from_doc already enforces mode/task-size consistency for
    // explicitly configured keys, so no explicit task size is re-applied
    let spec = RunSpec::parse(&text)?;
    if spec.run.fabric == FabricKind::Socket {
        if flags.contains_key("--progress") {
            return Err(HarpsgError::InvalidJob(
                "`--progress` is not available with `run.fabric = \"socket\"`".into(),
            )
            .into());
        }
        return execute_socket(
            &spec.template,
            &spec.dataset,
            spec.scale,
            spec.run,
            None,
            None,
            flags.contains_key("--json"),
        );
    }
    let t = load_template(&spec.template)?;
    let g = load_dataset(&spec.dataset, spec.scale)?;
    execute(
        t,
        g,
        spec.run,
        None,
        flags.contains_key("--json"),
        flags.contains_key("--progress"),
    )
}

fn cmd_templates() -> Result<()> {
    println!(
        "{:>8} {:>4} {:>10} {:>13} {:>10}",
        "template", "k", "memory", "computation", "intensity"
    );
    for name in BUILTIN_NAMES {
        let c = harpsg::template::complexity(&builtin(name)?);
        println!(
            "{:>8} {:>4} {:>10} {:>13} {:>10.1}",
            name, c.k, c.memory, c.computation, c.intensity
        );
    }
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let rt = XlaRuntime::load_default()?;
    println!("platform: {}", rt.platform);
    println!("artifacts ({}):", rt.manifest.entries.len());
    for e in &rt.manifest.entries {
        println!(
            "  {:?} k={} a={} a1={} block={} [{} sets x {} splits] {}",
            e.kind,
            e.k,
            e.a,
            e.a1,
            e.block,
            e.n_sets,
            e.n_splits,
            e.file.file_name().unwrap().to_string_lossy()
        );
    }
    Ok(())
}
