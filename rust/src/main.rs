//! `harpsg` — the CLI launcher for the coordinator.
//!
//! Subcommands:
//!   count     --template <name|path> --dataset <abbrev|path> [options]
//!   run       --config <file.toml>
//!   templates                      (print the Table-3 complexity table)
//!   artifacts                      (check the AOT artifact manifest)
//!
//! Examples:
//!   harpsg count --template u10-2 --dataset R500K3 --scale 2000 \
//!       --ranks 8 --mode adaptive-lb --iters 2
//!   harpsg run --config configs/quickstart.toml

use anyhow::{bail, Context, Result};
use harpsg::config::RunSpec;
use harpsg::coordinator::{DistributedRunner, EngineKind, ModeSelect, RunConfig};
use harpsg::graph::{degree_stats, loader, Dataset, Graph};
use harpsg::runtime::{XlaCombine, XlaRuntime};
use harpsg::template::{builtin, complexity, Template, BUILTIN_NAMES};
use harpsg::util::{human_bytes, human_secs};

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("count") => cmd_count(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("templates") => cmd_templates(),
        Some("artifacts") => cmd_artifacts(),
        _ => {
            eprintln!(
                "usage: harpsg <count|run|templates|artifacts> [options]\n\
                 see README.md for details"
            );
            std::process::exit(2);
        }
    }
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn load_template(spec: &str) -> Result<Template> {
    if BUILTIN_NAMES.contains(&spec) {
        builtin(spec)
    } else {
        let text = std::fs::read_to_string(spec)
            .with_context(|| format!("read template file {spec}"))?;
        Template::parse(spec, &text)
    }
}

fn load_dataset(spec: &str, scale: u32) -> Result<Graph> {
    let ds = match spec {
        "MI" => Some(Dataset::MiamiS),
        "OR" => Some(Dataset::OrkutS),
        "NY" => Some(Dataset::NycS),
        "TW" => Some(Dataset::TwitterS),
        "SK" => Some(Dataset::SkS),
        "FR" => Some(Dataset::FriendsterS),
        "R250K1" => Some(Dataset::R250K1),
        "R250K3" => Some(Dataset::R250K3),
        "R250K8" => Some(Dataset::R250K8),
        "R500K3" => Some(Dataset::R500K3),
        _ => None,
    };
    match ds {
        Some(d) => Ok(d.generate(scale)),
        None => loader::load_edge_list(std::path::Path::new(spec)),
    }
}

fn execute(t: &Template, g: &Graph, cfg: RunConfig) -> Result<()> {
    let st = degree_stats(g);
    println!(
        "graph: {} vertices, {} edges, avg deg {:.1}, max deg {}",
        st.n_vertices, st.n_edges, st.avg_degree, st.max_degree
    );
    let tc = complexity(t);
    println!(
        "template: {} (k={}, intensity {:.1}) — {} mode on {} ranks",
        t.name,
        t.size(),
        tc.intensity,
        cfg.mode.name(),
        cfg.n_ranks
    );
    let use_xla = cfg.engine == EngineKind::Xla;
    let mut runner = DistributedRunner::new(t, g, cfg);
    if use_xla {
        let rt = XlaRuntime::load_default().context("load artifacts (run `make artifacts`)")?;
        println!("engine: XLA via PJRT ({})", rt.platform);
        runner.xla = Some(XlaCombine::new(std::sync::Arc::new(rt)));
    }
    let r = runner.run();
    println!();
    println!("estimate:        {:.6e} embeddings", r.estimate);
    println!(
        "model time/iter: {} ({:.0}% compute, mean rho {:.2})",
        human_secs(r.model.total),
        100.0 * (1.0 - r.model.comm_ratio()),
        r.model.mean_rho()
    );
    println!("peak memory:     {} per rank", human_bytes(r.peak_mem()));
    println!("real wall-clock: {}", human_secs(r.real_seconds));
    if r.oom {
        println!("WARNING: modeled per-rank memory exceeds the configured limit (OOM)");
    }
    Ok(())
}

fn cmd_count(args: &[String]) -> Result<()> {
    let template = flag(args, "--template").context("--template required")?;
    let dataset = flag(args, "--dataset").context("--dataset required")?;
    let scale: u32 = flag(args, "--scale")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2000);
    let mut cfg = RunConfig::default();
    if let Some(v) = flag(args, "--ranks") {
        cfg.n_ranks = v.parse()?;
    }
    if let Some(v) = flag(args, "--threads") {
        cfg.n_threads = v.parse()?;
    }
    if let Some(v) = flag(args, "--iters") {
        cfg.n_iterations = v.parse()?;
    }
    if let Some(v) = flag(args, "--seed") {
        cfg.seed = v.parse()?;
    }
    if let Some(v) = flag(args, "--task-size") {
        cfg.task_size = v.parse()?;
    }
    if let Some(v) = flag(args, "--mode") {
        cfg.mode = match v.as_str() {
            "naive" => ModeSelect::Naive,
            "pipeline" => ModeSelect::Pipeline,
            "adaptive" => ModeSelect::Adaptive,
            "adaptive-lb" => ModeSelect::AdaptiveLb,
            other => bail!("unknown mode {other}"),
        };
    }
    if flag(args, "--engine").as_deref() == Some("xla") {
        cfg.engine = EngineKind::Xla;
    }
    let t = load_template(&template)?;
    let g = load_dataset(&dataset, scale)?;
    execute(&t, &g, cfg)
}

fn cmd_run(args: &[String]) -> Result<()> {
    let path = flag(args, "--config").context("--config required")?;
    let text = std::fs::read_to_string(&path).with_context(|| format!("read {path}"))?;
    let spec = RunSpec::parse(&text)?;
    let t = load_template(&spec.template)?;
    let g = load_dataset(&spec.dataset, spec.scale)?;
    execute(&t, &g, spec.run)
}

fn cmd_templates() -> Result<()> {
    println!(
        "{:>8} {:>4} {:>10} {:>13} {:>10}",
        "template", "k", "memory", "computation", "intensity"
    );
    for name in BUILTIN_NAMES {
        let c = complexity(&builtin(name)?);
        println!(
            "{:>8} {:>4} {:>10} {:>13} {:>10.1}",
            name, c.k, c.memory, c.computation, c.intensity
        );
    }
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let rt = XlaRuntime::load_default()?;
    println!("platform: {}", rt.platform);
    println!("artifacts ({}):", rt.manifest.entries.len());
    for e in &rt.manifest.entries {
        println!(
            "  {:?} k={} a={} a1={} block={} [{} sets x {} splits] {}",
            e.kind,
            e.k,
            e.a,
            e.a1,
            e.block,
            e.n_sets,
            e.n_splits,
            e.file.file_name().unwrap().to_string_lossy()
        );
    }
    Ok(())
}
