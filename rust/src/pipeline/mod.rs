//! The pipeline time algebra (paper §3.2.1–3.2.2, Fig 3, Eq 9–14).
//!
//! Given per-(step, rank) measured compute times and modeled communication
//! times, this module computes the makespan of
//!
//! * the **pipelined** execution: `W+1` stages, where stage `s` overlaps
//!   the computation on step `s-1`'s data with step `s`'s transfer, with a
//!   cross-rank synchronization at every stage boundary (the dashed lines
//!   in Fig 3 — the straggler term δ of Eq 9), and
//! * the **naive** execution: one bulk exchange, then all the computation,
//!
//! plus the per-step overlap ratio ρ_w (Eq 14) and the exposed (non-
//! overlapped) communication (Eq 13) reported in Fig 8.

/// Per-rank timing of one exchange step.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepTiming {
    /// compute time for the data received at this step, seconds
    pub comp: f64,
    /// transfer time of this step's messages, seconds
    pub comm: f64,
}

/// Summary of one pipelined combine.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    /// modeled wall-clock of the whole exchange+update
    pub makespan: f64,
    /// Σ max_p comm — what the naive schedule would pay in transfer
    pub comm_total: f64,
    /// makespan minus useful compute: exposed transfer PLUS straggler
    /// wait — the paper's Eq 8 communication definition (δ included)
    pub comm_exposed: f64,
    /// rank-averaged useful compute Σ_w mean_p comp
    pub comp_total: f64,
    /// overlap ratio ρ_w per step (Eq 14), step 0 is the cold start
    pub rho: Vec<f64>,
    /// straggler wait δ summed over stages (Eq 9)
    pub straggler: f64,
}

impl PipelineReport {
    /// Mean overlap ratio over the non-cold-start steps (the Fig 8 series).
    pub fn mean_rho(&self) -> f64 {
        if self.rho.len() <= 1 {
            return 0.0;
        }
        self.rho[1..].iter().sum::<f64>() / (self.rho.len() - 1) as f64
    }
}

/// `timings[w][p]`: step `w`, rank `p`. Computes the pipelined makespan.
pub fn pipelined(timings: &[Vec<StepTiming>]) -> PipelineReport {
    let n_steps = timings.len();
    if n_steps == 0 {
        return PipelineReport {
            makespan: 0.0,
            comm_total: 0.0,
            comm_exposed: 0.0,
            comp_total: 0.0,
            rho: vec![],
            straggler: 0.0,
        };
    }
    let n_ranks = timings[0].len();
    let max_comm = |w: usize| -> f64 {
        timings[w].iter().map(|t| t.comm).fold(0.0, f64::max)
    };
    let max_comp = |w: usize| -> f64 {
        timings[w].iter().map(|t| t.comp).fold(0.0, f64::max)
    };

    let mut makespan = 0.0;
    let mut straggler = 0.0;
    let comm_exposed;
    let mut rho = Vec::with_capacity(n_steps);

    // stage 0 (cold start): only step 0's transfer runs
    makespan += max_comm(0);
    rho.push(0.0);

    // stages 1..W-1: overlap comp(w-1) with comm(w)
    for w in 1..n_steps {
        // per-rank stage time, then the sync barrier takes the max (δ)
        let mut stage = 0.0f64;
        let mut min_stage = f64::INFINITY;
        let mut rho_w = 0.0;
        for p in 0..n_ranks {
            let t = timings[w][p].comm.max(timings[w - 1][p].comp);
            stage = stage.max(t);
            min_stage = min_stage.min(t);
            // Eq 14 per rank, averaged
            if timings[w][p].comm > 0.0 {
                rho_w += (timings[w - 1][p].comp.min(timings[w][p].comm))
                    / timings[w][p].comm;
            } else {
                rho_w += 1.0;
            }
        }
        rho_w /= n_ranks as f64;
        rho.push(rho_w);
        straggler += stage - min_stage;
        makespan += stage;
    }

    // final stage: computation on the last step's data
    makespan += max_comp(n_steps - 1);

    let comm_total: f64 = (0..n_steps).map(max_comm).sum();
    // useful compute = rank-averaged Σ comp; everything else the barrier
    // timeline spends is exposed transfer + straggler wait (Eq 8's δ)
    let comp_total: f64 = (0..n_steps)
        .map(|w| timings[w].iter().map(|t| t.comp).sum::<f64>() / n_ranks as f64)
        .sum();
    comm_exposed = (makespan - comp_total).max(0.0);

    PipelineReport {
        makespan,
        comm_total,
        comm_exposed,
        comp_total,
        rho,
        straggler,
    }
}

// ---------------------------------------------------------------------
// Measured pipeline (the rank-parallel executor's real counterpart)
// ---------------------------------------------------------------------

/// One exchange step as the rank-parallel executor actually ran it:
/// seconds spent folding the step's received rows vs. seconds blocked
/// waiting for them to arrive. The modeled [`StepTiming`] predicts this
/// pair; `MeasuredStep` is what the threads really did.
#[derive(Debug, Clone, Copy, Default)]
pub struct MeasuredStep {
    /// wall seconds folding this step's received rows (rank-averaged,
    /// summed over combines until [`MeasuredPipeline`] normalizes)
    pub comp_s: f64,
    /// wall seconds blocked waiting for this step's packets — the
    /// *exposed* (non-overlapped) communication of the real schedule
    pub wait_s: f64,
}

impl MeasuredStep {
    /// Measured overlap ratio: the fraction of the stage spent computing
    /// rather than blocked. 1.0 when the transfer hid completely behind
    /// the previous step's fold (the Fig-3 ideal), 0.0 when the rank only
    /// waited. Steps that did neither (empty exchange) count as fully
    /// overlapped.
    pub fn rho(&self) -> f64 {
        let total = self.comp_s + self.wait_s;
        if total <= 0.0 {
            1.0
        } else {
            self.comp_s / total
        }
    }
}

/// Aggregated measured-overlap record of a run: what the rank-parallel
/// pipelined executor *did*, next to the [`PipelineReport`] the time
/// algebra *predicts*. Accumulated over every non-leaf combine of every
/// iteration; step entries hold rank-averaged seconds summed over
/// combines (normalize per combine with [`Self::mean_steps`]).
#[derive(Debug, Clone, Default)]
pub struct MeasuredPipeline {
    /// per exchange step: rank-averaged compute/wait seconds, summed over
    /// all combines
    pub steps: Vec<MeasuredStep>,
    /// combines that actually ran each step — per-sub schedules differ
    /// under the adaptive sweep, so step `w`'s seconds must be normalized
    /// by the combines that had a step `w`, not the total
    pub step_counts: Vec<u64>,
    /// total rank-averaged fold seconds across the run's exchanges
    pub comp_s: f64,
    /// total rank-averaged blocked-wait seconds (the run's real exposed
    /// communication)
    pub exposed_wait_s: f64,
    /// per-rank high-water mark of `MemClass::RecvBuffer` bytes
    pub recv_peak_per_rank: Vec<u64>,
    /// per-rank largest single-step received bytes — the streaming
    /// executor's guaranteed bound on `recv_peak_per_rank`
    pub max_step_recv_bytes_per_rank: Vec<u64>,
    /// high-water mark of payload bytes parked in the fabric (sent, not
    /// yet received) — the cost of overlapping send w with fold w-1
    pub in_flight_peak_bytes: u64,
    /// non-leaf combines folded into this record
    pub n_combines: u64,
}

impl MeasuredPipeline {
    pub fn new(n_ranks: usize) -> Self {
        MeasuredPipeline {
            recv_peak_per_rank: vec![0; n_ranks],
            max_step_recv_bytes_per_rank: vec![0; n_ranks],
            ..Default::default()
        }
    }

    /// Fold one combine's step record in: `comp_s`/`wait_s` must already
    /// be rank-averaged seconds for step `w`.
    pub fn add_step(&mut self, w: usize, comp_s: f64, wait_s: f64) {
        if self.steps.len() <= w {
            self.steps.resize(w + 1, MeasuredStep::default());
            self.step_counts.resize(w + 1, 0);
        }
        self.steps[w].comp_s += comp_s;
        self.steps[w].wait_s += wait_s;
        self.step_counts[w] += 1;
        self.comp_s += comp_s;
        self.exposed_wait_s += wait_s;
    }

    /// Record one rank's memory observations from one combine.
    pub fn observe_rank(&mut self, p: usize, recv_peak: u64, max_step_bytes: u64) {
        self.recv_peak_per_rank[p] = self.recv_peak_per_rank[p].max(recv_peak);
        self.max_step_recv_bytes_per_rank[p] =
            self.max_step_recv_bytes_per_rank[p].max(max_step_bytes);
    }

    pub fn observe_in_flight_peak(&mut self, bytes: u64) {
        self.in_flight_peak_bytes = self.in_flight_peak_bytes.max(bytes);
    }

    pub fn finish_combine(&mut self) {
        self.n_combines += 1;
    }

    /// Per-combine step averages (rank-averaged seconds per step), each
    /// step normalized by the combines that actually ran it.
    pub fn mean_steps(&self) -> Vec<MeasuredStep> {
        self.steps
            .iter()
            .zip(&self.step_counts)
            .map(|(s, &n)| {
                let n = n.max(1) as f64;
                MeasuredStep {
                    comp_s: s.comp_s / n,
                    wait_s: s.wait_s / n,
                }
            })
            .collect()
    }

    /// Mean measured overlap over the non-cold-start steps, mirroring
    /// [`PipelineReport::mean_rho`]. Step 0's wait can never be hidden
    /// (there is no earlier fold to overlap with), so it is excluded;
    /// single-step exchanges (all-to-all) report 0.
    pub fn mean_rho(&self) -> f64 {
        if self.steps.len() <= 1 {
            return 0.0;
        }
        self.steps[1..].iter().map(|s| s.rho()).sum::<f64>() / (self.steps.len() - 1) as f64
    }

    /// Largest per-rank receive-buffer high-water mark.
    pub fn recv_peak(&self) -> u64 {
        self.recv_peak_per_rank.iter().copied().max().unwrap_or(0)
    }
}

/// Naive (all-to-all, no interleave): every rank first completes the whole
/// exchange, then computes on the full received buffer.
pub fn naive(timings: &[Vec<StepTiming>]) -> PipelineReport {
    let n_steps = timings.len();
    if n_steps == 0 {
        return pipelined(timings);
    }
    let n_ranks = timings[0].len();
    let comm_total: f64 = (0..n_steps)
        .map(|w| timings[w].iter().map(|t| t.comm).fold(0.0, f64::max))
        .sum();
    let comp_max: f64 = (0..n_steps)
        .map(|w| timings[w].iter().map(|t| t.comp).fold(0.0, f64::max))
        .sum();
    let comp_total: f64 = (0..n_steps)
        .map(|w| timings[w].iter().map(|t| t.comp).sum::<f64>() / n_ranks as f64)
        .sum();
    let makespan = comm_total + comp_max;
    PipelineReport {
        makespan,
        comm_total,
        comm_exposed: (makespan - comp_total).max(0.0),
        comp_total,
        rho: vec![0.0; n_steps],
        straggler: comp_max - comp_total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(w: usize, p: usize, comp: f64, comm: f64) -> Vec<Vec<StepTiming>> {
        vec![vec![StepTiming { comp, comm }; p]; w]
    }

    #[test]
    fn empty_schedule() {
        let r = pipelined(&[]);
        assert_eq!(r.makespan, 0.0);
    }

    #[test]
    fn perfect_overlap_hides_all_but_first() {
        // comp == comm: every transfer after the first hides fully
        let t = uniform(5, 4, 1.0, 1.0);
        let r = pipelined(&t);
        // 1 (cold) + 4 stages of max(1,1) + 1 final comp = 6
        assert!((r.makespan - 6.0).abs() < 1e-12);
        assert!((r.mean_rho() - 1.0).abs() < 1e-12);
        // naive pays 5 + 5 = 10
        let n = naive(&t);
        assert!((n.makespan - 10.0).abs() < 1e-12);
        assert!(r.makespan < n.makespan);
    }

    #[test]
    fn compute_bound_pipeline() {
        // comp >> comm: makespan ≈ cold comm + Σ comp
        let t = uniform(4, 2, 10.0, 0.1);
        let r = pipelined(&t);
        assert!((r.makespan - (0.1 + 3.0 * 10.0 + 10.0)).abs() < 1e-9);
        assert!((r.mean_rho() - 1.0).abs() < 1e-12);
        assert!(r.comm_exposed < 0.2);
    }

    #[test]
    fn comm_bound_pipeline_gains_nothing() {
        // comm >> comp: pipelining cannot hide anything
        let t = uniform(4, 2, 0.1, 10.0);
        let r = pipelined(&t);
        let n = naive(&t);
        // pipeline pays all transfers + final comp; ≈ naive
        assert!(r.makespan >= 0.99 * n.makespan - 0.5);
        assert!(r.mean_rho() < 0.02);
    }

    #[test]
    fn straggler_accounting() {
        // one slow rank at one step creates wait for the others
        let mut t = uniform(3, 3, 1.0, 1.0);
        t[1][2].comp = 5.0; // rank 2 is slow computing step 1's data
        let r = pipelined(&t);
        assert!(r.straggler > 0.0);
        // makespan grows by the extra 4s at stage 2
        assert!((r.makespan - (1.0 + 1.0 + 5.0 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn rho_zero_when_no_compute() {
        let t = uniform(3, 2, 0.0, 1.0);
        let r = pipelined(&t);
        assert!(r.mean_rho() < 1e-12);
        assert!((r.comm_exposed - 3.0).abs() < 1e-12);
    }

    #[test]
    fn measured_pipeline_accumulates_and_normalizes() {
        let mut m = MeasuredPipeline::new(2);
        // two combines with the same 3-step shape
        for _ in 0..2 {
            m.add_step(0, 0.0, 1.0); // cold start: pure wait
            m.add_step(1, 2.0, 0.0); // fully hidden
            m.add_step(2, 1.0, 1.0); // half hidden
            m.finish_combine();
        }
        assert_eq!(m.n_combines, 2);
        assert!((m.comp_s - 6.0).abs() < 1e-12);
        assert!((m.exposed_wait_s - 4.0).abs() < 1e-12);
        let means = m.mean_steps();
        assert_eq!(means.len(), 3);
        assert!((means[1].comp_s - 2.0).abs() < 1e-12);
        assert!((means[2].wait_s - 1.0).abs() < 1e-12);
        // rho: step0 excluded, step1 = 1.0, step2 = 0.5
        assert!((m.mean_rho() - 0.75).abs() < 1e-12);
        assert!((m.steps[0].rho() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn mean_steps_normalizes_by_per_step_combine_count() {
        // heterogeneous schedules (the adaptive sweep): a 1-step
        // all-to-all combine next to a 3-step ring — later steps must be
        // averaged over the combines that actually ran them
        let mut m = MeasuredPipeline::new(2);
        m.add_step(0, 1.0, 1.0);
        m.finish_combine();
        m.add_step(0, 3.0, 1.0);
        m.add_step(1, 2.0, 0.0);
        m.add_step(2, 4.0, 4.0);
        m.finish_combine();
        assert_eq!(m.step_counts, vec![2, 1, 1]);
        let means = m.mean_steps();
        assert!((means[0].comp_s - 2.0).abs() < 1e-12); // (1+3)/2
        assert!((means[1].comp_s - 2.0).abs() < 1e-12); // 2/1, not 2/2
        assert!((means[2].wait_s - 4.0).abs() < 1e-12); // 4/1
    }

    #[test]
    fn measured_pipeline_memory_observations() {
        let mut m = MeasuredPipeline::new(3);
        m.observe_rank(0, 100, 120);
        m.observe_rank(0, 80, 90); // maxima stick
        m.observe_rank(2, 50, 60);
        m.observe_in_flight_peak(40);
        m.observe_in_flight_peak(30);
        assert_eq!(m.recv_peak_per_rank, vec![100, 0, 50]);
        assert_eq!(m.max_step_recv_bytes_per_rank, vec![120, 0, 60]);
        assert_eq!(m.recv_peak(), 100);
        assert_eq!(m.in_flight_peak_bytes, 40);
    }

    #[test]
    fn measured_step_rho_edge_cases() {
        assert!((MeasuredStep { comp_s: 0.0, wait_s: 0.0 }.rho() - 1.0).abs() < 1e-12);
        assert!((MeasuredStep { comp_s: 3.0, wait_s: 1.0 }.rho() - 0.75).abs() < 1e-12);
        // single-step (all-to-all) exchanges have no overlap window
        let mut m = MeasuredPipeline::new(1);
        m.add_step(0, 5.0, 5.0);
        m.finish_combine();
        assert_eq!(m.mean_rho(), 0.0);
    }
}
