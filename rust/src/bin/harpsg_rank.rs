//! `harpsg-rank` — one rank of a process-mode count. Not meant to be run
//! by hand: the launcher (`harpsg count --fabric socket`, or
//! `coordinator::procmode::launch` from the API) spawns one of these per
//! rank, feeds the canonical run config on stdin, collects the listen
//! address, broadcasts the peer list, and parses the result block this
//! process prints on stdout. See `coordinator/procmode.rs` for the
//! protocol.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = harpsg::coordinator::rank_main(&args) {
        eprintln!("harpsg-rank: {e}");
        std::process::exit(1);
    }
}
