//! `bench-report` — the perf-trajectory harness.
//!
//! Runs the hot-path combine legs (scalar/simd × pruned/unpruned × row-
//! occupancy sweep — the same [`harpsg::metrics::legs`] workloads
//! `benches/hotpath.rs` prints) in **fixed-iteration** mode and writes
//! the machine-readable trajectory artifact (default `BENCH_10.json`).
//!
//! With `--floor <file>` it also enforces the CI gates and exits
//! non-zero on violation:
//! * no floored leg more than 25% below its checked-in floor
//!   (`benches/hotpath_floor.tsv` — conservative Munits/s minima meant
//!   to catch order-of-magnitude hot-path regressions on any runner);
//! * every pruned leg at frontier occupancy ≤ 0.2 at least 1.5× its
//!   unpruned twin (the ISSUE 10 acceptance speedup).
//!
//! Usage:
//!   bench-report [--iters N] [--workers N] [--out FILE] [--floor FILE]

use harpsg::metrics::legs::{
    check_floor, check_prune_ratio, default_legs, parse_floor, results_json,
};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut iters = 8usize;
    let mut workers = 1usize;
    let mut out = String::from("BENCH_10.json");
    let mut floor: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        let need = |n: usize| {
            args.get(n).unwrap_or_else(|| {
                eprintln!("{} needs a value", args[n - 1]);
                std::process::exit(2);
            })
        };
        match args[i].as_str() {
            "--iters" => {
                iters = need(i + 1).parse().expect("--iters N");
                i += 2;
            }
            "--workers" => {
                workers = need(i + 1).parse().expect("--workers N");
                i += 2;
            }
            "--out" => {
                out = need(i + 1).clone();
                i += 2;
            }
            "--floor" => {
                floor = Some(need(i + 1).clone());
                i += 2;
            }
            other => {
                eprintln!(
                    "unknown arg `{other}` — usage: bench-report [--iters N] \
                     [--workers N] [--out FILE] [--floor FILE]"
                );
                std::process::exit(2);
            }
        }
    }

    println!("bench-report: {iters} iterations per leg, {workers} worker(s)");
    let results: Vec<_> = default_legs()
        .iter()
        .map(|spec| {
            let r = harpsg::metrics::legs::run_leg(spec, iters, workers);
            println!(
                "  {:<36} {:>9.1} Munits/s  (pairs_skipped {}, rows_skipped {})",
                r.leg, r.munits_per_s, r.pairs_skipped, r.rows_skipped
            );
            r
        })
        .collect();

    let json = results_json(&results);
    if let Err(e) = std::fs::write(&out, &json) {
        eprintln!("bench-report: cannot write {out}: {e}");
        std::process::exit(1);
    }
    println!("wrote {out}");

    if let Some(path) = floor {
        let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
            eprintln!("bench-report: cannot read floor file {path}: {e}");
            std::process::exit(1);
        });
        let mut viols = check_floor(&results, &parse_floor(&text), 0.25);
        viols.extend(check_prune_ratio(&results, 1.5, 0.2));
        if !viols.is_empty() {
            eprintln!("bench-report: {} gate violation(s):", viols.len());
            for v in &viols {
                eprintln!("  {v}");
            }
            std::process::exit(1);
        }
        println!("floor + prune-speedup gates passed");
    }
}
