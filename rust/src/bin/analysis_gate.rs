//! CI entry point for the static-analysis gate ([`harpsg::analysis`]).
//!
//! Scans the crate's `src/` tree (or the directory given as the first
//! argument) and exits non-zero if any gate rule fires, printing one
//! `file:line [rule] detail` line per violation.

use std::path::PathBuf;
use std::process::ExitCode;

use harpsg::analysis;

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src"));
    match analysis::check_tree(&root) {
        Ok(v) if v.is_empty() => {
            println!("analysis gate: clean ({})", root.display());
            ExitCode::SUCCESS
        }
        Ok(v) => {
            eprint!("{}", analysis::render(&v));
            eprintln!("analysis gate: {} violation(s) in {}", v.len(), root.display());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("analysis gate: cannot scan {}: {e}", root.display());
            ExitCode::FAILURE
        }
    }
}
