//! `harpsg-bench` — regenerate every table and figure of the paper.
//!
//! Usage:
//!   harpsg-bench all [--scale-mult N] [--iters N] [--seed S]
//!   harpsg-bench table3 fig6 fig7 ... (any subset of IDs)
//!
//! Prints each series as markdown and writes `results/<id>.md` + `.csv`.

use harpsg::figures::{run_figure, FigureCtx, ALL_FIGURES};
use harpsg::metrics::{write_result, Timer};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!(
            "usage: harpsg-bench <all|{}> [--scale-mult N] [--iters N] [--seed S]",
            ALL_FIGURES.join("|")
        );
        std::process::exit(2);
    }
    let mut ctx = FigureCtx::default();
    let mut ids: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--scale-mult" => {
                ctx.scale_mult = args[i + 1].parse().expect("--scale-mult N");
                i += 2;
            }
            "--iters" => {
                ctx.iters = args[i + 1].parse().expect("--iters N");
                i += 2;
            }
            "--seed" => {
                ctx.seed = args[i + 1].parse().expect("--seed S");
                i += 2;
            }
            "all" => {
                ids.extend(ALL_FIGURES.iter().map(|s| s.to_string()));
                i += 1;
            }
            other => {
                ids.push(other.to_string());
                i += 1;
            }
        }
    }

    for id in &ids {
        let t = Timer::start();
        let Some(series) = run_figure(id, &ctx) else {
            eprintln!("unknown figure id `{id}` — known: {}", ALL_FIGURES.join(", "));
            std::process::exit(2);
        };
        let mut md = String::new();
        let mut csv = String::new();
        for s in &series {
            md.push_str(&s.to_markdown());
            md.push('\n');
            csv.push_str(&s.to_csv());
            csv.push('\n');
        }
        println!("{md}");
        println!("[{id}: {:.1}s]", t.secs());
        let _ = write_result(&format!("{id}.md"), &md);
        let _ = write_result(&format!("{id}.csv"), &csv);
    }
}
