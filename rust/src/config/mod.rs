//! Run configuration: a minimal TOML-subset parser (the vendored crate set
//! has no `toml`/`serde` facade) and the `RunSpec` that the CLI launcher
//! maps onto a coordinator `RunConfig` + dataset + template.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! ("..."), integer, float and boolean values, `#` comments.

use crate::comm::HockneyParams;
use crate::coordinator::{EngineKind, ModeSelect, RunConfig};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// Parsed TOML-subset document: `section.key -> raw value` (top-level keys
/// live under the empty section "").
#[derive(Debug, Clone, Default)]
pub struct Doc {
    values: HashMap<String, Value>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", ln + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", ln + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let v = v.trim();
            let val = if let Some(s) = v.strip_prefix('"') {
                Value::Str(
                    s.strip_suffix('"')
                        .ok_or_else(|| anyhow!("line {}: unterminated string", ln + 1))?
                        .to_string(),
                )
            } else if v == "true" {
                Value::Bool(true)
            } else if v == "false" {
                Value::Bool(false)
            } else if let Ok(i) = v.parse::<i64>() {
                Value::Int(i)
            } else if let Ok(f) = v.parse::<f64>() {
                Value::Float(f)
            } else {
                bail!("line {}: cannot parse value `{v}`", ln + 1);
            };
            values.insert(key, val);
        }
        Ok(Doc { values })
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        match self.values.get(key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn int(&self, key: &str) -> Option<i64> {
        match self.values.get(key) {
            Some(Value::Int(i)) => Some(*i),
            _ => None,
        }
    }

    pub fn float(&self, key: &str) -> Option<f64> {
        match self.values.get(key) {
            Some(Value::Float(f)) => Some(*f),
            Some(Value::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn bool(&self, key: &str) -> Option<bool> {
        match self.values.get(key) {
            Some(Value::Bool(b)) => Some(*b),
            _ => None,
        }
    }
}

/// A full experiment specification (what the CLI launches).
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// builtin template name or a path to a template file
    pub template: String,
    /// dataset abbreviation (Table 2) or a path to an edge list
    pub dataset: String,
    /// dataset downscale factor
    pub scale: u32,
    pub run: RunConfig,
}

impl RunSpec {
    pub fn from_doc(doc: &Doc) -> Result<RunSpec> {
        let template = doc
            .str("template")
            .context("missing `template`")?
            .to_string();
        let dataset = doc.str("dataset").context("missing `dataset`")?.to_string();
        let scale = doc.int("scale").unwrap_or(500) as u32;
        let mut run = RunConfig::default();
        if let Some(p) = doc.int("run.ranks") {
            run.n_ranks = p as usize;
        }
        if let Some(t) = doc.int("run.threads") {
            run.n_threads = t as usize;
        }
        if let Some(s) = doc.int("run.task_size") {
            run.task_size = s as u32;
        }
        if let Some(n) = doc.int("run.iterations") {
            run.n_iterations = n as usize;
        }
        if let Some(s) = doc.int("run.seed") {
            run.seed = s as u64;
        }
        if let Some(m) = doc.str("run.mode") {
            run.mode = match m {
                "naive" => ModeSelect::Naive,
                "pipeline" => ModeSelect::Pipeline,
                "adaptive" => ModeSelect::Adaptive,
                "adaptive-lb" | "adaptivelb" => ModeSelect::AdaptiveLb,
                other => bail!("unknown mode `{other}`"),
            };
        }
        if let Some(e) = doc.str("run.engine") {
            run.engine = match e {
                "native" => EngineKind::Native,
                "xla" => EngineKind::Xla,
                other => bail!("unknown engine `{other}`"),
            };
        }
        if let Some(a) = doc.float("net.alpha") {
            run.net.alpha = a;
        }
        if let Some(b) = doc.float("net.beta") {
            run.net.beta = b;
        }
        if doc.str("net.preset") == Some("10gbe") {
            run.net = HockneyParams::tengige();
        }
        if let Some(l) = doc.int("run.mem_limit_mb") {
            run.mem_limit = Some((l as u64) << 20);
        }
        Ok(RunSpec {
            template,
            dataset,
            scale,
            run,
        })
    }

    pub fn parse(text: &str) -> Result<RunSpec> {
        Self::from_doc(&Doc::parse(text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# quickstart config
template = "u10-2"
dataset = "R500K3"
scale = 1000

[run]
ranks = 8
threads = 48
task_size = 50
iterations = 2
mode = "adaptive-lb"
engine = "native"

[net]
alpha = 2e-6
beta = 1.7e-10
"#;

    #[test]
    fn parses_sample() {
        let spec = RunSpec::parse(SAMPLE).unwrap();
        assert_eq!(spec.template, "u10-2");
        assert_eq!(spec.dataset, "R500K3");
        assert_eq!(spec.scale, 1000);
        assert_eq!(spec.run.n_ranks, 8);
        assert_eq!(spec.run.mode, ModeSelect::AdaptiveLb);
        assert!((spec.run.net.alpha - 2e-6).abs() < 1e-18);
    }

    #[test]
    fn rejects_bad_mode() {
        let bad = SAMPLE.replace("adaptive-lb", "warp-drive");
        assert!(RunSpec::parse(&bad).is_err());
    }

    #[test]
    fn missing_template_errors() {
        assert!(RunSpec::parse("dataset = \"MI\"").is_err());
    }

    #[test]
    fn doc_value_kinds() {
        let d = Doc::parse("a = 3\nb = 2.5\nc = \"x\"\nd = true\n[s]\ne = 1").unwrap();
        assert_eq!(d.int("a"), Some(3));
        assert_eq!(d.float("b"), Some(2.5));
        assert_eq!(d.float("a"), Some(3.0));
        assert_eq!(d.str("c"), Some("x"));
        assert_eq!(d.bool("d"), Some(true));
        assert_eq!(d.int("s.e"), Some(1));
    }

    #[test]
    fn doc_errors() {
        assert!(Doc::parse("[open").is_err());
        assert!(Doc::parse("novalue").is_err());
        assert!(Doc::parse("x = \"unterminated").is_err());
        assert!(Doc::parse("x = 1 2 3").is_err());
    }
}
