//! Run configuration: a minimal TOML-subset parser (the vendored crate set
//! has no `toml`/`serde` facade) and the `RunSpec` that the CLI launcher
//! maps onto a coordinator `RunConfig` + dataset + template.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! ("..."), integer, float and boolean values, `#` comments.

use crate::api::HarpsgError;
use crate::colorcount::{KernelMode, PruneMode, StorageMode};
use crate::comm::HockneyParams;
use crate::coordinator::{EngineKind, ExchangeExec, FabricKind, ModeSelect, RunConfig};
use crate::graph::GraphStorageMode;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Parsed TOML-subset document: `section.key -> raw value` (top-level keys
/// live under the empty section "").
#[derive(Debug, Clone, Default)]
pub struct Doc {
    values: HashMap<String, Value>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", ln + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", ln + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let v = v.trim();
            let val = if let Some(s) = v.strip_prefix('"') {
                Value::Str(
                    s.strip_suffix('"')
                        .ok_or_else(|| anyhow!("line {}: unterminated string", ln + 1))?
                        .to_string(),
                )
            } else if v == "true" {
                Value::Bool(true)
            } else if v == "false" {
                Value::Bool(false)
            } else if let Ok(i) = v.parse::<i64>() {
                Value::Int(i)
            } else if let Ok(f) = v.parse::<f64>() {
                Value::Float(f)
            } else {
                bail!("line {}: cannot parse value `{v}`", ln + 1);
            };
            values.insert(key, val);
        }
        Ok(Doc { values })
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        match self.values.get(key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn int(&self, key: &str) -> Option<i64> {
        match self.values.get(key) {
            Some(Value::Int(i)) => Some(*i),
            _ => None,
        }
    }

    pub fn float(&self, key: &str) -> Option<f64> {
        match self.values.get(key) {
            Some(Value::Float(f)) => Some(*f),
            Some(Value::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn bool(&self, key: &str) -> Option<bool> {
        match self.values.get(key) {
            Some(Value::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    /// Raw value access (lets callers distinguish "missing" from "wrong
    /// type", which the permissive typed getters above cannot).
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    /// All keys present in the document (section-qualified).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|k| k.as_str())
    }
}

/// A full experiment specification (what the CLI launches).
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// builtin template name or a path to a template file
    pub template: String,
    /// dataset abbreviation (Table 2) or a path to an edge list
    pub dataset: String,
    /// dataset downscale factor
    pub scale: u32,
    pub run: RunConfig,
}

/// The keys `RunSpec::from_doc` understands; anything else is a typo and
/// is rejected with `HarpsgError::UnknownFlag` instead of being silently
/// ignored.
const KNOWN_KEYS: [&str; 23] = [
    "template",
    "dataset",
    "scale",
    "run.ranks",
    "run.threads",
    "run.workers",
    "run.task_size",
    "run.iterations",
    "run.seed",
    "run.mode",
    "run.engine",
    "run.exchange",
    "run.fabric",
    "run.adaptive",
    "run.table_storage",
    "run.kernel",
    "run.prune",
    "run.graph_storage",
    "run.graph_budget_mb",
    "run.mem_limit_mb",
    "net.alpha",
    "net.beta",
    "net.preset",
];

fn want_int(doc: &Doc, key: &str) -> Result<Option<i64>, HarpsgError> {
    match doc.get(key) {
        None => Ok(None),
        Some(Value::Int(i)) => Ok(Some(*i)),
        Some(other) => Err(HarpsgError::Parse(format!(
            "`{key}`: expected an integer, got {other:?}"
        ))),
    }
}

fn want_str<'d>(doc: &'d Doc, key: &str) -> Result<Option<&'d str>, HarpsgError> {
    match doc.get(key) {
        None => Ok(None),
        Some(Value::Str(s)) => Ok(Some(s)),
        Some(other) => Err(HarpsgError::Parse(format!(
            "`{key}`: expected a string, got {other:?}"
        ))),
    }
}

fn want_float(doc: &Doc, key: &str) -> Result<Option<f64>, HarpsgError> {
    match doc.get(key) {
        None => Ok(None),
        Some(Value::Float(f)) => Ok(Some(*f)),
        Some(Value::Int(i)) => Ok(Some(*i as f64)),
        Some(other) => Err(HarpsgError::Parse(format!(
            "`{key}`: expected a number, got {other:?}"
        ))),
    }
}

fn want_bool(doc: &Doc, key: &str) -> Result<Option<bool>, HarpsgError> {
    match doc.get(key) {
        None => Ok(None),
        Some(Value::Bool(b)) => Ok(Some(*b)),
        Some(other) => Err(HarpsgError::Parse(format!(
            "`{key}`: expected a boolean, got {other:?}"
        ))),
    }
}

fn want_nonneg(doc: &Doc, key: &str) -> Result<Option<i64>, HarpsgError> {
    match want_int(doc, key)? {
        Some(v) if v < 0 => Err(HarpsgError::Parse(format!(
            "`{key}`: must be non-negative, got {v}"
        ))),
        other => Ok(other),
    }
}

impl RunSpec {
    pub fn from_doc(doc: &Doc) -> Result<RunSpec, HarpsgError> {
        for key in doc.keys() {
            if !KNOWN_KEYS.contains(&key) {
                return Err(HarpsgError::UnknownFlag(key.to_string()));
            }
        }
        let template = want_str(doc, "template")?
            .ok_or_else(|| HarpsgError::MissingValue("config key `template`".into()))?
            .to_string();
        let dataset = want_str(doc, "dataset")?
            .ok_or_else(|| HarpsgError::MissingValue("config key `dataset`".into()))?
            .to_string();
        let scale = want_nonneg(doc, "scale")?.unwrap_or(500) as u32;
        let mut run = RunConfig::default();
        if let Some(p) = want_nonneg(doc, "run.ranks")? {
            run.n_ranks = p as usize;
        }
        if let Some(t) = want_nonneg(doc, "run.threads")? {
            run.n_threads = t as usize;
        }
        if let Some(w) = want_nonneg(doc, "run.workers")? {
            // range validation (≥ 1, ≤ 512) happens in CountJob::build
            run.n_workers = w as usize;
        }
        let task_size_set = want_nonneg(doc, "run.task_size")?;
        if let Some(s) = task_size_set {
            run.task_size = s as u32;
        }
        if let Some(n) = want_nonneg(doc, "run.iterations")? {
            run.n_iterations = n as usize;
        }
        if let Some(s) = want_nonneg(doc, "run.seed")? {
            run.seed = s as u64;
        }
        if let Some(m) = want_str(doc, "run.mode")? {
            run.mode =
                ModeSelect::parse(m).ok_or_else(|| HarpsgError::UnknownMode(m.to_string()))?;
        }
        if let Some(e) = want_str(doc, "run.engine")? {
            run.engine =
                EngineKind::parse(e).ok_or_else(|| HarpsgError::UnknownEngine(e.to_string()))?;
        }
        if let Some(x) = want_str(doc, "run.exchange")? {
            run.exchange = ExchangeExec::parse(x).ok_or_else(|| {
                HarpsgError::Parse(format!(
                    "`run.exchange`: unknown executor `{x}` (threaded|sequential)"
                ))
            })?;
        }
        if let Some(f) = want_str(doc, "run.fabric")? {
            run.fabric = FabricKind::parse(f).ok_or_else(|| {
                HarpsgError::Parse(format!(
                    "`run.fabric`: unknown fabric `{f}` (threaded|socket)"
                ))
            })?;
        }
        if let Some(b) = want_bool(doc, "run.adaptive")? {
            run.adaptive_group = b;
        }
        if let Some(s) = want_str(doc, "run.table_storage")? {
            run.table_storage = StorageMode::parse(s).ok_or_else(|| {
                HarpsgError::Parse(format!(
                    "`run.table_storage`: unknown storage `{s}` (dense|sparse|auto)"
                ))
            })?;
        }
        if let Some(s) = want_str(doc, "run.kernel")? {
            run.kernel = KernelMode::parse(s).ok_or_else(|| {
                HarpsgError::Parse(format!(
                    "`run.kernel`: unknown kernel `{s}` (scalar|simd|auto)"
                ))
            })?;
        }
        if let Some(s) = want_str(doc, "run.prune")? {
            run.prune = PruneMode::parse(s).ok_or_else(|| {
                HarpsgError::Parse(format!("`run.prune`: unknown mode `{s}` (on|off|auto)"))
            })?;
        }
        if let Some(a) = want_float(doc, "net.alpha")? {
            run.net.alpha = a;
        }
        if let Some(b) = want_float(doc, "net.beta")? {
            run.net.beta = b;
        }
        if let Some(preset) = want_str(doc, "net.preset")? {
            run.net = match preset {
                "10gbe" => HockneyParams::tengige(),
                "infiniband" => HockneyParams::infiniband(),
                other => {
                    return Err(HarpsgError::Parse(format!(
                        "`net.preset`: unknown preset `{other}` (10gbe|infiniband)"
                    )))
                }
            };
        }
        if let Some(s) = want_str(doc, "run.graph_storage")? {
            run.graph_storage = GraphStorageMode::parse(s).ok_or_else(|| {
                HarpsgError::Parse(format!(
                    "`run.graph_storage`: unknown storage `{s}` (resident|mmap|auto)"
                ))
            })?;
        }
        if let Some(b) = want_nonneg(doc, "run.graph_budget_mb")? {
            run.graph_budget = Some((b as u64) << 20);
        }
        if let Some(l) = want_nonneg(doc, "run.mem_limit_mb")? {
            run.mem_limit = Some((l as u64) << 20);
        }
        // the same mode/task-size consistency the CountJob builder
        // enforces: an explicitly configured task size is meaningless
        // outside adaptive-lb and should fail loudly, not be ignored
        if task_size_set.is_some() && run.mode != ModeSelect::AdaptiveLb {
            return Err(HarpsgError::InvalidJob(format!(
                "`run.task_size` only applies to adaptive-lb; mode is {}",
                run.mode.flag()
            )));
        }
        // mirror the CountJob builder: the model-driven sweep only makes
        // sense when an adaptive mode is driving the decision
        if run.adaptive_group
            && !matches!(run.mode, ModeSelect::Adaptive | ModeSelect::AdaptiveLb)
        {
            return Err(HarpsgError::InvalidJob(format!(
                "`run.adaptive` only applies to adaptive/adaptive-lb; mode is {}",
                run.mode.flag()
            )));
        }
        Ok(RunSpec {
            template,
            dataset,
            scale,
            run,
        })
    }

    pub fn parse(text: &str) -> Result<RunSpec, HarpsgError> {
        let doc = Doc::parse(text).map_err(|e| HarpsgError::Parse(format!("{e:#}")))?;
        Self::from_doc(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# quickstart config
template = "u10-2"
dataset = "R500K3"
scale = 1000

[run]
ranks = 8
threads = 48
workers = 4
task_size = 50
iterations = 2
mode = "adaptive-lb"
engine = "native"

[net]
alpha = 2e-6
beta = 1.7e-10
"#;

    #[test]
    fn parses_sample() {
        let spec = RunSpec::parse(SAMPLE).unwrap();
        assert_eq!(spec.template, "u10-2");
        assert_eq!(spec.dataset, "R500K3");
        assert_eq!(spec.scale, 1000);
        assert_eq!(spec.run.n_ranks, 8);
        assert_eq!(spec.run.n_workers, 4);
        assert_eq!(spec.run.mode, ModeSelect::AdaptiveLb);
        assert!((spec.run.net.alpha - 2e-6).abs() < 1e-18);
    }

    #[test]
    fn workers_key_parses_and_defaults() {
        // default when omitted
        let spec = RunSpec::parse(&SAMPLE.replace("workers = 4\n", "")).unwrap();
        assert_eq!(spec.run.n_workers, 1);
        // wrong type is a typed parse error
        let bad = SAMPLE.replace("workers = 4", "workers = \"four\"");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
    }

    #[test]
    fn exchange_key_parses_and_defaults() {
        // default when omitted: the rank-parallel pipelined executor
        let spec = RunSpec::parse(SAMPLE).unwrap();
        assert_eq!(spec.run.exchange, ExchangeExec::Threaded);
        let with_key = format!("{SAMPLE}\n[run]\nexchange = \"sequential\"\n");
        assert_eq!(
            RunSpec::parse(&with_key).unwrap().run.exchange,
            ExchangeExec::Sequential
        );
        let bad = format!("{SAMPLE}\n[run]\nexchange = \"quantum\"\n");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
    }

    #[test]
    fn fabric_key_parses_and_validates() {
        // default when omitted: the in-process threaded fabric
        assert_eq!(RunSpec::parse(SAMPLE).unwrap().run.fabric, FabricKind::Threaded);
        for (spelling, kind) in [
            ("threaded", FabricKind::Threaded),
            ("socket", FabricKind::Socket),
        ] {
            let with_key = format!("{SAMPLE}\n[run]\nfabric = \"{spelling}\"\n");
            assert_eq!(RunSpec::parse(&with_key).unwrap().run.fabric, kind);
        }
        // unknown spellings and wrong types are typed errors
        let bad = format!("{SAMPLE}\n[run]\nfabric = \"mpi\"\n");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
        let bad = format!("{SAMPLE}\n[run]\nfabric = 2\n");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
    }

    #[test]
    fn adaptive_key_parses_and_validates() {
        // default: off
        assert!(!RunSpec::parse(SAMPLE).unwrap().run.adaptive_group);
        let with_key = format!("{SAMPLE}\n[run]\nadaptive = true\n");
        assert!(RunSpec::parse(&with_key).unwrap().run.adaptive_group);
        // wrong type is a typed parse error
        let bad = format!("{SAMPLE}\n[run]\nadaptive = 1\n");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
        // sweep without an adaptive mode is inconsistent
        let naive = SAMPLE
            .replace("mode = \"adaptive-lb\"", "mode = \"naive\"")
            .replace("task_size = 50\n", "");
        let bad = format!("{naive}\n[run]\nadaptive = true\n");
        assert!(matches!(
            RunSpec::parse(&bad),
            Err(HarpsgError::InvalidJob(_))
        ));
        // …and `adaptive = false` with any mode stays fine
        let ok = format!("{naive}\n[run]\nadaptive = false\n");
        assert!(!RunSpec::parse(&ok).unwrap().run.adaptive_group);
    }

    #[test]
    fn table_storage_key_parses_and_validates() {
        // default: the historical dense layout
        assert_eq!(
            RunSpec::parse(SAMPLE).unwrap().run.table_storage,
            StorageMode::Dense
        );
        for (spelling, mode) in [
            ("dense", StorageMode::Dense),
            ("sparse", StorageMode::Sparse),
            ("auto", StorageMode::Auto),
        ] {
            let with_key = format!("{SAMPLE}\n[run]\ntable_storage = \"{spelling}\"\n");
            assert_eq!(RunSpec::parse(&with_key).unwrap().run.table_storage, mode);
        }
        // unknown spellings and wrong types are typed errors
        let bad = format!("{SAMPLE}\n[run]\ntable_storage = \"csr\"\n");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
        let bad = format!("{SAMPLE}\n[run]\ntable_storage = 1\n");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
    }

    #[test]
    fn kernel_key_parses_and_validates() {
        // default: the scalar differential baseline
        assert_eq!(
            RunSpec::parse(SAMPLE).unwrap().run.kernel,
            KernelMode::Scalar
        );
        for (spelling, mode) in [
            ("scalar", KernelMode::Scalar),
            ("simd", KernelMode::Simd),
            ("auto", KernelMode::Auto),
        ] {
            let with_key = format!("{SAMPLE}\n[run]\nkernel = \"{spelling}\"\n");
            assert_eq!(RunSpec::parse(&with_key).unwrap().run.kernel, mode);
        }
        // unknown spellings and wrong types are typed errors
        let bad = format!("{SAMPLE}\n[run]\nkernel = \"avx\"\n");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
        let bad = format!("{SAMPLE}\n[run]\nkernel = 8\n");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
    }

    #[test]
    fn prune_key_parses_and_validates() {
        // default: the historical unpruned combine
        assert_eq!(RunSpec::parse(SAMPLE).unwrap().run.prune, PruneMode::Off);
        for (spelling, mode) in [
            ("on", PruneMode::On),
            ("off", PruneMode::Off),
            ("auto", PruneMode::Auto),
        ] {
            let with_key = format!("{SAMPLE}\n[run]\nprune = \"{spelling}\"\n");
            assert_eq!(RunSpec::parse(&with_key).unwrap().run.prune, mode);
        }
        // unknown spellings and wrong types are typed errors
        let bad = format!("{SAMPLE}\n[run]\nprune = \"maybe\"\n");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
        let bad = format!("{SAMPLE}\n[run]\nprune = 1\n");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
    }

    #[test]
    fn graph_storage_key_parses_and_validates() {
        // default: the historical fully resident CSR
        assert_eq!(
            RunSpec::parse(SAMPLE).unwrap().run.graph_storage,
            GraphStorageMode::Resident
        );
        assert_eq!(RunSpec::parse(SAMPLE).unwrap().run.graph_budget, None);
        for (spelling, mode) in [
            ("resident", GraphStorageMode::Resident),
            ("mmap", GraphStorageMode::Mmap),
            ("auto", GraphStorageMode::Auto),
        ] {
            let with_key = format!("{SAMPLE}\n[run]\ngraph_storage = \"{spelling}\"\n");
            assert_eq!(RunSpec::parse(&with_key).unwrap().run.graph_storage, mode);
        }
        // the budget arrives in MiB and lands in bytes
        let with_budget = format!("{SAMPLE}\n[run]\ngraph_budget_mb = 256\n");
        assert_eq!(
            RunSpec::parse(&with_budget).unwrap().run.graph_budget,
            Some(256 << 20)
        );
        // unknown spellings and wrong types are typed errors
        let bad = format!("{SAMPLE}\n[run]\ngraph_storage = \"disk\"\n");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
        let bad = format!("{SAMPLE}\n[run]\ngraph_storage = 2\n");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
        let bad = format!("{SAMPLE}\n[run]\ngraph_budget_mb = -1\n");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
    }

    #[test]
    fn rejects_bad_mode() {
        let bad = SAMPLE.replace("adaptive-lb", "warp-drive");
        assert!(matches!(
            RunSpec::parse(&bad),
            Err(HarpsgError::UnknownMode(m)) if m == "warp-drive"
        ));
    }

    #[test]
    fn rejects_bad_engine() {
        let bad = SAMPLE.replace("\"native\"", "\"tpu\"");
        assert!(matches!(
            RunSpec::parse(&bad),
            Err(HarpsgError::UnknownEngine(e)) if e == "tpu"
        ));
    }

    #[test]
    fn missing_template_errors() {
        assert!(matches!(
            RunSpec::parse("dataset = \"MI\""),
            Err(HarpsgError::MissingValue(_))
        ));
    }

    #[test]
    fn rejects_unknown_keys() {
        let bad = format!("{SAMPLE}\n[run]\nrnaks = 8\n");
        assert!(matches!(
            RunSpec::parse(&bad),
            Err(HarpsgError::UnknownFlag(k)) if k == "run.rnaks"
        ));
    }

    #[test]
    fn rejects_wrong_value_types() {
        // ranks as a string
        let bad = SAMPLE.replace("ranks = 8", "ranks = \"eight\"");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
        // template as an integer
        let bad = SAMPLE.replace("template = \"u10-2\"", "template = 3");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
        // negative iterations
        let bad = SAMPLE.replace("iterations = 2", "iterations = -2");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
        // alpha as a bool
        let bad = SAMPLE.replace("alpha = 2e-6", "alpha = true");
        assert!(matches!(RunSpec::parse(&bad), Err(HarpsgError::Parse(_))));
    }

    #[test]
    fn rejects_task_size_outside_adaptive_lb() {
        let bad = SAMPLE.replace("mode = \"adaptive-lb\"", "mode = \"naive\"");
        assert!(matches!(
            RunSpec::parse(&bad),
            Err(HarpsgError::InvalidJob(_))
        ));
        // dropping the explicit task_size makes the same mode valid
        let ok = bad.replace("task_size = 50\n", "");
        assert_eq!(RunSpec::parse(&ok).unwrap().run.mode, ModeSelect::Naive);
    }

    #[test]
    fn rejects_unknown_net_preset() {
        let spec = format!("{SAMPLE}\n[net]\npreset = \"carrier-pigeon\"\n");
        assert!(matches!(RunSpec::parse(&spec), Err(HarpsgError::Parse(_))));
        let ok = format!("{SAMPLE}\n[net]\npreset = \"10gbe\"\n");
        let parsed = RunSpec::parse(&ok).unwrap();
        assert_eq!(parsed.run.net, HockneyParams::tengige());
    }

    #[test]
    fn doc_syntax_errors_are_typed() {
        assert!(matches!(
            RunSpec::parse("template = "),
            Err(HarpsgError::Parse(_))
        ));
    }

    #[test]
    fn doc_value_kinds() {
        let d = Doc::parse("a = 3\nb = 2.5\nc = \"x\"\nd = true\n[s]\ne = 1").unwrap();
        assert_eq!(d.int("a"), Some(3));
        assert_eq!(d.float("b"), Some(2.5));
        assert_eq!(d.float("a"), Some(3.0));
        assert_eq!(d.str("c"), Some("x"));
        assert_eq!(d.bool("d"), Some(true));
        assert_eq!(d.int("s.e"), Some(1));
    }

    #[test]
    fn doc_errors() {
        assert!(Doc::parse("[open").is_err());
        assert!(Doc::parse("novalue").is_err());
        assert!(Doc::parse("x = \"unterminated").is_err());
        assert!(Doc::parse("x = 1 2 3").is_err());
    }
}
