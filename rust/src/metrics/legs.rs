//! Perf-trajectory legs: the synthetic pruned/unpruned combine workloads
//! behind the `bench-report` bin and the pruned section of
//! `benches/hotpath.rs`.
//!
//! Each leg is one DP combine at the u12 mid shape (k=12, a=6, a1=2 —
//! n_agg = 495, wide enough for the SIMD lane tree) over a ring graph,
//! with both tables thinned to a target *row* occupancy: a dead row is
//! all-zero, exactly what the frontier layer detects. Pruned legs filter
//! pairs by the active table's frontier and pass the passive frontier
//! plus a [`TaskCostModel`] to [`combine_batches_pruned`] — the same
//! call shape `coordinator::dist` uses. Throughput is reported in
//! Munits/s of the **unpruned** unit count for both variants, so the
//! pruned/unpruned ratio reads directly as end-to-end speedup on the
//! same logical work.
//!
//! The module also owns the `BENCH_10.json` emitter and the floor /
//! speedup checks the CI job enforces, so the comparison logic is unit-
//! tested here rather than living in shell.

use std::fmt::Write as _;
use std::time::Instant;

use crate::colorcount::{
    combine_batches_pruned, combine_batches_with, CountTable, KernelMode, PairBatch, RowsRef,
};
use crate::combin::{Binomial, SplitTable};
use crate::sched::TaskCostModel;

/// One synthetic combine workload.
#[derive(Debug, Clone)]
pub struct LegSpec {
    pub kernel: KernelMode,
    pub pruned: bool,
    /// target fraction of live rows in both tables
    pub occupancy: f64,
    /// vertices (= table rows)
    pub n: usize,
    /// ring out-degree (pairs = n * deg)
    pub deg: usize,
}

impl LegSpec {
    /// Stable leg identifier — the floor file keys on this.
    pub fn name(&self) -> String {
        format!(
            "combine/{}/{}/occ{:.2}",
            self.kernel.name(),
            if self.pruned { "pruned" } else { "unpruned" },
            self.occupancy
        )
    }
}

/// Measured outcome of one leg over its fixed iteration count.
#[derive(Debug, Clone)]
pub struct LegResult {
    pub leg: String,
    pub kernel: &'static str,
    pub pruned: bool,
    pub occupancy: f64,
    pub munits_per_s: f64,
    pub pairs_skipped: u64,
    pub rows_skipped: u64,
}

/// The trajectory's standard sweep: scalar/simd × unpruned/pruned at
/// full, half, low (the acceptance 0.2) and very-low row occupancy.
pub fn default_legs() -> Vec<LegSpec> {
    let mut legs = Vec::new();
    for &kernel in &[KernelMode::Scalar, KernelMode::Simd] {
        for &pruned in &[false, true] {
            for &occupancy in &[1.0f64, 0.5, 0.2, 0.05] {
                legs.push(LegSpec {
                    kernel,
                    pruned,
                    occupancy,
                    n: 1024,
                    deg: 16,
                });
            }
        }
    }
    legs
}

/// Deterministic row-liveness hash: row `r` (salted) is live with
/// probability ≈ `occupancy`. Knuth multiplicative scatter, so dead rows
/// are spread, not a prefix.
fn row_live(r: usize, salt: u64, occupancy: f64) -> bool {
    let h = (r as u64).wrapping_add(salt).wrapping_mul(2654435761) >> 13;
    (h % 1000) < (occupancy * 1000.0) as u64
}

fn mk_table(n: usize, n_sets: usize, salt: u64, occupancy: f64) -> CountTable {
    let mut t = CountTable::zeros(n, n_sets);
    for r in 0..n {
        if row_live(r, salt, occupancy) {
            for (s, x) in t.row_mut(r).iter_mut().enumerate() {
                *x = ((r * 7 + s * 3) % 5) as f32 + 1.0;
            }
        }
    }
    t
}

/// Run one leg for exactly `iters` combines and report its throughput.
/// The workload (tables, pair list, frontiers) is built once outside the
/// timed region; `n_workers = 1` measures the pure kernel path.
pub fn run_leg(spec: &LegSpec, iters: usize, n_workers: usize) -> LegResult {
    let binom = Binomial::new();
    let split = SplitTable::new(12, 6, 2, &binom);
    let c2 = binom.c(12, 4) as usize;
    let passive = mk_table(spec.n, binom.c(12, 2) as usize, 17, spec.occupancy);
    let active = mk_table(spec.n, c2, 53, spec.occupancy);
    let pairs: Vec<(u32, u32)> = (0..spec.n as u32)
        .flat_map(|v| (1..=spec.deg as u32).map(move |d| (v, (v + d) % spec.n as u32)))
        .collect();
    let act_front = active.frontier();
    let pass_front = passive.frontier();
    let kept: Vec<(u32, u32)> = pairs
        .iter()
        .copied()
        .filter(|&(_, u)| act_front.contains(u as usize))
        .collect();
    let cost_model = TaskCostModel {
        unit_per_pair: (split.n_sets * split.n_splits) as f64,
        unit_per_task: 0.0,
        overhead: 0.0,
    };
    let mut out = CountTable::zeros(spec.n, split.n_sets);
    let mut pairs_skipped = 0u64;
    let mut rows_skipped = 0u64;
    let start = Instant::now();
    for _ in 0..iters.max(1) {
        if spec.pruned {
            let batch = [PairBatch {
                pairs: &kept,
                rows: RowsRef::dense(&active),
            }];
            let st = combine_batches_pruned(
                &mut out,
                RowsRef::dense(&passive),
                &split,
                &batch,
                0,
                n_workers,
                spec.kernel,
                Some(&pass_front),
                Some(&cost_model),
            );
            pairs_skipped += (pairs.len() - kept.len()) as u64;
            rows_skipped += st.rows_skipped;
        } else {
            let batch = [PairBatch {
                pairs: &pairs,
                rows: RowsRef::dense(&active),
            }];
            combine_batches_with(
                &mut out,
                RowsRef::dense(&passive),
                &split,
                &batch,
                0,
                n_workers,
                spec.kernel,
            );
        }
    }
    let secs = start.elapsed().as_secs_f64().max(1e-9);
    std::hint::black_box(&out);
    // unpruned unit count for *both* variants: Munits/s then compares as
    // speedup on identical logical work
    let units_per_iter = pairs.len() as f64 * c2 as f64
        + spec.n as f64 * (split.n_sets * split.n_splits) as f64;
    LegResult {
        leg: spec.name(),
        kernel: spec.kernel.name(),
        pruned: spec.pruned,
        occupancy: spec.occupancy,
        munits_per_s: units_per_iter * iters.max(1) as f64 / secs / 1e6,
        pairs_skipped,
        rows_skipped,
    }
}

/// Render the trajectory artifact (hand-rolled: the vendored crate set
/// has no serde).
pub fn results_json(results: &[LegResult]) -> String {
    let mut s = String::new();
    s.push_str("{\n  \"issue\": 10,\n  \"unit\": \"Munits/s of the unpruned unit count\",\n");
    s.push_str("  \"legs\": [\n");
    for (i, r) in results.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"leg\": \"{}\", \"kernel\": \"{}\", \"pruned\": {}, \
             \"occupancy\": {}, \"munits_per_s\": {:.3}, \
             \"pairs_skipped\": {}, \"rows_skipped\": {}}}",
            r.leg, r.kernel, r.pruned, r.occupancy, r.munits_per_s, r.pairs_skipped,
            r.rows_skipped
        );
        s.push_str(if i + 1 < results.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// Parse the checked-in floor file: one `<leg> <Munits/s>` pair per
/// line, `#` comments and blank lines ignored.
pub fn parse_floor(text: &str) -> Vec<(String, f64)> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let (leg, floor) = l.split_once(char::is_whitespace)?;
            Some((leg.to_string(), floor.trim().parse().ok()?))
        })
        .collect()
}

/// Regression gate: every floored leg must reach `floor × (1 −
/// max_regression)`. Returns human-readable violations (empty = pass);
/// a floored leg missing from `results` is itself a violation.
pub fn check_floor(
    results: &[LegResult],
    floors: &[(String, f64)],
    max_regression: f64,
) -> Vec<String> {
    let mut viols = Vec::new();
    for (leg, floor) in floors {
        match results.iter().find(|r| &r.leg == leg) {
            Some(r) if r.munits_per_s < floor * (1.0 - max_regression) => viols.push(format!(
                "{leg}: {:.1} Munits/s is >{:.0}% below the floor {floor:.1}",
                r.munits_per_s,
                max_regression * 100.0
            )),
            Some(_) => {}
            None => viols.push(format!("{leg}: floored leg missing from the run")),
        }
    }
    viols
}

/// Acceptance gate: on every low-occupancy shape (≤ `max_occupancy`),
/// the pruned leg must beat its unpruned twin by ≥ `min_ratio`.
pub fn check_prune_ratio(
    results: &[LegResult],
    min_ratio: f64,
    max_occupancy: f64,
) -> Vec<String> {
    let mut viols = Vec::new();
    for p in results.iter().filter(|r| r.pruned && r.occupancy <= max_occupancy) {
        let twin = results
            .iter()
            .find(|r| !r.pruned && r.kernel == p.kernel && r.occupancy == p.occupancy);
        match twin {
            Some(u) if p.munits_per_s < min_ratio * u.munits_per_s => viols.push(format!(
                "{}: {:.1} Munits/s < {min_ratio}x unpruned {:.1}",
                p.leg, p.munits_per_s, u.munits_per_s
            )),
            _ => {}
        }
    }
    viols
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(kernel: KernelMode, pruned: bool, occupancy: f64) -> LegSpec {
        LegSpec {
            kernel,
            pruned,
            occupancy,
            n: 96,
            deg: 4,
        }
    }

    #[test]
    fn default_legs_are_distinct_and_cover_the_acceptance_point() {
        let legs = default_legs();
        let names: std::collections::BTreeSet<String> = legs.iter().map(|l| l.name()).collect();
        assert_eq!(names.len(), legs.len(), "leg names must be unique");
        // the acceptance criterion's shape: pruned at occupancy ≤ 0.2,
        // with its unpruned twin present, for both kernels
        for kernel in ["scalar", "simd"] {
            assert!(names.contains(&format!("combine/{kernel}/pruned/occ0.20")));
            assert!(names.contains(&format!("combine/{kernel}/unpruned/occ0.20")));
        }
    }

    #[test]
    fn pruned_leg_skips_work_only_at_low_occupancy() {
        let r = run_leg(&tiny(KernelMode::Scalar, true, 0.2), 1, 1);
        assert!(r.pairs_skipped > 0, "dead active rows must prune pairs");
        assert!(r.rows_skipped > 0, "dead passive rows must skip contractions");
        assert!(r.munits_per_s > 0.0);
        let full = run_leg(&tiny(KernelMode::Scalar, true, 1.0), 1, 1);
        assert_eq!(full.pairs_skipped, 0);
        assert_eq!(full.rows_skipped, 0);
        let off = run_leg(&tiny(KernelMode::Simd, false, 0.2), 1, 1);
        assert_eq!(off.pairs_skipped, 0);
        assert_eq!(off.rows_skipped, 0);
    }

    #[test]
    fn json_carries_every_leg() {
        let results = [
            run_leg(&tiny(KernelMode::Scalar, false, 1.0), 1, 1),
            run_leg(&tiny(KernelMode::Scalar, true, 0.05), 1, 1),
        ];
        let json = results_json(&results);
        for r in &results {
            assert!(json.contains(&r.leg), "missing {}", r.leg);
        }
        assert!(json.contains("\"pairs_skipped\""));
        assert!(json.contains("\"issue\": 10"));
        // exactly one trailing comma structure: last entry unterminated
        assert!(!json.contains("}},\n  ]"));
    }

    fn fake(leg: &str, kernel: &'static str, pruned: bool, occ: f64, rate: f64) -> LegResult {
        LegResult {
            leg: leg.to_string(),
            kernel,
            pruned,
            occupancy: occ,
            munits_per_s: rate,
            pairs_skipped: 0,
            rows_skipped: 0,
        }
    }

    #[test]
    fn floor_parse_and_regression_check() {
        let floors = parse_floor("# comment\n\ncombine/a 100\ncombine/b 40.5\n");
        assert_eq!(floors.len(), 2);
        assert_eq!(floors[1], ("combine/b".to_string(), 40.5));
        let results = [
            fake("combine/a", "scalar", false, 1.0, 80.0), // 20% down: within 25%
            fake("combine/b", "scalar", false, 1.0, 20.0), // >25% down: fails
        ];
        let v = check_floor(&results, &floors, 0.25);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].starts_with("combine/b"), "{v:?}");
        // a floored leg that never ran is a failure, not a silent pass
        let v = check_floor(&results[..1], &floors, 0.25);
        assert!(v.iter().any(|m| m.contains("missing")), "{v:?}");
    }

    #[test]
    fn prune_ratio_check_pairs_twins() {
        let results = [
            fake("u", "scalar", false, 0.2, 100.0),
            fake("p", "scalar", true, 0.2, 300.0), // 3x: fine
            fake("u2", "simd", false, 0.1, 100.0),
            fake("p2", "simd", true, 0.1, 120.0), // 1.2x: violation
            fake("p3", "simd", true, 1.0, 1.0),   // high occupancy: exempt
        ];
        let v = check_prune_ratio(&results, 1.5, 0.2);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].starts_with("p2"), "{v:?}");
    }
}
