//! Reporting: markdown/CSV series emitters used by the figure harness to
//! print the same rows the paper's tables and figures report, plus simple
//! wall-clock timers and the perf-trajectory legs (`legs`).

pub mod legs;

use std::fmt::Write as _;
use std::time::Instant;

/// A labelled series table: rows × columns of f64, rendered as markdown
/// (for EXPERIMENTS.md) or CSV (for plotting).
#[derive(Debug, Clone)]
pub struct Series {
    pub title: String,
    pub col_names: Vec<String>,
    pub row_names: Vec<String>,
    pub cells: Vec<Vec<f64>>,
    /// printf-style precision per table
    pub precision: usize,
}

impl Series {
    pub fn new(title: &str, cols: &[&str]) -> Self {
        Series {
            title: title.to_string(),
            col_names: cols.iter().map(|s| s.to_string()).collect(),
            row_names: Vec::new(),
            cells: Vec::new(),
            precision: 3,
        }
    }

    pub fn push_row(&mut self, name: &str, vals: Vec<f64>) {
        assert_eq!(vals.len(), self.col_names.len(), "row width mismatch");
        self.row_names.push(name.to_string());
        self.cells.push(vals);
    }

    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "### {}\n", self.title);
        let _ = write!(s, "| |");
        for c in &self.col_names {
            let _ = write!(s, " {c} |");
        }
        let _ = writeln!(s);
        let _ = write!(s, "|---|");
        for _ in &self.col_names {
            let _ = write!(s, "---|");
        }
        let _ = writeln!(s);
        for (r, row) in self.row_names.iter().zip(&self.cells) {
            let _ = write!(s, "| {r} |");
            for v in row {
                let _ = write!(s, " {v:.prec$} |", prec = self.precision);
            }
            let _ = writeln!(s);
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let _ = write!(s, "row");
        for c in &self.col_names {
            let _ = write!(s, ",{c}");
        }
        let _ = writeln!(s);
        for (r, row) in self.row_names.iter().zip(&self.cells) {
            let _ = write!(s, "{r}");
            for v in row {
                let _ = write!(s, ",{v}");
            }
            let _ = writeln!(s);
        }
        s
    }
}

/// Scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Write a results artifact under `results/`, creating the directory.
pub fn write_result(name: &str, content: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_render() {
        let mut s = Series::new("Fig X", &["4 nodes", "8 nodes"]);
        s.push_row("naive", vec![1.0, 2.5]);
        s.push_row("pipeline", vec![0.5, 0.75]);
        let md = s.to_markdown();
        assert!(md.contains("### Fig X"));
        assert!(md.contains("| naive | 1.000 | 2.500 |"));
    }

    #[test]
    fn csv_render() {
        let mut s = Series::new("t", &["a"]);
        s.push_row("r1", vec![0.25]);
        assert_eq!(s.to_csv(), "row,a\nr1,0.25\n");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_checked() {
        let mut s = Series::new("t", &["a", "b"]);
        s.push_row("r", vec![1.0]);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.secs() >= 0.002);
    }
}

/// Micro-bench helper (the vendored crate set has no criterion): run `f`
/// until `min_time` elapses (warmup included), report median/min per-op.
pub fn bench<R>(name: &str, mut f: impl FnMut() -> R) -> f64 {
    // warmup
    for _ in 0..3 {
        std::hint::black_box(f());
    }
    let mut samples = Vec::new();
    let budget = std::time::Duration::from_millis(
        std::env::var("HARPSG_BENCH_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(400),
    );
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 5 {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let min = samples[0];
    println!(
        "bench {name:<44} median {:>12} min {:>12} ({} runs)",
        crate::util::human_secs(median),
        crate::util::human_secs(min),
        samples.len()
    );
    median
}
