//! R-MAT graph generator (Chakrabarti, Zhan, Faloutsos 2004) with a skew
//! knob, reproducing the paper's PaRMAT-generated datasets
//! (R250K1/K3/K8, R500K3) and the scaled-down analogs of the real graphs.
//!
//! The recursive-matrix model drops each edge into one of four quadrants
//! with probabilities (a, b, c, d); higher `a` concentrates edges on
//! low-id vertices and produces a heavier-tailed degree distribution. The
//! paper parameterizes datasets by a "skewness" level k ∈ {1, 3, 8}; we map
//! skew levels to `a` as below and verify the resulting max/avg degree
//! ratios ordering in tests (exact PaRMAT parameters are not published in
//! the chapter — documented substitution, DESIGN.md §1).

use super::csr::{Graph, GraphBuilder};
use crate::util::Rng;

#[derive(Debug, Clone, Copy)]
pub struct RmatParams {
    pub n_vertices: usize,
    pub n_edges: u64,
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub seed: u64,
}

impl RmatParams {
    /// Map the paper's skew level to R-MAT quadrant probabilities.
    /// skew 1 ≈ near-uniform (Erdős–Rényi-like), 3 ≈ social-network-like,
    /// 8 ≈ extremely skewed (power-law with giant hubs).
    pub fn with_skew(n_vertices: usize, n_edges: u64, skew: u32, seed: u64) -> Self {
        let (a, b, c) = match skew {
            0 | 1 => (0.30, 0.25, 0.25),
            2 => (0.45, 0.22, 0.22),
            3 => (0.55, 0.19, 0.19),
            4..=6 => (0.62, 0.17, 0.17),
            _ => (0.70, 0.14, 0.14),
        };
        RmatParams {
            n_vertices,
            n_edges,
            a,
            b,
            c,
            seed,
        }
    }
}

/// Generate an undirected R-MAT graph. Duplicate edges and self loops are
/// dropped by the CSR builder, so the final edge count is slightly below
/// `n_edges` for very skewed settings (as with real PaRMAT output).
pub fn generate(p: &RmatParams) -> Graph {
    let levels = (p.n_vertices as f64).log2().ceil() as u32;
    let n = 1usize << levels;
    let mut rng = Rng::stream(p.seed, RMAT_STREAM);
    let mut b = GraphBuilder::new(p.n_vertices.max(1));
    let ab = p.a + p.b;
    let abc = p.a + p.b + p.c;
    for _ in 0..p.n_edges {
        let (mut x0, mut x1) = (0usize, n);
        let (mut y0, mut y1) = (0usize, n);
        for _ in 0..levels {
            let r = rng.f64();
            let (mx, my) = (x0 + (x1 - x0) / 2, y0 + (y1 - y0) / 2);
            if r < p.a {
                x1 = mx;
                y1 = my;
            } else if r < ab {
                x1 = mx;
                y0 = my;
            } else if r < abc {
                x0 = mx;
                y1 = my;
            } else {
                x0 = mx;
                y0 = my;
            }
        }
        // fold into the requested vertex range
        let u = (x0 % p.n_vertices) as u32;
        let v = (y0 % p.n_vertices) as u32;
        b.add_edge(u, v);
    }
    b.build()
}

/// RNG stream tag for the generator ("RMAT").
const RMAT_STREAM: u64 = 0x524d_4154;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::degree_stats;

    #[test]
    fn deterministic_for_seed() {
        let p = RmatParams::with_skew(1 << 10, 8_000, 3, 42);
        let g1 = generate(&p);
        let g2 = generate(&p);
        assert_eq!(g1.adj, g2.adj);
        assert_eq!(g1.offsets, g2.offsets);
    }

    #[test]
    fn seed_changes_graph() {
        let p1 = RmatParams::with_skew(1 << 10, 8_000, 3, 42);
        let p2 = RmatParams::with_skew(1 << 10, 8_000, 3, 43);
        assert_ne!(generate(&p1).adj, generate(&p2).adj);
    }

    #[test]
    fn skew_orders_max_degree() {
        let n = 1 << 12;
        let m = 40_000;
        let s1 = degree_stats(&generate(&RmatParams::with_skew(n, m, 1, 7)));
        let s3 = degree_stats(&generate(&RmatParams::with_skew(n, m, 3, 7)));
        let s8 = degree_stats(&generate(&RmatParams::with_skew(n, m, 8, 7)));
        assert!(
            s1.max_degree < s3.max_degree && s3.max_degree < s8.max_degree,
            "skew must increase hubs: {} {} {}",
            s1.max_degree,
            s3.max_degree,
            s8.max_degree
        );
    }

    #[test]
    fn edge_count_near_target() {
        let p = RmatParams::with_skew(1 << 12, 20_000, 1, 5);
        let g = generate(&p);
        // low skew -> few duplicates
        assert!(g.n_edges > 18_000, "n_edges={}", g.n_edges);
        assert!(g.n_edges <= 20_000);
    }
}
