//! Vertex partitioning of the input graph across P simulated ranks, plus
//! the per-pair *request lists* that determine exactly which count rows
//! must travel between ranks during the combine exchange (Alg 2 line 15 /
//! Alg 3). Random (hashed) vertex partitioning matches the paper's
//! assumption in the Eq 5 complexity analysis.

use super::csr::Graph;
use super::loader::{self, GraphLoadError};
use super::shard::{self, SegmentedGraph};
use crate::util::mix2;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// A partitioning of `0..n_vertices` across `n_ranks` ranks.
#[derive(Debug, Clone)]
pub struct Partition {
    pub n_ranks: usize,
    /// vertex -> owning rank
    pub owner: Vec<u16>,
    /// rank -> its vertices (global ids, ascending)
    pub locals: Vec<Vec<u32>>,
    /// vertex -> index within its owner's `locals` list
    pub local_index: Vec<u32>,
}

impl Partition {
    /// Deterministic pseudo-random partition: owner(v) = hash(seed, v) % P.
    /// Matches the paper's "randomly partitioned" assumption while staying
    /// reproducible across runs and rank counts.
    pub fn random(n_vertices: usize, n_ranks: usize, seed: u64) -> Self {
        assert!(n_ranks >= 1 && n_ranks <= u16::MAX as usize);
        let mut owner = vec![0u16; n_vertices];
        let mut locals = vec![Vec::new(); n_ranks];
        let mut local_index = vec![0u32; n_vertices];
        for v in 0..n_vertices {
            let p = (mix2(seed, v as u64) % n_ranks as u64) as u16;
            owner[v] = p;
            local_index[v] = locals[p as usize].len() as u32;
            locals[p as usize].push(v as u32);
        }
        Partition {
            n_ranks,
            owner,
            locals,
            local_index,
        }
    }

    /// Contiguous block partition (used by tests and as an ablation).
    ///
    /// Blocks are balanced: the first `n_vertices % n_ranks` ranks get one
    /// extra vertex. The previous ceil-chunk math starved trailing ranks
    /// whenever `n_ranks` didn't divide `n_vertices` (and emptied *every*
    /// rank past index `n_vertices` when `n_ranks > n_vertices`); balanced
    /// blocks leave no rank empty as long as `n_vertices >= n_ranks`.
    pub fn block(n_vertices: usize, n_ranks: usize) -> Self {
        assert!(n_ranks >= 1 && n_ranks <= u16::MAX as usize);
        let mut owner = vec![0u16; n_vertices];
        let mut locals = vec![Vec::new(); n_ranks];
        let mut local_index = vec![0u32; n_vertices];
        let base = n_vertices / n_ranks;
        let extra = n_vertices % n_ranks;
        let mut v = 0usize;
        for p in 0..n_ranks {
            let len = base + usize::from(p < extra);
            for _ in 0..len {
                owner[v] = p as u16;
                local_index[v] = locals[p].len() as u32;
                locals[p].push(v as u32);
                v += 1;
            }
        }
        debug_assert_eq!(v, n_vertices);
        Partition {
            n_ranks,
            owner,
            locals,
            local_index,
        }
    }

    #[inline]
    pub fn owner_of(&self, v: u32) -> usize {
        self.owner[v as usize] as usize
    }

    #[inline]
    pub fn n_local(&self, rank: usize) -> usize {
        self.locals[rank].len()
    }

    /// Storage-sharding step: rewrite a resident CSR into per-rank
    /// segment files under `dir` (shared header + one segment per rank,
    /// see [`crate::graph::shard`] for the format). Each rank's segment
    /// holds exactly its vertices' adjacency rows in `locals` order, so
    /// an out-of-core run keeps only the partition-proportional slice
    /// resident. The returned [`SegmentedGraph`] has already re-validated
    /// the header it wrote.
    pub fn shard_storage(&self, g: &Graph, dir: &Path) -> Result<SegmentedGraph, GraphLoadError> {
        assert_eq!(g.n_vertices(), self.owner.len(), "partition/graph mismatch");
        shard::write_segments(g, self, dir)?;
        SegmentedGraph::open(dir)
    }
}

/// Rewrite a `HARPSG01` binary into per-rank segment files under `dir`
/// **without materializing the adjacency**: the offsets section is read
/// first (with the same strict header validation as
/// [`loader::load_binary`]), per-rank segment headers and local offsets
/// are derived from it, and the adjacency section is then streamed once,
/// routing each vertex's row to its owner's segment writer. Peak memory
/// is the offsets array plus one buffered writer per rank — the path a
/// multi-billion-edge ingest takes. `partition_for` receives the vertex
/// count and returns the partition to cut against (e.g.
/// `|n| Partition::random(n, ranks, seed)`).
pub fn shard_binary(
    src: &Path,
    dir: &Path,
    partition_for: impl FnOnce(usize) -> Partition,
) -> Result<SegmentedGraph, GraphLoadError> {
    let src_err = |e: std::io::Error| loader::io_error(src, e);
    let f = std::fs::File::open(src).map_err(src_err)?;
    let file_len = f.metadata().map_err(src_err)?.len();
    let mut r = BufReader::new(f);
    let (n, n_edges, offsets) = loader::read_csr_header(&mut r, file_len, src)?;
    let part = partition_for(n);
    assert_eq!(part.owner.len(), n, "partition_for returned wrong size");

    std::fs::create_dir_all(dir).map_err(|e| loader::io_error(dir, e))?;
    let mut segs = Vec::with_capacity(part.n_ranks);
    let mut writers = Vec::with_capacity(part.n_ranks);
    for p in 0..part.n_ranks {
        let sp = dir.join(shard::segment_file_name(p));
        let io_err = |e: std::io::Error| loader::io_error(&sp, e);
        let adj_len: u64 = part.locals[p]
            .iter()
            .map(|&v| offsets[v as usize + 1] - offsets[v as usize])
            .sum();
        let fp = std::fs::File::create(&sp).map_err(io_err)?;
        let mut w = BufWriter::new(fp);
        w.write_all(shard::SEG_MAGIC).map_err(io_err)?;
        w.write_all(&(p as u64).to_le_bytes()).map_err(io_err)?;
        w.write_all(&(part.locals[p].len() as u64).to_le_bytes())
            .map_err(io_err)?;
        w.write_all(&adj_len.to_le_bytes()).map_err(io_err)?;
        let mut off = 0u64;
        w.write_all(&off.to_le_bytes()).map_err(io_err)?;
        for &v in &part.locals[p] {
            off += offsets[v as usize + 1] - offsets[v as usize];
            w.write_all(&off.to_le_bytes()).map_err(io_err)?;
        }
        segs.push(shard::SegMeta {
            n_local: part.locals[p].len() as u64,
            adj_len,
        });
        writers.push((w, sp));
    }

    // single streaming pass over the adjacency, validated row by row
    // exactly as load_binary would (range, sortedness, loops, dups)
    let mut u32buf = [0u8; 4];
    for v in 0..n {
        let deg = (offsets[v + 1] - offsets[v]) as usize;
        let (w, sp) = &mut writers[part.owner_of(v as u32)];
        let mut prev: Option<u32> = None;
        for j in 0..deg {
            r.read_exact(&mut u32buf).map_err(src_err)?;
            let u = u32::from_le_bytes(u32buf);
            if u as usize >= n {
                return Err(GraphLoadError::AdjOutOfRange {
                    index: offsets[v] as usize + j,
                    value: u,
                    n_vertices: n,
                });
            }
            if u == v as u32 {
                return Err(GraphLoadError::SelfLoop { vertex: v as u32 });
            }
            match prev {
                Some(pn) if u == pn => {
                    return Err(GraphLoadError::DuplicateNeighbor {
                        vertex: v as u32,
                        value: u,
                    })
                }
                Some(pn) if u < pn => {
                    return Err(GraphLoadError::UnsortedNeighbors { vertex: v as u32 })
                }
                _ => {}
            }
            prev = Some(u);
            w.write_all(&u32buf).map_err(|e| loader::io_error(sp, e))?;
        }
    }
    for (w, sp) in &mut writers {
        w.flush().map_err(|e| loader::io_error(sp, e))?;
    }
    shard::write_header(dir, n as u64, n_edges, shard::partition_tag(&part), &segs)?;
    SegmentedGraph::open(dir)
}

/// For every ordered rank pair, which remote vertices does `p` need?
/// `needs[p][q]` = sorted global ids owned by `q` that appear in the
/// neighbor list of at least one vertex owned by `p` (q != p).
///
/// These are exactly the count rows that `q` must ship to `p` when a
/// subtemplate combine runs — the paper's `C_{x,y}(v, Ti, Si)` sets.
#[derive(Debug, Clone)]
pub struct RequestLists {
    pub needs: Vec<Vec<Vec<u32>>>,
}

impl RequestLists {
    pub fn build(g: &Graph, part: &Partition) -> Self {
        let p_count = part.n_ranks;
        let mut needs: Vec<Vec<Vec<u32>>> = vec![vec![Vec::new(); p_count]; p_count];
        // mark remote neighbors per (p, q)
        let mut seen: Vec<u64> = Vec::new();
        for p in 0..p_count {
            seen.clear();
            for &v in &part.locals[p] {
                for &u in g.neighbors(v) {
                    let q = part.owner_of(u);
                    if q != p {
                        seen.push(((q as u64) << 32) | u as u64);
                    }
                }
            }
            seen.sort_unstable();
            seen.dedup();
            for &key in &seen {
                let q = (key >> 32) as usize;
                needs[p][q].push(key as u32);
            }
        }
        RequestLists { needs }
    }

    /// Total remote rows rank `p` receives (the Σ_u in Eq 5).
    pub fn total_in(&self, p: usize) -> usize {
        self.needs[p].iter().map(|v| v.len()).sum()
    }

    /// Rows rank `q` must send to rank `p`.
    pub fn rows(&self, p: usize, q: usize) -> &[u32] {
        &self.needs[p][q]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::graph_from_edges;
    use crate::graph::rmat::{generate, RmatParams};
    use crate::util::prop;

    #[test]
    fn random_partition_covers_all() {
        let part = Partition::random(1000, 7, 42);
        let total: usize = part.locals.iter().map(|l| l.len()).sum();
        assert_eq!(total, 1000);
        for (v, &o) in part.owner.iter().enumerate() {
            let li = part.local_index[v] as usize;
            assert_eq!(part.locals[o as usize][li], v as u32);
        }
    }

    #[test]
    fn random_partition_roughly_balanced() {
        let part = Partition::random(10_000, 8, 1);
        for l in &part.locals {
            let frac = l.len() as f64 / 10_000.0;
            assert!((frac - 0.125).abs() < 0.03, "rank holds {frac}");
        }
    }

    /// Satellite: the old ceil-chunk block math starved trailing ranks
    /// whenever P∤n (n=6, P=4 gave sizes [2,2,2,0]) and emptied all but
    /// the first n ranks when P>n with bogus bookkeeping. Balanced blocks
    /// must cover every vertex, stay contiguous, keep sizes within one of
    /// each other, and keep `local_index` consistent — including n=0,
    /// P>n, and every remainder class.
    #[test]
    fn block_partition_balanced_covering_consistent() {
        for n in [0usize, 1, 2, 3, 4, 5, 6, 7, 10, 16, 33] {
            for p_count in 1..=8usize {
                let part = Partition::block(n, p_count);
                assert_eq!(part.n_ranks, p_count);
                let total: usize = part.locals.iter().map(|l| l.len()).sum();
                assert_eq!(total, n, "n={n} P={p_count} loses vertices");
                let sizes: Vec<usize> = part.locals.iter().map(|l| l.len()).collect();
                let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "n={n} P={p_count} sizes {sizes:?}");
                if n >= p_count {
                    assert!(*min >= 1, "n={n} P={p_count} starves a rank: {sizes:?}");
                }
                // contiguous: owners are non-decreasing across vertex ids
                for v in 1..n {
                    assert!(part.owner[v] >= part.owner[v - 1]);
                }
                // local_index round-trips through the owner's locals list
                for v in 0..n {
                    let o = part.owner[v] as usize;
                    assert_eq!(part.locals[o][part.local_index[v] as usize], v as u32);
                }
            }
        }
    }

    /// P > n regression in the style of the P=2/P=3 adaptive regressions:
    /// the surplus ranks are exactly the empty ones, and request lists
    /// still build cleanly over them.
    #[test]
    fn block_partition_more_ranks_than_vertices() {
        let g = graph_from_edges(3, &[(0, 1), (1, 2)]);
        let part = Partition::block(3, 5);
        for p in 0..3 {
            assert_eq!(part.locals[p], vec![p as u32]);
        }
        for p in 3..5 {
            assert!(part.locals[p].is_empty());
            assert_eq!(part.n_local(p), 0);
        }
        let req = RequestLists::build(&g, &part);
        assert_eq!(req.rows(0, 1), &[1]);
        assert_eq!(req.rows(1, 0), &[0]);
        assert_eq!(req.rows(1, 2), &[2]);
        for p in 3..5 {
            assert_eq!(req.total_in(p), 0);
        }
    }

    #[test]
    fn request_lists_path_graph() {
        // path 0-1-2-3, ranks: block partition {0,1} {2,3}
        let g = graph_from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let part = Partition::block(4, 2);
        let req = RequestLists::build(&g, &part);
        assert_eq!(req.rows(0, 1), &[2]); // rank0's vertex 1 needs vertex 2
        assert_eq!(req.rows(1, 0), &[1]); // rank1's vertex 2 needs vertex 1
        assert_eq!(req.total_in(0), 1);
    }

    /// The streaming HARPSG01 rewrite must produce byte-identical segment
    /// files to the in-memory sharding step.
    #[test]
    fn shard_binary_matches_in_memory_sharding() {
        let g = generate(&RmatParams::with_skew(120, 400, 3, 5));
        let base = std::env::temp_dir().join(format!("harpsg-shardbin-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let src = base.join("g.bin");
        std::fs::create_dir_all(&base).unwrap();
        crate::graph::loader::save_binary(&g, &src).unwrap();
        let part = Partition::random(g.n_vertices(), 3, 7);
        let mem_dir = base.join("mem");
        let stream_dir = base.join("stream");
        let seg_mem = part.shard_storage(&g, &mem_dir).unwrap();
        let seg_stream = shard_binary(&src, &stream_dir, |n| {
            assert_eq!(n, g.n_vertices());
            part.clone()
        })
        .unwrap();
        assert_eq!(seg_mem.segs, seg_stream.segs);
        for p in 0..3 {
            let a = std::fs::read(mem_dir.join(shard::segment_file_name(p))).unwrap();
            let b = std::fs::read(stream_dir.join(shard::segment_file_name(p))).unwrap();
            assert_eq!(a, b, "segment {p} differs");
        }
        let ha = std::fs::read(mem_dir.join(shard::SHARD_HEADER_FILE)).unwrap();
        let hb = std::fs::read(stream_dir.join(shard::SHARD_HEADER_FILE)).unwrap();
        assert_eq!(ha, hb);
        // and the streamed shards re-load to the resident rows
        for p in 0..3 {
            let c = seg_stream.load_rank(p, &part.locals[p]).unwrap();
            for (r, &v) in part.locals[p].iter().enumerate() {
                assert_eq!(c.neighbors(r), g.neighbors(v));
            }
        }
        drop((seg_mem, seg_stream));
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn prop_request_lists_sound_and_complete() {
        prop::check("request_lists", |g| {
            let n = g.usize_in(8, 200);
            let m = g.usize_in(n, 4 * n) as u64;
            let ranks = g.usize_in(2, 6);
            let graph = generate(&RmatParams::with_skew(n, m, 3, g.case_seed));
            let part = Partition::random(graph.n_vertices(), ranks, 7);
            let req = RequestLists::build(&graph, &part);
            // completeness: every remote neighbor of every vertex is listed
            for p in 0..ranks {
                for &v in &part.locals[p] {
                    for &u in graph.neighbors(v) {
                        let q = part.owner_of(u);
                        if q != p && req.rows(p, q).binary_search(&u).is_err() {
                            return Err(format!("missing {u} in needs[{p}][{q}]"));
                        }
                    }
                }
            }
            // soundness: every listed vertex is owned by q and adjacent to p
            for p in 0..ranks {
                for q in 0..ranks {
                    for &u in req.rows(p, q) {
                        if part.owner_of(u) != q {
                            return Err(format!("{u} not owned by {q}"));
                        }
                        let touches_p = graph
                            .neighbors(u)
                            .iter()
                            .any(|&w| part.owner_of(w) == p);
                        if !touches_p {
                            return Err(format!("{u} not adjacent to rank {p}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
