//! Vertex partitioning of the input graph across P simulated ranks, plus
//! the per-pair *request lists* that determine exactly which count rows
//! must travel between ranks during the combine exchange (Alg 2 line 15 /
//! Alg 3). Random (hashed) vertex partitioning matches the paper's
//! assumption in the Eq 5 complexity analysis.

use super::csr::Graph;
use crate::util::mix2;

/// A partitioning of `0..n_vertices` across `n_ranks` ranks.
#[derive(Debug, Clone)]
pub struct Partition {
    pub n_ranks: usize,
    /// vertex -> owning rank
    pub owner: Vec<u16>,
    /// rank -> its vertices (global ids, ascending)
    pub locals: Vec<Vec<u32>>,
    /// vertex -> index within its owner's `locals` list
    pub local_index: Vec<u32>,
}

impl Partition {
    /// Deterministic pseudo-random partition: owner(v) = hash(seed, v) % P.
    /// Matches the paper's "randomly partitioned" assumption while staying
    /// reproducible across runs and rank counts.
    pub fn random(n_vertices: usize, n_ranks: usize, seed: u64) -> Self {
        assert!(n_ranks >= 1 && n_ranks <= u16::MAX as usize);
        let mut owner = vec![0u16; n_vertices];
        let mut locals = vec![Vec::new(); n_ranks];
        let mut local_index = vec![0u32; n_vertices];
        for v in 0..n_vertices {
            let p = (mix2(seed, v as u64) % n_ranks as u64) as u16;
            owner[v] = p;
            local_index[v] = locals[p as usize].len() as u32;
            locals[p as usize].push(v as u32);
        }
        Partition {
            n_ranks,
            owner,
            locals,
            local_index,
        }
    }

    /// Contiguous block partition (used by tests and as an ablation).
    pub fn block(n_vertices: usize, n_ranks: usize) -> Self {
        assert!(n_ranks >= 1 && n_ranks <= u16::MAX as usize);
        let mut owner = vec![0u16; n_vertices];
        let mut locals = vec![Vec::new(); n_ranks];
        let mut local_index = vec![0u32; n_vertices];
        let chunk = n_vertices.div_ceil(n_ranks.max(1)).max(1);
        for v in 0..n_vertices {
            let p = (v / chunk).min(n_ranks - 1) as u16;
            owner[v] = p;
            local_index[v] = locals[p as usize].len() as u32;
            locals[p as usize].push(v as u32);
        }
        Partition {
            n_ranks,
            owner,
            locals,
            local_index,
        }
    }

    #[inline]
    pub fn owner_of(&self, v: u32) -> usize {
        self.owner[v as usize] as usize
    }

    #[inline]
    pub fn n_local(&self, rank: usize) -> usize {
        self.locals[rank].len()
    }
}

/// For every ordered rank pair, which remote vertices does `p` need?
/// `needs[p][q]` = sorted global ids owned by `q` that appear in the
/// neighbor list of at least one vertex owned by `p` (q != p).
///
/// These are exactly the count rows that `q` must ship to `p` when a
/// subtemplate combine runs — the paper's `C_{x,y}(v, Ti, Si)` sets.
#[derive(Debug, Clone)]
pub struct RequestLists {
    pub needs: Vec<Vec<Vec<u32>>>,
}

impl RequestLists {
    pub fn build(g: &Graph, part: &Partition) -> Self {
        let p_count = part.n_ranks;
        let mut needs: Vec<Vec<Vec<u32>>> = vec![vec![Vec::new(); p_count]; p_count];
        // mark remote neighbors per (p, q)
        let mut seen: Vec<u64> = Vec::new();
        for p in 0..p_count {
            seen.clear();
            for &v in &part.locals[p] {
                for &u in g.neighbors(v) {
                    let q = part.owner_of(u);
                    if q != p {
                        seen.push(((q as u64) << 32) | u as u64);
                    }
                }
            }
            seen.sort_unstable();
            seen.dedup();
            for &key in &seen {
                let q = (key >> 32) as usize;
                needs[p][q].push(key as u32);
            }
        }
        RequestLists { needs }
    }

    /// Total remote rows rank `p` receives (the Σ_u in Eq 5).
    pub fn total_in(&self, p: usize) -> usize {
        self.needs[p].iter().map(|v| v.len()).sum()
    }

    /// Rows rank `q` must send to rank `p`.
    pub fn rows(&self, p: usize, q: usize) -> &[u32] {
        &self.needs[p][q]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::graph_from_edges;
    use crate::graph::rmat::{generate, RmatParams};
    use crate::util::prop;

    #[test]
    fn random_partition_covers_all() {
        let part = Partition::random(1000, 7, 42);
        let total: usize = part.locals.iter().map(|l| l.len()).sum();
        assert_eq!(total, 1000);
        for (v, &o) in part.owner.iter().enumerate() {
            let li = part.local_index[v] as usize;
            assert_eq!(part.locals[o as usize][li], v as u32);
        }
    }

    #[test]
    fn random_partition_roughly_balanced() {
        let part = Partition::random(10_000, 8, 1);
        for l in &part.locals {
            let frac = l.len() as f64 / 10_000.0;
            assert!((frac - 0.125).abs() < 0.03, "rank holds {frac}");
        }
    }

    #[test]
    fn request_lists_path_graph() {
        // path 0-1-2-3, ranks: block partition {0,1} {2,3}
        let g = graph_from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let part = Partition::block(4, 2);
        let req = RequestLists::build(&g, &part);
        assert_eq!(req.rows(0, 1), &[2]); // rank0's vertex 1 needs vertex 2
        assert_eq!(req.rows(1, 0), &[1]); // rank1's vertex 2 needs vertex 1
        assert_eq!(req.total_in(0), 1);
    }

    #[test]
    fn prop_request_lists_sound_and_complete() {
        prop::check("request_lists", |g| {
            let n = g.usize_in(8, 200);
            let m = g.usize_in(n, 4 * n) as u64;
            let ranks = g.usize_in(2, 6);
            let graph = generate(&RmatParams::with_skew(n, m, 3, g.case_seed));
            let part = Partition::random(graph.n_vertices(), ranks, 7);
            let req = RequestLists::build(&graph, &part);
            // completeness: every remote neighbor of every vertex is listed
            for p in 0..ranks {
                for &v in &part.locals[p] {
                    for &u in graph.neighbors(v) {
                        let q = part.owner_of(u);
                        if q != p && req.rows(p, q).binary_search(&u).is_err() {
                            return Err(format!("missing {u} in needs[{p}][{q}]"));
                        }
                    }
                }
            }
            // soundness: every listed vertex is owned by q and adjacent to p
            for p in 0..ranks {
                for q in 0..ranks {
                    for &u in req.rows(p, q) {
                        if part.owner_of(u) != q {
                            return Err(format!("{u} not owned by {q}"));
                        }
                        let touches_p = graph
                            .neighbors(u)
                            .iter()
                            .any(|&w| part.owner_of(w) == p);
                        if !touches_p {
                            return Err(format!("{u} not adjacent to rank {p}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
