//! Graph substrate: CSR storage, loaders, the R-MAT generator used to
//! reproduce the paper's datasets (Table 2), degree statistics, and the
//! rank partitioning + request lists that drive the distributed exchange.

pub mod csr;
pub mod loader;
pub mod partition;
pub mod rmat;
pub mod shard;
pub mod stats;

pub use csr::{graph_from_edges, Graph, GraphBuilder};
pub use loader::GraphLoadError;
pub use partition::{shard_binary, Partition, RequestLists};
pub use rmat::RmatParams;
pub use shard::{GraphStorageMode, GraphStore, RankView, SegmentedGraph};
pub use stats::{degree_stats, Dataset, DegreeStats, DEFAULT_SCALE};
