//! Degree statistics and the dataset catalog (Table 2 of the paper, with
//! scaled-down analogs of the real graphs — see DESIGN.md §1).

use super::csr::Graph;
use super::rmat::{generate, RmatParams};

#[derive(Debug, Clone, PartialEq)]
pub struct DegreeStats {
    pub n_vertices: usize,
    pub n_edges: u64,
    pub avg_degree: f64,
    pub max_degree: usize,
    /// max/avg degree ratio — the skewness proxy used in the figures
    pub skewness: f64,
}

pub fn degree_stats(g: &Graph) -> DegreeStats {
    let avg = g.avg_degree();
    let max = g.max_degree();
    DegreeStats {
        n_vertices: g.n_vertices(),
        n_edges: g.n_edges,
        avg_degree: avg,
        max_degree: max,
        skewness: if avg > 0.0 { max as f64 / avg } else { 0.0 },
    }
}

/// The experiment datasets. Real-application graphs from Table 2 are
/// reproduced as R-MAT analogs with matched average degree and a skew
/// level chosen to match the paper's max/avg ratio regime. Sizes are scaled
/// down ~100–1000× to fit a single-core container; the per-step cost model
/// (Eq 6) is scale-free in |E|/P², so figure *shapes* are preserved.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// Miami analog — low skew social contact network (49 avg deg)
    MiamiS,
    /// Orkut analog — moderate skew (76 avg deg)
    OrkutS,
    /// NYC analog — low skew, bounded max degree
    NycS,
    /// Twitter analog — extreme skew (3M max degree in the paper)
    TwitterS,
    /// SK-2005 analog — web crawl, extreme skew
    SkS,
    /// Friendster analog — moderate skew, biggest graph
    FriendsterS,
    /// RMAT 250M-edge analog at skew 1/3/8 (paper: 5M vertices)
    R250K1,
    R250K3,
    R250K8,
    /// RMAT 500M-edge analog at skew 3 (paper: 5M vertices)
    R500K3,
    /// Weak-scaling family: R-MAT skew 3 with per-rank-proportional size
    WeakRmat { n_vertices: usize, n_edges: u64 },
}

impl Dataset {
    pub fn abbrev(&self) -> String {
        match self {
            Dataset::MiamiS => "MI".into(),
            Dataset::OrkutS => "OR".into(),
            Dataset::NycS => "NY".into(),
            Dataset::TwitterS => "TW".into(),
            Dataset::SkS => "SK".into(),
            Dataset::FriendsterS => "FR".into(),
            Dataset::R250K1 => "R250K1".into(),
            Dataset::R250K3 => "R250K3".into(),
            Dataset::R250K8 => "R250K8".into(),
            Dataset::R500K3 => "R500K3".into(),
            Dataset::WeakRmat { n_vertices, .. } => format!("WEAK{}", n_vertices),
        }
    }

    /// Generation parameters: (n_vertices, n_edges, skew). The paper's
    /// vertex/edge counts divided by the scale factor, degree preserved.
    pub fn params(&self, scale: u32) -> RmatParams {
        let s = scale.max(1) as u64;
        let (n, m, skew, seed) = match self {
            // paper: 2.1M vertices, 51M edges, avg 49, max 9.8K (low skew)
            Dataset::MiamiS => (2_100_000 / s, 51_000_000 / s, 1, 101),
            // paper: 3M vertices, 230M edges, avg 76, max 33K (moderate)
            Dataset::OrkutS => (3_000_000 / s, 230_000_000 / s, 3, 102),
            // paper: 18M vertices, 480M edges, avg 54, max 429 (very low)
            Dataset::NycS => (18_000_000 / s, 480_000_000 / s, 0, 103),
            // paper: 44M vertices, 2B edges, avg 50, max 3M (extreme)
            Dataset::TwitterS => (44_000_000 / s, 2_000_000_000 / s, 8, 104),
            // paper: 50M vertices, 3.8B edges, avg 73, max 8M (extreme)
            Dataset::SkS => (50_000_000 / s, 3_800_000_000 / s, 8, 105),
            // paper: 66M vertices, 5B edges, avg 57, max 5214 (low-mod)
            Dataset::FriendsterS => (66_000_000 / s, 5_000_000_000 / s, 2, 106),
            // paper: 5M vertices, 250M edges
            Dataset::R250K1 => (5_000_000 / s, 250_000_000 / s, 1, 107),
            Dataset::R250K3 => (5_000_000 / s, 250_000_000 / s, 3, 108),
            Dataset::R250K8 => (5_000_000 / s, 250_000_000 / s, 8, 109),
            Dataset::R500K3 => (5_000_000 / s, 500_000_000 / s, 3, 110),
            Dataset::WeakRmat {
                n_vertices,
                n_edges,
            } => (*n_vertices as u64, *n_edges, 3, 111),
        };
        RmatParams::with_skew(n.max(64) as usize, m.max(128), skew, seed)
    }

    /// Generate the dataset at a given downscale factor.
    pub fn generate(&self, scale: u32) -> Graph {
        generate(&self.params(scale))
    }
}

/// Default downscale factor used by the figure harness: paper sizes / 500.
pub const DEFAULT_SCALE: u32 = 500;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::graph_from_edges;

    #[test]
    fn stats_of_star() {
        let g = graph_from_edges(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]);
        let s = degree_stats(&g);
        assert_eq!(s.max_degree, 4);
        assert!((s.avg_degree - 1.6).abs() < 1e-12);
        assert!((s.skewness - 2.5).abs() < 1e-12);
    }

    #[test]
    fn analog_degrees_match_paper_regime() {
        // At scale 500: Miami-S ~4.2K vertices, ~102K edges, avg deg ≈ 49
        let g = Dataset::MiamiS.generate(500);
        let s = degree_stats(&g);
        assert!(
            s.avg_degree > 25.0 && s.avg_degree < 60.0,
            "MI-S avg degree {} should approximate the paper's 49",
            s.avg_degree
        );
    }

    #[test]
    fn twitter_analog_is_skewed() {
        let tw = degree_stats(&Dataset::TwitterS.generate(2000));
        let mi = degree_stats(&Dataset::MiamiS.generate(2000));
        assert!(
            tw.skewness > 4.0 * mi.skewness,
            "TW-S skew {} must dwarf MI-S {}",
            tw.skewness,
            mi.skewness
        );
    }

    #[test]
    fn abbreviations_unique() {
        let all = [
            Dataset::MiamiS,
            Dataset::OrkutS,
            Dataset::NycS,
            Dataset::TwitterS,
            Dataset::SkS,
            Dataset::FriendsterS,
            Dataset::R250K1,
            Dataset::R250K3,
            Dataset::R250K8,
            Dataset::R500K3,
        ];
        let mut abbrevs: Vec<_> = all.iter().map(|d| d.abbrev()).collect();
        abbrevs.sort();
        abbrevs.dedup();
        assert_eq!(abbrevs.len(), all.len());
    }
}
