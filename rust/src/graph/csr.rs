//! Compressed-sparse-row graph storage.
//!
//! The input graphs are undirected (each edge stored in both adjacency
//! lists, as in FASCIA); vertex ids are dense `u32`. CSR is the only
//! runtime representation — loaders and generators all funnel through
//! [`GraphBuilder`].

/// An undirected graph in CSR form.
#[derive(Debug, Clone)]
pub struct Graph {
    /// offsets into `adj`, len = n_vertices + 1
    pub offsets: Vec<u64>,
    /// concatenated neighbor lists, len = 2 * n_edges
    pub adj: Vec<u32>,
    /// number of undirected edges
    pub n_edges: u64,
}

impl Graph {
    #[inline]
    pub fn n_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        &self.adj[lo..hi]
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n_vertices() as u32)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    pub fn avg_degree(&self) -> f64 {
        if self.n_vertices() == 0 {
            return 0.0;
        }
        self.adj.len() as f64 / self.n_vertices() as f64
    }

    /// Approximate resident bytes of the CSR arrays.
    pub fn bytes(&self) -> u64 {
        self.offsets.len() as u64 * 8 + self.adj.len() as u64 * 4
    }

    /// Edge iterator (each undirected edge once, u < v).
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.n_vertices() as u32).flat_map(move |u| {
            self.neighbors(u)
                .iter()
                .copied()
                .filter(move |&v| u < v)
                .map(move |v| (u, v))
        })
    }
}

/// Accumulates an edge list, deduplicates, drops self-loops, builds CSR.
#[derive(Debug, Default)]
pub struct GraphBuilder {
    n_vertices: usize,
    edges: Vec<(u32, u32)>,
}

impl GraphBuilder {
    pub fn new(n_vertices: usize) -> Self {
        GraphBuilder {
            n_vertices,
            edges: Vec::new(),
        }
    }

    /// Add an undirected edge; self-loops are ignored, duplicates removed
    /// at build time. Vertex ids may grow the graph.
    pub fn add_edge(&mut self, u: u32, v: u32) {
        if u == v {
            return;
        }
        let hi = u.max(v) as usize + 1;
        if hi > self.n_vertices {
            self.n_vertices = hi;
        }
        self.edges.push((u.min(v), u.max(v)));
    }

    pub fn n_pending_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn build(mut self) -> Graph {
        self.edges.sort_unstable();
        self.edges.dedup();
        let n = self.n_vertices;
        let mut deg = vec![0u64; n + 1];
        for &(u, v) in &self.edges {
            deg[u as usize + 1] += 1;
            deg[v as usize + 1] += 1;
        }
        let mut offsets = deg;
        for i in 1..=n {
            offsets[i] += offsets[i - 1];
        }
        let mut cursor = offsets.clone();
        let mut adj = vec![0u32; offsets[n] as usize];
        for &(u, v) in &self.edges {
            adj[cursor[u as usize] as usize] = v;
            cursor[u as usize] += 1;
            adj[cursor[v as usize] as usize] = u;
            cursor[v as usize] += 1;
        }
        // sort each neighbor list for deterministic traversal + bsearch
        for v in 0..n {
            let lo = offsets[v] as usize;
            let hi = offsets[v + 1] as usize;
            adj[lo..hi].sort_unstable();
        }
        let n_edges = self.edges.len() as u64;
        Graph {
            offsets,
            adj,
            n_edges,
        }
    }
}

/// Build a graph directly from an edge slice (test/convenience helper).
pub fn graph_from_edges(n: usize, edges: &[(u32, u32)]) -> Graph {
    let mut b = GraphBuilder::new(n);
    for &(u, v) in edges {
        b.add_edge(u, v);
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_csr_path_graph() {
        let g = graph_from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert_eq!(g.n_vertices(), 4);
        assert_eq!(g.n_edges, 3);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.neighbors(2), &[1, 3]);
        assert_eq!(g.degree(1), 2);
    }

    #[test]
    fn dedup_and_self_loops() {
        let g = graph_from_edges(3, &[(0, 1), (1, 0), (0, 1), (2, 2)]);
        assert_eq!(g.n_edges, 1);
        assert_eq!(g.degree(2), 0);
    }

    #[test]
    fn grows_vertex_space() {
        let g = graph_from_edges(0, &[(5, 9)]);
        assert_eq!(g.n_vertices(), 10);
        assert_eq!(g.neighbors(9), &[5]);
    }

    #[test]
    fn edge_iterator_unique() {
        let g = graph_from_edges(4, &[(0, 1), (1, 2), (2, 3), (0, 3)]);
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es.len(), 4);
        for &(u, v) in &es {
            assert!(u < v);
        }
    }

    #[test]
    fn degree_stats() {
        let g = graph_from_edges(4, &[(0, 1), (0, 2), (0, 3)]);
        assert_eq!(g.max_degree(), 3);
        assert!((g.avg_degree() - 1.5).abs() < 1e-12);
    }
}
