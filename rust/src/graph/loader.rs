//! Graph loaders: whitespace/comment-tolerant edge-list text (the format
//! SNAP datasets and FASCIA use) and a fast little-endian binary format
//! for caching generated analogs between runs.

use super::csr::{Graph, GraphBuilder};
use anyhow::{Context, Result};
use std::fmt;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Typed corruption diagnostics for the `HARPSG01` binary graph format.
/// Every structural invariant of the CSR payload is checked up front so a
/// corrupt cache file fails here with a precise reason instead of
/// panicking later inside the engine (out-of-bounds rows, bogus slices).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphLoadError {
    /// the 8-byte magic is not `HARPSG01`
    BadMagic,
    /// an I/O failure while opening or reading, annotated with the path
    Io(String),
    /// the file is shorter (or longer) than the header-declared payload
    Truncated { expected: u64, actual: u64 },
    /// a header-declared size (vertex count or adjacency total) is so
    /// large the payload length overflows u64 — no real file matches
    SizeOverflow,
    /// `offsets` must start at 0 and be non-decreasing
    NonMonotoneOffsets { index: usize },
    /// an adjacency entry names a vertex ≥ n_vertices
    AdjOutOfRange {
        index: usize,
        value: u32,
        n_vertices: usize,
    },
    /// `offsets[n]` disagrees with the header's undirected edge count
    /// (a valid CSR stores each edge in both endpoint lists)
    EdgeCountMismatch { header: u64, adjacency: u64 },
}

impl fmt::Display for GraphLoadError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GraphLoadError::BadMagic => write!(f, "not a HARPSG01 binary graph"),
            GraphLoadError::Io(m) => write!(f, "io error: {m}"),
            GraphLoadError::Truncated { expected, actual } => write!(
                f,
                "corrupt payload: expected {expected} bytes, file has {actual}"
            ),
            GraphLoadError::SizeOverflow => {
                write!(f, "corrupt header: declared sizes overflow u64")
            }
            GraphLoadError::NonMonotoneOffsets { index } => {
                write!(f, "corrupt CSR: offsets[{index}] breaks monotonicity")
            }
            GraphLoadError::AdjOutOfRange {
                index,
                value,
                n_vertices,
            } => write!(
                f,
                "corrupt CSR: adj[{index}] = {value} out of range for {n_vertices} vertices"
            ),
            GraphLoadError::EdgeCountMismatch { header, adjacency } => write!(
                f,
                "corrupt CSR: header claims {header} edges but the adjacency \
                 holds {adjacency} entries (expected 2x)"
            ),
        }
    }
}

impl std::error::Error for GraphLoadError {}

/// Load an edge-list text file: one `u v` pair per line; lines starting
/// with `#` or `%` are comments; blank lines ignored.
pub fn load_edge_list(path: &Path) -> Result<Graph> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut b = GraphBuilder::new(0);
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let u: u32 = it
            .next()
            .context("missing u")?
            .parse()
            .with_context(|| format!("line {}: bad u", lineno + 1))?;
        let v: u32 = it
            .next()
            .context("missing v")?
            .parse()
            .with_context(|| format!("line {}: bad v", lineno + 1))?;
        b.add_edge(u, v);
    }
    Ok(b.build())
}

const BIN_MAGIC: &[u8; 8] = b"HARPSG01";

/// Write the CSR arrays as `HARPSG01 | n_vertices u64 | n_edges u64 |
/// offsets[] u64 | adj[] u32`, little-endian.
pub fn save_binary(g: &Graph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.n_vertices() as u64).to_le_bytes())?;
    w.write_all(&g.n_edges.to_le_bytes())?;
    for &o in &g.offsets {
        w.write_all(&o.to_le_bytes())?;
    }
    for &a in &g.adj {
        w.write_all(&a.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Load a `HARPSG01` binary graph, validating every structural invariant
/// before the CSR is handed to the engine: magic, header-vs-file length
/// (truncation *and* trailing garbage), monotone offsets starting at 0,
/// adjacency entries < n_vertices, and the 2·n_edges adjacency total.
/// Corruption reports a typed [`GraphLoadError`] instead of a later panic.
pub fn load_binary(path: &Path) -> Result<Graph, GraphLoadError> {
    let io_err = |e: std::io::Error| GraphLoadError::Io(format!("{}: {e}", path.display()));
    let f = std::fs::File::open(path).map_err(io_err)?;
    let file_len = f.metadata().map_err(io_err)?.len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).map_err(io_err)?;
    if &magic != BIN_MAGIC {
        return Err(GraphLoadError::BadMagic);
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf).map_err(io_err)?;
    let n64 = u64::from_le_bytes(u64buf);
    r.read_exact(&mut u64buf).map_err(io_err)?;
    let n_edges = u64::from_le_bytes(u64buf);

    // validate the declared sizes against the real file length *before*
    // allocating — a corrupt header must not drive a huge allocation
    const HEADER_LEN: u64 = 8 + 8 + 8;
    let offsets_bytes = n64
        .checked_add(1)
        .and_then(|c| c.checked_mul(8))
        .ok_or(GraphLoadError::SizeOverflow)?;
    let min_len = HEADER_LEN
        .checked_add(offsets_bytes)
        .ok_or(GraphLoadError::SizeOverflow)?;
    if file_len < min_len {
        return Err(GraphLoadError::Truncated {
            expected: min_len,
            actual: file_len,
        });
    }
    let n = n64 as usize;

    let mut offsets = Vec::with_capacity(n + 1);
    for i in 0..=n {
        r.read_exact(&mut u64buf).map_err(io_err)?;
        let o = u64::from_le_bytes(u64buf);
        let floor = offsets.last().copied().unwrap_or(0);
        if (i == 0 && o != 0) || o < floor {
            return Err(GraphLoadError::NonMonotoneOffsets { index: i });
        }
        offsets.push(o);
    }
    let total = offsets[n];
    let expected_len = min_len
        .checked_add(total.checked_mul(4).ok_or(GraphLoadError::SizeOverflow)?)
        .ok_or(GraphLoadError::SizeOverflow)?;
    if file_len != expected_len {
        return Err(GraphLoadError::Truncated {
            expected: expected_len,
            actual: file_len,
        });
    }
    // each undirected edge sits in both endpoints' neighbor lists
    if n_edges.checked_mul(2) != Some(total) {
        return Err(GraphLoadError::EdgeCountMismatch {
            header: n_edges,
            adjacency: total,
        });
    }

    let total = total as usize;
    let mut adj = Vec::with_capacity(total);
    let mut u32buf = [0u8; 4];
    for i in 0..total {
        r.read_exact(&mut u32buf).map_err(io_err)?;
        let v = u32::from_le_bytes(u32buf);
        if v as usize >= n {
            return Err(GraphLoadError::AdjOutOfRange {
                index: i,
                value: v,
                n_vertices: n,
            });
        }
        adj.push(v);
    }
    Ok(Graph {
        offsets,
        adj,
        n_edges,
    })
}

/// Load `path` if it exists, else run `gen`, cache to `path`, and return.
pub fn load_or_generate(path: &Path, gen: impl FnOnce() -> Graph) -> Result<Graph> {
    if path.exists() {
        load_binary(path).with_context(|| format!("load cached graph {}", path.display()))
    } else {
        let g = gen();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        save_binary(&g, path)?;
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::graph_from_edges;

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("harpsg_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn edge_list_roundtrip() {
        let p = tmp("el.txt");
        std::fs::write(&p, "# comment\n0 1\n1 2\n\n% other comment\n2 3\n").unwrap();
        let g = load_edge_list(&p).unwrap();
        assert_eq!(g.n_edges, 3);
        assert_eq!(g.neighbors(1), &[0, 2]);
    }

    #[test]
    fn edge_list_bad_line_errors() {
        let p = tmp("bad.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(load_edge_list(&p).is_err());
    }

    #[test]
    fn binary_roundtrip() {
        let g = graph_from_edges(5, &[(0, 1), (1, 2), (3, 4), (0, 4)]);
        let p = tmp("g.bin");
        save_binary(&g, &p).unwrap();
        let g2 = load_binary(&p).unwrap();
        assert_eq!(g.offsets, g2.offsets);
        assert_eq!(g.adj, g2.adj);
        assert_eq!(g.n_edges, g2.n_edges);
    }

    #[test]
    fn binary_rejects_garbage() {
        let p = tmp("garbage.bin");
        std::fs::write(&p, b"NOTAGRPH........").unwrap();
        assert!(matches!(load_binary(&p), Err(GraphLoadError::BadMagic)));
    }

    /// Satellite: corrupt-file fixtures — every structural invariant of
    /// the binary CSR fails with its typed diagnosis, never a panic.
    #[test]
    fn binary_corruption_is_typed() {
        let g = graph_from_edges(5, &[(0, 1), (1, 2), (3, 4), (0, 4)]);
        let p = tmp("corrupt_base.bin");
        save_binary(&g, &p).unwrap();
        let good = std::fs::read(&p).unwrap();
        // layout: magic 8 | n 8 | n_edges 8 | offsets (n+1)·8 | adj ·4
        let off0 = 24usize;
        let adj0 = off0 + (g.n_vertices() + 1) * 8;
        let t = tmp("corrupt_mut.bin");

        // truncated payload: the last adjacency entry is missing
        std::fs::write(&t, &good[..good.len() - 4]).unwrap();
        match load_binary(&t) {
            Err(GraphLoadError::Truncated { expected, actual }) => {
                assert_eq!(expected as usize, good.len());
                assert_eq!(actual as usize, good.len() - 4);
            }
            other => panic!("want Truncated, got {other:?}"),
        }

        // trailing garbage is corruption too, not silently ignored
        let mut longer = good.clone();
        longer.extend_from_slice(&[0u8; 3]);
        std::fs::write(&t, &longer).unwrap();
        assert!(matches!(
            load_binary(&t),
            Err(GraphLoadError::Truncated { .. })
        ));

        // offsets must start at 0…
        let mut bad = good.clone();
        bad[off0..off0 + 8].copy_from_slice(&1u64.to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        assert!(matches!(
            load_binary(&t),
            Err(GraphLoadError::NonMonotoneOffsets { index: 0 })
        ));

        // …and never decrease: a spiked offsets[1] trips the next index
        let mut bad = good.clone();
        bad[off0 + 8..off0 + 16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        assert!(matches!(
            load_binary(&t),
            Err(GraphLoadError::NonMonotoneOffsets { index: 2 })
        ));

        // adjacency entries must name real vertices
        let mut bad = good.clone();
        bad[adj0..adj0 + 4].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        match load_binary(&t) {
            Err(GraphLoadError::AdjOutOfRange {
                index,
                value,
                n_vertices,
            }) => {
                assert_eq!(index, 0);
                assert_eq!(value, 99);
                assert_eq!(n_vertices, 5);
            }
            other => panic!("want AdjOutOfRange, got {other:?}"),
        }

        // header edge count must match the adjacency total (2 per edge)
        let mut bad = good.clone();
        bad[16..24].copy_from_slice(&5u64.to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        assert!(matches!(
            load_binary(&t),
            Err(GraphLoadError::EdgeCountMismatch {
                header: 5,
                adjacency: 8
            })
        ));

        // a header-declared size too large for the file cannot allocate:
        // an overflowing vertex count is its own typed diagnosis…
        let mut bad = good.clone();
        bad[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        assert!(matches!(load_binary(&t), Err(GraphLoadError::SizeOverflow)));
        // …and a merely-huge one reports the real expected length
        let mut bad = good.clone();
        bad[8..16].copy_from_slice(&(1u64 << 40).to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        match load_binary(&t) {
            Err(GraphLoadError::Truncated { expected, actual }) => {
                assert_eq!(expected, 24 + ((1u64 << 40) + 1) * 8);
                assert_eq!(actual as usize, good.len());
            }
            other => panic!("want Truncated, got {other:?}"),
        }

        // the untouched baseline still loads
        let ok = load_binary(&p).unwrap();
        assert_eq!(ok.adj, g.adj);
    }

    #[test]
    fn load_or_generate_caches() {
        let p = tmp("cache.bin");
        let _ = std::fs::remove_file(&p);
        let g1 = load_or_generate(&p, || graph_from_edges(3, &[(0, 1), (1, 2)])).unwrap();
        assert!(p.exists());
        // second load must come from cache (generator panics if called)
        let g2 = load_or_generate(&p, || panic!("generator re-invoked")).unwrap();
        assert_eq!(g1.adj, g2.adj);
    }
}
