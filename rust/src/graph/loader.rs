//! Graph loaders: whitespace/comment-tolerant edge-list text (the format
//! SNAP datasets and FASCIA use) and a fast little-endian binary format
//! for caching generated analogs between runs.

use super::csr::{Graph, GraphBuilder};
use anyhow::{Context, Result};
use std::fmt;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Typed corruption diagnostics for the `HARPSG01` binary graph format.
/// Every structural invariant of the CSR payload is checked up front so a
/// corrupt cache file fails here with a precise reason instead of
/// panicking later inside the engine (out-of-bounds rows, bogus slices).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphLoadError {
    /// the 8-byte magic is not `HARPSG01` (or the expected segment magic)
    BadMagic,
    /// an I/O failure while opening or reading: the [`std::io::ErrorKind`]
    /// is preserved so callers can tell ENOENT from a short read on a
    /// shard segment, and the detail string carries the path
    Io {
        kind: std::io::ErrorKind,
        detail: String,
    },
    /// the file is shorter (or longer) than the header-declared payload
    Truncated { expected: u64, actual: u64 },
    /// a header-declared size (vertex count or adjacency total) is so
    /// large the payload length overflows u64 — no real file matches
    SizeOverflow,
    /// `offsets` must start at 0 and be non-decreasing
    NonMonotoneOffsets { index: usize },
    /// an adjacency entry names a vertex ≥ n_vertices
    AdjOutOfRange {
        index: usize,
        value: u32,
        n_vertices: usize,
    },
    /// `offsets[n]` disagrees with the header's undirected edge count
    /// (a valid CSR stores each edge in both endpoint lists)
    EdgeCountMismatch { header: u64, adjacency: u64 },
    /// a neighbor row contains its own vertex — the engine's treelet DP
    /// assumes simple graphs, and a self-loop double-counts in Eq 5
    SelfLoop { vertex: u32 },
    /// a neighbor row repeats an entry — a duplicate edge double-counts
    DuplicateNeighbor { vertex: u32, value: u32 },
    /// a neighbor row is not strictly ascending (every builder output is
    /// sorted; unsorted rows break the exchange's binary searches)
    UnsortedNeighbors { vertex: u32 },
    /// a per-rank segment file disagrees with its shared shard header or
    /// with the partition it claims to implement
    SegmentMismatch { rank: usize, detail: String },
}

impl fmt::Display for GraphLoadError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GraphLoadError::BadMagic => write!(f, "not a HARPSG01 binary graph"),
            GraphLoadError::Io { kind, detail } => write!(f, "io error ({kind:?}): {detail}"),
            GraphLoadError::Truncated { expected, actual } => write!(
                f,
                "corrupt payload: expected {expected} bytes, file has {actual}"
            ),
            GraphLoadError::SizeOverflow => {
                write!(f, "corrupt header: declared sizes overflow u64")
            }
            GraphLoadError::NonMonotoneOffsets { index } => {
                write!(f, "corrupt CSR: offsets[{index}] breaks monotonicity")
            }
            GraphLoadError::AdjOutOfRange {
                index,
                value,
                n_vertices,
            } => write!(
                f,
                "corrupt CSR: adj[{index}] = {value} out of range for {n_vertices} vertices"
            ),
            GraphLoadError::EdgeCountMismatch { header, adjacency } => write!(
                f,
                "corrupt CSR: header claims {header} edges but the adjacency \
                 holds {adjacency} entries (expected 2x)"
            ),
            GraphLoadError::SelfLoop { vertex } => {
                write!(f, "corrupt CSR: vertex {vertex} lists itself as a neighbor")
            }
            GraphLoadError::DuplicateNeighbor { vertex, value } => {
                write!(f, "corrupt CSR: vertex {vertex} lists neighbor {value} twice")
            }
            GraphLoadError::UnsortedNeighbors { vertex } => {
                write!(f, "corrupt CSR: vertex {vertex}'s neighbor row is unsorted")
            }
            GraphLoadError::SegmentMismatch { rank, detail } => {
                write!(f, "corrupt shard segment {rank}: {detail}")
            }
        }
    }
}

impl std::error::Error for GraphLoadError {}

/// Annotate an I/O failure with the path it happened on, preserving the
/// [`std::io::ErrorKind`] for typed matching (ENOENT vs short read).
pub(crate) fn io_error(path: &Path, e: std::io::Error) -> GraphLoadError {
    GraphLoadError::Io {
        kind: e.kind(),
        detail: format!("{}: {e}", path.display()),
    }
}

/// Validate the per-row invariants of a CSR payload: every neighbor row
/// strictly ascending (no duplicate edges — they double-count in the DP
/// and skew the Eq 5 `avg_degree` model input) and free of self-loops.
/// `row_vertex` maps a row index to the global vertex id it stores (the
/// identity for a resident CSR, `locals[row]` for a shard segment).
pub(crate) fn validate_rows(
    offsets: &[u64],
    adj: &[u32],
    row_vertex: impl Fn(usize) -> u32,
) -> Result<(), GraphLoadError> {
    for r in 0..offsets.len().saturating_sub(1) {
        let v = row_vertex(r);
        let row = &adj[offsets[r] as usize..offsets[r + 1] as usize];
        let mut prev: Option<u32> = None;
        for &u in row {
            if u == v {
                return Err(GraphLoadError::SelfLoop { vertex: v });
            }
            match prev {
                Some(p) if u == p => {
                    return Err(GraphLoadError::DuplicateNeighbor { vertex: v, value: u })
                }
                Some(p) if u < p => return Err(GraphLoadError::UnsortedNeighbors { vertex: v }),
                _ => {}
            }
            prev = Some(u);
        }
    }
    Ok(())
}

/// Load an edge-list text file: one `u v` pair per line; lines starting
/// with `#` or `%` are comments; blank lines ignored.
///
/// **Duplicate/self-loop policy:** the loader funnels every pair through
/// [`GraphBuilder`], which *drops* self-loops (`u == v`) and *dedupes*
/// repeated edges in either orientation (`u v` and `v u` are the same
/// undirected edge). Real SNAP dumps repeat edges freely; keeping them
/// would double-count in the CSR and skew the `avg_degree` input to the
/// Eq 5 cost model, so the simple-graph normal form is enforced here
/// rather than rejected. Binary and shard loads *verify* the same
/// invariants instead (typed [`GraphLoadError::DuplicateNeighbor`] /
/// [`GraphLoadError::SelfLoop`]) because those files claim to already be
/// in normal form.
pub fn load_edge_list(path: &Path) -> Result<Graph> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut b = GraphBuilder::new(0);
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let u: u32 = it
            .next()
            .context("missing u")?
            .parse()
            .with_context(|| format!("line {}: bad u", lineno + 1))?;
        let v: u32 = it
            .next()
            .context("missing v")?
            .parse()
            .with_context(|| format!("line {}: bad v", lineno + 1))?;
        b.add_edge(u, v);
    }
    Ok(b.build())
}

const BIN_MAGIC: &[u8; 8] = b"HARPSG01";

/// Read and validate the `HARPSG01` header + offsets section: magic,
/// header-vs-file length (truncation *and* trailing garbage, checked
/// against the declared sizes *before* allocating — a corrupt header must
/// not drive a huge allocation), monotone offsets starting at 0, and the
/// 2·n_edges adjacency total. Shared by [`load_binary`] and the
/// storage-sharding rewrite in [`crate::graph::partition::shard_binary`];
/// the reader is left positioned at the adjacency section. Returns
/// `(n_vertices, n_edges, offsets)`.
pub(crate) fn read_csr_header<R: Read>(
    r: &mut R,
    file_len: u64,
    path: &Path,
) -> Result<(usize, u64, Vec<u64>), GraphLoadError> {
    let io_err = |e: std::io::Error| io_error(path, e);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).map_err(io_err)?;
    if &magic != BIN_MAGIC {
        return Err(GraphLoadError::BadMagic);
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf).map_err(io_err)?;
    let n64 = u64::from_le_bytes(u64buf);
    r.read_exact(&mut u64buf).map_err(io_err)?;
    let n_edges = u64::from_le_bytes(u64buf);

    const HEADER_LEN: u64 = 8 + 8 + 8;
    let offsets_bytes = n64
        .checked_add(1)
        .and_then(|c| c.checked_mul(8))
        .ok_or(GraphLoadError::SizeOverflow)?;
    let min_len = HEADER_LEN
        .checked_add(offsets_bytes)
        .ok_or(GraphLoadError::SizeOverflow)?;
    if file_len < min_len {
        return Err(GraphLoadError::Truncated {
            expected: min_len,
            actual: file_len,
        });
    }
    let n = n64 as usize;

    let mut offsets = Vec::with_capacity(n + 1);
    for i in 0..=n {
        r.read_exact(&mut u64buf).map_err(io_err)?;
        let o = u64::from_le_bytes(u64buf);
        let floor = offsets.last().copied().unwrap_or(0);
        if (i == 0 && o != 0) || o < floor {
            return Err(GraphLoadError::NonMonotoneOffsets { index: i });
        }
        offsets.push(o);
    }
    let total = offsets[n];
    let expected_len = min_len
        .checked_add(total.checked_mul(4).ok_or(GraphLoadError::SizeOverflow)?)
        .ok_or(GraphLoadError::SizeOverflow)?;
    if file_len != expected_len {
        return Err(GraphLoadError::Truncated {
            expected: expected_len,
            actual: file_len,
        });
    }
    // each undirected edge sits in both endpoints' neighbor lists
    if n_edges.checked_mul(2) != Some(total) {
        return Err(GraphLoadError::EdgeCountMismatch {
            header: n_edges,
            adjacency: total,
        });
    }
    Ok((n, n_edges, offsets))
}

/// Write the CSR arrays as `HARPSG01 | n_vertices u64 | n_edges u64 |
/// offsets[] u64 | adj[] u32`, little-endian.
pub fn save_binary(g: &Graph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.n_vertices() as u64).to_le_bytes())?;
    w.write_all(&g.n_edges.to_le_bytes())?;
    for &o in &g.offsets {
        w.write_all(&o.to_le_bytes())?;
    }
    for &a in &g.adj {
        w.write_all(&a.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Load a `HARPSG01` binary graph, validating every structural invariant
/// before the CSR is handed to the engine: magic, header-vs-file length
/// (truncation *and* trailing garbage), monotone offsets starting at 0,
/// adjacency entries < n_vertices, strictly-ascending neighbor rows with
/// no self-loops, and the 2·n_edges adjacency total. Corruption reports a
/// typed [`GraphLoadError`] instead of a later panic. The same checks run
/// per segment for sharded storage ([`crate::graph::shard`]).
pub fn load_binary(path: &Path) -> Result<Graph, GraphLoadError> {
    let io_err = |e: std::io::Error| io_error(path, e);
    let f = std::fs::File::open(path).map_err(io_err)?;
    let file_len = f.metadata().map_err(io_err)?.len();
    let mut r = BufReader::new(f);
    let (n, n_edges, offsets) = read_csr_header(&mut r, file_len, path)?;
    let total = offsets[n] as usize;
    let mut adj = Vec::with_capacity(total);
    let mut u32buf = [0u8; 4];
    for i in 0..total {
        r.read_exact(&mut u32buf).map_err(io_err)?;
        let v = u32::from_le_bytes(u32buf);
        if v as usize >= n {
            return Err(GraphLoadError::AdjOutOfRange {
                index: i,
                value: v,
                n_vertices: n,
            });
        }
        adj.push(v);
    }
    validate_rows(&offsets, &adj, |r| r as u32)?;
    Ok(Graph {
        offsets,
        adj,
        n_edges,
    })
}

/// Load `path` if it exists, else run `gen`, cache to `path`, and return.
pub fn load_or_generate(path: &Path, gen: impl FnOnce() -> Graph) -> Result<Graph> {
    if path.exists() {
        load_binary(path).with_context(|| format!("load cached graph {}", path.display()))
    } else {
        let g = gen();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        save_binary(&g, path)?;
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::graph_from_edges;

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("harpsg_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn edge_list_roundtrip() {
        let p = tmp("el.txt");
        std::fs::write(&p, "# comment\n0 1\n1 2\n\n% other comment\n2 3\n").unwrap();
        let g = load_edge_list(&p).unwrap();
        assert_eq!(g.n_edges, 3);
        assert_eq!(g.neighbors(1), &[0, 2]);
    }

    #[test]
    fn edge_list_bad_line_errors() {
        let p = tmp("bad.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(load_edge_list(&p).is_err());
    }

    #[test]
    fn binary_roundtrip() {
        let g = graph_from_edges(5, &[(0, 1), (1, 2), (3, 4), (0, 4)]);
        let p = tmp("g.bin");
        save_binary(&g, &p).unwrap();
        let g2 = load_binary(&p).unwrap();
        assert_eq!(g.offsets, g2.offsets);
        assert_eq!(g.adj, g2.adj);
        assert_eq!(g.n_edges, g2.n_edges);
    }

    /// Satellite: the text loader's documented policy — duplicate edges
    /// (either orientation) collapse to one, self-loops are dropped, and
    /// the resulting degree statistics see the simple graph only.
    #[test]
    fn edge_list_dedupes_and_drops_self_loops() {
        let p = tmp("dups.txt");
        std::fs::write(&p, "0 1\n1 0\n0 1\n2 2\n1 2\n2 1\n").unwrap();
        let g = load_edge_list(&p).unwrap();
        assert_eq!(g.n_edges, 2); // {0,1} and {1,2}; 2-2 dropped
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.neighbors(2), &[1]);
        let st = crate::graph::stats::degree_stats(&g);
        assert!((st.avg_degree - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn binary_rejects_garbage() {
        let p = tmp("garbage.bin");
        std::fs::write(&p, b"NOTAGRPH........").unwrap();
        assert!(matches!(load_binary(&p), Err(GraphLoadError::BadMagic)));
    }

    /// Satellite: `GraphLoadError::Io` carries the `io::ErrorKind`, so a
    /// missing file and a short read are distinguishable by type.
    #[test]
    fn io_errors_carry_kind() {
        match load_binary(&tmp("does_not_exist.bin")) {
            Err(GraphLoadError::Io { kind, detail }) => {
                assert_eq!(kind, std::io::ErrorKind::NotFound);
                assert!(detail.contains("does_not_exist.bin"));
            }
            other => panic!("want Io(NotFound), got {other:?}"),
        }
        // a file too short to even hold the magic dies mid-read_exact
        let p = tmp("stub.bin");
        std::fs::write(&p, b"HARP").unwrap();
        match load_binary(&p) {
            Err(GraphLoadError::Io { kind, .. }) => {
                assert_eq!(kind, std::io::ErrorKind::UnexpectedEof);
            }
            other => panic!("want Io(UnexpectedEof), got {other:?}"),
        }
    }

    /// Satellite: corrupt-file fixtures — every structural invariant of
    /// the binary CSR fails with its typed diagnosis, never a panic.
    #[test]
    fn binary_corruption_is_typed() {
        let g = graph_from_edges(5, &[(0, 1), (1, 2), (3, 4), (0, 4)]);
        let p = tmp("corrupt_base.bin");
        save_binary(&g, &p).unwrap();
        let good = std::fs::read(&p).unwrap();
        // layout: magic 8 | n 8 | n_edges 8 | offsets (n+1)·8 | adj ·4
        let off0 = 24usize;
        let adj0 = off0 + (g.n_vertices() + 1) * 8;
        let t = tmp("corrupt_mut.bin");

        // truncated payload: the last adjacency entry is missing
        std::fs::write(&t, &good[..good.len() - 4]).unwrap();
        match load_binary(&t) {
            Err(GraphLoadError::Truncated { expected, actual }) => {
                assert_eq!(expected as usize, good.len());
                assert_eq!(actual as usize, good.len() - 4);
            }
            other => panic!("want Truncated, got {other:?}"),
        }

        // trailing garbage is corruption too, not silently ignored
        let mut longer = good.clone();
        longer.extend_from_slice(&[0u8; 3]);
        std::fs::write(&t, &longer).unwrap();
        assert!(matches!(
            load_binary(&t),
            Err(GraphLoadError::Truncated { .. })
        ));

        // offsets must start at 0…
        let mut bad = good.clone();
        bad[off0..off0 + 8].copy_from_slice(&1u64.to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        assert!(matches!(
            load_binary(&t),
            Err(GraphLoadError::NonMonotoneOffsets { index: 0 })
        ));

        // …and never decrease: a spiked offsets[1] trips the next index
        let mut bad = good.clone();
        bad[off0 + 8..off0 + 16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        assert!(matches!(
            load_binary(&t),
            Err(GraphLoadError::NonMonotoneOffsets { index: 2 })
        ));

        // adjacency entries must name real vertices
        let mut bad = good.clone();
        bad[adj0..adj0 + 4].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        match load_binary(&t) {
            Err(GraphLoadError::AdjOutOfRange {
                index,
                value,
                n_vertices,
            }) => {
                assert_eq!(index, 0);
                assert_eq!(value, 99);
                assert_eq!(n_vertices, 5);
            }
            other => panic!("want AdjOutOfRange, got {other:?}"),
        }

        // header edge count must match the adjacency total (2 per edge)
        let mut bad = good.clone();
        bad[16..24].copy_from_slice(&5u64.to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        assert!(matches!(
            load_binary(&t),
            Err(GraphLoadError::EdgeCountMismatch {
                header: 5,
                adjacency: 8
            })
        ));

        // a header-declared size too large for the file cannot allocate:
        // an overflowing vertex count is its own typed diagnosis…
        let mut bad = good.clone();
        bad[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        assert!(matches!(load_binary(&t), Err(GraphLoadError::SizeOverflow)));
        // …and a merely-huge one reports the real expected length
        let mut bad = good.clone();
        bad[8..16].copy_from_slice(&(1u64 << 40).to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        match load_binary(&t) {
            Err(GraphLoadError::Truncated { expected, actual }) => {
                assert_eq!(expected, 24 + ((1u64 << 40) + 1) * 8);
                assert_eq!(actual as usize, good.len());
            }
            other => panic!("want Truncated, got {other:?}"),
        }

        // a crafted binary whose rows hold self-loops or duplicate edges
        // would silently double-count; each is its own typed diagnosis.
        // layout of adj for this graph: v0:[1,4] v1:[0,2] v2:[1] v3:[4] v4:[0,3]
        let mut bad = good.clone();
        bad[adj0..adj0 + 4].copy_from_slice(&0u32.to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        assert!(matches!(
            load_binary(&t),
            Err(GraphLoadError::SelfLoop { vertex: 0 })
        ));
        let mut bad = good.clone();
        bad[adj0 + 4..adj0 + 8].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        assert!(matches!(
            load_binary(&t),
            Err(GraphLoadError::DuplicateNeighbor {
                vertex: 0,
                value: 1
            })
        ));
        let mut bad = good.clone();
        bad[adj0 + 8..adj0 + 12].copy_from_slice(&3u32.to_le_bytes());
        std::fs::write(&t, &bad).unwrap();
        assert!(matches!(
            load_binary(&t),
            Err(GraphLoadError::UnsortedNeighbors { vertex: 1 })
        ));

        // the untouched baseline still loads
        let ok = load_binary(&p).unwrap();
        assert_eq!(ok.adj, g.adj);
    }

    #[test]
    fn load_or_generate_caches() {
        let p = tmp("cache.bin");
        let _ = std::fs::remove_file(&p);
        let g1 = load_or_generate(&p, || graph_from_edges(3, &[(0, 1), (1, 2)])).unwrap();
        assert!(p.exists());
        // second load must come from cache (generator panics if called)
        let g2 = load_or_generate(&p, || panic!("generator re-invoked")).unwrap();
        assert_eq!(g1.adj, g2.adj);
    }
}
