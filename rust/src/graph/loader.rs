//! Graph loaders: whitespace/comment-tolerant edge-list text (the format
//! SNAP datasets and FASCIA use) and a fast little-endian binary format
//! for caching generated analogs between runs.

use super::csr::{Graph, GraphBuilder};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Load an edge-list text file: one `u v` pair per line; lines starting
/// with `#` or `%` are comments; blank lines ignored.
pub fn load_edge_list(path: &Path) -> Result<Graph> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut b = GraphBuilder::new(0);
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let u: u32 = it
            .next()
            .context("missing u")?
            .parse()
            .with_context(|| format!("line {}: bad u", lineno + 1))?;
        let v: u32 = it
            .next()
            .context("missing v")?
            .parse()
            .with_context(|| format!("line {}: bad v", lineno + 1))?;
        b.add_edge(u, v);
    }
    Ok(b.build())
}

const BIN_MAGIC: &[u8; 8] = b"HARPSG01";

/// Write the CSR arrays as `HARPSG01 | n_vertices u64 | n_edges u64 |
/// offsets[] u64 | adj[] u32`, little-endian.
pub fn save_binary(g: &Graph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.n_vertices() as u64).to_le_bytes())?;
    w.write_all(&g.n_edges.to_le_bytes())?;
    for &o in &g.offsets {
        w.write_all(&o.to_le_bytes())?;
    }
    for &a in &g.adj {
        w.write_all(&a.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

pub fn load_binary(path: &Path) -> Result<Graph> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        bail!("{}: not a HARPSG01 binary graph", path.display());
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let n = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let n_edges = u64::from_le_bytes(u64buf);
    let mut offsets = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        r.read_exact(&mut u64buf)?;
        offsets.push(u64::from_le_bytes(u64buf));
    }
    let total = offsets[n] as usize;
    let mut adj = Vec::with_capacity(total);
    let mut u32buf = [0u8; 4];
    for _ in 0..total {
        r.read_exact(&mut u32buf)?;
        adj.push(u32::from_le_bytes(u32buf));
    }
    Ok(Graph {
        offsets,
        adj,
        n_edges,
    })
}

/// Load `path` if it exists, else run `gen`, cache to `path`, and return.
pub fn load_or_generate(path: &Path, gen: impl FnOnce() -> Graph) -> Result<Graph> {
    if path.exists() {
        load_binary(path)
    } else {
        let g = gen();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        save_binary(&g, path)?;
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::graph_from_edges;

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("harpsg_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn edge_list_roundtrip() {
        let p = tmp("el.txt");
        std::fs::write(&p, "# comment\n0 1\n1 2\n\n% other comment\n2 3\n").unwrap();
        let g = load_edge_list(&p).unwrap();
        assert_eq!(g.n_edges, 3);
        assert_eq!(g.neighbors(1), &[0, 2]);
    }

    #[test]
    fn edge_list_bad_line_errors() {
        let p = tmp("bad.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(load_edge_list(&p).is_err());
    }

    #[test]
    fn binary_roundtrip() {
        let g = graph_from_edges(5, &[(0, 1), (1, 2), (3, 4), (0, 4)]);
        let p = tmp("g.bin");
        save_binary(&g, &p).unwrap();
        let g2 = load_binary(&p).unwrap();
        assert_eq!(g.offsets, g2.offsets);
        assert_eq!(g.adj, g2.adj);
        assert_eq!(g.n_edges, g2.n_edges);
    }

    #[test]
    fn binary_rejects_garbage() {
        let p = tmp("garbage.bin");
        std::fs::write(&p, b"NOTAGRPH........").unwrap();
        assert!(load_binary(&p).is_err());
    }

    #[test]
    fn load_or_generate_caches() {
        let p = tmp("cache.bin");
        let _ = std::fs::remove_file(&p);
        let g1 = load_or_generate(&p, || graph_from_edges(3, &[(0, 1), (1, 2)])).unwrap();
        assert!(p.exists());
        // second load must come from cache (generator panics if called)
        let g2 = load_or_generate(&p, || panic!("generator re-invoked")).unwrap();
        assert_eq!(g1.adj, g2.adj);
    }
}
