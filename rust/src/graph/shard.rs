//! Out-of-core, partition-sharded graph storage.
//!
//! The paper counts on graphs of 2–5 billion edges; a fully resident CSR
//! shared by every simulated rank is exactly the "whole graph in RAM per
//! rank" assumption the Eq 5/Eq 7 memory analysis rejects. This module
//! breaks it: [`Partition::shard_storage`](super::Partition::shard_storage)
//! rewrites the resident CSR (and [`super::partition::shard_binary`] a
//! `HARPSG01` file, streamed) into **per-rank segment files** under a
//! shared header, and [`SegmentedGraph`] serves each rank *only its own
//! vertex partition's adjacency slice*. Non-resident neighbor rows never
//! need local adjacency — they already travel through the request-list
//! machinery — so the exchange plan is the single consumer of adjacency
//! and the only layer that changes.
//!
//! Storage is selected per job via `--graph-storage resident|mmap|auto`
//! ([`GraphStorageMode`]): `resident` is the historical shared CSR,
//! `mmap` maps each rank's segment through a chunked-file view (plain
//! buffered `std` reads — no OS mmap dependency; segments are loaded one
//! rank at a time and dropped, so peak graph memory is one slice, not the
//! whole graph), and `auto` picks `mmap` exactly when the full CSR
//! exceeds the resident-adjacency budget. The resolved decision and the
//! per-rank slice bytes are charged to the memory ledger
//! (`MemClass::GraphShard`) and surfaced in `JobReport` JSON
//! (`config.graph_storage`, `memory.graph_resident_per_rank`).
//!
//! ## On-disk format
//!
//! Shared header `shards.hdr`:
//! `HARPSGS1 | n_vertices u64 | n_edges u64 | n_ranks u64 |
//!  partition_tag u64 | per-rank (n_local u64, adj_len u64)…`
//!
//! Per-rank segment `seg_<p>.bin`:
//! `HARPSGP1 | rank u64 | n_local u64 | adj_len u64 |
//!  offsets[(n_local+1)·8] | adj[adj_len·4]`
//!
//! all little-endian. Segment offsets are *local-row* offsets; adjacency
//! entries stay global vertex ids; rows appear in `locals[p]` (ascending
//! global id) order. `partition_tag` folds the owner array through
//! [`mix2`] so a segment set can never be silently served for a different
//! partition. Every validation `load_binary` performs on the monolithic
//! file runs segment-aware here — magic, exact length, monotone offsets,
//! adjacency range, row sortedness/self-loops, and cross-file sum checks
//! — failing with the same typed [`GraphLoadError`]s.

use super::csr::Graph;
use super::loader::{io_error, validate_rows, GraphLoadError};
use super::partition::Partition;
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

pub(crate) const HDR_MAGIC: &[u8; 8] = b"HARPSGS1";
pub(crate) const SEG_MAGIC: &[u8; 8] = b"HARPSGP1";

/// Name of the shared shard header inside a shard directory.
pub const SHARD_HEADER_FILE: &str = "shards.hdr";

/// Name of rank `p`'s segment file inside a shard directory.
pub fn segment_file_name(rank: usize) -> String {
    format!("seg_{rank}.bin")
}

/// Which backend serves each rank's adjacency slice (`--graph-storage`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphStorageMode {
    /// the historical fully resident CSR, shared by every simulated rank
    Resident,
    /// per-rank segment files behind a chunked-file view: each rank's
    /// slice is read from disk during plan build and dropped after use
    Mmap,
    /// `mmap` iff the full CSR exceeds the resident-adjacency budget
    Auto,
}

impl GraphStorageMode {
    /// Budget `auto` resolves against when none is configured: 1 GiB.
    pub const DEFAULT_BUDGET: u64 = 1 << 30;

    pub fn name(&self) -> &'static str {
        match self {
            GraphStorageMode::Resident => "resident",
            GraphStorageMode::Mmap => "mmap",
            GraphStorageMode::Auto => "auto",
        }
    }

    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "resident" => Some(GraphStorageMode::Resident),
            "mmap" => Some(GraphStorageMode::Mmap),
            "auto" => Some(GraphStorageMode::Auto),
            _ => None,
        }
    }

    /// Resolve the mode against the full CSR size and the configured
    /// resident-adjacency budget (`None` → [`Self::DEFAULT_BUDGET`]).
    pub fn resolves_to_mmap(&self, graph_bytes: u64, budget: Option<u64>) -> bool {
        match self {
            GraphStorageMode::Resident => false,
            GraphStorageMode::Mmap => true,
            GraphStorageMode::Auto => graph_bytes > budget.unwrap_or(Self::DEFAULT_BUDGET),
        }
    }
}

/// Deterministic fingerprint of a partition's owner array, stored in the
/// shard header so segments are never served for a different partition.
pub fn partition_tag(part: &Partition) -> u64 {
    let mut h = crate::util::mix2(0x5348_4152_4431u64, part.n_ranks as u64);
    for (v, &o) in part.owner.iter().enumerate() {
        h = crate::util::mix2(h, ((v as u64) << 16) | o as u64);
    }
    h
}

/// One rank's adjacency slice, loaded from its segment file: local-row
/// offsets plus global-id neighbor entries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RankCsr {
    pub offsets: Vec<u64>,
    pub adj: Vec<u32>,
}

impl RankCsr {
    #[inline]
    pub fn neighbors(&self, local_row: usize) -> &[u32] {
        &self.adj[self.offsets[local_row] as usize..self.offsets[local_row + 1] as usize]
    }
}

/// A borrowed (resident) or loaded (segment) view of one rank's rows.
pub enum RankView<'a> {
    Resident { g: &'a Graph, locals: &'a [u32] },
    Loaded(RankCsr),
}

impl RankView<'_> {
    /// Neighbor list of the rank's `row`-th local vertex.
    #[inline]
    pub fn neighbors(&self, row: usize) -> &[u32] {
        match self {
            RankView::Resident { g, locals } => g.neighbors(locals[row]),
            RankView::Loaded(c) => c.neighbors(row),
        }
    }
}

/// Storage backend abstraction the exchange-plan build runs against: the
/// resident [`Graph`] and the segment-file [`SegmentedGraph`] both serve
/// per-rank row views and account their per-rank resident bytes.
pub trait GraphStore {
    fn n_vertices(&self) -> usize;
    fn n_edges(&self) -> u64;
    /// resolved backend name recorded in plans and reports
    fn storage_name(&self) -> &'static str;
    /// graph bytes rank `p` keeps resident, charged to the memory ledger
    fn rank_bytes(&self, part: &Partition, p: usize) -> u64;
    /// rank `p`'s adjacency rows, in `part.locals[p]` order
    fn rank_view<'a>(&'a self, part: &'a Partition, p: usize)
        -> Result<RankView<'a>, GraphLoadError>;
}

impl GraphStore for Graph {
    fn n_vertices(&self) -> usize {
        Graph::n_vertices(self)
    }
    fn n_edges(&self) -> u64 {
        self.n_edges
    }
    fn storage_name(&self) -> &'static str {
        "resident"
    }
    fn rank_bytes(&self, part: &Partition, p: usize) -> u64 {
        // historical charge: partition bookkeeping (owner + locals +
        // local_index ≈ 12 B/vertex) plus an even share of the shared CSR
        (part.n_local(p) * 12) as u64 + self.bytes() / part.n_ranks as u64
    }
    fn rank_view<'a>(
        &'a self,
        part: &'a Partition,
        p: usize,
    ) -> Result<RankView<'a>, GraphLoadError> {
        Ok(RankView::Resident {
            g: self,
            locals: &part.locals[p],
        })
    }
}

/// Per-rank segment metadata from the shared header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegMeta {
    pub n_local: u64,
    pub adj_len: u64,
}

/// A partition-sharded graph on disk: a directory of per-rank segment
/// files plus the shared header. Opening validates the header; each
/// rank's slice is loaded (and fully re-validated) on demand.
#[derive(Debug)]
pub struct SegmentedGraph {
    dir: PathBuf,
    n_vertices: usize,
    n_edges: u64,
    n_ranks: usize,
    partition_tag: u64,
    pub segs: Vec<SegMeta>,
    /// scratch shards remove their directory on drop
    cleanup: bool,
}

impl SegmentedGraph {
    /// Open and validate the shared header under `dir`.
    pub fn open(dir: &Path) -> Result<Self, GraphLoadError> {
        let hp = dir.join(SHARD_HEADER_FILE);
        let buf = std::fs::read(&hp).map_err(|e| io_error(&hp, e))?;
        if buf.len() < 8 || &buf[..8] != HDR_MAGIC {
            return Err(GraphLoadError::BadMagic);
        }
        let rd_u64 = |at: usize| -> Option<u64> {
            buf.get(at..at + 8)
                .map(|b| u64::from_le_bytes(b.try_into().expect("8-byte slice")))
        };
        let n_ranks64 = rd_u64(24).ok_or(GraphLoadError::Truncated {
            expected: 40,
            actual: buf.len() as u64,
        })?;
        let expected = 40u64
            .checked_add(n_ranks64.checked_mul(16).ok_or(GraphLoadError::SizeOverflow)?)
            .ok_or(GraphLoadError::SizeOverflow)?;
        if buf.len() as u64 != expected {
            return Err(GraphLoadError::Truncated {
                expected,
                actual: buf.len() as u64,
            });
        }
        let n64 = rd_u64(8).expect("length checked");
        if n64 > u32::MAX as u64 {
            return Err(GraphLoadError::SizeOverflow);
        }
        let n_edges = rd_u64(16).expect("length checked");
        let tag = rd_u64(32).expect("length checked");
        let n_ranks = n_ranks64 as usize;
        let mut segs = Vec::with_capacity(n_ranks);
        let (mut sum_local, mut sum_adj) = (0u64, 0u64);
        for p in 0..n_ranks {
            let n_local = rd_u64(40 + 16 * p).expect("length checked");
            let adj_len = rd_u64(48 + 16 * p).expect("length checked");
            sum_local = sum_local
                .checked_add(n_local)
                .ok_or(GraphLoadError::SizeOverflow)?;
            sum_adj = sum_adj
                .checked_add(adj_len)
                .ok_or(GraphLoadError::SizeOverflow)?;
            segs.push(SegMeta { n_local, adj_len });
        }
        if sum_local != n64 {
            return Err(GraphLoadError::SegmentMismatch {
                rank: n_ranks,
                detail: format!("segments hold {sum_local} vertices, header claims {n64}"),
            });
        }
        if n_edges.checked_mul(2) != Some(sum_adj) {
            return Err(GraphLoadError::EdgeCountMismatch {
                header: n_edges,
                adjacency: sum_adj,
            });
        }
        Ok(SegmentedGraph {
            dir: dir.to_path_buf(),
            n_vertices: n64 as usize,
            n_edges,
            n_ranks,
            partition_tag: tag,
            segs,
            cleanup: false,
        })
    }

    pub fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Mark this shard set as scratch: the directory is removed on drop.
    pub fn set_cleanup(&mut self, yes: bool) {
        self.cleanup = yes;
    }

    /// Reject a partition other than the one the segments were cut for.
    pub fn verify_partition(&self, part: &Partition) -> Result<(), GraphLoadError> {
        if part.n_ranks != self.n_ranks {
            return Err(GraphLoadError::SegmentMismatch {
                rank: 0,
                detail: format!(
                    "segments cut for {} ranks, partition has {}",
                    self.n_ranks, part.n_ranks
                ),
            });
        }
        if partition_tag(part) != self.partition_tag {
            return Err(GraphLoadError::SegmentMismatch {
                rank: 0,
                detail: "partition tag mismatch: segments were cut for a different \
                         vertex partition"
                    .to_string(),
            });
        }
        Ok(())
    }

    /// Load rank `p`'s segment, validating every invariant the monolithic
    /// loader checks plus the segment-vs-header cross checks. `locals` is
    /// the rank's vertex list, used both as a length cross-check and to
    /// map local rows back to global ids for self-loop detection.
    pub fn load_rank(&self, p: usize, locals: &[u32]) -> Result<RankCsr, GraphLoadError> {
        let meta = self.segs[p];
        let sp = self.dir.join(segment_file_name(p));
        let io_err = |e: std::io::Error| io_error(&sp, e);
        let f = std::fs::File::open(&sp).map_err(io_err)?;
        let file_len = f.metadata().map_err(io_err)?.len();
        let mut r = std::io::BufReader::new(f);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic).map_err(io_err)?;
        if &magic != SEG_MAGIC {
            return Err(GraphLoadError::BadMagic);
        }
        let mut u64buf = [0u8; 8];
        let mut rd = |r: &mut std::io::BufReader<std::fs::File>| -> Result<u64, GraphLoadError> {
            r.read_exact(&mut u64buf).map_err(io_err)?;
            Ok(u64::from_le_bytes(u64buf))
        };
        let rank = rd(&mut r)?;
        let n_local = rd(&mut r)?;
        let adj_len = rd(&mut r)?;
        if rank != p as u64 || n_local != meta.n_local || adj_len != meta.adj_len {
            return Err(GraphLoadError::SegmentMismatch {
                rank: p,
                detail: format!(
                    "segment header (rank {rank}, {n_local} rows, {adj_len} adj) \
                     disagrees with shard header (rank {p}, {} rows, {} adj)",
                    meta.n_local, meta.adj_len
                ),
            });
        }
        if n_local != locals.len() as u64 {
            return Err(GraphLoadError::SegmentMismatch {
                rank: p,
                detail: format!(
                    "segment holds {n_local} rows, partition assigns {}",
                    locals.len()
                ),
            });
        }
        // exact length before allocating, same alloc-guard as load_binary
        let expected = 32u64
            .checked_add(
                n_local
                    .checked_add(1)
                    .and_then(|c| c.checked_mul(8))
                    .ok_or(GraphLoadError::SizeOverflow)?,
            )
            .and_then(|b| b.checked_add(adj_len.checked_mul(4)?))
            .ok_or(GraphLoadError::SizeOverflow)?;
        if file_len != expected {
            return Err(GraphLoadError::Truncated {
                expected,
                actual: file_len,
            });
        }
        let rows = n_local as usize;
        let mut offsets = Vec::with_capacity(rows + 1);
        let mut u64buf = [0u8; 8];
        for i in 0..=rows {
            r.read_exact(&mut u64buf).map_err(io_err)?;
            let o = u64::from_le_bytes(u64buf);
            let floor = offsets.last().copied().unwrap_or(0);
            if (i == 0 && o != 0) || o < floor {
                return Err(GraphLoadError::NonMonotoneOffsets { index: i });
            }
            offsets.push(o);
        }
        if offsets[rows] != adj_len {
            return Err(GraphLoadError::SegmentMismatch {
                rank: p,
                detail: format!(
                    "row offsets end at {} but the segment declares {adj_len} \
                     adjacency entries",
                    offsets[rows]
                ),
            });
        }
        let total = adj_len as usize;
        let mut adj = Vec::with_capacity(total);
        let mut u32buf = [0u8; 4];
        for i in 0..total {
            r.read_exact(&mut u32buf).map_err(io_err)?;
            let v = u32::from_le_bytes(u32buf);
            if v as usize >= self.n_vertices {
                return Err(GraphLoadError::AdjOutOfRange {
                    index: i,
                    value: v,
                    n_vertices: self.n_vertices,
                });
            }
            adj.push(v);
        }
        validate_rows(&offsets, &adj, |row| locals[row])?;
        Ok(RankCsr { offsets, adj })
    }
}

impl GraphStore for SegmentedGraph {
    fn n_vertices(&self) -> usize {
        self.n_vertices
    }
    fn n_edges(&self) -> u64 {
        self.n_edges
    }
    fn storage_name(&self) -> &'static str {
        "mmap"
    }
    fn rank_bytes(&self, part: &Partition, p: usize) -> u64 {
        // partition bookkeeping plus this rank's own slice only — the
        // partition-proportional bound the ledger verifies
        let n_local = part.n_local(p) as u64;
        n_local * 12 + (n_local + 1) * 8 + self.segs[p].adj_len * 4
    }
    fn rank_view<'a>(
        &'a self,
        part: &'a Partition,
        p: usize,
    ) -> Result<RankView<'a>, GraphLoadError> {
        self.load_rank(p, &part.locals[p]).map(RankView::Loaded)
    }
}

impl Drop for SegmentedGraph {
    fn drop(&mut self) {
        if self.cleanup {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }
}

/// Write the shared header for an already-cut segment set.
pub(crate) fn write_header(
    dir: &Path,
    n_vertices: u64,
    n_edges: u64,
    tag: u64,
    segs: &[SegMeta],
) -> Result<(), GraphLoadError> {
    let hp = dir.join(SHARD_HEADER_FILE);
    let io_err = |e: std::io::Error| io_error(&hp, e);
    let f = std::fs::File::create(&hp).map_err(io_err)?;
    let mut w = BufWriter::new(f);
    let mut write = |b: &[u8]| w.write_all(b).map_err(io_err);
    write(HDR_MAGIC)?;
    write(&n_vertices.to_le_bytes())?;
    write(&n_edges.to_le_bytes())?;
    write(&(segs.len() as u64).to_le_bytes())?;
    write(&tag.to_le_bytes())?;
    for s in segs {
        write(&s.n_local.to_le_bytes())?;
        write(&s.adj_len.to_le_bytes())?;
    }
    w.flush().map_err(io_err)
}

/// Cut a resident CSR into per-rank segment files under `dir`.
pub(crate) fn write_segments(
    g: &Graph,
    part: &Partition,
    dir: &Path,
) -> Result<(), GraphLoadError> {
    std::fs::create_dir_all(dir).map_err(|e| io_error(dir, e))?;
    let mut segs = Vec::with_capacity(part.n_ranks);
    for p in 0..part.n_ranks {
        let sp = dir.join(segment_file_name(p));
        let io_err = |e: std::io::Error| io_error(&sp, e);
        let f = std::fs::File::create(&sp).map_err(io_err)?;
        let mut w = BufWriter::new(f);
        let adj_len: u64 = part.locals[p]
            .iter()
            .map(|&v| g.neighbors(v).len() as u64)
            .sum();
        w.write_all(SEG_MAGIC).map_err(io_err)?;
        w.write_all(&(p as u64).to_le_bytes()).map_err(io_err)?;
        w.write_all(&(part.locals[p].len() as u64).to_le_bytes())
            .map_err(io_err)?;
        w.write_all(&adj_len.to_le_bytes()).map_err(io_err)?;
        let mut off = 0u64;
        w.write_all(&off.to_le_bytes()).map_err(io_err)?;
        for &v in &part.locals[p] {
            off += g.neighbors(v).len() as u64;
            w.write_all(&off.to_le_bytes()).map_err(io_err)?;
        }
        for &v in &part.locals[p] {
            for &u in g.neighbors(v) {
                w.write_all(&u.to_le_bytes()).map_err(io_err)?;
            }
        }
        w.flush().map_err(io_err)?;
        segs.push(SegMeta {
            n_local: part.locals[p].len() as u64,
            adj_len,
        });
    }
    write_header(
        dir,
        g.n_vertices() as u64,
        g.n_edges,
        partition_tag(part),
        &segs,
    )
}

/// Cut a resident CSR into a fresh scratch directory under the system
/// temp dir; the returned [`SegmentedGraph`] removes it on drop.
pub fn shard_to_scratch(g: &Graph, part: &Partition) -> Result<SegmentedGraph, GraphLoadError> {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    let dir = std::env::temp_dir().join(format!(
        "harpsg-shards-{}-{:x}-{:x}-{}",
        std::process::id(),
        nanos,
        g as *const Graph as usize,
        part.n_ranks
    ));
    write_segments(g, part, &dir)?;
    let mut seg = SegmentedGraph::open(&dir)?;
    seg.set_cleanup(true);
    Ok(seg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::graph_from_edges;
    use crate::graph::rmat::{generate, RmatParams};

    #[test]
    fn storage_mode_parse_name_roundtrip() {
        for m in [
            GraphStorageMode::Resident,
            GraphStorageMode::Mmap,
            GraphStorageMode::Auto,
        ] {
            assert_eq!(GraphStorageMode::parse(m.name()), Some(m));
        }
        assert_eq!(GraphStorageMode::parse("disk"), None);
    }

    #[test]
    fn auto_resolves_against_budget() {
        use GraphStorageMode::*;
        assert!(!Resident.resolves_to_mmap(u64::MAX, Some(1)));
        assert!(Mmap.resolves_to_mmap(0, Some(u64::MAX)));
        assert!(Auto.resolves_to_mmap(101, Some(100)));
        assert!(!Auto.resolves_to_mmap(100, Some(100)));
        assert!(!Auto.resolves_to_mmap(GraphStorageMode::DEFAULT_BUDGET, None));
    }

    #[test]
    fn shard_roundtrip_matches_resident_rows() {
        let g = generate(&RmatParams::with_skew(200, 600, 3, 11));
        for ranks in [1usize, 2, 5, 6] {
            let part = Partition::random(g.n_vertices(), ranks, 7);
            let seg = shard_to_scratch(&g, &part).unwrap();
            seg.verify_partition(&part).unwrap();
            assert_eq!(GraphStore::n_vertices(&seg), g.n_vertices());
            assert_eq!(GraphStore::n_edges(&seg), g.n_edges);
            for p in 0..ranks {
                let c = seg.load_rank(p, &part.locals[p]).unwrap();
                for (r, &v) in part.locals[p].iter().enumerate() {
                    assert_eq!(c.neighbors(r), g.neighbors(v), "rank {p} row {r}");
                }
                // the slice charge is partition-proportional, not n_ranks⁻¹
                let want =
                    (part.n_local(p) as u64) * 12 + (part.n_local(p) as u64 + 1) * 8
                        + c.adj.len() as u64 * 4;
                assert_eq!(seg.rank_bytes(&part, p), want);
            }
        }
    }

    #[test]
    fn segments_reject_foreign_partition() {
        let g = graph_from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]);
        let part = Partition::random(6, 2, 7);
        let seg = shard_to_scratch(&g, &part).unwrap();
        let other = Partition::random(6, 2, 8);
        assert!(matches!(
            seg.verify_partition(&other),
            Err(GraphLoadError::SegmentMismatch { .. })
        ));
        let three = Partition::random(6, 3, 7);
        assert!(matches!(
            seg.verify_partition(&three),
            Err(GraphLoadError::SegmentMismatch { .. })
        ));
    }
}
