//! Machine-readable run reports. `JobReport` is a strict superset of the
//! coordinator's `RunResult`: it adds the template complexity, the
//! per-subtemplate comm-mode decisions, the graph shape and the session
//! setup accounting, and it serializes to JSON (via the in-repo
//! `util::Json` writer) and CSV (via `metrics::Series`).

use crate::colorcount::ExecStats;
use crate::coordinator::{
    CommDecision, ModelTime, PruneStats, RankLink, RunResult, StorageDecision, ThreadStats,
};
use crate::graph::Graph;
use crate::metrics::Series;
use crate::pipeline::MeasuredPipeline;
use crate::template::{complexity, TemplateComplexity};
use crate::util::Json;

use super::job::CountJob;

/// Everything a run produced, in one serializable value.
#[derive(Debug, Clone)]
pub struct JobReport {
    /// template name (builtin id or file path)
    pub template: String,
    /// template vertex count k
    pub k: usize,
    /// Table-3 complexity (memory, computation, intensity)
    pub complexity: TemplateComplexity,
    pub graph_vertices: usize,
    pub graph_edges: u64,
    /// Table-1 mode name (e.g. "AdaptiveLB")
    pub mode: String,
    /// combine backend name ("native" | "xla")
    pub engine: String,
    /// exchange executor name ("threaded" | "sequential")
    pub exchange: String,
    /// count-table storage mode ("dense" | "sparse" | "auto")
    pub table_storage: String,
    /// combine kernel ("scalar" | "simd" | "auto")
    pub kernel: String,
    /// frontier-pruning mode the job requested ("on" | "off" | "auto")
    pub prune_mode: String,
    /// resolved graph-storage backend ("resident" | "mmap") — the run's
    /// actual decision, `auto` never survives to the report
    pub graph_storage: String,
    /// rank transport the job selected ("threaded" | "socket")
    pub fabric: String,
    /// measured per-rank link parameters — the OLS fit of real wall-clock
    /// send timings against the Hockney model (socket fabric only; empty
    /// when the in-process mailbox carried the exchange)
    pub link: Vec<RankLink>,
    /// graph bytes each rank kept resident, as charged to the ledger
    pub graph_resident_per_rank: Vec<u64>,
    /// model-driven per-subtemplate group selection was enabled
    pub adaptive: bool,
    pub n_ranks: usize,
    pub n_threads: usize,
    /// configured real combine-executor threads (`--workers`)
    pub n_workers: usize,
    pub n_iterations: usize,
    pub seed: u64,
    pub task_size: u32,
    /// the subgraph-count estimate (median of means over iterations)
    pub estimate: f64,
    /// per-iteration estimates
    pub samples: Vec<f64>,
    /// per-iteration raw colorful counts (exactness cross-checks)
    pub colorful: Vec<f64>,
    pub model: ModelTime,
    /// exchange schedule chosen per non-leaf subtemplate
    pub comm_decisions: Vec<CommDecision>,
    /// modeled (virtual-replay) thread stats — the Fig-11 reconstruction
    pub threads: ThreadStats,
    /// *measured* per-worker record of the real combine executor (busy
    /// seconds, tasks, pairs per worker) — see `colorcount::parallel`
    pub workers: ExecStats,
    /// *measured* pipeline record of the rank-parallel exchange executor
    /// (real per-step overlap ρ, exposed wait, per-rank receive-buffer
    /// peaks); `None` when the sequential executor ran
    pub measured: Option<MeasuredPipeline>,
    /// per-subtemplate storage outcome (final iteration): measured
    /// density, chosen representation, resident vs dense-layout bytes
    pub storage: Vec<StorageDecision>,
    /// per-subtemplate frontier-pruning outcome (final iteration):
    /// measured frontier occupancy and the skip tallies across the
    /// aggregate/contract/exchange legs (all zeros with pruning off)
    pub prune: Vec<PruneStats>,
    pub peak_mem_per_rank: Vec<u64>,
    /// per-rank peaks under the unconditional dense layout (the baseline
    /// the `bytes_saved` delta is measured against)
    pub peak_mem_dense_per_rank: Vec<u64>,
    /// measured seconds per compute unit
    pub flop_time: f64,
    /// real single-core wall-clock of the run, seconds
    pub real_seconds: f64,
    pub oom: bool,
    /// true when the session served the partition/request lists from its
    /// cache instead of rebuilding them
    pub setup_reused: bool,
    /// seconds spent building or fetching the exchange plan
    pub setup_seconds: f64,
}

impl JobReport {
    /// Assemble a report from a finished run. Public (not just
    /// crate-internal) because the process-mode launcher path composes
    /// reports outside the `Session` — from the merged [`RunResult`] of
    /// `coordinator::procmode::launch`.
    pub fn from_run(
        job: &CountJob,
        g: &Graph,
        r: RunResult,
        setup_reused: bool,
        setup_seconds: f64,
    ) -> JobReport {
        JobReport {
            template: job.template.name.clone(),
            k: job.template.size(),
            complexity: complexity(&job.template),
            graph_vertices: g.n_vertices(),
            graph_edges: g.n_edges,
            mode: job.cfg.mode.name().to_string(),
            engine: job.cfg.engine.name().to_string(),
            exchange: job.cfg.exchange.name().to_string(),
            table_storage: job.cfg.table_storage.name().to_string(),
            kernel: job.cfg.kernel.name().to_string(),
            prune_mode: job.cfg.prune.name().to_string(),
            graph_storage: r.graph_storage,
            fabric: job.cfg.fabric.name().to_string(),
            link: r.link,
            graph_resident_per_rank: r.graph_resident_per_rank,
            adaptive: job.cfg.adaptive_group,
            n_ranks: job.cfg.n_ranks,
            n_threads: job.cfg.n_threads,
            n_workers: job.cfg.n_workers,
            n_iterations: job.cfg.n_iterations,
            seed: job.cfg.seed,
            task_size: job.cfg.effective_task_size(),
            estimate: r.estimate,
            samples: r.samples,
            colorful: r.colorful,
            model: r.model,
            comm_decisions: r.comm_decisions,
            threads: r.threads,
            workers: r.workers,
            measured: r.measured,
            storage: r.storage,
            prune: r.prune,
            peak_mem_per_rank: r.peak_mem_per_rank,
            peak_mem_dense_per_rank: r.peak_mem_dense_per_rank,
            flop_time: r.flop_time,
            real_seconds: r.real_seconds,
            oom: r.oom,
            setup_reused,
            setup_seconds,
        }
    }

    /// Largest per-rank peak, bytes (the Fig-12 quantity).
    pub fn peak_mem(&self) -> u64 {
        self.peak_mem_per_rank.iter().copied().max().unwrap_or(0)
    }

    /// Largest per-rank peak under the dense-baseline ledger.
    pub fn peak_mem_dense(&self) -> u64 {
        self.peak_mem_dense_per_rank
            .iter()
            .copied()
            .max()
            .unwrap_or(0)
    }

    /// Peak-memory savings against the dense layout (0 in dense mode).
    pub fn peak_bytes_saved(&self) -> u64 {
        self.peak_mem_dense().saturating_sub(self.peak_mem())
    }

    /// The full report as a JSON value.
    pub fn to_json(&self) -> Json {
        let num_arr = |xs: &[f64]| Json::Arr(xs.iter().map(|&v| Json::Num(v)).collect());
        Json::Obj(vec![
            (
                "template".into(),
                Json::Obj(vec![
                    ("name".into(), Json::Str(self.template.clone())),
                    ("k".into(), Json::Num(self.k as f64)),
                    ("memory".into(), Json::Num(self.complexity.memory as f64)),
                    (
                        "computation".into(),
                        Json::Num(self.complexity.computation as f64),
                    ),
                    ("intensity".into(), Json::Num(self.complexity.intensity)),
                ]),
            ),
            (
                "graph".into(),
                Json::Obj(vec![
                    ("n_vertices".into(), Json::Num(self.graph_vertices as f64)),
                    ("n_edges".into(), Json::Num(self.graph_edges as f64)),
                ]),
            ),
            (
                "config".into(),
                Json::Obj(vec![
                    ("mode".into(), Json::Str(self.mode.clone())),
                    ("engine".into(), Json::Str(self.engine.clone())),
                    ("exchange".into(), Json::Str(self.exchange.clone())),
                    ("table_storage".into(), Json::Str(self.table_storage.clone())),
                    ("kernel".into(), Json::Str(self.kernel.clone())),
                    ("prune".into(), Json::Str(self.prune_mode.clone())),
                    ("graph_storage".into(), Json::Str(self.graph_storage.clone())),
                    ("fabric".into(), Json::Str(self.fabric.clone())),
                    ("adaptive".into(), Json::Bool(self.adaptive)),
                    ("ranks".into(), Json::Num(self.n_ranks as f64)),
                    ("threads".into(), Json::Num(self.n_threads as f64)),
                    ("workers".into(), Json::Num(self.n_workers as f64)),
                    ("iterations".into(), Json::Num(self.n_iterations as f64)),
                    // string, not number: u64 seeds above 2^53 would lose
                    // precision through a JSON double
                    ("seed".into(), Json::Str(self.seed.to_string())),
                    ("task_size".into(), Json::Num(self.task_size as f64)),
                ]),
            ),
            ("estimate".into(), Json::Num(self.estimate)),
            ("samples".into(), num_arr(&self.samples)),
            ("colorful".into(), num_arr(&self.colorful)),
            (
                "model".into(),
                Json::Obj(vec![
                    ("total_s".into(), Json::Num(self.model.total)),
                    ("comp_s".into(), Json::Num(self.model.comp)),
                    ("comm_total_s".into(), Json::Num(self.model.comm_total)),
                    ("comm_exposed_s".into(), Json::Num(self.model.comm_exposed)),
                    ("straggler_s".into(), Json::Num(self.model.straggler)),
                    ("comm_ratio".into(), Json::Num(self.model.comm_ratio())),
                    ("mean_rho".into(), Json::Num(self.model.mean_rho())),
                    (
                        "rho_by_sub".into(),
                        Json::Arr(
                            self.model
                                .rho_by_sub
                                .iter()
                                .map(|&(sub, rho)| {
                                    Json::Obj(vec![
                                        ("sub".into(), Json::Num(sub as f64)),
                                        ("rho".into(), Json::Num(rho)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                // the rank-parallel executor's *measured* overlap record,
                // next to the modeled section above: real per-step ρ
                // (comp / (comp + wait)), the exposed wait the threads
                // actually paid, and the streaming memory bound. `null`
                // when the sequential executor ran.
                "pipeline_measured".into(),
                match &self.measured {
                    None => Json::Null,
                    Some(m) => Json::Obj(vec![
                        (
                            "steps".into(),
                            Json::Arr(
                                m.mean_steps()
                                    .iter()
                                    .enumerate()
                                    .map(|(w, s)| {
                                        Json::Obj(vec![
                                            ("step".into(), Json::Num(w as f64)),
                                            ("comp_s".into(), Json::Num(s.comp_s)),
                                            ("wait_s".into(), Json::Num(s.wait_s)),
                                            ("rho".into(), Json::Num(s.rho())),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                        ("mean_rho".into(), Json::Num(m.mean_rho())),
                        ("comp_s".into(), Json::Num(m.comp_s)),
                        ("exposed_wait_s".into(), Json::Num(m.exposed_wait_s)),
                        ("combines".into(), Json::Num(m.n_combines as f64)),
                        (
                            "recv_peak_per_rank".into(),
                            Json::Arr(
                                m.recv_peak_per_rank
                                    .iter()
                                    .map(|&b| Json::Num(b as f64))
                                    .collect(),
                            ),
                        ),
                        (
                            "max_step_recv_bytes_per_rank".into(),
                            Json::Arr(
                                m.max_step_recv_bytes_per_rank
                                    .iter()
                                    .map(|&b| Json::Num(b as f64))
                                    .collect(),
                            ),
                        ),
                        (
                            "in_flight_peak_bytes".into(),
                            Json::Num(m.in_flight_peak_bytes as f64),
                        ),
                    ]),
                },
            ),
            (
                // per-subtemplate exchange decisions: the chosen shape and
                // the model's predicted overlap next to what the
                // rank-parallel executor measured (`rho_meas` is null for
                // sequential runs and single-step schedules)
                "comm".into(),
                Json::Arr(
                    self.comm_decisions
                        .iter()
                        .map(|d| {
                            Json::Obj(vec![
                                ("sub".into(), Json::Num(d.sub as f64)),
                                ("mode".into(), Json::Str(d.mode_name().to_string())),
                                ("g".into(), Json::Num(d.g as f64)),
                                ("n_steps".into(), Json::Num(d.n_steps as f64)),
                                ("rho_pred".into(), Json::Num(d.predicted_rho)),
                                (
                                    "rho_meas".into(),
                                    match d.measured_rho {
                                        Some(m) => Json::Num(m),
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                // per-subtemplate storage outcome (final iteration): the
                // measured density probe, the representation the policy
                // picked per rank, and the resident-vs-dense byte delta
                "storage".into(),
                Json::Arr(
                    self.storage
                        .iter()
                        .map(|d| {
                            Json::Obj(vec![
                                ("sub".into(), Json::Num(d.sub as f64)),
                                ("density".into(), Json::Num(d.density)),
                                (
                                    "storage".into(),
                                    Json::Str(d.storage_name().to_string()),
                                ),
                                ("dense_bytes".into(), Json::Num(d.dense_bytes as f64)),
                                (
                                    "resident_bytes".into(),
                                    Json::Num(d.resident_bytes as f64),
                                ),
                                ("bytes_saved".into(), Json::Num(d.bytes_saved() as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                // per-subtemplate frontier-pruning outcome (final
                // iteration): the measured live-row fraction of the
                // stored tables and the tallies of work each pruning leg
                // elided — aggregation pairs, contraction rows, and rows
                // dropped from the wire by the masked encoding
                "prune".into(),
                Json::Arr(
                    self.prune
                        .iter()
                        .map(|s| {
                            Json::Obj(vec![
                                ("sub".into(), Json::Num(s.sub as f64)),
                                (
                                    "frontier_occupancy".into(),
                                    Json::Num(s.frontier_occupancy),
                                ),
                                (
                                    "pairs_skipped".into(),
                                    Json::Num(s.pairs_skipped as f64),
                                ),
                                ("rows_skipped".into(), Json::Num(s.rows_skipped as f64)),
                                (
                                    "wire_rows_dropped".into(),
                                    Json::Num(s.wire_rows_dropped as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "threads".into(),
                Json::Obj(vec![
                    (
                        "avg_concurrency".into(),
                        Json::Num(self.threads.avg_concurrency),
                    ),
                    (
                        "concurrency_histogram".into(),
                        num_arr(&self.threads.concurrency_histogram),
                    ),
                ]),
            ),
            (
                // measured (not modeled) combine-executor record
                "workers".into(),
                Json::Obj(vec![
                    ("configured".into(), Json::Num(self.n_workers as f64)),
                    (
                        "busy".into(),
                        Json::Num(self.workers.busy_workers() as f64),
                    ),
                    ("imbalance".into(), Json::Num(self.workers.imbalance())),
                    (
                        "busy_seconds".into(),
                        num_arr(&self.workers.busy_seconds),
                    ),
                    (
                        "tasks".into(),
                        Json::Arr(
                            self.workers
                                .worker_tasks
                                .iter()
                                .map(|&t| Json::Num(t as f64))
                                .collect(),
                        ),
                    ),
                    (
                        "pairs".into(),
                        Json::Arr(
                            self.workers
                                .worker_pairs
                                .iter()
                                .map(|&p| Json::Num(p as f64))
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "memory".into(),
                Json::Obj(vec![
                    (
                        "peak_per_rank".into(),
                        Json::Arr(
                            self.peak_mem_per_rank
                                .iter()
                                .map(|&b| Json::Num(b as f64))
                                .collect(),
                        ),
                    ),
                    ("peak".into(), Json::Num(self.peak_mem() as f64)),
                    // what the unconditional dense layout would have
                    // peaked at (== peak in dense mode), and the delta
                    // the chosen storage saved
                    (
                        "peak_dense_baseline".into(),
                        Json::Num(self.peak_mem_dense() as f64),
                    ),
                    (
                        "bytes_saved".into(),
                        Json::Num(self.peak_bytes_saved() as f64),
                    ),
                    // the graph entry of each rank's ledger: an even CSR
                    // share when resident, the rank's own partition-
                    // proportional segment slice under --graph-storage mmap
                    (
                        "graph_resident_per_rank".into(),
                        Json::Arr(
                            self.graph_resident_per_rank
                                .iter()
                                .map(|&b| Json::Num(b as f64))
                                .collect(),
                        ),
                    ),
                    ("oom".into(), Json::Bool(self.oom)),
                ]),
            ),
            (
                // measured link parameters per rank process: the OLS fit
                // of real wall-clock send timings (α seconds, β
                // seconds/byte) the Hockney calibration loop consumed.
                // Empty for the in-process fabric, which has no wire.
                "link".into(),
                Json::Arr(
                    self.link
                        .iter()
                        .map(|l| {
                            Json::Obj(vec![
                                ("rank".into(), Json::Num(l.rank as f64)),
                                ("alpha_s".into(), Json::Num(l.alpha_s)),
                                ("beta_s_per_byte".into(), Json::Num(l.beta_s_per_byte)),
                                ("samples".into(), Json::Num(l.samples as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "time".into(),
                Json::Obj(vec![
                    ("real_seconds".into(), Json::Num(self.real_seconds)),
                    ("flop_time".into(), Json::Num(self.flop_time)),
                    ("setup_seconds".into(), Json::Num(self.setup_seconds)),
                    ("setup_reused".into(), Json::Bool(self.setup_reused)),
                ]),
            ),
        ])
    }

    /// The JSON report rendered to a string (what `harpsg count --json`
    /// prints).
    pub fn to_json_string(&self) -> String {
        self.to_json().render()
    }

    /// Key metrics as a one-row `metrics::Series` (render with
    /// `to_csv()`/`to_markdown()`); batch callers can merge rows from
    /// several reports with [`JobReport::series_of`].
    pub fn to_series(&self) -> Series {
        Self::series_of(std::slice::from_ref(self))
    }

    /// One row per report, aligned columns — the CSV emitter for batch
    /// sweeps.
    pub fn series_of(reports: &[JobReport]) -> Series {
        let mut s = Series::new(
            "job reports",
            &[
                "k",
                "intensity",
                "estimate",
                "model_total_s",
                "comp_s",
                "comm_exposed_s",
                "mean_rho",
                "peak_mem_mib",
                "real_s",
                "setup_s",
            ],
        );
        s.precision = 6;
        for r in reports {
            s.push_row(
                &r.template,
                vec![
                    r.k as f64,
                    r.complexity.intensity,
                    r.estimate,
                    r.model.total,
                    r.model.comp,
                    r.model.comm_exposed,
                    r.model.mean_rho(),
                    r.peak_mem() as f64 / (1u64 << 20) as f64,
                    r.real_seconds,
                    r.setup_seconds,
                ],
            );
        }
        s
    }
}
